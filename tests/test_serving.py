"""Serving engine: continuous batching == reference generation; metrics;
no block leaks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ServeConfig, get_config
from repro.models.api import build_model
from repro.serving.engine import Request, ServingEngine

KEY = jax.random.PRNGKey(0)


def _reference_generate(model, params, prompt, max_new):
    """Greedy decode with the contiguous cache (oracle)."""
    cache = model.init_decode_cache(1, len(prompt) + max_new + 1)
    tok = None
    for t in prompt:
        logits, cache = model.decode_step(params, cache,
                                          jnp.asarray([t], jnp.int32))
        tok = int(jnp.argmax(logits[0]))
    out = [tok]
    for _ in range(max_new - 1):
        logits, cache = model.decode_step(params, cache,
                                          jnp.asarray([tok], jnp.int32))
        tok = int(jnp.argmax(logits[0]))
        out.append(tok)
    return out


def _make():
    cfg = get_config("qwen2-1.5b").reduced(dtype="float32")
    model = build_model(cfg, remat=False)
    params = model.init(KEY)
    return cfg, model, params


@pytest.mark.slow       # 3 token-by-token oracle generations (~30 s)
def test_engine_matches_reference_generation():
    cfg, model, params = _make()
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, (n,), dtype=np.int32)
               for n in (5, 9, 3)]
    max_new = 6
    serve = ServeConfig(model=cfg.name, kv_block_size=4, max_batch=3)
    engine = ServingEngine(model, params, cfg, serve, num_blocks=64)
    for i, p in enumerate(prompts):
        engine.submit(Request(req_id=i, prompt=p, max_new_tokens=max_new))
    engine.run_until_done()
    assert len(engine.finished) == 3
    for req in engine.finished:
        ref = _reference_generate(model, params, prompts[req.req_id], max_new)
        assert req.output == ref, (req.req_id, req.output, ref)


def test_engine_frees_all_blocks_and_reports_metrics():
    cfg, model, params = _make()
    rng = np.random.default_rng(1)
    serve = ServeConfig(model=cfg.name, kv_block_size=4, max_batch=2)
    engine = ServingEngine(model, params, cfg, serve, num_blocks=48)
    for i in range(5):  # more requests than max_batch -> queueing
        engine.submit(Request(
            req_id=i,
            prompt=rng.integers(0, cfg.vocab_size, (4,), dtype=np.int32),
            max_new_tokens=3))
    engine.run_until_done()
    m = engine.metrics()
    assert m["finished"] == 5
    assert m["blocks_free"] == 48          # no leak
    assert m["mean_ttft_s"] > 0 and m["mean_tpot_s"] >= 0
    assert len(engine._free_slots) == 2    # all slots returned


def test_engine_queues_when_pool_full():
    cfg, model, params = _make()
    rng = np.random.default_rng(2)
    serve = ServeConfig(model=cfg.name, kv_block_size=4, max_batch=4)
    engine = ServingEngine(model, params, cfg, serve, num_blocks=5)
    for i in range(3):
        engine.submit(Request(
            req_id=i,
            prompt=rng.integers(0, cfg.vocab_size, (6,), dtype=np.int32),
            max_new_tokens=2))
    engine.step()
    assert len(engine.waiting) > 0         # pool too small for all at once
    engine.run_until_done()
    assert len(engine.finished) == 3       # but everyone finishes eventually
