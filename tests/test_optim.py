"""Optimizer + schedule unit tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adamw, cosine_warmup, sgd_momentum
from repro.optim.optimizer import (
    apply_updates, clip_by_global_norm, global_norm)


def test_adamw_converges_quadratic():
    opt = adamw(weight_decay=0.0, grad_clip=None)
    params = {"x": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    target = jnp.asarray([1.0, 2.0])

    def loss_fn(p):
        return jnp.sum((p["x"] - target) ** 2)

    for _ in range(300):
        g = jax.grad(loss_fn)(params)
        upd, state, _ = opt.update(g, state, params, 0.05)
        params = apply_updates(params, upd)
    np.testing.assert_allclose(np.asarray(params["x"]), np.asarray(target),
                               atol=1e-2)


def test_adamw_first_step_is_lr_sized():
    """Bias correction: |Δ| ≈ lr on step 1 regardless of grad scale."""
    opt = adamw(weight_decay=0.0, grad_clip=None)
    p = {"x": jnp.asarray([0.0])}
    s = opt.init(p)
    for scale in [1e-3, 1.0, 1e3]:
        upd, _, _ = opt.update({"x": jnp.asarray([scale])}, s, p, 0.1)
        np.testing.assert_allclose(abs(float(upd["x"][0])), 0.1, rtol=1e-3)


def test_weight_decay_shrinks():
    opt = adamw(weight_decay=0.5, grad_clip=None)
    p = {"x": jnp.asarray([10.0])}
    s = opt.init(p)
    upd, _, _ = opt.update({"x": jnp.asarray([0.0])}, s, p, 0.1)
    assert float(upd["x"][0]) < 0  # pulled toward zero


def test_clipping():
    tree = {"a": jnp.ones((4,)) * 3.0}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)
    np.testing.assert_allclose(float(norm), 6.0, rtol=1e-5)


def test_sgd_momentum():
    opt = sgd_momentum(momentum=0.9)
    p = {"x": jnp.asarray([1.0])}
    s = opt.init(p)
    upd1, s, _ = opt.update({"x": jnp.asarray([1.0])}, s, p, 0.1)
    upd2, s, _ = opt.update({"x": jnp.asarray([1.0])}, s, p, 0.1)
    assert abs(float(upd2["x"][0])) > abs(float(upd1["x"][0]))  # momentum


def test_cosine_warmup_shape():
    lr = cosine_warmup(1.0, 10, 100, min_ratio=0.1)
    assert float(lr(jnp.asarray(0))) == 0.0
    np.testing.assert_allclose(float(lr(jnp.asarray(10))), 1.0, rtol=1e-5)
    assert float(lr(jnp.asarray(55))) < 1.0
    np.testing.assert_allclose(float(lr(jnp.asarray(100))), 0.1, rtol=1e-3)


def test_moments_are_f32_under_bf16_params():
    opt = adamw()
    p = {"x": jnp.ones((3,), jnp.bfloat16)}
    s = opt.init(p)
    assert s.m["x"].dtype == jnp.float32
    upd, s2, _ = opt.update({"x": jnp.ones((3,), jnp.bfloat16)}, s, p, 0.1)
    assert upd["x"].dtype == jnp.bfloat16
    assert s2.v["x"].dtype == jnp.float32
