"""Per-arch smoke tests (assignment requirement): reduced same-family
configs, one forward/train step on CPU, output shapes + no NaNs; decode
consistency for every family."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config
from repro.configs import ASSIGNED_LM_ARCHS
from repro.models.api import build_model

# the full per-arch sweep is multi-minute -> excluded from the fast tier
pytestmark = pytest.mark.slow

KEY = jax.random.PRNGKey(0)


def _inputs(cfg, B=2, S=16):
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    extra = None
    if cfg.family == "vlm":
        extra = jax.random.normal(KEY, (B, cfg.vision_tokens, cfg.d_model)) * 0.02
    if cfg.family == "audio":
        extra = jax.random.normal(KEY, (B, cfg.encoder_seq, cfg.d_model)) * 0.02
    return toks, extra


@pytest.mark.parametrize("arch", ASSIGNED_LM_ARCHS)
def test_forward_and_train_step(arch):
    cfg = get_config(arch).reduced(dtype="float32")
    model = build_model(cfg, remat=False)
    params = model.init(KEY)
    toks, extra = _inputs(cfg)
    logits, _ = model.forward(params, toks, extra)
    expect_s = toks.shape[1] + (cfg.vision_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (2, expect_s, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits)).any(), arch
    batch = {"tokens": toks}
    if extra is not None:
        batch["extra_embeds"] = extra
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert gn > 0


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "qwen3-moe-235b-a22b",
                                  "rwkv6-1.6b", "zamba2-2.7b"])
def test_decode_matches_forward(arch):
    cfg = get_config(arch).reduced(dtype="float32")
    if cfg.hybrid_attn_every:
        cfg = dataclasses.replace(cfg, num_layers=4, hybrid_attn_every=2)
    if cfg.moe is not None:
        # decode never drops tokens (full capacity); give the teacher-forced
        # oracle the same guarantee so the comparison is exact
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.num_experts)))
    model = build_model(cfg, remat=False)
    params = model.init(KEY)
    toks, _ = _inputs(cfg, S=12)
    logits, _ = model.forward(params, toks)
    cache = (model.init_decode_cache(2) if cfg.family == "ssm"
             else model.init_decode_cache(2, 16))
    outs = []
    for t in range(8):
        lg, cache = model.decode_step(params, cache, toks[:, t])
        outs.append(lg)
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(logits[:, :8]),
                               rtol=3e-3, atol=3e-3)


def test_whisper_decode_matches_forward():
    cfg = get_config("whisper-tiny").reduced(dtype="float32")
    model = build_model(cfg, remat=False)
    params = model.init(KEY)
    toks, frames = _inputs(cfg, S=12)
    logits, _ = model.forward(params, toks, frames)
    cache = model.prefill_cross(params, model.init_decode_cache(2, 16), frames)
    outs = []
    for t in range(8):
        lg, cache = model.decode_step(params, cache, toks[:, t])
        outs.append(lg)
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(logits[:, :8]),
                               rtol=3e-3, atol=3e-3)


def test_scan_matches_unrolled():
    cfg = get_config("qwen2-1.5b").reduced(dtype="float32")
    toks, _ = _inputs(cfg)
    m_scan = build_model(cfg, remat=False, scan_layers=True)
    m_unroll = build_model(cfg, remat=False, scan_layers=False)
    params = m_scan.init(KEY)
    l1, _ = m_scan.forward(params, toks)
    l2, _ = m_unroll.forward(params, toks)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=1e-4, atol=1e-4)


def test_training_reduces_loss():
    """A few SGD steps on a tiny model must reduce loss on a fixed batch."""
    from repro.optim import adamw
    from repro.optim.optimizer import apply_updates
    cfg = get_config("smollm-360m").reduced(dtype="float32", num_layers=2,
                                            vocab_size=64)
    model = build_model(cfg, remat=False)
    params = model.init(KEY)
    opt = adamw(weight_decay=0.0)
    state = opt.init(params)
    toks = jax.random.randint(KEY, (4, 32), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    losses = []

    @jax.jit
    def step(params, state):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        upd, state, _ = opt.update(grads, state, params, 1e-2)
        return apply_updates(params, upd), state, loss

    for _ in range(10):
        params, state, loss = step(params, state)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.9, losses


def test_dlrm_smoke():
    cfg = dataclasses.replace(get_config("rm1"), num_embeddings=64)
    model = build_model(cfg)
    params = model.init(KEY)
    batch = {
        "dense": jax.random.normal(KEY, (4, 13)),
        "indices": jax.random.randint(KEY, (4, cfg.num_tables,
                                            cfg.gathers_per_table), 0, 64),
        "label": jnp.ones((4,), jnp.int32),
    }
    out = model.forward(params, batch)
    assert out.shape == (4,)
    loss = model.loss(params, batch)
    assert np.isfinite(float(loss))
