"""Pluggable serving-policy API: registry precedence, per-axis strategy
behaviour (admission order, preemption ranking, eviction scoring), slot
compaction, and the registry-enumerated parity sweep — every registered
policy triple must complete the same workload with identical greedy
outputs."""
import numpy as np
import pytest

from repro.config import ServeConfig, get_config
from repro.core.paged_kv import BlockAllocator
from repro.serving import policy
from repro.serving.request import Request, RequestState
from repro.serving.scheduler import Scheduler

SHIPPED = {
    "admission": {"fcfs", "priority", "deadline-slo", "predicted-length",
                  "auto"},
    "preemption": {"latest-arrival", "fewest-remaining-tokens", "most-blocks",
                   "auto"},
    "eviction": {"lru", "hit-rate", "refcount-aware", "tiered", "auto"},
}


def _req(i, *, prompt_len=4, max_new=4, arrival=0.0, prio=0, deadline=None):
    return Request(req_id=i, prompt=np.arange(prompt_len, dtype=np.int32),
                   max_new_tokens=max_new, arrival=arrival, priority=prio,
                   deadline=deadline)


# ----------------------------------------------------------------- registry
def test_every_shipped_policy_is_registered():
    for axis, expected in SHIPPED.items():
        assert expected <= set(policy.names(axis)), axis
        # the axis default is the pre-API hardcoded behaviour
        assert policy.DEFAULTS[axis] in policy.names(axis)


def test_resolve_precedence_explicit_scope_config_default():
    assert policy.resolve("admission").name == "fcfs"
    assert policy.resolve("admission", config="priority").name == "priority"
    with policy.force_policies(admission="deadline-slo"):
        # scope beats config, explicit beats scope
        assert policy.resolve("admission", config="priority"
                              ).name == "deadline-slo"
        assert policy.resolve("admission", "fcfs").name == "fcfs"
    assert policy.resolve("admission", config="priority").name == "priority"


def test_resolve_strict_on_unknown_names():
    with pytest.raises(policy.UnknownPolicyError):
        policy.resolve("admission", "nope")
    with pytest.raises(policy.UnknownPolicyError):
        policy.resolve("eviction", config="nope")
    with pytest.raises(policy.UnknownPolicyError):
        with policy.force_policies(preemption="nope"):
            pass                                # validated on scope entry
    with pytest.raises(ValueError):
        policy.resolve("not-an-axis")


def test_resolve_instance_passthrough_and_axis_check():
    inst = policy.resolve("preemption", "most-blocks")
    assert policy.resolve("preemption", inst) is inst
    with pytest.raises(ValueError):
        policy.resolve("admission", inst)       # wrong axis


def test_record_resolutions_collects_axis_name_pairs():
    with policy.record_resolutions() as log:
        policy.resolve("admission")
        policy.resolve("eviction", "hit-rate")
    assert ("admission", "fcfs") in log
    assert ("eviction", "hit-rate") in log


def test_resolutions_give_fresh_instances_with_own_counters():
    a = policy.resolve("admission")
    b = policy.resolve("admission")
    assert a is not b
    a.count("admitted")
    assert b.counters == {}


# ---------------------------------------------------------------- admission
def test_fcfs_orders_by_arrival_and_resumes_preempted_first():
    pol = policy.resolve("admission", "fcfs")
    old, new = _req(0, arrival=1.0), _req(1, arrival=2.0)
    assert pol.select([new, old], now=3.0) is old
    # a preempted request resumes ahead of an earlier fresh arrival
    pre = _req(2, arrival=9.0)
    pre.begin_prefill(slot=0, cached_tokens=0)
    pre.preempt()
    assert pre.state is RequestState.PREEMPTED
    assert pol.select([old, new, pre], now=10.0) is pre


def test_priority_admission_orders_by_priority_then_fcfs():
    pol = policy.resolve("admission", "priority")
    lo_early = _req(0, arrival=1.0, prio=0)
    hi_late = _req(1, arrival=5.0, prio=3)
    hi_later = _req(2, arrival=6.0, prio=3)
    assert pol.select([lo_early, hi_later, hi_late], now=7.0) is hi_late


def test_deadline_admission_is_edf_and_counts_misses():
    pol = policy.resolve("admission", "deadline-slo")
    tight = _req(0, arrival=0.0, deadline=5.0)
    loose = _req(1, arrival=0.0, deadline=50.0)
    none = _req(2, arrival=0.0)
    assert pol.select([none, loose, tight], now=1.0) is tight
    assert pol.select([none, loose], now=1.0) is loose  # deadline-free last
    pol.on_admit(tight, now=9.0)                        # already past 5.0
    pol.on_admit(loose, now=9.0)
    assert pol.counters == {"admitted": 2, "deadline_missed": 1}


# --------------------------------------------------------------- preemption
def _running_pair(alloc):
    """Two admitted requests: id 0 older/longer, id 1 newer/shorter."""
    a = _req(0, prompt_len=12, max_new=8, arrival=1.0)
    b = _req(1, prompt_len=4, max_new=8, arrival=2.0)
    alloc.allocate(0, 12)                      # 3 blocks
    alloc.allocate(1, 4)                       # 1 block
    return a, b


def test_latest_arrival_ranks_newest_first():
    alloc = BlockAllocator(num_blocks=16, block_size=4)
    a, b = _running_pair(alloc)
    pol = policy.resolve("preemption", "latest-arrival")
    assert pol.rank([a, b], alloc, now=3.0) == [b, a]


def test_fewest_remaining_tokens_ranks_nearly_done_first():
    alloc = BlockAllocator(num_blocks=16, block_size=4)
    a, b = _running_pair(alloc)
    a.output = [7] * 6                         # 2 remaining
    b.output = [7] * 1                         # 7 remaining
    pol = policy.resolve("preemption", "fewest-remaining-tokens")
    assert pol.rank([a, b], alloc, now=3.0) == [a, b]


def test_most_blocks_ranks_biggest_holder_first():
    alloc = BlockAllocator(num_blocks=16, block_size=4)
    a, b = _running_pair(alloc)                # a holds 3 blocks, b holds 1
    pol = policy.resolve("preemption", "most-blocks")
    assert pol.rank([a, b], alloc, now=3.0) == [a, b]
    pol.on_preempt(a, alloc)
    assert pol.counters == {"victims": 1, "blocks_reclaimed": 3}


def test_scheduler_protects_least_preemptable_request():
    """The ranking's bottom request is never the victim; a single running
    request yields no victim at all."""
    alloc = BlockAllocator(num_blocks=16, block_size=4)
    sched = Scheduler(alloc, max_batch=4, token_budget=16)
    a, b = _running_pair(alloc)
    sched.running = {0: a, 1: b}
    assert sched._pick_victim(now=3.0) is b    # latest arrival; a protected
    sched.running = {0: a}
    assert sched._pick_victim(now=3.0) is None


# ----------------------------------------------------------------- eviction
def _cache_two_prefixes(al):
    """Register two single-block prefixes and free them (cached-free)."""
    hot = np.arange(4, dtype=np.int32)
    cold = np.arange(100, 104, dtype=np.int32)
    al.allocate_prefix(0, hot)
    al.reserve_tokens(0, 4)
    al.commit_tokens(0, 4)
    al.register_prefix(0, hot, 4)
    al.allocate_prefix(1, cold)
    al.reserve_tokens(1, 4)
    al.commit_tokens(1, 4)
    al.register_prefix(1, cold, 4)
    hot_blk, cold_blk = al.table(0)[0], al.table(1)[0]
    return hot, cold, hot_blk, cold_blk


def test_lru_eviction_drops_oldest_freed_block():
    al = BlockAllocator(num_blocks=2, block_size=4,
                        eviction_policy=policy.resolve("eviction", "lru"))
    hot, cold, hot_blk, cold_blk = _cache_two_prefixes(al)
    al.free(0)                                  # hot freed first -> older
    al.free(1)
    al.allocate(2, 4)                           # needs one eviction
    assert al.peek_prefix(hot) == 0             # oldest (hot) was dropped
    assert al.peek_prefix(cold) == 3
    assert al.eviction_policy.counters == {"evictions": 1}


def test_hit_rate_eviction_keeps_reused_prefix():
    al = BlockAllocator(num_blocks=2, block_size=4,
                        eviction_policy=policy.resolve("eviction", "hit-rate"))
    hot, cold, hot_blk, cold_blk = _cache_two_prefixes(al)
    assert al.allocate_prefix(2, hot) == 3      # a hit on the hot block
    al.free(2)
    al.free(0)
    al.free(1)                                  # both prefixes cached-free
    al.allocate(3, 4)
    # LRU would evict hot (freed before cold); hit-rate keeps it
    assert al.peek_prefix(hot) == 3
    assert al.peek_prefix(cold) == 0
    assert al.block_stats(hot_blk).hits == 1


def test_refcount_aware_eviction_keeps_once_shared_block():
    al = BlockAllocator(
        num_blocks=2, block_size=4,
        eviction_policy=policy.resolve("eviction", "refcount-aware"))
    hot, cold, hot_blk, cold_blk = _cache_two_prefixes(al)
    al.allocate_prefix(2, hot)                  # hot shared: peak_ref -> 2
    assert al.block_stats(hot_blk).peak_ref == 2
    al.free(2)
    al.free(0)
    al.free(1)
    al.allocate(3, 4)
    assert al.peek_prefix(hot) == 3             # never-shared cold evicted
    assert al.peek_prefix(cold) == 0


def test_tiered_eviction_selects_coldest_and_gates_demotion():
    """``tiered`` is a registered policy like any other: select() evicts the
    block with the least reuse evidence; without a HostPool attached the
    demote hook is inert, with one it keeps blocks that earned hits or were
    shared and drops the rest (tests/test_disagg.py covers the tier)."""
    al = BlockAllocator(num_blocks=2, block_size=4,
                        eviction_policy=policy.resolve("eviction", "tiered"))
    hot, cold, hot_blk, cold_blk = _cache_two_prefixes(al)
    assert al.allocate_prefix(2, hot) == 3      # hot earns a hit
    al.free(2)
    al.free(0)
    al.free(1)
    al.allocate(3, 4)                           # cold (0 hits) evicted first
    assert al.peek_prefix(hot) == 3
    assert al.peek_prefix(cold) == 0
    pol = al.eviction_policy
    assert pol.counters["evictions"] == 1
    assert "demoted" not in pol.counters        # no host pool -> hook unused
    base = policy.resolve("eviction", "lru")
    assert base.demote(0, {}) is True           # base hook: always demote


def test_stats_reset_when_block_repurposed():
    al = BlockAllocator(num_blocks=2, block_size=4)
    hot, cold, hot_blk, cold_blk = _cache_two_prefixes(al)
    al.allocate_prefix(2, hot)
    assert al.block_stats(hot_blk).peak_ref == 2
    al.free(2)
    al.free(0)
    al.free(1)
    al.allocate(3, 8)                           # evicts + repurposes both
    assert al.block_stats(hot_blk).peak_ref == 1
    assert al.block_stats(hot_blk).hits == 0


# ------------------------------------------------------ scheduler admission
def test_scheduler_admits_in_policy_order():
    alloc = BlockAllocator(num_blocks=64, block_size=4)
    sched = Scheduler(alloc, max_batch=1, token_budget=64,
                      admission=policy.resolve("admission", "priority"))
    for i, prio in enumerate([0, 5, 1]):
        sched.submit(_req(i, arrival=float(i), prio=prio))
    sched.schedule()
    assert list(sched.running) == [1]           # highest priority first
    assert sched.admission.counters["admitted"] == 1


def test_scheduler_head_of_line_blocks_per_policy():
    """If the policy's top pick does not fit, nobody jumps the queue."""
    alloc = BlockAllocator(num_blocks=4, block_size=4)
    sched = Scheduler(alloc, max_batch=2, token_budget=64)
    alloc.allocate(99, 8)                       # 2 of 4 blocks occupied
    sched.running[99] = _req(99)                # hold them (fake runner)
    big = _req(0, prompt_len=12, arrival=1.0)   # needs 3+1 > 2 free
    small = _req(1, prompt_len=4, arrival=2.0)  # would fit
    sched.submit(big)
    sched.submit(small)
    sched._admit()
    assert big.state is RequestState.WAITING    # head-of-line did not fit
    assert small.state is RequestState.WAITING  # and nobody jumped it


# ------------------------------------------------------------- compaction
def test_slot_compaction_remaps_survivor_down():
    alloc = BlockAllocator(num_blocks=64, block_size=4)
    sched = Scheduler(alloc, max_batch=4, token_budget=64)
    reqs = [_req(i, arrival=float(i)) for i in range(4)]
    for r in reqs:
        sched.submit(r)
    sched.schedule()
    assert [reqs[i].slot for i in range(4)] == [0, 1, 2, 3]
    for r in reqs[:3]:                          # low slots drain
        sched.release(r)
        r.finish()
    assert reqs[3].slot == 3
    sched.schedule()                            # survivor drops to slot 0
    assert reqs[3].slot == 0
    assert sched.num_slot_compactions == 1
    assert sorted(sched.free_slots) == [1, 2, 3]


def test_freed_slots_reissue_lowest_first_after_drain():
    """After a full burst drains (nothing running), a fresh admission must
    land on slot 0 — not on whatever slot was released last."""
    alloc = BlockAllocator(num_blocks=64, block_size=4)
    sched = Scheduler(alloc, max_batch=4, token_budget=64)
    reqs = [_req(i, arrival=float(i)) for i in range(4)]
    for r in reqs:
        sched.submit(r)
    sched.schedule()
    for r in reqs:                              # drain: free list [0,1,2,3]
        sched.release(r)
        r.finish()
    late = _req(9)
    sched.submit(late)
    sched.schedule()
    assert late.slot == 0


# ------------------------------------------------------------- parity sweep
def _policy_triples():
    """Every registered policy, exercised once: vary one axis at a time off
    the default triple (new registrations auto-enroll — no list here)."""
    base = dict(policy.DEFAULTS)
    triples = [tuple(sorted(base.items()))]
    for axis in policy.AXES:
        for name in policy.names(axis):
            t = dict(base, **{axis: name})
            triples.append(tuple(sorted(t.items())))
    return sorted(set(triples))


@pytest.mark.slow       # one engine run per registered policy
@pytest.mark.parametrize("triple", _policy_triples(),
                         ids=lambda t: "/".join(n for _, n in t))
def test_policy_triples_identical_greedy_outputs(triple, policy_parity_ref):
    """Acceptance: each policy triple completes the same workload with
    identical token outputs under greedy sampling.  The workload starves the
    pool (preemption + cached-free eviction fire) and shares a prefix
    (prefix cache populated), so all three axes actually make decisions."""
    outputs, metrics = policy_parity_ref["run"](dict(triple))
    assert metrics["finished"] == policy_parity_ref["n_requests"]
    for axis, name in triple:
        assert metrics[f"{axis}_policy"] == name
    assert metrics["blocks_free"] == policy_parity_ref["num_blocks"]
    ref = policy_parity_ref["outputs"]
    assert outputs == ref, f"policy triple {dict(triple)} diverged"


@pytest.fixture(scope="module")
def policy_parity_ref():
    """Shared workload runner + the default-triple reference outputs."""
    from repro.models.api import build_model
    from repro.serving.engine import ServingEngine
    import jax

    cfg = get_config("qwen2-1.5b").reduced(dtype="float32")
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)
    num_blocks, n_req = 8, 4
    prefix = rng.integers(0, cfg.vocab_size, (4,), dtype=np.int32)
    prompts = [np.concatenate([prefix,
                               rng.integers(0, cfg.vocab_size, (2 + i,),
                                            dtype=np.int32)])
               for i in range(n_req)]

    def run(pol):
        serve = ServeConfig(model=cfg.name, kv_block_size=4, max_batch=3,
                            **pol)
        eng = ServingEngine(model, params, cfg, serve, num_blocks=num_blocks)
        for i, p in enumerate(prompts):
            eng.submit(Request(req_id=i, prompt=p, max_new_tokens=10,
                               priority=i % 2,
                               deadline=float(i) if i % 2 else None))
        eng.run_until_done()
        return ({r.req_id: r.output for r in eng.finished}, eng.metrics())

    outputs, metrics = run(dict(policy.DEFAULTS))
    assert metrics["preemptions"] > 0           # the workload really starves
    return {"run": run, "outputs": outputs, "metrics": metrics,
            "n_requests": n_req, "num_blocks": num_blocks}
