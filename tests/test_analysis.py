"""Self-tests for repro.analysis: per-rule lint fixtures + runtime
sanitizers (retrace guard, host-sync guard, allocator invariants)."""
import os
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import lint
from repro.analysis import sanitize as sanitize_lib
from repro.analysis.sanitize import (SanitizeError, Sanitizer, host_read,
                                     jit_signature)
from repro.core.paged_kv import BlockAllocator

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


_TREE_SEQ = iter(range(10_000))


def _lint_tree(tmp_path, files, rules=None, tests=None):
    """Write {relpath: source} into a fresh subroot of tmp_path and lint it
    (fresh per call so one test's violating fixture never leaks into its
    clean fixture)."""
    root = tmp_path / f"tree{next(_TREE_SEQ)}"
    for rel, src in files.items():
        p = root / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
    tests_dir = None
    if tests is not None:
        tdir = root / "tests"
        tdir.mkdir(exist_ok=True)
        for rel, src in tests.items():
            (tdir / rel).write_text(textwrap.dedent(src))
        tests_dir = str(tdir)
    roots = [str(root / r) for r in {rel.split("/", 1)[0] for rel in files}]
    return lint.run_lint(sorted(roots), tests_dir=tests_dir, rules=rules)


# ---------------------------------------------------------------------------
# Layer 1: one violating + one clean fixture per rule
# ---------------------------------------------------------------------------
def test_allocator_privacy_rule(tmp_path):
    bad = _lint_tree(tmp_path, {
        "pkg/engine.py": """
            def peek(eng):
                return eng.alloc._tables[0], eng.alloc._free[:]
        """}, rules=["allocator-privacy"])
    assert len(bad) == 2 and all(f.rule == "allocator-privacy" for f in bad)
    assert bad[0].path.endswith("engine.py") and bad[0].line == 3

    clean = _lint_tree(tmp_path, {
        # the owning module may touch its own private state
        "pkg/core/paged_kv.py": """
            def inside(alloc):
                return alloc._tables
        """,
        "pkg/engine2.py": """
            def peek(eng):
                return eng.alloc.table(0), eng.alloc.num_free
        """}, rules=["allocator-privacy"])
    assert clean == []


def test_backend_conditional_rule(tmp_path):
    bad = _lint_tree(tmp_path, {
        "pkg/op.py": """
            def run(backend, x):
                if backend == "pallas":
                    return x
                return -x
        """}, rules=["backend-conditional"])
    assert [f.rule for f in bad] == ["backend-conditional"]

    clean = _lint_tree(tmp_path, {
        # the registry itself is the one allowed home for these compares
        "pkg/core/dispatch.py": """
            def resolve(backend):
                if backend == "pallas":
                    return 1
        """,
        "pkg/op.py": """
            def run(backend, x):
                impl = resolve("family", config=backend)
                return impl(x)
        """}, rules=["backend-conditional"])
    assert clean == []


_PARITY_OK = {"test_backend_parity.py":
              "FAMILIES = list(dispatch.list_ops())\n"}


def test_op_ref_parity_rule(tmp_path):
    bad = _lint_tree(tmp_path, {
        "pkg/ops.py": """
            from repro.core import dispatch
            _OP = dispatch.op("orphan_family")
        """}, rules=["op-ref-parity"], tests=_PARITY_OK)
    msgs = sorted(f.message for f in bad)
    assert len(bad) == 2
    assert "no 'ref' implementation" in msgs[1]
    assert "no example= factory" in msgs[0]

    clean = _lint_tree(tmp_path, {
        "pkg/ops.py": """
            from repro.core import dispatch

            def _example():
                return ()

            _OP = dispatch.op("good_family", example=_example)

            @_OP.register("ref")
            def _ref(x):
                return x
        """}, rules=["op-ref-parity"], tests=_PARITY_OK)
    assert clean == []


def test_op_ref_parity_requires_enrollment(tmp_path):
    # parity suite neither registry-driven nor naming the family
    bad = _lint_tree(tmp_path, {
        "pkg/ops.py": """
            from repro.core import dispatch

            def _example():
                return ()

            _OP = dispatch.op("lonely_family", example=_example)
            _OP.register("ref")(lambda x: x)
        """}, rules=["op-ref-parity"],
        tests={"test_backend_parity.py": 'FAMILIES = ["other_family"]\n'})
    assert [f.message for f in bad] == [
        "op family 'lonely_family' is not enrolled in "
        "test_backend_parity.py (the suite neither enumerates "
        "dispatch.list_ops() nor names it)"]


_POLICY_MOD = """
    ADMISSION = "admission"

    def register(axis, name):
        def deco(cls):
            return cls
        return deco

    @register(ADMISSION, "fcfs")
    class Fcfs:
        pass

    @register(ADMISSION, "ghost")
    class Ghost:
        pass
"""


def test_policy_enrollment_rule(tmp_path):
    # "ghost" is registered but test_policy.py never names it.
    bad = _lint_tree(tmp_path, {"pkg/serving/policy.py": _POLICY_MOD},
                     rules=["policy-enrollment"],
                     tests={"test_policy.py": 'SHIPPED = {"fcfs"}\n'})
    assert [f.rule for f in bad] == ["policy-enrollment"]
    assert "'ghost'" in bad[0].message and "SHIPPED" in bad[0].message
    assert bad[0].path.endswith("policy.py")

    # Either quote style in the suite counts as enrollment.
    clean = _lint_tree(tmp_path, {"pkg/serving/policy.py": _POLICY_MOD},
                       rules=["policy-enrollment"],
                       tests={"test_policy.py":
                              "SHIPPED = {\"fcfs\", 'ghost'}\n"})
    assert clean == []

    # Registrations elsewhere than serving/policy.py are out of scope, and
    # without a tests dir the rule has nothing to check against.
    elsewhere = _lint_tree(tmp_path, {"pkg/other.py": _POLICY_MOD},
                           rules=["policy-enrollment"],
                           tests={"test_policy.py": "SHIPPED = set()\n"})
    assert elsewhere == []
    no_tests = _lint_tree(tmp_path, {"pkg/serving/policy.py": _POLICY_MOD},
                          rules=["policy-enrollment"])
    assert no_tests == []


_TUNABLE_CONFIG = """
    class ServeConfig:
        q_chunk: int = 16
"""


def test_tunable_reachability_rule(tmp_path):
    bad = _lint_tree(tmp_path, {
        "pkg/repro/config.py": _TUNABLE_CONFIG,
        "pkg/repro/launch/serve.py": 'FLAGS = "--q-chunk"\n',
        "pkg/repro/ops.py": """
            from repro.core import dispatch
            _OP = dispatch.op("fam", example=make,
                              tunables={"mystery_knob": 1})
            _OP.register("ref")(lambda: 0)
        """}, rules=["tunable-reachability"])
    assert len(bad) == 2           # no ServeConfig field AND no argparse flag
    assert all("mystery_knob" in f.message for f in bad)

    clean = _lint_tree(tmp_path, {
        "pkg/repro/config.py": _TUNABLE_CONFIG,
        "pkg/repro/launch/serve.py": 'FLAGS = "--q-chunk"\n',
        "pkg/repro/ops.py": """
            from repro.core import dispatch
            _OP = dispatch.op("fam", example=make,
                              tunables={"q_chunk": 16})
            _OP.register("ref")(lambda: 0)
        """}, rules=["tunable-reachability"])
    assert clean == []


_DMA_CLEAN = """
    def ring_kernel(k_hbm, k_buf, k_sem):
        def start(e):
            pltpu.make_async_copy(k_hbm.at[e], k_buf.at[e], k_sem.at[e]).start()
        start(0)
        pltpu.make_async_copy(k_hbm.at[e], k_buf.at[e], k_sem.at[e]).wait()

    def scratch():
        return [pltpu.VMEM((depth, 8, 8), jnp.float32),
                pltpu.SemaphoreType.DMA((depth,))]
"""


def test_dma_pairing_rule(tmp_path):
    # re-introducing an unpaired .start() (ISSUE acceptance demo)
    bad = _lint_tree(tmp_path, {
        "pkg/kernel.py": """
            def ring_kernel(k_hbm, k_buf, k_sem):
                pltpu.make_async_copy(
                    k_hbm.at[e], k_buf.at[e], k_sem.at[e]).start()
        """}, rules=["dma-pairing"])
    assert len(bad) == 1
    assert "1 start(s) but 0 wait(s)" in bad[0].message

    mismatched_sem = _lint_tree(tmp_path, {
        "pkg/kernel2.py": """
            def scratch():
                return [pltpu.VMEM((depth, 8, 8), jnp.float32),
                        pltpu.SemaphoreType.DMA((2 * depth,))]
        """}, rules=["dma-pairing"])
    assert len(mismatched_sem) == 1
    assert "matches no VMEM ring" in mismatched_sem[0].message

    clean = _lint_tree(tmp_path, {"pkg/kernel3.py": _DMA_CLEAN},
                       rules=["dma-pairing"])
    assert clean == []


def test_wallclock_rule(tmp_path):
    bad = _lint_tree(tmp_path, {
        "pkg/model.py": """
            def step_kernel(x):
                return x * time.time() + np.random.rand()
        """}, rules=["wallclock-in-device-code"])
    assert len(bad) == 2
    assert all(f.rule == "wallclock-in-device-code" for f in bad)

    clean = _lint_tree(tmp_path, {
        "pkg/model.py": """
            def host_loop(x):
                return x * time.time()       # host code: fine

            def step_kernel(x, key):
                return x + jax.random.normal(key, x.shape)
        """}, rules=["wallclock-in-device-code"])
    assert clean == []


def test_full_src_tree_lints_clean():
    findings = lint.run_lint([os.path.join(ROOT, "src")],
                             tests_dir=os.path.join(ROOT, "tests"))
    assert findings == [], "\n".join(f.format() for f in findings)


def test_cli_exit_codes(tmp_path, capsys):
    (tmp_path / "bad.py").write_text(
        "def f(eng):\n    return eng.alloc._tables\n")
    assert lint.main([str(tmp_path)]) == 1
    out = capsys.readouterr().out
    assert "bad.py:2: [allocator-privacy]" in out
    assert lint.main([os.path.join(ROOT, "src"), "--tests-dir",
                      os.path.join(ROOT, "tests")]) == 0
    assert lint.main(["--rules", "no-such-rule", str(tmp_path)]) == 2


def test_rule_registry_is_strict():
    with pytest.raises(lint.DuplicateRuleError):
        lint.rule("dma-pairing")(lambda ctx: [])
    with pytest.raises(lint.UnknownRuleError):
        lint.get_rule("no-such-rule")
    names = [r.name for r in lint.list_rules()]
    assert names == sorted(names) and "dma-pairing" in names


# ---------------------------------------------------------------------------
# Layer 2: runtime sanitizers
# ---------------------------------------------------------------------------
def test_retrace_guard_catches_per_call_jit():
    # PR 5's bug class: a FRESH jax.jit wrapper per call compiles every
    # call for the same signature (ISSUE acceptance demo)
    s = Sanitizer(strict=False)
    x = jnp.arange(7.0)
    sig = jit_signature("demo", x)
    for _ in range(3):
        f = jax.jit(lambda v: v * 2.0)     # noqa: B023 — the bug on purpose
        with s.expect_cached(sig):
            f(x).block_until_ready()
    assert s.counters()["retraces"] >= 1
    assert not s.clean


def test_retrace_guard_passes_cached_jit():
    s = Sanitizer(strict=True)
    x = jnp.arange(7.0)
    f = jax.jit(lambda v: v * 3.0)
    sig = jit_signature("demo-cached", x)
    for _ in range(4):
        with s.expect_cached(sig):
            f(x).block_until_ready()
    assert s.counters()["retraces"] == 0 and s.clean


def test_retrace_guard_strict_raises():
    s = Sanitizer(strict=True)
    x = jnp.arange(5.0)
    sig = jit_signature("demo-strict", x)
    with s.expect_cached(sig):
        jax.jit(lambda v: v - 1.0)(x).block_until_ready()
    with pytest.raises(SanitizeError, match="retrace"):
        with s.expect_cached(sig):
            jax.jit(lambda v: v - 1.0)(x).block_until_ready()


def test_jit_signature_distinguishes_shapes_not_values():
    a, b = jnp.zeros((4,)), jnp.ones((4,))
    assert jit_signature("t", a) == jit_signature("t", b)
    assert jit_signature("t", a) != jit_signature("t", jnp.zeros((8,)))
    assert jit_signature("t", a) != jit_signature("u", a)


def test_host_sync_guard_allowlist_and_trip():
    s = Sanitizer(strict=True)
    x = jnp.arange(4)
    # outside any scope: plain asarray, nothing recorded
    np.testing.assert_array_equal(host_read(x, reason="anything"),
                                  np.arange(4))
    with s.no_host_sync("build"):
        host_read(x, reason="tier-drain")          # allowlisted
        host_read(x, reason="disagg-handoff")      # allowlisted
        with pytest.raises(SanitizeError, match="rogue"):
            host_read(x, reason="rogue")
    c = s.counters()
    assert c["allowed_host_syncs"] == 2
    assert c["transfer_guard_trips"] == 1

    lenient = Sanitizer(strict=False)
    with lenient.no_host_sync("build"):
        host_read(x, reason="rogue")               # counted, not raised
    assert lenient.counters()["transfer_guard_trips"] == 1


def test_allocator_invariants_clean_and_corrupted():
    alloc = BlockAllocator(num_blocks=8, block_size=4)
    alloc.allocate(1, 8)
    alloc.check_invariants()                       # healthy state passes
    # corruption: mark a free block as refcounted behind the API's back
    phantom = alloc._free[-1]
    alloc._ref[phantom] = 1
    with pytest.raises(ValueError, match="free and refcounted"):
        alloc.check_invariants()
    del alloc._ref[phantom]
    # corruption: refcount disagrees with table occurrences
    blk = alloc.table(1)[0]
    alloc._ref[blk] += 1
    with pytest.raises(ValueError, match="disagree"):
        alloc.check_invariants()
    alloc._ref[blk] -= 1
    alloc.free(1)
    alloc.check_invariants(drained=True)           # both pools fully drain

    s = Sanitizer()
    alloc._ref[0] = 3                              # corrupt again
    with pytest.raises(SanitizeError, match="allocator invariant"):
        s.check_allocator(alloc)
    assert s.counters()["invariant_checks"] == 1


# ---------------------------------------------------------------------------
# Engine-level: a sanitized run is clean and bit-identical
# ---------------------------------------------------------------------------
def _run_engine(sanitize):
    from repro.config import ServeConfig, get_config
    from repro.models.api import build_model
    from repro.serving.engine import Request, ServingEngine

    cfg = get_config("smollm-360m").reduced(dtype="float32")
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    serve = ServeConfig(model=cfg.name, kv_block_size=4, max_batch=3,
                        overlap=True, sanitize=sanitize)
    eng = ServingEngine(model, params, cfg, serve, num_blocks=48, eos_id=-1)
    rng = np.random.default_rng(7)
    for i in range(3):
        eng.submit(Request(
            req_id=i,
            prompt=rng.integers(0, cfg.vocab_size, (10,), dtype=np.int32),
            max_new_tokens=5))
    eng.run_until_done()
    outs = {r.req_id: list(r.output) for r in eng.finished}
    return outs, eng.metrics()


def test_sanitized_engine_run_is_clean_and_bit_identical():
    base_outs, base_m = _run_engine(sanitize=False)
    outs, m = _run_engine(sanitize=True)
    assert outs == base_outs                # guards never perturb the run
    assert base_m["sanitize"]["enabled"] is False
    san = m["sanitize"]
    assert san["enabled"] is True
    assert san["retraces"] == 0
    assert san["transfer_guard_trips"] == 0
    assert san["invariant_checks"] > 0
    # counters ride beside the policy counters for benchmark rows
    assert m["policy_counters"]["sanitize.retraces"] == 0
