"""Pallas kernel sweeps vs the pure-jnp oracles (interpret=True on CPU).

Interpret-mode Pallas is orders of magnitude slower than compiled jnp, so
the whole module is marked ``slow`` — the fast CI tier (tools/ci_fast.sh)
skips it; the full tier still runs everything.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.paged_kv import BlockAllocator

pytestmark = pytest.mark.slow

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("B,S,H,KV,hd,causal,dtype", [
    (2, 128, 4, 2, 64, True, jnp.float32),
    (1, 256, 6, 6, 64, False, jnp.float32),
    (2, 64, 8, 2, 128, True, jnp.float32),
    (1, 128, 4, 4, 64, True, jnp.bfloat16),
])
def test_flash_attention_sweep(B, S, H, KV, hd, causal, dtype):
    from repro.kernels.flash_attention.kernel import flash_attention_pallas
    from repro.kernels.flash_attention.ref import flash_attention_ref
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd), dtype)
    out = flash_attention_pallas(q, k, v, causal=causal, bq=64, bk=64,
                                 interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("NB,BS,KV,hd,H,B,lens", [
    (24, 8, 2, 64, 8, 3, [13, 8, 21]),
    (40, 16, 4, 128, 8, 4, [40, 1, 64, 17]),
    (16, 8, 6, 64, 6, 2, [5, 9]),
    (16, 8, 1, 64, 4, 2, [8, 16]),
])
def test_paged_attention_kernel_sweep(NB, BS, KV, hd, H, B, lens):
    from repro.kernels.paged_attention.kernel import paged_attention_pallas
    from repro.kernels.paged_attention.ref import paged_attention_ref
    ks = jax.random.split(KEY, 3)
    pk = jax.random.normal(ks[0], (NB, BS, KV, hd), jnp.float32)
    pv = jax.random.normal(ks[1], (NB, BS, KV, hd), jnp.float32)
    q = jax.random.normal(ks[2], (B, H, hd), jnp.float32)
    al = BlockAllocator(num_blocks=NB, block_size=BS)
    al._free = np.random.RandomState(1).permutation(NB).tolist()
    for r, L in enumerate(lens):
        al.allocate(r, L)
    tot = sum(-(-L // BS) for L in lens) + 3
    args = [jnp.asarray(x) for x in
            al.build_block_list(list(range(B)), max_total=tot)]
    out = paged_attention_pallas(q, pk, pv, *args, interpret=True)
    ref = paged_attention_ref(q, pk, pv, *args)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("NB,BS,KV,hd,H,lens,chunks,q_chunk,depth", [
    (24, 8, 2, 32, 8, [13, 8, 21], [1, 4, 2], 4, 0),
    (40, 16, 4, 32, 8, [40, 1, 64, 17], [3, 1, 5, 2], 8, 0),
    (16, 8, 1, 16, 4, [8, 16], [2, 7], 3, 0),   # q_chunk not dividing T
    # multi-buffered KV-page DMA ring (prefetch_depth >= 2): same math,
    # manual async copies into a depth-slot VMEM ring instead of BlockSpec
    # pipelining — must stay BIT-identical to the depth<=1 path
    (24, 8, 2, 32, 8, [13, 8, 21], [1, 4, 2], 4, 2),
    (40, 16, 4, 32, 8, [40, 1, 64, 17], [3, 1, 5, 2], 8, 3),
    (16, 8, 1, 16, 4, [8, 16], [2, 7], 3, 16),  # depth > #kv blocks
])
def test_paged_attention_chunked_kernel_sweep(NB, BS, KV, hd, H, lens,
                                              chunks, q_chunk, depth):
    """Query-chunk grid kernel vs the jnp chunked-prefill oracle: mixed
    decode/prefill lanes, shuffled pool blocks, trailing padding lanes."""
    from repro.core.attention_api import paged_attention_chunked
    from repro.kernels.paged_attention.kernel import (
        paged_attention_chunked_pallas)
    B = len(lens)
    al = BlockAllocator(num_blocks=NB, block_size=BS)
    al._free = np.random.RandomState(1).permutation(NB).tolist()
    for r, L in enumerate(lens):
        al.allocate(r, L)
    tot = sum(-(-L // BS) for L in lens) + 3
    bl, br, bp, _ = [jnp.asarray(x) for x in
                     al.build_block_list(list(range(B)), max_total=tot)]
    kv_lens = jnp.asarray(lens, jnp.int32)
    treq, tpos = [], []
    for r, c in enumerate(chunks):                # last c positions of req r
        treq += [r] * c
        tpos += list(range(lens[r] - c, lens[r]))
    treq += [B, B]                                # two padding lanes
    tpos += [0, 0]
    T = len(treq)
    ks = jax.random.split(KEY, 3)
    pk = jax.random.normal(ks[0], (NB, BS, KV, hd), jnp.float32)
    pv = jax.random.normal(ks[1], (NB, BS, KV, hd), jnp.float32)
    q = jax.random.normal(ks[2], (T, H, hd), jnp.float32)
    treq = jnp.asarray(treq, jnp.int32)
    tpos = jnp.asarray(tpos, jnp.int32)
    out = paged_attention_chunked_pallas(q, pk, pv, bl, br, bp, kv_lens,
                                         treq, tpos, q_chunk=q_chunk,
                                         prefetch_depth=depth,
                                         interpret=True)
    ref = paged_attention_chunked(q, pk, pv, bl, br, bp, kv_lens, treq, tpos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)
    assert np.all(np.isfinite(np.asarray(out)[-2:])), "pad lanes must be 0"
    np.testing.assert_allclose(np.asarray(out)[-2:], 0.0)
    if depth >= 2:      # the DMA ring cannot drift from the serial path
        serial = paged_attention_chunked_pallas(
            q, pk, pv, bl, br, bp, kv_lens, treq, tpos, q_chunk=q_chunk,
            prefetch_depth=0, interpret=True)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(serial))


def test_paged_attention_chunked_sharded_equals_chunked():
    """Sequence-sharded chunked combine vs the single-device chunked oracle:
    mixed decode/prefill/draft-style lanes over a pool sharded into 4
    contiguous slices, with per-shard LOCAL BlockLists rendered by
    ``build_sharded_block_lists`` — plus the registry's ``sharded`` backend
    (flat-list split, replicated pool) on the same inputs."""
    from conftest import run_multidevice
    snippet = """
    import jax, jax.numpy as jnp, numpy as np
    from functools import partial
    from jax.sharding import PartitionSpec as P
    from repro.core.attention_api import (
        paged_attention_chunked, paged_attention_chunked_sharded)
    from repro.core.dispatch import get_op
    from repro.core.paged_kv import BlockAllocator
    from repro.kernels.compat import shard_map

    SHARDS, BS, KV, hd, H = 4, 8, 2, 32, 8
    NB = SHARDS * 6
    lens, chunks = [13, 8, 21], [1, 4, 2]      # decode + prefill-chunk lanes
    B = len(lens)
    al = BlockAllocator(num_blocks=NB, block_size=BS, num_shards=SHARDS)
    for r, L in enumerate(lens):
        al.allocate(r, L)
    kv_lens = jnp.asarray(lens, jnp.int32)
    treq, tpos = [], []
    for r, c in enumerate(chunks):             # last c positions of req r
        treq += [r] * c
        tpos += list(range(lens[r] - c, lens[r]))
    treq += [B, B]                             # padding lanes
    tpos += [0, 0]
    T = len(treq)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    pk = jax.random.normal(ks[0], (NB, BS, KV, hd), jnp.float32)
    pv = jax.random.normal(ks[1], (NB, BS, KV, hd), jnp.float32)
    q = jax.random.normal(ks[2], (T, H, hd), jnp.float32)
    treq = jnp.asarray(treq, jnp.int32)
    tpos = jnp.asarray(tpos, jnp.int32)

    bl, br, bp, _ = al.build_block_list(list(range(B)), max_total=NB)
    ref = paged_attention_chunked(q, pk, pv, jnp.asarray(bl),
                                  jnp.asarray(br), jnp.asarray(bp),
                                  kv_lens, treq, tpos)

    # engine form: sequence-sharded pool + per-shard LOCAL lists
    sbl, sbr, sbp = al.build_sharded_block_lists(
        [(r, r) for r in range(B)], pad_req=B)
    mesh = jax.make_mesh((SHARDS,), ("model",))
    fn = shard_map(
        lambda q, pk, pv, bl, br, bp: paged_attention_chunked_sharded(
            q, pk, pv, bl[0], br[0], bp[0], kv_lens, treq, tpos,
            axis="model"),
        mesh=mesh,
        in_specs=(P(), P("model"), P("model"), P("model"), P("model"),
                  P("model")),
        out_specs=P(), check_rep=False)
    out = jax.jit(fn)(q, pk, pv, jnp.asarray(sbl), jnp.asarray(sbr),
                      jnp.asarray(sbp))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(out)[-2:], 0.0)  # pad lanes

    # registry form: the auto-enrolled `sharded` backend on the flat list
    fam = get_op("paged_attention_chunked")
    out2 = fam(q, pk, pv, jnp.asarray(bl), jnp.asarray(br), jnp.asarray(bp),
               kv_lens, treq, tpos, backend="sharded")
    np.testing.assert_allclose(np.asarray(out2), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)
    print("OK")
    """
    r = run_multidevice(snippet, n_devices=4)
    assert "OK" in r.stdout, (r.stdout[-300:], r.stderr[-2500:])


@pytest.mark.parametrize("R,D,B,T,L,dtype", [
    (64, 128, 3, 4, 5, jnp.float32),
    (32, 256, 2, 10, 20, jnp.float32),
    (64, 128, 2, 4, 1, jnp.bfloat16),
])
def test_batched_embedding_sweep(R, D, B, T, L, dtype):
    from repro.kernels.batched_embedding.kernel import batched_embedding_pallas
    from repro.kernels.batched_embedding.ref import batched_embedding_ref
    tbl = jax.random.normal(KEY, (R * T, D), dtype)
    offs = jnp.arange(T, dtype=jnp.int32) * R
    idx = jax.random.randint(KEY, (B, T, L), 0, R)
    gid = (idx + offs[None, :, None]).reshape(-1)
    out = batched_embedding_pallas(tbl, gid, L, interpret=True)
    ref = batched_embedding_ref(tbl, offs, idx).reshape(B * T, D)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("rows,block_rows,dtype", [
    (512, 16, jnp.float32), (1024, 256, jnp.float32),
    (512, 8, jnp.bfloat16),
])
def test_stream_sweep(rows, block_rows, dtype):
    from repro.kernels.stream.ops import stream_add, stream_scale, stream_triad
    n = rows * 128
    a = jax.random.normal(KEY, (n,), dtype)
    b = jax.random.normal(jax.random.PRNGKey(1), (n,), dtype)
    tol = dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=1e-6, atol=1e-5)
    # explicit backend: the sweep must exercise the kernel, not auto's jnp
    np.testing.assert_allclose(
        np.asarray(stream_add(a, b, block_rows, backend="pallas_interpret"),
                   np.float32),
        np.asarray(a + b, np.float32), **tol)
    np.testing.assert_allclose(
        np.asarray(stream_scale(a, 3.0, block_rows,
                                backend="pallas_interpret"), np.float32),
        np.asarray(3.0 * a, np.float32), **tol)
    np.testing.assert_allclose(
        np.asarray(stream_triad(a, b, 3.0, block_rows,
                                backend="pallas_interpret"), np.float32),
        np.asarray(3.0 * a + b, np.float32), **tol)


@pytest.mark.parametrize("R,D,N", [(100, 128, 37), (64, 256, 64)])
def test_gather_scatter_sweep(R, D, N):
    from repro.kernels.gather_scatter.ops import vector_gather, vector_scatter
    tbl = jax.random.normal(KEY, (R, D), jnp.float32)
    ids = jax.random.randint(KEY, (N,), 0, R)
    np.testing.assert_allclose(
        np.asarray(vector_gather(tbl, ids, backend="pallas_interpret")),
        np.asarray(jnp.take(tbl, ids, 0)))
    ids_u = jnp.asarray(np.random.RandomState(0).permutation(R)[:N])
    src = jax.random.normal(jax.random.PRNGKey(2), (N, D), jnp.float32)
    out = vector_scatter(tbl, ids_u, src, backend="pallas_interpret")
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(tbl.at[ids_u].set(src)))
