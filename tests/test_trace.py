"""repro.perf: trace generators, virtual-time replay, the perf table behind
`--policy auto`, and the CI regression gate.

The load-bearing acceptance tests for PR 9 live here: replayed greedy
streams are bit-identical to direct submit() of the same requests, `auto`
resolves the measured winner (and falls back counted when no table is
active), and the gate trips on a planted 20% counter regression."""
import json

import numpy as np
import pytest

from repro.perf import gate
from repro.perf.replay import (ReplayResult, RequestTiming, Slo, replay,
                               score)
from repro.perf.table import (AXES, SCHEMA_VERSION, PerfTable, SchemaError,
                              check_schema, parse_derived, perf_context,
                              resolve_winner)
from repro.perf.trace import (SCENARIOS, LengthModel, Trace, TraceRequest,
                              generate)
from repro.serving import policy
from repro.serving.request import Request, RequestState


# ------------------------------------------------------------- generators
@pytest.mark.parametrize("scenario", SCENARIOS)
def test_generator_deterministic_under_seed(scenario):
    a = generate(scenario, seed=42, n_requests=9)
    b = generate(scenario, seed=42, n_requests=9)
    assert a.as_dict() == b.as_dict()           # bit-for-bit, prompts included
    c = generate(scenario, seed=43, n_requests=9)
    assert a.as_dict() != c.as_dict()


def test_generator_invariants():
    tr = generate("mixed", seed=1, n_requests=10, vocab_size=64, gen_cap=9)
    assert len(tr.requests) == 10
    arrivals = [r.arrival for r in tr.requests]
    assert arrivals == sorted(arrivals)         # sorted on the virtual clock
    assert [r.req_id for r in tr.requests] == list(range(10))  # renumbered
    for r in tr.requests:
        assert all(0 <= t < 64 for t in r.prompt)
        assert 1 <= r.max_new_tokens <= 9 + 9 // 2   # long-tail outlier cap
    assert tr.max_positions() == max(len(r.prompt) + r.max_new_tokens
                                     for r in tr.requests)


def test_generator_rejects_unknown_scenario():
    with pytest.raises(ValueError, match="unknown scenario"):
        generate("steady", seed=0)


def test_trace_save_load_round_trip(tmp_path):
    tr = generate("shared-prefix", seed=5, n_requests=7)
    path = str(tmp_path / "t.json")
    tr.save(path)
    back = Trace.load(path)
    assert back == tr                           # dataclass equality, exact


def test_trace_rejects_wrong_schema_version():
    d = generate("bursty", seed=0, n_requests=2).as_dict()
    d["trace_schema_version"] = 99
    with pytest.raises(ValueError, match="trace schema"):
        Trace.from_dict(d)


def test_to_requests_offsets_arrival_and_deadline():
    tr = Trace(name="t", scenario="bursty", seed=0, vocab_size=8,
               requests=[TraceRequest(req_id=0, arrival=0.5, prompt=[1, 2],
                                      max_new_tokens=3, priority=2,
                                      deadline=1.5),
                         TraceRequest(req_id=1, arrival=0.7, prompt=[3],
                                      max_new_tokens=2)])
    reqs = tr.to_requests(base=100.0)
    assert reqs[0].arrival == 100.5 and reqs[0].deadline == 101.5
    assert reqs[1].arrival == 100.7 and reqs[1].deadline is None
    assert reqs[0].prompt.dtype == np.int32
    assert reqs[0].priority == 2


# ------------------------------------------------------------ length model
def test_length_model_fit_and_predict():
    tr = Trace(name="t", scenario="mixed", seed=0, vocab_size=8, requests=[
        TraceRequest(req_id=0, arrival=0.0, prompt=[0] * 6, max_new_tokens=4),
        TraceRequest(req_id=1, arrival=0.1, prompt=[0] * 7, max_new_tokens=6),
        TraceRequest(req_id=2, arrival=0.2, prompt=[0] * 14,
                     max_new_tokens=10)])
    m = LengthModel.fit(tr)
    assert m.buckets == {8: 5.0, 16: 10.0}      # pow2-bucketed means
    assert m.predict(6) == 5.0                  # exact bucket hit
    assert m.predict(30) == 10.0                # nearest bucket by log2
    assert m.predict(1) == 5.0
    empty = LengthModel.fit(Trace(name="e", scenario="mixed", seed=0,
                                  vocab_size=8, requests=[]))
    assert empty.predict(12) == empty.default == 1.0


# -------------------------------------------------------------- slo scorer
def _timing(rid, arrival, first, finish, out):
    return RequestTiming(req_id=rid, arrival_step=arrival, submit_step=arrival,
                         first_token_step=first, finish_step=finish,
                         output_tokens=out)


def test_slo_scorer_math_on_hand_built_timings():
    trace = Trace(name="t", scenario="mixed", seed=0, vocab_size=8,
                  step_period=0.1, requests=[])
    timings = {
        0: _timing(0, arrival=0, first=2, finish=6, out=5),   # ttft 0.2s,
        #                                                       tpot 0.1s
        1: _timing(1, arrival=0, first=4, finish=4, out=1),   # ttft 0.4s,
        #                                                       tpot 0.0s
        2: RequestTiming(req_id=2, arrival_step=3, submit_step=3),  # no token
    }
    result = ReplayResult(trace=trace, outputs={}, timings=timings, steps=7,
                          idle_fastforwards=1, metrics={"prefix_hits": 3,
                                                        "preemptions": 2})
    assert result.ttft_virtual_s() == pytest.approx([0.2, 0.4])
    assert result.tpot_virtual_s() == pytest.approx([0.1, 0.0])

    r = score(result, Slo(ttft_s=0.4, tpot_s=0.1))
    assert r.p50_ttft_s == pytest.approx(0.2)
    assert r.p99_ttft_s == pytest.approx(0.4)
    assert r.p50_tpot_s == pytest.approx(0.0)   # nearest rank over [0.0, 0.1]
    assert r.p99_tpot_s == pytest.approx(0.1)
    assert r.attainment_ttft == 1.0 and r.attainment_tpot == 1.0
    assert r.ok
    assert not score(result, Slo(ttft_s=0.3, tpot_s=0.1)).ok  # p99 ttft over
    tight = score(result, Slo(ttft_s=0.3, tpot_s=0.05))
    assert tight.attainment_ttft == 0.5 and tight.attainment_tpot == 0.5

    c = result.counters()
    assert c["finished"] == 2 and c["out_tokens"] == 6
    assert c["steps"] == 7 and c["idle_ff"] == 1
    assert c["tok_per_step"] == pytest.approx(6 / 7, abs=1e-4)
    assert c["prefix_hits"] == 3 and c["preempt"] == 2
    assert c["p99_ttft_steps"] == 4 and c["p99_tpot_steps"] == 1.0


def test_score_empty_result_is_not_ok():
    trace = Trace(name="t", scenario="mixed", seed=0, vocab_size=8,
                  requests=[])
    empty = ReplayResult(trace=trace, outputs={}, timings={}, steps=0,
                         idle_fastforwards=0)
    assert not score(empty, Slo(ttft_s=10.0, tpot_s=10.0)).ok


# ----------------------------------------------------------- replay parity
@pytest.fixture(scope="module")
def tiny_serving():
    from repro.config import ServeConfig, get_config
    from repro.models.api import build_model
    from repro.serving.engine import ServingEngine
    import jax

    cfg = get_config("smollm-360m").reduced(dtype="float32")
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))

    def mk_engine(**kw):
        serve = ServeConfig(model=cfg.name, kv_block_size=4, max_batch=3)
        return ServingEngine(model, params, cfg, serve, num_blocks=64, **kw)

    return {"cfg": cfg, "mk_engine": mk_engine}


@pytest.mark.slow       # two engine runs on the reduced model
def test_replay_streams_bit_identical_to_direct_submit(tiny_serving):
    """The repo-wide invariant, extended to the replayer: arrival timing
    changes scheduling, never tokens.  Replaying a trace on the virtual
    clock must emit exactly the streams direct submit() emits."""
    cfg = tiny_serving["cfg"]
    trace = generate("mixed", seed=11, n_requests=6,
                     vocab_size=cfg.vocab_size, prompt_hi=10, gen_cap=6)

    result = replay(tiny_serving["mk_engine"](), trace)
    assert set(result.outputs) == {r.req_id for r in trace.requests}
    assert result.steps > 0
    for t in result.timings.values():           # everyone finished
        assert t.finish_step is not None
        assert t.first_token_step >= t.submit_step
        assert t.output_tokens == len(result.outputs[t.req_id])

    direct = tiny_serving["mk_engine"]()
    for req in trace.to_requests():
        direct.submit(req)
    direct.run_until_done()
    assert all(r.state == RequestState.FINISHED for r in direct.finished)
    assert result.outputs == {r.req_id: list(r.output)
                              for r in direct.finished}


# ------------------------------------------------- perf table + auto triple
def _table_row(name, triple, *, scenario="mixed", slo_ok="1", ttft="10",
               tpot="1.0", steps="50", spec="off", overlap="off"):
    adm, pre, evi = triple
    return {"name": name, "scenario": scenario, "admission": adm,
            "preemption": pre, "eviction": evi, "spec": spec,
            "overlap": overlap, "slo_ok": slo_ok, "p99_ttft_steps": ttft,
            "p99_tpot_steps": tpot, "steps": steps}


EDF = ("deadline-slo", "most-blocks", "refcount-aware")
FCFS = ("fcfs", "latest-arrival", "lru")


def _mixed_table():
    return PerfTable([
        _table_row("a", EDF, slo_ok="1", ttft="12"),
        _table_row("b", FCFS, slo_ok="0", ttft="5"),      # SLO miss loses
        _table_row("c", EDF, slo_ok="1", ttft="4", spec="ngram"),   # excluded
        _table_row("d", EDF, slo_ok="1", ttft="4", overlap="on"),   # excluded
        _table_row("e", ("auto", "auto", "auto"), ttft="1"),        # excluded
    ])


def test_winner_resolution_prefers_slo_then_latency():
    table = _mixed_table()
    assert [r["name"] for r in table.comparable_rows("mixed")] == ["a", "b"]
    assert table.winner("mixed") == dict(zip(AXES, EDF))
    assert table.winner("bursty") is None       # no rows for that scenario
    # Flip the SLO verdicts: the lower-latency triple must win instead.
    flipped = PerfTable([_table_row("a", EDF, slo_ok="0", ttft="12"),
                         _table_row("b", FCFS, slo_ok="0", ttft="5")])
    assert flipped.winner("mixed") == dict(zip(AXES, FCFS))


def test_auto_triple_resolves_measured_winner():
    with perf_context(scenario="mixed", table=_mixed_table()):
        assert resolve_winner("admission") == "deadline-slo"
        triple = {axis: policy.get(axis, "auto")() for axis in AXES}
    for axis, want in zip(AXES, EDF):
        pol = triple[axis]
        assert pol.resolved == want
        assert pol.counters["auto_resolved"] == 1
        assert pol.counters[f"resolved_{want.replace('-', '_')}"] == 1
        assert "auto_fallback" not in pol.counters


def test_auto_triple_counted_fallback_without_table(monkeypatch):
    monkeypatch.delenv("REPRO_PERF_SCENARIO", raising=False)
    monkeypatch.delenv("REPRO_PERF_TABLE", raising=False)
    # No context at all: no scenario -> defaults, counted.
    pol = policy.get("admission", "auto")()
    assert pol.resolved == policy.DEFAULTS["admission"]
    assert pol.counters["auto_fallback"] == 1
    # Scenario active but the table has nothing comparable: same fallback.
    with perf_context(scenario="mixed", table=PerfTable([])):
        pol = policy.get("eviction", "auto")()
    assert pol.resolved == policy.DEFAULTS["eviction"]
    assert pol.counters["auto_fallback"] == 1


def test_auto_scoring_delegates_to_winner():
    """auto's admission_key must equal the resolved policy's key, so the
    scheduler's decisions are bit-identical to running the winner triple."""
    req = Request(req_id=3, prompt=np.arange(4, dtype=np.int32),
                  max_new_tokens=4, arrival=2.5, priority=1, deadline=9.0)
    with perf_context(scenario="mixed", table=_mixed_table()):
        auto = policy.get("admission", "auto")()
    concrete = policy.get("admission", "deadline-slo")()
    assert auto.admission_key(req, now=3.0) == concrete.admission_key(
        req, now=3.0)


def test_predicted_length_admission_orders_by_model(monkeypatch):
    monkeypatch.delenv("REPRO_PERF_SCENARIO", raising=False)
    monkeypatch.delenv("REPRO_PERF_TABLE", raising=False)
    short = Request(req_id=0, prompt=np.arange(6, dtype=np.int32),
                    max_new_tokens=12, arrival=0.0)
    long = Request(req_id=1, prompt=np.arange(14, dtype=np.int32),
                   max_new_tokens=2, arrival=0.0)
    model = LengthModel(buckets={8: 2.0, 16: 20.0}, default=5.0)
    with perf_context(length_model=model):
        pol = policy.get("admission", "predicted-length")()
    assert "model_absent" not in pol.counters
    # The model predicts the short prompt finishes first despite its larger
    # declared cap — the whole point of learned admission.
    assert pol.admission_key(short, 0.0) < pol.admission_key(long, 0.0)

    bare = policy.get("admission", "predicted-length")()
    assert bare.counters["model_absent"] == 1
    # Without a model the declared cap is the estimate: ordering flips.
    assert bare.admission_key(long, 0.0) < bare.admission_key(short, 0.0)


# ------------------------------------------------------------------- gate
def _bench_rows(**overrides):
    base = {"steps": 100, "p99_ttft_steps": 12, "p99_tpot_steps": 1.2,
            "tok_per_step": 1.5, "prefix_hits": 10, "finished": 12,
            "out_tokens": 90}
    base.update(overrides)
    derived = "scenario=mixed;admission=fcfs;preemption=latest-arrival;" \
              "eviction=lru;" + ";".join(f"{k}={v}" for k, v in base.items())
    return [{"name": "trace_mixed_fcfs", "us_per_call": 123.0,
             "derived": derived}]


def _bench_file(tmp_path, fname, rows, schema=SCHEMA_VERSION):
    results = [{"module": "trace_replay", "backend": "ref",
                "schema_version": schema, "git_commit": "abc1234",
                "rows": rows}]
    path = tmp_path / fname
    path.write_text(json.dumps(results))
    return str(path)


def test_gate_clean_when_counters_match(tmp_path, capsys):
    base = _bench_file(tmp_path, "base.json", _bench_rows())
    cur = _bench_file(tmp_path, "cur.json", _bench_rows())
    assert gate.main(["--baseline", base, "--current", cur]) == 0
    out = capsys.readouterr().out
    assert "compared 1 pinned rows" in out and "OK" in out


def test_gate_trips_on_planted_20pct_regression(tmp_path, capsys):
    """The acceptance check: a 25% step-count regression must trip the gate
    at the default 20% threshold, and a 15% one must not."""
    base = _bench_file(tmp_path, "base.json", _bench_rows(steps=100))
    bad = _bench_file(tmp_path, "bad.json", _bench_rows(steps=125))
    assert gate.main(["--baseline", base, "--current", bad,
                      "--threshold", "0.2"]) == 1
    err = capsys.readouterr().err
    assert "steps 100 -> 125" in err and "+25.0%" in err

    ok = _bench_file(tmp_path, "ok.json", _bench_rows(steps=115))
    assert gate.main(["--baseline", base, "--current", ok,
                      "--threshold", "0.2"]) == 0


def test_gate_direction_and_noise_floor(tmp_path):
    base = _bench_file(tmp_path, "base.json", _bench_rows())
    # tok_per_step is a down-is-bad column: a 33% drop trips.
    slow = _bench_file(tmp_path, "slow.json", _bench_rows(tok_per_step=1.0))
    assert gate.main(["--baseline", base, "--current", slow]) == 1
    # ... but an *increase* on it (or on prefix hits) is never a regression.
    fast = _bench_file(tmp_path, "fast.json",
                       _bench_rows(tok_per_step=9.9, prefix_hits=99))
    assert gate.main(["--baseline", base, "--current", fast]) == 0
    # prefix_hits has min_abs 2: a 1-hit wobble on a small base is noise.
    wobble = _bench_file(tmp_path, "wob.json", _bench_rows(prefix_hits=9))
    assert gate.main(["--baseline", base, "--current", wobble]) == 0


def test_gate_exact_columns_catch_workload_drift(tmp_path, capsys):
    base = _bench_file(tmp_path, "base.json", _bench_rows(finished=12))
    drift = _bench_file(tmp_path, "drift.json", _bench_rows(finished=11))
    assert gate.main(["--baseline", base, "--current", drift,
                      "--threshold", "0.99"]) == 1   # threshold can't hide it
    assert "finished" in capsys.readouterr().err


def test_gate_refuses_schema_mismatch(tmp_path, capsys):
    base = _bench_file(tmp_path, "base.json", _bench_rows())
    alien = _bench_file(tmp_path, "alien.json", _bench_rows(), schema=99)
    assert gate.main(["--baseline", base, "--current", alien]) == 2
    assert "SCHEMA REFUSED" in capsys.readouterr().err
    with pytest.raises(SchemaError):
        check_schema({"module": "trace_replay", "schema_version": None},
                     "x.json")


def test_gate_fails_when_nothing_comparable(tmp_path, capsys):
    base = _bench_file(tmp_path, "base.json", _bench_rows())
    rows = _bench_rows()
    rows[0]["name"] = "trace_mixed_renamed"
    other = _bench_file(tmp_path, "other.json", rows)
    assert gate.main(["--baseline", base, "--current", other]) == 1
    assert "no comparable" in capsys.readouterr().err


def test_gate_unreadable_input_is_usage_error(tmp_path):
    base = _bench_file(tmp_path, "base.json", _bench_rows())
    assert gate.main(["--baseline", base,
                      "--current", str(tmp_path / "missing.json")]) == 2


def test_parse_derived_round_trip():
    d = parse_derived("a=1;b=x/y; c = 3 ;junk;")
    assert d == {"a": "1", "b": "x/y", "c": "3"}
    assert parse_derived("") == {}
