"""Registry-enumerated backend parity + resolver semantics.

Every implementation of every op family registered with
:mod:`repro.core.dispatch` is checked against that family's ``ref``
implementation on the family's example inputs — the parametrization is built
FROM the registry, so registering a new backend (or a whole new op family
with an ``example`` factory) auto-enrolls it here with no hand-maintained
list.  The resolver tests pin the precedence contract: explicit arg (strict,
round-tripping) > force_backend scope > REPRO_BACKEND env > config hint >
capability-ranked auto.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dispatch

FAMILIES = list(dispatch.list_ops())

PARITY_CASES = [
    pytest.param(fam.name, impl.backend, id=f"{fam.name}-{impl.backend}")
    for fam in FAMILIES
    for impl in fam.impls()
    if impl.backend != dispatch.REF
]


@pytest.fixture(autouse=True)
def _no_env_backend(monkeypatch):
    """Resolution tests must see the real precedence, not CI's env pin."""
    monkeypatch.delenv(dispatch.ENV_VAR, raising=False)


def test_every_family_has_ref_and_example():
    assert FAMILIES, "registry is empty"
    for fam in FAMILIES:
        assert fam.get(dispatch.REF) is not None, f"{fam.name} lacks ref"
        assert fam.example is not None, f"{fam.name} lacks example inputs"


def test_chunked_resolvable_to_ref_and_pallas():
    """Acceptance: the serving hot path has ≥2 registry-resolvable impls."""
    fam = dispatch.get_op("paged_attention_chunked")
    assert fam.resolve("ref").backend == "ref"
    # interpret-mode Pallas must resolve on every platform (CPU included)
    assert fam.resolve("pallas_interpret").backend == "pallas_interpret"


@pytest.mark.parametrize("op_name,backend", PARITY_CASES)
def test_parity_vs_ref(op_name, backend):
    fam = dispatch.get_op(op_name)
    args, kwargs = fam.example()
    spec = dispatch.CallSpec(platform=jax.default_backend(), args=args,
                             kwargs=kwargs)
    impl = fam.get(backend)
    if not impl.supports(spec):
        # Capability-gated impls must refuse explicit selection loudly...
        with pytest.raises(dispatch.BackendUnavailableError):
            fam.resolve(backend, spec=spec)
        # ...and never be chosen by auto.
        assert fam.resolve(spec=spec).backend != backend
        pytest.skip(f"{backend} unsupported on {spec.platform}")
    ref = fam(*args, backend=dispatch.REF, **kwargs)
    out = fam(*args, backend=backend, **kwargs)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("fam", FAMILIES, ids=lambda f: f.name)
def test_explicit_resolution_round_trips(fam):
    """resolve(name).backend == name for every supported impl (the guarantee
    that killed the old double dispatch)."""
    args, kwargs = fam.example()
    spec = dispatch.CallSpec(platform=jax.default_backend(), args=args,
                             kwargs=kwargs)
    for impl in fam.impls():
        if impl.supports(spec):
            assert fam.resolve(impl.backend, spec=spec).backend == impl.backend


def test_auto_never_picks_pallas_on_cpu():
    if jax.default_backend() == "tpu":
        pytest.skip("CPU-only check")
    for fam in FAMILIES:
        assert fam.resolve().backend not in ("pallas", "pallas_interpret"), \
            fam.name


def test_precedence_scope_over_env_over_config(monkeypatch):
    fam = dispatch.get_op("paged_attention")
    # config hint is the weakest preference
    assert fam.resolve(config="ref").backend == "ref"
    monkeypatch.setenv(dispatch.ENV_VAR, "pallas_interpret")
    assert fam.resolve(config="ref").backend == "pallas_interpret"
    with dispatch.force_backend("ref"):
        assert fam.resolve(config="xla").backend == "ref"
        # explicit arg still beats the scope
        assert fam.resolve("xla").backend == "xla"


def test_unsupported_preference_falls_back_to_auto():
    if jax.default_backend() == "tpu":
        pytest.skip("CPU-only check")
    fam = dispatch.get_op("paged_attention")
    with dispatch.force_backend("pallas"):
        assert fam.resolve().backend == "xla"      # graceful degrade
    with pytest.raises(dispatch.BackendUnavailableError):
        fam.resolve("pallas")                       # explicit stays strict


def test_shape_capability_fallback():
    """stream pallas tiling needs whole 128-lane rows; a ragged array must
    fall back to ref under auto and refuse explicit pallas selection."""
    fam = dispatch.get_op("stream_add")
    a = jnp.ones((100,), jnp.float32)               # not a multiple of 128
    spec = dispatch.CallSpec(platform=jax.default_backend(), args=(a, a),
                             kwargs={})
    assert fam.resolve(spec=spec).backend == "ref"
    with pytest.raises(dispatch.BackendUnavailableError):
        fam.resolve("pallas_interpret", spec=spec)


def test_resolution_log_records_op_and_backend():
    fam = dispatch.get_op("vector_gather")
    args, kwargs = fam.example()
    with dispatch.record_resolutions() as log:
        fam(*args, backend="ref", **kwargs)
    assert ("vector_gather", "ref") in log


def test_nested_resolution_logs_stay_separate():
    """Exiting an inner record_resolutions scope must not drop the outer
    (removal is by identity — two empty logs compare equal)."""
    with dispatch.record_resolutions() as outer:
        with dispatch.record_resolutions() as inner:
            pass
        dispatch.resolve("vector_gather", "ref")
    assert ("vector_gather", "ref") in outer
    assert inner == []


def test_duplicate_registration_rejected():
    fam = dispatch.get_op("stream_add")
    with pytest.raises(ValueError):
        fam.register("ref")(lambda *a, **k: None)
    with pytest.raises(ValueError):
        fam.register("not_a_backend")(lambda *a, **k: None)
