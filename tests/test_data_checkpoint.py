"""Data pipeline determinism + checkpoint manager fault tolerance."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.data.pipeline import (
    DataPipeline, SyntheticLMDataset, SyntheticRecSysDataset)


def test_dataset_deterministic_and_sharded():
    ds = SyntheticLMDataset(1000, 16, 8, seed=7)
    a = ds.batch_at(3)["tokens"]
    b = ds.batch_at(3)["tokens"]
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, ds.batch_at(4)["tokens"])
    h0 = ds.batch_at(3, host=0, num_hosts=2)["tokens"]
    h1 = ds.batch_at(3, host=1, num_hosts=2)["tokens"]
    assert h0.shape == (4, 16)
    assert not np.array_equal(h0, h1)


def test_pipeline_prefetch_order_and_restart():
    ds = SyntheticLMDataset(100, 8, 4)
    p = DataPipeline(ds, start_step=5)
    s0, b0 = next(p)
    s1, b1 = next(p)
    p.close()
    assert (s0, s1) == (5, 6)
    # restart at step 6 reproduces the same batch — restart safety
    p2 = DataPipeline(ds, start_step=6)
    s2, b2 = next(p2)
    p2.close()
    assert s2 == 6
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])


def test_recsys_dataset_shapes():
    from repro.config import get_config
    cfg = get_config("rm2")
    ds = SyntheticRecSysDataset(cfg, 8)
    b = ds.batch_at(0)
    assert b["indices"].shape == (8, 20, 20)
    assert b["dense"].shape == (8, 13)


def test_checkpoint_roundtrip_and_retention(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16)}}
    for step in (10, 20, 30):
        scaled = jax.tree.map(lambda x: x * step, tree)
        cm.save(step, scaled, blocking=True)
    assert cm.all_steps() == [20, 30]     # keep=2 retention
    assert cm.latest_step() == 30
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    restored = cm.restore(30, like)
    np.testing.assert_allclose(np.asarray(restored["w"], np.float32),
                               np.arange(6, dtype=np.float32).reshape(2, 3) * 30)


def test_checkpoint_async_and_placer(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.ones((8, 8))}
    cm.save(1, tree, blocking=False)
    cm.wait()
    assert cm.latest_step() == 1
    placed = cm.restore(1, tree, placer=lambda x, like: jax.device_put(x))
    assert isinstance(placed["w"], jax.Array)


def test_checkpoint_atomic_no_partial(tmp_path):
    """No .tmp dirs survive a completed save; LATEST matches a real dir."""
    cm = CheckpointManager(str(tmp_path))
    cm.save(5, {"x": jnp.zeros((2,))}, blocking=True)
    assert not list(tmp_path.glob(".tmp*"))
    assert (tmp_path / "step_000000005").exists()


def test_trainer_checkpoint_resume(tmp_path):
    """Kill-and-resume: trainer restores state and continues."""
    from repro.config import get_config
    from repro.models.api import build_model
    from repro.optim import adamw, cosine_warmup
    from repro.training.train_step import init_state, make_train_step
    from repro.training.trainer import Trainer

    cfg = get_config("smollm-360m").reduced(dtype="float32", num_layers=1,
                                            vocab_size=64)
    model = build_model(cfg, remat=False)
    opt = adamw()
    step = jax.jit(make_train_step(model, opt, cosine_warmup(1e-3, 2, 20)))
    state = init_state(model, jax.random.PRNGKey(0), opt)
    ds = SyntheticLMDataset(cfg.vocab_size, 16, 2)

    p1 = DataPipeline(ds)
    cm = CheckpointManager(str(tmp_path))
    t1 = Trainer(step_fn=step, state=state, pipeline=p1, ckpt=cm,
                 checkpoint_every=4)
    t1.run(8)
    p1.close()
    step8 = int(t1.state.step)

    # "crash": new trainer from scratch, resume from checkpoint
    state2 = init_state(model, jax.random.PRNGKey(42), opt)
    p2 = DataPipeline(ds, start_step=cm.latest_step())
    t2 = Trainer(step_fn=step, state=state2, pipeline=p2, ckpt=cm)
    resumed = t2.maybe_restore()
    p2.close()
    assert resumed == 8
    assert int(t2.state.step) == step8
    np.testing.assert_allclose(
        np.asarray(jax.tree.leaves(t2.state.params)[0], np.float32),
        np.asarray(jax.tree.leaves(t1.state.params)[0], np.float32))
