"""Scheduler-driven serving stack: allocator refcount/prefix/CoW edge cases,
request state machine, chunked-prefill equivalence, preemption round trip,
shared-prefix block savings."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ServeConfig, get_config
from repro.core.paged_kv import BlockAllocator, OutOfBlocksError, make_pool
from repro.models.api import build_model
from repro.serving.engine import Request, ServingEngine
from repro.serving.request import RequestState, SamplingParams
from repro.serving.sampling import sample_batched

KEY = jax.random.PRNGKey(0)


# --------------------------------------------------------------- allocator
def test_allocator_double_free_protection():
    al = BlockAllocator(num_blocks=8, block_size=4)
    al.allocate(0, 6)
    al.free(0)
    with pytest.raises(KeyError):
        al.free(0)
    assert al.num_free == 8


def test_allocator_refcount_shared_prefix_and_free_order():
    al = BlockAllocator(num_blocks=8, block_size=4)
    p = np.arange(8, dtype=np.int32)
    al.allocate_prefix(0, p)
    al.reserve_tokens(0, 8)
    al.commit_tokens(0, 8)
    al.register_prefix(0, p, 8)
    cached = al.allocate_prefix(1, p)          # shares both full blocks
    assert cached == 7                          # last token left to recompute
    assert al.table(1) == al.table(0)
    assert al.ref_count(al.table(0)[0]) == 2
    al.free(0)                                  # shared blocks must survive
    assert al.ref_count(al.table(1)[0]) == 1
    al.free(1)
    assert al.num_free == 8                     # hashed blocks cached-free


def test_allocator_copy_on_write_on_shared_block():
    al = BlockAllocator(num_blocks=8, block_size=4)
    p = np.arange(8, dtype=np.int32)
    al.allocate_prefix(0, p)
    al.reserve_tokens(0, 8)
    al.commit_tokens(0, 8)
    al.register_prefix(0, p, 8)
    al.allocate_prefix(1, p)                    # table(1) aliases table(0)
    shared = al.table(0)[1]
    slots = al.reserve_tokens(1, 1)             # write pos 7 -> shared block
    assert al.cow_copies == 1
    assert al.table(1)[1] != shared             # private copy in the table
    assert al.table(0)[1] == shared             # owner untouched
    assert al.drain_copies() == [(shared, al.table(1)[1])]
    assert tuple(slots[0]) == (al.table(1)[1], 3)
    # the freshly reserved (uncommitted) position sits on the new block;
    # writing the owner's block again must NOT CoW (refcount back to 1)
    al.reserve_tokens(0, 1)
    assert al.cow_copies == 1


def test_allocator_prefix_hit_miss_accounting():
    al = BlockAllocator(num_blocks=16, block_size=4)
    p = np.arange(12, dtype=np.int32)
    al.allocate_prefix(0, p)                    # cold: 3 full blocks missed
    assert (al.prefix_hits, al.prefix_misses) == (0, 3)
    al.reserve_tokens(0, 12)
    al.commit_tokens(0, 12)
    al.register_prefix(0, p, 12)
    q = np.concatenate([p[:8], np.array([99, 98, 97, 96], np.int32)])
    al.allocate_prefix(1, q)                    # 2 hits, third block differs
    assert (al.prefix_hits, al.prefix_misses) == (2, 4)
    assert al.peek_prefix(q) == 8               # peek does not mutate
    assert (al.prefix_hits, al.prefix_misses) == (2, 4)


def test_allocator_rewind_truncate_release_blocks():
    al = BlockAllocator(num_blocks=8, block_size=2)
    al.allocate(0, 5)                           # 3 blocks
    assert al.num_free == 5
    al.rewind(0, 2)                             # len 3 -> 2 blocks
    assert al.seq_len(0) == 3 and len(al.table(0)) == 2 and al.num_free == 6
    al.truncate(0, 0)                           # keeps one block minimum
    assert al.seq_len(0) == 0 and len(al.table(0)) == 1 and al.num_free == 7
    with pytest.raises(AssertionError):
        al.truncate(0, 5)                       # cannot truncate upward


def test_allocator_cached_free_eviction_makes_room():
    al = BlockAllocator(num_blocks=4, block_size=4)
    p = np.arange(8, dtype=np.int32)
    al.allocate_prefix(0, p)
    al.reserve_tokens(0, 8)
    al.commit_tokens(0, 8)
    al.register_prefix(0, p, 8)
    al.free(0)                                  # 2 hashed blocks cached-free
    assert al.num_free == 4
    al.allocate(1, 16)                          # needs the whole pool
    assert al.cache_evictions == 2
    assert al.peek_prefix(p) == 0               # cache entries dropped


# ----------------------------------------------------------- state machine
def test_request_state_machine_transitions():
    req = Request(req_id=0, prompt=np.arange(4, dtype=np.int32),
                  max_new_tokens=4)
    assert req.state is RequestState.WAITING
    req.begin_prefill(slot=0, cached_tokens=0)
    assert req.state is RequestState.PREFILLING
    req.preempt()
    assert req.state is RequestState.PREEMPTED and req.slot == -1
    req.output.append(7)
    req.begin_prefill(slot=1, cached_tokens=0)
    assert len(req.active_prompt) == 5          # prompt + generated token
    req.to_state(RequestState.DECODING)
    req.finish()
    with pytest.raises(AssertionError):
        req.to_state(RequestState.DECODING)     # FINISHED is terminal


# ------------------------------------------------------------- engine e2e
def _make():
    cfg = get_config("qwen2-1.5b").reduced(dtype="float32")
    model = build_model(cfg, remat=False)
    params = model.init(KEY)
    return cfg, model, params


@pytest.mark.slow       # two full engine runs
def test_shared_prefix_allocates_fewer_blocks_with_hits():
    """N requests with a common prefix must allocate strictly fewer fresh
    pool blocks than N independent prompts, with prefix hits > 0."""
    cfg, model, params = _make()
    rng = np.random.default_rng(0)
    serve = ServeConfig(model=cfg.name, kv_block_size=4, max_batch=2)
    prefix = rng.integers(0, cfg.vocab_size, (8,), dtype=np.int32)

    def run(prompts):
        eng = ServingEngine(model, params, cfg, serve, num_blocks=64)
        for i, p in enumerate(prompts):
            eng.submit(Request(req_id=i, prompt=p, max_new_tokens=3))
        eng.run_until_done()
        return eng

    shared = [np.concatenate([prefix,
                              rng.integers(0, cfg.vocab_size, (2,),
                                           dtype=np.int32)])
              for _ in range(6)]
    indep = [rng.integers(0, cfg.vocab_size, (10,), dtype=np.int32)
             for _ in range(6)]
    es, ei = run(shared), run(indep)
    ms = es.metrics()
    assert ms["prefix_hits"] > 0
    assert ms["prefix_hit_rate"] > 0
    assert es.alloc.blocks_allocated < ei.alloc.blocks_allocated
    assert ms["finished"] == 6 and ei.metrics()["finished"] == 6


@pytest.mark.slow       # two full engine runs
def test_chunked_prefill_token_identical_across_budgets():
    """Chunked prefill (budget 2) == one-shot prefill (budget 2048) for
    greedy sampling — the acceptance equivalence for the fused step."""
    cfg, model, params = _make()
    rng = np.random.default_rng(1)
    serve = ServeConfig(model=cfg.name, kv_block_size=4, max_batch=3)
    prompts = [rng.integers(0, cfg.vocab_size, (n,), dtype=np.int32)
               for n in (5, 9, 3)]

    def run(budget):
        eng = ServingEngine(model, params, cfg, serve, num_blocks=64,
                            token_budget=budget)
        for i, p in enumerate(prompts):
            eng.submit(Request(req_id=i, prompt=p, max_new_tokens=6))
        eng.run_until_done()
        return {r.req_id: r.output for r in eng.finished}

    assert run(2) == run(2048)


@pytest.mark.slow       # two full engine runs
def test_preemption_resume_round_trip_preserves_output():
    """Starving the pool forces preemption; recompute-resume must reproduce
    the un-preempted generation exactly (greedy)."""
    cfg, model, params = _make()
    rng = np.random.default_rng(2)
    serve = ServeConfig(model=cfg.name, kv_block_size=4, max_batch=3)
    prompts = [rng.integers(0, cfg.vocab_size, (6,), dtype=np.int32)
               for _ in range(3)]

    def run(num_blocks):
        eng = ServingEngine(model, params, cfg, serve, num_blocks=num_blocks)
        for i, p in enumerate(prompts):
            eng.submit(Request(req_id=i, prompt=p, max_new_tokens=8))
        eng.run_until_done()
        return eng

    big, small = run(64), run(8)
    assert small.metrics()["preemptions"] > 0
    big_out = {r.req_id: r.output for r in big.finished}
    for r in small.finished:
        assert r.output == big_out[r.req_id], r.req_id
    assert small.metrics()["blocks_free"] == 8          # no leak across preempt
    assert max(r.num_preemptions for r in small.finished) > 0


@pytest.mark.slow       # two full engine runs
def test_per_request_sampling_plugs_into_fused_step():
    """Greedy and stochastic requests share one batch; greedy lanes must be
    unaffected by their stochastic neighbours."""
    cfg, model, params = _make()
    rng = np.random.default_rng(3)
    serve = ServeConfig(model=cfg.name, kv_block_size=4, max_batch=2)
    prompt = rng.integers(0, cfg.vocab_size, (6,), dtype=np.int32)

    eng = ServingEngine(model, params, cfg, serve, num_blocks=64)
    eng.submit(Request(req_id=0, prompt=prompt, max_new_tokens=5))
    eng.submit(Request(req_id=1, prompt=prompt, max_new_tokens=5,
                       sampling=SamplingParams(temperature=1.0, top_k=40,
                                               top_p=0.9)))
    eng.run_until_done()
    outs = {r.req_id: r.output for r in eng.finished}

    solo = ServingEngine(model, params, cfg, serve, num_blocks=64)
    solo.submit(Request(req_id=0, prompt=prompt, max_new_tokens=5))
    solo.run_until_done()
    assert outs[0] == solo.finished[0].output


def test_sample_batched_greedy_lane_matches_argmax():
    logits = jax.random.normal(KEY, (4, 32))
    toks = sample_batched(
        jax.random.PRNGKey(1), logits,
        jnp.asarray([0.0, 0.0, 1.0, 0.7]), jnp.asarray([0, 5, 0, 3]),
        jnp.asarray([1.0, 1.0, 0.9, 1.0]))
    ref = jnp.argmax(logits, axis=-1)
    assert toks[0] == ref[0] and toks[1] == ref[1]
    assert toks.shape == (4,) and toks.dtype == jnp.int32


def test_metrics_expose_percentiles_and_throughput():
    cfg, model, params = _make()
    rng = np.random.default_rng(4)
    serve = ServeConfig(model=cfg.name, kv_block_size=4, max_batch=2)
    eng = ServingEngine(model, params, cfg, serve, num_blocks=64)
    for i in range(4):
        eng.submit(Request(
            req_id=i,
            prompt=rng.integers(0, cfg.vocab_size, (5,), dtype=np.int32),
            max_new_tokens=3))
    eng.run_until_done()
    m = eng.metrics()
    for k in ("p50_ttft_s", "p99_ttft_s", "p50_tpot_s", "p99_tpot_s",
              "throughput_tok_s", "preemptions", "prefix_hit_rate",
              "cow_copies"):
        assert k in m, k
    assert m["p99_ttft_s"] >= m["p50_ttft_s"] > 0
    assert m["throughput_tok_s"] > 0
    assert m["finished"] == 4
