"""Integration: the dry-run machinery end-to-end on an 8-device host mesh —
the same code path the 16×16 / 2×16×16 production runs use."""
import json

import pytest

from conftest import run_multidevice

# 8-device subprocess integration: multi-minute -> excluded from the fast tier
pytestmark = pytest.mark.slow

_RUNNER = """
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import jax, warnings
warnings.filterwarnings('ignore')
import repro.launch.dryrun as dr
from repro.config import ShapeCell

def make_small(*, multi_pod=False):
    if multi_pod:
        return jax.make_mesh((2, 2, 2), ('pod', 'data', 'model'))
    return jax.make_mesh((4, 2), ('data', 'model'))

dr.make_production_mesh = make_small
dr.SHAPES = dict(dr.SHAPES)
dr.SHAPES['train_4k'] = ShapeCell('train_4k', 256, 8, 'train')
dr.SHAPES['decode_32k'] = ShapeCell('decode_32k', 1024, 8, 'decode')
dr.SHAPES['prefill_32k'] = ShapeCell('prefill_32k', 1024, 4, 'prefill')
"""


@pytest.mark.parametrize("arch,shape,multi", [
    ("smollm-360m", "train_4k", False),
    ("smollm-360m", "train_4k", True),
    ("granite-moe-1b-a400m", "decode_32k", True),
    ("rwkv6-1.6b", "prefill_32k", False),
])
def test_dryrun_cell(arch, shape, multi):
    snippet = _RUNNER + f"""
rec = dr.run_cell({arch!r}, {shape!r}, multi_pod={multi}, probes=False,
                  verbose=False)
assert rec['status'] == 'ok', rec
rl = rec['roofline']
assert rl['hlo_flops'] > 0 and rl['hlo_bytes'] > 0
assert rl['bottleneck'] in ('compute', 'memory', 'collective')
import json
print('REC', json.dumps({{'flops': rl['hlo_flops'],
                          'coll': rl['collective_bytes']}}))
print('OK')
"""
    r = run_multidevice(snippet, timeout=900)
    assert "OK" in r.stdout, (r.stdout[-500:], r.stderr[-3000:])


def test_dryrun_probe_extrapolation_close_to_model_flops():
    """Probed HLO FLOPs within 2× of analytic 6·N·D for a dense arch."""
    snippet = _RUNNER + """
from repro.roofline.model_flops import model_flops
from repro.config import get_config
rec = dr.run_cell('smollm-360m', 'train_4k', multi_pod=False, probes=True,
                  verbose=False)
assert rec['status'] == 'ok', rec
assert 'probe_error' not in rec, rec.get('probe_error')
ratio = rec['roofline']['useful_flops_ratio']
assert 0.5 < ratio <= 1.2, ratio
print('OK', ratio)
"""
    r = run_multidevice(snippet, timeout=900)
    assert "OK" in r.stdout, (r.stdout[-500:], r.stderr[-3000:])


def test_long500k_skip_policy():
    snippet = _RUNNER + """
rec = dr.run_cell('qwen3-32b', 'long_500k', multi_pod=False, probes=False)
assert rec['status'] == 'skipped'
print('OK')
"""
    r = run_multidevice(snippet, timeout=300)
    assert "OK" in r.stdout, r.stderr[-2000:]
