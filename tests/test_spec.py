"""Speculative decoding subsystem: proposer registry precedence, the shipped
ngram / draft-model proposers, batched verify + rejection-accept semantics,
allocator rollback invariants (refcounts/free-list restored after a
fully-rejected step), generated-token prefix caching, and the greedy
spec-vs-baseline parity sweep across the registry-enumerated policy triples
— speculation changes *speed*, never *tokens*."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ServeConfig, get_config
from repro.core.paged_kv import BlockAllocator
from repro.models.api import build_model
from repro.serving import policy
from repro.serving import spec
from repro.serving.engine import Request, ServingEngine
from repro.serving.request import RequestState
from repro.serving.scheduler import Scheduler
from repro.serving.spec import NgramProposer, verify_batched

KEY = jax.random.PRNGKey(0)

SHIPPED = {"ngram", "draft-model"}


def _req(i, prompt, max_new=8, **kw):
    return Request(req_id=i, prompt=np.asarray(prompt, np.int32),
                   max_new_tokens=max_new, **kw)


# ------------------------------------------------------------------ registry
def test_shipped_proposers_are_registered():
    assert SHIPPED <= set(spec.names())
    assert spec.names()[0] == spec.OFF          # "off" leads the listing
    assert spec.OFF not in spec.names(include_off=False)


def test_resolve_precedence_explicit_scope_config_default():
    assert spec.resolve() is None               # default: off
    assert isinstance(spec.resolve(config="ngram"), NgramProposer)
    with spec.force_proposer("draft-model"):
        # scope beats config, explicit beats scope
        assert spec.resolve(config="ngram").name == "draft-model"
        assert spec.resolve("ngram").name == "ngram"
        assert spec.resolve("off") is None      # explicit off wins too
    with spec.force_proposer("off"):
        assert spec.resolve(config="ngram") is None  # forced off beats config
    assert spec.resolve(config="off") is None


def test_draft_alias_resolves_to_canonical_name():
    assert spec.get("draft") is spec.get("draft-model")
    prop = spec.resolve("draft")
    assert prop.name == "draft-model"
    with spec.record_resolutions() as log:
        spec.resolve(config="draft")
    assert log == ["draft-model"]               # attribution is canonical
    with spec.force_proposer("draft"):          # scopes normalize too
        assert spec.forced_proposer() == "draft-model"


def test_resolve_strict_on_unknown_names():
    with pytest.raises(spec.UnknownProposerError):
        spec.resolve("nope")
    with pytest.raises(spec.UnknownProposerError):
        spec.resolve(config="nope")
    with pytest.raises(spec.UnknownProposerError):
        with spec.force_proposer("nope"):
            pass                                # validated on scope entry


def test_resolve_instance_passthrough_and_fresh_counters():
    inst = spec.resolve("ngram")
    assert spec.resolve(inst) is inst
    a, b = spec.resolve("ngram"), spec.resolve("ngram")
    assert a is not b
    a.count("proposals")
    assert b.counters == {}


def test_record_resolutions_collects_names():
    with spec.record_resolutions() as log:
        spec.resolve("ngram")
        spec.resolve()                          # off is logged too
    assert log == ["ngram", "off"]


# ----------------------------------------------------------------- proposers
def test_ngram_proposes_continuation_of_most_recent_match():
    p = NgramProposer()
    r = _req(0, [7, 1, 2, 3, 7, 1, 2])
    # suffix [7,1,2] matched at position 0 -> the tokens that followed
    assert list(p.propose(r, 2)) == [3, 7]
    # proposal is clipped by the sequence end
    assert list(p.propose(r, 10)) == [3, 7, 1, 2]


def test_ngram_reads_generated_tokens_and_prefers_recent():
    p = NgramProposer()
    r = _req(0, [5, 1, 2, 9])
    r.output = [1, 2, 4, 1, 2]                  # generation looped
    # suffix [.., 1, 2]: the most recent earlier occurrence (output pos 3)
    # would run off the end, so the next-most-recent wins -> follows with 4
    assert list(p.propose(r, 2)) == [4, 1]


def test_ngram_no_match_or_tiny_context_is_empty():
    p = NgramProposer()
    assert len(p.propose(_req(0, [1, 2, 3, 4]), 3)) == 0   # no repetition
    assert len(p.propose(_req(0, [1]), 3)) == 0            # too short
    assert len(p.propose(_req(0, [1, 1, 1]), 0)) == 0      # k == 0


def test_draft_model_proposer_is_deterministic_and_in_vocab():
    cfg = get_config("qwen2-1.5b").reduced(dtype="float32")
    model = build_model(cfg, remat=False)
    params = model.init(KEY)
    p = spec.DraftModelProposer(model=model, params=params, window=16)
    p.bind(None)                                # injected model: no engine
    r = _req(0, [3, 1, 4, 1, 5])
    d1, d2 = p.propose(r, 3), p.propose(r, 3)
    assert d1.shape == (3,) and d1.dtype == np.int32
    assert list(d1) == list(d2)
    assert all(0 <= t < cfg.vocab_size for t in d1)
    assert p.counters["draft_forwards"] == 6


def test_propose_batch_default_matches_propose():
    """The base-class batched entry point must loop propose() exactly
    (k <= 0 rows come back empty without touching propose)."""
    p, q = NgramProposer(), NgramProposer()
    r0 = _req(0, [1, 2, 3, 1, 2])
    r1 = _req(1, [4, 4, 4, 4])
    r2 = _req(2, [9, 8, 7])
    out = p.propose_batch([(r0, 3), (r1, 2), (r2, 0)])
    assert set(out) == {0, 1, 2}
    assert list(out[0]) == list(q.propose(r0, 3))
    assert list(out[1]) == list(q.propose(r1, 2))
    assert len(out[2]) == 0


def test_draft_model_propose_batch_matches_per_request():
    """The batched rollout (ROADMAP: one bucketed forward per round instead
    of per-request host loops) proposes EXACTLY what per-request propose
    would, in k_max forwards instead of sum(k_i)."""
    cfg = get_config("qwen2-1.5b").reduced(dtype="float32")
    model = build_model(cfg, remat=False)
    params = model.init(KEY)
    pb = spec.DraftModelProposer(model=model, params=params, window=16)
    ps = spec.DraftModelProposer(model=model, params=params, window=16)
    pb.bind(None)
    ps.bind(None)
    rng = np.random.default_rng(3)
    reqs = []
    for i, k in enumerate([3, 1, 2, 0]):
        r = _req(i, rng.integers(0, cfg.vocab_size,
                                 (int(rng.integers(2, 12)),), dtype=np.int32))
        r.output = [int(t) for t in
                    rng.integers(0, cfg.vocab_size, (i,), dtype=np.int32)]
        reqs.append((r, k))
    batched = pb.propose_batch(reqs)
    for r, k in reqs:
        solo = ps.propose(r, k)
        assert list(batched[r.req_id]) == list(solo), (r.req_id, k)
    assert pb.counters["draft_forwards"] == 3           # k_max rounds
    assert pb.counters["batched_rollouts"] == 1
    assert ps.counters["draft_forwards"] == 6           # sum of k_i


# -------------------------------------------------------------------- verify
def _verify_greedy(logits, draft, d):
    out, acc = verify_batched(
        KEY, jnp.asarray(logits, jnp.float32)[None],
        jnp.asarray(draft, jnp.int32)[None],
        jnp.asarray([d], jnp.int32), jnp.zeros((1,), jnp.float32),
        jnp.zeros((1,), jnp.int32), jnp.ones((1,), jnp.float32))
    return np.asarray(out)[0], int(np.asarray(acc)[0])


def test_verify_greedy_accepts_matching_prefix_and_corrects():
    V = 8
    rows = np.full((3, V), -1.0, np.float32)
    rows[0, 2] = rows[1, 5] = rows[2, 1] = 1.0  # argmax per row: 2, 5, 1
    out, a = _verify_greedy(rows, [2, 4], d=2)  # draft 2 ok, 4 != 5
    assert a == 1
    assert list(out[:2]) == [2, 5]              # accepted + corrected
    out, a = _verify_greedy(rows, [2, 5], d=2)  # full accept -> bonus row
    assert a == 2
    assert list(out[:3]) == [2, 5, 1]
    out, a = _verify_greedy(rows, [3, 5], d=2)  # first draft wrong
    assert a == 0
    assert out[0] == 2
    out, a = _verify_greedy(rows, [0, 0], d=0)  # no drafts: plain decode
    assert a == 0
    assert out[0] == 2


def test_verify_acceptance_never_skips_a_rejection():
    """A rejected draft must gate everything behind it, even if a later
    draft happens to match its row's argmax."""
    V = 8
    rows = np.full((3, V), -1.0, np.float32)
    rows[0, 2] = rows[1, 5] = rows[2, 1] = 1.0
    out, a = _verify_greedy(rows, [9 % V, 5], d=2)   # row0 rejects, row1 ok
    assert a == 0
    assert out[0] == 2


# --------------------------------------- distribution preservation (property)
@jax.jit
def _first_emitted(keys, logits, draft):
    """First emitted token of a stochastic 1-draft verify, per key (N,)."""
    def one(k):
        out, _ = verify_batched(
            k, logits[None], draft[None, None], jnp.ones((1,), jnp.int32),
            jnp.ones((1,), jnp.float32), jnp.zeros((1,), jnp.int32),
            jnp.ones((1,), jnp.float32))
        return out[0, 0]
    return jax.vmap(one)(keys)


def _check_first_token_distribution(draft, lg, n=4000, tol=0.06):
    logits = jnp.asarray([lg, lg], jnp.float32)           # (R=2, V=4)
    keys = jax.random.split(jax.random.PRNGKey(draft), n)
    toks = np.asarray(_first_emitted(keys, logits, jnp.int32(draft)))
    emp = np.bincount(toks, minlength=len(lg)) / n
    target = np.asarray(jax.nn.softmax(jnp.asarray(lg, jnp.float32)))
    tv = 0.5 * np.abs(emp - target).sum()
    assert tv < tol, (tv, emp, target, draft)


def test_rejection_sampling_preserves_target_distribution():
    """The delta-q accept/residual rule emits the first token EXACTLY from
    the target distribution p, whatever token the proposer guessed:
    P(accept d) = p(d), P(reject -> t) = (1 - p(d)) * p(t) / (1 - p(d)).

    Property-based when hypothesis is available; otherwise a fixed sweep
    over representative cases (a dominant draft, a dominated draft, near-
    uniform logits, a spread distribution — and every draft position)."""
    try:
        from hypothesis import given, settings
        from hypothesis import strategies as st
    except ImportError:
        for lg in ([2.0, -2.0, 0.0, 1.0], [0.1, 0.0, -0.1, 0.05],
                   [-2.0, 1.5, 1.4, 0.0]):
            for draft in range(4):
                _check_first_token_distribution(draft, lg)
        return

    @settings(max_examples=6, deadline=None)
    @given(draft=st.integers(0, 3),
           lg=st.lists(st.floats(-2.0, 2.0), min_size=4, max_size=4))
    def prop(draft, lg):
        _check_first_token_distribution(draft, lg)

    prop()


# ------------------------------------------------- scheduler spec budgeting
def _decoding_req(alloc, rid, prompt_len, slot=0, max_new=8):
    r = _req(rid, np.arange(prompt_len), max_new=max_new)
    alloc.allocate(rid, prompt_len)
    r.begin_prefill(slot, prompt_len,
                    active_prompt=np.asarray(r.prompt, np.int32))
    r.to_state(RequestState.DECODING)
    r.output = [1]
    return r


def test_scheduler_plans_spec_lanes_and_counts_tokens():
    alloc = BlockAllocator(num_blocks=8, block_size=4)
    sched = Scheduler(alloc, max_batch=2, token_budget=8)
    r = _decoding_req(alloc, 0, prompt_len=4)
    sched.running[0] = r
    plan = sched.schedule(spec_drafts={0: np.asarray([7, 8, 9], np.int32)})
    assert list(plan.spec) == [0]
    assert plan.decode_tokens(r) == 4
    assert plan.num_tokens == 4


def test_scheduler_splits_budget_between_drafts_and_pending_prefill():
    alloc = BlockAllocator(num_blocks=16, block_size=4)
    sched = Scheduler(alloc, max_batch=2, token_budget=4)
    r = _decoding_req(alloc, 0, prompt_len=4, slot=0)
    pre = _req(1, np.arange(10), max_new=4)
    alloc.allocate(1, 0)
    pre.begin_prefill(1, 0, active_prompt=np.asarray(pre.prompt, np.int32))
    sched.running = {0: r, 1: pre}
    plan = sched.schedule(spec_drafts={0: np.asarray([7, 8, 9], np.int32)})
    # with prefill waiting, drafts get at most half the budget (trimmed to
    # 2 lanes) and prefill keeps the remainder — slowed, never starved
    assert list(plan.spec[0]) == [7, 8]
    assert plan.prefill == [(pre, 2)]
    assert plan.num_tokens == 5                 # 1 decode + 2 draft + 2 chunk


def test_scheduler_trims_drafts_to_token_budget():
    alloc = BlockAllocator(num_blocks=16, block_size=4)
    sched = Scheduler(alloc, max_batch=2, token_budget=2)
    r = _decoding_req(alloc, 0, prompt_len=4)
    sched.running = {0: r}
    plan = sched.schedule(spec_drafts={0: np.asarray([7, 8, 9], np.int32)})
    # no prefill pending: drafts may use the whole budget, but no more —
    # total lanes stay within #decode + token_budget
    assert list(plan.spec[0]) == [7, 8]
    assert plan.num_tokens == 3


def test_scheduler_sheds_drafts_before_preempting():
    alloc = BlockAllocator(num_blocks=1, block_size=4)
    sched = Scheduler(alloc, max_batch=2, token_budget=8)
    r = _decoding_req(alloc, 0, prompt_len=3)   # 1 block, pos 3: 1 slot left
    sched.running[0] = r
    plan = sched.schedule(spec_drafts={0: np.asarray([7, 8], np.int32)})
    # drafts would need a second block the pool doesn't have: shed them,
    # keep the request running its plain one-token step
    assert plan.spec == {}
    assert plan.num_tokens == 1
    assert sched.num_spec_sheds == 1
    assert sched.num_preemptions == 0


# ------------------------------------------------------ rollback invariants
def test_rollback_fully_rejected_step_allocator_invariants():
    """reserve K+1 / commit 1 / truncate (the engine's fully-rejected spec
    step) must restore refcounts and the free list exactly — no leaked
    blocks, no refcount drift on the speculatively-grown tail."""
    al = BlockAllocator(num_blocks=8, block_size=4)
    al.allocate(0, 3)                           # pos 3: one committed block
    table_before = al.table(0)
    free_before = al.num_free
    slots = al.reserve_tokens(0, 4)             # 1 in-block + 3 spilling
    grown = [b for b in al.table(0) if b not in table_before]
    assert len(grown) == 1 and al.ref_count(grown[0]) == 1
    al.commit_tokens(0, 1)                      # only the non-draft token
    al.truncate(0, al.seq_len(0))               # rewind the rejected tail
    assert al.table(0) == table_before
    assert al.num_free == free_before
    assert al.ref_count(grown[0]) == 0          # back on the free list
    assert al.seq_len(0) == 4
    # the next reservation re-issues the rewound position (same offset, and
    # the just-freed block comes straight back off the free list)
    slots2 = al.reserve_tokens(0, 1)
    assert tuple(slots2[0]) == (grown[0], 0)
    al.commit_tokens(0, 1)
    assert al.seq_len(0) == 5


# --------------------------------------------------------- engine-level spec
class _ScriptedProposer(spec.Proposer):
    """Proposes a fixed function of the known baseline continuation —
    perfect (always accepted) or adversarial (never accepted) drafts."""

    name = "scripted"

    def __init__(self, continuations, vocab, wrong=False):
        super().__init__()
        self.continuations = continuations      # req_id -> full output list
        self.vocab = vocab
        self.wrong = wrong

    def propose(self, req, k):
        nxt = self.continuations[req.req_id][len(req.output):][:k]
        if self.wrong:
            nxt = [(t + 1) % self.vocab for t in nxt]
        return np.asarray(nxt, np.int32)


@pytest.fixture(scope="module")
def spec_env():
    """Shared tiny model + pool-starving shared-prefix workload, plus the
    non-speculative baseline outputs (the parity oracle)."""
    cfg = get_config("qwen2-1.5b").reduced(dtype="float32")
    model = build_model(cfg, remat=False)
    params = model.init(KEY)
    rng = np.random.default_rng(7)
    num_blocks, n_req = 8, 4
    prefix = rng.integers(0, cfg.vocab_size, (4,), dtype=np.int32)
    prompts = [np.concatenate([prefix,
                               rng.integers(0, cfg.vocab_size, (2 + i,),
                                            dtype=np.int32)])
               for i in range(n_req)]

    def run(pol=None, spec_name="off", spec_k=3, proposer=None,
            num_blocks=num_blocks):
        serve = ServeConfig(model=cfg.name, kv_block_size=4, max_batch=3,
                            spec=spec_name, spec_k=spec_k, **(pol or {}))
        eng = ServingEngine(model, params, cfg, serve, num_blocks=num_blocks,
                            proposer=proposer)
        for i, p in enumerate(prompts):
            eng.submit(Request(req_id=i, prompt=p, max_new_tokens=10,
                               priority=i % 2,
                               deadline=float(i) if i % 2 else None))
        eng.run_until_done()
        return ({r.req_id: list(r.output) for r in eng.finished},
                eng.metrics())

    outputs, metrics = run()
    assert metrics["preemptions"] > 0           # the workload really starves
    return {"cfg": cfg, "run": run, "outputs": outputs,
            "num_blocks": num_blocks}


def test_stochastic_proposer_refused_at_adoption(spec_env):
    """deterministic=False is a declared capability the delta-q rule cannot
    serve: the engine must refuse adoption with a clear error instead of
    silently biasing the emitted distribution."""
    class _StochasticProposer(spec.Proposer):
        name = "stochastic-test"
        deterministic = False

        def propose(self, req, k):       # pragma: no cover - never reached
            return np.zeros((0,), np.int32)

    with pytest.raises(ValueError, match="delta-q|deterministic"):
        spec_env["run"](proposer=_StochasticProposer())


def test_ngram_spec_greedy_parity_and_metrics(spec_env):
    outputs, m = spec_env["run"](spec_name="ngram")
    assert outputs == spec_env["outputs"]
    s = m["spec"]
    assert s["proposer"] == "ngram" and s["k"] == 3
    assert s["proposed_tokens"] > 0
    assert 0.0 < s["acceptance_rate"] <= 1.0
    assert s["tokens_per_decode_lane"] > 1.0
    assert m["tokens_per_step"] > 0
    assert m["blocks_free"] == spec_env["num_blocks"]   # no leaked blocks
    assert set(m["phase_s"]) >= {"propose", "schedule_render", "device",
                                 "commit"}


def test_perfect_proposer_accepts_everything(spec_env):
    prop = _ScriptedProposer(spec_env["outputs"], spec_env["cfg"].vocab_size)
    # roomy pool: no draft shedding, so every proposed token gets verified
    # (outputs are scheduling-invariant, so the baseline still applies)
    outputs, m = spec_env["run"](proposer=prop, num_blocks=32)
    assert outputs == spec_env["outputs"]
    s = m["spec"]
    assert s["acceptance_rate"] == 1.0
    assert s["rollback_blocks"] == 0
    assert s["tokens_per_decode_lane"] > 2.0    # k=3 drafts mostly land


def test_adversarial_proposer_rejects_everything_and_rolls_back(spec_env):
    """Every draft is wrong: outputs must still match the baseline exactly
    (total rejection degrades to plain decoding), every speculatively
    reserved block is rewound, and nothing leaks."""
    prop = _ScriptedProposer(spec_env["outputs"], spec_env["cfg"].vocab_size,
                             wrong=True)
    outputs, m = spec_env["run"](proposer=prop)
    assert outputs == spec_env["outputs"]
    s = m["spec"]
    assert s["acceptance_rate"] == 0.0
    assert s["tokens_per_decode_lane"] == 1.0   # one token per lane, always
    assert s["rollback_blocks"] > 0             # speculative tails rewound
    assert m["blocks_free"] == spec_env["num_blocks"]


@pytest.mark.slow       # k draft forwards per decode step
def test_draft_model_spec_greedy_parity(spec_env):
    outputs, m = spec_env["run"](spec_name="draft-model")
    assert outputs == spec_env["outputs"]
    assert m["spec"]["proposer"] == "draft-model"
    assert m["spec"]["proposed_tokens"] > 0


def _policy_triples():
    """Every registered policy, exercised once: vary one axis at a time off
    the default triple (new registrations auto-enroll — no list here)."""
    base = dict(policy.DEFAULTS)
    triples = [tuple(sorted(base.items()))]
    for axis in policy.AXES:
        for name in policy.names(axis):
            triples.append(tuple(sorted(dict(base, **{axis: name}).items())))
    return sorted(set(triples))


@pytest.mark.slow       # one spec engine run per registered policy
@pytest.mark.parametrize("triple", _policy_triples(),
                         ids=lambda t: "/".join(n for _, n in t))
def test_spec_greedy_parity_across_policy_triples(triple, spec_env):
    """Acceptance sweep: with --spec ngram, greedy output streams are
    bit-identical to the non-spec engine under EVERY registered policy
    triple — speculation composes with admission/preemption/eviction
    without touching tokens."""
    outputs, m = spec_env["run"](pol=dict(triple), spec_name="ngram")
    assert outputs == spec_env["outputs"], (
        f"spec diverged under policy triple {dict(triple)}")
    assert m["spec"]["acceptance_rate"] > 0.0
    assert m["blocks_free"] == spec_env["num_blocks"]


# -------------------------------------------- generated-token prefix caching
def test_decode_blocks_are_hash_registered(spec_env):
    """Blocks FILLED during decode join the prefix cache (ROADMAP item):
    the full prompt+generation sequence of a finished request is
    re-adoptable up to the last full block."""
    cfg = spec_env["cfg"]
    model = build_model(cfg, remat=False)
    params = model.init(KEY)
    serve = ServeConfig(model=cfg.name, kv_block_size=4, max_batch=1)
    eng = ServingEngine(model, params, cfg, serve, num_blocks=16)
    prompt = np.arange(6, dtype=np.int32)
    eng.submit(Request(req_id=0, prompt=prompt, max_new_tokens=10))
    eng.run_until_done()
    seq = np.concatenate([prompt, np.asarray(eng.finished[0].output,
                                             np.int32)])
    assert len(seq) == 16
    # committed KV covers 15 tokens (the final sampled token finishes the
    # request before its KV lands), so blocks 0..2 are full and hashed —
    # block 0 by prefill, 1 and 2 by DECODE (the new behaviour under test)
    assert eng.alloc.peek_prefix(seq) == 12
    # and a second identical request actually adopts the cached blocks
    eng.submit(Request(req_id=1, prompt=seq, max_new_tokens=2))
    hits_before = eng.alloc.prefix_hits
    eng.run_until_done()
    assert eng.alloc.prefix_hits - hits_before == 3


def test_spec_generated_blocks_registered_with_true_content(spec_env):
    """The spec path hashes decode blocks under their TRUE token content —
    including accepted draft tokens committed before they land in
    req.output.  peek_prefix recomputes the hash from the actual sequence,
    so a hit proves key == content for every full committed block."""
    cfg = spec_env["cfg"]
    model = build_model(cfg, remat=False)
    params = model.init(KEY)
    serve = ServeConfig(model=cfg.name, kv_block_size=4, max_batch=1,
                        spec="ngram", spec_k=3)
    eng = ServingEngine(model, params, cfg, serve, num_blocks=16)
    prompt = np.arange(6, dtype=np.int32)
    eng.submit(Request(req_id=0, prompt=prompt, max_new_tokens=10))
    eng.run_until_done()
    assert eng.metrics()["spec"]["accepted_tokens"] > 0
    seq = np.concatenate([prompt, np.asarray(eng.finished[0].output,
                                             np.int32)])
    committed = len(seq) - 1                    # last token's KV never lands
    assert eng.alloc.peek_prefix(seq) == (committed // 4) * 4
