"""Layer-level unit tests: attention/rope/moe/ssm/rwkv correctness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import AttentionConfig, MoEConfig, RWKVConfig, SSMConfig
from repro.layers import attention as attn_lib
from repro.layers import moe as moe_lib
from repro.layers import rwkv as rwkv_lib
from repro.layers import ssm as ssm_lib
from repro.layers.norm import layernorm, layernorm_init, rmsnorm, rmsnorm_init
from repro.layers.rope import apply_rope

KEY = jax.random.PRNGKey(0)


def test_rmsnorm_scale_invariance():
    p = rmsnorm_init(16)
    x = jax.random.normal(KEY, (2, 3, 16))
    y1 = rmsnorm(p, x, eps=1e-9)
    y2 = rmsnorm(p, x * 7.3, eps=1e-9)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-5)


def test_layernorm_moments():
    p = layernorm_init(64)
    x = jax.random.normal(KEY, (4, 64)) * 3 + 1
    y = np.asarray(layernorm(p, x))
    np.testing.assert_allclose(y.mean(-1), 0.0, atol=1e-5)
    np.testing.assert_allclose(y.std(-1), 1.0, atol=1e-2)


def test_rope_preserves_norm_and_relative():
    x = jax.random.normal(KEY, (1, 8, 2, 32))
    pos = jnp.broadcast_to(jnp.arange(8), (1, 8))
    y = apply_rope(x, pos, 10_000.0)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-5)
    # relative property: <rope(q,i), rope(k,j)> depends only on i-j
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 32))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 32))
    def dot_at(i, j):
        qi = apply_rope(q, jnp.full((1, 1), i), 1e4)
        kj = apply_rope(k, jnp.full((1, 1), j), 1e4)
        return float(jnp.sum(qi * kj))
    assert abs(dot_at(3, 1) - dot_at(10, 8)) < 1e-3


@pytest.mark.parametrize("H,KV,causal", [(4, 4, True), (8, 2, True),
                                         (6, 3, False)])
def test_chunked_attention_matches_full(H, KV, causal):
    S, hd = 64, 16
    q = jax.random.normal(KEY, (2, S, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, S, KV, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, S, KV, hd))
    full = attn_lib.full_attention(q, k, v, causal=causal)
    chunked = attn_lib.chunked_attention(q, k, v, causal=causal, chunk=16)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(full),
                               rtol=2e-4, atol=2e-4)


def test_chunked_attention_non_divisible():
    q = jax.random.normal(KEY, (1, 60, 2, 8))
    k = v = jax.random.normal(jax.random.PRNGKey(1), (1, 60, 2, 8))
    full = attn_lib.full_attention(q, k, v, causal=True)
    chunked = attn_lib.chunked_attention(q, k, v, causal=True, chunk=32)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(full),
                               rtol=2e-4, atol=2e-4)


def test_moe_routing_conservation():
    m = MoEConfig(num_experts=4, top_k=2, d_expert=16, capacity_factor=2.0)
    params = moe_lib.moe_init(KEY, 8, m)
    x = jax.random.normal(KEY, (2, 16, 8))
    out, aux = moe_lib.moe_apply(params, x, m)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    assert float(aux) > 0.0  # load-balance loss positive


def test_moe_capacity_dropping():
    """With capacity_factor→tiny, outputs shrink toward zero (dropped)."""
    m_small = MoEConfig(num_experts=4, top_k=2, d_expert=16,
                        capacity_factor=0.01)
    m_big = dataclasses.replace(m_small, capacity_factor=4.0)
    params = moe_lib.moe_init(KEY, 8, m_big)
    x = jax.random.normal(KEY, (2, 32, 8))
    out_small, _ = moe_lib.moe_apply(params, x, m_small)
    out_big, _ = moe_lib.moe_apply(params, x, m_big)
    assert (np.abs(np.asarray(out_small)).sum()
            < np.abs(np.asarray(out_big)).sum())


def test_ssm_chunked_matches_step():
    s = SSMConfig(d_state=8, d_conv=4, expand=2, head_dim=8, chunk_size=8)
    d_model = 16
    params = ssm_lib.ssm_init(KEY, d_model, s)
    x = jax.random.normal(KEY, (2, 32, d_model)) * 0.5
    y_chunked = ssm_lib.ssm_chunked(params, x, s, d_model)
    state = ssm_lib.ssm_init_state(2, d_model, s)
    ys = []
    for t in range(32):
        y, state = ssm_lib.ssm_step(params, x[:, t:t + 1], state, s, d_model)
        ys.append(y)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_chunked),
                               rtol=2e-3, atol=2e-3)


def test_rwkv_chunked_matches_step():
    r = RWKVConfig(head_size=8, decay_lora=4)
    d = 16
    tm = rwkv_lib.rwkv_time_mix_init(KEY, d, r)
    x = jax.random.normal(KEY, (2, 64, d)) * 0.5
    y_chunked = rwkv_lib.time_mix_chunked(tm, x, r)
    state = {"shift": jnp.zeros((2, d)),
             "S": jnp.zeros((2, d // 8, 8, 8), jnp.float32)}
    ys = []
    for t in range(64):
        y, state = rwkv_lib.time_mix_step(tm, x[:, t:t + 1], state, r)
        ys.append(y)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_chunked),
                               rtol=2e-3, atol=2e-3)


def test_qk_norm_and_bias_paths():
    a = AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=8,
                        qk_norm=True, qkv_bias=True)
    p = attn_lib.attention_init(KEY, 16, a)
    assert "q_norm" in p and "bq" in p
    x = jax.random.normal(KEY, (1, 8, 16))
    out, (k, v) = attn_lib.attention_block(
        p, x, jnp.broadcast_to(jnp.arange(8), (1, 8)), a)
    assert out.shape == (1, 8, 16)
    assert k.shape == (1, 8, 2, 8)
