"""Config registry + parameter-count sanity vs published sizes."""
import pytest

from repro.config import SHAPES, get_config, list_configs
from repro.configs import ASSIGNED_LM_ARCHS, PAPER_ARCHS

EXPECTED_PARAMS_B = {
    "qwen3-moe-235b-a22b": (235, 0.05),
    "qwen3-32b": (32.8, 0.05),
    "internlm2-20b": (19.9, 0.08),
    "llama31-8b": (8.0, 0.05),
    "llama31-70b": (70.6, 0.05),
    "qwen2-1.5b": (1.54, 0.08),
    "smollm-360m": (0.36, 0.10),
    "rwkv6-1.6b": (1.6, 0.15),
    "zamba2-2.7b": (2.7, 0.20),
    "granite-moe-1b-a400m": (1.33, 0.10),
}


def test_all_assigned_registered():
    known = set(list_configs())
    for a in ASSIGNED_LM_ARCHS + PAPER_ARCHS:
        assert a in known, a


@pytest.mark.parametrize("arch,expected", sorted(EXPECTED_PARAMS_B.items()))
def test_param_counts(arch, expected):
    target, tol = expected
    n = get_config(arch).num_params() / 1e9
    assert abs(n - target) / target < tol, (arch, n, target)


def test_moe_active_params():
    cfg = get_config("qwen3-moe-235b-a22b")
    active = cfg.num_active_params() / 1e9
    assert 20 < active < 24, active  # A22B


def test_shapes_cells():
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k",
                           "long_500k"}
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["long_500k"].seq_len == 524_288


@pytest.mark.parametrize("arch", ASSIGNED_LM_ARCHS)
def test_reduced_and_depth(arch):
    cfg = get_config(arch)
    r = cfg.reduced()
    assert r.num_layers <= 4 and r.d_model <= 128
    d1 = cfg.with_depth(1)
    assert d1.depth_units == 1
    assert d1.d_model == cfg.d_model  # width preserved


def test_json_roundtrip():
    s = get_config("qwen3-32b").to_json()
    assert "151936" in s
