"""Async overlapped engine loop (docs/async_engine.md): greedy streams are
bit-identical with overlap on vs off — including speculative rollback and
preemption mid-flight — and the phase accounting shows the point of the
pipeline: host work hides inside the device window.

The fused step function is wrapped with a host-side sleep (the "fake slow
device") so the device phase bucket is large and deterministic relative to
host bookkeeping, making the attribution assertions robust on fast CI
machines."""
import time

import jax
import numpy as np
import pytest

from repro.config import ServeConfig, get_config
from repro.models.api import build_model
from repro.serving.engine import Request, ServingEngine

KEY = jax.random.PRNGKey(0)


def _slow(fn, delay):
    """Wrap the fused step fn: the sleep lands between dispatch and the
    future's resolution, i.e. inside the ``device`` phase bucket."""
    def wrapped(*args, **kwargs):
        time.sleep(delay)
        return fn(*args, **kwargs)
    return wrapped


@pytest.fixture(scope="module")
def overlap_env():
    """Tiny model + pool-starving shared-prefix workload, run under any
    overlap/spec/pool setting; the overlap-off runs are the parity oracle."""
    cfg = get_config("qwen2-1.5b").reduced(dtype="float32")
    model = build_model(cfg, remat=False)
    params = model.init(KEY)
    rng = np.random.default_rng(7)
    prefix = rng.integers(0, cfg.vocab_size, (4,), dtype=np.int32)
    prompts = [np.concatenate([prefix,
                               rng.integers(0, cfg.vocab_size, (2 + i,),
                                            dtype=np.int32)])
               for i in range(4)]

    def run(overlap, *, spec_name="off", num_blocks=48, max_batch=4,
            eos_id=-1, slow=0.0, prefetch_depth=0):
        serve = ServeConfig(model=cfg.name, kv_block_size=4,
                            max_batch=max_batch, spec=spec_name, spec_k=3,
                            overlap=overlap, prefetch_depth=prefetch_depth)
        eng = ServingEngine(model, params, cfg, serve,
                            num_blocks=num_blocks, eos_id=eos_id)
        if slow:
            eng._step_fn = _slow(eng._step_fn, slow)
        for i, p in enumerate(prompts):
            eng.submit(Request(req_id=i, prompt=p, max_new_tokens=10))
        eng.run_until_done()
        return eng

    return {"cfg": cfg, "run": run}


def _streams(eng):
    return {r.req_id: list(r.output) for r in eng.finished}


def _check_drained(eng):
    assert eng._pending is None and not eng._chain
    assert eng.alloc.num_free == eng.alloc.num_blocks     # no block leak
    assert len(eng.finished) == 4


def test_overlap_greedy_parity_base(overlap_env):
    e0 = overlap_env["run"](False)
    e1 = overlap_env["run"](True, slow=0.002)
    assert _streams(e0) == _streams(e1)
    _check_drained(e1)
    assert e0.metrics()["overlap"] is False
    assert e1.metrics()["overlap"] is True


def test_overlap_parity_under_preemption_mid_flight(overlap_env):
    """A pool-starving run preempts requests whose final token is still a
    device future; the resolved token must survive the recompute re-queue
    (or finish the request straight out of PREEMPTED)."""
    e0 = overlap_env["run"](False, num_blocks=8, max_batch=3)
    e1 = overlap_env["run"](True, num_blocks=8, max_batch=3, slow=0.002)
    assert e0.metrics()["preemptions"] > 0       # the workload really starves
    assert e1.metrics()["preemptions"] > 0
    assert _streams(e0) == _streams(e1)
    _check_drained(e1)


def test_overlap_parity_with_spec_rollback(overlap_env):
    """Drafted steps are synchronization barriers inside the overlapped
    loop: the pipeline drains, the verify runs synchronously (including
    rejected-tail rollback), and the pipeline refills after — streams stay
    bit-identical to the serial spec engine."""
    e0 = overlap_env["run"](False, spec_name="ngram", num_blocks=8,
                            max_batch=3)
    e1 = overlap_env["run"](True, spec_name="ngram", num_blocks=8,
                            max_batch=3, slow=0.002)
    for e in (e0, e1):       # speculation really ran, with rejections
        c = e._spec_counters
        assert c["drafted_steps"] > 0
        assert c["proposed_tokens"] > c["accepted_tokens"]
    assert _streams(e0) == _streams(e1)
    _check_drained(e1)


def test_overlap_parity_with_eos(overlap_env):
    """EOS resolves a step late under overlap: the finish must cancel the
    request's already-dispatched next action and pop its placeholder."""
    tok = overlap_env["run"](False)  # steal a token every stream emits
    eos = next(iter(_streams(tok).values()))[1]
    e0 = overlap_env["run"](False, eos_id=eos)
    e1 = overlap_env["run"](True, eos_id=eos, slow=0.002)
    s0, s1 = _streams(e0), _streams(e1)
    assert s0 == s1
    assert any(len(s) < 10 for s in s0.values())       # EOS actually fired
    _check_drained(e1)


def test_device_phase_dominates_under_overlap(overlap_env):
    """With a slow device, the overlapped loop's wall time is the device
    wall: host propose/schedule/render/commit hide inside the device
    window, so phase_s["device"] dominates every host bucket combined."""
    e1 = overlap_env["run"](True, slow=0.02)
    p = e1.metrics()["phase_s"]
    host = sum(v for k, v in p.items() if k != "device")
    assert p["device"] > host, p


def test_overlap_metrics_attribution(overlap_env):
    """overlap / prefetch_depth are reported like backend / mesh_shape, and
    an iteration with nothing scheduled and nothing in flight is an idle
    step: counted separately, wall time kept in phase_s["idle"]."""
    e1 = overlap_env["run"](True, prefetch_depth=0)
    m = e1.metrics()
    assert m["overlap"] is True and m["prefetch_depth"] == 0
    assert m["num_idle_steps"] == 0
    steps = m["steps"]
    assert e1.step() == 0                       # drained engine: idle tick
    m2 = e1.metrics()
    assert m2["num_idle_steps"] == 1
    assert m2["steps"] == steps                 # idle ticks aren't steps
    assert "idle" in m2["phase_s"]
