"""Adafactor + sampling + latency tracker tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.adafactor import adafactor
from repro.optim.optimizer import apply_updates
from repro.serving.metrics import LatencyTracker
from repro.serving.sampling import greedy, sample


def test_adafactor_converges_and_is_factored():
    opt = adafactor(grad_clip=None)
    params = {"w": jnp.ones((8, 6)) * 3.0, "b": jnp.ones((6,)) * 2.0}
    state = opt.init(params)
    # factored state is O(n+m), not O(n*m)
    assert state.vr["w"].shape == (8,)
    assert state.vc["w"].shape == (6,)
    assert state.vr["b"].shape == (6,)

    def loss_fn(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)

    for _ in range(200):
        g = jax.grad(loss_fn)(params)
        upd, state, _ = opt.update(g, state, params, 0.05)
        params = apply_updates(params, upd)
    assert float(loss_fn(params)) < 1.0


def test_adafactor_memory_is_sublinear():
    p = {"big": jnp.zeros((512, 256))}
    st = adafactor().init(p)
    n_state = sum(x.size for x in jax.tree.leaves((st.vr, st.vc)))
    assert n_state == 512 + 256  # vs 2*512*256 for adam


def test_sampling_modes():
    key = jax.random.PRNGKey(0)
    logits = jnp.asarray([[0.0, 5.0, 1.0, -2.0]] * 4)
    np.testing.assert_array_equal(np.asarray(greedy(logits)), [1, 1, 1, 1])
    # temperature 0 == greedy
    np.testing.assert_array_equal(
        np.asarray(sample(key, logits, temperature=0.0)), [1, 1, 1, 1])
    # top_k=1 forces argmax even at high temperature
    np.testing.assert_array_equal(
        np.asarray(sample(key, logits, temperature=5.0, top_k=1)),
        [1, 1, 1, 1])
    # top_p tiny keeps only the argmax
    np.testing.assert_array_equal(
        np.asarray(sample(key, logits, temperature=2.0, top_p=0.01)),
        [1, 1, 1, 1])
    # unconstrained sampling covers >1 token across many draws
    draws = [int(sample(jax.random.PRNGKey(i), logits[:1],
                        temperature=3.0)[0]) for i in range(40)]
    assert len(set(draws)) > 1


def test_latency_tracker_percentiles():
    t = LatencyTracker()
    for v in reversed(range(100)):
        t.record(float(v))
    s = t.summary()
    # nearest-rank: p-th percentile of 0..99 is the ceil(p)-th sample
    assert s["p50"] == 49.0 and s["p99"] == 98.0
    assert abs(s["mean"] - 49.5) < 1e-9
    t2 = LatencyTracker()
    t2.record(0.010)
    t2.record(0.100)
    assert t2.percentile(50) == 0.010           # p50 of 2 samples is the 1st
