"""Benchmark-level reproduction of the paper's claims (hardware-independent
derived quantities, not CPU wall time)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.attention_api import (
    paged_attention_base, paged_attention_opt)
from repro.core.paged_kv import BlockAllocator

KEY = jax.random.PRNGKey(0)


def _hlo_bytes(fn, *args) -> float:
    c = jax.jit(fn).lower(*args).compile()
    ca = c.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else ca
    return float(ca.get("bytes accessed", 0.0))


def _setup(B, eff_blocks, max_blocks, BS=8, KV=2, HD=32, H=4):
    NB = B * max_blocks + 4
    al = BlockAllocator(num_blocks=NB, block_size=BS)
    for r in range(B):
        al.allocate(r, eff_blocks * BS)
    tab, lens = al.build_block_table(list(range(B)), max_blocks=max_blocks)
    bl, br, bp, lens2 = al.build_block_list(list(range(B)),
                                            max_total=B * eff_blocks)
    ks = jax.random.split(KEY, 3)
    pk = jax.random.normal(ks[0], (NB, BS, KV, HD))
    pv = jax.random.normal(ks[1], (NB, BS, KV, HD))
    q = jax.random.normal(ks[2], (B, H, HD))
    return (q, pk, pv, jnp.asarray(tab), jnp.asarray(lens), jnp.asarray(bl),
            jnp.asarray(br), jnp.asarray(bp), jnp.asarray(lens2))


def test_blocklist_bytes_shrink_with_padding_fraction():
    """Paper Fig 17b, hardware-independent: the BlockList path's memory
    traffic falls with the zero-padding fraction while the padded
    BlockTable's stays flat — so the advantage GROWS with padding."""
    max_blocks = 16
    ratios = []
    for eff in (16, 8, 2):          # 0%, 50%, 87.5% padding
        (q, pk, pv, tab, lens, bl, br, bp, l2) = _setup(8, eff, max_blocks)
        b_base = _hlo_bytes(paged_attention_base, q, pk, pv, tab, lens)
        b_opt = _hlo_bytes(paged_attention_opt, q, pk, pv, bl, br, bp, l2)
        ratios.append(b_base / b_opt)
    assert ratios[0] < ratios[1] < ratios[2], ratios
    assert ratios[2] > 2.0, ratios   # large win at high padding


def test_blocklist_correct_under_padding():
    (q, pk, pv, tab, lens, bl, br, bp, l2) = _setup(4, 3, 16)
    o_base = paged_attention_base(q, pk, pv, tab, lens)
    o_opt = paged_attention_opt(q, pk, pv, bl, br, bp, l2)
    np.testing.assert_allclose(np.asarray(o_base), np.asarray(o_opt),
                               rtol=1e-4, atol=1e-4)


def test_batched_embedding_single_launch():
    """Paper Fig 15: BatchedTable = ONE fused gather regardless of #tables
    (SingleTable lowers one gather per table)."""
    from repro.core.embedding_api import (
        batched_table_lookup, single_table_lookup)
    T, R, D, B, L = 12, 64, 32, 4, 5
    big = jax.random.normal(KEY, (T * R, D))
    offs = jnp.arange(T, dtype=jnp.int32) * R
    tabs = [big[t * R:(t + 1) * R] for t in range(T)]
    idx = jax.random.randint(KEY, (B, T, L), 0, R)

    def count_takes(jaxpr):
        """One `take` call == one gather-op launch in the traced program."""
        n = 0
        for eqn in jaxpr.jaxpr.eqns:
            name = str(eqn.params.get("name", "")) if eqn.params else ""
            if "take" in name or "gather" in str(eqn.primitive):
                n += 1
        return n

    n_single = count_takes(jax.make_jaxpr(single_table_lookup)(tabs, idx))
    n_batched = count_takes(
        jax.make_jaxpr(batched_table_lookup)(big, offs, idx))
    assert n_batched == 1, n_batched
    assert n_single == T, n_single


def test_recsys_rm2_more_memory_bound_than_rm1():
    """Paper Table 3/Fig 11: RM2 is embedding(memory)-dominated, RM1
    MLP(compute)-dominated — visible as arithmetic intensity."""
    import dataclasses
    from repro.config import get_config
    from repro.models.api import build_model
    from repro.data.pipeline import SyntheticRecSysDataset
    ais = {}
    for name in ("rm1", "rm2"):
        cfg = dataclasses.replace(get_config(name), num_embeddings=2000)
        model = build_model(cfg)
        params = model.init(KEY)
        batch = {k: jnp.asarray(v) for k, v in
                 SyntheticRecSysDataset(cfg, 64).batch_at(0).items()}
        c = jax.jit(model.forward).lower(params, batch).compile()
        ca = c.cost_analysis()
        ca = ca[0] if isinstance(ca, list) else ca
        ais[name] = ca["flops"] / ca["bytes accessed"]
    assert ais["rm1"] > 2 * ais["rm2"], ais
