"""Mesh-native serving engine: sharded-vs-single greedy bit-parity.

The acceptance bar of the sharded serving stack (docs/sharded_serving.md):
with a mesh, `ServingEngine` runs TP-sharded params, a sequence-sharded KV
pool and the shard_map log-sum-exp attention combine — and the greedy output
stream of every request must be BIT-IDENTICAL to the single-device engine,
across representative policy triples, speculative proposers (off / ngram)
and a memory-pressure (preemption + eviction) pool.  Device counts are real
forced host devices, so each sweep runs in a subprocess (slow tier).
"""
import pytest

from conftest import run_multidevice

pytestmark = pytest.mark.slow

_SWEEP = """
import numpy as np, jax
from repro.config import ServeConfig, get_config
from repro.launch.mesh import make_serving_mesh
from repro.models.api import build_model
from repro.serving.engine import Request, ServingEngine

cfg = get_config("smollm-360m").reduced(dtype="float32")
model = build_model(cfg, remat=False)
params = model.init(jax.random.PRNGKey(0))
mesh = make_serving_mesh()
S = mesh.shape["model"]
assert S == %(n)d, mesh.shape

def requests():
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(4):
        if i %% 2:                       # looping motif: ngram drafts land
            prompt = np.tile(rng.integers(0, cfg.vocab_size, (3,),
                                          dtype=np.int32), 3)
        else:
            prompt = rng.integers(0, cfg.vocab_size,
                                  (int(rng.integers(4, 12)),), dtype=np.int32)
        reqs.append(Request(req_id=i, prompt=prompt, max_new_tokens=8,
                            priority=i %% 2, deadline=None))
    return reqs

def run(mesh, spec, triple, nblocks):
    adm, pre, evi = triple
    serve = ServeConfig(model=cfg.name, kv_block_size=4, max_batch=3,
                        admission=adm, preemption=pre, eviction=evi,
                        spec=spec, spec_k=3)
    eng = ServingEngine(model, params, cfg, serve, num_blocks=nblocks,
                        mesh=mesh)
    for r in requests():
        eng.submit(r)
    eng.run_until_done()
    return ({r.req_id: list(r.output) for r in eng.finished}, eng.metrics())

TRIPLES = [("fcfs", "latest-arrival", "lru"),
           ("priority", "fewest-remaining-tokens", "hit-rate")]
for spec in ("off", "ngram"):
    for triple in TRIPLES:
        for nblocks in (64, 16):        # roomy + preemption pressure
            single, _ = run(None, spec, triple, nblocks)
            shard, m = run(mesh, spec, triple, nblocks)
            assert single == shard, (spec, triple, nblocks, single, shard)
            assert m["backend"] == "sharded", m["backend"]
            assert m["devices"] == S and m["mesh_shape"]["model"] == S
            assert m["finished"] == 4
            if spec == "ngram":
                assert m["spec"]["proposer"] == "ngram"
print("PARITY OK", S)
"""


@pytest.mark.parametrize("n_devices", [2, 4])
def test_sharded_engine_greedy_bit_parity(n_devices):
    r = run_multidevice(_SWEEP % {"n": n_devices}, n_devices=n_devices)
    assert f"PARITY OK {n_devices}" in r.stdout, (
        r.stdout[-500:], r.stderr[-2500:])


def test_sharded_engine_cow_and_prefix_cache_parity():
    """Copy-on-write through the SHARDED pool: a borrower adopting a live
    donor's prefix blocks (refcount 2) must CoW its first append —
    `copy_pool_blocks` runs against the sequence-sharded device array —
    and the streams stay bit-identical with identical CoW/hit counters."""
    snippet = """
    import numpy as np, jax
    from repro.config import ServeConfig, get_config
    from repro.launch.mesh import make_serving_mesh
    from repro.models.api import build_model
    from repro.serving.engine import Request, ServingEngine

    cfg = get_config("smollm-360m").reduced(dtype="float32")
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    serve = ServeConfig(model=cfg.name, kv_block_size=4, max_batch=2)
    prefix = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (12,), dtype=np.int32)   # 3 full shared blocks

    def run(mesh):
        eng = ServingEngine(model, params, cfg, serve, num_blocks=64,
                            mesh=mesh)
        eng.submit(Request(req_id=0, prompt=prefix.copy(),
                           max_new_tokens=8))
        for _ in range(3):     # donor's hashes publish; donor keeps decoding
            eng.step()
        eng.submit(Request(req_id=1, prompt=prefix.copy(),
                           max_new_tokens=6))
        eng.run_until_done()
        return ({r.req_id: list(r.output) for r in eng.finished},
                eng.metrics())

    single, ms = run(None)
    shard, md = run(make_serving_mesh())
    assert single == shard, (single, shard)
    assert md["cow_copies"] > 0 and md["prefix_hits"] > 0, md
    assert (md["cow_copies"], md["prefix_hits"]) == (
        ms["cow_copies"], ms["prefix_hits"])
    print("COW PARITY OK")
    """
    r = run_multidevice(snippet, n_devices=2)
    assert "COW PARITY OK" in r.stdout, (r.stdout[-500:], r.stderr[-2500:])
