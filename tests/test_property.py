"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import attention_api
from repro.core.paged_kv import BlockAllocator
from repro.distributed.compression import dequantize_int8, quantize_int8
from repro.training.losses import softmax_cross_entropy

SETTINGS = dict(max_examples=25, deadline=None)


@settings(**SETTINGS)
@given(st.lists(st.tuples(st.sampled_from(["alloc", "free", "append"]),
                          st.integers(0, 5), st.integers(1, 30)),
                min_size=1, max_size=40))
def test_allocator_never_leaks_or_double_allocates(ops):
    """Fuzz alloc/free/append: block conservation + no block owned twice."""
    al = BlockAllocator(num_blocks=24, block_size=4)
    live = set()
    for op, rid, n in ops:
        try:
            if op == "alloc" and rid not in live:
                al.allocate(rid, n)
                live.add(rid)
            elif op == "free" and rid in live:
                al.free(rid)
                live.remove(rid)
            elif op == "append" and rid in live:
                al.append_token(rid)
        except Exception as e:
            from repro.core.paged_kv import OutOfBlocksError
            assert isinstance(e, OutOfBlocksError)
        owned = [b for r in live for b in al.table(r)]
        assert len(owned) == len(set(owned))          # no double ownership
        assert len(owned) + al.num_free == 24          # conservation
        for r in live:                                  # enough blocks
            assert len(al.table(r)) * 4 >= al.seq_len(r)


@settings(**SETTINGS)
@given(st.integers(1, 4), st.integers(1, 3), st.lists(
    st.integers(1, 40), min_size=1, max_size=4))
def test_paged_attention_layout_invariance(kv, g, lens):
    """The result must not depend on WHICH pool blocks are used."""
    B = len(lens)
    H, HD, BS = kv * g, 16, 8
    NB = sum(-(-L // BS) for L in lens) + 4
    key = jax.random.PRNGKey(B * 97 + kv)
    k_rows = jax.random.normal(key, (B, 48, kv, HD))
    v_rows = jax.random.normal(jax.random.fold_in(key, 1), (B, 48, kv, HD))
    q = jax.random.normal(jax.random.fold_in(key, 2), (B, H, HD))

    outs = []
    for perm_seed in (0, 1):
        al = BlockAllocator(num_blocks=NB, block_size=BS)
        al._free = np.random.RandomState(perm_seed).permutation(NB).tolist()
        pk = jnp.zeros((NB, BS, kv, HD))
        pv = jnp.zeros((NB, BS, kv, HD))
        for r, L in enumerate(lens):
            al.allocate(r, L)
            tab = al.table(r)
            for pos in range(L):
                pk = pk.at[tab[pos // BS], pos % BS].set(k_rows[r, pos])
                pv = pv.at[tab[pos // BS], pos % BS].set(v_rows[r, pos])
        bl, br, bp, ll = al.build_block_list(list(range(B)),
                                             max_total=NB)
        outs.append(attention_api.paged_attention_opt(
            q, pk, pv, jnp.asarray(bl), jnp.asarray(br), jnp.asarray(bp),
            jnp.asarray(ll)))
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(outs[1]),
                               rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(st.integers(2, 64), st.floats(0.01, 100.0))
def test_quantization_error_bounded(n, scale):
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(n), (n,))) * scale
    q, s = quantize_int8(jnp.asarray(x))
    err = np.abs(np.asarray(dequantize_int8(q, s)) - x)
    assert err.max() <= float(s) * 0.5 + 1e-6 * scale


@settings(**SETTINGS)
@given(st.integers(2, 32), st.integers(2, 50))
def test_vocab_parallel_ce_matches_naive(b, v):
    key = jax.random.PRNGKey(b * 131 + v)
    logits = jax.random.normal(key, (b, v)) * 3
    targets = jax.random.randint(jax.random.fold_in(key, 1), (b,), 0, v)
    ours = softmax_cross_entropy(logits, targets)
    naive = -jnp.take_along_axis(jax.nn.log_softmax(logits, -1),
                                 targets[:, None], axis=-1)[:, 0]
    np.testing.assert_allclose(np.asarray(ours), np.asarray(naive),
                               rtol=1e-5, atol=1e-5)


@settings(**SETTINGS)
@given(st.integers(1, 6), st.integers(1, 8))
def test_plan_remesh_always_valid(pods_lost, data_min):
    from repro.distributed.elastic import plan_remesh
    total = 512 - pods_lost * 37
    plan = plan_remesh(total, 256, model_parallel=16, min_data=data_min)
    if plan is not None:
        p, d, m = plan
        assert p * d * m <= max(total, 0)
        assert m == 16 and d >= data_min
