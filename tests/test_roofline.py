"""Roofline machinery: collective HLO parsing (incl. loop trip-count
scaling), per-device cost semantics, report math."""
import numpy as np

from conftest import run_multidevice
from repro.roofline.analysis import (
    HW, RooflineReport, collective_bytes_from_hlo)


def test_report_math():
    r = RooflineReport(arch="a", shape="s", mesh="16x16", chips=256,
                       hlo_flops=197e12 * 256 * 0.010,
                       hlo_bytes=819e9 * 256 * 0.020,
                       collective_bytes=50e9 * 256 * 0.005,
                       model_flops=197e12 * 256 * 0.008)
    assert abs(r.t_compute - 0.010) < 1e-9
    assert abs(r.t_memory - 0.020) < 1e-9
    assert abs(r.t_collective - 0.005) < 1e-9
    assert r.bottleneck == "memory"
    assert abs(r.mfu - 0.008 / 0.020) < 1e-6
    assert abs(r.useful_flops_ratio - 0.8) < 1e-6


def test_parser_on_synthetic_hlo():
    hlo = """
HloModule m

%body (x: f32[128,256]) -> f32[128,256] {
  %p = f32[128,256]{1,0} parameter(0)
  %ar = f32[128,256]{1,0} all-reduce(%p), replica_groups={}, to_apply=%add
}

ENTRY %main (a: f32[64,64]) -> f32[64,64] {
  %a = f32[64,64]{1,0} parameter(0)
  %ag = f32[64,64]{1,0} all-gather(%a), dimensions={0}
  %w = (s32[], f32[64,64]{1,0}) while(%t), condition=%c, body=%body, backend_config={"known_trip_count":{"n":"5"}}
}
"""
    out = collective_bytes_from_hlo(hlo)
    assert out["all-gather"] == 64 * 64 * 4
    assert out["all-reduce"] == 128 * 256 * 4 * 5   # ×trip count


def test_cost_analysis_is_per_device_and_scan_counts_once():
    """Documents the two XLA facts the dry-run correction relies on."""
    snippet = """
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P, NamedSharding

    # (1) per-device: sharded matmul reports global/ndev flops
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    def f(x, w):
        return x @ w
    x = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    w = jax.ShapeDtypeStruct((512, 128), jnp.float32)
    with mesh:
        c = jax.jit(f, in_shardings=(
            NamedSharding(mesh, P("data", None)),
            NamedSharding(mesh, P(None, "model")))).lower(x, w).compile()
    ca = c.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else ca
    expected = 2 * 256 * 512 * 128 / 8
    assert abs(ca["flops"] - expected) / expected < 0.05, ca["flops"]

    # (2) scan body counted once
    def scanned(x, ws):
        def layer(h, w):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(layer, x, ws)
        return h
    xs = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((8, 64, 64), jnp.float32)
    c2 = jax.jit(scanned).lower(xs, ws).compile()
    ca2 = c2.cost_analysis()
    ca2 = ca2[0] if isinstance(ca2, list) else ca2
    one_layer = 2 * 64 * 64 * 64
    assert ca2["flops"] < 2 * one_layer, ca2["flops"]
    print("OK")
    """
    r = run_multidevice(snippet)
    assert "OK" in r.stdout, r.stderr[-2000:]


def test_collective_parse_real_compiled_program():
    snippet = """
    import jax, jax.numpy as jnp, sys
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.roofline.analysis import collective_bytes_from_hlo
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    def f(x, ws):
        def layer(h, w):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(layer, x, ws)
        return h
    x = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    ws = jax.ShapeDtypeStruct((8, 512, 512), jnp.float32)
    with mesh:
        c = jax.jit(f, in_shardings=(
            NamedSharding(mesh, P("data", "model")),
            NamedSharding(mesh, P(None, "model", None))),
            out_shardings=NamedSharding(mesh, P("data", "model"))
            ).lower(x, ws).compile()
    out = collective_bytes_from_hlo(c.as_text())
    # loop all-reduce of (64,512) f32 × 8 trips
    assert out["all-reduce"] == 64 * 512 * 4 * 8, out
    print("OK")
    """
    r = run_multidevice(snippet)
    assert "OK" in r.stdout, r.stderr[-2000:]


def test_model_flops_sane():
    from repro.config import SHAPES, get_config
    from repro.roofline.model_flops import model_flops
    cfg = get_config("llama31-8b")
    f_train = model_flops(cfg, SHAPES["train_4k"])
    tokens = 256 * 4096
    assert f_train > 6 * 8e9 * tokens          # at least 6·N·D
    assert f_train < 12 * 8e9 * tokens         # attention won't double it at 4k
    f_dec = model_flops(cfg, SHAPES["decode_32k"])
    assert f_dec < f_train / 1000
