"""Paged KV core: allocator invariants + BlockTable/BlockList equivalence +
paged attention base==opt + end-to-end paged decode == dense forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config
from repro.core import attention_api
from repro.core.paged_kv import (
    BlockAllocator, OutOfBlocksError, gather_prefill_into_pool, make_pool)
from repro.models.api import build_model

KEY = jax.random.PRNGKey(0)


def test_allocator_lifecycle():
    al = BlockAllocator(num_blocks=10, block_size=4)
    b0 = al.allocate(0, 6)           # 2 blocks
    assert len(b0) == 2 and al.num_free == 8
    al.allocate(1, 9)                # 3 blocks
    assert al.num_free == 5
    al.free(0)
    assert al.num_free == 7
    with pytest.raises(OutOfBlocksError):
        al.allocate(2, 100)


def test_allocator_reserve_commit():
    al = BlockAllocator(num_blocks=8, block_size=2)
    al.allocate(0, 0)
    slots = []
    for _ in range(5):
        blk, off = al.reserve_slot(0)
        slots.append((blk, off))
        al.commit_token(0)
    assert al.seq_len(0) == 5
    offs = [s[1] for s in slots]
    assert offs == [0, 1, 0, 1, 0]
    assert len(set(s[0] for s in slots)) == 3  # 3 blocks touched


def test_sharded_allocator_interleaves_and_localizes():
    """num_shards > 1: the free list cycles shards (balanced fills) and
    build_sharded_block_lists renders slot-keyed LOCAL indices on each
    block's physical owner shard, bucketing capacity past the slice size."""
    al = BlockAllocator(num_blocks=12, block_size=4, num_shards=4)
    assert al.blocks_per_shard == 3
    blocks = al.allocate(7, 12)                   # 3 blocks, one per shard
    assert sorted(al.shard_of(b) for b in blocks) == [0, 1, 2]
    assert [b % al.blocks_per_shard for b in blocks] == [0, 0, 0]
    al.allocate(9, 4)                             # next pop: shard 3
    assert al.shard_of(al.table(9)[0]) == 3

    bl, br, bp = al.build_sharded_block_lists([(7, 0), (9, 1)], pad_req=2)
    assert bl.shape == br.shape == bp.shape == (4, 3)
    for s in range(4):
        for j in range(3):
            if br[s, j] == 2:                     # padding entry
                continue
            req = 7 if br[s, j] == 0 else 9
            blk = al.table(req)[bp[s, j]]
            assert al.shard_of(blk) == s          # physical owner
            assert bl[s, j] == blk % al.blocks_per_shard
    # every real table entry appears exactly once across shards
    assert int((br != 2).sum()) == len(al.table(7)) + len(al.table(9))
    # capacity grows by doubling when shared blocks overflow a slice
    al2 = BlockAllocator(num_blocks=4, block_size=4, num_shards=2)
    for r in range(4):
        al2._tables[r] = [0, 1]                   # all on shard 0
        al2._lens[r] = 8
    bl2, _, _ = al2.build_sharded_block_lists(
        [(r, r) for r in range(4)], pad_req=4)
    assert bl2.shape == (2, 8)                    # 8 entries on shard 0


def test_block_table_vs_list_equivalence():
    al = BlockAllocator(num_blocks=32, block_size=4)
    al._free = np.random.RandomState(3).permutation(32).tolist()
    lens = [7, 12, 1]
    for r, L in enumerate(lens):
        al.allocate(r, L)
    tab, tl = al.build_block_table([0, 1, 2], max_blocks=4)
    bl, br, bp, ll = al.build_block_list([0, 1, 2])
    # every effectual entry of the table appears in the list in order
    for r in range(3):
        n = -(-lens[r] // 4)
        assert list(tab[r, :n]) == list(bl[br == r])
        assert list(bp[br == r]) == list(range(n))
    np.testing.assert_array_equal(tl, ll)


def test_paged_attention_base_equals_opt():
    NB, BS, KV, HD, H, B = 24, 8, 2, 16, 6, 3
    ks = jax.random.split(KEY, 3)
    pk = jax.random.normal(ks[0], (NB, BS, KV, HD))
    pv = jax.random.normal(ks[1], (NB, BS, KV, HD))
    q = jax.random.normal(ks[2], (B, H, HD))
    al = BlockAllocator(num_blocks=NB, block_size=BS)
    al._free = np.random.RandomState(0).permutation(NB).tolist()
    for r, L in enumerate([13, 8, 21]):
        al.allocate(r, L)
    tab, lens = al.build_block_table(list(range(B)), max_blocks=6)
    bl, br, bp, lens2 = al.build_block_list(list(range(B)), max_total=18)
    o_base = attention_api.paged_attention_base(
        q, pk, pv, jnp.asarray(tab), jnp.asarray(lens))
    o_opt = attention_api.paged_attention_opt(
        q, pk, pv, jnp.asarray(bl), jnp.asarray(br), jnp.asarray(bp),
        jnp.asarray(lens2))
    np.testing.assert_allclose(np.asarray(o_base), np.asarray(o_opt),
                               rtol=1e-4, atol=1e-4)


def test_paged_attention_equals_contiguous_oracle():
    """Paged attention over a scrambled pool == plain masked attention."""
    NB, BS, KV, HD, H, B = 16, 4, 2, 8, 4, 2
    lens = [10, 5]
    al = BlockAllocator(num_blocks=NB, block_size=BS)
    al._free = np.random.RandomState(7).permutation(NB).tolist()
    k_seq = jax.random.normal(KEY, (B, 12, KV, HD))
    v_seq = jax.random.normal(jax.random.PRNGKey(1), (B, 12, KV, HD))
    pk = jnp.zeros((NB, BS, KV, HD))
    pv = jnp.zeros((NB, BS, KV, HD))
    for r, L in enumerate(lens):
        al.allocate(r, L)
        tab = al.table(r)
        for pos in range(L):
            pk = pk.at[tab[pos // BS], pos % BS].set(k_seq[r, pos])
            pv = pv.at[tab[pos // BS], pos % BS].set(v_seq[r, pos])
    q = jax.random.normal(jax.random.PRNGKey(2), (B, H, HD))
    bl, br, bp, ll = al.build_block_list([0, 1], max_total=8)
    out = attention_api.paged_attention_opt(
        q, pk, pv, jnp.asarray(bl), jnp.asarray(br), jnp.asarray(bp),
        jnp.asarray(ll))
    # oracle: dense masked attention per request
    for r, L in enumerate(lens):
        qg = q[r].reshape(KV, H // KV, HD)
        s = jnp.einsum("kgd,skd->kgs", qg, k_seq[r, :L]) * HD ** -0.5
        w = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("kgs,skd->kgd", w, v_seq[r, :L]).reshape(H, HD)
        np.testing.assert_allclose(np.asarray(out[r]), np.asarray(o),
                                   rtol=1e-4, atol=1e-4)


def test_prefill_scatter_roundtrip():
    NB, BS, KV, HD = 8, 4, 2, 8
    pool = jnp.zeros((NB, BS, KV, HD))
    k_seq = jax.random.normal(KEY, (2, 8, KV, HD))
    table = jnp.asarray([[5, 1], [2, 7]], jnp.int32)
    pool = gather_prefill_into_pool(pool, k_seq, table, 8, BS)
    np.testing.assert_allclose(np.asarray(pool[5]), np.asarray(k_seq[0, :4]))
    np.testing.assert_allclose(np.asarray(pool[7]), np.asarray(k_seq[1, 4:]))


def test_paged_decode_matches_forward_e2e():
    cfg = get_config("qwen2-1.5b").reduced(dtype="float32")
    model = build_model(cfg, remat=False)
    params = model.init(KEY)
    B, S, BS = 2, 12, 4
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    logits_fwd, _ = model.forward(params, toks)
    a = cfg.attention
    al = BlockAllocator(num_blocks=16, block_size=BS)
    pk, pv = make_pool(cfg.num_layers, 16, BS, a.num_kv_heads, a.head_dim,
                       jnp.float32)
    pools = {"k": pk, "v": pv}
    for r in range(B):
        al.allocate(r, 0)
    outs = []
    for t in range(S):
        slots = al.write_slots(list(range(B)))
        bl, br, bp, lens = al.build_block_list(list(range(B)), max_total=8)
        lists = {"block_list": jnp.asarray(bl), "block_req": jnp.asarray(br),
                 "block_pos": jnp.asarray(bp), "seq_lens": jnp.asarray(lens),
                 "slots": jnp.asarray(slots)}
        lg, pools = model.decode_step_paged(params, pools, lists, toks[:, t])
        outs.append(lg)
        for r in range(B):
            al.commit_token(r)
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(logits_fwd), rtol=3e-3, atol=3e-3)
