"""Ragged prefill+decode kernel over the fused KV pool (docs/ragged_kernel.md).

Four contracts:

* op-level: the ``paged_attention_ragged`` family is BIT-identical per
  backend to ``paged_attention_chunked`` on the registry examples (the
  ragged example re-expresses the chunked one as cu prefix sums over the
  fused pool), and ``ragged_lane_metadata`` reproduces the chunked lane
  arrays exactly — integer derivation, not approximation;
* pool-level: fuse/split-view round-trips are lossless and the allocator's
  whole-block copy primitive moves ONE fused buffer;
* engine-level: greedy streams are bit-identical between ``attn_impl``
  "ragged" and "chunked" across policy triples x spec x overlap (the
  2-device mesh sweep rides in tests/test_sharded_engine.py, which runs the
  default ragged path against the single-device engine);
* autotune: a committed tune table resolves the ragged tunables at engine
  construction (counted ``tuned_resolved``), any miss falls back to the
  registry defaults (counted ``tuned_fallback``).

Backend-enrollment parity for the new family is registry-driven —
tests/test_backend_parity.py enumerates ``dispatch.list_ops()``.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ServeConfig, get_config
from repro.core import dispatch
from repro.core.attention_api import ragged_lane_metadata
from repro.core.paged_kv import copy_pool_blocks, fuse_kv_heads, fused_kv_views
from repro.perf import autotune
from repro.serving.engine import Request, ServingEngine

KEY = jax.random.PRNGKey(0)


def _examples():
    dispatch._ensure_registered()
    ragged = dispatch.get_op("paged_attention_ragged").example()
    chunked = dispatch.get_op("paged_attention_chunked").example()
    return ragged, chunked


# ------------------------------------------------------------------ op level
@pytest.mark.parametrize("backend", ["ref", "xla", "pallas_interpret"])
def test_ragged_matches_chunked_bitwise_per_backend(backend):
    (r_args, r_kw), (c_args, c_kw) = _examples()
    fam_r = dispatch.get_op("paged_attention_ragged")
    fam_c = dispatch.get_op("paged_attention_chunked")
    out_r = fam_r(*r_args, backend=backend, **r_kw)
    out_c = fam_c(*c_args, backend=backend, **c_kw)
    assert np.array_equal(np.asarray(out_r), np.asarray(out_c)), backend


def test_ragged_lane_metadata_reproduces_chunked_lanes():
    (r_args, _), (c_args, _) = _examples()
    _, _, _, _, _, cu_q, cu_kv, seq_slot = r_args
    q, _, _, _, _, _, kv_lens, token_req, token_pos = c_args
    treq, tpos, kvl = ragged_lane_metadata(cu_q, cu_kv, seq_slot,
                                           q.shape[0], kv_lens.shape[0])
    assert np.array_equal(np.asarray(treq), np.asarray(token_req))
    assert np.array_equal(np.asarray(tpos), np.asarray(token_pos))
    assert np.array_equal(np.asarray(kvl), np.asarray(kv_lens))


def test_ragged_tunables_registered():
    fam = dispatch.get_op("paged_attention_ragged")
    assert set(fam.tunables) == set(autotune.TUNABLE_KEYS)
    # Tunable values never change the math, only the grid shape.
    (r_args, _), _ = _examples()
    base = fam(*r_args, backend="pallas_interpret",
               num_queries_per_block=16, num_kv_pages_per_block=1)
    for nq, nk, vmem in [(1, 1, 0), (3, 2, 0), (16, 4, 4096)]:
        out = fam(*r_args, backend="pallas_interpret",
                  num_queries_per_block=nq, num_kv_pages_per_block=nk,
                  vmem_limit_bytes=vmem)
        assert np.array_equal(np.asarray(out), np.asarray(base)), (nq, nk)


# ---------------------------------------------------------------- pool level
def test_fused_pool_roundtrip_and_block_copy():
    NB, BS, KV, HD = 6, 4, 2, 8
    ks = jax.random.split(KEY, 2)
    k = jax.random.normal(ks[0], (3, NB, BS, KV, HD))
    v = jax.random.normal(ks[1], (3, NB, BS, KV, HD))
    fused = fuse_kv_heads(k, v)
    assert fused.shape == (3, NB, BS, 2 * KV, HD)
    k2, v2 = fused_kv_views(fused)
    assert np.array_equal(np.asarray(k2), np.asarray(k))
    assert np.array_equal(np.asarray(v2), np.asarray(v))
    # the allocator's CoW primitive moves ONE buffer; per-channel copies of
    # the split views land in the same places
    srcs, dsts = jnp.asarray([1, 2]), jnp.asarray([4, 5])
    fc = copy_pool_blocks(fused, srcs, dsts)
    kc = copy_pool_blocks(k, srcs, dsts)
    vc = copy_pool_blocks(v, srcs, dsts)
    assert np.array_equal(np.asarray(fc), np.asarray(fuse_kv_heads(kc, vc)))


# -------------------------------------------------------------- engine level
@pytest.fixture(scope="module")
def serving_ref():
    from repro.models.api import build_model
    cfg = get_config("smollm-360m").reduced(dtype="float32")
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _run_engine(cfg, model, params, *, num_blocks=24, n_req=4,
                admission=None, preemption=None, eviction=None, **kw):
    serve = ServeConfig(model=cfg.name, kv_block_size=4, max_batch=3, **kw)
    eng = ServingEngine(model, params, cfg, serve, num_blocks=num_blocks,
                        admission=admission, preemption=preemption,
                        eviction=eviction)
    rng = np.random.default_rng(0)
    for i in range(n_req):
        if i % 2:                       # looping motif: ngram drafts land
            prompt = np.tile(rng.integers(0, cfg.vocab_size, (3,),
                                          dtype=np.int32), 3)
        else:
            prompt = rng.integers(0, cfg.vocab_size,
                                  (int(rng.integers(8, 16)),), dtype=np.int32)
        eng.submit(Request(req_id=i, prompt=prompt, max_new_tokens=5,
                           priority=i % 2))
    eng.run_until_done()
    return {r.req_id: list(r.output) for r in eng.finished}, eng.metrics()


def test_engine_fused_pool_and_metrics(serving_ref):
    cfg, model, params = serving_ref
    outs, m = _run_engine(cfg, model, params)
    assert m["attn_impl"] == "ragged"
    for key in autotune.TUNABLE_KEYS:
        assert key in m, key
        assert m["policy_counters"]["tune.tuned_resolved"] + \
            m["policy_counters"]["tune.tuned_fallback"] == 1
    # ONE fused channel, head-interleaved: (L, NB, BS, 2*KV, HD)
    eng_serve = ServeConfig(model=cfg.name, kv_block_size=4, max_batch=2)
    eng = ServingEngine(model, params, cfg, eng_serve, num_blocks=8)
    assert set(eng.pools) == {"kv"}
    a = cfg.attention
    assert eng.pools["kv"].shape == (
        cfg.num_layers, 8, 4, 2 * a.num_kv_heads, a.head_dim)


def test_engine_ragged_vs_chunked_greedy_parity(serving_ref):
    cfg, model, params = serving_ref
    ref, m_ref = _run_engine(cfg, model, params, attn_impl="chunked")
    for kw in (dict(), dict(overlap=True), dict(spec="ngram", spec_k=3)):
        outs, m = _run_engine(cfg, model, params, attn_impl="ragged", **kw)
        assert outs == ref, (kw, outs, ref)
        assert m["attn_impl"] == "ragged"
    assert m_ref["attn_impl"] == "chunked"


@pytest.mark.slow
def test_engine_ragged_vs_chunked_policy_pressure_sweep(serving_ref):
    cfg, model, params = serving_ref
    triples = [("fcfs", "latest-arrival", "lru"),
               ("priority", "fewest-remaining-tokens", "hit-rate")]
    for adm, pre, evi in triples:
        for nblocks in (24, 10):        # roomy + preemption pressure
            kw = dict(admission=adm, preemption=pre, eviction=evi)
            ref, _ = _run_engine(cfg, model, params, num_blocks=nblocks,
                                 attn_impl="chunked", **kw)
            outs, _ = _run_engine(cfg, model, params, num_blocks=nblocks,
                                  attn_impl="ragged", **kw)
            assert outs == ref, (adm, nblocks)


# ------------------------------------------------------------------ autotune
def _tune_results(cfg_vals, page_size, head_dim, backend):
    derived = ("tune=1;" f"page_size={page_size};head_dim={head_dim};"
               f"backend={backend};"
               + ";".join(f"{k}={v}" for k, v in cfg_vals.items())
               + ";best=1")
    return [{"module": "paged_attention_bench", "schema_version": 1,
             "rows": [{"name": "ragged_tune_test", "us": 1.0,
                       "derived": derived}]}]


def test_autotune_table_resolve_and_fallback(tmp_path):
    cfg_vals = {"num_queries_per_block": 4, "num_kv_pages_per_block": 2,
                "vmem_limit_bytes": 1 << 20}
    path = tmp_path / "BENCH_010.json"
    path.write_text(json.dumps(_tune_results(cfg_vals, 8, 64, "ref")))
    assert autotune.resolve_tunables(8, 64, "ref", str(path)) == cfg_vals
    # misses: wrong cell, absent file — None, never an exception
    assert autotune.resolve_tunables(16, 64, "ref", str(path)) is None
    assert autotune.resolve_tunables(8, 64, "xla", str(path)) is None
    assert autotune.resolve_tunables(8, 64, "ref",
                                     str(tmp_path / "nope.json")) is None
    # best=0 rows never resolve; malformed rows are skipped whole
    res = _tune_results(cfg_vals, 8, 64, "ref")
    res[0]["rows"][0]["derived"] = res[0]["rows"][0]["derived"].replace(
        "best=1", "best=0")
    path.write_text(json.dumps(res))
    assert autotune.resolve_tunables(8, 64, "ref", str(path)) is None


def test_engine_consults_tune_table(serving_ref, tmp_path, monkeypatch):
    cfg, model, params = serving_ref
    a = cfg.attention
    cfg_vals = {"num_queries_per_block": 4, "num_kv_pages_per_block": 2,
                "vmem_limit_bytes": 0}
    path = tmp_path / "BENCH_010.json"
    path.write_text(json.dumps(_tune_results(cfg_vals, 4, a.head_dim, "ref")))
    monkeypatch.setenv("REPRO_TUNE_TABLE", str(path))
    ref, _ = _run_engine(cfg, model, params, attn_impl="chunked",
                         backend="ref")
    outs, m = _run_engine(cfg, model, params, backend="ref")
    assert m["policy_counters"]["tune.tuned_resolved"] == 1
    assert m["policy_counters"]["tune.tuned_fallback"] == 0
    for k, v in cfg_vals.items():
        assert m[k] == v, (k, m[k])
    assert outs == ref             # tunables never change the stream
    # explicit config pins win over the table
    _, m2 = _run_engine(cfg, model, params, backend="ref",
                        num_queries_per_block=7)
    assert m2["num_queries_per_block"] == 7
    assert m2["num_kv_pages_per_block"] == 2       # unpinned: still tuned
    # fallback: no table for this cell -> registry defaults, counted
    monkeypatch.setenv("REPRO_TUNE_TABLE", str(tmp_path / "missing.json"))
    defaults = dispatch.get_op("paged_attention_ragged").tunables
    _, m3 = _run_engine(cfg, model, params, backend="ref")
    assert m3["policy_counters"]["tune.tuned_fallback"] == 1
    for k, v in defaults.items():
        assert m3[k] == v, (k, m3[k])
