"""serving/metrics: nearest-rank percentile edge cases and the
EngineMetrics rollup — pure host-side, no jax."""
import pytest

from repro.serving.metrics import EngineMetrics, LatencyTracker, percentile


# ------------------------------------------------------------- percentiles
def test_empty_tracker_reports_zeros():
    t = LatencyTracker()
    assert t.percentile(50) == 0.0
    assert t.percentile(99) == 0.0
    assert t.mean == 0.0
    assert t.summary() == {"mean": 0.0, "p50": 0.0, "p90": 0.0, "p99": 0.0,
                           "n": 0.0}


def test_single_sample_is_every_percentile():
    t = LatencyTracker()
    t.record(3.5)
    for p in (0, 1, 50, 90, 99, 100):
        assert t.percentile(p) == 3.5
    assert t.mean == 3.5


def test_nearest_rank_small_n():
    """Nearest-rank: smallest sample whose rank >= ceil(p/100 * n)."""
    t = LatencyTracker()
    for v in (4.0, 1.0, 3.0, 2.0):              # insertion order irrelevant
        t.record(v)
    assert t.samples == [1.0, 2.0, 3.0, 4.0]    # sorted insertion
    assert t.percentile(50) == 2.0              # ceil(0.5*4)=2 -> rank 2
    assert t.percentile(25) == 1.0              # ceil(0.25*4)=1
    assert t.percentile(75) == 3.0
    assert t.percentile(99) == 4.0              # ceil(0.99*4)=4
    assert t.percentile(100) == 4.0
    assert t.percentile(0) == 1.0               # clamped to first sample


def test_p50_p99_on_n100_hit_exact_ranks():
    t = LatencyTracker()
    for v in range(100, 0, -1):                 # 1..100 reversed
        t.record(float(v))
    assert t.percentile(50) == 50.0
    assert t.percentile(99) == 99.0
    assert t.percentile(90) == 90.0
    assert t.mean == pytest.approx(50.5)


# ------------------------------------------------------- module-level helper
def test_percentile_helper_matches_tracker():
    """The free function is THE percentile definition — LatencyTracker and
    the replay SLO scorer (repro.perf.replay) both delegate to it, so a
    replayed p99 and an engine p99 over the same samples always agree."""
    samples = [4.0, 1.0, 3.0, 2.0]
    t = LatencyTracker()
    for v in samples:
        t.record(v)
    for p in (0, 25, 50, 75, 90, 99, 100):
        assert percentile(samples, p) == t.percentile(p)
    assert percentile(samples, 50) == 2.0       # input order irrelevant
    assert percentile([], 99) == 0.0
    assert percentile([7.5], 1) == 7.5
    assert percentile(list(range(1, 11)), 90) == 9


# ----------------------------------------------------------- engine rollup
def test_engine_metrics_summary_keys_and_types():
    m = EngineMetrics(backend="xla")
    m.record_finished(ttft=0.2, tpot=0.01, num_output_tokens=5,
                      arrival=100.0, done_at=101.0)
    m.record_finished(ttft=0.4, tpot=0.02, num_output_tokens=5,
                      arrival=100.5, done_at=102.0)
    s = m.summary()
    assert set(s) == {"backend", "finished", "output_tokens",
                      "mean_ttft_s", "p50_ttft_s", "p90_ttft_s", "p99_ttft_s",
                      "mean_tpot_s", "p50_tpot_s", "p90_tpot_s", "p99_tpot_s",
                      "throughput_tok_s", "steps", "num_idle_steps",
                      "tokens_per_step", "lane_tokens_per_step", "phase_s"}
    assert s["backend"] == "xla"
    assert s["finished"] == 2
    assert s["output_tokens"] == 10
    assert s["p50_ttft_s"] == 0.2 and s["p99_ttft_s"] == 0.4
    assert s["p90_ttft_s"] == 0.4 and s["p90_tpot_s"] == 0.02
    # wall clock spans first arrival -> last finish
    assert m.elapsed_s == pytest.approx(2.0)
    assert s["throughput_tok_s"] == pytest.approx(10 / 2.0)


def test_engine_metrics_empty_run_no_division_by_zero():
    s = EngineMetrics().summary()
    assert s["finished"] == 0
    assert s["throughput_tok_s"] == 0.0
    assert s["mean_ttft_s"] == 0.0 and s["p99_tpot_s"] == 0.0


def test_engine_metrics_step_accounting_and_phase_buckets():
    """record_step: tokens-per-step means emitted OUTPUT tokens per step
    (speculative decoding pushes it past one per decode lane), lane tokens
    count the fused program's width, and phase walls accumulate per key."""
    m = EngineMetrics()
    m.record_step(num_tokens=8, emitted_tokens=1,
                  phases={"propose": 0.1, "device": 0.5})
    m.record_step(num_tokens=4, emitted_tokens=3,
                  phases={"propose": 0.2, "device": 0.5, "commit": 0.25})
    s = m.summary()
    assert s["steps"] == 2
    assert s["tokens_per_step"] == pytest.approx(2.0)       # (1 + 3) / 2
    assert s["lane_tokens_per_step"] == pytest.approx(6.0)  # (8 + 4) / 2
    assert s["phase_s"] == pytest.approx(
        {"propose": 0.3, "device": 1.0, "commit": 0.25})


def test_engine_metrics_zero_steps_no_division_by_zero():
    s = EngineMetrics().summary()
    assert s["steps"] == 0
    assert s["tokens_per_step"] == 0.0
    assert s["lane_tokens_per_step"] == 0.0
    assert s["phase_s"] == {}


def test_engine_metrics_none_latencies_skip_trackers():
    """A request preempted before its first token has ttft/tpot None —
    recorded as finished without poisoning the percentile trackers."""
    m = EngineMetrics()
    m.record_finished(ttft=None, tpot=None, num_output_tokens=1,
                      arrival=10.0, done_at=11.0)
    s = m.summary()
    assert s["finished"] == 1
    assert s["mean_ttft_s"] == 0.0
    assert float(m.ttft.summary()["n"]) == 0.0
