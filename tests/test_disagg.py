"""Disaggregated prefill/decode serving + tiered (HBM + host) KV cache:
greedy bit-parity with the monolithic engine swept across policy triples x
spec off/ngram x overlap on/off, the KV-written watermark / same-wave
prefix-dedup primitive, handoff leak-freedom, and the host-tier
demote/promote invariants (round-trips preserve content, stats and
refcounts)."""
import numpy as np
import pytest

from repro.config import ServeConfig, get_config
from repro.core.paged_kv import (BlockAllocator, BlockStats, HostPool,
                                 OutOfBlocksError)
from repro.serving import policy
from repro.serving.disagg import DisaggEngine, parse_roles
from repro.serving.engine import Request, ServingEngine


# ------------------------------------------------------------ watermark core
def test_commit_advances_written_watermark_per_block():
    al = BlockAllocator(num_blocks=8, block_size=4)
    al.allocate(0, 0)
    al.reserve_tokens(0, 6)
    al.commit_tokens(0, 6)
    t = al.table(0)
    assert al.written(t[0]) == 4            # first block fully covered
    assert al.written(t[1]) == 2            # second block partially
    assert al.transferable(0)


def test_truncate_lowers_watermark_on_private_unpublished_block():
    al = BlockAllocator(num_blocks=8, block_size=4)
    al.allocate(0, 0)
    al.reserve_tokens(0, 3)
    al.commit_tokens(0, 3)
    blk = al.table(0)[0]
    assert al.written(blk) == 3
    al.rewind(0, 2)                         # spec-style rollback
    assert al.written(blk) == 1             # stale KV no longer claimed
    assert al.transferable(0)


def test_truncate_keeps_watermark_on_published_block():
    """Published content stays valid for other holders — only private,
    unpublished blocks get their watermark lowered."""
    al = BlockAllocator(num_blocks=8, block_size=4)
    toks = np.arange(4, dtype=np.int32)
    al.allocate_prefix(0, toks)
    al.reserve_tokens(0, 4)
    al.commit_tokens(0, 4)
    al.register_prefix(0, toks, 4)
    blk = al.table(0)[0]
    al.truncate(0, 2)
    assert al.written(blk) == 4


def test_cow_copy_inherits_watermark():
    al = BlockAllocator(num_blocks=8, block_size=4)
    toks = np.arange(4, dtype=np.int32)
    al.allocate_prefix(0, toks)
    al.reserve_tokens(0, 4)
    al.commit_tokens(0, 4)
    al.register_prefix(0, toks, 4)
    assert al.allocate_prefix(1, toks) == 3  # last token left to recompute
    al.reserve_tokens(1, 1)                 # shared last block -> CoW
    new = al.table(1)[0]
    assert new != al.table(0)[0]
    assert al.written(new) == 4             # whole-block device copy carries


# --------------------------------------------------- same-wave prefix dedup
def test_extend_prefix_adopts_published_written_blocks():
    """A borrower admitted mid-wave fast-forwards over blocks the donor
    published after the borrower's admission (the ROADMAP open item)."""
    al = BlockAllocator(num_blocks=16, block_size=4)
    toks = np.arange(10, dtype=np.int32)
    assert al.allocate_prefix(0, toks) == 0          # donor, cold cache
    assert al.allocate_prefix(1, toks) == 0          # borrower, same wave
    al.reserve_tokens(0, 8)
    al.commit_tokens(0, 8)
    al.register_prefix(0, toks, 8)                   # donor publishes 2 blocks
    adopted = al.extend_prefix(1, toks)
    assert adopted == 8
    assert al.seq_len(1) == 8
    assert al.table(1)[:2] == al.table(0)[:2]        # shared, refcount 2
    assert al.ref_count(al.table(0)[0]) == 2
    al.free(0)
    al.free(1)
    assert al.num_free == al.num_blocks


def test_extend_prefix_requires_full_watermark():
    """A published hash alone is not enough: the donor's KV write must have
    covered the whole block (the watermark is the proof)."""
    al = BlockAllocator(num_blocks=16, block_size=4)
    toks = np.arange(10, dtype=np.int32)
    al.allocate_prefix(0, toks)
    al.allocate_prefix(1, toks)
    al.reserve_tokens(0, 2)
    al.commit_tokens(0, 2)                           # half a block written
    donor_blk = al.table(0)[0]
    al._hash_of[donor_blk] = b"x" * 16               # simulate early publish
    al._block_of[b"x" * 16] = donor_blk
    # the borrower's lookup misses (different key) — but even a forced match
    # would be rejected: the watermark gate guards partially-written blocks
    assert al.extend_prefix(1, toks) == 0


def test_extend_prefix_swaps_untouched_placeholder():
    """The cold-start placeholder block (private, unpublished, watermark 0)
    is returned to the free list when the borrower adopts a donor block."""
    al = BlockAllocator(num_blocks=16, block_size=4)
    toks = np.arange(10, dtype=np.int32)
    al.allocate_prefix(0, toks)
    al.allocate_prefix(1, toks)                      # placeholder popped
    placeholder = al.table(1)[0]
    free_before = al.num_free
    al.reserve_tokens(0, 8)
    al.commit_tokens(0, 8)
    al.register_prefix(0, toks, 8)
    assert al.extend_prefix(1, toks) == 8
    assert placeholder not in al.table(1)
    assert al.num_free == free_before                # swap, not a leak
    al.free(0)
    al.free(1)
    assert al.num_free == al.num_blocks


def test_extend_prefix_never_crosses_touched_frontier():
    """A borrower that already committed KV into its frontier block must not
    swap it out from under itself."""
    al = BlockAllocator(num_blocks=16, block_size=4)
    toks = np.arange(10, dtype=np.int32)
    al.allocate_prefix(0, toks)
    al.allocate_prefix(1, toks)
    al.reserve_tokens(1, 2)                          # borrower already wrote
    al.commit_tokens(1, 2)
    al.truncate(1, 0)                                # rewound, but was touched
    al.reserve_tokens(1, 1)
    al.commit_tokens(1, 1)
    al.truncate(1, 0)
    own = al.table(1)[0]
    al._written[own] = 1                             # sticky partial write
    al.reserve_tokens(0, 8)
    al.commit_tokens(0, 8)
    al.register_prefix(0, toks, 8)
    assert al.extend_prefix(1, toks) == 0


def test_extend_prefix_leaves_last_token_to_recompute():
    """Like allocate_prefix, dedup never fast-forwards past len - 1: the
    final logits must always be recomputed."""
    al = BlockAllocator(num_blocks=16, block_size=4)
    toks = np.arange(8, dtype=np.int32)              # exactly 2 blocks
    al.allocate_prefix(0, toks)
    al.allocate_prefix(1, toks)
    al.reserve_tokens(0, 8)
    al.commit_tokens(0, 8)
    al.register_prefix(0, toks, 8)
    assert al.extend_prefix(1, toks) == 4            # second block withheld
    assert al.seq_len(1) == 4


def test_engine_same_wave_dedup_shares_blocks(disagg_ref):
    """Two same-prompt requests admitted in one wave share prefix blocks:
    the second adopts blocks as the first publishes them mid-prefill."""
    cfg, model, params = disagg_ref["build"]
    prompt = disagg_ref["rng"]().integers(0, cfg.vocab_size, (20,),
                                          dtype=np.int32)
    serve = ServeConfig(model=cfg.name, kv_block_size=4, max_batch=2,
                        prefill_chunk=8)
    eng = ServingEngine(model, params, cfg, serve, num_blocks=32)
    eng.submit(Request(req_id=0, prompt=prompt, max_new_tokens=4))
    eng.submit(Request(req_id=1, prompt=prompt, max_new_tokens=4))
    eng.run_until_done()
    outs = {r.req_id: r.output for r in eng.finished}
    assert outs[0] == outs[1]
    m = eng.metrics()
    assert m["prefix_hits"] > 0                      # dedup actually fired
    assert m["blocks_free"] == 32


# ----------------------------------------------------------------- host tier
def _tiered_alloc(num_blocks=2, host=4):
    return BlockAllocator(
        num_blocks=num_blocks, block_size=4,
        eviction_policy=policy.resolve("eviction", "tiered"),
        host_pool=HostPool(host))


def _cache_prefix(al, toks, rid):
    al.allocate_prefix(rid, toks)
    al.reserve_tokens(rid, len(toks))
    al.commit_tokens(rid, len(toks))
    al.register_prefix(rid, toks, len(toks))
    blk = al.table(rid)[0]
    al.free(rid)
    return blk


def test_tiered_demote_gate_drops_cold_keeps_warm():
    """The registered ``tiered`` policy demotes blocks with reuse evidence
    (hits or sharing) and drops never-reused ones."""
    al = _tiered_alloc()
    hot = np.arange(4, dtype=np.int32)
    cold = np.arange(100, 104, dtype=np.int32)
    _cache_prefix(al, hot, 0)
    _cache_prefix(al, cold, 1)
    assert al.allocate_prefix(2, np.concatenate([hot, hot[:1]])) == 4  # hit
    al.free(2)
    al.allocate(3, 8)                       # evicts both cached prefixes
    pol = al.eviction_policy
    assert pol.counters["dropped"] == 1     # cold: no evidence -> dropped
    assert pol.counters["demoted"] == 1     # hot: hit evidence -> demoted
    assert len(al.host_pool) == 1
    ops = al.drain_tier_ops()
    assert [op[0] for op in ops] == ["demote"]


def test_demote_promote_round_trip_preserves_stats_and_refcounts():
    al = _tiered_alloc()
    hot = np.arange(4, dtype=np.int32)
    blk = _cache_prefix(al, hot, 0)
    al.allocate_prefix(1, np.concatenate([hot, hot[:1]]))      # hit: hits=1
    al.free(1)
    al.allocate(2, 8)                       # demote hot to host
    al.free(2)
    assert hot.tobytes() and len(al.host_pool) == 1
    assert al.peek_prefix(np.concatenate([hot, hot[:1]])) == 0  # HBM miss
    cached = al.allocate_prefix(3, np.concatenate([hot, hot[:1]]))
    assert cached == 4                      # promoted from the host tier
    new = al.table(3)[0]
    assert al.ref_count(new) == 1
    assert al.written(new) == al.block_size
    assert al.block_stats(new).hits >= 2    # pre-demotion evidence survived
    ops = al.drain_tier_ops()
    assert [op[0] for op in ops] == ["demote", "promote"]      # ordered
    assert ops[0][1] is ops[1][1]           # same HostBlock entry round-trips
    assert al.host_pool.counters["promotes"] == 1
    al.free(3)
    assert al.num_free == al.num_blocks


def test_promote_rolls_back_when_hbm_pool_cannot_yield():
    al = _tiered_alloc(num_blocks=2)
    hot = np.arange(4, dtype=np.int32)
    _cache_prefix(al, hot, 0)
    al.allocate_prefix(1, np.concatenate([hot, hot[:1]]))
    al.free(1)
    al.allocate(2, 8)                       # hot demoted, pool fully live
    assert len(al.host_pool) == 1
    with pytest.raises(OutOfBlocksError):   # promote fails, then cold start
        al.allocate_prefix(3, np.concatenate([hot, hot[:1]]))
    assert len(al.host_pool) == 1           # untake restored the entry
    assert al.host_pool.counters["promotes"] == 0


def test_host_pool_lru_drops_oldest_past_capacity():
    hp = HostPool(2)
    a, b, c = (bytes([i]) * 16 for i in range(3))
    hp.put(a, BlockStats())
    hp.put(b, BlockStats())
    hp.put(c, BlockStats())
    assert len(hp) == 2 and a not in hp and b in hp and c in hp
    assert hp.counters["drops"] == 1
    assert hp.take(a) is None


def test_engine_tier_round_trip_bit_identical(disagg_ref):
    """A prefix fully demoted to host and promoted back yields the same
    greedy stream as the unpressured engine — KV content survives the
    device->host->device round-trip."""
    cfg, model, params = disagg_ref["build"]
    rng = disagg_ref["rng"]()
    prompt = rng.integers(0, cfg.vocab_size, (17,), dtype=np.int32)
    filler = rng.integers(0, cfg.vocab_size, (17,), dtype=np.int32)

    def run(num_blocks, host_blocks, rounds):
        serve = ServeConfig(model=cfg.name, kv_block_size=4, max_batch=1,
                            eviction="tiered", host_blocks=host_blocks)
        eng = ServingEngine(model, params, cfg, serve, num_blocks=num_blocks)
        outs = []
        for i, p in enumerate(rounds):
            eng.submit(Request(req_id=i, prompt=p, max_new_tokens=4))
            eng.run_until_done()
            outs.append(eng.finished[-1].output)
        return outs, eng

    # ample pool, no pressure: the reference streams
    ref, _ = run(64, 8, [prompt, prompt, filler, prompt])
    # starved pool: prompt's blocks earn a hit (round 2), get demoted by the
    # filler (round 3), and must promote back for round 4
    outs, eng = run(7, 8, [prompt, prompt, filler, prompt])
    assert outs == ref
    hp = eng.host_pool
    assert hp.counters["demotes"] > 0 and hp.counters["promotes"] > 0
    m = eng.metrics()
    assert m["tier"]["host_blocks"] == 8
    assert m["policy_counters"]["tier.promotes"] == hp.counters["promotes"]
    assert m["blocks_free"] == 7            # no leak under tier traffic


def test_host_tier_rejected_on_sharded_engine(disagg_ref):
    cfg, model, params = disagg_ref["build"]
    serve = ServeConfig(model=cfg.name, kv_block_size=4, max_batch=1,
                        devices=2, host_blocks=4)
    with pytest.raises(ValueError):
        ServingEngine(model, params, cfg, serve, num_blocks=8)


# ------------------------------------------------------------ disagg engine
@pytest.fixture(scope="module")
def disagg_ref():
    """Shared model + the monolithic reference outputs for the parity sweep."""
    import jax
    from repro.models.api import build_model

    cfg = get_config("smollm-360m").reduced(dtype="float32")
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))

    def rng():
        return np.random.default_rng(0)

    def requests(n=4, lo=12, hi=25, max_new=5):
        r = np.random.default_rng(3)
        shared = r.integers(0, cfg.vocab_size, (8,), dtype=np.int32)
        out = []
        for i in range(n):
            tail = r.integers(0, cfg.vocab_size,
                              (int(r.integers(lo, hi)),), dtype=np.int32)
            prompt = np.concatenate([shared, tail]) if i % 2 else tail
            out.append(Request(req_id=i, prompt=prompt,
                               max_new_tokens=max_new))
        return out

    serve = ServeConfig(model=cfg.name, kv_block_size=8, max_batch=2)
    eng = ServingEngine(model, params, cfg, serve, num_blocks=64)
    for q in requests():
        eng.submit(q)
    eng.run_until_done()
    outputs = {q.req_id: q.output for q in eng.finished}
    assert len(outputs) == 4
    return {"build": (cfg, model, params), "requests": requests,
            "outputs": outputs, "rng": rng}


def _run_disagg(disagg_ref, serve_kw, engine_kw=None, requests_kw=None):
    cfg, model, params = disagg_ref["build"]
    serve = ServeConfig(model=cfg.name, kv_block_size=8, max_batch=2,
                        roles="prefill,decode", **serve_kw)
    eng = DisaggEngine(model, params, cfg, serve, num_blocks=64,
                       **(engine_kw or {}))
    for q in disagg_ref["requests"](**(requests_kw or {})):
        eng.submit(q)
    eng.run_until_done()
    return {q.req_id: q.output for q in eng.finished}, eng


def test_disagg_matches_monolithic_and_leaks_nothing(disagg_ref):
    outs, eng = _run_disagg(disagg_ref, {})
    assert outs == disagg_ref["outputs"]
    assert eng.num_handoffs > 0
    assert eng.pre.alloc.num_free == eng.pre.alloc.num_blocks
    assert eng.dec.alloc.num_free == eng.dec.alloc.num_blocks
    assert not eng._staged and not eng._pending_handoffs


def test_disagg_interleave_ratio_does_not_change_outputs(disagg_ref):
    for k in (1, 7):
        outs, _ = _run_disagg(disagg_ref, {},
                              engine_kw={"decode_steps_per_step": k})
        assert outs == disagg_ref["outputs"], f"ratio {k} diverged"


def test_disagg_routes_sub_block_prompts_direct(disagg_ref):
    outs, eng = _run_disagg(disagg_ref, {},
                            requests_kw={"lo": 3, "hi": 6, "n": 2})
    assert eng.num_direct > 0               # tail-only prompts skip prefill
    assert len(outs) == 2


def test_disagg_submit_validation(disagg_ref):
    cfg, model, params = disagg_ref["build"]
    serve = ServeConfig(model=cfg.name, kv_block_size=8, max_batch=2,
                        roles="prefill,decode")
    eng = DisaggEngine(model, params, cfg, serve, num_blocks=64)
    with pytest.raises(ValueError):
        eng.submit(Request(req_id=-1, prompt=np.arange(4, dtype=np.int32),
                           max_new_tokens=1))
    eng.submit(Request(req_id=0, prompt=np.arange(4, dtype=np.int32),
                       max_new_tokens=1))
    with pytest.raises(ValueError):         # duplicate id
        eng.submit(Request(req_id=0, prompt=np.arange(4, dtype=np.int32),
                           max_new_tokens=1))
    big = np.zeros((8 * 70,), np.int32)     # stages more than the pool
    with pytest.raises(ValueError):
        eng.submit(Request(req_id=1, prompt=big, max_new_tokens=1))
    with pytest.raises(ValueError):
        parse_roles("prefill,prefill")
    assert parse_roles("split") == ("prefill", "decode")
    assert parse_roles("") == ()


def test_disagg_metrics_attribution(disagg_ref):
    outs, eng = _run_disagg(disagg_ref, {"eviction": "tiered",
                                         "host_blocks": 8})
    m = eng.metrics()
    assert m["role"] == "prefill,decode"
    assert set(m["roles"]) == {"prefill", "decode"}
    assert m["roles"]["prefill"]["prefills_completed"] == m["handoffs"] > 0
    assert m["roles"]["prefill"]["tier"]["host_blocks"] == 8
    assert m["handoff_ms"]["n"] == m["handoffs"]
    assert m["handoff_ms"]["p99"] >= 0
    for k in ("tier.demotes", "tier.promotes", "tier.prefill.demotes"):
        assert k in m["policy_counters"], k
    assert m["tier"]["hbm_blocks"] == 64


@pytest.mark.slow       # one disagg engine run per (triple, spec, overlap)
@pytest.mark.parametrize(
    "eviction,spec,overlap",
    [(e, s, o) for e in ("lru", "hit-rate", "refcount-aware", "tiered")
     for s in ("off", "ngram") for o in (False, True)],
    ids=lambda v: str(v).lower())
def test_disagg_parity_sweep(disagg_ref, eviction, spec, overlap):
    """Acceptance: greedy streams stay bit-identical to the monolithic
    engine across eviction policies x spec off/ngram x overlap on/off (the
    host tier rides along whenever the tiered policy is under test)."""
    kw = {"eviction": eviction, "spec": spec, "overlap": overlap}
    if eviction == "tiered":
        kw["host_blocks"] = 8
    outs, eng = _run_disagg(disagg_ref, kw)
    assert outs == disagg_ref["outputs"], f"{kw} diverged"
    assert eng.dec.alloc.num_free == eng.dec.alloc.num_blocks


@pytest.mark.slow
@pytest.mark.parametrize("admission,preemption",
                         [("priority", "latest-arrival"),
                          ("fcfs", "most-blocks"),
                          ("deadline-slo", "fewest-remaining-tokens")])
def test_disagg_parity_other_axes(disagg_ref, admission, preemption):
    outs, _ = _run_disagg(disagg_ref, {"admission": admission,
                                       "preemption": preemption})
    assert outs == disagg_ref["outputs"]
