import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]
for p in (str(ROOT / "src"), str(ROOT)):
    if p not in sys.path:
        sys.path.insert(0, p)

# NOTE: tests run with the real device count (1 CPU). Multi-device tests go
# through run_multidevice() in a subprocess so the 512-device dry-run env
# never leaks into smoke tests (see dryrun.py step 0).


def run_multidevice(snippet: str, n_devices: int = 8, timeout: int = 600
                    ) -> subprocess.CompletedProcess:
    """Run a python snippet in a subprocess with n host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = f"{ROOT / 'src'}:{ROOT}"
    return subprocess.run(
        [sys.executable, "-c", textwrap.dedent(snippet)],
        capture_output=True, text=True, timeout=timeout, env=env)


@pytest.fixture(scope="session")
def rng_key():
    import jax
    return jax.random.PRNGKey(0)
