import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]
for p in (str(ROOT / "src"), str(ROOT)):
    if p not in sys.path:
        sys.path.insert(0, p)

# NOTE: tests run with the real device count (1 CPU). Multi-device tests go
# through run_multidevice() in a subprocess so the 512-device dry-run env
# never leaks into smoke tests (see dryrun.py step 0).


def run_multidevice(snippet: str, n_devices: int = 8, timeout: int = 600
                    ) -> subprocess.CompletedProcess:
    """Run a python snippet in a subprocess with n host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = f"{ROOT / 'src'}:{ROOT}"
    return subprocess.run(
        [sys.executable, "-c", textwrap.dedent(snippet)],
        capture_output=True, text=True, timeout=timeout, env=env)


@pytest.fixture(scope="session")
def rng_key():
    import jax
    return jax.random.PRNGKey(0)


# Opt-in runtime sanitizers (docs/static_analysis.md): REPRO_SANITIZE=1
# forces ServeConfig.sanitize=True for every engine built by the pipeline
# suites (test_overlap.py, test_disagg.py — DisaggEngine builds the same
# ServingEngine class, so both roles are covered), turning on the retrace
# guard, host-sync guard and per-step allocator invariant checks there
# without touching the tests themselves.
_SANITIZED_MODULES = ("test_overlap", "test_disagg")


# module-scoped + autouse: pytest instantiates autouse fixtures first
# within a scope, so the patch is live before the suites' module-scoped
# engine fixtures build their engines
@pytest.fixture(scope="module", autouse=True)
def _repro_sanitize(request):
    name = request.module.__name__.rpartition(".")[2]
    if os.environ.get("REPRO_SANITIZE") != "1" \
            or name not in _SANITIZED_MODULES:
        yield
        return
    import dataclasses

    from repro.serving import engine as engine_lib

    orig = engine_lib.ServingEngine.__init__

    def sanitized(self, model, params, cfg, serve, *args, **kwargs):
        serve = dataclasses.replace(serve, sanitize=True)
        return orig(self, model, params, cfg, serve, *args, **kwargs)

    engine_lib.ServingEngine.__init__ = sanitized
    try:
        yield
    finally:
        engine_lib.ServingEngine.__init__ = orig
