"""Distribution: sharding rules, compression, pipeline PP, elastic logic.
Multi-device paths run in subprocesses with forced host devices."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from conftest import run_multidevice
from repro.distributed.compression import dequantize_int8, quantize_int8
from repro.distributed.elastic import (
    HeartbeatMonitor, StragglerWatchdog, plan_remesh)

# multi-device subprocess paths: excluded from the fast tier
pytestmark = pytest.mark.slow


# ------------------------------------------------------------------ sharding
def test_sharding_rules_divisibility_fallback():
    from repro.distributed.sharding import ShardingRules
    snippet = """
    import jax, jax.numpy as jnp
    from repro.distributed.sharding import ShardingRules
    from repro.config import get_config
    from repro.models.api import build_model
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rules = ShardingRules(mesh)
    # granite vocab 49155 %4 != 0 -> unsharded; d_model 1024 %2 == 0 -> fsdp
    spec = rules.param_spec(("embed", "table"), (49155, 1024))
    assert spec == jax.sharding.PartitionSpec(None, None), spec
    spec = rules.param_spec(("layers", "attn", "wq"), (24, 1024, 2048))
    assert spec[1] == "data" and spec[2] == "model", spec
    spec = rules.param_spec(("layers", "moe", "w_gate"), (24, 32, 1024, 512))
    assert spec[1] == "model" and spec[2] == "data", spec
    print("OK")
    """
    r = run_multidevice(snippet)
    assert "OK" in r.stdout, r.stderr[-2000:]


def test_train_step_numerics_match_sharded_vs_single():
    """1-device result == 8-device sharded result (same seed/batch).

    head_dim is passed to ShardingRules so attention projections only
    TP-shard on whole-head boundaries. Without it this config (1 kv head x
    head_dim 16) sharded wk's 16-wide output over the model axis, and jax
    0.4.37's GSPMD partitioner miscompiles that sub-head sharding inside
    the scan-over-layers body: the sharded forward silently diverged from
    the single-device result by ~0.6% (loss 5.9959 vs 6.0306). Bisected:
    the same block applied outside lax.scan, or the same scan with
    scan_layers=False (unrolled), or any whole-head sharding, is exact to
    float32 noise — so this was a partitioner artifact, not accumulation
    order, and the fix is the head-granularity constraint every TP system
    imposes anyway.
    """
    snippet = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.config import get_config
    from repro.models.api import build_model
    from repro.optim import adamw, cosine_warmup
    from repro.training.train_step import init_state, jit_train_step, make_train_step
    from repro.distributed.sharding import ShardingRules

    cfg = get_config("smollm-360m").reduced(dtype="float32", num_layers=2,
                                            d_model=64, vocab_size=256)
    model = build_model(cfg, remat=False)
    opt = adamw()
    lr = cosine_warmup(1e-3, 2, 10)
    state = init_state(model, jax.random.PRNGKey(0), opt)
    toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, 256)
    batch = {"tokens": toks}
    # single-device reference
    _, m_ref = jax.jit(make_train_step(model, opt, lr))(state, batch)
    # sharded (head-granular TP: see the test docstring)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    rules = ShardingRules(mesh, head_dim=cfg.attention.head_dim)
    step = jit_train_step(model, opt, lr, mesh, rules,
                          jax.eval_shape(lambda: state), batch, donate=False)
    with mesh:
        _, m_sh = step(state, batch)
    np.testing.assert_allclose(float(m_ref["loss"]), float(m_sh["loss"]),
                               rtol=1e-4)
    np.testing.assert_allclose(float(m_ref["grad_norm"]),
                               float(m_sh["grad_norm"]), rtol=1e-3)
    print("OK")
    """
    r = run_multidevice(snippet)
    assert "OK" in r.stdout, r.stderr[-2000:]


# --------------------------------------------------------------- compression
def test_quantize_roundtrip_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (256,)) * 3
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x))
    assert err.max() <= float(s) * 0.5 + 1e-6


def test_compressed_psum_with_error_feedback():
    snippet = """
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.distributed.compression import compressed_psum
    shard_map = getattr(jax, "shard_map", None)
    if shard_map is None:  # pre-0.5 jax
        from jax.experimental.shard_map import shard_map
    mesh = jax.make_mesh((8,), ("x",))
    g = jax.random.normal(jax.random.PRNGKey(0), (8, 64))

    def f(g, r):
        return compressed_psum(g, r, "x")

    out, res = jax.jit(shard_map(f, mesh=mesh,
        in_specs=(P("x"), P("x")), out_specs=(P("x"), P("x"))))(
        g, jnp.zeros_like(g))
    ref = jnp.mean(g, axis=0)
    # every shard holds the same reduced mean, within int8 quantization err
    for i in range(8):
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(ref),
                                   atol=0.1)
    # error feedback: residual equals what quantization dropped
    assert float(jnp.abs(res).max()) < 0.2
    # accumulated over steps, mean residual-corrected error shrinks
    print("OK")
    """
    r = run_multidevice(snippet)
    assert "OK" in r.stdout, r.stderr[-2000:]


# ------------------------------------------------------------------ pipeline
def test_pipeline_matches_reference():
    snippet = """
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.distributed.pipeline import bubble_fraction, pipeline_forward
    shard_map = getattr(jax, "shard_map", None)
    if shard_map is None:  # pre-0.5 jax
        from jax.experimental.shard_map import shard_map
    S, M, mb, D = 4, 6, 2, 8
    mesh = jax.make_mesh((S,), ("pp",))
    ws = jax.random.normal(jax.random.PRNGKey(0), (S, D, D)) * 0.3
    x = jax.random.normal(jax.random.PRNGKey(1), (M, mb, D))

    def layer_fn(w, h):
        return jnp.tanh(h @ w)

    def run(ws_stage, x_all):
        return pipeline_forward(layer_fn, ws_stage[0], x_all,
                                axis="pp", num_stages=S)

    out = jax.jit(shard_map(run, mesh=mesh,
        in_specs=(P("pp"), P()), out_specs=P()))(ws, x)
    # reference: apply all stages sequentially
    ref = x
    for s in range(S):
        ref = layer_fn(ws[s], ref)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)
    assert abs(bubble_fraction(S, M) - 3/9) < 1e-9
    print("OK")
    """
    r = run_multidevice(snippet, n_devices=4)
    assert "OK" in r.stdout, r.stderr[-2000:]


# ------------------------------------------------------------------- elastic
def test_heartbeat_monitor():
    hb = HeartbeatMonitor([0, 1, 2], timeout_s=10.0)
    hb.beat(0, now=100.0)
    hb.beat(1, now=95.0)
    hb.beat(2, now=50.0)
    assert hb.dead(now=104.0) == [2]
    assert hb.alive(now=104.0) == [0, 1]


def test_plan_remesh():
    # full 2 pods healthy
    assert plan_remesh(512, 256, model_parallel=16) == (2, 16, 16)
    # one pod lost
    assert plan_remesh(256, 256, model_parallel=16) == (1, 16, 16)
    # partial pod: shrink data by powers of two
    assert plan_remesh(200, 256, model_parallel=16) == (1, 8, 16)
    # not enough for even one model replica
    assert plan_remesh(8, 256, model_parallel=16) is None


def test_straggler_watchdog():
    wd = StragglerWatchdog(threshold=2.0)
    for i in range(10):
        assert not wd.record(i, 1.0)
    assert wd.record(10, 5.0)                  # straggler flagged
    assert wd.slow_steps == [10]
    assert abs(wd.baseline - 1.0) < 1e-6       # baseline unpoisoned
