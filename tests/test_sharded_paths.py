"""shard_map paths: sharded BlockList paged attention (flash-decoding
combine) and row-sharded BatchedTable embedding — each must equal its
single-device oracle."""
import pytest

from conftest import run_multidevice

# multi-device subprocess sweeps: excluded from the fast tier
pytestmark = pytest.mark.slow


def test_paged_attention_sharded_equals_opt():
    snippet = """
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core.attention_api import (
        paged_attention_opt, paged_attention_sharded)
    from repro.core.paged_kv import BlockAllocator
    shard_map = getattr(jax, "shard_map", None)
    if shard_map is None:  # pre-0.5 jax
        from jax.experimental.shard_map import shard_map

    SHARDS, BS, KV, HD, H, B = 4, 4, 2, 16, 4, 3
    NB_PER = 8
    NB = SHARDS * NB_PER
    lens = [14, 7, 22]
    al = BlockAllocator(num_blocks=NB, block_size=BS, num_shards=SHARDS)
    # interleave blocks so every shard owns every 4th block:
    # shard s owns blocks [s*NB_PER, (s+1)*NB_PER); allocate round-robin
    order = [s * NB_PER + i for i in range(NB_PER) for s in range(SHARDS)]
    al._free = list(reversed(order))
    for r, L in enumerate(lens):
        al.allocate(r, L)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    pool_k = jax.random.normal(ks[0], (NB, BS, KV, HD))
    pool_v = jax.random.normal(ks[1], (NB, BS, KV, HD))
    q = jax.random.normal(ks[2], (B, H, HD))

    # oracle: flat list, single device
    bl, br, bp, ll = al.build_block_list(list(range(B)), max_total=NB)
    ref = paged_attention_opt(q, pool_k, pool_v, jnp.asarray(bl),
                              jnp.asarray(br), jnp.asarray(bp),
                              jnp.asarray(ll))

    # sharded: per-shard lists with LOCAL pool indices
    mesh = jax.make_mesh((SHARDS,), ("model",))
    maxp = 8
    sbl = np.zeros((SHARDS, maxp), np.int32)
    sbr = np.full((SHARDS, maxp), B, np.int32)
    sbp = np.zeros((SHARDS, maxp), np.int32)
    fill = [0] * SHARDS
    for r in range(B):
        for k_i, blk in enumerate(al.table(r)):
            s = blk // NB_PER
            j = fill[s]; fill[s] += 1
            sbl[s, j] = blk % NB_PER          # local index within shard pool
            sbr[s, j] = r
            sbp[s, j] = k_i

    def f(q, pk, pv, bl, br, bp, sl):
        return paged_attention_sharded(q, pk[0], pv[0], bl[0], br[0], bp[0],
                                       sl, axis="model")

    out = jax.jit(shard_map(
        f, mesh=mesh,
        in_specs=(P(), P("model"), P("model"), P("model"), P("model"),
                  P("model"), P()),
        out_specs=P()))(
        q, pool_k.reshape(SHARDS, NB_PER, BS, KV, HD),
        pool_v.reshape(SHARDS, NB_PER, BS, KV, HD),
        jnp.asarray(sbl), jnp.asarray(sbr), jnp.asarray(sbp),
        jnp.asarray(ll))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    print("OK")
    """
    r = run_multidevice(snippet, n_devices=4)
    assert "OK" in r.stdout, (r.stdout[-300:], r.stderr[-2500:])


def test_row_sharded_embedding_equals_dense():
    snippet = """
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core.embedding_api import (
        batched_table_lookup, batched_table_lookup_sharded)
    shard_map = getattr(jax, "shard_map", None)
    if shard_map is None:  # pre-0.5 jax
        from jax.experimental.shard_map import shard_map
    SHARDS, T, R, D, B, L = 4, 3, 16, 8, 2, 5
    big = jax.random.normal(jax.random.PRNGKey(0), (T * R, D))
    offs = jnp.arange(T, dtype=jnp.int32) * R
    idx = jax.random.randint(jax.random.PRNGKey(1), (B, T, L), 0, R)
    ref = batched_table_lookup(big, offs, idx)
    mesh = jax.make_mesh((SHARDS,), ("model",))

    def f(tbl, offs, idx):
        return batched_table_lookup_sharded(tbl, offs, idx, axis="model")

    out = jax.jit(shard_map(
        f, mesh=mesh, in_specs=(P("model"), P(), P()), out_specs=P()))(
        big, offs, idx)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    print("OK")
    """
    r = run_multidevice(snippet, n_devices=4)
    assert "OK" in r.stdout, (r.stdout[-300:], r.stderr[-2500:])
