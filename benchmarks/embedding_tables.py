"""Paper Fig 15: SingleTable vs BatchedTable embedding-lookup throughput.

THE paper §4.1 reproduction. SingleTable launches one gather per table;
BatchedTable fuses all tables into one op (FBGEMM design). Sweeps number of
tables, batch size, and vector width (the paper's three axes). Derived:
launch-count ratio and effective-bandwidth model; the paper's claim
(BatchedTable ≥1.5× at small batch, converging at large batch) is asserted
by tests/test_benchmarks.py over these numbers."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core.embedding_api import (
    batched_table_lookup, single_table_lookup)

ROWS = 4_096


def run(quick: bool = True) -> None:
    key = jax.random.PRNGKey(0)
    dims = [64] if quick else [16, 64, 128, 256]
    tables_sweep = [4, 20] if quick else [1, 4, 10, 20, 40]
    batch_sweep = [4, 64] if quick else [4, 16, 64, 256, 1024]
    L = 20                                      # pooling factor (RM2)
    single = jax.jit(single_table_lookup)
    batched = jax.jit(batched_table_lookup)
    for D in dims:
        for T in tables_sweep:
            big = jax.random.normal(key, (T * ROWS, D), jnp.float32)
            offs = jnp.arange(T, dtype=jnp.int32) * ROWS
            tabs = [big[t * ROWS:(t + 1) * ROWS] for t in range(T)]
            for B in batch_sweep:
                idx = jax.random.randint(key, (B, T, L), 0, ROWS)
                us_s = time_fn(single, tabs, idx, iters=3)
                us_b = time_fn(batched, big, offs, idx, iters=3)
                speedup = us_s / max(us_b, 1e-9)
                emit(f"embed_single_T{T}_B{B}_D{D}", us_s, f"launches={T}")
                emit(f"embed_batched_T{T}_B{B}_D{D}", us_b,
                     f"launches=1;speedup_vs_single={speedup:.2f}")
