"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. ``--full`` widens sweeps.

``--backend a,b,...`` repeats the run once per backend name, with each pass
scoped under ``dispatch.force_backend`` so every registry-dispatched op
(kernels AND the serving engine) follows the preference; ``--json PATH``
then writes the per-backend rows plus the ``(op, backend)`` pairs that
actually resolved — the paper-style microbenchmark comparison across
software stacks, attributable to the implementation that really ran
(an unsupported preference degrades to capability-ranked auto).

``--policy adm/pre/evi,...`` sweeps serving-policy triples the same way:
each triple is scoped under ``repro.serving.policy.force_policies`` so every
serving engine built inside the pass (the bursty / shared-prefix /
memory-pressure / repetitive-suffix scenarios of ``llm_e2e``) runs that
admission/preemption/eviction combination; rows and JSON records carry the
resolved triple.  An axis left empty (``//refcount-aware``) keeps its
default.  Only modules in ``POLICY_SENSITIVE`` (those that build serving
engines) repeat per triple; policy-blind modules run once, under the first
triple — their numbers cannot depend on the policy choice.

``--spec off,ngram,draft-model`` sweeps speculative-decoding proposers the
same way again (scoped under ``repro.serving.spec.force_proposer``); every
llm_e2e engine row carries the resolved proposer plus its acceptance rate,
so multi-token-decode wins are attributable to one proposer.  Like policy
sweeps, only ``SPEC_SENSITIVE`` modules repeat per proposer.  The
``draft-model`` pass runs k extra draft forwards per decode step — treat it
as a slow sweep (it is skipped under ``REPRO_BENCH_SMOKE=1``; the CI smoke
sweeps ``off,ngram`` only).

``--devices 1,2,4`` sweeps host device counts: the XLA device count is
fixed at first jax init, so each count re-runs the selected modules in a
SUBPROCESS under ``XLA_FLAGS=--xla_force_host_platform_device_count=<n>``.
With > 1 device the llm_e2e scenario engines build a serving mesh
(``repro.launch.mesh.make_serving_mesh``) and run the sharded fused step
(docs/sharded_serving.md); every ``--json`` record and row is stamped with
``devices=<n>``, so single-vs-mesh throughput is attributable per count —
the paper-style scale-out comparison for the serving stack.  ``--devices``
composes with the other sweep flags (they are forwarded to each
subprocess).

| module                 | paper figure/table |
|------------------------|--------------------|
| gemm_roofline          | Fig 4, 5, 7        |
| stream                 | Fig 8 / Alg 1      |
| gather_scatter         | Fig 9              |
| collectives            | Fig 10             |
| embedding_tables       | Fig 15 (S4.1)      |
| paged_attention_bench  | Fig 17 a-c (S4.2)  |
| recsys_e2e             | Fig 11 / Table 3   |
| llm_e2e                | Fig 12, 17 d-e     |
| saturation             | S4.2 pipeline      |
| disagg                 | S4.2 disaggregation|
| trace_replay           | S5 trace replay / SLO sweep (docs/perf_gate.md) |

Every ``--json`` result carries provenance: ``schema_version`` (bumped on
incompatible row-grammar changes — ``repro.perf.gate`` refuses to diff a
mismatch), a best-effort ``git_commit``, and per-row ``seed`` where the
module's workload is RNG-generated.
"""
from __future__ import annotations

import argparse
import itertools
import json
import os
import subprocess
import sys
import tempfile
import time
import traceback

from benchmarks import common
from repro.core import dispatch
from repro.serving import policy as policy_lib
from repro.serving import spec as spec_lib

MODULES = [
    "gemm_roofline",
    "stream",
    "gather_scatter",
    "collectives",
    "embedding_tables",
    "paged_attention_bench",
    "recsys_e2e",
    "llm_e2e",
    "saturation",
    "disagg",
    "trace_replay",
]

# Modules that build serving engines — the only ones whose numbers can
# depend on the serving-policy triple. A --policy sweep re-runs just these
# per triple; everything else runs once (under the first triple's scope).
# trace_replay is deliberately NOT here: it sweeps policy triples itself
# with explicit ctor args (which outrank any force_policies scope), so an
# outer --policy pass cannot change its numbers.
POLICY_SENSITIVE = {"llm_e2e", "saturation", "disagg"}
# Likewise for the speculative-decoding proposer (--spec sweep).
SPEC_SENSITIVE = {"llm_e2e"}


def _parse_spec_names(arg):
    """``off,ngram,draft-model`` -> canonical proposer names (validated).

    Aliases (``draft``) normalize here so pass labels, the smoke skip and
    per-row attribution all agree on one spelling."""
    out = []
    for name in arg.split(","):
        name = name.strip()
        if name != spec_lib.OFF:
            try:
                name = spec_lib.get(name).name
            except spec_lib.UnknownProposerError as e:
                raise SystemExit(f"--spec: {e}") from None
        out.append(name)
    return out


def _parse_policy_triples(arg):
    """``adm/pre/evi,adm/pre/evi`` -> list of per-axis override dicts.

    Names are validated here so a typo fails as one usage error before the
    sweep starts, not as a traceback per module."""
    triples = []
    for spec in arg.split(","):
        parts = spec.split("/")
        if len(parts) != 3:
            raise SystemExit(
                f"--policy: expected admission/preemption/eviction, "
                f"got {spec!r}")
        triple = {}
        for axis, name in zip(policy_lib.AXES, parts):
            if name:
                try:
                    policy_lib.get(axis, name)
                except policy_lib.UnknownPolicyError as e:
                    raise SystemExit(f"--policy: {e}") from None
            triple[axis] = name or None
        triples.append(triple)
    return triples


def _resolved_triple(plog):
    """Attribute one policy triple to a pass from its resolution log."""
    by_axis = {}
    for axis, name in plog:
        by_axis.setdefault(axis, set()).add(name)
    return "/".join(
        "/".join(sorted(by_axis[a])) if a in by_axis else policy_lib.DEFAULTS[a]
        for a in policy_lib.AXES)


def _sweep_devices(args) -> int:
    """Re-run the selected modules once per host device count.

    The XLA host-platform device count is frozen at first jax init, so each
    count gets its own subprocess with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=<n>`` — the child is
    this very module minus ``--devices``/``--json``, plus a temp ``--json``
    whose records the parent merges with a ``devices`` stamp on every
    record and row.
    """
    counts = []
    for c in args.devices.split(","):
        try:
            counts.append(int(c))
        except ValueError:
            raise SystemExit(f"--devices: not a device count: {c!r}")
        if counts[-1] < 1:
            raise SystemExit(f"--devices: device counts are >= 1: {c!r}")
    child_args, skip = [], False
    for a in sys.argv[1:]:
        if skip:
            skip = False
            continue
        if a in ("--devices", "--json"):
            skip = True
            continue
        if a.startswith(("--devices=", "--json=")):
            continue
        child_args.append(a)
    merged, failures = [], 0
    for n in counts:
        print(f"# devices sweep: {n}", file=sys.stderr)
        env = dict(os.environ)
        # APPEND the forced count: XLA flag parsing is last-occurrence-wins,
        # so a pre-existing --xla_force_host_platform_device_count in the
        # user's XLA_FLAGS must not silently override the sweep.
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + f" --xla_force_host_platform_device_count={n}"
                            ).strip()
        # Engine-building modules (llm_e2e) opt into a serving mesh ONLY on
        # this explicit signal — ambient multi-device hosts keep running the
        # single-device engine so --backend sweeps stay comparable.
        env["REPRO_BENCH_DEVICES"] = str(n)
        fd, tmp = tempfile.mkstemp(suffix=".json", prefix="bench_devices_")
        os.close(fd)
        try:
            r = subprocess.run(
                [sys.executable, "-m", "benchmarks.run", *child_args,
                 "--json", tmp], env=env)
            failures += r.returncode != 0
            try:
                with open(tmp) as f:
                    results = json.load(f)
            except (OSError, json.JSONDecodeError):
                results = []
            for res in results:
                res["devices"] = n
                for row in res["rows"]:
                    row["devices"] = n
            merged.extend(results)
        finally:
            os.unlink(tmp)
        print(f"# devices={n} done", file=sys.stderr)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(merged, f, indent=2)
        print(f"# wrote {args.json}", file=sys.stderr)
    return 1 if failures else 0


def main() -> None:
    # allow_abbrev=False: _sweep_devices re-invokes this module with
    # --devices/--json stripped from sys.argv BY EXACT SPELLING — an
    # abbreviated `--device` would survive the strip, re-trigger the sweep
    # in every child and fork forever.
    p = argparse.ArgumentParser(allow_abbrev=False)
    p.add_argument("--only", default=None, help="comma-separated module list")
    p.add_argument("--full", action="store_true")
    p.add_argument("--backend", default=None,
                   help="comma-separated backend sweep (e.g. "
                        "ref,xla,pallas_interpret); each backend scopes the "
                        "whole run via repro.core.dispatch.force_backend")
    p.add_argument("--policy", default=None,
                   help="comma-separated serving-policy triples "
                        "admission/preemption/eviction (e.g. "
                        "fcfs/latest-arrival/lru,priority/most-blocks/"
                        "hit-rate); each triple scopes the run via "
                        "repro.serving.policy.force_policies")
    p.add_argument("--spec", default=None,
                   help="comma-separated speculative-proposer sweep (e.g. "
                        "off,ngram,draft-model); each name scopes the run "
                        "via repro.serving.spec.force_proposer")
    p.add_argument("--devices", default=None,
                   help="comma-separated host device counts (e.g. 1,2,4); "
                        "each count re-runs the selected modules in a "
                        "subprocess with XLA_FLAGS=--xla_force_host_"
                        "platform_device_count=<n> — multi-device passes "
                        "run the sharded serving engine and every JSON "
                        "row is stamped devices=<n>")
    p.add_argument("--json", default=None,
                   help="write per-backend/per-policy/per-proposer result "
                        "rows (+ resolved (op, backend), (axis, policy) and "
                        "proposer names) to this path")
    args = p.parse_args()
    if args.devices is not None:
        raise SystemExit(_sweep_devices(args))
    mods = args.only.split(",") if args.only else MODULES
    backends = args.backend.split(",") if args.backend else [None]
    policies = (_parse_policy_triples(args.policy) if args.policy
                else [None])
    specs = _parse_spec_names(args.spec) if args.spec else [None]
    print("name,us_per_call,derived")
    failures = 0
    results = []
    commit = common.git_commit()
    for b in backends:
        if b is not None:
            print(f"# backend sweep: {b}", file=sys.stderr)
        for (pi, pol), (si, spc) in itertools.product(enumerate(policies),
                                                      enumerate(specs)):
            pol_kwargs = {a: (pol or {}).get(a) for a in policy_lib.AXES}
            pol_str = ("/".join(pol_kwargs[a] or policy_lib.DEFAULTS[a]
                                for a in policy_lib.AXES)
                       if pol is not None else None)
            if pol_str is not None:
                print(f"# policy sweep: {pol_str}", file=sys.stderr)
            if spc is not None:
                print(f"# spec sweep: {spc}", file=sys.stderr)
            for m in mods:
                if pol is not None and pi > 0 and m not in POLICY_SENSITIVE:
                    continue               # policy-blind: one pass is enough
                if spc is not None and si > 0 and m not in SPEC_SENSITIVE:
                    continue               # proposer-blind: ditto
                mod = __import__(f"benchmarks.{m}", fromlist=["run"])
                t0 = time.time()
                common.RECORDS.clear()
                log, plog, slog = [], [], []
                try:
                    with dispatch.force_backend(b), \
                            dispatch.record_resolutions() as log, \
                            policy_lib.force_policies(**pol_kwargs), \
                            policy_lib.record_resolutions() as plog, \
                            spec_lib.force_proposer(spc), \
                            spec_lib.record_resolutions() as slog:
                        mod.run(quick=not args.full)
                except Exception:
                    traceback.print_exc()
                    failures += 1
                resolved_pol = _resolved_triple(plog) if plog else None
                resolved_spec = (sorted(set(slog))[0]
                                 if len(set(slog)) == 1 else None)
                # sanitize attribution: REPRO_SANITIZE=1 rows ran under the
                # runtime guards (retrace/host-sync/allocator) — stamped per
                # row like policy/spec so guarded and unguarded sweeps are
                # distinguishable in one JSON
                sanitized = os.environ.get("REPRO_SANITIZE") == "1"
                results.append({
                    "module": m,
                    "schema_version": common.SCHEMA_VERSION,
                    "git_commit": commit,
                    "requested_backend": b or "auto",
                    "requested_policy": pol_str or "default",
                    "requested_spec": spc or "default",
                    "sanitize": sanitized,
                    "resolved": sorted({f"{op}={bk}" for op, bk in log}),
                    "resolved_policies": sorted(
                        {f"{ax}={nm}" for ax, nm in plog}),
                    "resolved_spec": sorted(set(slog)),
                    "rows": [dict(r) for r in common.RECORDS],
                })
                for r in results[-1]["rows"]:
                    # setdefault: rows that self-attribute via emit(**attrs)
                    # (trace_replay's internal policy sweep) keep their own
                    # per-row triple over the pass-level rollup.
                    if resolved_pol:
                        r.setdefault("policy", resolved_pol)
                    if resolved_spec:
                        r.setdefault("spec", resolved_spec)
                    r["sanitize"] = sanitized
                print(f"# {m} done in {time.time()-t0:.1f}s"
                      + (f" [backend={b}]" if b else "")
                      + (f" [policy={pol_str}]" if pol_str else "")
                      + (f" [spec={spc}]" if spc else ""),
                      file=sys.stderr)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2)
        print(f"# wrote {args.json}", file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
