"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. ``--full`` widens sweeps.

| module                 | paper figure/table |
|------------------------|--------------------|
| gemm_roofline          | Fig 4, 5, 7        |
| stream                 | Fig 8 / Alg 1      |
| gather_scatter         | Fig 9              |
| collectives            | Fig 10             |
| embedding_tables       | Fig 15 (S4.1)      |
| paged_attention_bench  | Fig 17 a-c (S4.2)  |
| recsys_e2e             | Fig 11 / Table 3   |
| llm_e2e                | Fig 12, 17 d-e     |
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

MODULES = [
    "gemm_roofline",
    "stream",
    "gather_scatter",
    "collectives",
    "embedding_tables",
    "paged_attention_bench",
    "recsys_e2e",
    "llm_e2e",
]


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--only", default=None, help="comma-separated module list")
    p.add_argument("--full", action="store_true")
    args = p.parse_args()
    mods = args.only.split(",") if args.only else MODULES
    print("name,us_per_call,derived")
    failures = 0
    for m in mods:
        mod = __import__(f"benchmarks.{m}", fromlist=["run"])
        t0 = time.time()
        try:
            mod.run(quick=not args.full)
        except Exception:
            traceback.print_exc()
            failures += 1
        print(f"# {m} done in {time.time()-t0:.1f}s", file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
