"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. ``--full`` widens sweeps.

``--backend a,b,...`` repeats the run once per backend name, with each pass
scoped under ``dispatch.force_backend`` so every registry-dispatched op
(kernels AND the serving engine) follows the preference; ``--json PATH``
then writes the per-backend rows plus the ``(op, backend)`` pairs that
actually resolved — the paper-style microbenchmark comparison across
software stacks, attributable to the implementation that really ran
(an unsupported preference degrades to capability-ranked auto).

| module                 | paper figure/table |
|------------------------|--------------------|
| gemm_roofline          | Fig 4, 5, 7        |
| stream                 | Fig 8 / Alg 1      |
| gather_scatter         | Fig 9              |
| collectives            | Fig 10             |
| embedding_tables       | Fig 15 (S4.1)      |
| paged_attention_bench  | Fig 17 a-c (S4.2)  |
| recsys_e2e             | Fig 11 / Table 3   |
| llm_e2e                | Fig 12, 17 d-e     |
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback

from benchmarks import common
from repro.core import dispatch

MODULES = [
    "gemm_roofline",
    "stream",
    "gather_scatter",
    "collectives",
    "embedding_tables",
    "paged_attention_bench",
    "recsys_e2e",
    "llm_e2e",
]


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--only", default=None, help="comma-separated module list")
    p.add_argument("--full", action="store_true")
    p.add_argument("--backend", default=None,
                   help="comma-separated backend sweep (e.g. "
                        "ref,xla,pallas_interpret); each backend scopes the "
                        "whole run via repro.core.dispatch.force_backend")
    p.add_argument("--json", default=None,
                   help="write per-backend result rows (+ resolved (op, "
                        "backend) pairs) to this path")
    args = p.parse_args()
    mods = args.only.split(",") if args.only else MODULES
    backends = args.backend.split(",") if args.backend else [None]
    print("name,us_per_call,derived")
    failures = 0
    results = []
    for b in backends:
        if b is not None:
            print(f"# backend sweep: {b}", file=sys.stderr)
        for m in mods:
            mod = __import__(f"benchmarks.{m}", fromlist=["run"])
            t0 = time.time()
            common.RECORDS.clear()
            log = []
            try:
                with dispatch.force_backend(b), \
                        dispatch.record_resolutions() as log:
                    mod.run(quick=not args.full)
            except Exception:
                traceback.print_exc()
                failures += 1
            results.append({
                "module": m,
                "requested_backend": b or "auto",
                "resolved": sorted({f"{op}={bk}" for op, bk in log}),
                "rows": list(common.RECORDS),
            })
            print(f"# {m} done in {time.time()-t0:.1f}s"
                  + (f" [backend={b}]" if b else ""), file=sys.stderr)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2)
        print(f"# wrote {args.json}", file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
