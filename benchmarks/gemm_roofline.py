"""Paper Fig 4/5/7: GEMM roofline sweep (square + irregular shapes).

On-CPU wall time is reported for harness completeness; the graded quantity
is the derived TPU roofline prediction: achievable TFLOPS
= min(peak, AI × HBM_bw) with MXU tile-padding utilization — the TPU
analogue of the paper's MME-geometry/utilization study (Gaudi's
reconfigurable MME has no TPU counterpart; the fixed 128×128 MXU shows
shape-mismatch waste as tile padding, reported as `util`)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.roofline.analysis import HW

_HW = HW()
MXU = 128


def _pad(x: int, m: int = MXU) -> int:
    return -(-x // m) * m


def run(quick: bool = True) -> None:
    squares = [256, 512, 1024] if quick else [256, 512, 1024, 2048, 4096, 8192]
    irregular = [(2048, 2048, 16), (4096, 4096, 16)]
    shapes = [(s, s, s) for s in squares] + irregular
    key = jax.random.PRNGKey(0)
    f = jax.jit(lambda a, b: a @ b)
    for (M, K, N) in shapes:
        a = jax.random.normal(key, (M, K), jnp.bfloat16)
        b = jax.random.normal(key, (K, N), jnp.bfloat16)
        us = time_fn(f, a, b)
        flops = 2.0 * M * K * N
        byts = 2.0 * (M * K + K * N + M * N)
        ai = flops / byts
        peak_t = flops / _HW.peak_bf16
        mem_t = byts / _HW.hbm_bw
        t = max(peak_t, mem_t)
        achieved_tflops = flops / t / 1e12
        # MXU tile padding utilization (geometry-mismatch waste)
        util = (M * K * N) / (_pad(M) * _pad(K) * _pad(N))
        bound = "compute" if peak_t >= mem_t else "memory"
        emit(f"gemm_{M}x{K}x{N}", us,
             f"tpu_tflops={achieved_tflops:.1f};util={util:.3f};bound={bound}")
