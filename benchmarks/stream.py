"""Paper Fig 8 / Alg 1: STREAM ADD/SCALE/TRIAD with tile-granularity sweep.

Backend selection goes through the unified registry: auto resolves to the
jnp form on CPU and the compiled Pallas kernel on TPU; run the harness with
``--backend pallas_interpret`` (or ``pallas`` on TPU) to trace the kernel's
granularity curve explicitly. The sweep (block_rows = the BlockSpec tile
height) is the TPU analogue of the paper's data-access-granularity sweep:
tiny tiles underfill the HBM→VMEM DMA pipeline exactly like sub-256 B
accesses on Gaudi. Derived: roofline bytes/s at each granularity from the
DMA-efficiency model eff = rows/(rows+latency rows), and the
operational-intensity saturation study (Fig 8 d/e/f)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.kernels.stream.ops import stream_add, stream_scale, stream_triad
from repro.roofline.analysis import HW

_HW = HW()


def run(quick: bool = True) -> None:
    n = 128 * 1024 if quick else 128 * 16384
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (n,), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (n,), jnp.float32)

    # block-granularity sweep (the "access granularity" analogue)
    for rows in ([8, 64, 256] if quick else [8, 16, 64, 256, 1024]):
        us = time_fn(stream_add, a, b, rows)
        # DMA pipeline model: fixed ~1 tile latency per grid step
        eff = rows / (rows + 8)
        bw = _HW.hbm_bw * eff
        emit(f"stream_add_rows{rows}", us, f"tpu_gbs={bw/1e9:.0f};eff={eff:.2f}")

    for name, fn, args, traffic, flops in [
        ("stream_add", stream_add, (a, b), 3 * 4 * n, n),
        ("stream_scale", stream_scale, (a, 3.0), 2 * 4 * n, n),
        ("stream_triad", stream_triad, (a, b, 3.0), 3 * 4 * n, 2 * n),
    ]:
        us = time_fn(fn, *args)
        ai = flops / traffic
        t = max(flops / _HW.peak_bf16, traffic / _HW.hbm_bw)
        emit(name, us, f"ai={ai:.3f};tpu_gflops={flops/t/1e9:.0f};bound=memory")

    # operational-intensity saturation (Fig 8 d/e/f): repeat the compute k×
    for k in [1, 8, 64, 512]:
        flops, traffic = 2 * n * k, 3 * 4 * n
        t = max(flops / _HW.peak_bf16, traffic / _HW.hbm_bw)
        sat = (flops / t) / _HW.peak_bf16
        emit(f"stream_triad_oi{k}", 0.0,
             f"tpu_util={sat:.3f};ai={flops/traffic:.1f}")
