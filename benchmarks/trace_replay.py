"""Trace-replay SLO sweep: the perf table behind `--policy auto` and the gate.

Replays the pinned synthetic traces (repro.perf.trace — bursty /
shared-prefix / long-tail / mixed, fixed seeds and sizes) through the serving
engine under a sweep of configurations: fixed policy triples, the
``predicted-length`` cost-model admission, a speculative (ngram) pass, an
overlapped-loop pass, and finally the ``auto`` triple resolved from the table
built *in this run* from the fixed-triple rows.  Every row's ``derived``
string is a full (scenario, config) attribution cell — the policy triple,
spec/overlap flags, the SLO verdict, and the deterministic replay counters
(steps, p99 TTFT/TPOT in steps, tokens/step, prefix hits, preemptions) that
``repro.perf.gate`` diffs in CI.  Wall time is emitted but never gated.

Traces and configs are identical under ``REPRO_BENCH_SMOKE=1`` — smoke only
restricts which *scenarios* run (the mixed trace) — so smoke rows are
bit-comparable against the committed quick-mode ``BENCH_009.json``.

Asserted perf, not printed perf: the module itself asserts that the ``auto``
row meets-or-beats every fixed triple's objective on each scenario (it runs
the measured winner, so equality is the floor), and that auto resolution was
counted in ``policy_counters``.
"""
from __future__ import annotations

import os
import time

import jax

from benchmarks.common import emit
from repro.config import ServeConfig, get_config
from repro.perf.replay import Slo, replay, score
from repro.perf.table import AXES, PerfTable, perf_context
from repro.perf.trace import LengthModel, generate
from repro.serving.engine import ServingEngine

# Pinned scenarios: trace parameters, pool sizing (deliberately starved so
# policies differentiate), and the p99 SLO targets in virtual seconds.
# Changing ANY value here invalidates the committed BENCH_009.json baseline —
# regenerate it in the same change (docs/perf_gate.md).
SCENARIOS = {
    "bursty": dict(seed=101, n_requests=12, slo=Slo(ttft_s=1.5, tpot_s=0.3)),
    "shared-prefix": dict(seed=202, n_requests=12,
                          slo=Slo(ttft_s=1.5, tpot_s=0.3)),
    "long-tail": dict(seed=303, n_requests=12,
                      slo=Slo(ttft_s=1.5, tpot_s=0.35)),
    "mixed": dict(seed=404, n_requests=12, slo=Slo(ttft_s=1.5, tpot_s=0.3)),
}
TRACE_KWARGS = dict(prompt_hi=16, gen_cap=14)
NUM_BLOCKS = 10
MAX_BATCH = 3
KV_BLOCK_SIZE = 8

# (label, admission/preemption/eviction, spec, overlap).  The auto row runs
# last against the table built from the fixed rows above it.
CONFIGS = [
    ("fcfs", ("fcfs", "latest-arrival", "lru"), "off", False),
    ("prio", ("priority", "fewest-remaining-tokens", "hit-rate"),
     "off", False),
    ("edf", ("deadline-slo", "most-blocks", "refcount-aware"), "off", False),
    ("plen", ("predicted-length", "latest-arrival", "lru"), "off", False),
    ("ngram", ("fcfs", "latest-arrival", "lru"), "ngram", False),
    ("overlap", ("fcfs", "latest-arrival", "lru"), "off", True),
    ("auto", ("auto", "auto", "auto"), "off", False),
]


def _run_one(model, params, cfg, scenario, trace, slo, triple, spec_name,
             overlap, *, table, length_model):
    serve = ServeConfig(model=cfg.name, kv_block_size=KV_BLOCK_SIZE,
                        max_batch=MAX_BATCH, spec=spec_name, spec_k=3,
                        overlap=overlap)
    adm, pre, evi = triple
    with perf_context(scenario=scenario, table=table,
                      length_model=length_model):
        eng = ServingEngine(model, params, cfg, serve, num_blocks=NUM_BLOCKS,
                            admission=adm, preemption=pre, eviction=evi)
    t0 = time.time()
    result = replay(eng, trace)
    dt = time.time() - t0
    report = score(result, slo)
    return eng, result, report, dt


def _row(scenario, label, trace, triple, spec_name, overlap, result, report):
    adm, pre, evi = triple
    c = result.counters()
    period = trace.step_period
    derived = (
        f"scenario={scenario};admission={adm};preemption={pre};"
        f"eviction={evi};spec={spec_name};"
        f"overlap={'on' if overlap else 'off'};"
        f"slo_ok={1 if report.ok else 0};"
        f"p99_ttft_steps={c['p99_ttft_steps']};"
        f"p99_tpot_steps={c['p99_tpot_steps']};"
        f"p99_ttft_vs={c['p99_ttft_steps'] * period:.3f};"
        f"p99_tpot_vs={c['p99_tpot_steps'] * period:.4f};"
        f"att_ttft={report.attainment_ttft};"
        f"att_tpot={report.attainment_tpot};"
        f"steps={c['steps']};finished={c['finished']};"
        f"out_tokens={c['out_tokens']};tok_per_step={c['tok_per_step']};"
        f"prefix_hits={c['prefix_hits']};preempt={c['preempt']};"
        f"idle_ff={c['idle_ff']}")
    return f"trace_{scenario}_{label}", derived


def run(quick: bool = True) -> None:
    smoke = bool(os.environ.get("REPRO_BENCH_SMOKE"))
    from repro.models.api import build_model
    cfg = get_config("smollm-360m").reduced(dtype="float32")
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))

    scenarios = ["mixed"] if smoke else list(SCENARIOS)
    for scenario in scenarios:
        params_s = SCENARIOS[scenario]
        trace = generate(scenario, seed=params_s["seed"],
                         n_requests=params_s["n_requests"],
                         vocab_size=cfg.vocab_size, **TRACE_KWARGS)
        slo = params_s["slo"]
        length_model = LengthModel.fit(trace)
        fixed_rows = []
        for label, triple, spec_name, overlap in CONFIGS:
            if label == "auto":
                continue
            eng, result, report, dt = _run_one(
                model, params, cfg, scenario, trace, slo, triple, spec_name,
                overlap, table=None, length_model=length_model)
            name, derived = _row(scenario, label, trace, triple, spec_name,
                                 overlap, result, report)
            emit(name, dt * 1e6, derived, seed=trace.seed,
                 policy="/".join(triple))
            fixed_rows.append(dict([kv.split("=", 1)
                                    for kv in derived.split(";")],
                                   name=name))

        # Consumption pass: `auto` resolves the per-scenario winner from the
        # table just measured (the same resolution path the committed
        # BENCH_009.json feeds at launch time).
        table = PerfTable(fixed_rows)
        winner = table.winner(scenario)
        label, triple, spec_name, overlap = CONFIGS[-1]
        eng, result, report, dt = _run_one(
            model, params, cfg, scenario, trace, slo, triple, spec_name,
            overlap, table=table, length_model=length_model)
        counters = eng.metrics()["policy_counters"]
        resolved = "/".join(winner[a] for a in AXES)
        name, derived = _row(scenario, label, trace, triple, spec_name,
                             overlap, result, report)
        derived += f";resolved={resolved}"
        emit(name, dt * 1e6, derived, seed=trace.seed,
             policy="/".join(triple))

        # Asserted perf: auto ran the measured winner, so its objective can
        # never be worse than the best fixed triple — and resolution (not
        # fallback) must have been counted on every axis.
        for axis in AXES:
            assert counters.get(f"{axis}.auto_resolved", 0) >= 1, (
                scenario, axis, counters)
        auto_row = dict([kv.split("=", 1) for kv in derived.split(";")])
        auto_obj = PerfTable.objective(auto_row)
        best_fixed = table.best_objective(scenario)
        assert auto_obj[:4] <= best_fixed[:4], (
            f"{scenario}: auto {auto_obj} worse than best fixed "
            f"{best_fixed}")
