"""Trace-replay SLO sweep: the perf table behind `--policy auto` and the gate.

Replays the pinned synthetic traces (repro.perf.trace — bursty /
shared-prefix / long-tail / mixed, fixed seeds and sizes) through the serving
engine under a sweep of configurations: fixed policy triples, the
``predicted-length`` cost-model admission, a speculative (ngram) pass, an
overlapped-loop pass, a 2-device sharded-engine pass (the ``devices`` axis —
skipped with a note when the host has one device; its counters are asserted
bit-identical to the single-device fcfs twin), and finally the ``auto``
triple resolved from the table built *in this run* from the fixed-triple
rows.  Every row's ``derived``
string is a full (scenario, config) attribution cell — the policy triple,
spec/overlap flags, the SLO verdict, and the deterministic replay counters
(steps, p99 TTFT/TPOT in steps, tokens/step, prefix hits, preemptions) that
``repro.perf.gate`` diffs in CI.  Wall time is emitted but never gated.

Traces and configs are identical under ``REPRO_BENCH_SMOKE=1`` — smoke only
restricts which *scenarios* run (the mixed trace) — so smoke rows are
bit-comparable against the committed quick-mode ``BENCH_009.json``.

Asserted perf, not printed perf: the module itself asserts that the ``auto``
row meets-or-beats every fixed triple's objective on each scenario (it runs
the measured winner, so equality is the floor), and that auto resolution was
counted in ``policy_counters``.
"""
from __future__ import annotations

import os
import time

import jax

from benchmarks.common import emit
from repro.config import ServeConfig, get_config
from repro.perf.replay import Slo, replay, score
from repro.perf.table import AXES, PerfTable, perf_context
from repro.perf.trace import LengthModel, generate
from repro.serving.engine import ServingEngine

# Pinned scenarios: trace parameters, pool sizing (deliberately starved so
# policies differentiate), and the p99 SLO targets in virtual seconds.
# Changing ANY value here invalidates the committed BENCH_009.json baseline —
# regenerate it in the same change (docs/perf_gate.md).
SCENARIOS = {
    "bursty": dict(seed=101, n_requests=12, slo=Slo(ttft_s=1.5, tpot_s=0.3)),
    "shared-prefix": dict(seed=202, n_requests=12,
                          slo=Slo(ttft_s=1.5, tpot_s=0.3)),
    "long-tail": dict(seed=303, n_requests=12,
                      slo=Slo(ttft_s=1.5, tpot_s=0.35)),
    "mixed": dict(seed=404, n_requests=12, slo=Slo(ttft_s=1.5, tpot_s=0.3)),
}
TRACE_KWARGS = dict(prompt_hi=16, gen_cap=14)
NUM_BLOCKS = 10
MAX_BATCH = 3
KV_BLOCK_SIZE = 8

# (label, admission/preemption/eviction, spec, overlap, devices).  The dev2
# row runs the sharded engine on a 2-device host mesh (skipped with a note
# when the host can't supply it — its counters must be bit-identical to the
# fcfs row, so it never changes winner resolution and is excluded from
# comparable_rows by its devices axis).  The auto row runs last against the
# table built from the fixed rows above it.
CONFIGS = [
    ("fcfs", ("fcfs", "latest-arrival", "lru"), "off", False, 1),
    ("prio", ("priority", "fewest-remaining-tokens", "hit-rate"),
     "off", False, 1),
    ("edf", ("deadline-slo", "most-blocks", "refcount-aware"), "off", False,
     1),
    ("plen", ("predicted-length", "latest-arrival", "lru"), "off", False, 1),
    ("ngram", ("fcfs", "latest-arrival", "lru"), "ngram", False, 1),
    ("overlap", ("fcfs", "latest-arrival", "lru"), "off", True, 1),
    ("dev2", ("fcfs", "latest-arrival", "lru"), "off", False, 2),
    ("auto", ("auto", "auto", "auto"), "off", False, 1),
]

# Replay counters that must be BIT-identical between the dev2 row and its
# single-device fcfs twin (same triple, same trace — the sharded engine's
# greedy streams are bit-identical, so its deterministic counters are too).
PARITY_KEYS = ("steps", "finished", "out_tokens", "tok_per_step",
               "prefix_hits", "preempt", "p99_ttft_steps", "p99_tpot_steps")


def _run_one(model, params, cfg, scenario, trace, slo, triple, spec_name,
             overlap, devices, *, table, length_model):
    serve = ServeConfig(model=cfg.name, kv_block_size=KV_BLOCK_SIZE,
                        max_batch=MAX_BATCH, spec=spec_name, spec_k=3,
                        overlap=overlap,
                        devices=devices if devices > 1 else 0)
    adm, pre, evi = triple
    with perf_context(scenario=scenario, table=table,
                      length_model=length_model):
        eng = ServingEngine(model, params, cfg, serve, num_blocks=NUM_BLOCKS,
                            admission=adm, preemption=pre, eviction=evi)
    t0 = time.time()
    result = replay(eng, trace)
    dt = time.time() - t0
    report = score(result, slo)
    return eng, result, report, dt


def _row(scenario, label, trace, triple, spec_name, overlap, devices, result,
         report):
    adm, pre, evi = triple
    c = result.counters()
    period = trace.step_period
    derived = (
        f"scenario={scenario};admission={adm};preemption={pre};"
        f"eviction={evi};spec={spec_name};"
        f"overlap={'on' if overlap else 'off'};"
        f"devices={devices};"
        f"slo_ok={1 if report.ok else 0};"
        f"p99_ttft_steps={c['p99_ttft_steps']};"
        f"p99_tpot_steps={c['p99_tpot_steps']};"
        f"p99_ttft_vs={c['p99_ttft_steps'] * period:.3f};"
        f"p99_tpot_vs={c['p99_tpot_steps'] * period:.4f};"
        f"att_ttft={report.attainment_ttft};"
        f"att_tpot={report.attainment_tpot};"
        f"steps={c['steps']};finished={c['finished']};"
        f"out_tokens={c['out_tokens']};tok_per_step={c['tok_per_step']};"
        f"prefix_hits={c['prefix_hits']};preempt={c['preempt']};"
        f"idle_ff={c['idle_ff']}")
    return f"trace_{scenario}_{label}", derived


def run(quick: bool = True) -> None:
    smoke = bool(os.environ.get("REPRO_BENCH_SMOKE"))
    from repro.models.api import build_model
    cfg = get_config("smollm-360m").reduced(dtype="float32")
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))

    scenarios = ["mixed"] if smoke else list(SCENARIOS)
    for scenario in scenarios:
        params_s = SCENARIOS[scenario]
        trace = generate(scenario, seed=params_s["seed"],
                         n_requests=params_s["n_requests"],
                         vocab_size=cfg.vocab_size, **TRACE_KWARGS)
        slo = params_s["slo"]
        length_model = LengthModel.fit(trace)
        fixed_rows = []
        by_label = {}
        for label, triple, spec_name, overlap, devices in CONFIGS:
            if label == "auto":
                continue
            if devices > len(jax.devices()):
                print(f"[trace_replay] {scenario}/{label}: skipped — needs "
                      f"{devices} devices, host has {len(jax.devices())} "
                      "(run under XLA_FLAGS="
                      "--xla_force_host_platform_device_count="
                      f"{devices})")
                continue
            eng, result, report, dt = _run_one(
                model, params, cfg, scenario, trace, slo, triple, spec_name,
                overlap, devices, table=None, length_model=length_model)
            name, derived = _row(scenario, label, trace, triple, spec_name,
                                 overlap, devices, result, report)
            emit(name, dt * 1e6, derived, seed=trace.seed,
                 policy="/".join(triple))
            row = dict([kv.split("=", 1) for kv in derived.split(";")],
                       name=name)
            fixed_rows.append(row)
            by_label[label] = row

        # Asserted parity: the sharded engine's greedy streams are
        # bit-identical to single-device, so the dev2 row's deterministic
        # counters must equal its fcfs twin exactly.
        if "dev2" in by_label:
            for k in PARITY_KEYS:
                assert by_label["dev2"][k] == by_label["fcfs"][k], (
                    scenario, k, by_label["dev2"][k], by_label["fcfs"][k])

        # Consumption pass: `auto` resolves the per-scenario winner from the
        # table just measured (the same resolution path the committed
        # BENCH_009.json feeds at launch time).
        table = PerfTable(fixed_rows)
        winner = table.winner(scenario)
        label, triple, spec_name, overlap, devices = CONFIGS[-1]
        eng, result, report, dt = _run_one(
            model, params, cfg, scenario, trace, slo, triple, spec_name,
            overlap, devices, table=table, length_model=length_model)
        counters = eng.metrics()["policy_counters"]
        resolved = "/".join(winner[a] for a in AXES)
        name, derived = _row(scenario, label, trace, triple, spec_name,
                             overlap, devices, result, report)
        derived += f";resolved={resolved}"
        emit(name, dt * 1e6, derived, seed=trace.seed,
             policy="/".join(triple))

        # Asserted perf: auto ran the measured winner, so its objective can
        # never be worse than the best fixed triple — and resolution (not
        # fallback) must have been counted on every axis.
        for axis in AXES:
            assert counters.get(f"{axis}.auto_resolved", 0) >= 1, (
                scenario, axis, counters)
        auto_row = dict([kv.split("=", 1) for kv in derived.split(";")])
        auto_obj = PerfTable.objective(auto_row)
        best_fixed = table.best_objective(scenario)
        assert auto_obj[:4] <= best_fixed[:4], (
            f"{scenario}: auto {auto_obj} worse than best fixed "
            f"{best_fixed}")
