"""Shared benchmark utilities: timing + CSV emission + TPU roofline model."""
from __future__ import annotations

import time
from typing import Callable, Dict, List

import jax

from repro.roofline.analysis import HW

_HW = HW()

# Rows emitted since the last clear — the harness (benchmarks/run.py) drains
# this to build per-backend JSON for its --backend sweep.
RECORDS: List[Dict[str, object]] = []


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time per call in microseconds (CPU measurement)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def tpu_time_model(flops: float, bytes_moved: float) -> float:
    """Roofline-predicted TPU time (s): max(compute, memory) terms."""
    return max(flops / _HW.peak_bf16, bytes_moved / _HW.hbm_bw)


def emit(name: str, us: float, derived: str) -> None:
    RECORDS.append({"name": name, "us_per_call": us, "derived": derived})
    print(f"{name},{us:.1f},{derived}")
