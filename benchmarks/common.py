"""Shared benchmark utilities: timing + CSV emission + TPU roofline model."""
from __future__ import annotations

import os
import subprocess
import time
from typing import Callable, Dict, List

import jax

# The one schema constant: repro.perf.gate refuses to diff result files whose
# trace-replay results don't carry exactly this version (docs/perf_gate.md).
from repro.perf.table import SCHEMA_VERSION  # noqa: F401  (re-export)
from repro.roofline.analysis import HW

_HW = HW()


def git_commit() -> str:
    """Best-effort short commit hash of the repo checkout ("unknown" if any
    part fails — benchmarks must run from a tarball too)."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    try:
        out = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             cwd=repo, capture_output=True, text=True,
                             timeout=10)
        commit = out.stdout.strip()
        return commit if out.returncode == 0 and commit else "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"

# Rows emitted since the last clear — the harness (benchmarks/run.py) drains
# this to build per-backend JSON for its --backend sweep.
RECORDS: List[Dict[str, object]] = []


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time per call in microseconds (CPU measurement)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def tpu_time_model(flops: float, bytes_moved: float) -> float:
    """Roofline-predicted TPU time (s): max(compute, memory) terms."""
    return max(flops / _HW.peak_bf16, bytes_moved / _HW.hbm_bw)


def emit(name: str, us: float, derived: str, **attrs: object) -> None:
    """Record one benchmark row.

    ``attrs`` become extra row keys (e.g. ``seed=`` — the RNG key that
    generated the row's workload, part of the provenance satellite; or a
    row-level ``policy=`` that the harness will NOT overwrite with its
    pass-level attribution).
    """
    record: Dict[str, object] = {"name": name, "us_per_call": us,
                                 "derived": derived}
    record.update(attrs)
    RECORDS.append(record)
    print(f"{name},{us:.1f},{derived}")
