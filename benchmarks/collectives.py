"""Paper Fig 10: collective-communication bus bandwidth, 6 primitives ×
device counts × sizes.

Measured: wall time of each collective on the host devices (when >1).
Derived: the paper's actual finding — bus-bandwidth utilization under
(a) an all-to-all switch (DGX/NVSwitch model: full BW at any device count),
(b) P2P pairwise links (HLS-Gaudi-2 model: BW ∝ (n-1)/(N-1)), and
(c) a TPU 2D-torus ICI (per-chip 4 links; ring algorithms at any n) —
reproducing the Fig 10 trend that P2P bus utilization decays as the group
shrinks while switch/torus stay flat."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn

PRIMS = ("all_reduce", "all_gather", "reduce_scatter", "all_to_all",
         "reduce", "broadcast")


def _bus_factor(prim: str, n: int) -> float:
    """NCCL bus-bandwidth convention: algbw→busbw factor."""
    if prim in ("all_reduce",):
        return 2 * (n - 1) / n
    if prim in ("all_gather", "reduce_scatter", "all_to_all"):
        return (n - 1) / n
    return 1.0


def run(quick: bool = True) -> None:
    devs = jax.devices()
    sizes = [2_048, 1 << 20, 32 << 20] if quick else [
        2_048, 65_536, 1 << 20, 8 << 20, 32 << 20]
    max_n = len(devs)
    for prim in PRIMS:
        for n in [2, 4, 8]:
            for size in sizes:
                # topology models (the paper's Fig 10 argument)
                switch = 1.0                      # NVSwitch: flat
                p2p = (n - 1) / max(8 - 1, 1)     # Gaudi P2P: ∝ links used
                torus = min(1.0, 4 / 4)           # ICI ring: flat (4 links)
                us = 0.0
                if n <= max_n and n > 1:
                    mesh = jax.make_mesh((n,), ("x",),
                                         devices=np.array(devs[:n]))
                    x = jnp.zeros((size // 4,), jnp.float32)
                    sh = jax.sharding.NamedSharding(
                        mesh, jax.sharding.PartitionSpec("x"))
                    f = jax.jit(
                        functools.partial(_collective, prim),
                        in_shardings=sh, out_shardings=None)
                    us = time_fn(f, x, iters=3)
                bf = _bus_factor(prim, n)
                emit(f"coll_{prim}_n{n}_{size}B", us,
                     f"bus_util_switch={switch*bf:.2f};"
                     f"bus_util_p2p={p2p*bf:.2f};bus_util_ici={torus*bf:.2f}")


def _collective(prim: str, x):
    import jax
    from jax.sharding import PartitionSpec as P

    def inner(v):
        if prim == "all_reduce":
            return jax.lax.psum(v, "x")
        if prim == "all_gather":
            return jax.lax.all_gather(v, "x")
        if prim == "reduce_scatter":
            return jax.lax.psum_scatter(v, "x")
        if prim == "all_to_all":
            r = v.reshape(jax.lax.psum(1, "x"), -1)
            return jax.lax.all_to_all(r, "x", 0, 0)
        if prim == "reduce":
            return jax.lax.psum(v, "x")           # reduce ≈ psum on TPU
        return jax.lax.all_gather(v, "x")         # broadcast ≈ gather root
    mesh = jax.sharding.get_abstract_mesh()
    return jax.shard_map(inner, mesh=mesh, in_specs=P("x"),
                         out_specs=P("x"))(x)
