"""Paper Fig 9: GUPS-style random vector gather/scatter, vector-size sweep.

The paper's finding: Gaudi's 256 B minimum access granularity wastes
bandwidth for small vectors (15% util ≤128 B vs A100's 36%). The TPU
analogue: a (1, D) row DMA moves at least one (8,128)-lane tile; derived
`tpu_bw_util` applies exactly that waste model. Wall time uses the jnp path
(XLA gather) — the Pallas kernel is validated in tests."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.kernels.gather_scatter.ref import gather_ref, scatter_ref
from repro.roofline.analysis import HW

_HW = HW()
TILE_BYTES = 128 * 4          # one f32 lane row


def run(quick: bool = True) -> None:
    R = 65_536 if quick else 4_000_000
    N = 8_192 if quick else 1_000_000
    key = jax.random.PRNGKey(0)
    idx = jax.random.randint(key, (N,), 0, R)
    g = jax.jit(gather_ref)
    s = jax.jit(scatter_ref)
    for vec_bytes in [16, 64, 128, 256, 512, 2048]:
        D = max(vec_bytes // 4, 1)
        table = jax.random.normal(key, (R, D), jnp.float32)
        src = jax.random.normal(key, (N, D), jnp.float32)
        us_g = time_fn(g, table, idx)
        us_s = time_fn(s, table, idx, src)
        waste = vec_bytes / (max(-(-vec_bytes // TILE_BYTES), 1) * TILE_BYTES)
        util = 0.85 * waste          # 0.85 = random-access ceiling
        emit(f"gather_{vec_bytes}B", us_g, f"tpu_bw_util={util:.2f}")
        emit(f"scatter_{vec_bytes}B", us_s, f"tpu_bw_util={util:.2f}")
