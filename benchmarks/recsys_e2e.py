"""Paper Fig 11 / Table 3: end-to-end RecSys (RM1 compute-bound, RM2
memory-bound) serving latency + energy model.

Derived: the roofline energy model replaces the paper's hl-smi/nvidia-smi
power rails (documented in DESIGN.md): J = flops·0.3pJ + bytes·60pJ (TPU-
class constants), reported per inference."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.config import get_config
from repro.data.pipeline import SyntheticRecSysDataset
from repro.models.api import build_model

PJ_FLOP = 0.3e-12
PJ_BYTE = 60e-12


def run(quick: bool = True) -> None:
    rows = 10_000 if quick else 1_000_000
    for name in ("rm1", "rm2"):
        cfg = dataclasses.replace(get_config(name), num_embeddings=rows)
        for use_batched in (True, False):
            model = build_model(cfg, use_batched=use_batched)
            params = model.init(jax.random.PRNGKey(0))
            fwd = jax.jit(model.forward)
            for B in ([64] if quick else [16, 64, 256, 1024, 4096]):
                ds = SyntheticRecSysDataset(cfg, B)
                batch = {k: jnp.asarray(v)
                         for k, v in ds.batch_at(0).items()}
                us = time_fn(fwd, params, batch, iters=3)
                c = jax.jit(model.forward).lower(params, batch).compile()
                ca = c.cost_analysis()
                ca = ca[0] if isinstance(ca, list) else ca
                fl, by = ca.get("flops", 0.0), ca.get("bytes accessed", 0.0)
                joules = fl * PJ_FLOP + by * PJ_BYTE
                tag = "batched" if use_batched else "single"
                emit(f"recsys_{name}_{tag}_B{B}", us,
                     f"flops={fl:.3g};bytes={by:.3g};"
                     f"energy_uJ_per_inf={joules/B*1e6:.2f}")
