"""Paper Fig 12 + 17 d/e: end-to-end LLM serving — prefill/decode latency
breakdown across output lengths, plus scenario sweeps through the
scheduler-driven engine (chunked prefill, prefix-cached paged KV,
preemption):

  * ``llm_engine_*``   continuous batching, Dynamic-Sonnet-style variable
    lengths, p50/p99 TTFT/TPOT + tokens/sec;
  * ``llm_burst_*``    bursty arrivals (whole wave at t0) vs trickle;
  * ``llm_prefix_*``   shared-prefix workload — reports the prefix-cache hit
    rate and fresh-block allocations vs independent prompts;
  * ``llm_preempt_*``  memory-pressure preemption (pool sized below the
    working set) — reports preemption count and completion;
  * ``llm_repeat_*``   repetitive-suffix workload (looping prompt motifs +
    greedy decode loops) — the speculative-decoding showcase: the ``ngram``
    proposer reads the repetition and multi-token steps land, reported as
    acceptance rate and output tokens per decode lane.

Every engine row carries the resolved serving-policy triple
(``policies=admission/preemption/eviction``) AND the resolved speculative
proposer (``spec=...;spec_accept=...;tok_per_lane=...``), so
``benchmarks/run.py --policy`` / ``--spec`` sweeps attribute each scenario
to the combination that ran it.  Setting ``REPRO_BENCH_SMOKE=1`` restricts
the run to the four scenario sweeps at minimum sizes — the deterministic
policy/spec-regression smoke that ``tools/ci_fast.sh`` drives — and skips
``draft-model`` passes (k draft forwards per decode step: a slow sweep).
"""
from __future__ import annotations

import os
import time

import jax
import numpy as np

from benchmarks.common import emit, time_fn
from repro.config import ServeConfig, get_config
from repro.models.api import build_model
from repro.serving.engine import Request, ServingEngine

_MESH = None


def _mesh():
    """The scenario engines' mesh: sharded ONLY on explicit opt-in.

    ``benchmarks/run.py --devices N,M`` re-runs this module in subprocesses
    with forced host device counts AND ``REPRO_BENCH_DEVICES=<n>``, so the
    SAME scenarios attribute rows to 1-device and mesh runs (``devices=``
    in the derived string).  The env signal — not ambient device count —
    gates the mesh: a mesh engine pins the ``sharded`` backend, which would
    silently defeat a ``--backend`` sweep on a multi-device host.
    """
    global _MESH
    want = int(os.environ.get("REPRO_BENCH_DEVICES", "0") or 0)
    if _MESH is None and want > 1:
        from repro.launch.mesh import make_serving_mesh
        _MESH = make_serving_mesh(model=want)
    return _MESH


def _drain(engine) -> float:
    t0 = time.time()
    engine.run_until_done()
    return time.time() - t0


def _emit_engine(tag: str, engine, dt: float) -> None:
    m = engine.metrics()
    s = m["spec"]
    emit(tag, dt * 1e6,
         f"ttft_p50_ms={m['p50_ttft_s']*1e3:.1f};"
         f"ttft_p99_ms={m['p99_ttft_s']*1e3:.1f};"
         f"tpot_p50_ms={m['p50_tpot_s']*1e3:.1f};"
         f"tpot_p99_ms={m['p99_tpot_s']*1e3:.1f};"
         f"tok_s={m['throughput_tok_s']:.1f};"
         f"preempt={m['preemptions']};"
         f"finished={m['finished']};"
         f"prefix_hit_rate={m['prefix_hit_rate']:.2f};"
         f"backend={m['backend']};"
         f"devices={m['devices']};"
         f"policies={m['admission_policy']}/{m['preemption_policy']}/"
         f"{m['eviction_policy']};"
         f"spec={s['proposer']};"
         f"spec_accept={s['acceptance_rate']:.2f};"
         f"tok_per_lane={s['tokens_per_decode_lane']:.2f}")


def run(quick: bool = True) -> None:
    from repro.serving import spec as spec_lib
    smoke = bool(os.environ.get("REPRO_BENCH_SMOKE"))
    if smoke and spec_lib.forced_proposer() == "draft-model":
        return      # slow sweep (k draft forwards per decode step): the
                    # deterministic smoke covers off/ngram only
    cfg = get_config("smollm-360m").reduced(dtype="float32")
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))

    if not smoke:
        # prefill vs decode latency breakdown (Fig 12b)
        import jax.numpy as jnp
        B, in_len = (2, 64) if quick else (16, 100)
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, in_len), 0,
                                  cfg.vocab_size)
        prefill = jax.jit(lambda p, t: model.forward(p, t, last_only=True)[0])
        us_prefill = time_fn(prefill, params, toks, iters=3)
        cache = model.init_decode_cache(B, in_len + 64)
        step = jax.jit(model.decode_step)
        one = jnp.zeros((B,), jnp.int32)
        us_decode = time_fn(lambda p, c, t: step(p, c, t)[0], params, cache,
                            one, iters=3)
        for out_len in [25, 100, 400]:
            total = us_prefill + out_len * us_decode
            emit(f"llm_breakdown_out{out_len}", total,
                 f"prefill_frac={us_prefill/total:.2f};"
                 f"decode_frac={out_len*us_decode/total:.2f}")

    rng = np.random.default_rng(0)

    def var_requests(n):
        return [Request(
            req_id=i,
            prompt=rng.integers(0, cfg.vocab_size,
                                (int(rng.integers(4, 12)),), dtype=np.int32),
            max_new_tokens=int(rng.integers(3, 8))) for i in range(n)]

    if not smoke:
        # continuous batching TTFT/TPOT with variable lengths (Fig 17 d/e)
        n_req = 3 if quick else 16
        for max_batch in ([2] if quick else [2, 8, 32]):
            serve = ServeConfig(model=cfg.name, kv_block_size=8,
                                max_batch=max_batch)
            engine = ServingEngine(model, params, cfg, serve, num_blocks=256,
                               mesh=_mesh())
            for r in var_requests(n_req):
                engine.submit(r)
            _emit_engine(f"llm_engine_maxbatch{max_batch}", engine,
                         _drain(engine))

    # bursty arrivals: the whole wave lands at t0 and queues behind max_batch
    n_burst = 3 if smoke else (6 if quick else 32)
    serve = ServeConfig(model=cfg.name, kv_block_size=8, max_batch=2)
    engine = ServingEngine(model, params, cfg, serve, num_blocks=256,
                           mesh=_mesh())
    for r in var_requests(n_burst):
        engine.submit(r)
    _emit_engine(f"llm_burst_n{n_burst}", engine, _drain(engine))

    # shared-prefix workload: common system prompt, prefix cache reuses blocks
    n_pfx = 3 if smoke else (6 if quick else 24)
    plen = 16
    prefix = rng.integers(0, cfg.vocab_size, (plen,), dtype=np.int32)
    serve = ServeConfig(model=cfg.name, kv_block_size=8, max_batch=2)
    eng_shared = ServingEngine(model, params, cfg, serve, num_blocks=256,
                               mesh=_mesh())
    for i in range(n_pfx):
        tail = rng.integers(0, cfg.vocab_size, (4,), dtype=np.int32)
        eng_shared.submit(Request(req_id=i,
                                  prompt=np.concatenate([prefix, tail]),
                                  max_new_tokens=4))
    dt = _drain(eng_shared)
    eng_indep = ServingEngine(model, params, cfg, serve, num_blocks=256,
                              mesh=_mesh())
    for i in range(n_pfx):
        eng_indep.submit(Request(
            req_id=i,
            prompt=rng.integers(0, cfg.vocab_size, (plen + 4,),
                                dtype=np.int32),
            max_new_tokens=4))
    dt_i = _drain(eng_indep)
    m = eng_shared.metrics()
    emit(f"llm_prefix_shared_n{n_pfx}", dt * 1e6,
         f"prefix_hit_rate={m['prefix_hit_rate']:.2f};"
         f"blocks_allocated={eng_shared.alloc.blocks_allocated};"
         f"indep_blocks_allocated={eng_indep.alloc.blocks_allocated};"
         f"speedup_vs_indep={dt_i/max(dt, 1e-9):.2f}")

    # memory pressure: pool below the working set forces preemption
    serve = ServeConfig(model=cfg.name, kv_block_size=4, max_batch=3)
    engine = ServingEngine(model, params, cfg, serve, num_blocks=10,
                           mesh=_mesh())
    for i in range(3):
        engine.submit(Request(
            req_id=i,
            prompt=rng.integers(0, cfg.vocab_size, (6,), dtype=np.int32),
            max_new_tokens=8))
    dt = _drain(engine)
    m = engine.metrics()
    emit("llm_preempt_pressure", dt * 1e6,
         f"preemptions={m['preemptions']};finished={m['finished']};"
         f"tok_s={m['throughput_tok_s']:.1f};"
         f"policies={m['admission_policy']}/{m['preemption_policy']}/"
         f"{m['eviction_policy']}")

    # repetitive-suffix workload: prompts loop a short motif and greedy
    # decodes of a tiny model fall into loops of their own — exactly the
    # evidence the ngram proposer reads, so speculative acceptance lands
    # here (the --spec sweep's showcase scenario)
    n_rep = 3 if smoke else (6 if quick else 16)
    serve = ServeConfig(model=cfg.name, kv_block_size=8, max_batch=2)
    engine = ServingEngine(model, params, cfg, serve, num_blocks=256,
                           mesh=_mesh())
    for i in range(n_rep):
        motif = rng.integers(0, cfg.vocab_size, (3,), dtype=np.int32)
        engine.submit(Request(req_id=i, prompt=np.tile(motif, 4),
                              max_new_tokens=16))
    _emit_engine(f"llm_repeat_n{n_rep}", engine, _drain(engine))
