"""Paper Fig 12 + 17 d/e: end-to-end LLM serving — prefill/decode latency
breakdown across output lengths, TTFT/TPOT from the continuous-batching
engine (Dynamic-Sonnet-style variable lengths)."""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit, time_fn
from repro.config import ServeConfig, get_config
from repro.models.api import build_model
from repro.serving.engine import Request, ServingEngine


def run(quick: bool = True) -> None:
    cfg = get_config("smollm-360m").reduced(dtype="float32")
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))

    # prefill vs decode latency breakdown (Fig 12b)
    import jax.numpy as jnp
    B, in_len = (2, 64) if quick else (16, 100)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, in_len), 0,
                              cfg.vocab_size)
    prefill = jax.jit(lambda p, t: model.forward(p, t, last_only=True)[0])
    us_prefill = time_fn(prefill, params, toks, iters=3)
    cache = model.init_decode_cache(B, in_len + 64)
    step = jax.jit(model.decode_step)
    one = jnp.zeros((B,), jnp.int32)
    us_decode = time_fn(lambda p, c, t: step(p, c, t)[0], params, cache, one,
                        iters=3)
    for out_len in [25, 100, 400]:
        total = us_prefill + out_len * us_decode
        emit(f"llm_breakdown_out{out_len}", total,
             f"prefill_frac={us_prefill/total:.2f};"
             f"decode_frac={out_len*us_decode/total:.2f}")

    # continuous batching TTFT/TPOT with variable lengths (Fig 17 d/e)
    n_req = 3 if quick else 16
    rng = np.random.default_rng(0)
    for max_batch in ([2] if quick else [2, 8, 32]):
        serve = ServeConfig(model=cfg.name, kv_block_size=8,
                            max_batch=max_batch)
        engine = ServingEngine(model, params, cfg, serve, num_blocks=256)
        for i in range(n_req):
            plen = int(rng.integers(4, 12))
            engine.submit(Request(
                req_id=i,
                prompt=rng.integers(0, cfg.vocab_size, (plen,),
                                    dtype=np.int32),
                max_new_tokens=int(rng.integers(3, 8))))
        t0 = time.time()
        engine.run_until_done()
        dt = time.time() - t0
        m = engine.metrics()
        emit(f"llm_engine_maxbatch{max_batch}", dt * 1e6,
             f"ttft_ms={m['mean_ttft_s']*1e3:.1f};"
             f"tpot_ms={m['mean_tpot_s']*1e3:.1f};"
             f"tok_s={m['output_tokens']/dt:.1f}")
