"""Disaggregated prefill/decode serving + tiered KV cache (S4.2 serving
asymmetry; docs/disaggregated.md):

  * ``llm_disagg_burst_*``    a burst of LONG prompts lands on engines that
    already have decode lanes in flight.  The monolithic engine carries
    prefill chunks inside every fused step, so in-flight decodes inherit
    prefill-sized step latency (TPOT spikes); the disaggregated frontend
    runs decode-only steps at a fixed decode:prefill cadence, so the same
    burst leaves decode TPOT flat.  Same model, same device budget, same
    total HBM blocks — the mono/split rows differ only in role topology.
  * ``llm_tier_pressure_*``   recurring prompt prefixes cycle through an
    HBM pool sized below the working set.  HBM-only eviction drops content,
    so only back-to-back reuse hits the prefix cache; the ``tiered``
    eviction policy demotes evicted blocks with reuse evidence to a host
    pool and promotes them back on the next recurrence — a structurally
    higher prefix hit rate at the SAME HBM pool size.

Every row carries ``roles=``/``tier=`` attribution (role topology and
hbm/host pool sizes) plus the handoff / tier counters that explain the win,
so ``benchmarks/run.py`` sweeps stay attributable.  ``REPRO_BENCH_SMOKE=1``
shrinks both scenarios to the deterministic minimum ``tools/ci_fast.sh``
checks (counters, not wall-clock, gate the smoke).
"""
from __future__ import annotations

import os
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.config import ServeConfig, get_config
from repro.models.api import build_model
from repro.serving.disagg import DisaggEngine
from repro.serving.engine import Request, ServingEngine


def _drain(engine) -> float:
    t0 = time.time()
    engine.run_until_done()
    return time.time() - t0


def _tier_str(m) -> str:
    t = m["tier"]
    return f"tier=hbm:{t['hbm_blocks']}+host:{t['host_blocks']}"


def _burst_row(tag: str, engine, dt: float, roles: str) -> None:
    m = engine.metrics()
    extra = ""
    if roles != "full":
        h = m["handoff_ms"]
        extra = (f";handoffs={m['handoffs']};"
                 f"handoff_p50_ms={h['p50']:.2f};"
                 f"prefill_steps={m['roles']['prefill']['steps']};"
                 f"decode_steps={m['roles']['decode']['steps']}")
    emit(tag, dt * 1e6,
         f"tpot_p50_ms={m['p50_tpot_s']*1e3:.1f};"
         f"tpot_p99_ms={m['p99_tpot_s']*1e3:.1f};"
         f"ttft_p50_ms={m['p50_ttft_s']*1e3:.1f};"
         f"ttft_p99_ms={m['p99_ttft_s']*1e3:.1f};"
         f"tok_s={m['throughput_tok_s']:.1f};"
         f"finished={m['finished']};"
         f"backend={m['backend']};"
         f"roles={roles.replace(',', '+')};{_tier_str(m)}" + extra)


def run(quick: bool = True) -> None:
    smoke = bool(os.environ.get("REPRO_BENCH_SMOKE"))
    cfg = get_config("smollm-360m").reduced(dtype="float32")
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))

    # ---- bursty long-prompt arrivals: prefill-induced TPOT spikes --------
    n_req = 4 if smoke else (6 if quick else 16)
    plen = 40 if smoke else 96
    max_new = 8 if smoke else 16
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, (plen,), dtype=np.int32)
               for _ in range(n_req)]

    def burst_requests():
        return [Request(req_id=i, prompt=p, max_new_tokens=max_new)
                for i, p in enumerate(prompts)]

    serve = ServeConfig(model=cfg.name, kv_block_size=8, max_batch=2,
                        prefill_chunk=16)
    blocks = n_req * (-(-(plen + max_new) // 8) + 1)
    mono = ServingEngine(model, params, cfg, serve, num_blocks=blocks)
    for r in burst_requests():
        mono.submit(r)
    _burst_row(f"llm_disagg_burst_mono_n{n_req}", mono, _drain(mono), "full")

    split = DisaggEngine(model, params, cfg, serve, num_blocks=blocks)
    for r in burst_requests():
        split.submit(r)
    _burst_row(f"llm_disagg_burst_split_n{n_req}", split, _drain(split),
               "prefill,decode")

    # ---- memory pressure: host tier vs HBM-only at equal HBM pool --------
    n_prompts = 3 if smoke else 5
    rounds = 2 if smoke else 3
    bs, hbm = 8, 7 if smoke else 11
    pressure_prompts = [rng.integers(0, cfg.vocab_size, (3 * bs,),
                                     dtype=np.int32)
                        for _ in range(n_prompts)]

    def pressure_run(tag: str, eviction: str, host_blocks: int) -> None:
        serve = ServeConfig(model=cfg.name, kv_block_size=bs, max_batch=1,
                            eviction=eviction, host_blocks=host_blocks)
        eng = ServingEngine(model, params, cfg, serve, num_blocks=hbm)
        t0 = time.time()
        rid = 0
        for _ in range(rounds):
            for p in pressure_prompts:
                for _ in range(2):          # back-to-back reuse earns hits
                    eng.submit(Request(req_id=rid, prompt=p,
                                       max_new_tokens=6))
                    rid += 1
                eng.run_until_done()
        dt = time.time() - t0
        m = eng.metrics()
        t = m["tier"]
        emit(tag, dt * 1e6,
             f"prefix_hit_rate={m['prefix_hit_rate']:.2f};"
             f"prefix_hits={m['prefix_hits']};"
             f"evictions={eng.alloc.cache_evictions};"
             f"demotes={t['demotes']};promotes={t['promotes']};"
             f"tier_hits={t['hits']};drops={t['drops']};"
             f"finished={m['finished']};"
             f"eviction={m['eviction_policy']};"
             f"roles=full;{_tier_str(m)}")

    pressure_run(f"llm_tier_pressure_hbm_only_r{rounds}", "lru", 0)
    pressure_run(f"llm_tier_pressure_tiered_r{rounds}", "tiered",
                 4 * n_prompts)
