"""Saturation + DMA-vs-compute profile for the async overlapped engine loop
(docs/async_engine.md):

  * ``llm_saturation_*`` — offered load >> capacity: a whole wave of
    requests lands at t0 against a small batch and a tight KV pool, so the
    engine is never idle and every step's host work (propose / schedule /
    render / commit) competes with device execution. Run twice — overlap
    off (serial build->resolve) vs on (build N+1 while N executes) — the
    throughput delta is the pipeline win, and ``device_frac`` (device phase
    wall over total phase wall) rises under overlap because host buckets
    hide inside the device window.
  * ``paged_dma_profile_*`` — the chunked paged-attention kernel's
    multi-buffered KV-page prefetch ring, swept over prefetch depth x page
    (block) size at fixed total KV. Depth 0 is the BlockSpec-pipelined
    serial path; depth >= 2 runs the manual DMA ring. Each row attributes
    the bytes a lane step must move vs the flash-update flops it must
    compute, so the depth that balances DMA against compute is readable
    from the JSON, not guessed.

Every row carries ``overlap=``/``prefetch_depth=`` (engine rows) or
``depth=``/``page=`` (kernel rows) so ``benchmarks/run.py --json`` sweeps
stay attributable per configuration (the BENCH_006.json baseline).
``REPRO_BENCH_SMOKE=1`` shrinks both sweeps to the deterministic minimum.
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.config import ServeConfig, get_config
from repro.core.paged_kv import BlockAllocator
from repro.serving.engine import Request, ServingEngine


def _saturated_engine(model, params, cfg, *, overlap: bool, n_req: int,
                      max_batch: int, num_blocks: int,
                      sanitize: bool = False) -> ServingEngine:
    serve = ServeConfig(model=cfg.name, kv_block_size=8, max_batch=max_batch,
                        overlap=overlap, sanitize=sanitize)
    eng = ServingEngine(model, params, cfg, serve, num_blocks=num_blocks)
    rng = np.random.default_rng(0)          # same wave for both passes
    for i in range(n_req):
        eng.submit(Request(
            req_id=i,
            prompt=rng.integers(0, cfg.vocab_size,
                                (int(rng.integers(4, 12)),), dtype=np.int32),
            max_new_tokens=int(rng.integers(6, 12))))
    return eng


def _emit_saturation(tag: str, eng: ServingEngine, dt: float) -> None:
    m = eng.metrics()
    total = sum(m["phase_s"].values()) or 1.0
    emit(tag, dt * 1e6,
         f"tok_s={m['throughput_tok_s']:.1f};"
         f"device_frac={m['phase_s'].get('device', 0.0) / total:.3f};"
         f"steps={m['steps']};"
         f"idle_steps={m['num_idle_steps']};"
         f"preempt={m['preemptions']};"
         f"finished={m['finished']};"
         f"overlap={str(m['overlap']).lower()};"
         f"prefetch_depth={m['prefetch_depth']};"
         f"sanitize={str(m['sanitize']['enabled']).lower()};"
         f"retraces={m['sanitize']['retraces']};"
         f"backend={m['backend']}")


def _dma_profile(quick: bool, smoke: bool) -> None:
    """Chunked-kernel prefetch ring: depth x page-size sweep at fixed KV.

    The work per lane step is constant across the sweep (same total KV
    tokens, same heads), so ``us_per_call`` differences are attributable to
    the fetch strategy; ``kv_bytes_per_step`` / ``flops_per_step`` give the
    DMA-vs-compute balance each (depth, page) point must hide.
    """
    from repro.kernels.paged_attention.kernel import (
        paged_attention_chunked_pallas)
    KV, hd, H = 2, 32, 8
    total_kv = 64 if smoke else (128 if quick else 512)
    lens = [total_kv // 2, total_kv // 4, total_kv // 4]
    depths = [0, 2] if smoke else ([0, 2, 4] if quick else [0, 2, 4, 8])
    pages = [8, 16] if (smoke or quick) else [8, 16, 32]
    for bs in pages:
        nb = sum(-(-L // bs) for L in lens) + 2
        al = BlockAllocator(num_blocks=nb, block_size=bs)
        for r, L in enumerate(lens):
            al.allocate(r, L)
        bl, br, bp, _ = [jnp.asarray(x) for x in
                         al.build_block_list(list(range(len(lens))),
                                             max_total=nb)]
        kv_lens = jnp.asarray(lens, jnp.int32)
        treq = jnp.asarray([0, 1, 2], jnp.int32)      # one decode lane each
        tpos = jnp.asarray([L - 1 for L in lens], jnp.int32)
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        pk = jax.random.normal(ks[0], (nb, bs, KV, hd), jnp.float32)
        pv = jax.random.normal(ks[1], (nb, bs, KV, hd), jnp.float32)
        q = jax.random.normal(ks[2], (3, H, hd), jnp.float32)
        # per grid step (one KV page): K+V page bytes in, flash update flops
        kv_bytes = 2 * bs * KV * hd * 4
        flops = 2 * 2 * len(treq) * H * bs * hd       # qk^T + pv per lane
        for depth in depths:
            fn = jax.jit(lambda q, pk, pv, d=depth: paged_attention_chunked_pallas(
                q, pk, pv, bl, br, bp, kv_lens, treq, tpos,
                q_chunk=4, prefetch_depth=d, interpret=True))
            us = time_fn(fn, q, pk, pv, iters=3)
            emit(f"paged_dma_profile_bs{bs}_d{depth}", us,
                 f"depth={depth};page={bs};kv_pages={int(bl.shape[0])};"
                 f"kv_bytes_per_step={kv_bytes};"
                 f"flops_per_step={flops};"
                 f"bytes_per_flop={kv_bytes / flops:.3f}")


def run(quick: bool = True) -> None:
    smoke = bool(os.environ.get("REPRO_BENCH_SMOKE"))
    from repro.models.api import build_model
    cfg = get_config("smollm-360m").reduced(dtype="float32")
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))

    # offered load >> capacity: requests outnumber batch slots ~6x and the
    # pool holds well under the working set, so the run saturates end to end
    n_req = 6 if smoke else (12 if quick else 48)
    max_batch = 2
    num_blocks = 24
    # REPRO_SANITIZE=1: run the saturation wave under the runtime guards and
    # ASSERT the steady-state contract — zero retraces and zero host-sync
    # trips across the whole saturated run (the retrace-guard assertion of
    # docs/static_analysis.md; ci_fast.sh's sanitized smoke relies on it).
    sanitize = os.environ.get("REPRO_SANITIZE") == "1"
    for overlap in (False, True):
        eng = _saturated_engine(model, params, cfg, overlap=overlap,
                                n_req=n_req, max_batch=max_batch,
                                num_blocks=num_blocks, sanitize=sanitize)
        t0 = time.time()
        eng.run_until_done()
        if sanitize:
            san = eng.metrics()["sanitize"]
            assert san["retraces"] == 0, san
            assert san["transfer_guard_trips"] == 0, san
            assert san["invariant_checks"] > 0, san
        _emit_saturation(
            f"llm_saturation_overlap_{'on' if overlap else 'off'}",
            eng, time.time() - t0)

    _dma_profile(quick, smoke)
