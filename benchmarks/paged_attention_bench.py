"""Paper Fig 17 a–c: PagedAttention — vLLM_base (padded BlockTable) vs
vLLM_opt (flat BlockList), with the zero-padding-fraction sweep.

THE paper §4.2 reproduction. The padded baseline gathers every BlockTable
entry including zero-pads; the BlockList path touches only effectual blocks.
Measured: wall time of both. Derived: the HLO gather-bytes ratio (from
cost_analysis of both jitted programs) — the hardware-independent form of
the paper's 7.4×/55.7× result. tests/test_benchmarks.py asserts the
speedup grows with the padding fraction."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core.attention_api import (
    paged_attention_base, paged_attention_chunked, paged_attention_opt)
from repro.core.paged_kv import BlockAllocator


def _setup(B, seq_lens, max_blocks, NB, BS, KV, HD, H, key):
    al = BlockAllocator(num_blocks=NB, block_size=BS)
    al._free = np.random.RandomState(0).permutation(NB).tolist()
    for r, L in enumerate(seq_lens):
        al.allocate(r, L)
    tab, lens = al.build_block_table(list(range(B)), max_blocks=max_blocks)
    tot = sum(-(-L // BS) for L in seq_lens)
    bl, br, bp, lens2 = al.build_block_list(list(range(B)), max_total=tot)
    ks = jax.random.split(key, 3)
    pool_k = jax.random.normal(ks[0], (NB, BS, KV, HD), jnp.float32)
    pool_v = jax.random.normal(ks[1], (NB, BS, KV, HD), jnp.float32)
    q = jax.random.normal(ks[2], (B, H, HD), jnp.float32)
    return (q, pool_k, pool_v, jnp.asarray(tab), jnp.asarray(lens),
            jnp.asarray(bl), jnp.asarray(br), jnp.asarray(bp),
            jnp.asarray(lens2))


def _hlo_bytes(fn, *args) -> float:
    c = jax.jit(fn).lower(*args).compile()
    ca = c.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else ca
    return float(ca.get("bytes accessed", 0.0))


def run(quick: bool = True) -> None:
    key = jax.random.PRNGKey(0)
    B, BS, KV, HD, H = (16, 16, 4, 64, 16) if quick else (32, 16, 8, 128, 32)
    full_blocks = 16 if quick else 64
    base_j = jax.jit(paged_attention_base)
    opt_j = jax.jit(paged_attention_opt)
    # padding fraction sweep (Fig 17b): all requests at (1-frac)·max length
    for frac in [0.0, 0.3, 0.6, 0.9]:
        eff_blocks = max(1, int(round(full_blocks * (1 - frac))))
        seq_lens = [eff_blocks * BS] * B
        NB = B * full_blocks + 8
        (q, pk, pv, tab, lens, bl, br, bp, lens2) = _setup(
            B, seq_lens, full_blocks, NB, BS, KV, HD, H, key)
        us_base = time_fn(base_j, q, pk, pv, tab, lens, iters=3)
        us_opt = time_fn(opt_j, q, pk, pv, bl, br, bp, lens2, iters=3)
        by_base = _hlo_bytes(paged_attention_base, q, pk, pv, tab, lens)
        by_opt = _hlo_bytes(paged_attention_opt, q, pk, pv, bl, br, bp, lens2)
        emit(f"paged_base_pad{int(frac*100)}", us_base,
             f"hlo_bytes={by_base:.0f}")
        emit(f"paged_opt_pad{int(frac*100)}", us_opt,
             f"hlo_bytes={by_opt:.0f};speedup={us_base/max(us_opt,1e-9):.2f};"
             f"bytes_ratio={by_base/max(by_opt,1):.2f}")
    # batch/seq sweep at 0% padding (Fig 17a)
    for B2, blocks in ([(8, 8), (32, 16)] if quick else
                       [(8, 8), (32, 16), (64, 32), (128, 64)]):
        seq_lens = [blocks * BS] * B2
        NB = B2 * blocks + 8
        (q, pk, pv, tab, lens, bl, br, bp, lens2) = _setup(
            B2, seq_lens, blocks, NB, BS, KV, HD, H, key)
        us_base = time_fn(base_j, q, pk, pv, tab, lens, iters=3)
        us_opt = time_fn(opt_j, q, pk, pv, bl, br, bp, lens2, iters=3)
        emit(f"paged_opt_B{B2}_S{blocks*BS}", us_opt,
             f"speedup_vs_base={us_base/max(us_opt,1e-9):.2f}")
    # chunked-prefill sweep: one fused call prefills C prompt tokens against
    # the paged pool (the serving engine's per-step shape). Per-token cost
    # should FALL with C — that amortization is why chunked prefill can ride
    # inside the decode step instead of stalling it.
    chunk_j = jax.jit(paged_attention_chunked)
    Bc, blocks_c = (4, 8) if quick else (16, 32)
    S = blocks_c * BS
    NB = Bc * blocks_c + 8
    seq_lens = [S] * Bc
    (q1, pk, pv, _, _, bl, br, bp, lens2) = _setup(
        Bc, seq_lens, blocks_c, NB, BS, KV, HD, H, key)
    for C in ([1, 4, 16] if quick else [1, 8, 64, 256]):
        T = Bc * C
        qs = jax.random.normal(key, (T, H, HD), jnp.float32)
        token_req = jnp.repeat(jnp.arange(Bc, dtype=jnp.int32), C)
        token_pos = jnp.tile(jnp.arange(S - C, S, dtype=jnp.int32), Bc)
        us = time_fn(chunk_j, qs, pk, pv, bl, br, bp, lens2, token_req,
                     token_pos, iters=3)
        emit(f"paged_chunked_C{C}", us,
             f"tokens={T};us_per_token={us/max(T,1):.2f}")
