"""Paper Fig 17 a–c: PagedAttention — vLLM_base (padded BlockTable) vs
vLLM_opt (flat BlockList), with the zero-padding-fraction sweep.

THE paper §4.2 reproduction. The padded baseline gathers every BlockTable
entry including zero-pads; the BlockList path touches only effectual blocks.
Measured: wall time of both. Derived: the HLO gather-bytes ratio (from
cost_analysis of both jitted programs) — the hardware-independent form of
the paper's 7.4×/55.7× result. tests/test_benchmarks.py asserts the
speedup grows with the padding fraction.

PR 10 extends the module with the ragged-kernel sweeps (docs/ragged_kernel.md):

* fused-vs-split KV layout — the SAME mixed prefill+decode workload through
  ``paged_attention_chunked`` on split (k, v) pools and
  ``paged_attention_ragged`` on the fused head-interleaved pool, asserted
  bit-identical before timing;
* a measured autotune grid over the ragged tunables per
  ``(page_size, head_dim, backend)`` cell — every point emits a ``tune=1``
  row and the fastest point carries ``best=1``.  The grid CONTAINS the
  registry defaults, so the best config meets-or-beats them by construction
  (asserted).  Committed as ``BENCH_010.json``, these rows are the table
  ``repro.perf.autotune`` resolves at engine construction.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import dispatch
from repro.core.attention_api import (
    paged_attention_base, paged_attention_chunked, paged_attention_opt)
from repro.core.paged_kv import BlockAllocator, fuse_kv_heads


def _setup(B, seq_lens, max_blocks, NB, BS, KV, HD, H, key):
    al = BlockAllocator(num_blocks=NB, block_size=BS)
    al._free = np.random.RandomState(0).permutation(NB).tolist()
    for r, L in enumerate(seq_lens):
        al.allocate(r, L)
    tab, lens = al.build_block_table(list(range(B)), max_blocks=max_blocks)
    tot = sum(-(-L // BS) for L in seq_lens)
    bl, br, bp, lens2 = al.build_block_list(list(range(B)), max_total=tot)
    ks = jax.random.split(key, 3)
    pool_k = jax.random.normal(ks[0], (NB, BS, KV, HD), jnp.float32)
    pool_v = jax.random.normal(ks[1], (NB, BS, KV, HD), jnp.float32)
    q = jax.random.normal(ks[2], (B, H, HD), jnp.float32)
    return (q, pool_k, pool_v, jnp.asarray(tab), jnp.asarray(lens),
            jnp.asarray(bl), jnp.asarray(br), jnp.asarray(bp),
            jnp.asarray(lens2))


def _hlo_bytes(fn, *args) -> float:
    c = jax.jit(fn).lower(*args).compile()
    ca = c.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else ca
    return float(ca.get("bytes accessed", 0.0))


def run(quick: bool = True) -> None:
    key = jax.random.PRNGKey(0)
    B, BS, KV, HD, H = (16, 16, 4, 64, 16) if quick else (32, 16, 8, 128, 32)
    full_blocks = 16 if quick else 64
    base_j = jax.jit(paged_attention_base)
    opt_j = jax.jit(paged_attention_opt)
    # padding fraction sweep (Fig 17b): all requests at (1-frac)·max length
    for frac in [0.0, 0.3, 0.6, 0.9]:
        eff_blocks = max(1, int(round(full_blocks * (1 - frac))))
        seq_lens = [eff_blocks * BS] * B
        NB = B * full_blocks + 8
        (q, pk, pv, tab, lens, bl, br, bp, lens2) = _setup(
            B, seq_lens, full_blocks, NB, BS, KV, HD, H, key)
        us_base = time_fn(base_j, q, pk, pv, tab, lens, iters=3)
        us_opt = time_fn(opt_j, q, pk, pv, bl, br, bp, lens2, iters=3)
        by_base = _hlo_bytes(paged_attention_base, q, pk, pv, tab, lens)
        by_opt = _hlo_bytes(paged_attention_opt, q, pk, pv, bl, br, bp, lens2)
        emit(f"paged_base_pad{int(frac*100)}", us_base,
             f"hlo_bytes={by_base:.0f}")
        emit(f"paged_opt_pad{int(frac*100)}", us_opt,
             f"hlo_bytes={by_opt:.0f};speedup={us_base/max(us_opt,1e-9):.2f};"
             f"bytes_ratio={by_base/max(by_opt,1):.2f}")
    # batch/seq sweep at 0% padding (Fig 17a)
    for B2, blocks in ([(8, 8), (32, 16)] if quick else
                       [(8, 8), (32, 16), (64, 32), (128, 64)]):
        seq_lens = [blocks * BS] * B2
        NB = B2 * blocks + 8
        (q, pk, pv, tab, lens, bl, br, bp, lens2) = _setup(
            B2, seq_lens, blocks, NB, BS, KV, HD, H, key)
        us_base = time_fn(base_j, q, pk, pv, tab, lens, iters=3)
        us_opt = time_fn(opt_j, q, pk, pv, bl, br, bp, lens2, iters=3)
        emit(f"paged_opt_B{B2}_S{blocks*BS}", us_opt,
             f"speedup_vs_base={us_base/max(us_opt,1e-9):.2f}")
    # chunked-prefill sweep: one fused call prefills C prompt tokens against
    # the paged pool (the serving engine's per-step shape). Per-token cost
    # should FALL with C — that amortization is why chunked prefill can ride
    # inside the decode step instead of stalling it.
    chunk_j = jax.jit(paged_attention_chunked)
    Bc, blocks_c = (4, 8) if quick else (16, 32)
    S = blocks_c * BS
    NB = Bc * blocks_c + 8
    seq_lens = [S] * Bc
    (q1, pk, pv, _, _, bl, br, bp, lens2) = _setup(
        Bc, seq_lens, blocks_c, NB, BS, KV, HD, H, key)
    for C in ([1, 4, 16] if quick else [1, 8, 64, 256]):
        T = Bc * C
        qs = jax.random.normal(key, (T, H, HD), jnp.float32)
        token_req = jnp.repeat(jnp.arange(Bc, dtype=jnp.int32), C)
        token_pos = jnp.tile(jnp.arange(S - C, S, dtype=jnp.int32), Bc)
        us = time_fn(chunk_j, qs, pk, pv, bl, br, bp, lens2, token_req,
                     token_pos, iters=3)
        emit(f"paged_chunked_C{C}", us,
             f"tokens={T};us_per_token={us/max(T,1):.2f}")

    # ------------------------------------------------------ ragged sweeps
    _layout_sweep(quick, key)
    _autotune_sweep(quick, key)


def _ragged_setup(B, pages_per_seq, BS, KV, HD, H, key):
    """Mixed prefill+decode workload in both metadata forms.

    Even slots carry one decode lane, odd slots a 4-token prefill chunk;
    sequence lengths are deliberately ragged (not page-aligned).  Returns the
    split pools, the fused pool, the flat BlockList, and BOTH the chunked
    token-lane arrays and the ragged prefix sums describing the same lanes.
    """
    seq_lens = [pages_per_seq * BS - (r % BS) for r in range(B)]
    NB = B * pages_per_seq + 4
    al = BlockAllocator(num_blocks=NB, block_size=BS)
    al._free = np.random.RandomState(0).permutation(NB).tolist()
    for r, L in enumerate(seq_lens):
        al.allocate(r, L)
    tot = sum(-(-L // BS) for L in seq_lens)
    bl, br, bp, kv_lens = al.build_block_list(list(range(B)), max_total=tot)
    ks = jax.random.split(key, 3)
    pk = jax.random.normal(ks[0], (NB, BS, KV, HD), jnp.float32)
    pv = jax.random.normal(ks[1], (NB, BS, KV, HD), jnp.float32)
    n_q = [1 if r % 2 == 0 else min(4, seq_lens[r]) for r in range(B)]
    T = int(sum(n_q))
    q = jax.random.normal(ks[2], (T, H, HD), jnp.float32)
    token_req = np.repeat(np.arange(B, dtype=np.int32), n_q)
    token_pos = np.concatenate([np.arange(L - n, L, dtype=np.int32)
                                for n, L in zip(n_q, seq_lens)])
    cu_q = np.zeros((B + 1,), np.int32)
    cu_q[1:] = np.cumsum(n_q)
    cu_kv = np.zeros((B + 1,), np.int32)
    cu_kv[1:] = np.cumsum(seq_lens)
    chunked_args = (q, pk, pv, jnp.asarray(bl), jnp.asarray(br),
                    jnp.asarray(bp), jnp.asarray(kv_lens),
                    jnp.asarray(token_req), jnp.asarray(token_pos))
    ragged_args = (q, fuse_kv_heads(pk, pv), jnp.asarray(bl),
                   jnp.asarray(br), jnp.asarray(bp), jnp.asarray(cu_q),
                   jnp.asarray(cu_kv), jnp.arange(B, dtype=jnp.int32))
    return chunked_args, ragged_args


def _layout_sweep(quick, key):
    """Fused-vs-split layout + ragged-vs-chunked on identical workloads."""
    fam = dispatch.get_op("paged_attention_ragged")
    BS, KV, HD, H = 16, 4, 64, 8
    sizes = [(4, 4), (8, 8)] if quick else [(4, 4), (8, 8), (16, 16)]
    for B, pages in sizes:
        chunked_args, ragged_args = _ragged_setup(B, pages, BS, KV, HD, H,
                                                  key)
        split = jax.jit(paged_attention_chunked)(*chunked_args)
        fused = fam(*ragged_args, backend="ref")
        assert np.array_equal(np.asarray(split), np.asarray(fused)), (
            "fused-pool ragged result diverged from split-pool chunked")
        us_split = time_fn(jax.jit(paged_attention_chunked), *chunked_args,
                           iters=3)
        us_fused = time_fn(partial(fam, backend="ref"), *ragged_args,
                           iters=3)
        T = chunked_args[0].shape[0]
        emit(f"ragged_layout_B{B}_p{pages}", us_fused,
             f"layout=fused;tokens={T};us_split={us_split:.1f};"
             f"speedup_vs_split={us_split/max(us_fused,1e-9):.2f}")


def _autotune_sweep(quick, key):
    """Measure the ragged tunable grid; best point per cell gets best=1."""
    fam = dispatch.get_op("paged_attention_ragged")
    defaults = dict(fam.tunables)
    KV, H = 2, 4
    B, pages = (4, 4) if quick else (8, 8)
    grid = sorted({(defaults["num_queries_per_block"],
                    defaults["num_kv_pages_per_block"]),
                   (8, 1), (8, 2), (16, 2)})
    for BS in (8, 16):
        for HD in (64,):
            _, ragged_args = _ragged_setup(B, pages, BS, KV, HD, H, key)
            for backend in ("ref", "pallas_interpret"):
                timed = []
                for nq, nk in grid:
                    cfg = {"num_queries_per_block": nq,
                           "num_kv_pages_per_block": nk,
                           "vmem_limit_bytes": 0}
                    us = time_fn(partial(fam, backend=backend, **cfg),
                                 *ragged_args, iters=3)
                    timed.append((us, cfg))
                best_us = min(us for us, _ in timed)
                default_us = next(
                    us for us, cfg in timed
                    if cfg["num_queries_per_block"]
                    == defaults["num_queries_per_block"]
                    and cfg["num_kv_pages_per_block"]
                    == defaults["num_kv_pages_per_block"])
                # The grid contains the registry defaults, so the winner can
                # never lose to them.
                assert best_us <= default_us, (BS, HD, backend, timed)
                emitted_best = False
                for us, cfg in timed:
                    best = (not emitted_best) and us == best_us
                    emitted_best = emitted_best or best
                    emit(f"ragged_tune_p{BS}_h{HD}_{backend}"
                         f"_q{cfg['num_queries_per_block']}"
                         f"_k{cfg['num_kv_pages_per_block']}", us,
                         "tune=1;"
                         f"page_size={BS};head_dim={HD};backend={backend};"
                         f"num_queries_per_block="
                         f"{cfg['num_queries_per_block']};"
                         f"num_kv_pages_per_block="
                         f"{cfg['num_kv_pages_per_block']};"
                         f"vmem_limit_bytes={cfg['vmem_limit_bytes']};"
                         f"best={1 if best else 0};"
                         f"vs_default={default_us/max(us,1e-9):.2f}")
