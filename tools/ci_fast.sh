#!/usr/bin/env bash
# Fast CI tier: everything except tests marked `slow` (Pallas interpret-mode
# kernel sweeps and other multi-minute paths). Target: < 2 minutes on CPU.
# Full tier remains `PYTHONPATH=src python -m pytest -x -q`.
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m pytest -q -m "not slow" "$@"
