#!/usr/bin/env bash
# Fast CI tier: everything except tests marked `slow` (Pallas interpret-mode
# kernel sweeps and other multi-minute paths), plus a tiny deterministic
# serving-policy sweep smoke. Target: < 2 minutes on CPU.
# Full tier remains `PYTHONPATH=src python -m pytest -x -q`.
#
# REPRO_BACKEND=ref pins every registry-dispatched op (repro.core.dispatch)
# to the jnp reference implementations, so the fast tier is deterministic
# across hosts; tests that probe resolver precedence clear the variable
# themselves, and the backend-parity suite's fast tier (the non-slow part of
# tests/test_backend_parity.py) still exercises every registered backend via
# explicit arguments, which outrank the env pin.
set -euo pipefail
cd "$(dirname "$0")/.."

# Lint stage: the repo-specific architectural linter (docs/static_analysis.md)
# runs FIRST — it imports only the standard library, so a contract violation
# (private allocator access, ad-hoc backend dispatch, unpaired DMA,
# unreachable tunable, wall-clock in device code, missing parity enrollment)
# fails the build before anything pays for a jax import. --json prints the
# findings machine-readably; nonzero exit on any finding aborts via set -e.
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m repro.analysis.lint --json src
echo "lint OK: src/ clean"

REPRO_BACKEND=ref \
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m pytest -q -m "not slow" "$@"

# Policy-sweep smoke: two serving-policy triples through the llm_e2e
# scenario benchmarks on a toy config (REPRO_BENCH_SMOKE=1 restricts the
# module to the bursty / shared-prefix / memory-pressure scenarios at
# minimum sizes). Greedy sampling makes the runs deterministic; the check
# below asserts every scenario finished its full workload under BOTH
# triples and that each JSON row is attributed to the resolved triple —
# a policy-dispatch regression fails fast here instead of in the slow tier.
POLICY_SMOKE_JSON="$(mktemp /tmp/policy_smoke.XXXXXX.json)"
trap 'rm -f "$POLICY_SMOKE_JSON"' EXIT
REPRO_BENCH_SMOKE=1 REPRO_BACKEND=ref \
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.run --only llm_e2e \
    --policy fcfs/latest-arrival/lru,priority/fewest-remaining-tokens/hit-rate \
    --json "$POLICY_SMOKE_JSON" >/dev/null
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python - "$POLICY_SMOKE_JSON" <<'PY'
import json, sys

results = json.load(open(sys.argv[1]))
assert len(results) == 2, f"expected 2 policy passes, got {len(results)}"
for res in results:
    triple = res["requested_policy"]
    rows = {r["name"]: r for r in res["rows"]}
    for name in ("llm_burst_n3", "llm_prefix_shared_n3",
                 "llm_preempt_pressure"):
        assert name in rows, f"[{triple}] missing scenario row {name}"
        assert rows[name]["policy"] == triple, (
            f"[{triple}] row {name} attributed to {rows[name]['policy']!r}")
    for name in ("llm_burst_n3", "llm_preempt_pressure"):
        derived = dict(kv.split("=", 1) for kv in
                       rows[name]["derived"].split(";"))
        assert derived["finished"] == "3", (
            f"[{triple}] {name}: finished={derived['finished']} != 3")
print(f"policy smoke OK: {len(results)} triples x 3 scenarios")
PY

# Speculative-decoding smoke: off vs ngram through the same deterministic
# scenario set (REPRO_BACKEND=ref + greedy + fixed seeds). Checks that each
# JSON row is attributed to the resolved proposer, that the ngram pass
# actually lands accepted drafts on the repetitive-suffix scenario
# (acceptance rate > 0 AND > 1 output token per decode lane — the
# multi-token-per-step win), and that speculation changes no completion
# counts. draft-model is excluded here by design: k extra draft forwards
# per decode step make it the slow sweep.
SPEC_SMOKE_JSON="$(mktemp /tmp/spec_smoke.XXXXXX.json)"
trap 'rm -f "$POLICY_SMOKE_JSON" "$SPEC_SMOKE_JSON"' EXIT
REPRO_BENCH_SMOKE=1 REPRO_BACKEND=ref \
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.run --only llm_e2e --spec off,ngram \
    --json "$SPEC_SMOKE_JSON" >/dev/null
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python - "$SPEC_SMOKE_JSON" <<'PY'
import json, sys

results = {r["requested_spec"]: r for r in json.load(open(sys.argv[1]))}
assert set(results) == {"off", "ngram"}, sorted(results)
for name, res in results.items():
    assert res["resolved_spec"] == [name], (name, res["resolved_spec"])
    rows = {r["name"]: r for r in res["rows"]}
    for scen in ("llm_burst_n3", "llm_repeat_n3"):
        assert scen in rows, f"[{name}] missing scenario row {scen}"
        assert rows[scen]["spec"] == name, (
            f"[{name}] row {scen} attributed to {rows[scen]['spec']!r}")
        derived = dict(kv.split("=", 1) for kv in
                       rows[scen]["derived"].split(";"))
        assert derived["finished"] == "3", (
            f"[{name}] {scen}: finished={derived['finished']} != 3")
rep = dict(kv.split("=", 1) for kv in
           {r["name"]: r for r in results["ngram"]["rows"]}
           ["llm_repeat_n3"]["derived"].split(";"))
assert float(rep["spec_accept"]) > 0, rep
assert float(rep["tok_per_lane"]) > 1, rep
print(f"spec smoke OK: ngram accept={rep['spec_accept']} "
      f"tok/lane={rep['tok_per_lane']}")
PY

# Sharded-engine smoke: 2 forced host devices, the same deterministic
# greedy workload through the single-device engine and the mesh-native
# engine (TP-sharded params, sequence-sharded KV pool, shard_map
# log-sum-exp combine — docs/sharded_serving.md). Asserts the sharded
# run resolves the `sharded` backend through the registry, reports the
# mesh in metrics, moves tokens (tokens/sec > 0) and streams BIT-IDENTICAL
# greedy outputs — the acceptance bar the slow-tier parity sweep
# (tests/test_sharded_engine.py) checks across policies/spec/devices.
XLA_FLAGS="--xla_force_host_platform_device_count=2" \
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python - <<'PY'
import numpy as np, jax
from repro.config import ServeConfig, get_config
from repro.launch.mesh import make_serving_mesh
from repro.models.api import build_model
from repro.serving.engine import Request, ServingEngine

cfg = get_config("smollm-360m").reduced(dtype="float32")
model = build_model(cfg, remat=False)
params = model.init(jax.random.PRNGKey(0))
serve = ServeConfig(model=cfg.name, kv_block_size=8, max_batch=2)

def run(mesh):
    rng = np.random.default_rng(0)
    eng = ServingEngine(model, params, cfg, serve, num_blocks=64, mesh=mesh)
    for i in range(3):
        eng.submit(Request(
            req_id=i,
            prompt=rng.integers(0, cfg.vocab_size,
                                (int(rng.integers(4, 10)),), dtype=np.int32),
            max_new_tokens=5))
    eng.run_until_done()
    return {r.req_id: list(r.output) for r in eng.finished}, eng.metrics()

single, _ = run(None)
shard, m = run(make_serving_mesh())
assert m["backend"] == "sharded", m["backend"]
assert m["devices"] == 2 and m["mesh_shape"] == {"data": 1, "model": 2}, m
assert m["throughput_tok_s"] > 0, m["throughput_tok_s"]
assert single == shard, (single, shard)
print(f"sharded smoke OK: 2 devices, {m['output_tokens']} tokens "
      f"bit-identical at {m['throughput_tok_s']:.1f} tok/s")
PY

# Async-overlap smoke: the same deterministic greedy workload through the
# serial engine and the overlapped engine (docs/async_engine.md: step N+1
# builds against provisional state while step N executes; placeholders
# reconcile at resolve), with and without a speculative proposer. Asserts
# BIT-IDENTICAL streams, no leaked blocks or dangling pipeline state, and
# the metrics attribution contract: `overlap` / `prefetch_depth` reported
# like `backend` / `mesh_shape`, idle iterations counted separately.
REPRO_BACKEND=ref \
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python - <<'PY'
import numpy as np, jax
from repro.config import ServeConfig, get_config
from repro.models.api import build_model
from repro.serving.engine import Request, ServingEngine

cfg = get_config("smollm-360m").reduced(dtype="float32")
model = build_model(cfg, remat=False)
params = model.init(jax.random.PRNGKey(0))

def run(overlap, spec):
    serve = ServeConfig(model=cfg.name, kv_block_size=8, max_batch=2,
                        spec=spec, spec_k=3, overlap=overlap,
                        prefetch_depth=0)
    rng = np.random.default_rng(0)
    eng = ServingEngine(model, params, cfg, serve, num_blocks=64)
    for i in range(3):
        eng.submit(Request(
            req_id=i,
            prompt=rng.integers(0, cfg.vocab_size,
                                (int(rng.integers(4, 10)),), dtype=np.int32),
            max_new_tokens=5))
    eng.run_until_done()
    assert eng._pending is None and not eng._chain, "pipeline not drained"
    assert eng.alloc.num_free == eng.alloc.num_blocks, "leaked blocks"
    return {r.req_id: list(r.output) for r in eng.finished}, eng.metrics()

for spec in ("off", "ngram"):
    serial, m0 = run(False, spec)
    overlap, m1 = run(True, spec)
    assert serial == overlap, (spec, serial, overlap)
    assert m0["overlap"] is False and m1["overlap"] is True, (m0, m1)
    for m in (m0, m1):
        assert m["prefetch_depth"] == 0, m["prefetch_depth"]
        assert m["num_idle_steps"] == 0, m["num_idle_steps"]
        assert "device" in m["phase_s"], m["phase_s"]
print("overlap smoke OK: bit-identical streams, spec off+ngram, "
      "attribution reported")
PY

# Disaggregated-serving smoke: 2 forced host devices so the prefill and
# decode roles pin to separate devices, the same deterministic greedy
# workload through the monolithic engine and the two-role DisaggEngine
# (docs/disaggregated.md: prompts prefill on one engine, full KV blocks
# hand off through the allocator's reserve/commit API, decode runs on the
# other) with a host KV tier under the registered `tiered` eviction policy.
# Asserts BIT-IDENTICAL greedy streams, real handoffs and host-tier traffic
# (demotes + promotes on a starved pool), leak-free pools on BOTH roles,
# and the metrics attribution contract: per-role sections, handoff latency
# percentiles, and tier counters flattened beside the policy counters.
XLA_FLAGS="--xla_force_host_platform_device_count=2" \
REPRO_BACKEND=ref \
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python - <<'PY'
import numpy as np, jax
from repro.config import ServeConfig, get_config
from repro.models.api import build_model
from repro.serving.disagg import DisaggEngine
from repro.serving.engine import Request, ServingEngine

cfg = get_config("smollm-360m").reduced(dtype="float32")
model = build_model(cfg, remat=False)
params = model.init(jax.random.PRNGKey(0))

def requests():
    rng = np.random.default_rng(0)
    return [Request(
        req_id=i,
        prompt=rng.integers(0, cfg.vocab_size,
                            (int(rng.integers(12, 25)),), dtype=np.int32),
        max_new_tokens=5) for i in range(3)]

serve = ServeConfig(model=cfg.name, kv_block_size=8, max_batch=2)
mono = ServingEngine(model, params, cfg, serve, num_blocks=64)
for r in requests():
    mono.submit(r)
mono.run_until_done()
ref = {r.req_id: list(r.output) for r in mono.finished}

devs = jax.devices()
assert len(devs) == 2, devs
serve = ServeConfig(model=cfg.name, kv_block_size=8, max_batch=2,
                    roles="prefill,decode", eviction="tiered", host_blocks=8)
eng = DisaggEngine(model, params, cfg, serve, num_blocks=64,
                   devices=(devs[0], devs[1]))
for r in requests():
    eng.submit(r)
eng.run_until_done()
split = {r.req_id: list(r.output) for r in eng.finished}
assert split == ref, (split, ref)
m = eng.metrics()
assert m["handoffs"] == 3 and m["handoff_ms"]["n"] == 3, m["handoffs"]
assert m["roles"]["prefill"]["prefills_completed"] == 3, m["roles"]
assert m["roles"]["decode"]["finished"] == 3, m["roles"]
assert m["handoff_ms"]["p99"] >= 0, m["handoff_ms"]
for k in ("tier.demotes", "tier.promotes", "tier.prefill.demotes"):
    assert k in m["policy_counters"], (k, sorted(m["policy_counters"]))
assert eng.pre.alloc.num_free == eng.pre.alloc.num_blocks, "prefill leak"
assert eng.dec.alloc.num_free == eng.dec.alloc.num_blocks, "decode leak"

# host-tier traffic on a starved decode pool: recurring prefixes earn hits,
# demote under pressure, and promote back on the next recurrence
serve = ServeConfig(model=cfg.name, kv_block_size=8, max_batch=1,
                    eviction="tiered", host_blocks=12)
tier = ServingEngine(model, params, cfg, serve, num_blocks=7)
rng = np.random.default_rng(1)
prompts = [rng.integers(0, cfg.vocab_size, (24,), dtype=np.int32)
           for _ in range(3)]
rid = 0
for _ in range(2):
    for p in prompts:
        for _ in range(2):
            tier.submit(Request(req_id=rid, prompt=p, max_new_tokens=4))
            rid += 1
        tier.run_until_done()
hp = tier.host_pool
assert hp.counters["demotes"] > 0 and hp.counters["promotes"] > 0, hp.counters
mt = tier.metrics()
assert mt["tier"]["host_blocks"] == 12, mt["tier"]
assert mt["policy_counters"]["tier.promotes"] == hp.counters["promotes"], mt
assert tier.alloc.num_free == tier.alloc.num_blocks, "tier leak"
print(f"disagg smoke OK: 2 roles on 2 devices, {m['handoffs']} handoffs "
      f"bit-identical; host tier demotes={hp.counters['demotes']} "
      f"promotes={hp.counters['promotes']}")
PY

# Disagg-benchmark smoke: the bursty + memory-pressure scenarios at minimum
# sizes through benchmarks/run.py, checking the JSON attribution contract —
# every row carries roles=/tier=, the split row reports nonzero handoffs,
# and the tiered row's prefix hit rate beats HBM-only at the same HBM pool.
DISAGG_SMOKE_JSON="$(mktemp /tmp/disagg_smoke.XXXXXX.json)"
trap 'rm -f "$POLICY_SMOKE_JSON" "$SPEC_SMOKE_JSON" "$DISAGG_SMOKE_JSON"' EXIT
REPRO_BENCH_SMOKE=1 REPRO_BACKEND=ref \
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.run --only disagg \
    --json "$DISAGG_SMOKE_JSON" >/dev/null
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python - "$DISAGG_SMOKE_JSON" <<'PY'
import json, sys

(res,) = json.load(open(sys.argv[1]))
rows = {r["name"]: dict(kv.split("=", 1) for kv in r["derived"].split(";"))
        for r in res["rows"]}
for name, d in rows.items():
    assert "roles" in d and "tier" in d, (name, sorted(d))
split = rows["llm_disagg_burst_split_n4"]
assert split["roles"] == "prefill+decode" and int(split["handoffs"]) > 0, split
assert split["finished"] == rows["llm_disagg_burst_mono_n4"]["finished"]
hbm = rows["llm_tier_pressure_hbm_only_r2"]
tiered = rows["llm_tier_pressure_tiered_r2"]
assert hbm["tier"].split("+")[0] == tiered["tier"].split("+")[0]  # equal HBM
assert int(tiered["promotes"]) > 0 and int(tiered["tier_hits"]) > 0, tiered
assert float(tiered["prefix_hit_rate"]) > float(hbm["prefix_hit_rate"]), (
    tiered["prefix_hit_rate"], hbm["prefix_hit_rate"])
print(f"disagg bench smoke OK: handoffs={split['handoffs']}, hit rate "
      f"{hbm['prefix_hit_rate']} -> {tiered['prefix_hit_rate']} with host tier")
PY

# Sanitized smoke (docs/static_analysis.md): one engine under all three
# runtime guards — retrace guard (strict: any steady-state recompile of a
# seen step signature raises), host-sync guard around the overlap build
# half, and per-step allocator invariant checks. Overlap + a starved
# tiered pool exercise the documented tier-drain host roundtrip, so the
# run must finish with retraces == 0, transfer_guard_trips == 0,
# invariant_checks > 0 and allowed_host_syncs > 0 — proving the allowlist
# routes the intentional copies while everything else stays guarded.
REPRO_BACKEND=ref \
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python - <<'PY'
import numpy as np, jax
from repro.config import ServeConfig, get_config
from repro.models.api import build_model
from repro.serving.engine import Request, ServingEngine

cfg = get_config("smollm-360m").reduced(dtype="float32")
model = build_model(cfg, remat=False)
params = model.init(jax.random.PRNGKey(0))
serve = ServeConfig(model=cfg.name, kv_block_size=8, max_batch=1,
                    overlap=True, eviction="tiered", host_blocks=12,
                    sanitize=True)
eng = ServingEngine(model, params, cfg, serve, num_blocks=7)
rng = np.random.default_rng(1)
prompts = [rng.integers(0, cfg.vocab_size, (24,), dtype=np.int32)
           for _ in range(3)]
rid = 0
for _ in range(2):
    for p in prompts:
        for _ in range(2):
            eng.submit(Request(req_id=rid, prompt=p, max_new_tokens=4))
            rid += 1
        eng.run_until_done()
san = eng.metrics()["sanitize"]
assert san["enabled"] is True, san
assert san["retraces"] == 0, san
assert san["transfer_guard_trips"] == 0, san
assert san["invariant_checks"] > 0, san
assert san["allowed_host_syncs"] > 0, san     # tier drains went via host_read
eng.alloc.check_invariants(drained=True)      # idle engine fully drains
print(f"sanitized smoke OK: retraces=0 trips=0 "
      f"invariant_checks={san['invariant_checks']} "
      f"allowed_host_syncs={san['allowed_host_syncs']}")
PY

# Saturation smoke under the guards: benchmarks/saturation.py itself asserts
# zero retraces / zero trips across the saturated overlap-off and overlap-on
# waves when REPRO_SANITIZE=1 (the retrace-guard assertion of the benchmark
# tier) — the run aborts on any steady-state recompile.
REPRO_SANITIZE=1 REPRO_BENCH_SMOKE=1 REPRO_BACKEND=ref \
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -c "from benchmarks import saturation; saturation.run(quick=True)" \
    >/dev/null
echo "sanitized saturation smoke OK"

# Trace-replay smoke + perf-regression gate (docs/perf_gate.md): the pinned
# mixed trace through all eight sweep configs (REPRO_BENCH_SMOKE=1 restricts
# the scenario list ONLY — traces and configs are identical to the committed
# quick-mode baseline, so the rows are bit-comparable). XLA_FLAGS forces the
# 2 host devices the pinned `dev2` sharded row needs; the module asserts its
# deterministic counters bit-identical to the single-device fcfs twin, and
# that `auto` resolved (not fell back) and met-or-beat every fixed triple;
# the check below asserts the provenance satellite (schema_version + commit +
# per-row seed) and the auto row's resolved= attribution, then the gate diffs
# the fresh rows against the committed BENCH_009.json on deterministic
# counters — a >20% scheduling/hot-path regression fails CI right here,
# wall clock never compared.
TRACE_SMOKE_JSON="$(mktemp /tmp/trace_smoke.XXXXXX.json)"
trap 'rm -f "$POLICY_SMOKE_JSON" "$SPEC_SMOKE_JSON" "$DISAGG_SMOKE_JSON" \
    "$TRACE_SMOKE_JSON"' EXIT
XLA_FLAGS="--xla_force_host_platform_device_count=2" \
REPRO_BENCH_SMOKE=1 REPRO_BACKEND=ref \
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.run --only trace_replay \
    --json "$TRACE_SMOKE_JSON" >/dev/null
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python - "$TRACE_SMOKE_JSON" <<'PY'
import json, sys

from repro.perf.table import SCHEMA_VERSION

(res,) = json.load(open(sys.argv[1]))
assert res["schema_version"] == SCHEMA_VERSION, res.get("schema_version")
assert res.get("git_commit"), "missing git_commit provenance"
rows = {r["name"]: r for r in res["rows"]}
labels = ("fcfs", "prio", "edf", "plen", "ngram", "overlap", "dev2", "auto")
for lbl in labels:
    name = f"trace_mixed_{lbl}"
    assert name in rows, f"missing sweep row {name}"
    d = dict(kv.split("=", 1) for kv in rows[name]["derived"].split(";"))
    assert rows[name].get("seed") == 404, (name, rows[name].get("seed"))
    assert d["finished"] == "12", (name, d["finished"])
    assert rows[name]["policy"] == (
        f"{d['admission']}/{d['preemption']}/{d['eviction']}"), name
auto = dict(kv.split("=", 1) for kv in
            rows["trace_mixed_auto"]["derived"].split(";"))
assert "auto" not in auto["resolved"], auto["resolved"]  # concrete triple
print(f"trace smoke OK: {len(labels)} configs on the pinned mixed trace, "
      f"auto resolved {auto['resolved']}")
PY
REPRO_BACKEND=ref \
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m repro.perf.gate --baseline BENCH_009.json \
    --current "$TRACE_SMOKE_JSON" --threshold 0.2

# Ragged-kernel + autotune-cache smoke (docs/ragged_kernel.md): the same
# deterministic greedy workload through `attn_impl=ragged` (the default —
# one ragged launch per layer over the fused head-interleaved KV pool) and
# `attn_impl=chunked` (the split-view drift oracle). Asserts BIT-IDENTICAL
# streams, the fused-pool shape (one "kv" channel, 2*num_kv_heads), the
# metrics attribution contract for the three kernel tunables, and the
# measured-autotune cache: the committed BENCH_010.json must resolve a
# tuned config for a swept (page_size, head_dim, backend) cell while an
# unknown cell falls back to the registry defaults (counted, never an
# error) — exactly the resolve path the engine runs at construction.
REPRO_BACKEND=ref \
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python - <<'PY'
import numpy as np, jax
from repro.config import ServeConfig, get_config
from repro.core import dispatch
from repro.models.api import build_model
from repro.perf import autotune
from repro.serving.engine import Request, ServingEngine

cfg = get_config("smollm-360m").reduced(dtype="float32")
model = build_model(cfg, remat=False)
params = model.init(jax.random.PRNGKey(0))

def run(attn_impl):
    serve = ServeConfig(model=cfg.name, kv_block_size=8, max_batch=2,
                        attn_impl=attn_impl)
    rng = np.random.default_rng(0)
    eng = ServingEngine(model, params, cfg, serve, num_blocks=64)
    for i in range(3):
        eng.submit(Request(
            req_id=i,
            prompt=rng.integers(0, cfg.vocab_size,
                                (int(rng.integers(4, 10)),), dtype=np.int32),
            max_new_tokens=5))
    eng.run_until_done()
    a = cfg.attention
    assert set(eng.pools) == {"kv"}, sorted(eng.pools)
    assert eng.pools["kv"].shape[3] == 2 * a.num_kv_heads, (
        eng.pools["kv"].shape)
    return {r.req_id: list(r.output) for r in eng.finished}, eng.metrics()

ragged, mr = run("ragged")
chunked, mc = run("chunked")
assert ragged == chunked, (ragged, chunked)
assert mr["attn_impl"] == "ragged" and mc["attn_impl"] == "chunked"
for k in autotune.TUNABLE_KEYS:
    assert k in mr, (k, sorted(mr))
pc = mr["policy_counters"]
assert pc["tune.tuned_resolved"] + pc["tune.tuned_fallback"] == 1, pc

# committed-table resolve: every swept cell in BENCH_010.json must answer
# with a full tunable assignment; an unknown cell must miss (-> defaults)
table = autotune.active_tune_table()
assert table is not None and table.best, "BENCH_010.json missing/empty"
(ps, hd, backend) = sorted(table.best)[0]
tuned = autotune.resolve_tunables(ps, hd, backend)
assert tuned is not None and set(tuned) == set(autotune.TUNABLE_KEYS), tuned
assert autotune.resolve_tunables(3, hd, backend) is None  # unknown cell
defaults = dispatch.get_op("paged_attention_ragged").tunables
assert set(defaults) == set(autotune.TUNABLE_KEYS), defaults
print(f"ragged smoke OK: bit-identical vs chunked; autotune table "
      f"{len(table.best)} cells, p{ps}/h{hd}/{backend} -> {tuned}")
PY
