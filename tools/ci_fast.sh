#!/usr/bin/env bash
# Fast CI tier: everything except tests marked `slow` (Pallas interpret-mode
# kernel sweeps and other multi-minute paths). Target: < 2 minutes on CPU.
# Full tier remains `PYTHONPATH=src python -m pytest -x -q`.
#
# REPRO_BACKEND=ref pins every registry-dispatched op (repro.core.dispatch)
# to the jnp reference implementations, so the fast tier is deterministic
# across hosts; tests that probe resolver precedence clear the variable
# themselves, and the backend-parity suite's fast tier (the non-slow part of
# tests/test_backend_parity.py) still exercises every registered backend via
# explicit arguments, which outrank the env pin.
set -euo pipefail
cd "$(dirname "$0")/.."
REPRO_BACKEND=ref \
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m pytest -q -m "not slow" "$@"
