"""Train DLRM-DCNv2 (RM2) end-to-end with the BatchedTable embedding path —
the paper's §4.1 technique inside a full training loop.

    PYTHONPATH=src python examples/train_dlrm.py
"""
import dataclasses
import time

import jax

from repro.config import get_config
from repro.data.pipeline import DataPipeline, SyntheticRecSysDataset
from repro.models.api import build_model
from repro.optim import adamw
from repro.optim.optimizer import apply_updates


def main() -> None:
    cfg = dataclasses.replace(get_config("rm2"), num_embeddings=5_000)
    model = build_model(cfg, use_batched=True)   # the paper's technique
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw(weight_decay=0.0)
    state = opt.init(params)

    @jax.jit
    def step(params, state, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        upd, state, _ = opt.update(grads, state, params, 1e-3)
        return apply_updates(params, upd), state, loss

    pipe = DataPipeline(SyntheticRecSysDataset(cfg, 256))
    t0 = time.time()
    first = last = None
    for i in range(30):
        _, batch = next(pipe)
        batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
        params, state, loss = step(params, state, batch)
        if i == 0:
            first = float(loss)
        last = float(loss)
        if i % 10 == 0:
            print(f"step {i:3d}  bce {float(loss):.4f}")
    pipe.close()
    print(f"30 steps in {time.time()-t0:.1f}s; loss {first:.4f} -> {last:.4f}")
    assert last < first


if __name__ == "__main__":
    main()
