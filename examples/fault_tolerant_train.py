"""Fault-tolerant training demo: checkpoint → simulated crash → restore →
continue, with straggler detection and an elastic remesh plan.

    PYTHONPATH=src python examples/fault_tolerant_train.py
"""
import tempfile

import jax

from repro.checkpoint import CheckpointManager
from repro.config import get_config
from repro.data.pipeline import DataPipeline, SyntheticLMDataset
from repro.distributed.elastic import (
    HeartbeatMonitor, StragglerWatchdog, plan_remesh)
from repro.models.api import build_model
from repro.optim import adamw, cosine_warmup
from repro.training.train_step import init_state, make_train_step
from repro.training.trainer import Trainer


def main() -> None:
    cfg = get_config("smollm-360m").reduced(dtype="float32", num_layers=2,
                                            vocab_size=512)
    model = build_model(cfg, remat=False)
    opt = adamw()
    step = jax.jit(make_train_step(model, opt, cosine_warmup(1e-3, 2, 40)))
    ds = SyntheticLMDataset(cfg.vocab_size, 32, 4)
    ckpt_dir = tempfile.mkdtemp(prefix="repro_ft_")
    cm = CheckpointManager(ckpt_dir, keep=2)

    # phase 1: train 10 steps, checkpointing every 5
    p1 = DataPipeline(ds)
    t1 = Trainer(step_fn=step, state=init_state(model, jax.random.PRNGKey(0),
                                                opt),
                 pipeline=p1, ckpt=cm, checkpoint_every=5,
                 watchdog=StragglerWatchdog(threshold=3.0))
    s1 = t1.run(10)
    p1.close()
    print(f"phase 1: loss {s1['final_loss']:.4f}, "
          f"{s1['straggler_steps']} stragglers, ckpt at {cm.latest_step()}")

    # simulated node failure: coordinator notices a dead host
    hb = HeartbeatMonitor(list(range(4)), timeout_s=1.0)
    hb.beat(0, now=100.0); hb.beat(1, now=100.0)
    hb.beat(2, now=100.0); hb.beat(3, now=90.0)
    dead = hb.dead(now=101.5)
    print(f"heartbeat: dead hosts {dead}")
    plan = plan_remesh(512 - 256, 256, model_parallel=16)
    print(f"elastic remesh plan after pod loss: {plan}")

    # phase 2: fresh process restores from the checkpoint and continues
    p2 = DataPipeline(ds, start_step=cm.latest_step())
    t2 = Trainer(step_fn=step,
                 state=init_state(model, jax.random.PRNGKey(99), opt),
                 pipeline=p2, ckpt=cm)
    resumed = t2.maybe_restore()
    s2 = t2.run(5)
    p2.close()
    print(f"phase 2: resumed from step {resumed}, loss {s2['final_loss']:.4f}")
    assert resumed == 10


if __name__ == "__main__":
    main()
