"""End-to-end LLM serving with the scheduler-driven stack: chunked prefill
fused into the decode step, prefix-cached paged KV (BlockList
PagedAttention), per-request sampling, preemption under block pressure —
running a non-default serving-policy triple (priority admission,
fewest-remaining-tokens preemption, hit-rate eviction) from the policy
registry (`repro.serving.policy`).

    PYTHONPATH=src python examples/serve_llm.py
"""
import time

import jax
import numpy as np

from repro.config import ServeConfig, get_config
from repro.models.api import build_model
from repro.serving.engine import Request, SamplingParams, ServingEngine


def main() -> None:
    cfg = get_config("qwen2-1.5b").reduced(dtype="float32")
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    serve = ServeConfig(model=cfg.name, kv_block_size=8, max_batch=4,
                        admission="priority",
                        preemption="fewest-remaining-tokens",
                        eviction="hit-rate")
    engine = ServingEngine(model, params, cfg, serve, num_blocks=128)

    rng = np.random.default_rng(0)
    # Dynamic-Sonnet-style mix: a shared "system prompt" prefix (prefix-cache
    # hits after the first wave) + per-request tails of variable length, a
    # mix of greedy and stochastic sampling policies, and interactive
    # requests marked high-priority so the admission policy reorders the
    # queue behind max_batch.
    system_prompt = rng.integers(0, cfg.vocab_size, (16,), dtype=np.int32)
    for i in range(8):
        tail = rng.integers(0, cfg.vocab_size,
                            (int(rng.integers(2, 8)),), dtype=np.int32)
        sampling = (SamplingParams() if i % 2 == 0 else
                    SamplingParams(temperature=0.8, top_k=40, top_p=0.95))
        engine.submit(Request(
            req_id=i,
            prompt=np.concatenate([system_prompt, tail]),
            max_new_tokens=int(rng.integers(4, 10)),
            priority=1 if i >= 6 else 0,        # late VIPs jump the queue
            sampling=sampling))
    t0 = time.time()
    engine.run_until_done()
    dt = time.time() - t0
    m = engine.metrics()
    print(f"served {m['finished']} requests / {m['output_tokens']} tokens "
          f"in {dt:.1f}s ({m['throughput_tok_s']:.1f} tok/s)")
    print(f"TTFT p50/p99 {m['p50_ttft_s']*1e3:.0f}/{m['p99_ttft_s']*1e3:.0f} ms, "
          f"TPOT p50/p99 {m['p50_tpot_s']*1e3:.0f}/{m['p99_tpot_s']*1e3:.0f} ms")
    print(f"prefix hit rate {m['prefix_hit_rate']:.2f} "
          f"({m['prefix_hits']} hits), preemptions {m['preemptions']}, "
          f"CoW copies {m['cow_copies']}")
    print(f"policies {m['admission_policy']}/{m['preemption_policy']}/"
          f"{m['eviction_policy']}  counters {m['policy_counters']}")
    print(f"pool leak check: {m['blocks_free']} == 128")
    assert m["blocks_free"] == 128
    assert m["prefix_hits"] > 0
    assert m["admission_policy"] == "priority"
    assert m["policy_counters"]["admission.admitted"] == 8


if __name__ == "__main__":
    main()
