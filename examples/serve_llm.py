"""End-to-end LLM serving with the paper's BlockList PagedAttention:
continuous batching, paged KV pool, TTFT/TPOT metrics.

    PYTHONPATH=src python examples/serve_llm.py
"""
import time

import jax
import numpy as np

from repro.config import ServeConfig, get_config
from repro.models.api import build_model
from repro.serving.engine import Request, ServingEngine


def main() -> None:
    cfg = get_config("qwen2-1.5b").reduced(dtype="float32")
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    serve = ServeConfig(model=cfg.name, kv_block_size=8, max_batch=4)
    engine = ServingEngine(model, params, cfg, serve, num_blocks=128)

    rng = np.random.default_rng(0)
    # Dynamic-Sonnet-style variable-length request mix (paper Fig 17 d/e)
    for i in range(8):
        engine.submit(Request(
            req_id=i,
            prompt=rng.integers(0, cfg.vocab_size,
                                (int(rng.integers(4, 20)),), dtype=np.int32),
            max_new_tokens=int(rng.integers(4, 10))))
    t0 = time.time()
    engine.run_until_done()
    dt = time.time() - t0
    m = engine.metrics()
    print(f"served {m['finished']} requests / {m['output_tokens']} tokens "
          f"in {dt:.1f}s")
    print(f"TTFT {m['mean_ttft_s']*1e3:.0f} ms, TPOT {m['mean_tpot_s']*1e3:.0f}"
          f" ms, pool leak check: {m['blocks_free']} == 128")
    assert m["blocks_free"] == 128


if __name__ == "__main__":
    main()
