"""Quickstart: build any assigned architecture, run a forward pass, a train
step, and a paged-attention decode — on CPU in under a minute.

    PYTHONPATH=src python examples/quickstart.py --arch qwen3-32b
"""
import argparse

import jax
import jax.numpy as jnp

from repro.config import get_config, list_configs
from repro.models.api import build_model
from repro.optim import adamw
from repro.optim.optimizer import apply_updates


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="qwen2-1.5b", choices=list_configs())
    args = p.parse_args()

    cfg = get_config(args.arch)
    print(f"{cfg.name}: {cfg.num_params()/1e9:.2f}B params "
          f"({getattr(cfg, 'family', 'recsys')})")
    reduced = cfg.reduced(dtype="float32") if hasattr(cfg, "reduced") else cfg
    model = build_model(reduced, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"reduced smoke config: {n/1e6:.2f}M params")

    if hasattr(reduced, "vocab_size"):
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                  reduced.vocab_size)
        extra = None
        if reduced.family == "vlm":
            extra = jnp.zeros((2, reduced.vision_tokens, reduced.d_model))
        if reduced.family == "audio":
            extra = jnp.zeros((2, reduced.encoder_seq, reduced.d_model))
        logits, _ = model.forward(params, toks, extra)
        print("forward:", logits.shape)

        batch = {"tokens": toks}
        if extra is not None:
            batch["extra_embeds"] = extra
        opt = adamw()
        state = opt.init(params)
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        upd, state, gnorm = opt.update(grads, state, params, 1e-3)
        params = apply_updates(params, upd)
        print(f"train step: loss={float(loss):.4f} grad_norm={float(gnorm):.3f}")
    print("quickstart OK")


if __name__ == "__main__":
    main()
