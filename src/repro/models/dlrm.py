"""DLRM-DCNv2 (paper Table 3, RM1/RM2) with the paper's BatchedTable
embedding technique as a first-class switch (`use_batched=True` default;
False = SingleTable baseline, per-table launches)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import DLRMConfig
from repro.core import embedding_api


def _mlp_init(key, dims, dtype):
    ks = jax.random.split(key, len(dims) - 1)
    return [{"w": (jax.random.normal(k, (a, b), jnp.float32) * a ** -0.5).astype(dtype),
             "b": jnp.zeros((b,), dtype)}
            for k, a, b in zip(ks, dims[:-1], dims[1:])]


def _mlp_apply(layers, x, final_act=False):
    for i, l in enumerate(layers):
        x = x @ l["w"] + l["b"]
        if i < len(layers) - 1 or final_act:
            x = jax.nn.relu(x)
    return x


class DLRM:
    def __init__(self, cfg: DLRMConfig, *, use_batched: bool = True,
                 backend: Optional[str] = None):
        self.cfg = cfg
        self.use_batched = use_batched
        self.backend = backend
        self.inter_dim = cfg.bottom_mlp[-1] + cfg.num_tables * cfg.embedding_dim

    def init(self, key, dtype=jnp.float32):
        cfg = self.cfg
        ke, kb, kt, kc = jax.random.split(key, 4)
        emb = (jax.random.normal(
            ke, (cfg.num_tables * cfg.num_embeddings, cfg.embedding_dim),
            jnp.float32) * cfg.embedding_dim ** -0.5).astype(dtype)
        offsets = jnp.arange(cfg.num_tables, dtype=jnp.int32) * cfg.num_embeddings
        cross_keys = jax.random.split(kc, cfg.cross_layers)
        d, r = self.inter_dim, cfg.cross_rank
        cross = [{
            "u": (jax.random.normal(jax.random.fold_in(k, 0), (d, r), jnp.float32)
                  * d ** -0.5).astype(dtype),
            "v": (jax.random.normal(jax.random.fold_in(k, 1), (r, d), jnp.float32)
                  * r ** -0.5).astype(dtype),
            "b": jnp.zeros((d,), dtype),
        } for k in cross_keys]
        return {
            "embedding": emb,
            "table_offsets": offsets,
            "bottom": _mlp_init(kb, (cfg.dense_features,) + cfg.bottom_mlp, dtype),
            "cross": cross,
            "top": _mlp_init(kt, (d,) + cfg.top_mlp, dtype),
        }

    def init_abstract(self, dtype=jnp.float32):
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0), dtype))

    def embedding_lookup(self, params, indices):
        """indices (B, T, L) -> pooled (B, T, D)."""
        if self.use_batched:  # the paper's BatchedTable: ONE fused lookup
            return embedding_api.embedding_bag(
                params["embedding"], params["table_offsets"], indices,
                backend=self.backend)
        # SingleTable baseline: per-table gathers (T separate ops)
        tables = [
            jax.lax.dynamic_slice_in_dim(
                params["embedding"], t * self.cfg.num_embeddings,
                self.cfg.num_embeddings, axis=0)
            for t in range(self.cfg.num_tables)
        ]
        return embedding_api.single_table_lookup(tables, indices)

    def forward(self, params, batch):
        """batch: {"dense": (B, 13) f32, "indices": (B, T, L) i32}."""
        dense = _mlp_apply(params["bottom"], batch["dense"], final_act=True)
        pooled = self.embedding_lookup(params, batch["indices"])
        B = dense.shape[0]
        x0 = jnp.concatenate([dense, pooled.reshape(B, -1)], axis=-1)
        x = x0
        for l in params["cross"]:      # DCNv2 low-rank cross layers
            x = x0 * ((x @ l["u"]) @ l["v"] + l["b"]) + x
        return _mlp_apply(params["top"], x)[:, 0]   # (B,) logit

    def loss(self, params, batch):
        logit = self.forward(params, batch)
        y = batch["label"].astype(jnp.float32)
        z = logit.astype(jnp.float32)
        # numerically stable BCE-with-logits
        return jnp.mean(jnp.maximum(z, 0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z))))
