"""Whisper-tiny enc-dec backbone. The conv/mel frontend is a STUB — per the
assignment, ``input_specs()`` supplies precomputed frame embeddings
(B, encoder_seq, d_model). LayerNorm + GELU per the original; RoPE replaces
learned positions so the mechanical decode_32k cell lowers cleanly."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.layers import attention as attn_lib
from repro.layers.embedding import embed, embedding_init, head_init, unembed
from repro.layers.mlp import mlp_apply, mlp_init
from repro.layers.norm import layernorm, layernorm_init
from repro.distributed.act_sharding import constrain_batch
from repro.training import remat as remat_lib

NEG_INF = -1e30


class WhisperEncDec:
    def __init__(self, cfg: ModelConfig, *, q_chunk: int = 512,
                 remat: bool = True, scan_layers: bool = True,
                 unroll_attn: bool = False):
        self.cfg = cfg
        self.q_chunk = q_chunk
        self.remat = remat
        self.scan_layers = scan_layers
        self.unroll_attn = unroll_attn
        self.dtype = jnp.dtype(cfg.dtype)

    def _run_layers(self, inner, x, layers, n: int):
        def body(x, lp):
            return inner(constrain_batch(x), lp)
        bf = remat_lib.wrap(body, self.remat)
        if self.scan_layers:
            x, _ = jax.lax.scan(bf, x, layers)
            return x
        for i in range(n):
            x, _ = bf(x, jax.tree.map(lambda t: t[i], layers))
        return x

    def _enc_layer_init(self, key):
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        return {
            "ln1": layernorm_init(cfg.d_model, self.dtype),
            "attn": attn_lib.attention_init(k1, cfg.d_model, cfg.attention,
                                            self.dtype),
            "ln2": layernorm_init(cfg.d_model, self.dtype),
            "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, "gelu", self.dtype),
        }

    def _dec_layer_init(self, key):
        cfg = self.cfg
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "ln1": layernorm_init(cfg.d_model, self.dtype),
            "self_attn": attn_lib.attention_init(k1, cfg.d_model,
                                                 cfg.attention, self.dtype),
            "ln_x": layernorm_init(cfg.d_model, self.dtype),
            "cross_attn": attn_lib.attention_init(k2, cfg.d_model,
                                                  cfg.attention, self.dtype),
            "ln2": layernorm_init(cfg.d_model, self.dtype),
            "mlp": mlp_init(k3, cfg.d_model, cfg.d_ff, "gelu", self.dtype),
        }

    def init(self, key):
        cfg = self.cfg
        ke, kenc, kdec, kh = jax.random.split(key, 4)
        return {
            "embed": embedding_init(ke, cfg.vocab_size, cfg.d_model, self.dtype),
            "enc_layers": jax.vmap(self._enc_layer_init)(
                jax.random.split(kenc, cfg.encoder_layers)),
            "enc_norm": layernorm_init(cfg.d_model, self.dtype),
            "dec_layers": jax.vmap(self._dec_layer_init)(
                jax.random.split(kdec, cfg.num_layers)),
            "final_norm": layernorm_init(cfg.d_model, self.dtype),
            "head": head_init(kh, cfg.vocab_size, cfg.d_model, self.dtype),
        }

    def init_abstract(self):
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))

    def encode(self, params, frames):
        """frames (B, enc_seq, D) stub embeddings -> encoder output."""
        cfg = self.cfg
        B, S, _ = frames.shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))

        def body(x, lp):
            h, _ = attn_lib.attention_block(
                lp["attn"], layernorm(lp["ln1"], x, cfg.norm_eps), positions,
                cfg.attention, causal=False, chunk=self.q_chunk,
                unroll=self.unroll_attn)
            x = x + h
            h = mlp_apply(lp["mlp"], layernorm(lp["ln2"], x, cfg.norm_eps),
                          "gelu")
            return x + h, None

        x = self._run_layers(body, frames.astype(self.dtype),
                             params["enc_layers"], cfg.encoder_layers)
        return layernorm(params["enc_norm"], x, cfg.norm_eps)

    def forward(self, params, tokens, extra_embeds=None, *, last_only: bool = False):
        """Teacher-forced train/prefill. extra_embeds = encoder frames stub."""
        cfg = self.cfg
        enc = self.encode(params, extra_embeds)
        x = embed(params["embed"], tokens)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))

        def body(x, lp):
            h, _ = attn_lib.attention_block(
                lp["self_attn"], layernorm(lp["ln1"], x, cfg.norm_eps),
                positions, cfg.attention, causal=True, chunk=self.q_chunk,
                unroll=self.unroll_attn)
            x = x + h
            kv = attn_lib.encode_kv(lp["cross_attn"], enc, cfg.attention)
            h = attn_lib.cross_attention_block(
                lp["cross_attn"], layernorm(lp["ln_x"], x, cfg.norm_eps), kv,
                cfg.attention)
            x = x + h
            h = mlp_apply(lp["mlp"], layernorm(lp["ln2"], x, cfg.norm_eps),
                          "gelu")
            return x + h, None

        x = self._run_layers(body, x, params["dec_layers"], cfg.num_layers)
        if last_only:
            x = x[:, -1:]
        x = layernorm(params["final_norm"], x, cfg.norm_eps)
        return unembed(params["head"], x), jnp.zeros((), jnp.float32)

    # ---------------------------------------------------------------- decode
    def init_decode_cache(self, batch: int, max_seq: int):
        cfg = self.cfg
        a = cfg.attention
        L = cfg.num_layers
        return {
            "k": jnp.zeros((L, batch, max_seq, a.num_kv_heads, a.head_dim),
                           self.dtype),
            "v": jnp.zeros((L, batch, max_seq, a.num_kv_heads, a.head_dim),
                           self.dtype),
            # cross-attn K/V precomputed from the encoder at prefill
            "xk": jnp.zeros((L, batch, cfg.encoder_seq, a.num_kv_heads,
                             a.head_dim), self.dtype),
            "xv": jnp.zeros((L, batch, cfg.encoder_seq, a.num_kv_heads,
                             a.head_dim), self.dtype),
            "seq_lens": jnp.zeros((batch,), jnp.int32),
        }

    def prefill_cross(self, params, cache, frames):
        """Encode once; fill cross-attn KV for every decoder layer."""
        enc = self.encode(params, frames)

        def per_layer(lp):
            return attn_lib.encode_kv(lp["cross_attn"], enc, self.cfg.attention)

        xk, xv = jax.vmap(per_layer)(params["dec_layers"])
        return dict(cache, xk=xk, xv=xv)

    def decode_step(self, params, cache, tokens):
        cfg = self.cfg
        a = cfg.attention
        seq_lens = cache["seq_lens"]
        x = embed(params["embed"], tokens)

        def body(x, inp):
            lp, k_c, v_c, xk, xv = inp
            x = constrain_batch(x)
            h = layernorm(lp["ln1"], x[:, None], cfg.norm_eps)
            q, k_new, v_new = attn_lib.project_qkv(lp["self_attn"], h, a,
                                                   seq_lens[:, None])
            k_c = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice_in_dim(
                c, n, i, axis=0))(k_c, k_new, seq_lens)
            v_c = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice_in_dim(
                c, n, i, axis=0))(v_c, v_new, seq_lens)
            B = x.shape[0]
            KV = a.num_kv_heads
            qg = q[:, 0].reshape(B, KV, a.num_heads // KV, a.head_dim)
            scores = jnp.einsum("bkgd,bskd->bkgs", qg, k_c).astype(jnp.float32)
            scores = scores * a.head_dim ** -0.5
            mask = jnp.arange(k_c.shape[1])[None] <= seq_lens[:, None]
            scores = jnp.where(mask[:, None, None], scores, NEG_INF)
            w = jax.nn.softmax(scores, axis=-1).astype(v_c.dtype)
            ctx = jnp.einsum("bkgs,bskd->bkgd", w, v_c).reshape(B, -1)
            x = x + jnp.einsum("be,ed->bd", ctx, lp["self_attn"]["wo"])
            # cross attention against precomputed encoder KV
            hx = layernorm(lp["ln_x"], x[:, None], cfg.norm_eps)
            o = attn_lib.cross_attention_block(lp["cross_attn"], hx, (xk, xv), a)
            x = x + o[:, 0]
            h = mlp_apply(lp["mlp"], layernorm(lp["ln2"], x[:, None],
                                               cfg.norm_eps), "gelu")
            return x + h[:, 0], (k_c, v_c)

        if self.scan_layers:
            x, (k, v) = jax.lax.scan(
                body, x, (params["dec_layers"], cache["k"], cache["v"],
                          cache["xk"], cache["xv"]))
        else:
            outs = []
            for i in range(cfg.num_layers):
                x, o = body(x, jax.tree.map(
                    lambda t: t[i], (params["dec_layers"], cache["k"],
                                     cache["v"], cache["xk"], cache["xv"])))
                outs.append(o)
            k, v = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        x = layernorm(params["final_norm"], x[:, None], cfg.norm_eps)
        logits = unembed(params["head"], x)[:, 0]
        return logits, dict(cache, k=k, v=v, seq_lens=seq_lens + 1)

    def loss(self, params, batch):
        logits, _ = self.forward(params, batch["tokens"],
                                 batch.get("extra_embeds"))
        from repro.training.losses import next_token_loss
        return next_token_loss(logits, batch["tokens"])
