"""RWKV6 "Finch" LM — attention-free; the paper's PagedAttention technique is
inapplicable here (no KV cache to page; see DESIGN.md §Arch-applicability).
Serving carries a constant-size recurrent state instead."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.layers import rwkv as rwkv_lib
from repro.layers.embedding import embed, embedding_init, head_init, unembed
from repro.layers.norm import layernorm, layernorm_init
from repro.distributed.act_sharding import constrain_batch
from repro.training import remat as remat_lib


class RWKV6LM:
    def __init__(self, cfg: ModelConfig, *, remat: bool = True,
                 scan_layers: bool = True):
        self.cfg = cfg
        self.remat = remat
        self.scan_layers = scan_layers
        self.dtype = jnp.dtype(cfg.dtype)

    def _layer_init(self, key):
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        return {
            "ln1": layernorm_init(cfg.d_model, self.dtype),
            "ln2": layernorm_init(cfg.d_model, self.dtype),
            "tm": rwkv_lib.rwkv_time_mix_init(k1, cfg.d_model, cfg.rwkv, self.dtype),
            "cm": rwkv_lib.rwkv_channel_mix_init(k2, cfg.d_model, cfg.d_ff, self.dtype),
        }

    def init(self, key):
        cfg = self.cfg
        ke, kl, kh = jax.random.split(key, 3)
        return {
            "embed": embedding_init(ke, cfg.vocab_size, cfg.d_model, self.dtype),
            "ln0": layernorm_init(cfg.d_model, self.dtype),
            "layers": jax.vmap(self._layer_init)(jax.random.split(kl, cfg.num_layers)),
            "final_norm": layernorm_init(cfg.d_model, self.dtype),
            "head": head_init(kh, cfg.vocab_size, cfg.d_model, self.dtype),
        }

    def init_abstract(self):
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))

    def forward(self, params, tokens, extra_embeds=None, *, last_only: bool = False):
        cfg = self.cfg
        x = layernorm(params["ln0"], embed(params["embed"], tokens))

        def body(x, lp):
            x = constrain_batch(x)
            h = rwkv_lib.time_mix_chunked(
                lp["tm"], layernorm(lp["ln1"], x, cfg.norm_eps), cfg.rwkv)
            x = x + h
            h, _ = rwkv_lib.channel_mix(
                lp["cm"], layernorm(lp["ln2"], x, cfg.norm_eps))
            return x + h, None

        if self.scan_layers:
            body_fn = remat_lib.wrap(body, self.remat)
            x, _ = jax.lax.scan(body_fn, x, params["layers"])
        else:
            body_fn = remat_lib.wrap(body, self.remat)
            for i in range(cfg.num_layers):
                lp = jax.tree.map(lambda t: t[i], params["layers"])
                x, _ = body_fn(x, lp)
        if last_only:
            x = x[:, -1:]
        x = layernorm(params["final_norm"], x, cfg.norm_eps)
        return unembed(params["head"], x), jnp.zeros((), jnp.float32)

    # ---------------------------------------------------------------- decode
    def init_decode_cache(self, batch: int, max_seq: int = 0):
        cfg = self.cfg
        L, D = cfg.num_layers, cfg.d_model
        H = D // cfg.rwkv.head_size
        N = cfg.rwkv.head_size
        return {
            "tm_shift": jnp.zeros((L, batch, D), self.dtype),
            "cm_shift": jnp.zeros((L, batch, D), self.dtype),
            "S": jnp.zeros((L, batch, H, N, N), jnp.float32),
            "seq_lens": jnp.zeros((batch,), jnp.int32),
        }

    def decode_step(self, params, cache, tokens):
        cfg = self.cfg
        x = layernorm(params["ln0"], embed(params["embed"], tokens))  # (B,D)

        def body(x, inp):
            lp, tm_sh, cm_sh, S = inp
            x = constrain_batch(x)
            h = layernorm(lp["ln1"], x[:, None], cfg.norm_eps)
            o, st = rwkv_lib.time_mix_step(
                lp["tm"], h, {"shift": tm_sh, "S": S}, cfg.rwkv)
            x = x + o[:, 0]
            new_tm_sh, new_S = h[:, 0], st["S"]
            h = layernorm(lp["ln2"], x[:, None], cfg.norm_eps)
            o, new_cm_sh = rwkv_lib.channel_mix(lp["cm"], h, cm_sh)
            return x + o[:, 0], (new_tm_sh, new_cm_sh, new_S)

        if self.scan_layers:
            x, (tm_sh, cm_sh, S) = jax.lax.scan(
                body, x, (params["layers"], cache["tm_shift"],
                          cache["cm_shift"], cache["S"]))
        else:
            outs = []
            for i in range(cfg.num_layers):
                inp = jax.tree.map(
                    lambda t: t[i], (params["layers"], cache["tm_shift"],
                                     cache["cm_shift"], cache["S"]))
                x, o = body(x, inp)
                outs.append(o)
            tm_sh, cm_sh, S = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        x = layernorm(params["final_norm"], x[:, None], cfg.norm_eps)
        logits = unembed(params["head"], x)[:, 0]
        return logits, {"tm_shift": tm_sh, "cm_shift": cm_sh, "S": S,
                        "seq_lens": cache["seq_lens"] + 1}

    def loss(self, params, batch):
        logits, aux = self.forward(params, batch["tokens"])
        from repro.training.losses import next_token_loss
        return next_token_loss(logits, batch["tokens"])
