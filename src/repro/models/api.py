"""Model factory + input_specs builder (ShapeDtypeStruct stand-ins).

``build_model(cfg)`` dispatches on family. ``input_specs(cfg, shape, kind)``
returns jax.ShapeDtypeStruct pytrees for every model input — weak-type
correct, shardable, no device allocation — consumed by the dry-run.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.config import (
    DLRMConfig, ModelConfig, ShapeCell, get_config)


def build_model(cfg, **kw) -> Any:
    if isinstance(cfg, str):
        cfg = get_config(cfg)
    if isinstance(cfg, DLRMConfig):
        from repro.models.dlrm import DLRM
        return DLRM(cfg, **kw)
    assert isinstance(cfg, ModelConfig)
    if cfg.family == "ssm":
        from repro.models.rwkv6 import RWKV6LM
        kw.pop("q_chunk", None)  # attention-free
        kw.pop("unroll_attn", None)
        kw.pop("moe_groups", None)
        return RWKV6LM(cfg, **kw)
    if cfg.family == "hybrid":
        from repro.models.zamba2 import Zamba2LM
        return Zamba2LM(cfg, **kw)
    if cfg.family == "audio":
        from repro.models.whisper import WhisperEncDec
        return WhisperEncDec(cfg, **kw)
    from repro.models.transformer import TransformerLM
    return TransformerLM(cfg, **kw)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, cell: ShapeCell) -> Dict[str, Any]:
    """Model-input stand-ins for one (arch × shape) dry-run cell.

    train/prefill: tokens (B, S) [+ stub frontend embeddings for vlm/audio —
    the text sequence shrinks so total context == cell.seq_len].
    decode: tokens (B,) one new token (KV cache shapes come from the model).
    """
    B, S = cell.global_batch, cell.seq_len
    if cell.kind in ("train", "prefill"):
        specs: Dict[str, Any] = {}
        if cfg.family == "vlm":
            n_img = cfg.vision_tokens
            specs["extra_embeds"] = _sds((B, n_img, cfg.d_model), jnp.bfloat16)
            specs["tokens"] = _sds((B, S - n_img), jnp.int32)
        elif cfg.family == "audio":
            specs["extra_embeds"] = _sds((B, cfg.encoder_seq, cfg.d_model),
                                         jnp.bfloat16)
            specs["tokens"] = _sds((B, S), jnp.int32)
        else:
            specs["tokens"] = _sds((B, S), jnp.int32)
        return specs
    # decode: one token per request
    return {"tokens": _sds((B,), jnp.int32)}


def dlrm_input_specs(cfg: DLRMConfig, batch: int) -> Dict[str, Any]:
    return {
        "dense": _sds((batch, cfg.dense_features), jnp.float32),
        "indices": _sds((batch, cfg.num_tables, cfg.gathers_per_table),
                        jnp.int32),
        "label": _sds((batch,), jnp.int32),
    }
