"""Decoder-only transformer LM: dense + MoE + VLM variants, scan-over-layers.

Covers qwen3-moe-235b, granite-moe-1b, qwen2-1.5b, qwen3-32b, internlm2-20b,
smollm-360m, internvl2-26b (ViT-stub), llama31-8b/70b.

Functional API:
  init(key)                                -> params
  forward(params, tokens, extra_embeds)    -> logits (train / prefill)
  forward_with_kv(...)                     -> (logits, (k, v) stacked (L,...))
  init_decode_cache(batch, max_seq)        -> contiguous cache pytree
  decode_step(params, cache, tokens)       -> (logits, cache)       [pjit path]
  decode_step_paged(params, pools, lists…) -> (logits, pools)       [paper path]
  decode_tokens_paged(params, pools, …)    -> (logits, pools)  [chunked prefill
                                               + decode fused in one program]
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.core import attention_api, paged_kv
from repro.distributed.act_sharding import constrain_batch
from repro.training import remat as remat_lib
from repro.layers import attention as attn_lib
from repro.layers import moe as moe_lib
from repro.layers.embedding import embed, embedding_init, head_init, unembed
from repro.layers.mlp import mlp_apply, mlp_init
from repro.layers.norm import rmsnorm, rmsnorm_init
from repro.layers.rope import apply_rope

NEG_INF = -1e30


class TransformerLM:
    def __init__(self, cfg: ModelConfig, *, q_chunk: int = 512,
                 shard_moe: bool = False, remat: bool = True,
                 scan_layers: bool = True, unroll_attn: bool = False,
                 moe_groups: int = 1):
        self.cfg = cfg
        self.q_chunk = q_chunk
        self.shard_moe = shard_moe
        self.remat = remat
        self.scan_layers = scan_layers
        self.unroll_attn = unroll_attn
        self.moe_groups = moe_groups
        self.dtype = jnp.dtype(cfg.dtype)

    # ------------------------------------------------------------------ init
    def _layer_init(self, key):
        cfg = self.cfg
        k1, k2, k3, k4 = jax.random.split(key, 4)
        p = {
            "ln1": rmsnorm_init(cfg.d_model, self.dtype),
            "ln2": rmsnorm_init(cfg.d_model, self.dtype),
            "attn": attn_lib.attention_init(k1, cfg.d_model, cfg.attention,
                                            self.dtype),
        }
        if cfg.moe is not None:
            p["moe"] = moe_lib.moe_init(k2, cfg.d_model, cfg.moe, self.dtype)
        else:
            p["mlp"] = mlp_init(k3, cfg.d_model, cfg.d_ff, cfg.act, self.dtype)
        return p

    def init(self, key):
        cfg = self.cfg
        ke, kl, kh = jax.random.split(key, 3)
        layer_keys = jax.random.split(kl, cfg.num_layers)
        params = {
            "embed": embedding_init(ke, cfg.vocab_size, cfg.d_model, self.dtype),
            "layers": jax.vmap(self._layer_init)(layer_keys),
            "final_norm": rmsnorm_init(cfg.d_model, self.dtype),
        }
        if not cfg.tie_embeddings:
            params["head"] = head_init(kh, cfg.vocab_size, cfg.d_model, self.dtype)
        return params

    def init_abstract(self):
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))

    # --------------------------------------------------------------- forward
    def _block(self, lp, x, positions, *, collect_kv: bool):
        cfg = self.cfg
        x = constrain_batch(x)
        h, kv = attn_lib.attention_block(
            lp["attn"], rmsnorm(lp["ln1"], x, cfg.norm_eps), positions,
            cfg.attention, chunk=self.q_chunk, unroll=self.unroll_attn)
        x = x + h
        if cfg.moe is not None:
            h, aux = moe_lib.moe_apply(
                lp["moe"], rmsnorm(lp["ln2"], x, cfg.norm_eps), cfg.moe,
                shard=self.shard_moe, groups=self.moe_groups)
        else:
            h = mlp_apply(lp["mlp"], rmsnorm(lp["ln2"], x, cfg.norm_eps), cfg.act)
            aux = jnp.zeros((), jnp.float32)
        return x + h, aux, kv

    def _embed_inputs(self, params, tokens, extra_embeds):
        x = embed(params["embed"], tokens)
        if extra_embeds is not None:  # VLM: prepend vision-stub embeddings
            x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
        return x

    def forward(self, params, tokens, extra_embeds=None, *,
                return_kv: bool = False, last_only: bool = False):
        cfg = self.cfg
        x = self._embed_inputs(params, tokens, extra_embeds)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))

        def body(carry, lp):
            x, aux_sum = carry
            x, aux, kv = self._block(lp, x, positions, collect_kv=return_kv)
            return (x, aux_sum + aux), (kv if return_kv else None)

        if self.scan_layers:
            body_fn = remat_lib.wrap(body, self.remat)
            (x, aux), kvs = jax.lax.scan(
                body_fn, (x, jnp.zeros((), jnp.float32)), params["layers"])
        else:  # unrolled (cost probes / scan-vs-unroll experiments)
            body_fn = remat_lib.wrap(body, self.remat)
            carry = (x, jnp.zeros((), jnp.float32))
            kv_list = []
            for i in range(cfg.num_layers):
                lp = jax.tree.map(lambda t: t[i], params["layers"])
                carry, kv = body_fn(carry, lp)
                if return_kv:
                    kv_list.append(kv)
            x, aux = carry
            kvs = (jax.tree.map(lambda *xs: jnp.stack(xs), *kv_list)
                   if return_kv else None)
        if last_only:
            x = x[:, -1:]
        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = unembed(params.get("head", params["embed"]), x)
        if return_kv:
            return logits, aux, kvs
        return logits, aux

    # ---------------------------------------------------------------- decode
    def init_decode_cache(self, batch: int, max_seq: int):
        cfg = self.cfg
        a = cfg.attention
        shape = (cfg.num_layers, batch, max_seq, a.num_kv_heads, a.head_dim)
        return {
            "k": jnp.zeros(shape, self.dtype),
            "v": jnp.zeros(shape, self.dtype),
            "seq_lens": jnp.zeros((batch,), jnp.int32),
        }

    def _decode_attn(self, lp, x, k_cache, v_cache, seq_lens):
        """One decode token against a contiguous cache.

        §Perf A2 (revised): GSPMD splits the softmax over the model-sharded
        seq dim into local partials + tiny stat all-reduces on its own, so
        the dense form IS flash-decoding at the collective level; an
        explicit KV-chunk scan (tried first) broke the seq sharding and
        all-gathered every chunk. Scores use ``preferred_element_type`` so
        no f32 copies of q/k/cache are materialized.
        """
        cfg = self.cfg
        a = cfg.attention
        B = x.shape[0]
        q, k_new, v_new = attn_lib.project_qkv(
            lp["attn"], x[:, None], a, seq_lens[:, None])
        q = q[:, 0]                                       # (B,H,hd)
        # append new kv at position seq_lens
        k_cache = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice_in_dim(
            c, n, i, axis=0))(k_cache, k_new, seq_lens)
        v_cache = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice_in_dim(
            c, n, i, axis=0))(v_cache, v_new, seq_lens)
        S = k_cache.shape[1]
        KV = a.num_kv_heads
        qg = q.reshape(B, KV, a.num_heads // KV, a.head_dim)
        scores = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache,
                            preferred_element_type=jnp.float32)
        scores = scores * a.head_dim ** -0.5
        mask = jnp.arange(S)[None] <= seq_lens[:, None]   # includes new token
        scores = jnp.where(mask[:, None, None], scores, NEG_INF)
        w = jax.nn.softmax(scores, axis=-1).astype(v_cache.dtype)
        ctx = jnp.einsum("bkgs,bskd->bkgd", w, v_cache)
        return ctx.reshape(B, -1), k_cache, v_cache

    def decode_step(self, params, cache, tokens):
        """tokens (B,) -> logits (B, V); contiguous cache (pjit path)."""
        cfg = self.cfg
        seq_lens = cache["seq_lens"]
        x = embed(params["embed"], tokens)                # (B, D)

        def body(x, inp):
            lp, k_c, v_c = inp
            x = constrain_batch(x)
            h = rmsnorm(lp["ln1"], x[:, None], cfg.norm_eps)[:, 0]
            ctx, k_c, v_c = self._decode_attn(lp, h, k_c, v_c, seq_lens)
            x = x + jnp.einsum("be,ed->bd", ctx, lp["attn"]["wo"])
            h = rmsnorm(lp["ln2"], x[:, None], cfg.norm_eps)
            if cfg.moe is not None:
                o, _ = moe_lib.moe_apply(lp["moe"], h, cfg.moe,
                                         shard=self.shard_moe,
                                         full_capacity=True,
                                         groups=self.moe_groups)
            else:
                o = mlp_apply(lp["mlp"], h, cfg.act)
            return x + o[:, 0], (k_c, v_c)

        if self.scan_layers:
            x, (k, v) = jax.lax.scan(body, x, (params["layers"], cache["k"],
                                               cache["v"]))
        else:
            ks, vs = [], []
            for i in range(cfg.num_layers):
                inp = jax.tree.map(lambda t: t[i],
                                   (params["layers"], cache["k"], cache["v"]))
                x, (k_i, v_i) = body(x, inp)
                ks.append(k_i)
                vs.append(v_i)
            k, v = jnp.stack(ks), jnp.stack(vs)
        x = rmsnorm(params["final_norm"], x[:, None], cfg.norm_eps)
        logits = unembed(params.get("head", params["embed"]), x)[:, 0]
        new_cache = {"k": k, "v": v, "seq_lens": seq_lens + 1}
        return logits, new_cache

    def decode_step_paged(self, params, pools, lists, tokens, *,
                          axis: Optional[str] = None,
                          attn_backend: Optional[str] = None):
        """Paged decode (the paper's technique).

        pools: {"k","v"} (L, NB, BS, KV, HD); lists: dict with block_list /
        block_req / block_pos (flat BlockList), seq_lens (B,), slots (B,2).
        ``axis`` set ⇒ running inside shard_map with the pool sequence-sharded
        over that mesh axis (flash-decoding combine).  ``attn_backend``
        routes the attention op through the unified registry (resolved
        host-side at trace time; the sharded path is collective-combined and
        stays on its shard_map implementation).
        """
        cfg = self.cfg
        a = cfg.attention
        seq_lens = lists["seq_lens"]
        x = embed(params["embed"], tokens)

        def body(x, inp):
            lp, pk, pv = inp
            h = rmsnorm(lp["ln1"], x[:, None], cfg.norm_eps)
            q, k_new, v_new = attn_lib.project_qkv(lp["attn"], h, a,
                                                   seq_lens[:, None])
            # Non-owning ranks carry out-of-bounds slots -> scatter drops them.
            pk = paged_kv.append_to_pool(pk, k_new[:, 0], lists["slots"])
            pv = paged_kv.append_to_pool(pv, v_new[:, 0], lists["slots"])
            if axis is None:
                ctx = attention_api.paged_attention(
                    q[:, 0], pk, pv, lists["block_list"], lists["block_req"],
                    lists["block_pos"], seq_lens + 1, backend=attn_backend)
            else:
                ctx = attention_api.paged_attention_sharded(
                    q[:, 0], pk, pv, lists["block_list"], lists["block_req"],
                    lists["block_pos"], seq_lens + 1, axis=axis)
            x = x + jnp.einsum("be,ed->bd", ctx.reshape(x.shape[0], -1),
                               lp["attn"]["wo"])
            h = rmsnorm(lp["ln2"], x[:, None], cfg.norm_eps)
            if cfg.moe is not None:
                o, _ = moe_lib.moe_apply(lp["moe"], h, cfg.moe,
                                         shard=self.shard_moe,
                                         full_capacity=True,
                                         groups=self.moe_groups)
            else:
                o = mlp_apply(lp["mlp"], h, cfg.act)
            return x + o[:, 0], (pk, pv)

        x, (pk, pv) = jax.lax.scan(body, x, (params["layers"], pools["k"],
                                             pools["v"]))
        x = rmsnorm(params["final_norm"], x[:, None], cfg.norm_eps)
        logits = unembed(params.get("head", params["embed"]), x)[:, 0]
        return logits, {"k": pk, "v": pv}

    def _sharded_append_attend(self, mesh, axis, q, k_new, v_new, pkv,
                               lists, attn_impl="ragged"):
        """One layer's pool append + attention under shard_map (mesh path).

        ``pkv`` is the FUSED head-interleaved pool layer, sequence-sharded
        on its block dimension over ``axis``;
        ``block_list``/``block_req``/``block_pos`` are the (S, M) per-shard
        LOCAL BlockLists from ``BlockAllocator.build_sharded_block_lists``.
        Each rank translates the global write slots to local indices
        (non-owned lanes get an out-of-bounds sentinel the scatter drops),
        appends its lanes' interleaved KV to its pool shard in ONE scatter,
        computes flash partials against its local list, and the log-sum-exp
        combine (``paged_attention_ragged_sharded`` /
        ``paged_attention_chunked_sharded`` per ``attn_impl``; the ragged
        form derives its lanes from the replicated cu prefix sums) reduces
        across ``axis`` — the KV never leaves its shard.
        """
        from jax.sharding import PartitionSpec as P

        from repro.kernels.compat import shard_map

        ragged = attn_impl == "ragged"

        def local(q, k_new, v_new, pkv, bl, br, bp, kv_lens, token_req,
                  token_pos, cu_q, cu_kv, seq_slot, slots):
            s = jax.lax.axis_index(axis)
            per = pkv.shape[0]                      # local blocks per shard
            blk = slots[:, 0]
            # Non-owned lanes -> index == per: out of local bounds, dropped.
            local_blk = jnp.where(blk // per == s, blk - s * per, per)
            lslots = jnp.stack([local_blk, slots[:, 1]], axis=-1)
            pkv = paged_kv.append_to_pool(
                pkv, paged_kv.fuse_kv_heads(k_new, v_new), lslots)
            if ragged:
                ctx = attention_api.paged_attention_ragged_sharded(
                    q, pkv, bl[0], br[0], bp[0], cu_q, cu_kv, seq_slot,
                    axis=axis)
            else:
                pk, pv = paged_kv.fused_kv_views(pkv)
                ctx = attention_api.paged_attention_chunked_sharded(
                    q, pk, pv, bl[0], br[0], bp[0], kv_lens, token_req,
                    token_pos, axis=axis)
            return pkv, ctx

        fn = shard_map(
            local, mesh=mesh,
            in_specs=(P(), P(), P(), P(axis), P(axis), P(axis), P(axis),
                      P(), P(), P(), P(), P(), P(), P()),
            out_specs=(P(axis), P()), check_rep=False)
        return fn(q, k_new, v_new, pkv, lists["block_list"],
                  lists["block_req"], lists["block_pos"], lists["kv_lens"],
                  lists["token_req"], lists["token_pos"],
                  lists["cu_q_lens"], lists["cu_kv_lens"],
                  lists["seq_slot"], lists["slots"])

    def decode_tokens_paged(self, params, pools, lists, tokens, *,
                            attn_backend: Optional[str] = None,
                            q_chunk: int = 16,
                            prefetch_depth: int = 0,
                            attn_impl: str = "ragged",
                            num_queries_per_block: int = 16,
                            num_kv_pages_per_block: int = 1,
                            vmem_limit_bytes: int = 0,
                            mesh=None, axis: Optional[str] = None):
        """Fused chunked-prefill + decode over flat token lanes.

        The serving engine's single compiled program: each lane of ``tokens``
        (T,) is one token of some request — a decode token (one lane per
        decoding request) or one token of a prompt chunk (several lanes per
        prefilling request). Per layer the lane KV is appended to the FUSED
        head-interleaved pool (``pools["kv"]``, one scatter per layer), then
        every lane attends causally to its request's blocks through the op
        family ``attn_impl`` picks: ``"ragged"`` =
        :func:`attention_api.paged_attention_ragged_op` consuming the cu
        prefix sums in ONE launch, ``"chunked"`` = the token-lane op on
        split views of the same pool.  Greedy outputs are bit-identical
        either way (both reduce to the same flash update on the same
        values).

        lists:
          block_list/block_req/block_pos   flat BlockList keyed by slot id —
                          or, with ``mesh`` set, the (S, M) per-shard LOCAL
                          lists from ``build_sharded_block_lists``
          kv_lens   (B,)  valid KV per slot after this step's append
          token_req (T,)  owning slot of each lane (>= B ⇒ padding lane)
          token_pos (T,)  absolute position of each lane's token
          cu_q_lens (B+1,) lane-count prefix sums per committed sequence
          cu_kv_lens (B+1,) post-append KV-length prefix sums, same order
          seq_slot  (B,)  slot id per committed sequence (B ⇒ unused entry)
          slots     (T, 2) pool (block, offset) where each lane's KV lands
          last_lane (B,)  lane index holding each slot's last valid token
          logit_lanes (B, R)  [optional] lane indices to unembed per slot —
                          the speculative-verify path: each decoding slot
                          carries its last committed token plus K drafted
                          tokens, and needs a logit row per lane to judge
                          every draft in this ONE forward

        ``q_chunk``/``prefetch_depth`` tune the chunked op;
        ``num_queries_per_block``/``num_kv_pages_per_block``/
        ``vmem_limit_bytes`` tune the ragged op (autotuned — see
        docs/ragged_kernel.md; jnp backends ignore all of them).

        ``mesh``/``axis`` set ⇒ the mesh-native serving path: the pool is
        sequence-sharded on its block dimension over ``axis`` and each
        layer's append + attention runs under shard_map
        (:meth:`_sharded_append_attend`); everything outside attention is
        ordinary global-array code that GSPMD partitions against the
        TP-sharded params (``distributed.sharding.ShardingRules``).

        Returns (logits, new pools): logits (B, V) at each slot's
        ``last_lane``, or (B, R, V) at ``logit_lanes`` when present.
        """
        cfg = self.cfg
        a = cfg.attention
        token_pos = lists["token_pos"]
        if attn_impl not in ("ragged", "chunked"):
            raise ValueError(
                f"attn_impl {attn_impl!r}: expected 'ragged' or 'chunked'")
        ragged = attn_impl == "ragged"
        x = embed(params["embed"], tokens)                 # (T, D)

        def body(x, inp):
            lp, pkv = inp
            h = rmsnorm(lp["ln1"], x[:, None], cfg.norm_eps)
            q, k_new, v_new = attn_lib.project_qkv(lp["attn"], h, a,
                                                   token_pos[:, None])
            if mesh is not None:
                pkv, ctx = self._sharded_append_attend(
                    mesh, axis or "model", q[:, 0], k_new[:, 0],
                    v_new[:, 0], pkv, lists, attn_impl)
            else:
                # Padding lanes carry out-of-bounds slots -> scatter drops
                # them.
                pkv = paged_kv.append_to_pool(
                    pkv, paged_kv.fuse_kv_heads(k_new[:, 0], v_new[:, 0]),
                    lists["slots"])
                if ragged:
                    ctx = attention_api.paged_attention_ragged_op(
                        q[:, 0], pkv, lists["block_list"],
                        lists["block_req"], lists["block_pos"],
                        lists["cu_q_lens"], lists["cu_kv_lens"],
                        lists["seq_slot"], backend=attn_backend,
                        num_queries_per_block=num_queries_per_block,
                        num_kv_pages_per_block=num_kv_pages_per_block,
                        vmem_limit_bytes=vmem_limit_bytes)
                else:
                    pk, pv = paged_kv.fused_kv_views(pkv)
                    ctx = attention_api.paged_attention_chunked_op(
                        q[:, 0], pk, pv, lists["block_list"],
                        lists["block_req"], lists["block_pos"],
                        lists["kv_lens"], lists["token_req"], token_pos,
                        backend=attn_backend, q_chunk=q_chunk,
                        prefetch_depth=prefetch_depth)
            x = x + jnp.einsum("be,ed->bd", ctx.reshape(x.shape[0], -1),
                               lp["attn"]["wo"])
            h = rmsnorm(lp["ln2"], x[:, None], cfg.norm_eps)
            if cfg.moe is not None:
                o, _ = moe_lib.moe_apply(lp["moe"], h, cfg.moe,
                                         shard=self.shard_moe,
                                         full_capacity=True,
                                         groups=self.moe_groups)
            else:
                o = mlp_apply(lp["mlp"], h, cfg.act)
            return x + o[:, 0], pkv

        x, pkv = jax.lax.scan(body, x, (params["layers"], pools["kv"]))
        if "logit_lanes" in lists:
            # Speculative verify: a row per (slot, lane) pair, (B, R, V).
            x_sel = jnp.take(x, lists["logit_lanes"], axis=0)   # (B, R, D)
            x_sel = rmsnorm(params["final_norm"], x_sel, cfg.norm_eps)
            return (unembed(params.get("head", params["embed"]), x_sel),
                    {"kv": pkv})
        # Unembed only each slot's last valid lane: (B, D) -> (B, V).
        x_last = jnp.take(x, lists["last_lane"], axis=0)
        x_last = rmsnorm(params["final_norm"], x_last[:, None], cfg.norm_eps)
        logits = unembed(params.get("head", params["embed"]), x_last)[:, 0]
        return logits, {"kv": pkv}

    # ---------------------------------------------------------------- loss
    def loss(self, params, batch):
        """Next-token CE. batch: tokens (B,S) [+ extra_embeds, loss_mask]."""
        logits, aux = self.forward(params, batch["tokens"],
                                   batch.get("extra_embeds"))
        V = logits.shape[-1]
        # VLM: logits include vision positions; score text positions only.
        n_extra = 0
        if batch.get("extra_embeds") is not None:
            n_extra = batch["extra_embeds"].shape[1]
            logits = logits[:, n_extra:]
        from repro.training.losses import next_token_loss
        return next_token_loss(logits, batch["tokens"],
                               batch.get("loss_mask")) + 0.01 * aux
