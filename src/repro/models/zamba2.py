"""Zamba2 hybrid: Mamba2 backbone + ONE weight-shared attention block applied
every ``hybrid_attn_every`` layers (zamba-style). Sub-quadratic: runs the
long_500k cell. Shared-attention KV is paged (the paper's technique applies
to the attention applications only; Mamba state is O(1))."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.layers import attention as attn_lib
from repro.layers import ssm as ssm_lib
from repro.layers.embedding import embed, embedding_init, head_init, unembed
from repro.layers.mlp import mlp_apply, mlp_init
from repro.layers.norm import rmsnorm, rmsnorm_init
from repro.distributed.act_sharding import constrain_batch
from repro.training import remat as remat_lib

NEG_INF = -1e30


class Zamba2LM:
    def __init__(self, cfg: ModelConfig, *, q_chunk: int = 512,
                 remat: bool = True, scan_layers: bool = True,
                 unroll_attn: bool = False):
        self.cfg = cfg
        self.q_chunk = q_chunk
        self.remat = remat
        self.scan_layers = scan_layers
        self.unroll_attn = unroll_attn
        self.dtype = jnp.dtype(cfg.dtype)
        assert cfg.num_layers % cfg.hybrid_attn_every == 0
        self.n_groups = cfg.num_layers // cfg.hybrid_attn_every
        self.per_group = cfg.hybrid_attn_every

    def _mamba_init(self, key):
        return {
            "ln": rmsnorm_init(self.cfg.d_model, self.dtype),
            "ssm": ssm_lib.ssm_init(key, self.cfg.d_model, self.cfg.ssm, self.dtype),
        }

    def init(self, key):
        cfg = self.cfg
        ke, km, ka, kf, kh = jax.random.split(key, 5)
        mamba_keys = jax.random.split(km, cfg.num_layers).reshape(
            self.n_groups, self.per_group, 2)
        shared = {
            "ln1": rmsnorm_init(cfg.d_model, self.dtype),
            "attn": attn_lib.attention_init(ka, cfg.d_model, cfg.attention,
                                            self.dtype),
            "ln2": rmsnorm_init(cfg.d_model, self.dtype),
            "mlp": mlp_init(kf, cfg.d_model, cfg.d_ff, cfg.act, self.dtype),
        }
        return {
            "embed": embedding_init(ke, cfg.vocab_size, cfg.d_model, self.dtype),
            "mamba": jax.vmap(jax.vmap(self._mamba_init))(mamba_keys),
            "shared_attn": shared,
            "final_norm": rmsnorm_init(cfg.d_model, self.dtype),
            "head": head_init(kh, cfg.vocab_size, cfg.d_model, self.dtype),
        }

    def init_abstract(self):
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))

    def _attn_apply(self, shared, x, positions):
        cfg = self.cfg
        h, kv = attn_lib.attention_block(
            shared["attn"], rmsnorm(shared["ln1"], x, cfg.norm_eps), positions,
            cfg.attention, chunk=self.q_chunk, unroll=self.unroll_attn)
        x = x + h
        h = mlp_apply(shared["mlp"], rmsnorm(shared["ln2"], x, cfg.norm_eps),
                      cfg.act)
        return x + h

    def forward(self, params, tokens, extra_embeds=None, *, last_only: bool = False):
        cfg = self.cfg
        x = embed(params["embed"], tokens)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))

        def group_body(x, gp):
            x = constrain_batch(x)
            x = self._attn_apply(params["shared_attn"], x, positions)

            def mamba_body(x, lp):
                h = ssm_lib.ssm_chunked(
                    lp["ssm"], rmsnorm(lp["ln"], x, cfg.norm_eps), cfg.ssm,
                    cfg.d_model)
                return x + h, None

            if self.scan_layers:
                x, _ = jax.lax.scan(mamba_body, x, gp)
            else:
                for j in range(self.per_group):
                    x, _ = mamba_body(x, jax.tree.map(lambda t: t[j], gp))
            return x, None

        if self.scan_layers:
            gb = remat_lib.wrap(group_body, self.remat)
            x, _ = jax.lax.scan(gb, x, params["mamba"])
        else:
            gb = remat_lib.wrap(group_body, self.remat)
            for i in range(self.n_groups):
                x, _ = gb(x, jax.tree.map(lambda t: t[i], params["mamba"]))
        if last_only:
            x = x[:, -1:]
        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        return unembed(params["head"], x), jnp.zeros((), jnp.float32)

    # ---------------------------------------------------------------- decode
    def init_decode_cache(self, batch: int, max_seq: int):
        cfg = self.cfg
        a = cfg.attention
        dims = ssm_lib.ssm_dims(cfg.d_model, cfg.ssm)
        G, PG = self.n_groups, self.per_group
        return {
            "k": jnp.zeros((G, batch, max_seq, a.num_kv_heads, a.head_dim),
                           self.dtype),
            "v": jnp.zeros((G, batch, max_seq, a.num_kv_heads, a.head_dim),
                           self.dtype),
            "conv": jnp.zeros((G, PG, batch, cfg.ssm.d_conv - 1, dims.conv_dim),
                              self.dtype),
            "h": jnp.zeros((G, PG, batch, dims.num_heads, cfg.ssm.head_dim,
                            cfg.ssm.d_state), jnp.float32),
            "seq_lens": jnp.zeros((batch,), jnp.int32),
        }

    def decode_step(self, params, cache, tokens):
        cfg = self.cfg
        a = cfg.attention
        seq_lens = cache["seq_lens"]
        x = embed(params["embed"], tokens)           # (B,D)
        shared = params["shared_attn"]

        def group_body(x, inp):
            gp, k_c, v_c, conv, hst = inp
            x = constrain_batch(x)
            # shared attention (contiguous cache per group application)
            hx = rmsnorm(shared["ln1"], x[:, None], cfg.norm_eps)
            q, k_new, v_new = attn_lib.project_qkv(shared["attn"], hx, a,
                                                   seq_lens[:, None])
            k_c = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice_in_dim(
                c, n, i, axis=0))(k_c, k_new, seq_lens)
            v_c = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice_in_dim(
                c, n, i, axis=0))(v_c, v_new, seq_lens)
            B = x.shape[0]
            KV = a.num_kv_heads
            qg = q[:, 0].reshape(B, KV, a.num_heads // KV, a.head_dim)
            scores = jnp.einsum("bkgd,bskd->bkgs", qg, k_c).astype(jnp.float32)
            scores = scores * a.head_dim ** -0.5
            mask = jnp.arange(k_c.shape[1])[None] <= seq_lens[:, None]
            scores = jnp.where(mask[:, None, None], scores, NEG_INF)
            w = jax.nn.softmax(scores, axis=-1).astype(v_c.dtype)
            ctx = jnp.einsum("bkgs,bskd->bkgd", w, v_c).reshape(B, -1)
            x = x + jnp.einsum("be,ed->bd", ctx, shared["attn"]["wo"])
            h = mlp_apply(shared["mlp"],
                          rmsnorm(shared["ln2"], x[:, None], cfg.norm_eps),
                          cfg.act)
            x = x + h[:, 0]

            def mamba_body(x, minp):
                lp, cv, hs = minp
                o, st = ssm_lib.ssm_step(
                    lp["ssm"], rmsnorm(lp["ln"], x[:, None], cfg.norm_eps),
                    {"conv": cv, "h": hs}, cfg.ssm, cfg.d_model)
                return x + o[:, 0], (st["conv"], st["h"])

            if self.scan_layers:
                x, (conv, hst) = jax.lax.scan(mamba_body, x, (gp, conv, hst))
            else:
                outs = []
                for j in range(self.per_group):
                    x, o = mamba_body(
                        x, jax.tree.map(lambda t: t[j], (gp, conv, hst)))
                    outs.append(o)
                conv, hst = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
            return x, (k_c, v_c, conv, hst)

        if self.scan_layers:
            x, (k, v, conv, hst) = jax.lax.scan(
                group_body, x,
                (params["mamba"], cache["k"], cache["v"], cache["conv"],
                 cache["h"]))
        else:
            outs = []
            for i in range(self.n_groups):
                x, o = group_body(
                    x, jax.tree.map(lambda t: t[i],
                                    (params["mamba"], cache["k"], cache["v"],
                                     cache["conv"], cache["h"])))
                outs.append(o)
            k, v, conv, hst = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        x = rmsnorm(params["final_norm"], x[:, None], cfg.norm_eps)
        logits = unembed(params["head"], x)[:, 0]
        return logits, {"k": k, "v": v, "conv": conv, "h": hst,
                        "seq_lens": seq_lens + 1}

    def loss(self, params, batch):
        logits, _ = self.forward(params, batch["tokens"])
        from repro.training.losses import next_token_loss
        return next_token_loss(logits, batch["tokens"])
