"""The measured kernel-tunable table keyed by (page_size, head_dim, backend).

``benchmarks/paged_attention_bench.py`` sweeps the ``paged_attention_ragged``
tunables (``num_queries_per_block``, ``num_kv_pages_per_block``,
``vmem_limit_bytes``) over a grid that always includes the registry defaults,
times each configuration on the same workload, and emits one row per point
with ``tune=1`` attribution in the derived string; the fastest point per
``(page_size, head_dim, backend)`` cell additionally carries ``best=1``.
Committed as ``BENCH_010.json``, those rows are the table this module parses
back out — the kernel-layer mirror of :mod:`repro.perf.table`'s policy
winners.

The serving engine consults :func:`resolve_tunables` at construction for any
tunable the config leaves at 0 (counted ``tuned_resolved`` on a hit,
``tuned_fallback`` to the registry defaults on any miss — mirroring PR 9's
``auto_resolved``/``auto_fallback``).  Because the sweep grid contains the
defaults, the resolved config meets-or-beats the hand-picked values by
construction on every swept scenario.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional, Tuple

from repro.perf.table import check_schema, parse_derived

__all__ = ["TUNABLE_KEYS", "TuneTable", "default_tune_table_path",
           "active_tune_table", "resolve_tunables"]

TUNABLE_KEYS = ("num_queries_per_block", "num_kv_pages_per_block",
                "vmem_limit_bytes")

DEFAULT_TUNE_TABLE_NAME = "BENCH_010.json"
_ENV_TUNE_TABLE = "REPRO_TUNE_TABLE"

Key = Tuple[int, int, str]


class TuneTable:
    """Best measured tunable config per (page_size, head_dim, backend)."""

    def __init__(self, best: Dict[Key, Dict[str, int]]):
        self.best = best

    @classmethod
    def from_results(cls, results: List[Dict], *,
                     origin: str = "<in-memory>") -> "TuneTable":
        """Build from benchmark-JSON results (the list ``run.py`` writes)."""
        best: Dict[Key, Dict[str, int]] = {}
        for result in results:
            if result.get("module") != "paged_attention_bench":
                continue
            check_schema(result, origin)
            for row in result.get("rows", []):
                d = parse_derived(row.get("derived", ""))
                if d.get("tune") != "1" or d.get("best") != "1":
                    continue
                try:
                    key = (int(d["page_size"]), int(d["head_dim"]),
                           d["backend"])
                    cfg = {k: int(d[k]) for k in TUNABLE_KEYS}
                except (KeyError, ValueError):
                    continue          # malformed row — never half-resolve
                best[key] = cfg
        return cls(best)

    @classmethod
    def load(cls, path: str) -> "TuneTable":
        with open(path) as f:
            results = json.load(f)
        return cls.from_results(results, origin=path)

    def lookup(self, page_size: int, head_dim: int,
               backend: str) -> Optional[Dict[str, int]]:
        return self.best.get((int(page_size), int(head_dim), str(backend)))


def default_tune_table_path() -> Optional[str]:
    """Committed-table lookup: env override, cwd, then the repo checkout."""
    env = os.environ.get(_ENV_TUNE_TABLE)
    if env:
        return env
    cwd_path = os.path.join(os.getcwd(), DEFAULT_TUNE_TABLE_NAME)
    if os.path.exists(cwd_path):
        return cwd_path
    here = os.path.dirname(os.path.abspath(__file__))
    repo = os.path.dirname(os.path.dirname(os.path.dirname(here)))
    repo_path = os.path.join(repo, DEFAULT_TUNE_TABLE_NAME)
    if os.path.exists(repo_path):
        return repo_path
    return None


_TABLE_CACHE: Dict[Tuple[str, float], TuneTable] = {}


def active_tune_table(path: Optional[str] = None) -> Optional[TuneTable]:
    """The committed tune table (None on any miss — caller falls back)."""
    path = path or default_tune_table_path()
    if path is None:
        return None
    try:
        key = (path, os.path.getmtime(path))
        if key not in _TABLE_CACHE:
            _TABLE_CACHE[key] = TuneTable.load(path)
        return _TABLE_CACHE[key]
    except (OSError, ValueError):  # unreadable/incompatible file = no table
        return None


def resolve_tunables(page_size: int, head_dim: int, backend: str,
                     path: Optional[str] = None) -> Optional[Dict[str, int]]:
    """Measured-best tunables for this cell, or None on any miss.

    The caller (the engine) counts a hit as ``tuned_resolved`` and a miss as
    ``tuned_fallback`` to the registry defaults; this function never raises
    for an absent or unreadable table.
    """
    table = active_tune_table(path)
    if table is None:
        return None
    return table.lookup(page_size, head_dim, backend)
