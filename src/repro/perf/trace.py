"""Request traces: seeded synthetic workload mixtures with JSON load/save.

A :class:`Trace` is a pinned, fully deterministic description of a serving
workload: per-request virtual arrival times (seconds on the replay clock, see
:mod:`repro.perf.replay`), prompt token ids, generation budgets, and the
priority/deadline fields the admission policies consume.  Generators cover the
three mixture shapes the serving benchmarks care about — ``bursty`` (arrival
waves), ``shared-prefix`` (prefix-cache pressure), ``long-tail`` (a few long
generations among many short ones) — plus ``mixed``, which interleaves all
three.  Everything is driven by one seeded ``numpy`` generator, so the same
(seed, parameters) always produces the same trace bit-for-bit; JSON round-trips
are exact.

:class:`LengthModel` is the trace-history cost model behind the
``predicted-length`` admission policy: a prompt-length-bucketed estimate of
decode length, fit from a trace's (prompt length, generation length) pairs.
"""
from __future__ import annotations

import json
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.serving.request import Request, SamplingParams, bucket_pow2

TRACE_SCHEMA_VERSION = 1

SCENARIOS = ("bursty", "shared-prefix", "long-tail", "mixed")


@dataclass
class TraceRequest:
    """One request in a trace; times are virtual seconds from trace start."""

    req_id: int
    arrival: float
    prompt: List[int]
    max_new_tokens: int
    priority: int = 0
    deadline: Optional[float] = None

    def as_dict(self) -> Dict:
        return {
            "req_id": self.req_id,
            "arrival": self.arrival,
            "prompt": list(int(t) for t in self.prompt),
            "max_new_tokens": int(self.max_new_tokens),
            "priority": int(self.priority),
            "deadline": self.deadline,
        }


@dataclass
class Trace:
    """A pinned workload: requests sorted by (arrival, req_id)."""

    name: str
    scenario: str
    seed: int
    vocab_size: int
    step_period: float = 0.05  # virtual seconds per engine step
    requests: List[TraceRequest] = field(default_factory=list)

    def as_dict(self) -> Dict:
        return {
            "trace_schema_version": TRACE_SCHEMA_VERSION,
            "name": self.name,
            "scenario": self.scenario,
            "seed": self.seed,
            "vocab_size": self.vocab_size,
            "step_period": self.step_period,
            "requests": [r.as_dict() for r in self.requests],
        }

    @classmethod
    def from_dict(cls, d: Dict) -> "Trace":
        version = d.get("trace_schema_version")
        if version != TRACE_SCHEMA_VERSION:
            raise ValueError(
                f"trace schema {version!r} != supported {TRACE_SCHEMA_VERSION}")
        reqs = [TraceRequest(req_id=r["req_id"], arrival=r["arrival"],
                             prompt=list(r["prompt"]),
                             max_new_tokens=r["max_new_tokens"],
                             priority=r.get("priority", 0),
                             deadline=r.get("deadline"))
                for r in d["requests"]]
        return cls(name=d["name"], scenario=d["scenario"], seed=d["seed"],
                   vocab_size=d["vocab_size"], step_period=d["step_period"],
                   requests=reqs)

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.as_dict(), f, indent=1)

    @classmethod
    def load(cls, path: str) -> "Trace":
        with open(path) as f:
            return cls.from_dict(json.load(f))

    def to_requests(self, base: float = 0.0) -> List[Request]:
        """Materialize serving Requests with arrivals offset by ``base``.

        ``base`` is normally the wall-clock instant replay starts, so arrival
        *comparisons* (all any policy does with arrivals) match the virtual
        order exactly while engine-side wall timestamps stay sane.
        """
        out = []
        for tr in self.requests:
            out.append(Request(
                req_id=tr.req_id,
                prompt=np.asarray(tr.prompt, dtype=np.int32),
                max_new_tokens=tr.max_new_tokens,
                sampling=SamplingParams(temperature=0.0),
                arrival=base + tr.arrival,
                priority=tr.priority,
                deadline=None if tr.deadline is None else base + tr.deadline,
            ))
        return out

    def max_positions(self) -> int:
        return max((len(r.prompt) + r.max_new_tokens for r in self.requests),
                   default=0)


def _finish(requests: List[TraceRequest]) -> List[TraceRequest]:
    requests.sort(key=lambda r: (r.arrival, r.req_id))
    for i, r in enumerate(requests):
        r.req_id = i
    return requests


def _prompt(rng: np.random.Generator, lo: int, hi: int, vocab: int,
            prefix: Optional[List[int]] = None) -> List[int]:
    n = int(rng.integers(lo, hi + 1))
    body = rng.integers(0, vocab, size=n).tolist()
    return (list(prefix) + body) if prefix else body


def _gen_len(rng: np.random.Generator, prompt_len: int, cap: int) -> int:
    # Correlate decode length with the prompt-length bucket so the
    # predicted-length cost model has signal to learn.
    return int(min(cap, 2 + prompt_len // 3 + int(rng.integers(0, 3))))


def generate(scenario: str, *, seed: int = 0, n_requests: int = 8,
             vocab_size: int = 256, step_period: float = 0.05,
             prompt_lo: int = 4, prompt_hi: int = 14, gen_cap: int = 12,
             shared_prefix_len: int = 8, name: Optional[str] = None) -> Trace:
    """Deterministically generate a synthetic trace for ``scenario``."""
    if scenario not in SCENARIOS:
        raise ValueError(f"unknown scenario {scenario!r}; one of {SCENARIOS}")
    rng = np.random.default_rng(seed)
    reqs: List[TraceRequest]
    if scenario == "bursty":
        reqs = _bursty(rng, n_requests, vocab_size, prompt_lo, prompt_hi,
                       gen_cap)
    elif scenario == "shared-prefix":
        reqs = _shared_prefix(rng, n_requests, vocab_size, prompt_lo,
                              prompt_hi, gen_cap, shared_prefix_len)
    elif scenario == "long-tail":
        reqs = _long_tail(rng, n_requests, vocab_size, prompt_lo, prompt_hi,
                          gen_cap)
    else:  # mixed: one slice of each shape, interleaved on the same clock.
        per = max(2, n_requests // 3)
        reqs = (_bursty(rng, per, vocab_size, prompt_lo, prompt_hi, gen_cap)
                + _shared_prefix(rng, per, vocab_size, prompt_lo, prompt_hi,
                                 gen_cap, shared_prefix_len)
                + _long_tail(rng, n_requests - 2 * per, vocab_size, prompt_lo,
                             prompt_hi, gen_cap))
    return Trace(name=name or f"{scenario}-s{seed}-n{n_requests}",
                 scenario=scenario, seed=seed, vocab_size=vocab_size,
                 step_period=step_period, requests=_finish(reqs))


def _bursty(rng, n, vocab, lo, hi, cap) -> List[TraceRequest]:
    """Arrival waves: clustered bursts every ~0.8 virtual seconds."""
    wave = max(2, n // 3)
    reqs = []
    for i in range(n):
        t = 0.8 * (i // wave) + float(rng.uniform(0.0, 0.1))
        prompt = _prompt(rng, lo, hi, vocab)
        gen = _gen_len(rng, len(prompt), cap)
        deadline = t + 1.0 + float(rng.uniform(0.0, 1.0)) if i % 2 else None
        reqs.append(TraceRequest(req_id=i, arrival=t, prompt=prompt,
                                 max_new_tokens=gen,
                                 priority=int(rng.integers(0, 3)),
                                 deadline=deadline))
    return reqs


def _shared_prefix(rng, n, vocab, lo, hi, cap, prefix_len) -> List[TraceRequest]:
    """Groups of ~3 requests sharing a prompt prefix (prefix-cache pressure)."""
    reqs = []
    prefix: List[int] = []
    for i in range(n):
        if i % 3 == 0:
            prefix = rng.integers(0, vocab, size=prefix_len).tolist()
        t = float(rng.uniform(0.0, 1.2))
        prompt = _prompt(rng, lo, hi, vocab, prefix=prefix)
        gen = _gen_len(rng, len(prompt), cap)
        reqs.append(TraceRequest(req_id=i, arrival=t, prompt=prompt,
                                 max_new_tokens=gen))
    return reqs


def _long_tail(rng, n, vocab, lo, hi, cap) -> List[TraceRequest]:
    """Poisson-ish arrivals; every 4th request is a long-generation outlier."""
    reqs, t = [], 0.0
    for i in range(n):
        t += float(rng.exponential(0.15))
        long = (i % 4 == 3)
        prompt = _prompt(rng, lo, hi * 2 if long else hi, vocab)
        gen = _gen_len(rng, len(prompt), cap)
        if long:
            gen = min(cap + cap // 2, gen * 3)
        reqs.append(TraceRequest(req_id=i, arrival=t, prompt=prompt,
                                 max_new_tokens=gen,
                                 priority=int(rng.integers(0, 2))))
    return reqs


@dataclass
class LengthModel:
    """Prompt-length-bucketed decode-length estimate learned from a trace.

    Buckets are the pow2 buckets the engine already uses for lane shapes
    (``bucket_pow2``), so the model's granularity matches the scheduler's.
    """

    buckets: Dict[int, float]
    default: float

    @classmethod
    def fit(cls, trace: Trace) -> "LengthModel":
        sums: Dict[int, List[float]] = {}
        for r in trace.requests:
            sums.setdefault(bucket_pow2(len(r.prompt)), []).append(
                float(r.max_new_tokens))
        if not sums:
            return cls(buckets={}, default=1.0)
        buckets = {b: sum(v) / len(v) for b, v in sorted(sums.items())}
        default = sum(float(r.max_new_tokens) for r in trace.requests) / len(
            trace.requests)
        return cls(buckets=buckets, default=default)

    def predict(self, prompt_len: int) -> float:
        """Estimated decode length for a prompt of ``prompt_len`` tokens."""
        b = bucket_pow2(max(1, prompt_len))
        if b in self.buckets:
            return self.buckets[b]
        if self.buckets:  # nearest bucket by log-distance, lower on ties
            best = min(self.buckets,
                       key=lambda k: (abs(math.log2(k) - math.log2(b)), k))
            return self.buckets[best]
        return self.default
