"""Trace-replay and performance-attribution subsystem (docs/perf_gate.md).

The paper's method is systematic microbenchmark -> end-to-end workload
attribution; this package closes the loop so measured performance becomes an
*input* to the serving stack instead of just an output:

* :mod:`repro.perf.trace`   — the ``Trace`` format: seeded synthetic request
  mixtures (bursty / shared-prefix / long-tail / mixed) with arrival times,
  prompt/gen-length distributions and priority/deadline fields, JSON
  load/save, and the prompt-length-bucketed decode-length model.
* :mod:`repro.perf.replay`  — feeds a serving engine from trace arrivals in
  deterministic virtual time (one engine step = one tick) and scores the
  run against p99 TTFT/TPOT SLOs.
* :mod:`repro.perf.table`   — the measured perf table keyed by
  (scenario, config): per-scenario winner resolution consumed by the
  registered ``auto`` policy triple, plus the thread-local replay context
  (active scenario / table / length model).
* :mod:`repro.perf.gate`    — the CI regression gate:
  ``python -m repro.perf.gate --baseline BENCH_009.json --current new.json
  --threshold 0.2`` diffs pinned scenarios on deterministic counters and
  exits nonzero on regression.
"""
from repro.perf.table import (SCHEMA_VERSION, PerfTable, SchemaError,
                              perf_context)
from repro.perf.trace import LengthModel, Trace, TraceRequest, generate

__all__ = ["SCHEMA_VERSION", "PerfTable", "SchemaError", "perf_context",
           "Trace", "TraceRequest", "LengthModel", "generate"]
