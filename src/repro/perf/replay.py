"""Deterministic virtual-time trace replay + SLO scoring.

The replay clock is the engine step counter: one ``step()`` = one tick of
``trace.step_period`` virtual seconds.  Before each step, every trace request
whose arrival is due is submitted; when the engine drains while arrivals
remain, the clock fast-forwards to the next arrival (a counted idle skip).
Because submission timing, admission ordering, and token generation are all
deterministic under greedy sampling, every number this module reports —
per-request TTFT/TPOT *in steps*, total steps, tokens per step, preemptions,
prefix hits — is bit-stable across runs and hosts.  That is what lets
:mod:`repro.perf.gate` diff replay rows in CI: the gate compares these
counters, never wall clock.

Greedy token streams are bit-identical to submitting the same requests
directly (the repo-wide invariant: policies and arrival timing change
*scheduling*, never *tokens*) — ``tests/test_trace.py`` locks this in.

SLO scoring converts step-counted latencies to virtual seconds via
``step_period`` and compares nearest-rank percentiles (the public helper from
:mod:`repro.serving.metrics`) against p99 TTFT/TPOT targets.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.perf.trace import Trace
from repro.serving.metrics import percentile
from repro.serving.request import RequestState

__all__ = ["Slo", "RequestTiming", "ReplayResult", "SloReport", "replay",
           "score"]


@dataclass
class Slo:
    """p99 latency targets in virtual seconds."""

    ttft_s: float
    tpot_s: float


@dataclass
class RequestTiming:
    """Step-indexed lifecycle of one replayed request (all deterministic)."""

    req_id: int
    arrival_step: int                      # nominal due step: ceil(arrival/period)
    submit_step: int                       # step index the replayer submitted at
    first_token_step: Optional[int] = None  # steps executed when output[0] seen
    finish_step: Optional[int] = None       # steps executed when FINISHED seen
    output_tokens: int = 0

    @property
    def ttft_steps(self) -> Optional[int]:
        if self.first_token_step is None:
            return None
        return self.first_token_step - self.arrival_step

    @property
    def tpot_steps(self) -> Optional[float]:
        if self.finish_step is None or self.first_token_step is None:
            return None
        return ((self.finish_step - self.first_token_step)
                / max(self.output_tokens - 1, 1))


@dataclass
class ReplayResult:
    trace: Trace
    outputs: Dict[int, List[int]]
    timings: Dict[int, RequestTiming]
    steps: int
    idle_fastforwards: int
    metrics: Dict = field(default_factory=dict)

    def ttft_virtual_s(self) -> List[float]:
        return [t.ttft_steps * self.trace.step_period
                for t in self.timings.values() if t.ttft_steps is not None]

    def tpot_virtual_s(self) -> List[float]:
        return [t.tpot_steps * self.trace.step_period
                for t in self.timings.values() if t.tpot_steps is not None]

    def counters(self) -> Dict[str, float]:
        """The deterministic-counter row the perf table and gate consume."""
        m = self.metrics
        out_tokens = sum(t.output_tokens for t in self.timings.values())
        finished = sum(1 for t in self.timings.values()
                       if t.finish_step is not None)
        ttfts = [t.ttft_steps for t in self.timings.values()
                 if t.ttft_steps is not None]
        tpots = [t.tpot_steps for t in self.timings.values()
                 if t.tpot_steps is not None]
        return {
            "steps": self.steps,
            "idle_ff": self.idle_fastforwards,
            "finished": finished,
            "out_tokens": out_tokens,
            "tok_per_step": round(out_tokens / max(self.steps, 1), 4),
            "prefix_hits": m.get("prefix_hits", 0),
            "preempt": m.get("preemptions", 0),
            "p99_ttft_steps": percentile(ttfts, 99),
            "p99_tpot_steps": round(percentile(tpots, 99), 4),
        }


def replay(engine, trace: Trace, *, max_steps: int = 100_000) -> ReplayResult:
    """Feed ``engine`` from ``trace`` arrivals on the virtual clock.

    ``engine`` is any object with the ServingEngine surface used here
    (``submit`` / ``step`` / ``busy`` / ``metrics``) — DisaggEngine included.
    """
    period = trace.step_period
    base = time.time()  # wall offset: keeps engine-side timestamps monotone
    requests = trace.to_requests(base=base)
    order = sorted(range(len(requests)),
                   key=lambda i: (trace.requests[i].arrival,
                                  trace.requests[i].req_id))
    timings: Dict[int, RequestTiming] = {}
    live = {}  # req_id -> Request, for step-indexed lifecycle tracking
    step = 0
    idle_ff = 0
    i = 0
    while i < len(order) or engine.busy:
        now = step * period
        while i < len(order):
            tr = trace.requests[order[i]]
            if tr.arrival > now + 1e-9:
                break
            req = requests[order[i]]
            engine.submit(req)
            live[tr.req_id] = req
            timings[tr.req_id] = RequestTiming(
                req_id=tr.req_id,
                arrival_step=int(math.ceil(tr.arrival / period)),
                submit_step=step)
            i += 1
        if not engine.busy:
            # Engine drained before the next arrival: fast-forward the clock.
            nxt = trace.requests[order[i]].arrival
            step = max(step + 1, int(math.ceil(nxt / period)))
            idle_ff += 1
            continue
        engine.step()
        step += 1
        if step > max_steps:
            raise RuntimeError(f"replay exceeded max_steps={max_steps}")
        for rid, req in live.items():
            t = timings[rid]
            if t.first_token_step is None and len(req.output) > 0:
                t.first_token_step = step
            if t.finish_step is None and req.state == RequestState.FINISHED:
                t.finish_step = step
                t.output_tokens = len(req.output)
    outputs = {rid: list(req.output) for rid, req in live.items()}
    return ReplayResult(trace=trace, outputs=outputs, timings=timings,
                        steps=step, idle_fastforwards=idle_ff,
                        metrics=engine.metrics())


@dataclass
class SloReport:
    """Percentile summary (virtual seconds) vs the p99 targets."""

    p50_ttft_s: float
    p90_ttft_s: float
    p99_ttft_s: float
    p50_tpot_s: float
    p90_tpot_s: float
    p99_tpot_s: float
    attainment_ttft: float  # fraction of requests with ttft <= slo.ttft_s
    attainment_tpot: float
    ok: bool

    def as_dict(self) -> Dict:
        return dict(self.__dict__)


def score(result: ReplayResult, slo: Slo) -> SloReport:
    ttfts = result.ttft_virtual_s()
    tpots = result.tpot_virtual_s()
    att_ttft = (sum(1 for v in ttfts if v <= slo.ttft_s) / len(ttfts)
                if ttfts else 0.0)
    att_tpot = (sum(1 for v in tpots if v <= slo.tpot_s) / len(tpots)
                if tpots else 0.0)
    p99_ttft = percentile(ttfts, 99)
    p99_tpot = percentile(tpots, 99)
    return SloReport(
        p50_ttft_s=percentile(ttfts, 50), p90_ttft_s=percentile(ttfts, 90),
        p99_ttft_s=p99_ttft,
        p50_tpot_s=percentile(tpots, 50), p90_tpot_s=percentile(tpots, 90),
        p99_tpot_s=p99_tpot,
        attainment_ttft=round(att_ttft, 4), attainment_tpot=round(att_tpot, 4),
        ok=bool(ttfts) and p99_ttft <= slo.ttft_s and p99_tpot <= slo.tpot_s)
