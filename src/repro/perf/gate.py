"""CI perf-regression gate: diff trace-replay rows on deterministic counters.

Usage::

    python -m repro.perf.gate --baseline BENCH_009.json \
        --current new.json --threshold 0.2

Both files are benchmark JSON written by ``benchmarks/run.py``.  The gate
matches trace-replay rows by name (each name pins one (scenario, config)
cell), then compares ONLY deterministic counters — steps, p99 TTFT/TPOT in
steps, tokens per step, prefix hits, finished/emitted totals.  Wall-clock
columns (``us_per_call``) are never compared: they vary with host load, so a
wall-clock gate either flakes or gets its threshold widened until it is
useless.  The counter columns are bit-stable for a pinned trace (greedy
sampling, seeded generators, virtual-time submission), so a >threshold move
is a real scheduling/hot-path change, not noise.

Exit codes: 0 clean, 1 regression (or nothing comparable), 2 usage/schema
error.  Schema enforcement is strict: every trace_replay result in both files
must carry ``schema_version == repro.perf.table.SCHEMA_VERSION`` — refusing
to diff is cheaper than mis-comparing.
"""
from __future__ import annotations

import argparse
import json
import sys
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.perf.table import SchemaError, check_schema, parse_derived

__all__ = ["GATE_COLUMNS", "Column", "Regression", "collect_rows", "compare",
           "main"]


@dataclass(frozen=True)
class Column:
    """One gated counter: which direction is a regression, noise floor."""

    name: str
    direction: str  # "up" = increase is bad, "down" = decrease is bad,
    #                 "exact" = any change is a workload-drift failure
    min_abs: float  # ignore absolute moves smaller than this (tiny integers)


GATE_COLUMNS: Tuple[Column, ...] = (
    Column("steps", "up", 2.0),
    Column("p99_ttft_steps", "up", 2.0),
    Column("p99_tpot_steps", "up", 0.5),
    Column("tok_per_step", "down", 0.05),
    Column("prefix_hits", "down", 2.0),
    Column("finished", "exact", 0.0),
    Column("out_tokens", "exact", 0.0),
)


@dataclass
class Regression:
    row: str
    column: str
    baseline: float
    current: float
    rel: float

    def __str__(self) -> str:
        return (f"{self.row}: {self.column} {self.baseline:g} -> "
                f"{self.current:g} ({self.rel:+.1%})")


def collect_rows(results: List[Dict], origin: str) -> Dict[str, Dict[str, str]]:
    """name -> parsed derived dict, for every trace_replay row.

    Raises SchemaError when any trace_replay result is missing or mismatched
    on schema_version (the shared check from repro.perf.table).
    """
    rows: Dict[str, Dict[str, str]] = {}
    for result in results:
        if result.get("module") != "trace_replay":
            continue
        check_schema(result, origin)
        for row in result.get("rows", []):
            d = parse_derived(row.get("derived", ""))
            if "scenario" in d:
                rows[row.get("name", "")] = d
    return rows


def _value(row: Dict[str, str], col: str) -> Optional[float]:
    raw = row.get(col)
    if raw is None:
        return None
    try:
        return float(raw)
    except ValueError:
        return None


def compare(baseline: Dict[str, Dict[str, str]],
            current: Dict[str, Dict[str, str]],
            threshold: float) -> Tuple[List[Regression], List[str]]:
    """Diff the rows present in both files; return (regressions, compared)."""
    regressions: List[Regression] = []
    compared = sorted(set(baseline) & set(current))
    for name in compared:
        b_row, c_row = baseline[name], current[name]
        for col in GATE_COLUMNS:
            b = _value(b_row, col.name)
            c = _value(c_row, col.name)
            if b is None or c is None:
                continue
            delta = c - b
            if col.direction == "exact":
                if delta != 0:
                    regressions.append(Regression(name, col.name, b, c,
                                                  delta / b if b else 1.0))
                continue
            worse = delta if col.direction == "up" else -delta
            if worse <= col.min_abs:
                continue
            rel = worse / max(abs(b), 1e-9)
            if rel > threshold:
                regressions.append(
                    Regression(name, col.name, b, c,
                               rel if col.direction == "up" else -rel))
    return regressions, compared


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.perf.gate",
        description="Fail when pinned trace-replay scenarios regress on "
                    "deterministic counters (docs/perf_gate.md).")
    ap.add_argument("--baseline", required=True,
                    help="committed benchmark JSON (e.g. BENCH_009.json)")
    ap.add_argument("--current", required=True,
                    help="freshly generated benchmark JSON to check")
    ap.add_argument("--threshold", type=float, default=0.2,
                    help="max tolerated relative regression (default 0.2)")
    args = ap.parse_args(argv)

    try:
        with open(args.baseline) as f:
            base_rows = collect_rows(json.load(f), args.baseline)
        with open(args.current) as f:
            cur_rows = collect_rows(json.load(f), args.current)
    except SchemaError as e:
        print(f"perf-gate: SCHEMA REFUSED: {e}", file=sys.stderr)
        return 2
    except (OSError, json.JSONDecodeError) as e:
        print(f"perf-gate: cannot read inputs: {e}", file=sys.stderr)
        return 2

    regressions, compared = compare(base_rows, cur_rows, args.threshold)
    missing = sorted(set(base_rows) - set(cur_rows))
    if not compared:
        print("perf-gate: FAIL: no comparable trace-replay rows between "
              f"{args.baseline} and {args.current}", file=sys.stderr)
        return 1
    print(f"perf-gate: compared {len(compared)} pinned rows "
          f"(threshold {args.threshold:.0%}; "
          f"{len(missing)} baseline-only rows skipped)")
    if regressions:
        print(f"perf-gate: FAIL: {len(regressions)} regression(s):",
              file=sys.stderr)
        for r in regressions:
            print(f"  {r}", file=sys.stderr)
        return 1
    print("perf-gate: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
