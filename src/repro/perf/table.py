"""The measured perf table keyed by (scenario, config) + the replay context.

``benchmarks/trace_replay.py`` emits rows whose ``derived`` string carries the
full attribution a consumer needs: scenario, the policy triple, spec/overlap
config, SLO verdict, and the deterministic counters from
:meth:`repro.perf.replay.ReplayResult.counters`.  This module parses those rows
back out of benchmark JSON (the committed ``BENCH_009.json``) into a
:class:`PerfTable` and answers the one question the ``auto`` policy triple
asks at engine construction: *which registered policy triple won this
scenario?*  Winner selection is a deterministic objective over comparable rows
(spec/overlap off, no self-referencing ``auto`` rows): SLO-met first, then
p99 TTFT steps, p99 TPOT steps, total steps, and finally the triple string as
a total-order tie-break.

The thread-local *replay context* (:func:`perf_context`) is how a replayer,
benchmark, or launcher tells policies constructed under it what workload they
are about to serve: the active scenario keys the table lookup, and the active
:class:`~repro.perf.trace.LengthModel` feeds the ``predicted-length``
admission policy.  Environment fallbacks (``REPRO_PERF_SCENARIO``,
``REPRO_PERF_TABLE``) serve subprocess sweeps and the CLI.

``SCHEMA_VERSION`` stamps every benchmark JSON result (satellite in
``benchmarks/run.py``); :class:`SchemaError` is how loading refuses an
incompatible file instead of mis-comparing it.
"""
from __future__ import annotations

import json
import os
import threading
from typing import Dict, List, Optional, Tuple

__all__ = ["SCHEMA_VERSION", "SchemaError", "PerfTable", "parse_derived",
           "check_schema", "perf_context", "active_scenario", "active_table",
           "active_length_model", "resolve_winner", "default_table_path"]

# Version of the benchmark-JSON result schema (result-level provenance keys +
# the derived-row grammar the gate and this table parse). Bump on any
# incompatible change; repro.perf.gate refuses to diff mismatched versions.
SCHEMA_VERSION = 1

DEFAULT_TABLE_NAME = "BENCH_009.json"
_ENV_TABLE = "REPRO_PERF_TABLE"
_ENV_SCENARIO = "REPRO_PERF_SCENARIO"

AXES = ("admission", "preemption", "eviction")


class SchemaError(ValueError):
    """Benchmark JSON has a missing or incompatible schema_version."""


def parse_derived(derived: str) -> Dict[str, str]:
    """Parse a benchmark row's ``k=v;k=v`` derived string into a dict."""
    out: Dict[str, str] = {}
    for part in (derived or "").split(";"):
        if "=" in part:
            k, _, v = part.partition("=")
            out[k.strip()] = v.strip()
    return out


def check_schema(result: Dict, origin: str) -> None:
    version = result.get("schema_version")
    if version != SCHEMA_VERSION:
        raise SchemaError(
            f"{origin}: trace_replay result has schema_version={version!r}, "
            f"this build supports {SCHEMA_VERSION}")


class PerfTable:
    """Parsed trace-replay rows with per-scenario winner resolution."""

    def __init__(self, rows: List[Dict[str, str]]):
        # Keep only rows that carry full (scenario, triple) attribution.
        self.rows = [r for r in rows
                     if r.get("scenario") and all(r.get(a) for a in AXES)]

    @classmethod
    def from_results(cls, results: List[Dict], *,
                     origin: str = "<in-memory>") -> "PerfTable":
        """Build from benchmark-JSON results (the list ``run.py`` writes)."""
        rows: List[Dict[str, str]] = []
        for result in results:
            if result.get("module") != "trace_replay":
                continue
            check_schema(result, origin)
            for row in result.get("rows", []):
                d = parse_derived(row.get("derived", ""))
                d.setdefault("name", row.get("name", ""))
                rows.append(d)
        return cls(rows)

    @classmethod
    def load(cls, path: str) -> "PerfTable":
        with open(path) as f:
            results = json.load(f)
        return cls.from_results(results, origin=path)

    def scenarios(self) -> List[str]:
        return sorted({r["scenario"] for r in self.rows})

    @staticmethod
    def objective(row: Dict[str, str]) -> Tuple:
        """Deterministic goodness: lower is better, triple string tie-break."""
        triple = "/".join(row.get(a, "") for a in AXES)
        return (0 if row.get("slo_ok") == "1" else 1,
                float(row.get("p99_ttft_steps", "inf")),
                float(row.get("p99_tpot_steps", "inf")),
                float(row.get("steps", "inf")),
                triple)

    def comparable_rows(self, scenario: str) -> List[Dict[str, str]]:
        """Fixed-triple rows for ``scenario`` at the baseline config.

        Spec/overlap/multi-device variants and ``auto`` rows are excluded:
        the winner must be a concrete triple measured under the same config
        ``auto`` runs at.
        """
        return [r for r in self.rows
                if r.get("scenario") == scenario
                and r.get("spec", "off") == "off"
                and r.get("overlap", "off") == "off"
                and r.get("devices", "1") == "1"
                and "auto" not in tuple(r.get(a) for a in AXES)]

    def winner(self, scenario: str) -> Optional[Dict[str, str]]:
        """Best policy triple for ``scenario``: {axis: name}, or None."""
        rows = self.comparable_rows(scenario)
        if not rows:
            return None
        best = min(rows, key=self.objective)
        return {a: best[a] for a in AXES}

    def best_objective(self, scenario: str) -> Optional[Tuple]:
        rows = self.comparable_rows(scenario)
        return min(map(self.objective, rows)) if rows else None


# ---------------------------------------------------------------------------
# Active replay context (thread-local, env fallback)

_STATE = threading.local()


def _ctx_stack() -> List[Dict]:
    if not hasattr(_STATE, "stack"):
        _STATE.stack = []
    return _STATE.stack


class perf_context:
    """Scope declaring the workload for policies constructed inside it.

    Engines resolve their policy triple at construction, so wrap the engine
    *constructor* (not just the replay) when using ``auto`` or
    ``predicted-length``::

        with perf_context(scenario=trace.scenario, table=table,
                          length_model=model):
            engine = ServingEngine(...)
    """

    def __init__(self, *, scenario: Optional[str] = None,
                 table: Optional[PerfTable] = None,
                 length_model=None):
        self._frame = {"scenario": scenario, "table": table,
                       "length_model": length_model}

    def __enter__(self):
        _ctx_stack().append(self._frame)
        return self

    def __exit__(self, *exc):
        _ctx_stack().pop()
        return False


def _lookup(key: str):
    for frame in reversed(_ctx_stack()):
        if frame.get(key) is not None:
            return frame[key]
    return None


def active_scenario() -> Optional[str]:
    return _lookup("scenario") or os.environ.get(_ENV_SCENARIO) or None


def active_length_model():
    return _lookup("length_model")


def default_table_path() -> Optional[str]:
    """Committed-table lookup: env override, cwd, then the repo checkout."""
    env = os.environ.get(_ENV_TABLE)
    if env:
        return env
    cwd_path = os.path.join(os.getcwd(), DEFAULT_TABLE_NAME)
    if os.path.exists(cwd_path):
        return cwd_path
    here = os.path.dirname(os.path.abspath(__file__))
    repo = os.path.dirname(os.path.dirname(os.path.dirname(here)))
    repo_path = os.path.join(repo, DEFAULT_TABLE_NAME)
    if os.path.exists(repo_path):
        return repo_path
    return None


_TABLE_CACHE: Dict[Tuple[str, float], PerfTable] = {}


def active_table() -> Optional[PerfTable]:
    """The context's table, else the committed default (None on any miss)."""
    tab = _lookup("table")
    if tab is not None:
        return tab
    path = default_table_path()
    if path is None:
        return None
    try:
        key = (path, os.path.getmtime(path))
        if key not in _TABLE_CACHE:
            _TABLE_CACHE[key] = PerfTable.load(path)
        return _TABLE_CACHE[key]
    except (OSError, ValueError):  # unreadable/incompatible file = no table
        return None


def resolve_winner(axis: str) -> Optional[str]:
    """Winning policy name for ``axis`` under the active (scenario, table).

    Returns None — the caller falls back to defaults with a counted
    ``auto_fallback`` — when there is no active scenario, no table, or the
    table has no comparable rows for the scenario.
    """
    scenario = active_scenario()
    if scenario is None:
        return None
    table = active_table()
    if table is None:
        return None
    triple = table.winner(scenario)
    if triple is None:
        return None
    return triple.get(axis)
