"""Configuration system for the repro framework.

Frozen dataclasses describing models, parallelism, training and serving.
Every assigned architecture lives in ``repro.configs.<id>`` and registers a
``ModelConfig`` under its ``--arch`` id via :func:`register`.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Model-family tags (mirrors the assignment table).
# ---------------------------------------------------------------------------
DENSE = "dense"
MOE = "moe"
SSM = "ssm"
HYBRID = "hybrid"
VLM = "vlm"
AUDIO = "audio"
RECSYS = "recsys"


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts sub-config (token-choice top-k, capacity-based)."""

    num_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN hidden size
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    # Shared dense expert ala granite/qwen-moe shared expert (0 disables).
    d_shared_expert: int = 0


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2-style SSD sub-config (used by zamba2)."""

    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk_size: int = 128

    @property
    def num_heads_for(self) -> Callable[[int], int]:  # pragma: no cover
        raise AttributeError


@dataclass(frozen=True)
class RWKVConfig:
    """RWKV6 "Finch" sub-config: data-dependent decay time mix."""

    head_size: int = 64
    decay_lora: int = 64          # low-rank dim of the data-dependent decay
    token_shift: bool = True


@dataclass(frozen=True)
class AttentionConfig:
    num_heads: int
    num_kv_heads: int
    head_dim: int
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    causal: bool = True
    # Sliding window (0 = full attention).
    window: int = 0


@dataclass(frozen=True)
class ModelConfig:
    """A single architecture. Field names follow the assignment table."""

    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    attention: Optional[AttentionConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    # Hybrid (zamba2): attention block shared across the stack, applied every
    # `hybrid_attn_every` layers.
    hybrid_attn_every: int = 0
    # Encoder-decoder (whisper): encoder depth; num_layers is decoder depth.
    encoder_layers: int = 0
    encoder_seq: int = 0           # fixed encoder sequence (audio frames)
    # VLM: number of vision-stub tokens prepended (internvl).
    vision_tokens: int = 0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    act: str = "silu"              # silu (swiglu) | gelu
    dtype: str = "bfloat16"
    # Max position embeddings are irrelevant for RoPE; kept for reporting.
    max_seq: int = 524_288
    source: str = ""               # provenance string from assignment

    # ---- derived ---------------------------------------------------------
    @property
    def head_dim(self) -> int:
        assert self.attention is not None
        return self.attention.head_dim

    def num_params(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, L, V = self.d_model, self.num_layers, self.vocab_size
        emb = V * d
        head = 0 if self.tie_embeddings else V * d
        per_layer = 0
        if self.family in (DENSE, MOE, VLM, AUDIO):
            a = self.attention
            per_layer += d * a.num_heads * a.head_dim  # q
            per_layer += 2 * d * a.num_kv_heads * a.head_dim  # k,v
            per_layer += a.num_heads * a.head_dim * d  # o
            if self.moe is not None:
                m = self.moe
                per_layer += d * m.num_experts  # router
                per_layer += m.num_experts * 3 * d * m.d_expert
                if m.d_shared_expert:
                    per_layer += 3 * d * m.d_shared_expert
            else:
                per_layer += 3 * d * self.d_ff  # swiglu
            per_layer += 2 * d  # norms
        elif self.family == SSM:  # rwkv6
            per_layer += 4 * d * d            # r,k,v,o (time mix)
            per_layer += d * self.d_ff + self.d_ff * d + d * d  # channel mix
            per_layer += 2 * d
        elif self.family == HYBRID:  # zamba2: mamba2 blocks + shared attn
            s = self.ssm
            d_inner = s.expand * d
            per_layer += d * (2 * d_inner + 2 * s.d_state + d_inner // s.head_dim)
            per_layer += d_inner * d
            per_layer += 2 * d
            a = self.attention
            shared_attn = (
                d * a.num_heads * a.head_dim
                + 2 * d * a.num_kv_heads * a.head_dim
                + a.num_heads * a.head_dim * d
                + 3 * d * self.d_ff
            )
            return emb + head + L * per_layer + shared_attn
        total = emb + head + L * per_layer
        if self.encoder_layers:  # whisper encoder (self-attn + mlp, gelu: 2 mats)
            a = self.attention
            enc_layer = (
                d * a.num_heads * a.head_dim
                + 2 * d * a.num_kv_heads * a.head_dim
                + a.num_heads * a.head_dim * d
                + 2 * d * self.d_ff
                + 2 * d
            )
            # decoder cross-attention adds another attention block per layer
            total += self.encoder_layers * enc_layer
            total += self.num_layers * (
                d * a.num_heads * a.head_dim
                + 2 * d * a.num_kv_heads * a.head_dim
                + a.num_heads * a.head_dim * d
            )
        return total

    def num_active_params(self) -> int:
        """Active (per-token) params — differs from num_params for MoE."""
        if self.moe is None:
            return self.num_params()
        m = self.moe
        d, L = self.d_model, self.num_layers
        dense_total = self.num_params()
        all_experts = L * m.num_experts * 3 * d * m.d_expert
        active_experts = L * m.top_k * 3 * d * m.d_expert
        return dense_total - all_experts + active_experts

    @property
    def depth_units(self) -> int:
        """Repeating-unit count (layers; groups for hybrid)."""
        if self.family == HYBRID:
            return self.num_layers // self.hybrid_attn_every
        return self.num_layers

    def with_depth(self, units: int) -> "ModelConfig":
        """Same width, reduced depth — used by roofline cost probes."""
        if self.family == HYBRID:
            return dataclasses.replace(
                self, num_layers=self.hybrid_attn_every * units)
        if self.encoder_layers:
            return dataclasses.replace(self, num_layers=units,
                                       encoder_layers=units)
        return dataclasses.replace(self, num_layers=units)

    def reduced(self, **overrides: Any) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        small: Dict[str, Any] = dict(
            num_layers=2,
            d_model=64,
            d_ff=128,
            vocab_size=256,
        )
        if self.attention is not None:
            ah = self.attention
            ratio = max(1, ah.num_heads // max(1, ah.num_kv_heads))
            kv = max(1, 4 // ratio)
            small["attention"] = dataclasses.replace(
                ah, num_heads=kv * ratio, num_kv_heads=kv, head_dim=16
            )
        if self.moe is not None:
            small["moe"] = dataclasses.replace(
                self.moe, num_experts=4, top_k=2, d_expert=32,
                d_shared_expert=32 if self.moe.d_shared_expert else 0,
            )
        if self.ssm is not None:
            small["ssm"] = dataclasses.replace(
                self.ssm, d_state=16, head_dim=16, chunk_size=16
            )
        if self.rwkv is not None:
            small["rwkv"] = dataclasses.replace(self.rwkv, head_size=16, decay_lora=8)
        if self.encoder_layers:
            small["encoder_layers"] = 2
            small["encoder_seq"] = 16
        if self.vision_tokens:
            small["vision_tokens"] = 8
        if self.hybrid_attn_every:
            small["hybrid_attn_every"] = 2
        small.update(overrides)
        return dataclasses.replace(self, **small)

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=2)


@dataclass(frozen=True)
class DLRMConfig:
    """DLRM-DCNv2 config (paper Table 3, RM1/RM2)."""

    name: str
    num_tables: int
    num_embeddings: int            # rows per table
    embedding_dim: int             # vector width (bytes swept in benchmarks)
    gathers_per_table: int         # pooling factor (bag size)
    bottom_mlp: Tuple[int, ...]
    top_mlp: Tuple[int, ...]
    cross_rank: int                # DCNv2 low-rank dim
    cross_layers: int
    dense_features: int = 13
    family: str = RECSYS

    def num_params(self) -> int:
        emb = self.num_tables * self.num_embeddings * self.embedding_dim
        mlp = 0
        dims = (self.dense_features,) + self.bottom_mlp
        for a, b in zip(dims[:-1], dims[1:]):
            mlp += a * b + b
        # DCNv2 interaction input: concat([bottom_out, emb_1..emb_T])
        inter_in = self.bottom_mlp[-1] + self.num_tables * self.embedding_dim
        dims = (inter_in,) + self.top_mlp
        for a, b in zip(dims[:-1], dims[1:]):
            mlp += a * b + b
        cross = self.cross_layers * 2 * inter_in * self.cross_rank
        return emb + mlp + cross


# ---------------------------------------------------------------------------
# Input-shape cells (assignment: 4 shapes per LM arch).
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str      # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class ParallelConfig:
    """How a step is laid out on the mesh."""

    data_axis: Tuple[str, ...] = ("pod", "data")
    model_axis: str = "model"
    fsdp_axis: Optional[str] = "data"       # param sharding over data (FSDP)
    expert_axis: Optional[str] = "model"    # expert-parallel axis
    remat: str = "full"                     # none | full | dots
    scan_layers: bool = True
    # Beyond-paper knobs (hillclimbed in EXPERIMENTS.md §Perf):
    seq_shard_long: bool = True             # SP for long-context SSM scan
    compress_grads: bool = False            # int8 all-reduce w/ error feedback


@dataclass(frozen=True)
class TrainConfig:
    model: str
    shape: str = "train_4k"
    lr: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    warmup_steps: int = 100
    total_steps: int = 1000
    grad_clip: float = 1.0
    seed: int = 0
    parallel: ParallelConfig = field(default_factory=ParallelConfig)
    checkpoint_every: int = 200
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3


@dataclass(frozen=True)
class ServeConfig:
    model: str
    shape: str = "decode_32k"
    kv_block_size: int = 128       # tokens per paged KV block
    max_blocks: int = 0            # 0 = derived from shape
    max_batch: int = 128
    max_new_tokens: int = 128
    prefill_chunk: int = 2048
    use_block_list: bool = True    # paper technique ON (False = padded baseline)
    # Operator-backend preference for registry-dispatched ops (the config
    # level of repro.core.dispatch precedence: overridden by explicit args,
    # force_backend scopes and REPRO_BACKEND; falls back to capability-ranked
    # auto when the named backend can't serve this platform/call).
    backend: str = "auto"          # auto | ref | xla | pallas | pallas_interpret
    # Serving-policy preferences (the config level of repro.serving.policy
    # precedence: overridden by explicit ctor args and force_policies scopes;
    # names are validated strictly — there is no capability fallback).
    # "auto" on any axis delegates to the per-scenario winner measured in the
    # committed perf table (repro.perf, docs/perf_gate.md);
    # "predicted-length" admission ranks by a trace-learned decode estimate.
    admission: str = "fcfs"        # fcfs | priority | deadline-slo |
    #                                predicted-length | auto
    preemption: str = "latest-arrival"   # | fewest-remaining-tokens |
    #                                      most-blocks | auto
    eviction: str = "lru"          # lru | hit-rate | refcount-aware |
    #                                tiered | auto
    # Speculative decoding (repro.serving.spec): proposer name resolved
    # through the spec registry ("off" = one token per request per step),
    # and the max draft tokens verified per request per step.
    spec: str = "off"              # off | ngram | draft-model
    spec_k: int = 4
    # Async overlapped engine loop (docs/async_engine.md): step N+1's host
    # work (propose/schedule/render) runs while step N's fused program is
    # still on device; commit happens when the device future resolves.
    # Greedy streams are bit-identical overlap on vs off.
    overlap: bool = False
    # KV-page DMA ring depth for the Pallas chunked-attention kernel
    # (0/1 = BlockSpec pipeline, >= 2 = multi-buffered manual DMA —
    # `prefetch_depth` tunable of the paged_attention_chunked op family).
    prefetch_depth: int = 0
    # Query-chunk tile rows for the chunked paged-attention kernel
    # (`q_chunk` tunable of the paged_attention_chunked op family).
    q_chunk: int = 16
    # Which attention op family the fused step dispatches per layer:
    # "ragged" = paged_attention_ragged (ONE launch for prefill chunks +
    # decode lanes via cu_q_lens/cu_kv_lens metadata over the fused
    # head-interleaved KV pool), "chunked" = the PR-6 token-lane path on
    # split views of the same pool.  Greedy streams are bit-identical.
    attn_impl: str = "ragged"      # ragged | chunked
    # Ragged-kernel tunables (paged_attention_ragged op family,
    # docs/ragged_kernel.md). 0 = consult the committed autotune table
    # (BENCH_010.json via repro.perf.autotune, counted tuned_resolved /
    # tuned_fallback), falling back to the registry defaults; > 0 pins the
    # value explicitly.
    num_queries_per_block: int = 0   # query-tile rows per ragged grid step
    num_kv_pages_per_block: int = 0  # fused KV pages per ragged grid step
    vmem_limit_bytes: int = 0        # VMEM cap for the fused-page DMA ring
    # Mesh-native serving (docs/sharded_serving.md): device count of the
    # serving mesh's model axis. 0/1 = single-device engine; > 1 makes
    # ``repro.launch.serve`` build a mesh (repro.launch.mesh) and the engine
    # run the sharded fused step — params TP-sharded, KV pool
    # sequence-sharded, per-layer log-sum-exp combine over the axis.
    devices: int = 0
    # Disaggregated serving (docs/disaggregated.md): "" = monolithic engine;
    # "prefill,decode" (alias "split") makes ``repro.launch.serve`` build the
    # two-role DisaggEngine — prompts prefill on one engine, committed KV
    # blocks hand off through the allocator's reserve/commit API, decode runs
    # on the other. Greedy streams stay bit-identical to the monolithic
    # engine.
    roles: str = ""
    # Host-memory KV tier capacity in blocks (0 = HBM-only): cached-free
    # blocks evicted from the HBM pool demote into a host LRU instead of
    # dropping their content (gated by the eviction policy's `demote` hook —
    # the `tiered` policy scores it on BlockStats) and promote back into HBM
    # on a prefix hit.
    host_blocks: int = 0
    # Runtime sanitizers (docs/static_analysis.md, repro.analysis.sanitize):
    # retrace guard on the engine step loop, host-sync guard around the
    # overlap build half (allowlisted: disagg-handoff, tier-drain), and
    # BlockAllocator.check_invariants after every commit.  Counters surface
    # in metrics() as sanitize.*; violations raise SanitizeError.
    sanitize: bool = False
    # Trace replay (repro.perf, docs/perf_gate.md): path to a Trace JSON the
    # launcher replays in deterministic virtual time instead of the synthetic
    # workload ("" = synthetic).  The trace's scenario keys the `auto`
    # triple's perf-table lookup and its history fits the predicted-length
    # cost model.
    trace: str = ""
    parallel: ParallelConfig = field(default_factory=ParallelConfig)
    seed: int = 0


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
_REGISTRY: Dict[str, Any] = {}


def register(cfg: Any) -> Any:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> Any:
    if not _REGISTRY:
        _load_all()
    if name not in _REGISTRY:
        _load_all()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown arch {name!r}; known: {sorted(_REGISTRY)}"
        ) from None


def list_configs() -> Sequence[str]:
    _load_all()
    return sorted(_REGISTRY)


def _load_all() -> None:
    import repro.configs  # noqa: F401  (import side effect registers all)
