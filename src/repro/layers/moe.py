"""Token-choice top-k MoE with capacity-based expert-parallel dispatch.

Dispatch strategy (MaxText-style "dropping" MoE, TPU/GSPMD friendly):
  * router logits in f32; top-k gates per token
  * each expert keeps its top-C tokens by gate weight (C = T*k/E * cf),
    computed with ``lax.top_k`` over the (E, T) gate matrix — no (T,E,C)
    one-hot dispatch tensor is ever materialized
  * gathered (E, C, D) activations run a dense SwiGLU einsum per expert
    (single MXU-friendly batched GEMM) and are scatter-added back
  * sharding constraints put E on the expert axis and C on the data axes so
    GSPMD lowers dispatch to all-to-all rather than all-gather
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.config import MoEConfig


def moe_init(key, d_model: int, m: MoEConfig, dtype=jnp.float32):
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    E, F = m.num_experts, m.d_expert
    s = d_model ** -0.5
    p = {
        "router": (jax.random.normal(kr, (d_model, E), jnp.float32) * s).astype(jnp.float32),
        "w_gate": (jax.random.normal(kg, (E, d_model, F), jnp.float32) * s).astype(dtype),
        "w_up": (jax.random.normal(ku, (E, d_model, F), jnp.float32) * s).astype(dtype),
        "w_down": (jax.random.normal(kd, (E, F, d_model), jnp.float32) * F ** -0.5).astype(dtype),
    }
    if m.d_shared_expert:
        from repro.layers.mlp import swiglu_init
        p["shared"] = swiglu_init(ks, d_model, m.d_shared_expert, dtype)
    return p


def _constrain(x, spec: Optional[P]):
    if spec is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x  # outside mesh context (unit tests)


def _resolve_axes(data_axes):
    """Use the activation_sharding scope's axes when available — constraints
    built with axes missing from the mesh are silently dropped (measured:
    the dispatched tensor replicated, +60 GB/dev collectives)."""
    from repro.distributed.act_sharding import current_data_axes
    scoped = current_data_axes()
    return scoped if scoped is not None else data_axes


def moe_apply(params, x, m: MoEConfig, *, data_axes=("pod", "data"),
              expert_axis: Optional[str] = "model", shard: bool = False,
              full_capacity: bool = False, groups: int = 1):
    """x (B,S,D) -> (B,S,D). Capacity-dropped top-k routing.

    ``full_capacity=True`` sets C = T so no token can ever be dropped — the
    decode/serving mode (dropping is a training-throughput trade only).

    ``groups`` (§Perf iteration B1): dispatch is performed independently per
    token group, with the group dim sharded over the data axes. Global-index
    gathers over a data-sharded token tensor lower to masked-gather +
    ALL-REDUCE of the whole (E·C, D) dispatched tensor (measured 32 GB/dev
    per layer at 235B scale); batched per-group gathers stay shard-local and
    only the small routed tensor moves (all-to-all to the expert ranks).
    Set groups = number of data shards.
    """
    B, S, D = x.shape
    T = B * S
    E, K = m.num_experts, m.top_k
    data_axes = _resolve_axes(data_axes)
    G = max(1, min(groups, T))
    while T % G != 0:
        G -= 1
    Tg = T // G
    xg = x.reshape(G, Tg, D)
    if shard:
        xg = _constrain(xg, P(data_axes, None, None))

    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32),
                        params["router"])
    gates_all = jax.nn.softmax(logits, axis=-1)                  # (G,Tg,E) f32
    topk_val, topk_idx = jax.lax.top_k(gates_all, K)             # (G,Tg,K)
    # renormalize over selected experts (qwen/granite style)
    topk_val = topk_val / jnp.maximum(topk_val.sum(-1, keepdims=True), 1e-9)
    # (G,Tg,E) gate matrix restricted to selected experts
    sel = jnp.zeros((G, Tg, E), jnp.float32)
    sel = jax.vmap(jax.vmap(lambda row, idx, val: row.at[idx].set(val)))(
        sel, topk_idx, topk_val)

    if full_capacity:
        C = Tg
    else:
        C = min(max(1, int(Tg * K / E * m.capacity_factor)), Tg)
    # Each expert picks its top-C tokens per group (shard-local competition).
    gate_ec, token_idx = jax.lax.top_k(jnp.swapaxes(sel, 1, 2), C)  # (G,E,C)
    dispatched = jax.vmap(lambda xs, idx: jnp.take(xs, idx.reshape(-1),
                                                   axis=0))(xg, token_idx)
    dispatched = dispatched.reshape(G, E, C, D)
    if shard:
        dispatched = _constrain(dispatched,
                                P(data_axes, expert_axis, None, None))

    g = jnp.einsum("gecd,edf->gecf", dispatched, params["w_gate"])
    u = jnp.einsum("gecd,edf->gecf", dispatched, params["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    out_ec = jnp.einsum("gecf,efd->gecd", h, params["w_down"])
    out_ec = out_ec * gate_ec[..., None].astype(out_ec.dtype)
    if shard:
        out_ec = _constrain(out_ec, P(data_axes, expert_axis, None, None))

    # Scatter-add back to token order (per group, shard-local). Dropped
    # tokens get zero (residual keeps x).
    out = jax.vmap(lambda o, idx, vals: o.at[idx.reshape(-1)].add(
        vals.reshape(E * C, D), mode="drop"))(
        jnp.zeros((G, Tg, D), out_ec.dtype), token_idx, out_ec)
    if "shared" in params:
        from repro.layers.mlp import swiglu
        out = out + swiglu(params["shared"], xg)
    return out.reshape(B, S, D), _aux_loss(
        gates_all.reshape(T, E), topk_idx.reshape(T, K), E)


def _aux_loss(gates_all, topk_idx, E: int):
    """Switch-style load-balance aux loss (mean over tokens)."""
    T, K = topk_idx.shape
    counts = jnp.zeros((E,), jnp.float32).at[topk_idx.reshape(-1)].add(1.0)
    frac_tokens = counts / (T * K)
    frac_gates = gates_all.mean(0)
    return E * jnp.sum(frac_tokens * frac_gates)
