from repro.layers import attention, embedding, mlp, moe, norm, rope, rwkv, ssm  # noqa: F401
