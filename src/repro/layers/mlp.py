"""Feed-forward blocks: SwiGLU (llama/qwen) and GELU (whisper)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _dense_init(key, d_in, d_out, dtype, scale=None):
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def swiglu_init(key, d_model: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": _dense_init(k1, d_model, d_ff, dtype),
        "w_up": _dense_init(k2, d_model, d_ff, dtype),
        "w_down": _dense_init(k3, d_ff, d_model, dtype),
    }


def swiglu(params, x):
    g = jnp.einsum("...d,df->...f", x, params["w_gate"])
    u = jnp.einsum("...d,df->...f", x, params["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, params["w_down"])


def gelu_mlp_init(key, d_model: int, d_ff: int, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    return {
        "w_in": _dense_init(k1, d_model, d_ff, dtype),
        "b_in": jnp.zeros((d_ff,), dtype),
        "w_out": _dense_init(k2, d_ff, d_model, dtype),
        "b_out": jnp.zeros((d_model,), dtype),
    }


def gelu_mlp(params, x):
    h = jnp.einsum("...d,df->...f", x, params["w_in"]) + params["b_in"]
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("...f,fd->...d", h, params["w_out"]) + params["b_out"]


def mlp_init(key, d_model, d_ff, act: str, dtype=jnp.float32):
    if act == "gelu":
        return gelu_mlp_init(key, d_model, d_ff, dtype)
    return swiglu_init(key, d_model, d_ff, dtype)


def mlp_apply(params, x, act: str):
    if act == "gelu":
        return gelu_mlp(params, x)
    return swiglu(params, x)
