"""RWKV6 "Finch" layers: time-mix with data-dependent decay + channel-mix.

Recurrence (per head, head_size N):
    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
w_t is data-dependent (low-rank LoRA on the shifted input) — the defining
RWKV6 feature. Chunked GLA-style form for training (matmul-heavy); masked
decay differences are ≤ 0 before ``exp`` so the math is overflow-safe.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import RWKVConfig
from repro.layers.norm import layernorm, layernorm_init

CHUNK = 32


def _dense(key, i, o, dtype, scale=None):
    s = scale if scale is not None else i ** -0.5
    return (jax.random.normal(key, (i, o), jnp.float32) * s).astype(dtype)


def rwkv_time_mix_init(key, d: int, r: RWKVConfig, dtype=jnp.float32):
    H = d // r.head_size
    ks = jax.random.split(key, 8)
    return {
        "mix_r": jnp.full((d,), 0.5, dtype), "mix_k": jnp.full((d,), 0.5, dtype),
        "mix_v": jnp.full((d,), 0.5, dtype), "mix_w": jnp.full((d,), 0.5, dtype),
        "wr": _dense(ks[0], d, d, dtype), "wk": _dense(ks[1], d, d, dtype),
        "wv": _dense(ks[2], d, d, dtype), "wo": _dense(ks[3], d, d, dtype),
        # data-dependent decay LoRA: w_t = exp(-exp(w0 + tanh(x W1) W2))
        "w0": jnp.full((d,), -2.0, jnp.float32),
        "w1": _dense(ks[4], d, r.decay_lora, jnp.float32),
        "w2": _dense(ks[5], r.decay_lora, d, jnp.float32, scale=0.1),
        "u": jnp.zeros((H, r.head_size), jnp.float32),     # per-head bonus
        "ln_out": layernorm_init(d, dtype),
    }


def rwkv_channel_mix_init(key, d: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "mix_k": jnp.full((d,), 0.5, dtype), "mix_r": jnp.full((d,), 0.5, dtype),
        "wk": _dense(k1, d, d_ff, dtype),
        "wv": _dense(k2, d_ff, d, dtype),
        "wr": _dense(k3, d, d, dtype),
    }


def _token_shift(x, prev=None):
    """x (B,S,D) -> x shifted right by one; prev (B,D) fills position 0."""
    if prev is None:
        prev = jnp.zeros_like(x[:, 0])
    return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)


def _lerp(x, x_sh, mix):
    return x + (x_sh - x) * mix


def _decay(params, xw):
    """log decay per channel, clamped ≤ ~0: (B,S,D) f32."""
    lora = jnp.tanh(xw.astype(jnp.float32) @ params["w1"]) @ params["w2"]
    return -jnp.exp(jnp.clip(params["w0"] + lora, -20.0, 8.0))


def time_mix_chunked(params, x, r_cfg: RWKVConfig):
    """Training/prefill form. x (B,S,D) -> (B,S,D)."""
    B, S, D = x.shape
    N = r_cfg.head_size
    H = D // N
    x_sh = _token_shift(x)
    rr = _lerp(x, x_sh, params["mix_r"]) @ params["wr"]
    kk = _lerp(x, x_sh, params["mix_k"]) @ params["wk"]
    vv = _lerp(x, x_sh, params["mix_v"]) @ params["wv"]
    lw = _decay(params, _lerp(x, x_sh, params["mix_w"]))   # (B,S,D) log-decay

    Lc = min(CHUNK, S)
    assert S % Lc == 0, (S, Lc)
    nc = S // Lc

    def rs(t):
        return t.reshape(B, nc, Lc, H, N)

    r, k, v, lw = rs(rr), rs(kk), rs(vv), rs(lw)
    la_incl = jnp.cumsum(lw, axis=2)                       # (B,nc,Lc,H,N)
    la_excl = la_incl - lw
    idx = jnp.arange(Lc)
    mask_lt = idx[:, None] > idx[None, :]                  # j < i

    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    # intra: A_ij = sum_n r_in k_jn exp(la_excl_i - la_incl_j), j<i; diag bonus u
    ddiff = la_excl[:, :, :, None] - la_incl[:, :, None, :, :]  # (B,nc,i,j,H,N)
    ddiff = jnp.where(mask_lt[None, None, :, :, None, None], ddiff, -jnp.inf)
    A = jnp.einsum("bcihn,bcjhn,bcijhn->bcijh", rf, kf, jnp.exp(ddiff))
    diag = jnp.einsum("bcihn,hn,bcihn->bcih", rf, params["u"], kf)
    A = A + diag[:, :, :, None, :] * jnp.eye(Lc)[None, None, :, :, None]
    y_intra = jnp.einsum("bcijh,bcjhn->bcihn", A, vf)

    # inter-chunk state scan: h maps k-dim -> v-dim, (B,H,N,N)
    dec_to_end = jnp.exp(la_incl[:, :, -1:] - la_incl)     # (B,nc,Lc,H,N)
    chunk_state = jnp.einsum("bcjhn,bcjhm->bchnm", kf * dec_to_end, vf)
    chunk_decay = jnp.exp(la_incl[:, :, -1])               # (B,nc,H,N)

    def scan_fn(h_prev, inp):
        st, dec = inp
        return h_prev * dec[..., None] + st, h_prev

    h0 = jnp.zeros((B, H, N, N), jnp.float32)
    _, h_prevs = jax.lax.scan(
        scan_fn, h0,
        (jnp.moveaxis(chunk_state, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                  # (B,nc,H,N,N)

    y_inter = jnp.einsum("bcihn,bchnm->bcihm", rf * jnp.exp(la_excl), h_prevs)
    y = (y_intra + y_inter).reshape(B, S, D).astype(x.dtype)
    y = layernorm(params["ln_out"], y)
    return y @ params["wo"]


def time_mix_step(params, x, state, r_cfg: RWKVConfig):
    """Decode step. x (B,1,D); state {"shift": (B,D), "S": (B,H,N,N)}."""
    B, _, D = x.shape
    N = r_cfg.head_size
    H = D // N
    x_sh = state["shift"][:, None]
    rr = (_lerp(x, x_sh, params["mix_r"]) @ params["wr"]).reshape(B, H, N)
    kk = (_lerp(x, x_sh, params["mix_k"]) @ params["wk"]).reshape(B, H, N)
    vv = (_lerp(x, x_sh, params["mix_v"]) @ params["wv"]).reshape(B, H, N)
    lw = _decay(params, _lerp(x, x_sh, params["mix_w"])).reshape(B, H, N)
    rf, kf, vf = (t.astype(jnp.float32) for t in (rr, kk, vv))
    S_prev = state["S"]
    kv = jnp.einsum("bhn,bhm->bhnm", kf, vf)
    out = jnp.einsum("bhn,bhnm->bhm", rf, S_prev + params["u"][..., None] * kv)
    S_new = jnp.exp(lw)[..., None] * S_prev + kv
    y = layernorm(params["ln_out"], out.reshape(B, 1, D).astype(x.dtype))
    return y @ params["wo"], {"shift": x[:, 0], "S": S_new}


def channel_mix(params, x, prev=None):
    """x (B,S,D) -> (B,S,D). Returns (out, last_x) for decode chaining."""
    x_sh = _token_shift(x, prev)
    k = _lerp(x, x_sh, params["mix_k"]) @ params["wk"]
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(x.dtype)
    r = jax.nn.sigmoid((_lerp(x, x_sh, params["mix_r"]) @ params["wr"]).astype(jnp.float32))
    return (k @ params["wv"]) * r.astype(x.dtype), x[:, -1]


def rwkv_init_state(batch: int, d: int, r: RWKVConfig, dtype=jnp.float32):
    H = d // r.head_size
    return {
        "tm_shift": jnp.zeros((batch, d), dtype),
        "cm_shift": jnp.zeros((batch, d), dtype),
        "S": jnp.zeros((batch, H, r.head_size, r.head_size), jnp.float32),
    }
