"""GQA multi-head attention (qk-norm / bias variants), MXU-friendly.

Three attention cores:
  * ``full_attention``      O(S^2) reference (tests, tiny shapes)
  * ``chunked_attention``   scan over query chunks — bounded memory; this is
    the form lowered in train/prefill dry-runs (remat-friendly)
  * decode goes through ``repro.core.attention_api`` (paged, paper technique)
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import AttentionConfig
from repro.layers.norm import rmsnorm, rmsnorm_init
from repro.layers.rope import apply_rope

NEG_INF = -1e30


def _dense_init(key, d_in, d_out, dtype):
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * d_in ** -0.5).astype(dtype)


def attention_init(key, d_model: int, a: AttentionConfig, dtype=jnp.float32):
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(kq, d_model, a.num_heads * a.head_dim, dtype),
        "wk": _dense_init(kk, d_model, a.num_kv_heads * a.head_dim, dtype),
        "wv": _dense_init(kv, d_model, a.num_kv_heads * a.head_dim, dtype),
        "wo": _dense_init(ko, a.num_heads * a.head_dim, d_model, dtype),
    }
    if a.qkv_bias:
        p["bq"] = jnp.zeros((a.num_heads * a.head_dim,), dtype)
        p["bk"] = jnp.zeros((a.num_kv_heads * a.head_dim,), dtype)
        p["bv"] = jnp.zeros((a.num_kv_heads * a.head_dim,), dtype)
    if a.qk_norm:
        p["q_norm"] = rmsnorm_init(a.head_dim, dtype)
        p["k_norm"] = rmsnorm_init(a.head_dim, dtype)
    return p


def project_qkv(params, x, a: AttentionConfig, positions):
    """x (B,S,D) -> q (B,S,H,hd), k/v (B,S,KV,hd), rope applied."""
    B, S, _ = x.shape
    q = jnp.einsum("bsd,de->bse", x, params["wq"])
    k = jnp.einsum("bsd,de->bse", x, params["wk"])
    v = jnp.einsum("bsd,de->bse", x, params["wv"])
    if "bq" in params:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(B, S, a.num_heads, a.head_dim)
    k = k.reshape(B, S, a.num_kv_heads, a.head_dim)
    v = v.reshape(B, S, a.num_kv_heads, a.head_dim)
    if "q_norm" in params:
        q = rmsnorm(params["q_norm"], q)
        k = rmsnorm(params["k_norm"], k)
    q = apply_rope(q, positions, a.rope_theta)
    k = apply_rope(k, positions, a.rope_theta)
    return q, k, v


def _group(q, num_kv: int):
    """(B,S,H,hd) -> (B,S,KV,G,hd) grouped by kv head."""
    B, S, H, hd = q.shape
    return q.reshape(B, S, num_kv, H // num_kv, hd)


def full_attention(q, k, v, *, causal: bool = True,
                   q_positions=None, kv_positions=None) -> jnp.ndarray:
    """Reference attention. q (B,Sq,H,hd); k,v (B,Sk,KV,hd) -> (B,Sq,H,hd)."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    qg = _group(q, KV)
    scores = jnp.einsum("bikgd,bjkd->bkgij", qg, k).astype(jnp.float32)
    scores = scores * (hd ** -0.5)
    if causal:
        qi = q_positions if q_positions is not None else jnp.arange(Sq)
        kj = kv_positions if kv_positions is not None else jnp.arange(k.shape[1])
        mask = qi[:, None] >= kj[None, :]
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgij,bjkd->bikgd", w, v)
    return out.reshape(B, Sq, H, hd)


def chunked_attention(q, k, v, *, causal: bool = True,
                      chunk: int = 512, unroll: bool = False) -> jnp.ndarray:
    """Query-chunked attention: scan over q chunks, full KV per step.

    Memory per step is (B, KV, G, chunk, Sk) f32 — bounded; with scan remat
    this keeps prefill_32k compilable on every mesh. (Pallas flash kernel is
    the TPU runtime path; this is the lowering-equivalent jnp form.)
    """
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    if Sq <= chunk:
        return full_attention(q, k, v, causal=causal)
    if Sq % chunk != 0:  # largest divisor of Sq ≤ chunk (e.g. whisper's 1500)
        c = chunk
        while Sq % c != 0:
            c -= 1
        if c < 32:
            return full_attention(q, k, v, causal=causal)
        chunk = c
    n = Sq // chunk
    qg = _group(q, KV).reshape(B, n, chunk, KV, H // KV, hd)
    kj = jnp.arange(Sk)

    def step(_, qc_i):
        qc, i = qc_i                                     # (B,chunk,KV,G,hd)
        scores = jnp.einsum("bikgd,bjkd->bkgij", qc, k).astype(jnp.float32)
        scores = scores * (hd ** -0.5)
        if causal:
            qi = i * chunk + jnp.arange(chunk)
            mask = qi[:, None] >= kj[None, :]
            scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        out = jnp.einsum("bkgij,bjkd->bikgd", w, v)
        return None, out

    if unroll:  # cost probes: XLA cost analysis counts scan bodies once
        outs = [step(None, (qg[:, i], jnp.asarray(i)))[1] for i in range(n)]
        out = jnp.stack(outs, axis=1)                    # (B,n,chunk,KV,G,hd)
        return out.reshape(B, Sq, H, hd)
    _, outs = jax.lax.scan(step, None,
                           (jnp.moveaxis(qg, 1, 0), jnp.arange(n)))
    out = jnp.moveaxis(outs, 0, 1)                       # (B,n,chunk,KV,G,hd)
    return out.reshape(B, Sq, H, hd)


def attention_block(params, x, positions, a: AttentionConfig, *,
                    causal: Optional[bool] = None, chunk: int = 512,
                    unroll: bool = False):
    """Full attention block for train/prefill. Returns (out, (k, v))."""
    causal = a.causal if causal is None else causal
    q, k, v = project_qkv(params, x, a, positions)
    ctx = chunked_attention(q, k, v, causal=causal, chunk=chunk,
                            unroll=unroll)
    B, S = x.shape[:2]
    out = jnp.einsum("bse,ed->bsd", ctx.reshape(B, S, -1), params["wo"])
    return out, (k, v)


def cross_attention_block(params, x, kv_cache, a: AttentionConfig):
    """Whisper decoder cross-attn: kv precomputed from encoder (no rope)."""
    B, S, _ = x.shape
    q = jnp.einsum("bsd,de->bse", x, params["wq"])
    if "bq" in params:
        q = q + params["bq"]
    q = q.reshape(B, S, a.num_heads, a.head_dim)
    k, v = kv_cache
    ctx = full_attention(q, k, v, causal=False)
    return jnp.einsum("bse,ed->bsd", ctx.reshape(B, S, -1), params["wo"])


def encode_kv(params, enc, a: AttentionConfig):
    """Project encoder output to cross-attention K/V once (cached)."""
    B, S, _ = enc.shape
    k = jnp.einsum("bsd,de->bse", enc, params["wk"])
    v = jnp.einsum("bsd,de->bse", enc, params["wv"])
    if "bk" in params:
        k, v = k + params["bk"], v + params["bv"]
    return (k.reshape(B, S, a.num_kv_heads, a.head_dim),
            v.reshape(B, S, a.num_kv_heads, a.head_dim))
