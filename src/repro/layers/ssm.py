"""Mamba2-style SSD block (used by zamba2), chunked + single-step decode.

Recurrence (per head h, head_dim P, state N):
    h_t = a_t * h_{t-1} + (dt_t * x_t) ⊗ B_t        a_t scalar per head
    y_t = h_t C_t + D * x_t
Chunked form: intra-chunk is a masked (C·B) "attention" matmul; inter-chunk
is a scan over chunk states. All decay exponents are ≤ 0 by construction so
``exp`` is overflow-safe (masking happens before exponentiation).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.config import SSMConfig
from repro.layers.norm import rmsnorm, rmsnorm_init


class SSMDims(NamedTuple):
    d_inner: int
    num_heads: int
    conv_dim: int


def ssm_dims(d_model: int, s: SSMConfig) -> SSMDims:
    d_inner = s.expand * d_model
    num_heads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.d_state
    return SSMDims(d_inner, num_heads, conv_dim)


def ssm_init(key, d_model: int, s: SSMConfig, dtype=jnp.float32):
    dims = ssm_dims(d_model, s)
    ki, kc, ko, kd = jax.random.split(key, 4)
    in_dim = 2 * dims.d_inner + 2 * s.d_state + dims.num_heads  # z,x,B,C,dt
    scale = d_model ** -0.5
    return {
        "in_proj": (jax.random.normal(ki, (d_model, in_dim), jnp.float32) * scale).astype(dtype),
        "conv_w": (jax.random.normal(kc, (s.d_conv, dims.conv_dim), jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((dims.conv_dim,), dtype),
        "A_log": jnp.zeros((dims.num_heads,), jnp.float32),
        "D": jnp.ones((dims.num_heads,), jnp.float32),
        "dt_bias": jnp.zeros((dims.num_heads,), jnp.float32),
        "norm": rmsnorm_init(dims.d_inner, dtype),
        "out_proj": (jax.random.normal(ko, (dims.d_inner, d_model), jnp.float32)
                     * dims.d_inner ** -0.5).astype(dtype),
    }


def _split_in(proj, dims: SSMDims, s: SSMConfig):
    z, xBC, dt = jnp.split(
        proj, [dims.d_inner, dims.d_inner + dims.conv_dim], axis=-1)
    return z, xBC, dt


def _causal_conv(xBC, w, b, prev=None):
    """Depthwise causal conv1d. xBC (B,S,C); w (K,C). prev (B,K-1,C) or None."""
    K = w.shape[0]
    if prev is None:
        prev = jnp.zeros(xBC.shape[:1] + (K - 1,) + xBC.shape[2:], xBC.dtype)
    xp = jnp.concatenate([prev, xBC], axis=1)
    out = sum(xp[:, i:i + xBC.shape[1]] * w[i] for i in range(K)) + b
    new_prev = xp[:, -(K - 1):] if K > 1 else prev
    return jax.nn.silu(out.astype(jnp.float32)).astype(xBC.dtype), new_prev


def ssm_chunked(params, x, s: SSMConfig, d_model: int):
    """x (B,S,D) -> (B,S,D). Training / prefill form."""
    dims = ssm_dims(d_model, s)
    B, S, _ = x.shape
    proj = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    z, xBC, dt_raw = _split_in(proj, dims, s)
    xBC, _ = _causal_conv(xBC, params["conv_w"], params["conv_b"])
    xs, Bm, Cm = jnp.split(xBC, [dims.d_inner, dims.d_inner + s.d_state], axis=-1)
    H, P, N = dims.num_heads, s.head_dim, s.d_state
    xs = xs.reshape(B, S, H, P)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])   # (B,S,H)
    la_step = -jnp.exp(params["A_log"]) * dt                               # log a_t ≤ 0

    Lc = min(s.chunk_size, S)
    assert S % Lc == 0, (S, Lc)
    nc = S // Lc

    def reshape_c(t):
        return t.reshape((B, nc, Lc) + t.shape[2:])

    xs_c, B_c, C_c = reshape_c(xs), reshape_c(Bm), reshape_c(Cm)
    la_c = reshape_c(la_step)                                              # (B,nc,Lc,H)
    dtx = xs_c * dt.reshape(B, nc, Lc, H)[..., None].astype(xs_c.dtype)    # dt*x

    la_incl = jnp.cumsum(la_c, axis=2)                                     # (B,nc,Lc,H)
    idx = jnp.arange(Lc)
    mask = idx[:, None] >= idx[None, :]                                    # j<=i

    # intra-chunk: A[b,c,h,i,j] = (C_i·B_j) exp(la_i - la_j), j<=i
    cb = jnp.einsum("bcin,bcjn->bcij", C_c, B_c).astype(jnp.float32)       # (B,nc,Lc,Lc)
    ddiff = la_incl[:, :, :, None, :] - la_incl[:, :, None, :, :]          # (B,nc,i,j,H)
    ddiff = jnp.where(mask[None, None, :, :, None], ddiff, -jnp.inf)
    A = cb[..., None] * jnp.exp(ddiff)                                     # (B,nc,i,j,H)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", A.astype(xs_c.dtype), dtx)

    # inter-chunk state scan
    #   state contribution of chunk: sum_j exp(la_end - la_j) dtx_j ⊗ B_j
    dec_to_end = jnp.exp(la_incl[:, :, -1:, :] - la_incl)                  # (B,nc,Lc,H)
    chunk_state = jnp.einsum(
        "bcjh,bcjhp,bcjn->bchpn",
        dec_to_end.astype(jnp.float32), dtx.astype(jnp.float32),
        B_c.astype(jnp.float32))
    chunk_decay = jnp.exp(la_incl[:, :, -1, :])                            # (B,nc,H)

    def scan_fn(h_prev, inp):
        st, dec = inp                                                      # (B,H,P,N),(B,H)
        h_new = h_prev * dec[:, :, None, None] + st
        return h_new, h_prev

    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    _, h_prevs = jax.lax.scan(
        scan_fn, h0,
        (jnp.moveaxis(chunk_state, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                                  # (B,nc,H,P,N)

    # inter-chunk output: y_i += (C_i exp(la_incl_i)) · h_prev_chunk
    dec_from_start = jnp.exp(la_incl)                                      # (B,nc,Lc,H)
    y_inter = jnp.einsum(
        "bcin,bchpn,bcih->bcihp",
        C_c.astype(jnp.float32), h_prevs, dec_from_start.astype(jnp.float32))

    y = y_intra.astype(jnp.float32) + y_inter
    y = y + xs_c.astype(jnp.float32) * params["D"][None, None, None, :, None]
    y = y.reshape(B, S, dims.d_inner).astype(x.dtype)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype))
    return jnp.einsum("bse,ed->bsd", y, params["out_proj"])


def ssm_init_state(batch: int, d_model: int, s: SSMConfig, dtype=jnp.float32):
    dims = ssm_dims(d_model, s)
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, dims.conv_dim), dtype),
        "h": jnp.zeros((batch, dims.num_heads, s.head_dim, s.d_state), jnp.float32),
    }


def ssm_step(params, x, state, s: SSMConfig, d_model: int):
    """Single decode step. x (B,1,D) -> (B,1,D), new state."""
    dims = ssm_dims(d_model, s)
    B = x.shape[0]
    proj = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    z, xBC, dt_raw = _split_in(proj, dims, s)
    xBC, new_conv = _causal_conv(xBC, params["conv_w"], params["conv_b"],
                                 prev=state["conv"])
    xs, Bm, Cm = jnp.split(xBC, [dims.d_inner, dims.d_inner + s.d_state], axis=-1)
    H, P, N = dims.num_heads, s.head_dim, s.d_state
    xs = xs.reshape(B, H, P)
    Bm, Cm = Bm[:, 0], Cm[:, 0]                                            # (B,N)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + params["dt_bias"])  # (B,H)
    a = jnp.exp(-jnp.exp(params["A_log"]) * dt)                            # (B,H)
    dtx = xs.astype(jnp.float32) * dt[..., None]
    h = state["h"] * a[:, :, None, None] + jnp.einsum("bhp,bn->bhpn", dtx, Bm.astype(jnp.float32))
    y = jnp.einsum("bhpn,bn->bhp", h, Cm.astype(jnp.float32))
    y = y + xs.astype(jnp.float32) * params["D"][None, :, None]
    y = y.reshape(B, 1, dims.d_inner).astype(x.dtype)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype))
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"])
    return out, {"conv": new_conv, "h": h}
