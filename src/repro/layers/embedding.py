"""Token embedding / LM head (vocab-sharded friendly layouts)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def embedding_init(key, vocab: int, d: int, dtype=jnp.float32):
    return {"table": (jax.random.normal(key, (vocab, d), jnp.float32)
                      * d ** -0.5).astype(dtype)}


def embed(params, tokens):
    return jnp.take(params["table"], tokens, axis=0)


def unembed(params_head, x):
    """x (B,S,D) -> logits (B,S,V), f32 accumulation over bf16 operands.

    §Perf iteration A1: the earlier ``.astype(f32)`` materialized an f32
    COPY of the whole vocab table every step (2·V·D extra write + 2× read);
    ``preferred_element_type`` keeps operands bf16 and accumulates f32 on
    the MXU — same numerics, none of the traffic.
    """
    return jax.lax.dot_general(
        x, params_head["table"],
        (((x.ndim - 1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


def head_init(key, vocab: int, d: int, dtype=jnp.float32):
    return embedding_init(key, vocab, d, dtype)
