"""llama31-70b — the paper's multi-device TP serving workload (Table 3).
80L hidden=8192 64H (GQA kv=8) d_ff=28672 vocab=128256."""
from repro.config import AttentionConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama31-70b",
    family="dense",
    num_layers=80,
    d_model=8192,
    d_ff=28_672,
    vocab_size=128_256,
    attention=AttentionConfig(
        num_heads=64, num_kv_heads=8, head_dim=128,
        qk_norm=False, qkv_bias=False, rope_theta=500_000.0,
    ),
    act="silu",
    source="paper Table 3 / arXiv:2407.21783",
))
