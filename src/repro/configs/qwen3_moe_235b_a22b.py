"""qwen3-moe-235b-a22b [moe] — 94L d_model=4096 64H (GQA kv=4) moe_d_ff=1536
vocab=151936, MoE 128 experts top-8. [hf:Qwen/Qwen3-30B-A3B family; hf]"""
from repro.config import AttentionConfig, ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    d_ff=1536,                    # MoE expert FFN width (per assignment)
    vocab_size=151_936,
    attention=AttentionConfig(
        num_heads=64, num_kv_heads=4, head_dim=128,
        qk_norm=True, qkv_bias=False, rope_theta=1_000_000.0,
    ),
    moe=MoEConfig(num_experts=128, top_k=8, d_expert=1536),
    act="silu",
    source="hf:Qwen/Qwen3-30B-A3B; hf",
))
