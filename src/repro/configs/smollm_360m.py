"""smollm-360m [dense] — 32L d_model=960 15H (GQA kv=5) d_ff=2560
vocab=49152. llama-arch small. [hf:HuggingFaceTB/SmolLM-135M family; hf]"""
from repro.config import AttentionConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="smollm-360m",
    family="dense",
    num_layers=32,
    d_model=960,
    d_ff=2560,
    vocab_size=49_152,
    attention=AttentionConfig(
        num_heads=15, num_kv_heads=5, head_dim=64,
        qk_norm=False, qkv_bias=False, rope_theta=10_000.0,
    ),
    tie_embeddings=True,
    act="silu",
    source="hf:HuggingFaceTB/SmolLM-135M; hf",
))
