"""RM2 — memory-intensive DLRM-DCNv2 (paper Table 3): embedding dominated."""
from repro.config import DLRMConfig, register

CONFIG = register(DLRMConfig(
    name="rm2",
    num_tables=20,
    num_embeddings=1_000_000,
    embedding_dim=64,
    gathers_per_table=20,
    bottom_mlp=(256, 64, 64),
    top_mlp=(128, 64, 1),
    cross_rank=64,
    cross_layers=2,
))
