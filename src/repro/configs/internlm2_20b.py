"""internlm2-20b [dense] — 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92544. GQA. [arXiv:2403.17297; hf]"""
from repro.config import AttentionConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="internlm2-20b",
    family="dense",
    num_layers=48,
    d_model=6144,
    d_ff=16_384,
    vocab_size=92_544,
    attention=AttentionConfig(
        num_heads=48, num_kv_heads=8, head_dim=128,
        qk_norm=False, qkv_bias=False, rope_theta=1_000_000.0,
    ),
    act="silu",
    source="arXiv:2403.17297; hf",
))
