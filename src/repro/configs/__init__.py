"""Architecture registry — one module per assigned architecture.

Importing this package registers every config under its ``--arch`` id.
"""
from repro.configs import (  # noqa: F401
    granite_moe_1b_a400m,
    internlm2_20b,
    internvl2_26b,
    llama31_8b,
    llama31_70b,
    qwen2_1_5b,
    qwen3_32b,
    qwen3_moe_235b_a22b,
    rm1,
    rm2,
    rwkv6_1_6b,
    smollm_360m,
    whisper_tiny,
    zamba2_2_7b,
)

ASSIGNED_LM_ARCHS = [
    "qwen3-moe-235b-a22b",
    "granite-moe-1b-a400m",
    "qwen2-1.5b",
    "qwen3-32b",
    "internlm2-20b",
    "smollm-360m",
    "internvl2-26b",
    "rwkv6-1.6b",
    "zamba2-2.7b",
    "whisper-tiny",
]
PAPER_ARCHS = ["llama31-8b", "llama31-70b", "rm1", "rm2"]
