"""granite-moe-1b-a400m [moe] — 24L d_model=1024 16H (GQA kv=8) d_ff=512
vocab=49155, MoE 32 experts top-8. [hf:ibm-granite/granite-3.0-1b-a400m-base]"""
from repro.config import AttentionConfig, ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    d_ff=512,
    vocab_size=49_155,
    attention=AttentionConfig(
        num_heads=16, num_kv_heads=8, head_dim=64,
        qk_norm=False, qkv_bias=False, rope_theta=10_000.0,
    ),
    moe=MoEConfig(num_experts=32, top_k=8, d_expert=512),
    tie_embeddings=True,
    act="silu",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
))
