"""rwkv6-1.6b [ssm] — 24L d_model=2048 (attention-free) d_ff=7168 vocab=65536.
Finch: data-dependent decay. [arXiv:2404.05892; unverified]"""
from repro.config import ModelConfig, RWKVConfig, register

CONFIG = register(ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    num_layers=24,
    d_model=2048,
    d_ff=7168,
    vocab_size=65_536,
    rwkv=RWKVConfig(head_size=64, decay_lora=64, token_shift=True),
    act="relu",                   # rwkv channel-mix uses squared relu
    source="arXiv:2404.05892; unverified",
))
