"""internvl2-26b [vlm] — 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92553. InternViT frontend is a STUB: input_specs() supplies precomputed
patch embeddings (vision_tokens per image) prepended to the text sequence.
[arXiv:2404.16821; hf]"""
from repro.config import AttentionConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="internvl2-26b",
    family="vlm",
    num_layers=48,
    d_model=6144,
    d_ff=16_384,
    vocab_size=92_553,
    attention=AttentionConfig(
        num_heads=48, num_kv_heads=8, head_dim=128,
        qk_norm=False, qkv_bias=False, rope_theta=1_000_000.0,
    ),
    vision_tokens=256,            # pixel-unshuffled InternViT tile -> 256 tokens
    act="silu",
    source="arXiv:2404.16821; hf",
))
