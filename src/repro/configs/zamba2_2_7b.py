"""zamba2-2.7b [hybrid] — 54L d_model=2560 32H (GQA kv=32) d_ff=10240
ssm_state=64. Mamba2 backbone + ONE shared attention block applied every 6
mamba layers (weight-shared, zamba-style). [arXiv:2411.15242; hf]"""
from repro.config import AttentionConfig, ModelConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    d_ff=10_240,
    vocab_size=32_000,
    attention=AttentionConfig(
        num_heads=32, num_kv_heads=32, head_dim=80,
        qk_norm=False, qkv_bias=False, rope_theta=10_000.0,
    ),
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk_size=128),
    hybrid_attn_every=6,
    act="silu",
    source="arXiv:2411.15242; hf",
))
