"""llama31-8b — the paper's own end-to-end LLM workload (Table 3).
32L hidden=4096 32H (GQA kv=8) d_ff=14336 vocab=128256."""
from repro.config import AttentionConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama31-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    d_ff=14_336,
    vocab_size=128_256,
    attention=AttentionConfig(
        num_heads=32, num_kv_heads=8, head_dim=128,
        qk_norm=False, qkv_bias=False, rope_theta=500_000.0,
    ),
    act="silu",
    source="paper Table 3 / arXiv:2407.21783",
))
