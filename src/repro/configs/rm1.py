"""RM1 — compute-intensive DLRM-DCNv2 (paper Table 3)."""
from repro.config import DLRMConfig, register

CONFIG = register(DLRMConfig(
    name="rm1",
    num_tables=10,
    num_embeddings=1_000_000,
    embedding_dim=128,
    gathers_per_table=10,
    bottom_mlp=(512, 256, 64),
    top_mlp=(1024, 1024, 512, 256, 1),
    cross_rank=512,
    cross_layers=3,
))
