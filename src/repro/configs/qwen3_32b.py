"""qwen3-32b [dense] — 64L d_model=5120 64H (GQA kv=8) d_ff=25600
vocab=151936. qk_norm, GQA. [hf:Qwen/Qwen3-8B family; hf]"""
from repro.config import AttentionConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    d_ff=25_600,
    vocab_size=151_936,
    attention=AttentionConfig(
        num_heads=64, num_kv_heads=8, head_dim=128,
        qk_norm=True, qkv_bias=False, rope_theta=1_000_000.0,
    ),
    act="silu",
    source="hf:Qwen/Qwen3-8B; hf",
))
