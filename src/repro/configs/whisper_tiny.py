"""whisper-tiny [audio] — enc-dec, 4L d_model=384 6H d_ff=1536 vocab=51865.
Conv frontend is a STUB: input_specs() supplies precomputed frame embeddings
(encoder_seq x d_model). [arXiv:2212.04356; unverified]"""
from repro.config import AttentionConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-tiny",
    family="audio",
    num_layers=4,                 # decoder layers
    encoder_layers=4,
    encoder_seq=1500,             # 30 s of audio at 50 Hz after conv stub
    d_model=384,
    d_ff=1536,
    vocab_size=51_865,
    attention=AttentionConfig(
        num_heads=6, num_kv_heads=6, head_dim=64,
        qk_norm=False, qkv_bias=True, rope_theta=10_000.0, causal=True,
    ),
    act="gelu",
    source="arXiv:2212.04356; unverified",
))
