"""Embedding-bag lookups: SingleTable baseline vs fused BatchedTable.

Reproduces the paper's §4.1 FBGEMM/DLRM case study:

* :func:`single_table_lookup` — one op launch **per table** (Gaudi-SDK
  SingleTable analogue). N tables ⇒ N gathers over small index sets; at low
  batch each launch underutilizes memory bandwidth (paper Fig 15a).
* :func:`batched_table_lookup` — the paper's BatchedTable: all tables are
  concatenated into ONE tall table, per-table start offsets translate local
  row ids to global rows, and a single fused gather+pool op serves every
  (table, bag) pair. One launch, maximal memory-level parallelism.

Bags are fixed-size (pooling factor L, as in the paper's RM configs).
``batched_table_lookup`` math is identical to the Pallas kernel in
``repro.kernels.batched_embedding``.
"""
from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def concat_tables(tables: Sequence[jnp.ndarray]):
    """Stack per-table (rows_t, dim) arrays -> (Σrows, dim) + offsets (T,)."""
    offs = np.cumsum([0] + [t.shape[0] for t in tables[:-1]]).astype(np.int32)
    return jnp.concatenate(tables, axis=0), jnp.asarray(offs)


def single_table_lookup(tables: Sequence[jnp.ndarray], indices: jnp.ndarray):
    """Baseline: per-table gathers (T separate ops).

    indices (B, T, L) local row ids. Returns pooled (B, T, D).
    """
    outs: List[jnp.ndarray] = []
    B, T, L = indices.shape
    for t in range(T):  # one "kernel launch" per table — the baseline cost
        rows = jnp.take(tables[t], indices[:, t].reshape(-1), axis=0)
        outs.append(rows.reshape(B, L, -1).sum(axis=1))
    return jnp.stack(outs, axis=1)


def batched_table_lookup(big_table: jnp.ndarray, table_offsets: jnp.ndarray,
                         indices: jnp.ndarray):
    """Fused: ONE gather over the concatenated table (paper's BatchedTable).

    big_table (ΣR, D); table_offsets (T,); indices (B, T, L) local row ids.
    Returns pooled (B, T, D).
    """
    B, T, L = indices.shape
    global_idx = indices + table_offsets[None, :, None]
    rows = jnp.take(big_table, global_idx.reshape(-1), axis=0)
    return rows.reshape(B, T, L, -1).sum(axis=2)


def batched_table_lookup_sharded(big_table, table_offsets, indices, *,
                                 axis: str):
    """Beyond-paper: row-sharded tables inside shard_map.

    Rows are sharded over ``axis`` (size A); each rank gathers rows it owns
    (others → 0) and a psum combines — the standard TorchRec row-wise
    parallel embedding, expressed with jax collectives.
    """
    A = jax.lax.psum(1, axis)
    rank = jax.lax.axis_index(axis)
    rows_per = big_table.shape[0]                # local rows
    global_idx = indices + table_offsets[None, :, None]
    local = global_idx - rank * rows_per
    in_range = (local >= 0) & (local < rows_per)
    safe = jnp.clip(local, 0, rows_per - 1)
    rows = jnp.take(big_table, safe.reshape(-1), axis=0)
    rows = jnp.where(in_range.reshape(-1)[:, None], rows, 0)
    B, T, L = indices.shape
    pooled = rows.reshape(B, T, L, -1).sum(axis=2)
    return jax.lax.psum(pooled, axis)


def embedding_bag(big_table, table_offsets, indices, backend=None):
    """BatchedTable embedding bag through the unified registry.

    ONE resolver call (:mod:`repro.core.dispatch`); implementations are
    registered in ``repro.kernels.batched_embedding.ops`` (``ref`` is
    :func:`batched_table_lookup`).
    """
    from repro.core import dispatch
    return dispatch.get_op("embedding_bag")(
        big_table, table_offsets, indices, backend=backend)
