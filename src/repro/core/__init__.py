"""The paper's contribution as first-class framework features.

* :mod:`repro.core.dispatch` — unified operator-backend registry: ONE
  resolver (explicit arg > scope > env > config > capability-ranked auto)
  for every op family, from kernels to the serving engine
* :mod:`repro.core.paged_kv` — paged KV-cache pool + block allocator
* :mod:`repro.core.attention_api` — PagedAttention: padded ``BlockTable``
  baseline (vLLM_base) vs flat ``BlockList`` optimized path (vLLM_opt)
* :mod:`repro.core.embedding_api` — embedding lookups: ``SingleTable``
  baseline vs fused ``BatchedTable`` (FBGEMM-style)
"""
from repro.core import attention_api, dispatch, embedding_api, paged_kv  # noqa: F401
