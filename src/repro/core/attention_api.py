"""PagedAttention: baseline padded BlockTable vs optimized flat BlockList.

Reproduces the paper's §4.2 vLLM case study as a TPU-native op pair:

* :func:`paged_attention_base` — vLLM_base analogue. Gathers **every** entry
  of the padded (B, max_blocks) BlockTable, including zero-pad blocks, then
  masks. The redundant gathers are real HLO bytes (visible in cost analysis),
  exactly the waste the paper measures (Fig 17b).
* :func:`paged_attention_opt` — vLLM_opt analogue. A flat BlockList of only
  effectual blocks drives a *batched GEMM* over (total_blocks, block_size)
  tiles with a segment-softmax across each request's blocks. This is the
  MXU-friendly restructuring the paper performs at the PyTorch level; here it
  is also the exact math of the Pallas kernel in
  ``repro.kernels.paged_attention`` (scalar-prefetched index_map).
* :func:`paged_attention_sharded` — beyond-paper: flash-decoding combine of
  the opt path across a mesh axis (sequence-sharded KV pool), used by the
  multi-pod ``serve_step``.
* :func:`paged_attention_chunked` — chunked-prefill generalization: a flat
  batch of query *tokens* (decode tokens and prompt-chunk tokens mixed) each
  attends causally to its request's pool blocks. With one token per request
  it reduces to the opt path; with a chunk it is prefill-in-the-decode-step,
  which is what lets the serving engine run ONE fused program per step.
* :func:`paged_attention_chunked_sharded` — the two combined: the chunked
  math over a sequence-sharded KV pool inside ``shard_map``. Each rank holds
  a shard of the pool plus ITS OWN local BlockList slice
  (``BlockAllocator.build_sharded_block_lists``), computes flash-style
  partials (running max / sumexp / weighted-V) for every query lane against
  only local blocks, and the partials are log-sum-exp-combined across the
  mesh axis with (T, H)-sized collectives — the KV never moves.  This is
  the sharded serving engine's per-layer attention (docs/sharded_serving.md)
  and the ``sharded`` backend of the ``paged_attention_chunked`` op family.

All math: q (B, H, HD) single decode token (or (T, H, HD) flat token lanes
for the chunked op); pool (NB, BS, KV, HD). GQA handled by grouping H into
KV groups. f32 softmax accumulation.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import dispatch

NEG_INF = -1e30


def _q_grouped(q, num_kv: int):
    B, H, HD = q.shape
    return q.reshape(B, num_kv, H // num_kv, HD)


def paged_attention_base(q, pool_k, pool_v, block_table, seq_lens,
                         *, sm_scale: Optional[float] = None):
    """Baseline: padded BlockTable (B, MAXB). Gathers pad blocks too."""
    B, H, HD = q.shape
    NB, BS, KV, _ = pool_k.shape
    MAXB = block_table.shape[1]
    scale = sm_scale if sm_scale is not None else HD ** -0.5

    # Redundant gather: (B, MAXB, BS, KV, HD) — pads included, as in vLLM_base.
    k = jnp.take(pool_k, block_table.reshape(-1), axis=0).reshape(
        B, MAXB, BS, KV, HD)
    v = jnp.take(pool_v, block_table.reshape(-1), axis=0).reshape(
        B, MAXB, BS, KV, HD)
    qg = _q_grouped(q, KV)
    scores = jnp.einsum("bkgd,bmskd->bkgms", qg, k).astype(jnp.float32) * scale
    pos = (jnp.arange(MAXB)[:, None] * BS + jnp.arange(BS)[None, :])  # (MAXB,BS)
    mask = pos[None] < seq_lens[:, None, None]
    scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores.reshape(B, KV, qg.shape[2], -1), axis=-1)
    w = w.reshape(scores.shape).astype(v.dtype)
    out = jnp.einsum("bkgms,bmskd->bkgd", w, v)
    return out.reshape(B, H, HD)


def _opt_partials(q, pool_k, pool_v, block_list, block_req, block_pos,
                  seq_lens, num_reqs: int, scale: float):
    """Per-request (max, sumexp, weighted-V) from a flat BlockList segment."""
    B, H, HD = q.shape
    NB, BS, KV, _ = pool_k.shape
    T = block_list.shape[0]
    G = H // KV

    k = jnp.take(pool_k, block_list, axis=0)              # (T, BS, KV, HD)
    v = jnp.take(pool_v, block_list, axis=0)
    req = jnp.clip(block_req, 0, B - 1)
    qg = _q_grouped(q, KV)[req]                           # (T, KV, G, HD)
    scores = jnp.einsum("tkgd,tskd->tkgs", qg, k).astype(jnp.float32) * scale
    pos = block_pos[:, None] * BS + jnp.arange(BS)[None]  # (T, BS)
    valid = (pos < seq_lens[jnp.clip(block_req, 0, B - 1)][:, None]) & (
        block_req[:, None] < num_reqs)
    scores = jnp.where(valid[:, None, None], scores, NEG_INF)

    seg = jnp.where(block_req < num_reqs, block_req, B)   # pad -> dropped
    m_t = scores.max(axis=-1)                             # (T, KV, G)
    m = jax.ops.segment_max(m_t, seg, num_segments=B + 1)[:B]
    m = jnp.maximum(m, -1e30)
    p = jnp.exp(scores - m[jnp.clip(seg, 0, B - 1)][:, :, :, None])
    p = jnp.where(valid[:, None, None], p, 0.0)
    l_t = p.sum(axis=-1)                                  # (T, KV, G)
    l = jax.ops.segment_sum(l_t, seg, num_segments=B + 1)[:B]
    o_t = jnp.einsum("tkgs,tskd->tkgd", p.astype(v.dtype), v).astype(jnp.float32)
    o = jax.ops.segment_sum(o_t, seg, num_segments=B + 1)[:B]
    return m, l, o                                        # (B,KV,G),(B,KV,G),(B,KV,G,HD)


def paged_attention_opt(q, pool_k, pool_v, block_list, block_req, block_pos,
                        seq_lens, *, sm_scale: Optional[float] = None):
    """Optimized: flat BlockList — only effectual blocks are touched."""
    B, H, HD = q.shape
    KV = pool_k.shape[2]
    scale = sm_scale if sm_scale is not None else HD ** -0.5
    m, l, o = _opt_partials(q, pool_k, pool_v, block_list, block_req,
                            block_pos, seq_lens, B, scale)
    out = o / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, H, HD).astype(q.dtype)


def paged_attention_sharded(q, pool_k, pool_v, block_list, block_req,
                            block_pos, seq_lens, *, axis: str,
                            sm_scale: Optional[float] = None):
    """Flash-decoding combine across mesh axis ``axis`` (inside shard_map).

    Each rank holds a shard of the pool and ITS OWN BlockList slice (built by
    ``BlockAllocator.build_sharded_block_lists``). Partials are combined with
    small (B,H)-sized collectives — the sequence dimension never moves.
    """
    B, H, HD = q.shape
    scale = sm_scale if sm_scale is not None else HD ** -0.5
    m_r, l_r, o_r = _opt_partials(q, pool_k, pool_v, block_list, block_req,
                                  block_pos, seq_lens, B, scale)
    m = jax.lax.pmax(m_r, axis)
    corr = jnp.exp(m_r - m)
    l = jax.lax.psum(l_r * corr, axis)
    o = jax.lax.psum(o_r * corr[..., None], axis)
    out = o / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, H, HD).astype(q.dtype)


def paged_attention_chunked(q, pool_k, pool_v, block_list, block_req,
                            block_pos, kv_lens, token_req, token_pos,
                            *, sm_scale: Optional[float] = None):
    """Chunked-prefill paged attention over flat token lanes.

    q         (T, H, HD)  queries — a mix of decode tokens (one per request)
                          and prompt-chunk tokens (several per request)
    block_*   (Tb,)       flat BlockList as in :func:`paged_attention_opt`,
                          with ``block_req`` holding request/slot ids
    kv_lens   (B,)        total valid KV per request AFTER this step's tokens
                          were appended to the pool
    token_req (T,)        owning request/slot of each query lane (>= B ⇒ pad)
    token_pos (T,)        absolute sequence position of each query token

    Each query attends to keys of its own request with ``key_pos <=
    token_pos`` (causal within the chunk — the chunk's own KV is already in
    the pool). Padding lanes produce zeros. With T == B and one token per
    request this computes exactly :func:`paged_attention_opt`.
    """
    T, H, HD = q.shape
    scale = sm_scale if sm_scale is not None else HD ** -0.5
    m, l, o = _chunked_partials(q, pool_k, pool_v, block_list, block_req,
                                block_pos, kv_lens, token_req, token_pos,
                                scale)
    out = o / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(T, H, HD).astype(q.dtype)


def _chunked_partials(q, pool_k, pool_v, block_list, block_req, block_pos,
                      kv_lens, token_req, token_pos, scale: float):
    """Per-lane flash partials of the chunked math over a BlockList slice.

    Returns ``(m, l, o)`` with shapes (T, KV, G), (T, KV, G), (T, KV, G, HD):
    the running max, sum of exponentials and weighted-V accumulator of every
    query lane against ONLY the blocks in ``block_list``.  With the full
    BlockList this normalizes to :func:`paged_attention_chunked`; with a
    per-shard slice the partials are what the sharded combine reduces.  A
    lane that owns no block here has ``m == -1e30`` and ``l == 0`` — the
    combine's exp-correction weighs it out exactly.
    """
    T, H, HD = q.shape
    NB, BS, KV, _ = pool_k.shape
    B = kv_lens.shape[0]
    G = H // KV

    k = jnp.take(pool_k, block_list, axis=0)              # (Tb, BS, KV, HD)
    v = jnp.take(pool_v, block_list, axis=0)
    qg = q.reshape(T, KV, G, HD)
    scores = jnp.einsum("tkgd,uskd->tkgus", qg, k).astype(jnp.float32) * scale
    key_pos = block_pos[:, None] * BS + jnp.arange(BS)[None]    # (Tb, BS)
    breq = jnp.clip(block_req, 0, B - 1)
    valid = ((block_req[None, :] == token_req[:, None])         # (T, Tb)
             & (block_req[None, :] < B)
             & (token_req[:, None] < B))
    valid = (valid[:, :, None]
             & (key_pos[None] <= token_pos[:, None, None])      # causal
             & (key_pos[None] < kv_lens[breq][None, :, None]))  # (T, Tb, BS)
    scores = jnp.where(valid[:, None, None], scores, NEG_INF)
    m = jnp.max(scores, axis=(-2, -1))                    # (T, KV, G)
    m = jnp.maximum(m, -1e30)
    p = jnp.exp(scores - m[:, :, :, None, None])
    p = jnp.where(valid[:, None, None], p, 0.0)
    l = p.sum(axis=(-2, -1))                              # (T, KV, G)
    o = jnp.einsum("tkgus,uskd->tkgd", p.astype(v.dtype), v).astype(jnp.float32)
    return m, l, o


def paged_attention_chunked_sharded(q, pool_k, pool_v, block_list, block_req,
                                    block_pos, kv_lens, token_req, token_pos,
                                    *, axis: str,
                                    sm_scale: Optional[float] = None):
    """Chunked paged attention over a sequence-sharded pool (inside shard_map).

    The chunked generalization of :func:`paged_attention_sharded`: every
    query *lane* (decode tokens, prompt-chunk tokens, speculative draft
    lanes — anything :func:`paged_attention_chunked` accepts) computes
    flash partials against its rank's pool shard and LOCAL BlockList slice
    (built by ``BlockAllocator.build_sharded_block_lists``), then the
    per-rank (max, sumexp, weighted-V) triples are log-sum-exp-combined
    across mesh axis ``axis`` with (T, H)-sized collectives.  The sequence
    dimension never moves; lanes whose blocks all live on other ranks are
    weighed out by the exp correction.  Padding lanes produce zeros, like
    the single-device op.
    """
    T, H, HD = q.shape
    scale = sm_scale if sm_scale is not None else HD ** -0.5
    m_r, l_r, o_r = _chunked_partials(q, pool_k, pool_v, block_list,
                                      block_req, block_pos, kv_lens,
                                      token_req, token_pos, scale)
    m = jax.lax.pmax(m_r, axis)
    corr = jnp.exp(m_r - m)
    l = jax.lax.psum(l_r * corr, axis)
    o = jax.lax.psum(o_r * corr[..., None], axis)
    out = o / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(T, H, HD).astype(q.dtype)


def ragged_lane_metadata(cu_q_lens, cu_kv_lens, seq_slot, num_lanes: int,
                         num_slots: int):
    """Derive per-lane ``(token_req, token_pos, kv_lens)`` from ragged
    cu_q_lens/cu_kv_lens metadata (docs/ragged_kernel.md).

    The ragged contract indexes SEQUENCES in lane order: sequence ``j`` owns
    query lanes ``[cu_q_lens[j], cu_q_lens[j+1])``, holds ``cu_kv_lens[j+1] -
    cu_kv_lens[j]`` valid KV positions after this step's append, and lives in
    engine slot ``seq_slot[j]`` (an out-of-range slot marks an empty padding
    entry).  A sequence's query lanes are always its LAST ``nq`` positions —
    true for decode lanes, prefill chunks and speculative draft lanes alike,
    because the engine reserves this step's KV before rendering.

    Returns arrays bit-identical to the engine's rendered lane metadata:
    ``token_req``/``token_pos`` (num_lanes,) and slot-keyed ``kv_lens``
    (num_slots,) — lanes past ``cu_q_lens[-1]`` become padding lanes
    (owner == num_slots, every key masked).
    """
    nseq = seq_slot.shape[0]
    lanes = jnp.arange(num_lanes, dtype=jnp.int32)
    # rightmost j with cu_q_lens[j] <= lane: side="right" skips empty entries
    j = jnp.searchsorted(cu_q_lens.astype(jnp.int32), lanes,
                         side="right").astype(jnp.int32) - 1
    j = jnp.clip(j, 0, nseq - 1)
    nq = cu_q_lens[1:] - cu_q_lens[:-1]                  # (nseq,)
    kvl = cu_kv_lens[1:] - cu_kv_lens[:-1]               # (nseq,)
    in_range = lanes < cu_q_lens[-1]
    token_req = jnp.where(in_range, seq_slot[j], num_slots).astype(jnp.int32)
    token_pos = jnp.where(
        in_range, kvl[j] - nq[j] + (lanes - cu_q_lens[j]), 0).astype(jnp.int32)
    kv_lens = jnp.zeros((num_slots,), jnp.int32).at[seq_slot].set(
        kvl.astype(jnp.int32), mode="drop")              # pads dropped
    return token_req, token_pos, kv_lens


def paged_attention_ragged(q, kv_pool, block_list, block_req, block_pos,
                           cu_q_lens, cu_kv_lens, seq_slot,
                           *, sm_scale: Optional[float] = None):
    """One ragged launch for mixed prefill-chunk + decode lanes over the
    FUSED head-interleaved KV pool (the ``ref`` oracle of the
    ``paged_attention_ragged`` family).

    q          (T, H, HD)   flat token lanes, sequences contiguous in lane
                            order (decode lanes and prompt-chunk lanes mixed)
    kv_pool    (NB, BS, 2*KV, HD)  fused ``[K0,V0,K1,V1,...]`` pool layer
                            (:func:`repro.core.paged_kv.make_fused_pool`)
    block_*    (Tb,)        flat BlockList keyed by slot id, as in
                            :func:`paged_attention_chunked`
    cu_q_lens  (S+1,)       prefix sums of per-sequence query-lane counts
    cu_kv_lens (S+1,)       prefix sums of per-sequence valid-KV counts
                            (AFTER this step's tokens were appended)
    seq_slot   (S,)         sequence -> engine slot id (>= S ⇒ empty entry)

    The lane metadata is DERIVED from the ragged prefix sums
    (:func:`ragged_lane_metadata`) and the attention math is exactly
    :func:`_chunked_partials` over split views of the fused pool — integer
    derivation cannot perturb float ops, so results are bit-identical to the
    chunked path on the same workload.
    """
    from repro.core import paged_kv

    T, H, HD = q.shape
    S = seq_slot.shape[0]
    scale = sm_scale if sm_scale is not None else HD ** -0.5
    pool_k, pool_v = paged_kv.fused_kv_views(kv_pool)
    token_req, token_pos, kv_lens = ragged_lane_metadata(
        cu_q_lens, cu_kv_lens, seq_slot, T, S)
    m, l, o = _chunked_partials(q, pool_k, pool_v, block_list, block_req,
                                block_pos, kv_lens, token_req, token_pos,
                                scale)
    out = o / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(T, H, HD).astype(q.dtype)


def paged_attention_ragged_sharded(q, kv_pool, block_list, block_req,
                                   block_pos, cu_q_lens, cu_kv_lens, seq_slot,
                                   *, axis: str,
                                   sm_scale: Optional[float] = None):
    """Ragged attention over a sequence-sharded FUSED pool (inside shard_map).

    The ragged metadata is replicated (every rank derives the same lane
    arrays); each rank computes chunked flash partials against its pool
    shard's LOCAL BlockList slice and the triples are log-sum-exp-combined
    across ``axis`` — exactly :func:`paged_attention_chunked_sharded` on
    split views of the fused shard, so the sharded ragged engine stays
    bit-identical to the sharded chunked engine.
    """
    from repro.core import paged_kv

    T = q.shape[0]
    S = seq_slot.shape[0]
    pool_k, pool_v = paged_kv.fused_kv_views(kv_pool)
    token_req, token_pos, kv_lens = ragged_lane_metadata(
        cu_q_lens, cu_kv_lens, seq_slot, T, S)
    return paged_attention_chunked_sharded(
        q, pool_k, pool_v, block_list, block_req, block_pos, kv_lens,
        token_req, token_pos, axis=axis, sm_scale=sm_scale)


def paged_attention(q, pool_k, pool_v, block_list, block_req, block_pos,
                    seq_lens, backend=None):
    """Decode-shape PagedAttention through the unified registry.

    ONE resolver call (:mod:`repro.core.dispatch`): explicit ``backend`` is
    strict and round-trips to the named implementation; ``None`` follows
    scope/env/config/auto precedence.  Implementations are registered in
    ``repro.kernels.paged_attention.ops``.
    """
    return dispatch.get_op("paged_attention")(
        q, pool_k, pool_v, block_list, block_req, block_pos, seq_lens,
        backend=backend)


def paged_attention_chunked_op(q, pool_k, pool_v, block_list, block_req,
                               block_pos, kv_lens, token_req, token_pos,
                               *, backend=None, q_chunk: int = 16,
                               prefetch_depth: int = 0):
    """Chunked-prefill PagedAttention through the unified registry.

    Same contract as :func:`paged_attention_chunked` (which is the ``ref``
    implementation); ``pallas``/``pallas_interpret`` select the query-chunk
    grid kernel in ``repro.kernels.paged_attention.kernel``.
    ``prefetch_depth`` >= 2 additionally selects the multi-buffered KV-page
    DMA ring in the Pallas kernel (jnp backends ignore it); both knobs are
    declared as family tunables in the registry.
    """
    return dispatch.get_op("paged_attention_chunked")(
        q, pool_k, pool_v, block_list, block_req, block_pos, kv_lens,
        token_req, token_pos, q_chunk=q_chunk, prefetch_depth=prefetch_depth,
        backend=backend)


def paged_attention_ragged_op(q, kv_pool, block_list, block_req, block_pos,
                              cu_q_lens, cu_kv_lens, seq_slot, *,
                              backend=None, num_queries_per_block: int = 16,
                              num_kv_pages_per_block: int = 1,
                              vmem_limit_bytes: int = 0):
    """Ragged fused-pool PagedAttention through the unified registry.

    Same contract as :func:`paged_attention_ragged` (the ``ref``
    implementation); ``pallas``/``pallas_interpret`` select the ragged grid
    kernel in ``repro.kernels.paged_attention.kernel``.  The three kwargs are
    the family's registered tunables (docs/ragged_kernel.md):
    ``num_queries_per_block`` is the query-tile row count,
    ``num_kv_pages_per_block`` how many KV pages one grid step consumes from
    the double-buffered fused-page DMA ring, and ``vmem_limit_bytes`` caps
    the ring's VMEM footprint (0 = uncapped).  jnp backends ignore all
    three; measured best configs per (page_size, head_dim, backend) live in
    the committed autotune table (``repro.perf.autotune``).
    """
    return dispatch.get_op("paged_attention_ragged")(
        q, kv_pool, block_list, block_req, block_pos, cu_q_lens, cu_kv_lens,
        seq_slot, num_queries_per_block=num_queries_per_block,
        num_kv_pages_per_block=num_kv_pages_per_block,
        vmem_limit_bytes=vmem_limit_bytes, backend=backend)
