"""Unified operator-backend registry: ONE dispatch API from kernels to serving.

The paper's central observation is that performance portability lives in the
software layer that maps operators onto hardware backends.  Before this module
that mapping was re-implemented per file: every ``kernels/*/ops.py`` had its
own ``backend="auto"|"ref"|"interpret"`` string ladder, the ``core/*_api.py``
wrappers layered a second (inconsistent) ladder on top, and the serving engine
hardcoded one implementation.  This registry is the single place where

  * implementations of an op family are **registered** under a backend name,
  * each implementation carries a **capability predicate** (platform, dtype,
    shape constraints) and a rank used by auto selection,
  * a **resolver** picks the implementation with a well-defined precedence.

Backend names
-------------
``ref``               pure-jnp oracle (any platform, always available)
``xla``               jnp form tuned for XLA (e.g. segment-softmax BlockList)
``pallas``            compiled Pallas kernel (TPU only)
``pallas_interpret``  the same kernel in interpret mode (any platform; slow —
                      never chosen by auto, used for validation)
``sharded``           shard_map scale-out form (per-shard partials + mesh
                      collectives); gated on mesh presence (a ``mesh=`` hint
                      in the CallSpec kwargs, or >1 local device) and never
                      auto-preferred over the single-device forms — a mesh
                      is something a caller opts into, not a faster kernel

Resolution precedence (highest wins)
------------------------------------
1. explicit ``backend=`` argument at the call site — **strict**: if the named
   implementation is missing or its capability predicate rejects the call,
   :class:`BackendUnavailableError` is raised (no silent re-deciding);
2. ``with force_backend("..."):`` scope;
3. the ``REPRO_BACKEND`` environment variable;
4. a config hint (e.g. ``ServeConfig.backend``) passed by the caller;
5. capability-ranked auto: the supported implementation with the highest rank.

Levels 2–4 are *preferences*: if the preferred backend is unavailable for this
call the resolver falls back to auto ranking (so ``REPRO_BACKEND=pallas`` on a
CPU host degrades to the best supported implementation instead of crashing).
Every resolution is appended to the active :func:`record_resolutions` scope so
benchmarks can attribute numbers to the implementation that actually ran.

``jax.jit`` plumbing lives here too: implementations are registered already
jitted (with their own static argnames); the resolver runs host-side — either
outside jit or at trace time — so the backend name never becomes a traced
value.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import threading
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence, Tuple

import jax

__all__ = [
    "REF", "XLA", "PALLAS", "PALLAS_INTERPRET", "SHARDED", "BACKENDS",
    "ENV_VAR", "BackendUnavailableError", "CallSpec", "Impl", "OpFamily",
    "op", "get_op", "list_ops", "resolve", "force_backend", "forced_backend",
    "record_resolutions", "on_tpu", "mesh_present",
]

REF = "ref"
XLA = "xla"
PALLAS = "pallas"
PALLAS_INTERPRET = "pallas_interpret"
SHARDED = "sharded"
BACKENDS = (REF, XLA, PALLAS, PALLAS_INTERPRET, SHARDED)

ENV_VAR = "REPRO_BACKEND"

# Auto selection picks the highest-ranked *supported* implementation.
# pallas_interpret ranks below everything: it is a validation tool, orders of
# magnitude slower than the jnp forms — only an explicit request selects it.
# sharded sits below ref: scale-out is opted into (a mesh-holding caller
# resolves it explicitly), auto keeps picking the single-device forms even on
# multi-device hosts.
_DEFAULT_RANK = {PALLAS: 30, XLA: 20, REF: 10, SHARDED: 5,
                 PALLAS_INTERPRET: 0}

_AUTO_NAMES = (None, "auto", "")


class BackendUnavailableError(ValueError):
    """An explicitly requested backend is missing or rejects the call."""


@dataclasses.dataclass(frozen=True)
class CallSpec:
    """What the resolver knows about one call site.

    ``args``/``kwargs`` are the actual call operands (possibly tracers, or
    empty when resolving ahead of any call, as the serving engine does at
    init); capability predicates must treat missing operands as "supported"
    and only reject on positive evidence.  :func:`mesh_present` is the one
    deliberate exception: a ``sharded`` impl is uncallable without a device
    fabric, and "one local device and no mesh hint" IS positive evidence of
    its absence — callers resolving ``sharded`` ahead of a call must carry
    their mesh in ``kwargs`` (the sharded serving engine does).
    """

    platform: str                                  # "cpu" | "tpu" | "gpu"
    args: Tuple[Any, ...] = ()
    kwargs: Dict[str, Any] = dataclasses.field(default_factory=dict)


def on_tpu(spec: CallSpec) -> bool:
    """Capability predicate for compiled Pallas kernels."""
    return spec.platform == "tpu"


def mesh_present(spec: CallSpec) -> bool:
    """Capability predicate for ``sharded`` (shard_map) implementations.

    Positive evidence of a mesh: the caller resolved with a ``mesh=`` kwarg
    in its :class:`CallSpec` (the serving engine does, at init), or the host
    exposes more than one local device (``XLA_FLAGS=
    --xla_force_host_platform_device_count`` sweeps, real multi-chip hosts).
    A bare single-device call rejects, so the parity suite skips the
    collective path where no collective can run.
    """
    if spec.kwargs.get("mesh") is not None:
        return True
    return len(jax.devices()) > 1


def _always(spec: CallSpec) -> bool:
    return True


@dataclasses.dataclass(frozen=True)
class Impl:
    """One registered implementation of an op family."""

    op: str
    backend: str
    fn: Callable
    supports: Callable[[CallSpec], bool]
    rank: int

    def __call__(self, *args: Any, **kwargs: Any) -> Any:
        return self.fn(*args, **kwargs)


# --------------------------------------------------------------------------
# Scoped override + resolution log (thread-local so jit tracing in worker
# threads can't leak scopes across tests).
# --------------------------------------------------------------------------
_STATE = threading.local()


def _scope_stack() -> List[str]:
    if not hasattr(_STATE, "forced"):
        _STATE.forced = []
    return _STATE.forced


def _log_stack() -> List[List[Tuple[str, str]]]:
    if not hasattr(_STATE, "logs"):
        _STATE.logs = []
    return _STATE.logs


@contextlib.contextmanager
def force_backend(name: Optional[str]) -> Iterator[None]:
    """Scoped backend preference (``None``/"auto" is a no-op scope)."""
    stack = _scope_stack()
    stack.append(name if name is not None else "auto")
    try:
        yield
    finally:
        stack.pop()


def forced_backend() -> Optional[str]:
    """The innermost non-auto ``force_backend`` scope, if any."""
    for name in reversed(_scope_stack()):
        if name not in _AUTO_NAMES:
            return name
    return None


@contextlib.contextmanager
def record_resolutions() -> Iterator[List[Tuple[str, str]]]:
    """Collect ``(op, backend)`` pairs resolved inside the scope."""
    log: List[Tuple[str, str]] = []
    _log_stack().append(log)
    try:
        yield log
    finally:
        # Remove by IDENTITY — list.remove() compares by equality and two
        # empty logs are ==, so nested scopes would drop the wrong one.
        stack = _log_stack()
        for i in range(len(stack) - 1, -1, -1):
            if stack[i] is log:
                del stack[i]
                break


def _note(op_name: str, backend: str) -> None:
    for log in _log_stack():
        log.append((op_name, backend))


# --------------------------------------------------------------------------
# Op families
# --------------------------------------------------------------------------
class OpFamily:
    """A named operator with one or more backend implementations.

    Calling the family resolves and invokes in one step::

        out = flash_op(q, k, v, causal=True, backend=None)

    All implementations of a family share one call signature; per-backend
    extras (tile sizes, interpret flags) are baked in at registration.

    ``tunables`` declares the family's cross-backend performance knobs as
    ``{name: default}`` — keyword-only ints every implementation accepts
    (backends that have no use for one simply ``del`` it).  Declaring them
    here (instead of in each ops.py) gives benchmarks and metrics one place
    to enumerate what can be swept and what the defaults are; the values
    themselves still travel as ordinary static kwargs.
    """

    def __init__(self, name: str, *, doc: str = "",
                 example: Optional[Callable[[], Tuple[tuple, dict]]] = None,
                 tunables: Optional[Dict[str, Any]] = None):
        self.name = name
        self.doc = doc
        # Example-input factory: ``() -> (args, kwargs)`` with shapes small
        # enough for interpret mode.  Powers the registry-enumerated parity
        # suite — no hand-maintained op list in tests.
        self.example = example
        self.tunables: Dict[str, Any] = dict(tunables or {})
        self._impls: Dict[str, Impl] = {}

    # ------------------------------------------------------------- registry
    def register(self, backend: str, *, rank: Optional[int] = None,
                 supports: Optional[Callable[[CallSpec], bool]] = None,
                 ) -> Callable[[Callable], Callable]:
        """Decorator: register ``fn`` as this op's ``backend`` implementation.

        ``supports`` defaults to platform=="tpu" for ``pallas``, mesh
        presence for ``sharded`` and to always-true otherwise; compose extra
        shape/dtype constraints by passing a predicate (it replaces, not
        augments, the default — include :func:`on_tpu` /
        :func:`mesh_present` yourself for those backends).
        """
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; one of {BACKENDS}")
        if backend in self._impls:
            raise ValueError(f"{self.name}: backend {backend!r} registered twice")

        def deco(fn: Callable) -> Callable:
            pred = supports
            if pred is None:
                pred = {PALLAS: on_tpu, SHARDED: mesh_present}.get(
                    backend, _always)
            self._impls[backend] = Impl(
                op=self.name, backend=backend, fn=fn, supports=pred,
                rank=_DEFAULT_RANK[backend] if rank is None else rank)
            return fn

        return deco

    def impls(self) -> List[Impl]:
        """All implementations, highest rank first."""
        return sorted(self._impls.values(), key=lambda i: -i.rank)

    def backends(self) -> List[str]:
        return [i.backend for i in self.impls()]

    def get(self, backend: str) -> Optional[Impl]:
        return self._impls.get(backend)

    # -------------------------------------------------------------- resolve
    def resolve(self, backend: Optional[str] = None, *,
                config: Optional[str] = None,
                spec: Optional[CallSpec] = None) -> Impl:
        """Pick the implementation for one call (see module precedence)."""
        if not self._impls:
            raise BackendUnavailableError(f"op {self.name!r} has no backends")
        if spec is None:
            spec = CallSpec(platform=jax.default_backend())

        if backend not in _AUTO_NAMES:                 # 1. explicit — strict
            impl = self._impls.get(backend)
            if impl is None:
                raise BackendUnavailableError(
                    f"{self.name}: backend {backend!r} not registered "
                    f"(have {self.backends()})")
            if not impl.supports(spec):
                raise BackendUnavailableError(
                    f"{self.name}: backend {backend!r} does not support this "
                    f"call on platform {spec.platform!r}")
            # The resolved name must round-trip an explicit request — this is
            # the single-resolver guarantee that killed the old double
            # dispatch (pallas request silently re-deciding to ref).
            assert impl.backend == backend, (impl.backend, backend)
            self._note(impl)
            return impl

        for pref in (forced_backend(),                 # 2. scope
                     os.environ.get(ENV_VAR),          # 3. env
                     config):                          # 4. config hint
            if pref in _AUTO_NAMES:
                continue
            impl = self._impls.get(pref)
            if impl is not None and impl.supports(spec):
                self._note(impl)
                return impl
            # Preference unavailable for this call: fall through to auto.

        for impl in self.impls():                      # 5. ranked auto
            if impl.supports(spec):
                self._note(impl)
                return impl
        raise BackendUnavailableError(
            f"{self.name}: no registered backend supports this call on "
            f"platform {spec.platform!r}")

    def _note(self, impl: Impl) -> None:
        _note(self.name, impl.backend)

    # ----------------------------------------------------------------- call
    def __call__(self, *args: Any, backend: Optional[str] = None,
                 config_backend: Optional[str] = None, **kwargs: Any) -> Any:
        spec = CallSpec(platform=jax.default_backend(), args=args,
                        kwargs=kwargs)
        impl = self.resolve(backend, config=config_backend, spec=spec)
        return impl.fn(*args, **kwargs)


_REGISTRY: Dict[str, OpFamily] = {}


def op(name: str, *, doc: str = "",
       example: Optional[Callable[[], Tuple[tuple, dict]]] = None,
       tunables: Optional[Dict[str, Any]] = None) -> OpFamily:
    """Create (or fetch) the :class:`OpFamily` called ``name``."""
    fam = _REGISTRY.get(name)
    if fam is None:
        fam = _REGISTRY[name] = OpFamily(name, doc=doc, example=example,
                                         tunables=tunables)
    else:
        if doc:
            fam.doc = doc
        if example is not None:
            fam.example = example
        if tunables is not None:
            fam.tunables = dict(tunables)
    return fam


def get_op(name: str) -> OpFamily:
    _ensure_registered()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown op {name!r}; known: {sorted(_REGISTRY)}") from None


def list_ops() -> Sequence[OpFamily]:
    """All op families (importing the registering modules first)."""
    _ensure_registered()
    return [fam for _, fam in sorted(_REGISTRY.items())]


def resolve(name: str, backend: Optional[str] = None, *,
            config: Optional[str] = None,
            spec: Optional[CallSpec] = None) -> Impl:
    """Module-level convenience: ``get_op(name).resolve(...)``."""
    return get_op(name).resolve(backend, config=config, spec=spec)


def _ensure_registered() -> None:
    """Import every module that registers implementations (idempotent)."""
    import repro.core.attention_api       # noqa: F401
    import repro.core.embedding_api       # noqa: F401
    import repro.kernels.batched_embedding.ops  # noqa: F401
    import repro.kernels.flash_attention.ops    # noqa: F401
    import repro.kernels.gather_scatter.ops     # noqa: F401
    import repro.kernels.paged_attention.ops    # noqa: F401
    import repro.kernels.stream.ops             # noqa: F401
