"""Paged KV-cache pool and block allocator.

Host side (`BlockAllocator`): a free-list allocator over a fixed pool of
KV blocks, exactly vLLM's memory manager. Produces, per scheduling step,
either
  * a padded 2D **BlockTable** (B, max_blocks)  — the baseline layout whose
    zero-padding induces redundant gathers (paper Fig 16a), or
  * a flat 1D **BlockList** of only *effectual* blocks plus per-block request
    ids / positions — the paper's optimized layout (Fig 16b).

Device side: the pool is a dense array (num_blocks, block_size, KV, HD) per
layer (stacked over layers for scan). ``append_to_pool`` writes one new token
per active request into its current block/offset.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np


class OutOfBlocksError(RuntimeError):
    pass


@dataclass
class BlockAllocator:
    """Free-list allocator over ``num_blocks`` KV blocks of ``block_size`` tokens."""

    num_blocks: int
    block_size: int
    num_shards: int = 1          # model-axis shards for round-robin placement
    _free: List[int] = field(default_factory=list)
    _tables: Dict[int, List[int]] = field(default_factory=dict)
    _lens: Dict[int, int] = field(default_factory=dict)

    def __post_init__(self):
        self._free = list(range(self.num_blocks - 1, -1, -1))

    # -- lifecycle ----------------------------------------------------------
    def allocate(self, req_id: int, num_tokens: int) -> List[int]:
        assert req_id not in self._tables, req_id
        n = max(1, -(-num_tokens // self.block_size))
        if len(self._free) < n:
            raise OutOfBlocksError(f"need {n} blocks, have {len(self._free)}")
        blocks = [self._free.pop() for _ in range(n)]
        self._tables[req_id] = blocks
        self._lens[req_id] = num_tokens
        return blocks

    def reserve_slot(self, req_id: int) -> Tuple[int, int]:
        """Ensure a block exists for the NEXT token; return (block, offset).

        Does not advance the sequence — call :meth:`commit_token` after the
        decode step has written the KV entry.
        """
        pos = self._lens[req_id]
        need = pos // self.block_size + 1
        while len(self._tables[req_id]) < need:
            if not self._free:
                raise OutOfBlocksError("pool exhausted")
            self._tables[req_id].append(self._free.pop())
        blk = self._tables[req_id][pos // self.block_size]
        return blk, pos % self.block_size

    def commit_token(self, req_id: int) -> None:
        self._lens[req_id] += 1

    def append_token(self, req_id: int) -> Tuple[int, int]:
        """reserve + commit in one call (single-step convenience)."""
        slot = self.reserve_slot(req_id)
        self.commit_token(req_id)
        return slot

    def free(self, req_id: int) -> None:
        self._free.extend(reversed(self._tables.pop(req_id)))
        del self._lens[req_id]

    @property
    def num_free(self) -> int:
        return len(self._free)

    def seq_len(self, req_id: int) -> int:
        return self._lens[req_id]

    def table(self, req_id: int) -> List[int]:
        return list(self._tables[req_id])

    # -- device-layout builders ----------------------------------------------
    def build_block_table(self, req_ids: List[int], max_blocks: int,
                          pad_block: int = 0) -> Tuple[np.ndarray, np.ndarray]:
        """Baseline padded layout (vLLM_base): (B, max_blocks) + seq_lens (B,).

        Padding entries point at ``pad_block`` — they are *gathered anyway* by
        the baseline kernel, reproducing the paper's redundant-gather cost.
        """
        B = len(req_ids)
        tab = np.full((B, max_blocks), pad_block, np.int32)
        lens = np.zeros((B,), np.int32)
        for i, r in enumerate(req_ids):
            t = self._tables[r]
            assert len(t) <= max_blocks, (len(t), max_blocks)
            tab[i, :len(t)] = t
            lens[i] = self._lens[r]
        return tab, lens

    def build_block_list(self, req_ids: List[int], max_total: Optional[int] = None
                         ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """Optimized flat layout (vLLM_opt / this framework).

        Returns (block_list, block_req, block_pos, seq_lens):
          block_list (T,) pool indices of ONLY effectual blocks
          block_req  (T,) owning request index in [0,B)
          block_pos  (T,) block's ordinal position within its request
          seq_lens   (B,)
        Padded (if max_total given) with req = B (out-of-range ⇒ dropped by
        segment ops) so the array shape is static for jit.
        """
        lists, reqs, poss = [], [], []
        lens = np.zeros((len(req_ids),), np.int32)
        for i, r in enumerate(req_ids):
            t = self._tables[r]
            lists.extend(t)
            reqs.extend([i] * len(t))
            poss.extend(range(len(t)))
            lens[i] = self._lens[r]
        T = len(lists)
        if max_total is not None:
            assert T <= max_total, (T, max_total)
            pad = max_total - T
            lists.extend([0] * pad)
            reqs.extend([len(req_ids)] * pad)   # out-of-range segment id
            poss.extend([0] * pad)
        return (np.asarray(lists, np.int32), np.asarray(reqs, np.int32),
                np.asarray(poss, np.int32), lens)

    def build_sharded_block_lists(self, req_ids: List[int], max_per_shard: int
                                  ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """BlockList split round-robin across ``num_shards`` model ranks.

        Block k of request i goes to shard (k % num_shards); each shard's list
        is padded to ``max_per_shard``. Used by the shard_map flash-decoding
        paged attention (sequence sharded over the model axis).
        Returns (block_list (S, M), block_req (S, M), block_pos (S, M), seq_lens).
        """
        S = self.num_shards
        per: List[List[Tuple[int, int, int]]] = [[] for _ in range(S)]
        lens = np.zeros((len(req_ids),), np.int32)
        for i, r in enumerate(req_ids):
            for k, b in enumerate(self._tables[r]):
                per[k % S].append((b, i, k))
            lens[i] = self._lens[r]
        bl = np.zeros((S, max_per_shard), np.int32)
        br = np.full((S, max_per_shard), len(req_ids), np.int32)
        bp = np.zeros((S, max_per_shard), np.int32)
        for s in range(S):
            assert len(per[s]) <= max_per_shard, (len(per[s]), max_per_shard)
            for j, (b, i, k) in enumerate(per[s]):
                bl[s, j], br[s, j], bp[s, j] = b, i, k
        return bl, br, bp, lens

    def write_slots(self, req_ids: List[int]) -> np.ndarray:
        """(B, 2) [block, offset] where the NEXT token of each request lands.

        Reserves blocks on demand (call :meth:`commit_token` after the step).
        """
        out = np.zeros((len(req_ids), 2), np.int32)
        for i, r in enumerate(req_ids):
            out[i] = self.reserve_slot(r)
        return out


# ---------------------------------------------------------------------------
# Device-side pool ops (pure jnp; shapes are jit-static)
# ---------------------------------------------------------------------------
def make_pool(num_layers: int, num_blocks: int, block_size: int,
              num_kv: int, head_dim: int, dtype=jnp.bfloat16):
    shape = (num_layers, num_blocks, block_size, num_kv, head_dim)
    return jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)


def append_to_pool(pool_layer, kv_new, slots):
    """Write one token per request into a single layer's pool.

    pool_layer (NB, BS, KV, HD); kv_new (B, KV, HD); slots (B, 2) [block, off].
    Out-of-range slots (e.g. (NB, 0) on non-owning model ranks of a sharded
    pool) are dropped — this is how sharded writes stay shard-local.
    """
    return pool_layer.at[slots[:, 0], slots[:, 1]].set(
        kv_new.astype(pool_layer.dtype), mode="drop")


def gather_prefill_into_pool(pool_layer, k_seq, block_table, seq_len: int,
                             block_size: int):
    """Scatter a prefilled (B, S, KV, HD) K (or V) into pool blocks.

    block_table (B, nb) lists each request's blocks in order.
    """
    B, S = k_seq.shape[:2]
    nb = block_table.shape[1]
    assert nb * block_size >= S
    k_blocks = k_seq.reshape(B, S // block_size, block_size, *k_seq.shape[2:])
    flat_idx = block_table[:, :S // block_size].reshape(-1)
    return pool_layer.at[flat_idx].set(
        k_blocks.reshape((-1,) + k_blocks.shape[2:]).astype(pool_layer.dtype))
