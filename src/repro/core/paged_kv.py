"""Paged KV-cache pool and block allocator.

Host side (`BlockAllocator`): a refcounted free-list allocator over a fixed
pool of KV blocks — vLLM's memory manager, including its two serving-side
tricks:

  * **Prefix caching** — every *full* block of a sequence is content-hashed
    (chained over the prefix, so a block's key commits to everything before
    it): prompt blocks as prefill chunks commit, and blocks filled during
    DECODE under their true prompt+generation content (the engine's
    generated-token registration — preemption-resume recompute and repeated
    prompt+generation prefixes hit the cache). Freed blocks whose content is
    hashed are parked in a cached-free LRU instead of being scrubbed; a
    later prompt with the same prefix re-adopts them with a refcount bump
    and skips recomputing their KV.
  * **Copy-on-write** — a block shared by several requests (refcount > 1) is
    never written in place; :meth:`reserve_tokens` transparently allocates a
    private copy and records a (src, dst) pair for the engine to apply on the
    device pool via :func:`copy_pool_blocks`.

Which cached-free block is sacrificed when the pool needs a fresh one is NOT
decided here: it flows through a registered eviction policy
(``repro.serving.policy``, axis ``eviction``).  The allocator keeps per-block
:class:`BlockStats` (lifetime prefix-cache hits, peak refcount) so scorers
like ``hit-rate`` and ``refcount-aware`` have evidence to rank on; the
default resolves to the registered ``lru`` policy, byte-for-byte the old
oldest-freed-first behaviour.

Two extensions ride on that machinery (docs/disaggregated.md):

  * **KV-written watermark** — ``_written[block]`` counts how many leading
    token slots of a block hold committed KV.  It gates
    :meth:`extend_prefix` (same-wave prefix dedup: a borrower admitted
    while the donor is still prefilling fast-forwards over blocks the
    moment they are published, full and written) and backs
    :meth:`transferable`, the prefill→decode handoff's contract that a
    request's blocks can be copied out of this pool.
  * **Host-memory tier** — with a :class:`HostPool` attached, evicting a
    cached-free block *demotes* its content to host memory (policy-gated:
    the eviction policy's ``demote`` hook scores keep/drop on the same
    ``BlockStats``) instead of dropping it, and a prefix hit on a demoted
    key *promotes* it back into a fresh HBM block before admission.  The
    allocator only does bookkeeping; the actual device↔host copies are
    queued on :attr:`pending_tier_ops` for the engine to apply in order
    (demotes read old content before any reuse overwrites it).

Sequence state is mutated ONLY through the public API — ``allocate`` /
``allocate_prefix``, ``reserve_tokens`` + ``commit_tokens``, ``rewind`` /
``truncate``, ``free`` — so engines never poke ``_lens`` directly.  The
reserve/commit/truncate triple is also the speculative-decoding rollback
primitive: reserve K+1 write slots, commit only the accepted prefix, and
truncate to the committed length — refcounts and the free list are restored
exactly for a fully-rejected step (``tests/test_spec.py``).

Per scheduling step the allocator also renders the device layouts:
  * a padded 2D **BlockTable** (B, max_blocks)  — the baseline layout whose
    zero-padding induces redundant gathers (paper Fig 16a), or
  * a flat 1D **BlockList** of only *effectual* blocks plus per-block request
    ids / positions — the paper's optimized layout (Fig 16b), or
  * per-shard **local BlockLists** (``build_sharded_block_lists``) for the
    sequence-sharded chunked path: each mesh rank gets the slice of the
    BlockList its pool shard can serve, with LOCAL pool indices
    (docs/sharded_serving.md).

Device side: the pool is a dense array (num_blocks, block_size, KV, HD) per
layer (stacked over layers for scan). ``append_to_pool`` writes one new token
per active request into its current block/offset.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np


class OutOfBlocksError(RuntimeError):
    pass


@dataclass
class BlockStats:
    """Per-physical-block evidence for eviction scorers.

    ``hits``      lifetime prefix-cache adoptions of this block's content;
    ``peak_ref``  highest simultaneous refcount the block ever reached.
    Reset whenever the block is handed out for fresh content.
    """

    hits: int = 0
    peak_ref: int = 1


def _prefix_key(tokens: np.ndarray, n_tokens: int) -> bytes:
    """Content hash of ``tokens[:n_tokens]`` (chained prefix hash)."""
    buf = np.ascontiguousarray(tokens[:n_tokens], dtype=np.int32).tobytes()
    return hashlib.blake2b(buf, digest_size=16).digest()


@dataclass
class HostBlock:
    """One demoted KV block staged in host memory.

    ``data`` is filled lazily by the engine's tier drain (a device→host copy
    of the block's slice per pool channel — ONE fused ``kv`` slice with the
    head-interleaved layout, (k, v) slices with split pools); ``stats``
    carries the block's eviction evidence across the tier round-trip so a
    promoted block keeps its history.
    """

    key: bytes
    stats: BlockStats
    data: Optional[Tuple[np.ndarray, ...]] = None   # host copy per channel


class HostPool:
    """Host-memory KV tier: an LRU of demoted cached-free blocks.

    Capacity is counted in blocks.  ``put`` registers a demotion (oldest
    entry dropped on overflow), ``take`` consumes an entry for promotion.
    The pool never touches device memory — entries carry host ``np`` copies
    written by the engine's ordered tier drain.
    """

    def __init__(self, capacity: int):
        assert capacity > 0, capacity
        self.capacity = int(capacity)
        self._entries: "OrderedDict[bytes, HostBlock]" = OrderedDict()
        self.counters: Dict[str, int] = {
            "demotes": 0, "promotes": 0, "hits": 0, "drops": 0}

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: bytes) -> bool:
        return key in self._entries

    def put(self, key: bytes, stats: BlockStats) -> HostBlock:
        """Demote ``key``: stage a new entry (content copied in later by the
        engine's tier drain) and LRU-drop past capacity."""
        self._entries.pop(key, None)        # re-demotion replaces stale data
        entry = HostBlock(key=key, stats=stats)
        self._entries[key] = entry
        self.counters["demotes"] += 1
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.counters["drops"] += 1
        return entry

    def take(self, key: bytes) -> Optional[HostBlock]:
        """Consume an entry for promotion back into the HBM pool."""
        entry = self._entries.pop(key, None)
        if entry is not None:
            self.counters["promotes"] += 1
            self.counters["hits"] += 1
        return entry

    def untake(self, key: bytes, entry: HostBlock) -> None:
        """Roll back a ``take`` whose promotion could not get an HBM block."""
        self._entries[key] = entry
        self.counters["promotes"] -= 1
        self.counters["hits"] -= 1


@dataclass
class BlockAllocator:
    """Refcounted free-list allocator over ``num_blocks`` KV blocks."""

    num_blocks: int
    block_size: int
    # Sequence-sharding over a mesh axis: the device pool is split
    # CONTIGUOUSLY into ``num_shards`` equal slices, so physical block ``b``
    # lives on shard ``b // (num_blocks // num_shards)`` at local index
    # ``b % (num_blocks // num_shards)`` — exactly the slice shard_map hands
    # each rank when the pool array is sharded on its block dimension.  The
    # free list is interleaved across shards so allocation stays balanced.
    num_shards: int = 1
    # Cached-free eviction scorer: an ``EvictionPolicy`` from
    # ``repro.serving.policy`` (duck-typed here — core stays importable
    # without the serving layer; the registered default is resolved lazily on
    # first eviction).
    eviction_policy: Optional[Any] = None
    # Optional host-memory tier: evicted cached-free blocks are demoted into
    # it (policy-gated) instead of dropped, and promoted back on prefix hit.
    host_pool: Optional[HostPool] = None
    _free: List[int] = field(default_factory=list)
    _tables: Dict[int, List[int]] = field(default_factory=dict)
    _lens: Dict[int, int] = field(default_factory=dict)
    # block -> refcount, for every live (allocated or cached-free) block
    _ref: Dict[int, int] = field(default_factory=dict)
    # prefix cache: content hash <-> block (only FULL prompt blocks)
    _hash_of: Dict[int, bytes] = field(default_factory=dict)
    _block_of: Dict[bytes, int] = field(default_factory=dict)
    # refcount-0 blocks whose content is retained for prefix reuse, in
    # freed order (oldest first — the candidate order eviction scorers see)
    _cached_free: "OrderedDict[int, None]" = field(default_factory=OrderedDict)
    # block -> BlockStats, evidence for eviction scorers
    _stats: Dict[int, BlockStats] = field(default_factory=dict)
    # block -> KV-written watermark: #leading token slots holding committed
    # KV (the same-wave-dedup / handoff-transferability evidence)
    _written: Dict[int, int] = field(default_factory=dict)
    # (src, dst) copy-on-write pairs awaiting a device-pool copy
    pending_copies: List[Tuple[int, int]] = field(default_factory=list)
    # ordered host-tier traffic awaiting device copies: ("demote"|"promote",
    # HostBlock, block).  Order matters — a demote must read its block's
    # content before any same-step reuse overwrites it, and before a promote
    # consumes its data.
    pending_tier_ops: List[Tuple[str, HostBlock, int]] = field(
        default_factory=list)
    # counters (surfaced by ServingEngine.metrics)
    prefix_hits: int = 0
    prefix_misses: int = 0
    cow_copies: int = 0
    cache_evictions: int = 0
    blocks_allocated: int = 0    # total fresh-block grabs (prefix hits skip it)

    def __post_init__(self):
        if self.num_shards > 1:
            assert self.num_blocks % self.num_shards == 0, (
                self.num_blocks, self.num_shards)
            # Pop order cycles shards (0, per, 2*per, ..., 1, per+1, ...):
            # consecutive allocations land on different ranks, so per-shard
            # BlockList fills — and therefore per-rank attention work — stay
            # balanced instead of filling shard 0 first.
            per = self.blocks_per_shard
            order = [s * per + i for i in range(per)
                     for s in range(self.num_shards)]
            self._free = list(reversed(order))
        else:
            self._free = list(range(self.num_blocks - 1, -1, -1))

    @property
    def blocks_per_shard(self) -> int:
        return self.num_blocks // self.num_shards

    def shard_of(self, block: int) -> int:
        """Owning mesh rank of a physical block (contiguous pool slices)."""
        return block // self.blocks_per_shard

    # -- block bookkeeping --------------------------------------------------
    def _eviction(self) -> Any:
        """The eviction scorer, lazily resolved to the registered default.

        The import is deferred so ``repro.core`` never depends on the serving
        layer at module load (``repro.serving.policy`` imports this module).
        """
        if self.eviction_policy is None:
            from repro.serving.policy import EVICTION, resolve
            self.eviction_policy = resolve(EVICTION)
        return self.eviction_policy

    def _pop_block(self) -> int:
        """Take a block: plain free list first, then evict a cached-free
        block chosen by the registered eviction policy."""
        if self._free:
            blk = self._free.pop()
        elif self._cached_free:
            pol = self._eviction()
            blk = int(pol.select(tuple(self._cached_free), self._stats))
            if blk not in self._cached_free:
                raise RuntimeError(
                    f"eviction policy {getattr(pol, 'name', pol)!r} selected "
                    f"block {blk}, not a cached-free candidate")
            del self._cached_free[blk]
            key = self._hash_of.get(blk)        # capture before unregister
            self._unregister(blk)
            if self.host_pool is not None and key is not None:
                demote = getattr(pol, "demote", None)
                if demote is None or demote(blk, self._stats):
                    entry = self.host_pool.put(
                        key, self._stats.get(blk, BlockStats()))
                    self.pending_tier_ops.append(("demote", entry, blk))
            pol.on_evict(blk, self._stats)
            self.cache_evictions += 1
        else:
            raise OutOfBlocksError("pool exhausted")
        self.blocks_allocated += 1
        self._stats[blk] = BlockStats()          # fresh content, fresh record
        self._written[blk] = 0
        return blk

    def _unregister(self, blk: int) -> None:
        key = self._hash_of.pop(blk, None)
        if key is not None and self._block_of.get(key) == blk:
            del self._block_of[key]

    def _decref(self, blk: int) -> None:
        if blk not in self._ref:
            raise RuntimeError(f"double free of block {blk}")
        self._ref[blk] -= 1
        if self._ref[blk] == 0:
            del self._ref[blk]
            if blk in self._hash_of:      # keep content for prefix reuse
                self._cached_free[blk] = None
            else:
                self._free.append(blk)

    # -- lifecycle ----------------------------------------------------------
    def allocate(self, req_id: int, num_tokens: int) -> List[int]:
        assert req_id not in self._tables, req_id
        n = max(1, -(-num_tokens // self.block_size))
        if self.num_free < n:
            raise OutOfBlocksError(f"need {n} blocks, have {self.num_free}")
        blocks = [self._pop_block() for _ in range(n)]
        for b in blocks:
            self._ref[b] = 1
        self._tables[req_id] = blocks
        self._lens[req_id] = num_tokens
        return blocks

    def allocate_prefix(self, req_id: int, tokens: np.ndarray) -> int:
        """Admit ``req_id`` reusing cached prefix blocks; return #cached tokens.

        Every leading *full* block of ``tokens`` whose chained content hash is
        in the prefix cache is adopted (refcount bump) instead of allocated.
        With a host tier attached, a miss in the HBM cache falls back to
        promoting the demoted entry into a fresh block (content restored by
        the engine's tier drain before the step runs).  The sequence length
        starts at the cached token count, so prefill can skip straight to the
        first uncached token. At least one token is always left to recompute
        (a fully-cached prompt still needs its final logits), which makes the
        last shared block copy-on-write on first append.
        """
        assert req_id not in self._tables, req_id
        bs = self.block_size
        blocks: List[int] = []
        cached = 0
        full = len(tokens) // bs
        for i in range(full):
            key = _prefix_key(tokens, (i + 1) * bs)
            blk = self._block_of.get(key)
            if blk is None and self.host_pool is not None:
                blk = self._promote(key)
            if blk is None:
                break
            self._adopt(blk)
            blocks.append(blk)
            cached += bs
            self.prefix_hits += 1
        self.prefix_misses += full - len(blocks)
        if not blocks:                      # cold start: behave like allocate
            blk = self._pop_block()
            self._ref[blk] = 1
            blocks.append(blk)
        self._tables[req_id] = blocks
        cached = min(cached, max(len(tokens) - 1, 0))
        self._lens[req_id] = cached
        return cached

    def _adopt(self, blk: int) -> None:
        """Take one more reference on a cache-hit block (cached-free revival,
        live share, or a just-promoted tier block) and bump its evidence."""
        if blk in self._cached_free:
            del self._cached_free[blk]
            self._ref[blk] = 1
        else:
            self._ref[blk] = self._ref.get(blk, 0) + 1
        st = self._stats.setdefault(blk, BlockStats())
        st.hits += 1
        st.peak_ref = max(st.peak_ref, self._ref[blk])

    def _promote(self, key: bytes) -> Optional[int]:
        """Stage a host-tier entry back into a fresh HBM block.

        The block is hash-registered immediately (so chained lookups for the
        following blocks resolve) with its pre-demotion stats restored and a
        full watermark; the actual host→device content copy is queued on
        :attr:`pending_tier_ops`.  Returns ``None`` on a tier miss or when
        the HBM pool cannot yield a block (the entry is put back).
        """
        assert self.host_pool is not None
        entry = self.host_pool.take(key)
        if entry is None:
            return None
        try:
            blk = self._pop_block()
        except OutOfBlocksError:
            self.host_pool.untake(key, entry)
            return None
        self._hash_of[blk] = key
        self._block_of[key] = blk
        self._stats[blk] = entry.stats
        self._written[blk] = self.block_size
        self.pending_tier_ops.append(("promote", entry, blk))
        return blk

    def peek_prefix(self, tokens: np.ndarray) -> int:
        """#tokens a prompt would get from the HBM cache, without mutating it.

        Host-tier entries are deliberately NOT counted: a promotion consumes
        a fresh HBM block, so for admission sizing a demoted prefix block
        costs what a fresh block costs.
        """
        bs = self.block_size
        cached = 0
        for i in range(len(tokens) // bs):
            if _prefix_key(tokens, (i + 1) * bs) not in self._block_of:
                break
            cached += bs
        return min(cached, max(len(tokens) - 1, 0))

    def extend_prefix(self, req_id: int, tokens: np.ndarray) -> int:
        """Same-wave prefix dedup: fast-forward a mid-prefill request over
        blocks another request published since it was admitted.

        While ``req_id``'s committed length sits on a block boundary, adopt
        the published block for its next ``block_size`` tokens — but only if
        that block's KV-written watermark covers the whole block (the donor
        may still be prefilling later chunks; a published block is complete,
        the watermark is the proof).  An untouched placeholder block at the
        frontier (the cold-start pop: private, unpublished, watermark 0) is
        swapped back to the free list.  As in :meth:`allocate_prefix`, at
        least one token is always left to recompute.  Returns the number of
        tokens fast-forwarded; callers advance their prefill cursor by it.
        """
        bs = self.block_size
        pos = self._lens[req_id]
        table = self._tables[req_id]
        adopted = 0
        while pos % bs == 0 and pos + bs <= len(tokens) - 1:
            blk = self._block_of.get(_prefix_key(tokens, pos + bs))
            if blk is None or self._written.get(blk, 0) < bs:
                break
            bi = pos // bs
            if bi < len(table):
                own = table[bi]
                if (own == blk or self._ref.get(own) != 1
                        or own in self._hash_of
                        or self._written.get(own, 0) > 0):
                    break               # frontier block already has content
                table[bi] = blk
                self._decref(own)       # untouched placeholder -> free list
            else:
                assert bi == len(table), (bi, len(table))
                table.append(blk)
            self._adopt(blk)
            self.prefix_hits += 1
            pos += bs
            adopted += bs
        if adopted:
            self._lens[req_id] = pos
        return adopted

    def register_prefix(self, req_id: int, tokens: np.ndarray,
                        num_valid: int, start: int = 0) -> None:
        """Publish content hashes for full blocks covered by committed KV.

        ``tokens[:num_valid]`` must have their KV written to the request's
        blocks; ``start`` (a token count) skips blocks published by earlier
        calls so incremental prefill commits hash each block once.
        Shared-safe: an existing hash entry is never overwritten.
        """
        bs = self.block_size
        table = self._tables[req_id]
        for i in range(start // bs, num_valid // bs):
            blk = table[i]
            if blk in self._hash_of:
                continue
            key = _prefix_key(tokens, (i + 1) * bs)
            if key in self._block_of:       # identical content already cached
                continue
            self._hash_of[blk] = key
            self._block_of[key] = blk

    def reserve_tokens(self, req_id: int, n: int) -> np.ndarray:
        """Reserve write slots for the next ``n`` tokens; returns (n, 2).

        Grows the block table on demand and performs copy-on-write for any
        target block shared with another request (the (src, dst) pair lands
        in :attr:`pending_copies` — apply with :func:`copy_pool_blocks`
        before the step). Does not advance the sequence: call
        :meth:`commit_tokens` once the KV entries are written.
        """
        pos0 = self._lens[req_id]
        table = self._tables[req_id]
        out = np.zeros((n, 2), np.int32)
        for j in range(n):
            pos = pos0 + j
            bi = pos // self.block_size
            if bi == len(table):
                blk = self._pop_block()
                self._ref[blk] = 1
                table.append(blk)
            blk = table[bi]
            if self._ref[blk] > 1:          # shared: copy-on-write
                new = self._pop_block()
                self._ref[new] = 1
                self._ref[blk] -= 1
                table[bi] = new
                self.pending_copies.append((blk, new))
                self.cow_copies += 1
                # the device copy clones the whole block: watermark carries
                self._written[new] = self._written.get(blk, 0)
                blk = new
            elif blk in self._hash_of:      # private but published: invalidate
                self._unregister(blk)
            out[j] = (blk, pos % self.block_size)
        return out

    def commit_tokens(self, req_id: int, n: int) -> None:
        pos0 = self._lens[req_id]
        if n > 0:                           # advance KV-written watermarks
            bs = self.block_size
            table = self._tables[req_id]
            for bi in range(pos0 // bs, (pos0 + n - 1) // bs + 1):
                filled = min(pos0 + n - bi * bs, bs)
                blk = table[bi]
                if filled > self._written.get(blk, 0):
                    self._written[blk] = filled
        self._lens[req_id] = pos0 + n

    def drain_copies(self) -> List[Tuple[int, int]]:
        copies, self.pending_copies = self.pending_copies, []
        return copies

    def drain_tier_ops(self) -> List[Tuple[str, HostBlock, int]]:
        """Hand the queued host-tier traffic to the engine, IN ORDER."""
        ops, self.pending_tier_ops = self.pending_tier_ops, []
        return ops

    # Single-token conveniences (legacy API, used by tests/benchmarks).
    def reserve_slot(self, req_id: int) -> Tuple[int, int]:
        blk, off = self.reserve_tokens(req_id, 1)[0]
        return int(blk), int(off)

    def commit_token(self, req_id: int) -> None:
        self.commit_tokens(req_id, 1)

    def append_token(self, req_id: int) -> Tuple[int, int]:
        """reserve + commit in one call (single-step convenience)."""
        slot = self.reserve_slot(req_id)
        self.commit_token(req_id)
        return slot

    def rewind(self, req_id: int, n: int = 1) -> None:
        """Public rollback: drop the last ``n`` committed tokens.

        Trailing blocks no longer covered are released (decref — shared
        blocks survive for their other holders). The next
        :meth:`reserve_tokens` re-reserves the rewound positions, with
        copy-on-write if the block is still shared.
        """
        self.truncate(req_id, max(self._lens[req_id] - n, 0))

    def truncate(self, req_id: int, new_len: int) -> None:
        """Public truncation: keep only the first ``new_len`` tokens."""
        assert 0 <= new_len <= self._lens[req_id], (new_len, self._lens[req_id])
        table = self._tables[req_id]
        keep = max(1, -(-new_len // self.block_size))
        while len(table) > keep:
            self._decref(table.pop())
        self._lens[req_id] = new_len
        # Rolled-back KV in the last kept block is stale: lower its watermark
        # when the block is private and unpublished (the spec-rollback case —
        # shared/published blocks keep valid content for their other holders).
        last = table[-1]
        off = max(new_len - (len(table) - 1) * self.block_size, 0)
        if (self._ref.get(last) == 1 and last not in self._hash_of
                and off < self._written.get(last, 0)):
            self._written[last] = off

    def free(self, req_id: int) -> None:
        if req_id not in self._tables:
            raise KeyError(f"free of unknown request {req_id} (double free?)")
        for blk in self._tables.pop(req_id):
            self._decref(blk)
        del self._lens[req_id]

    @property
    def num_free(self) -> int:
        """Allocatable blocks: truly free + evictable cached-free."""
        return len(self._free) + len(self._cached_free)

    def check_invariants(self, *, drained: bool = False) -> None:
        """Validate the allocator's full internal state; raise ``ValueError``
        naming the first violated invariant.

        Called after every commit when ``ServeConfig.sanitize`` is on (via
        ``repro.analysis.sanitize``).  With ``drained=True`` additionally
        requires the fully-idle state: every block free, no tables, no
        pending device traffic.
        """
        def fail(msg: str) -> None:
            raise ValueError(msg)

        bs, blocks = self.block_size, set(range(self.num_blocks))
        free, cached = set(self._free), set(self._cached_free)
        live = set(self._ref)     # _decref drops the entry at refcount 0
        # 1. free / cached-free / refcounted partition the block space
        if len(free) != len(self._free):
            fail(f"duplicate ids on free list: {sorted(self._free)}")
        for a, b, what in ((free, cached, "free and cached-free"),
                           (free, live, "free and refcounted"),
                           (cached, live, "cached-free and refcounted")):
            if a & b:
                fail(f"blocks both {what}: {sorted(a & b)}")
        if (free | cached | live) != blocks:
            fail(f"blocks neither free nor tracked: "
                 f"{sorted(blocks - free - cached - live)}")
        # 2. refcounts equal table occurrences exactly
        occurrences: Dict[int, int] = {}
        for table in self._tables.values():
            for blk in table:
                occurrences[blk] = occurrences.get(blk, 0) + 1
        if occurrences != self._ref:
            off = {blk: (occurrences.get(blk, 0), self._ref.get(blk, 0))
                   for blk in set(occurrences) ^ set(self._ref)
                   or {b for b in occurrences
                       if occurrences[b] != self._ref.get(b)}}
            fail(f"refcounts disagree with table occurrences "
                 f"(block: (occurrences, refcount)): {off}")
        # 3. per-request table shape: lens keyed like tables, nonempty
        #    tables, enough blocks to cover the committed length (>= — a
        #    reserve may over-grow the table ahead of its commit)
        if set(self._lens) != set(self._tables):
            fail(f"_lens keys {sorted(self._lens)} != _tables keys "
                 f"{sorted(self._tables)}")
        for rid, table in self._tables.items():
            if not table:
                fail(f"request {rid} has an empty block table")
            need = -(-self._lens[rid] // bs)
            if len(table) < need:
                fail(f"request {rid}: {len(table)} blocks cover only "
                     f"{len(table) * bs} tokens < committed {self._lens[rid]}")
        # 4. prefix cache is a bijection and covers every cached-free block
        if {k: b for b, k in self._hash_of.items()} != dict(self._block_of):
            fail("prefix cache maps are not inverse bijections")
        if not cached <= set(self._hash_of):
            fail(f"cached-free blocks without a content hash: "
                 f"{sorted(cached - set(self._hash_of))}")
        # 5. watermarks in range (NOT <= committed fill: CoW carries the
        #    donor's watermark, which may exceed the new holder's fill)
        for blk, w in self._written.items():
            if not 0 <= w <= bs:
                fail(f"block {blk} watermark {w} outside [0, {bs}]")
        # 6. tier-op ordering: a promote's data must exist by the time it
        #    is applied — set at demotion or host-pool insertion
        for kind, entry, blk in self.pending_tier_ops:
            if kind == "promote" and entry.data is None:
                fail(f"pending promote of block {blk} has no host data")
        # 7. CoW queue: endpoints in range, destination refcounted
        for src, dst in self.pending_copies:
            if not (0 <= src < self.num_blocks
                    and 0 <= dst < self.num_blocks):
                fail(f"pending copy ({src}, {dst}) out of range")
            if dst not in self._ref:
                fail(f"pending copy destination {dst} is not a live block")
        # 8. fully drained state
        if drained:
            if self.num_free != self.num_blocks:
                fail(f"not drained: {self.num_free}/{self.num_blocks} free")
            if self._tables or self.pending_copies or self.pending_tier_ops:
                fail(f"not drained: tables={sorted(self._tables)} "
                     f"copies={self.pending_copies} "
                     f"tier_ops={len(self.pending_tier_ops)}")

    def ref_count(self, block: int) -> int:
        return self._ref.get(block, 0)

    def written(self, block: int) -> int:
        """KV-written watermark of a physical block (0 if never written)."""
        return self._written.get(block, 0)

    def transferable(self, req_id: int) -> bool:
        """True iff every committed token's KV is watermark-covered — the
        prefill→decode handoff contract: the request's blocks can be copied
        out of this pool without reading unwritten slots."""
        pos = self._lens[req_id]
        for i, blk in enumerate(self._tables[req_id]):
            need = min(max(pos - i * self.block_size, 0), self.block_size)
            if self._written.get(blk, 0) < need:
                return False
        return True

    def block_stats(self, block: int) -> BlockStats:
        """Eviction evidence for ``block`` (empty record if never touched)."""
        return self._stats.setdefault(block, BlockStats())

    def seq_len(self, req_id: int) -> int:
        return self._lens[req_id]

    def table(self, req_id: int) -> List[int]:
        return list(self._tables[req_id])

    # -- device-layout builders ----------------------------------------------
    def build_block_table(self, req_ids: List[int], max_blocks: int,
                          pad_block: int = 0) -> Tuple[np.ndarray, np.ndarray]:
        """Baseline padded layout (vLLM_base): (B, max_blocks) + seq_lens (B,).

        Padding entries point at ``pad_block`` — they are *gathered anyway* by
        the baseline kernel, reproducing the paper's redundant-gather cost.
        """
        B = len(req_ids)
        tab = np.full((B, max_blocks), pad_block, np.int32)
        lens = np.zeros((B,), np.int32)
        for i, r in enumerate(req_ids):
            t = self._tables[r]
            assert len(t) <= max_blocks, (len(t), max_blocks)
            tab[i, :len(t)] = t
            lens[i] = self._lens[r]
        return tab, lens

    def build_block_list(self, req_ids: List[int], max_total: Optional[int] = None
                         ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """Optimized flat layout (vLLM_opt / this framework).

        Returns (block_list, block_req, block_pos, seq_lens):
          block_list (T,) pool indices of ONLY effectual blocks
          block_req  (T,) owning request index in [0,B)
          block_pos  (T,) block's ordinal position within its request
          seq_lens   (B,)
        Padded (if max_total given) with req = B (out-of-range ⇒ dropped by
        segment ops) so the array shape is static for jit.
        """
        lists, reqs, poss = [], [], []
        lens = np.zeros((len(req_ids),), np.int32)
        for i, r in enumerate(req_ids):
            t = self._tables[r]
            lists.extend(t)
            reqs.extend([i] * len(t))
            poss.extend(range(len(t)))
            lens[i] = self._lens[r]
        T = len(lists)
        if max_total is not None:
            assert T <= max_total, (T, max_total)
            pad = max_total - T
            lists.extend([0] * pad)
            reqs.extend([len(req_ids)] * pad)   # out-of-range segment id
            poss.extend([0] * pad)
        return (np.asarray(lists, np.int32), np.asarray(reqs, np.int32),
                np.asarray(poss, np.int32), lens)

    def build_sharded_block_lists(self, req_slots: List[Tuple[int, int]],
                                  pad_req: int,
                                  min_per_shard: Optional[int] = None,
                                  ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Per-shard LOCAL BlockLists — the chunked sharded path's render.

        The sharded sibling of the flat list the engine renders per step:
        each entry of a request's table lands on its PHYSICAL owner shard
        (``shard_of``) as a LOCAL pool index (``block % blocks_per_shard``),
        keyed by the caller-supplied slot id (``req_slots`` is
        ``[(req_id, slot), ...]``) with its ordinal block position.  Sharding
        the resulting (S, M) arrays on dim 0 hands every shard_map rank
        exactly the slice of the BlockList its pool shard can serve —
        ``paged_attention_chunked_sharded`` combines the partials.

        ``M`` is ``min_per_shard`` (default ``blocks_per_shard``, mirroring
        the single-device render's pool-size capacity) grown by
        power-of-two doubling when prefix-shared tables overflow it, so the
        engine's jit cache stays O(log) programs.  Padding entries carry
        ``pad_req`` (an out-of-range slot id ⇒ masked by the kernel).
        Returns ``(block_list, block_req, block_pos)``, each (S, M) int32.
        """
        S = self.num_shards
        per_shard = self.blocks_per_shard
        entries: List[List[Tuple[int, int, int]]] = [[] for _ in range(S)]
        for r, slot in req_slots:
            for k, b in enumerate(self._tables[r]):
                entries[b // per_shard].append((b % per_shard, slot, k))
        cap = min_per_shard if min_per_shard is not None else per_shard
        need = max((len(e) for e in entries), default=0)
        while cap < need:
            cap *= 2
        bl = np.zeros((S, cap), np.int32)
        br = np.full((S, cap), pad_req, np.int32)
        bp = np.zeros((S, cap), np.int32)
        for s in range(S):
            for j, (b, slot, k) in enumerate(entries[s]):
                bl[s, j], br[s, j], bp[s, j] = b, slot, k
        return bl, br, bp

    def write_slots(self, req_ids: List[int]) -> np.ndarray:
        """(B, 2) [block, offset] where the NEXT token of each request lands.

        Reserves blocks on demand (call :meth:`commit_token` after the step).
        """
        out = np.zeros((len(req_ids), 2), np.int32)
        for i, r in enumerate(req_ids):
            out[i] = self.reserve_tokens(r, 1)[0]
        return out


# ---------------------------------------------------------------------------
# Device-side pool ops (pure jnp; shapes are jit-static)
# ---------------------------------------------------------------------------
def make_pool(num_layers: int, num_blocks: int, block_size: int,
              num_kv: int, head_dim: int, dtype=jnp.bfloat16):
    shape = (num_layers, num_blocks, block_size, num_kv, head_dim)
    return jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)


def make_fused_pool(num_layers: int, num_blocks: int, block_size: int,
                    num_kv: int, head_dim: int, dtype=jnp.bfloat16):
    """ONE head-interleaved KV buffer: ``[K0, V0, K1, V1, ...]`` on the head
    axis (docs/ragged_kernel.md).

    Shape (L, NB, BS, 2*KV, HD) — K and V of each kv-head are adjacent, so
    every whole-buffer move (CoW block copy, tier demote/promote, disagg
    handoff, the kernel's HBM->VMEM page DMA) is ONE transfer instead of two.
    ``fused_kv_views`` recovers (k, v) views for math written against split
    pools; ``fuse_kv_heads`` interleaves fresh per-token K/V for the append.
    """
    shape = (num_layers, num_blocks, block_size, 2 * num_kv, head_dim)
    return jnp.zeros(shape, dtype)


def fused_kv_views(pool):
    """Split-view shim over a fused pool: ``(..., 2*KV, HD) -> k, v``.

    Pure reshape + index (no data movement until consumed), valid for any
    leading dims — a whole layer stack, one layer, or a single VMEM page
    tile inside a kernel.  The views hold exactly the values a split pool
    would, so math running on them is bit-identical to the split layout.
    """
    *lead, kv2, hd = pool.shape
    r = pool.reshape(*lead, kv2 // 2, 2, hd)
    return r[..., 0, :], r[..., 1, :]


def fuse_kv_heads(k_new, v_new):
    """Interleave per-token K/V ``(..., KV, HD) x2 -> (..., 2*KV, HD)``.

    Inverse of :func:`fused_kv_views` on the head axis: the result's head
    order is ``[K0, V0, K1, V1, ...]``, ready for ONE ``append_to_pool``
    scatter into a fused pool.
    """
    *lead, kv, hd = k_new.shape
    return jnp.stack([k_new, v_new], axis=-2).reshape(*lead, 2 * kv, hd)


def append_to_pool(pool_layer, kv_new, slots):
    """Write one token per request into a single layer's pool.

    pool_layer (NB, BS, KV, HD); kv_new (B, KV, HD); slots (B, 2) [block, off].
    Out-of-range slots (e.g. (NB, 0) on non-owning model ranks of a sharded
    pool) are dropped — this is how sharded writes stay shard-local.
    """
    return pool_layer.at[slots[:, 0], slots[:, 1]].set(
        kv_new.astype(pool_layer.dtype), mode="drop")


def copy_pool_blocks(pool, srcs, dsts):
    """Copy whole blocks across the layer-stacked pool (copy-on-write).

    pool (L, NB, BS, KV, HD); srcs/dsts (n,) block indices.  Out-of-bounds
    entries (src = dst = NB) are inert padding: the gather clips to the last
    block and the ``mode="drop"`` scatter discards the write — callers pad
    the copy count to a power-of-two bucket so a varying number of CoW
    copies per step reuses a handful of compiled programs.
    """
    NB = pool.shape[1]
    vals = jnp.take(pool, jnp.minimum(srcs, NB - 1), axis=1)
    return pool.at[:, dsts].set(vals, mode="drop")


def gather_prefill_into_pool(pool_layer, k_seq, block_table, seq_len: int,
                             block_size: int):
    """Scatter a prefilled (B, S, KV, HD) K (or V) into pool blocks.

    block_table (B, nb) lists each request's blocks in order.
    """
    B, S = k_seq.shape[:2]
    nb = block_table.shape[1]
    assert nb * block_size >= S
    k_blocks = k_seq.reshape(B, S // block_size, block_size, *k_seq.shape[2:])
    flat_idx = block_table[:, :S // block_size].reshape(-1)
    return pool_layer.at[flat_idx].set(
        k_blocks.reshape((-1,) + k_blocks.shape[2:]).astype(pool_layer.dtype))
