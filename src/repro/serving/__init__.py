from repro.serving.request import (  # noqa: F401
    Request, RequestState, SamplingParams)
from repro.serving.steps import (  # noqa: F401
    jit_prefill_step, jit_serve_step, make_prefill_step, make_serve_step)
