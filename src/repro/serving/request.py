"""Serving request lifecycle: the explicit per-request state machine.

A request moves through

    WAITING -> PREFILLING -> DECODING -> FINISHED
       ^           |            |
       '--- PREEMPTED <---------'

* WAITING     queued; no slot, no KV blocks.
* PREFILLING  admitted; prompt KV is being written chunk-by-chunk (chunked
  prefill — chunks ride inside the fused decode step, they never stall the
  decode batch).
* DECODING    prompt fully cached; one token per engine step — or, with a
  speculative proposer resolved (``repro.serving.spec``), 1 to K+1 tokens
  per step: the engine carries the last token plus K drafts through one
  fused forward and commits the accepted prefix (the state machine is
  unchanged; only the per-step token count varies).
* PREEMPTED   evicted under block pressure; KV blocks were released and the
  request re-queued at the FRONT of the wait queue. On re-admission it
  recomputes KV for ``prompt + output`` (vLLM's recompute-style preemption),
  which reproduces the exact generation state — output tokens survive.
* FINISHED    hit ``max_new_tokens`` or EOS; blocks freed, metrics recorded.

This module is deliberately jax-free: it is pure host-side bookkeeping shared
by ``repro.serving.scheduler`` and ``repro.serving.engine``.
"""
from __future__ import annotations

import enum
import time
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np


def bucket_pow2(n: int, lo: int = 8) -> int:
    """Round ``n`` up to a power of two, at least ``lo``.

    The serving stack's shape-bucketing helper (bounded jit-cache growth):
    the engine buckets token-lane and active-slot counts, the draft-model
    proposer buckets its context window.
    """
    b = lo
    while b < n:
        b *= 2
    return b


class RequestState(enum.Enum):
    WAITING = "waiting"
    PREFILLING = "prefilling"
    DECODING = "decoding"
    PREEMPTED = "preempted"
    FINISHED = "finished"


_LEGAL = {
    RequestState.WAITING: {RequestState.PREFILLING},
    RequestState.PREFILLING: {RequestState.DECODING, RequestState.PREEMPTED,
                              RequestState.FINISHED},
    RequestState.DECODING: {RequestState.PREEMPTED, RequestState.FINISHED},
    # PREEMPTED -> FINISHED: the async overlapped loop can resolve a
    # request's final token (EOS / max_new_tokens) after the scheduler
    # preempted it mid-flight — the stream is complete, recompute is moot.
    RequestState.PREEMPTED: {RequestState.PREFILLING, RequestState.FINISHED},
    RequestState.FINISHED: set(),
}


@dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling policy, applied batched inside the jit'd step.

    ``temperature <= 0`` means greedy; ``top_k <= 0`` / ``top_p >= 1``
    disable the respective filters.
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0


@dataclass
class Request:
    req_id: int
    prompt: np.ndarray                  # (prompt_len,) int32
    max_new_tokens: int
    sampling: SamplingParams = field(default_factory=SamplingParams)
    arrival: float = field(default_factory=time.time)
    # Serving-policy inputs (repro.serving.policy): higher priority admits
    # first under the "priority" policy; deadline is an absolute time.time()
    # the "deadline-slo" policy schedules against (None = no SLO).
    priority: int = 0
    deadline: Optional[float] = None
    state: RequestState = RequestState.WAITING
    first_token_at: Optional[float] = None
    done_at: Optional[float] = None
    output: List[int] = field(default_factory=list)
    slot: int = -1
    # chunked-prefill cursor into active_prompt (tokens whose KV is cached)
    prefill_pos: int = 0
    num_preemptions: int = 0
    # tokens satisfied from the prefix cache at (last) admission
    cached_prompt_tokens: int = 0
    # prompt + already-generated tokens; set at admission (recompute resume)
    _active_prompt: Optional[np.ndarray] = None

    # ------------------------------------------------------------ transitions
    def to_state(self, new: RequestState) -> None:
        assert new in _LEGAL[self.state], (
            f"illegal transition {self.state.name} -> {new.name} "
            f"(req {self.req_id})")
        self.state = new

    def resume_tokens(self) -> np.ndarray:
        """Tokens to (re)prefill: prompt + already-generated output.

        The single source for admission sizing, prefix-cache hashing AND the
        engine's chunk content — recompute-style preemption resume depends
        on all three seeing the same sequence.
        """
        if not self.output:
            return np.asarray(self.prompt, np.int32)
        return np.concatenate([np.asarray(self.prompt, np.int32),
                               np.asarray(self.output, np.int32)])

    def begin_prefill(self, slot: int, cached_tokens: int,
                      active_prompt: Optional[np.ndarray] = None) -> None:
        """WAITING/PREEMPTED -> PREFILLING on an engine slot."""
        self._active_prompt = (active_prompt if active_prompt is not None
                               else self.resume_tokens())
        self.to_state(RequestState.PREFILLING)
        self.slot = slot
        self.prefill_pos = cached_tokens
        self.cached_prompt_tokens = cached_tokens

    def preempt(self) -> None:
        self.to_state(RequestState.PREEMPTED)
        self.slot = -1
        self.prefill_pos = 0
        self._active_prompt = None
        self.num_preemptions += 1

    def finish(self, now: Optional[float] = None) -> None:
        self.to_state(RequestState.FINISHED)
        self.done_at = now if now is not None else time.time()
        self.slot = -1

    # ------------------------------------------------------------- accessors
    @property
    def active_prompt(self) -> np.ndarray:
        assert self._active_prompt is not None, "request not admitted"
        return self._active_prompt

    @property
    def prefill_remaining(self) -> int:
        return len(self.active_prompt) - self.prefill_pos

    @property
    def ttft(self) -> Optional[float]:
        return (self.first_token_at - self.arrival
                if self.first_token_at else None)

    @property
    def tpot(self) -> Optional[float]:
        if self.done_at is None or self.first_token_at is None:
            return None
        n = max(len(self.output) - 1, 1)
        return (self.done_at - self.first_token_at) / n
