"""Pluggable serving policies: admission, preemption, and KV eviction.

The paper's vLLM study shows that serving throughput on a new backend is won
or lost in the software control plane — batching, admission, and KV
management — not raw kernel FLOPs.  This module makes those control-plane
decisions first-class, swappable strategies instead of hardcoded scheduler
branches, mirroring the operator-backend registry (`repro.core.dispatch`):
implementations are **registered** under a string key per axis, and ONE
resolver picks a policy with a well-defined precedence.

Axes and contracts
------------------
``admission``   orders the wait queue: which WAITING/PREEMPTED request is
                admitted next when a slot frees up.  Head-of-line semantics
                are preserved per policy: if the policy's top pick does not
                fit, admission stops (no starvation via queue-jumping).
``preemption``  ranks RUNNING requests most-preemptable-first under block
                pressure.  The scheduler evicts the top of the ranking and
                never touches the bottom (the policy's least-preemptable
                request is the progress guarantee).
``eviction``    scores refcount-0 cached-free blocks inside
                :class:`repro.core.paged_kv.BlockAllocator`: which block's
                prefix-cache content is dropped when the pool needs a fresh
                block.  Candidates arrive oldest-freed-first, with per-block
                :class:`~repro.core.paged_kv.BlockStats` (cache hits, peak
                refcount).

Resolution precedence (highest wins)
------------------------------------
1. explicit argument (a name or a policy *instance*) at the call site —
   strict: an unknown name raises :class:`UnknownPolicyError`;
2. ``with force_policies(admission=..., preemption=..., eviction=...):``
   scope (how ``benchmarks/run.py --policy`` sweeps triples);
3. a config hint (``ServeConfig.admission`` / ``.preemption`` /
   ``.eviction``, fed by ``repro.launch.serve --admission ...``);
4. the axis default (``fcfs`` / ``latest-arrival`` / ``lru`` — the exact
   behaviour the scheduler and allocator hardcoded before this API).

Unlike operator backends there is no capability predicate — every policy
works everywhere — so config-level names are validated strictly too: a typo'd
policy name fails loudly instead of degrading.

Policies are **instantiated per resolve** and carry per-run ``counters``
(e.g. admitted / victims / evictions) which the engine flattens into
``metrics()["policy_counters"]``.  Every resolution is appended to the active
:func:`record_resolutions` scope so benchmark rows can attribute numbers to
the policy triple that actually ran.
"""
from __future__ import annotations

import contextlib
import threading
from typing import (Any, Callable, Dict, Iterator, List, Mapping, Optional,
                    Sequence, Tuple, Type, Union)

from repro.core.paged_kv import BlockAllocator, BlockStats
from repro.serving.request import Request, RequestState

__all__ = [
    "ADMISSION", "PREEMPTION", "EVICTION", "AXES", "DEFAULTS",
    "UnknownPolicyError", "Policy", "AdmissionPolicy", "PreemptionPolicy",
    "EvictionPolicy", "register", "names", "get", "resolve", "resolve_triple",
    "force_policies", "forced_policy", "record_resolutions",
]

ADMISSION = "admission"
PREEMPTION = "preemption"
EVICTION = "eviction"
AXES = (ADMISSION, PREEMPTION, EVICTION)

# The pre-API hardcoded behaviour, byte-for-byte (see each class docstring).
DEFAULTS = {ADMISSION: "fcfs", PREEMPTION: "latest-arrival", EVICTION: "lru"}

_AUTO_NAMES = (None, "", "default")


class UnknownPolicyError(ValueError):
    """A requested policy name is not registered on its axis."""


# --------------------------------------------------------------------------
# Base classes (one per axis)
# --------------------------------------------------------------------------
class Policy:
    """Base for all policies: a registry name + per-run counters."""

    axis: str = ""           # set by @register
    name: str = ""           # set by @register

    def __init__(self) -> None:
        self.counters: Dict[str, int] = {}

    def count(self, key: str, n: int = 1) -> None:
        self.counters[key] = self.counters.get(key, 0) + n


class AdmissionPolicy(Policy):
    """Orders the wait queue.  Implement :meth:`admission_key`.

    Lower key = admitted sooner.  ``select`` returns the policy's top pick
    among ``waiting`` (the scheduler removes it from the queue itself so the
    policy never mutates scheduler state).
    """

    axis = ADMISSION

    def admission_key(self, req: Request, now: float) -> Tuple:
        raise NotImplementedError

    def select(self, waiting: Sequence[Request], now: float) -> Request:
        return min(waiting, key=lambda r: self.admission_key(r, now))

    def on_admit(self, req: Request, now: float) -> None:
        """Counter hook; called once per successful admission."""
        self.count("admitted")


class PreemptionPolicy(Policy):
    """Ranks running requests most-preemptable-first.

    Implement :meth:`victim_key`: HIGHER key = more preemptable.  The
    scheduler preempts ``rank(...)[0]`` and protects ``rank(...)[-1]`` (by
    taking the top only while two or more candidates exist), so the policy's
    least-preemptable request always keeps making progress.
    """

    axis = PREEMPTION

    def victim_key(self, req: Request, alloc: BlockAllocator,
                   now: float) -> Tuple:
        raise NotImplementedError

    def rank(self, running: Sequence[Request], alloc: BlockAllocator,
             now: float) -> List[Request]:
        return sorted(running,
                      key=lambda r: self.victim_key(r, alloc, now),
                      reverse=True)

    def on_preempt(self, req: Request, alloc: BlockAllocator) -> None:
        """Counter hook; called just before the victim's blocks are freed."""
        self.count("victims")
        self.count("blocks_reclaimed", len(alloc.table(req.req_id)))


class EvictionPolicy(Policy):
    """Scores cached-free blocks for eviction.  Implement :meth:`select`.

    ``candidates`` iterates oldest-freed-first (the allocator's cached-free
    order), ``stats`` maps block -> :class:`BlockStats`.  Return the block
    whose cached prefix content should be dropped.  The allocator calls
    :meth:`on_evict` after removing it.
    """

    axis = EVICTION

    def select(self, candidates: Sequence[int],
               stats: Mapping[int, BlockStats]) -> int:
        raise NotImplementedError

    def on_evict(self, block: int, stats: Mapping[int, BlockStats]) -> None:
        self.count("evictions")

    def demote(self, block: int, stats: Mapping[int, BlockStats]) -> bool:
        """Host-tier gate: should an evicted block's content be demoted to
        the host pool (True) or dropped (False)?  Only consulted when the
        allocator has a :class:`~repro.core.paged_kv.HostPool` attached; the
        base keeps everything — host capacity is cheap and the tier is LRU.
        """
        return True


# --------------------------------------------------------------------------
# Registry (mirrors repro.core.dispatch: register + resolve, scoped override,
# resolution log; thread-local so scopes can't leak across tests).
# --------------------------------------------------------------------------
_BASES = {ADMISSION: AdmissionPolicy, PREEMPTION: PreemptionPolicy,
          EVICTION: EvictionPolicy}
_REGISTRY: Dict[str, Dict[str, Type[Policy]]] = {a: {} for a in AXES}

_STATE = threading.local()


def register(axis: str, name: str) -> Callable[[Type[Policy]], Type[Policy]]:
    """Class decorator: register a policy class under ``name`` on ``axis``."""
    if axis not in AXES:
        raise ValueError(f"unknown policy axis {axis!r}; one of {AXES}")

    def deco(cls: Type[Policy]) -> Type[Policy]:
        if not issubclass(cls, _BASES[axis]):
            raise TypeError(
                f"{cls.__name__} must subclass {_BASES[axis].__name__} "
                f"to register on axis {axis!r}")
        if name in _REGISTRY[axis]:
            raise ValueError(f"{axis}: policy {name!r} registered twice")
        cls.axis = axis
        cls.name = name
        _REGISTRY[axis][name] = cls
        return cls

    return deco


def names(axis: str) -> List[str]:
    """Registered policy names on ``axis`` (sorted; default first)."""
    if axis not in AXES:
        raise ValueError(f"unknown policy axis {axis!r}; one of {AXES}")
    default = DEFAULTS[axis]
    rest = sorted(n for n in _REGISTRY[axis] if n != default)
    return [default] + rest if default in _REGISTRY[axis] else rest


def get(axis: str, name: str) -> Type[Policy]:
    try:
        return _REGISTRY[axis][name]
    except KeyError:
        raise UnknownPolicyError(
            f"{axis}: unknown policy {name!r}; registered: "
            f"{names(axis)}") from None


# -- scoped override + resolution log ---------------------------------------
def _scope_stack() -> List[Dict[str, str]]:
    if not hasattr(_STATE, "forced"):
        _STATE.forced = []
    return _STATE.forced


def _log_stack() -> List[List[Tuple[str, str]]]:
    if not hasattr(_STATE, "logs"):
        _STATE.logs = []
    return _STATE.logs


@contextlib.contextmanager
def force_policies(*, admission: Optional[str] = None,
                   preemption: Optional[str] = None,
                   eviction: Optional[str] = None) -> Iterator[None]:
    """Scoped policy preference per axis (``None`` axes are untouched).

    Names are validated on entry — a sweep over a typo'd triple fails before
    any engine is built, not mid-benchmark.
    """
    scope: Dict[str, str] = {}
    for axis, name in ((ADMISSION, admission), (PREEMPTION, preemption),
                       (EVICTION, eviction)):
        if name not in _AUTO_NAMES:
            get(axis, name)                      # validate eagerly
            scope[axis] = name
    stack = _scope_stack()
    stack.append(scope)
    try:
        yield
    finally:
        stack.pop()


def forced_policy(axis: str) -> Optional[str]:
    """The innermost ``force_policies`` preference for ``axis``, if any."""
    for scope in reversed(_scope_stack()):
        if axis in scope:
            return scope[axis]
    return None


@contextlib.contextmanager
def record_resolutions() -> Iterator[List[Tuple[str, str]]]:
    """Collect ``(axis, name)`` pairs resolved inside the scope."""
    log: List[Tuple[str, str]] = []
    _log_stack().append(log)
    try:
        yield log
    finally:
        stack = _log_stack()
        for i in range(len(stack) - 1, -1, -1):   # remove by identity
            if stack[i] is log:
                del stack[i]
                break


def _note(axis: str, name: str) -> None:
    for log in _log_stack():
        log.append((axis, name))


# -- resolver ----------------------------------------------------------------
def resolve(axis: str, explicit: Union[None, str, Policy] = None, *,
            config: Optional[str] = None) -> Policy:
    """Resolve one axis to a fresh policy instance (see module precedence).

    ``explicit`` may be a registered name or an already-built policy instance
    (injected by tests or embedding applications); instances pass through
    unchanged but are still logged under their registered name.
    """
    if axis not in AXES:
        raise ValueError(f"unknown policy axis {axis!r}; one of {AXES}")
    if isinstance(explicit, Policy):
        if explicit.axis != axis:
            raise ValueError(
                f"policy instance {explicit.name!r} is an {explicit.axis} "
                f"policy, not {axis}")
        _note(axis, explicit.name)
        return explicit
    for level in (explicit,                       # 1. explicit — strict
                  forced_policy(axis),            # 2. scope
                  config,                         # 3. config hint — strict
                  DEFAULTS[axis]):                # 4. default
        if level in _AUTO_NAMES:
            continue
        cls = get(axis, level)
        _note(axis, level)
        return cls()
    raise UnknownPolicyError(f"{axis}: no default policy registered")


def resolve_triple(*, admission: Union[None, str, Policy] = None,
                   preemption: Union[None, str, Policy] = None,
                   eviction: Union[None, str, Policy] = None,
                   config: Optional[Any] = None,
                   ) -> Tuple[AdmissionPolicy, PreemptionPolicy,
                              EvictionPolicy]:
    """Resolve all three axes at once (``config`` duck-types ServeConfig)."""
    cfg = {a: getattr(config, a, None) for a in AXES}
    return (resolve(ADMISSION, admission, config=cfg[ADMISSION]),
            resolve(PREEMPTION, preemption, config=cfg[PREEMPTION]),
            resolve(EVICTION, eviction, config=cfg[EVICTION]))


# --------------------------------------------------------------------------
# Admission policies
# --------------------------------------------------------------------------
@register(ADMISSION, "fcfs")
class FcfsAdmission(AdmissionPolicy):
    """First come, first served — the pre-API scheduler behaviour.

    Preempted requests resume ahead of fresh arrivals (they were re-queued at
    the FRONT of the old deque): they hold generated output whose recompute
    gets more expensive the longer they wait.
    """

    def admission_key(self, req: Request, now: float) -> Tuple:
        resumed = 0 if req.state is RequestState.PREEMPTED else 1
        return (resumed, req.arrival, req.req_id)


@register(ADMISSION, "priority")
class PriorityAdmission(AdmissionPolicy):
    """Highest ``Request.priority`` first; FCFS within a priority class."""

    def admission_key(self, req: Request, now: float) -> Tuple:
        resumed = 0 if req.state is RequestState.PREEMPTED else 1
        return (-req.priority, resumed, req.arrival, req.req_id)


@register(ADMISSION, "deadline-slo")
class DeadlineAdmission(AdmissionPolicy):
    """Earliest ``Request.deadline`` first (EDF); deadline-free last (FCFS).

    Counts ``deadline_missed`` for requests admitted after their deadline has
    already passed — an SLO burn-down visible in ``policy_counters``.
    """

    def admission_key(self, req: Request, now: float) -> Tuple:
        if req.deadline is None:
            return (1, 0.0, req.arrival, req.req_id)
        return (0, req.deadline, req.arrival, req.req_id)

    def on_admit(self, req: Request, now: float) -> None:
        super().on_admit(req, now)
        if req.deadline is not None and now > req.deadline:
            self.count("deadline_missed")


# --------------------------------------------------------------------------
# Preemption policies
# --------------------------------------------------------------------------
@register(PREEMPTION, "latest-arrival")
class LatestArrivalPreemption(PreemptionPolicy):
    """Evict the newest request — the pre-API hardcoded victim choice.

    Under FCFS this is the fairness-preserving victim: the request that has
    waited least loses least invested work, and the oldest request (ranked
    last) is protected.
    """

    def victim_key(self, req: Request, alloc: BlockAllocator,
                   now: float) -> Tuple:
        return (req.arrival, req.req_id)


@register(PREEMPTION, "fewest-remaining-tokens")
class FewestRemainingPreemption(PreemptionPolicy):
    """Evict the request with the least generation left to do.

    A nearly-done request re-prefills cheaply relative to its total KV (its
    recompute prompt is almost fully prefix-cacheable), and its short
    remaining decode makes it the quickest to clear the pool again after
    resume.  The request with the most work remaining is protected.
    """

    def victim_key(self, req: Request, alloc: BlockAllocator,
                   now: float) -> Tuple:
        remaining = req.max_new_tokens - len(req.output)
        return (-remaining, req.arrival, req.req_id)


@register(PREEMPTION, "most-blocks")
class MostBlocksPreemption(PreemptionPolicy):
    """Evict the request holding the most KV blocks.

    Frees the maximum pool space per preemption — fewest victims under a
    burst of pressure — at the cost of always punishing long sequences.
    """

    def victim_key(self, req: Request, alloc: BlockAllocator,
                   now: float) -> Tuple:
        return (len(alloc.table(req.req_id)), req.arrival, req.req_id)


# --------------------------------------------------------------------------
# Eviction policies (cached-free prefix blocks in BlockAllocator)
# --------------------------------------------------------------------------
@register(EVICTION, "lru")
class LruEviction(EvictionPolicy):
    """Drop the oldest-freed block — the pre-API hardcoded behaviour."""

    def select(self, candidates: Sequence[int],
               stats: Mapping[int, BlockStats]) -> int:
        return next(iter(candidates))


@register(EVICTION, "hit-rate")
class HitRateEviction(EvictionPolicy):
    """Drop the block with the fewest lifetime prefix-cache hits (tie: LRU).

    A block that keeps getting re-adopted (a shared system prompt) is worth
    keeping over one that was hashed but never matched again.
    """

    def select(self, candidates: Sequence[int],
               stats: Mapping[int, BlockStats]) -> int:
        return min(enumerate(candidates),
                   key=lambda iv: (stats[iv[1]].hits, iv[0]))[1]


@register(EVICTION, "refcount-aware")
class RefcountAwareEviction(EvictionPolicy):
    """Drop never-shared blocks first (peak refcount 1), then fewest hits.

    Peak refcount is the strongest evidence of sharing value: a block that
    was simultaneously held by several requests is the hottest prefix content
    in the pool even if its hit counter hasn't caught up yet.
    """

    def select(self, candidates: Sequence[int],
               stats: Mapping[int, BlockStats]) -> int:
        return min(enumerate(candidates),
                   key=lambda iv: (stats[iv[1]].peak_ref, stats[iv[1]].hits,
                                   iv[0]))[1]


@register(EVICTION, "tiered")
class TieredEviction(EvictionPolicy):
    """Host-tier-aware eviction: evict the coldest block, demote selectively.

    Selection drops the block with the least reuse evidence first (fewest
    hits, then lowest peak refcount, then LRU) — the mirror image of
    ``refcount-aware``'s keep order, so the HBM cache retains the hottest
    prefixes.  The :meth:`demote` gate then spends host-tier capacity only on
    blocks with *demonstrated* reuse (a prior hit or past sharing); a block
    that was hashed once and never matched is dropped outright instead of
    flushing hotter content out of the host LRU.  Counters: ``demoted`` /
    ``dropped`` per evicted block (only while a host pool is attached).
    """

    def select(self, candidates: Sequence[int],
               stats: Mapping[int, BlockStats]) -> int:
        return min(enumerate(candidates),
                   key=lambda iv: (stats[iv[1]].hits, stats[iv[1]].peak_ref,
                                   iv[0]))[1]

    def demote(self, block: int, stats: Mapping[int, BlockStats]) -> bool:
        st = stats.get(block, BlockStats())
        keep = st.hits > 0 or st.peak_ref > 1
        self.count("demoted" if keep else "dropped")
        return keep

# --------------------------------------------------------------------------
# Measured-table consumption (repro.perf, docs/perf_gate.md): policies whose
# behaviour is derived from trace-replay evidence rather than fixed heuristics.
# The `auto` triple delegates its scoring methods to the per-scenario winner
# from the committed perf table (BENCH_009.json); `predicted-length` admission
# orders the queue by a decode-length cost model fit from trace history.
# Both resolve their inputs from the thread-local replay context
# (repro.perf.table.perf_context) at construction time — which is when the
# engine resolves its triple — and fall back to deterministic defaults with a
# counted reason when no context/table is active.
# --------------------------------------------------------------------------
class _AutoDelegate:
    """Shared winner-resolution for the `auto` policies.

    Looks up the active (scenario, perf-table) pair and instantiates the
    winning concrete policy for this axis.  Counters land on the *auto*
    instance (`auto_resolved`/`auto_fallback` + a readable `resolved_<name>`
    marker); only scoring decisions are delegated, so scheduling is
    bit-identical to running the winner triple directly.
    """

    def _resolve_delegate(self) -> Policy:
        from repro.perf import table as perf_table  # lazy: no cycle at import
        name = perf_table.resolve_winner(self.axis)
        if name is None or name == self.name:
            self.count("auto_fallback")
            name = DEFAULTS[self.axis]
        else:
            self.count("auto_resolved")
        self.count(f"resolved_{name.replace('-', '_')}")
        self.resolved = name
        return get(self.axis, name)()


@register(ADMISSION, "auto")
class AutoAdmission(AdmissionPolicy, _AutoDelegate):
    """Admission order of the measured per-scenario winner (else fcfs)."""

    def __init__(self) -> None:
        super().__init__()
        self._impl = self._resolve_delegate()

    def admission_key(self, req: Request, now: float) -> Tuple:
        return self._impl.admission_key(req, now)


@register(PREEMPTION, "auto")
class AutoPreemption(PreemptionPolicy, _AutoDelegate):
    """Victim ranking of the measured winner (else latest-arrival)."""

    def __init__(self) -> None:
        super().__init__()
        self._impl = self._resolve_delegate()

    def victim_key(self, req: Request, alloc: BlockAllocator,
                   now: float) -> Tuple:
        return self._impl.victim_key(req, alloc, now)


@register(EVICTION, "auto")
class AutoEviction(EvictionPolicy, _AutoDelegate):
    """Block scoring + demote gate of the measured winner (else lru)."""

    def __init__(self) -> None:
        super().__init__()
        self._impl = self._resolve_delegate()

    def select(self, candidates: Sequence[int],
               stats: Mapping[int, BlockStats]) -> int:
        return self._impl.select(candidates, stats)

    def demote(self, block: int, stats: Mapping[int, BlockStats]) -> bool:
        return self._impl.demote(block, stats)


@register(ADMISSION, "predicted-length")
class PredictedLengthAdmission(AdmissionPolicy):
    """Shortest-predicted-job-first via a trace-learned decode-length model.

    The cost of admitting a request is its remaining work: tokens still to
    (re)prefill plus its *predicted* remaining decode length, estimated by
    the prompt-length-bucketed :class:`repro.perf.trace.LengthModel` from the
    active replay context.  Without a model the declared ``max_new_tokens``
    cap is the estimate (counted ``model_absent`` once).  Preempted requests
    still resume first — same no-starvation rationale as fcfs.
    """

    def __init__(self) -> None:
        super().__init__()
        from repro.perf import table as perf_table  # lazy: no cycle at import
        self.model = perf_table.active_length_model()
        if self.model is None:
            self.count("model_absent")

    def admission_key(self, req: Request, now: float) -> Tuple:
        resumed = 0 if req.state is RequestState.PREEMPTED else 1
        done = len(req.output)
        if self.model is not None:
            predicted = max(self.model.predict(len(req.prompt)) - done, 0.0)
        else:
            predicted = float(req.max_new_tokens - done)
        # remaining work = (re)prefill of prompt + generated-so-far, plus the
        # predicted remaining decode
        remaining = len(req.prompt) + done + predicted
        return (resumed, remaining, req.arrival, req.req_id)
