"""Serving scheduler: admission, chunked-prefill budgeting, preemption.

The policy half of the serving stack (the engine is the mechanism half —
it renders the scheduler's :class:`StepPlan` into one fused device step).

Per engine step the scheduler:

  1. **Admits** waiting requests FCFS while a batch slot is free and the
     allocator can hold the whole prompt (prefix-cached blocks are adopted
     at admission and don't count against free space).
  2. **Budgets prefill**: every DECODING request always gets its one decode
     lane; PREFILLING requests share a per-step token budget
     (``token_budget``, vLLM's ``max_num_batched_tokens`` analogue) so long
     prompts are chunked across steps instead of stalling the decode batch.
  3. **Preempts under block pressure**: if the step's block demand (new
     decode blocks + prefill-chunk blocks + copy-on-write copies) exceeds
     the pool, the latest-arrived running request is evicted — its blocks
     are released and it re-queues at the FRONT of the wait queue for
     recompute-style resume (see ``repro.serving.request``).

The scheduler owns the request queues and the slot free-list; it never
touches device state.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple

from repro.core.paged_kv import BlockAllocator, OutOfBlocksError
from repro.serving.request import Request, RequestState


@dataclass
class StepPlan:
    """What the engine should run this step."""

    decode: List[Request] = field(default_factory=list)
    prefill: List[Tuple[Request, int]] = field(default_factory=list)  # (req, n)

    @property
    def num_tokens(self) -> int:
        return len(self.decode) + sum(n for _, n in self.prefill)


class Scheduler:
    def __init__(self, alloc: BlockAllocator, *, max_batch: int,
                 token_budget: int):
        self.alloc = alloc
        self.max_batch = max_batch
        self.token_budget = max(1, token_budget)
        self.waiting: Deque[Request] = deque()
        self.running: Dict[int, Request] = {}
        self.free_slots: List[int] = list(range(max_batch - 1, -1, -1))
        self.num_preemptions = 0

    # ------------------------------------------------------------------ queue
    def submit(self, req: Request) -> None:
        assert req.state is RequestState.WAITING, req.state
        self.waiting.append(req)

    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    # -------------------------------------------------------------- admission
    def _admit(self) -> None:
        while self.waiting and self.free_slots:
            req = self.waiting[0]
            # resume prompt includes generated tokens (recompute preemption)
            active = req.resume_tokens()
            bs = self.alloc.block_size
            cached = self.alloc.peek_prefix(active)
            total_blocks = max(1, -(-len(active) // bs))
            fresh = max(total_blocks - cached // bs, 0) + 1  # +1 decode slack
            if self.alloc.num_free < fresh:
                # Livelock breaker: the whole pool is free and still too
                # small — this request (e.g. one whose resume prompt grew
                # past the pool after preemption) will NEVER be admittable,
                # and as FCFS head-of-line it would starve everyone behind
                # it. Fail loudly instead of spinning.
                if (not self.running
                        and self.alloc.num_free == self.alloc.num_blocks):
                    raise OutOfBlocksError(
                        f"request {req.req_id} needs {fresh} blocks but the "
                        f"whole pool is only {self.alloc.num_blocks}")
                break                                        # FCFS head-of-line
            self.waiting.popleft()
            slot = self.free_slots.pop()
            cached = self.alloc.allocate_prefix(req.req_id, active)
            req.begin_prefill(slot, cached, active_prompt=active)
            self.running[req.req_id] = req

    # -------------------------------------------------------------- capacity
    def _blocks_needed(self, plan: StepPlan) -> int:
        """Exact pool demand of the plan: new blocks + copy-on-write copies.

        A shared physical block written by several plan members costs
        ``min(#writers, refcount - 1)`` copies, not one per writer: each CoW
        drops the refcount, and the last writer at refcount 1 writes in
        place.
        """
        bs = self.alloc.block_size
        need = 0
        cow_writers: Dict[int, int] = {}     # physical block -> plan writers
        for req in plan.decode:
            pos = self.alloc.seq_len(req.req_id)
            table = self.alloc.table(req.req_id)
            bi = pos // bs
            if bi >= len(table):
                need += 1
            elif self.alloc.ref_count(table[bi]) > 1:
                cow_writers[table[bi]] = cow_writers.get(table[bi], 0) + 1
        for req, n in plan.prefill:
            pos = self.alloc.seq_len(req.req_id)
            table = self.alloc.table(req.req_id)
            last_bi = (pos + n - 1) // bs
            need += max(last_bi + 1 - len(table), 0)         # new blocks
            for bi in range(pos // bs, min(last_bi, len(table) - 1) + 1):
                if self.alloc.ref_count(table[bi]) > 1:
                    cow_writers[table[bi]] = cow_writers.get(table[bi], 0) + 1
        for blk, writers in cow_writers.items():
            need += min(writers, self.alloc.ref_count(blk) - 1)
        return need

    def _pick_victim(self, protect: Optional[Request]) -> Optional[Request]:
        """Latest-arrived running request (lowest priority under FCFS)."""
        victims = [r for r in self.running.values() if r is not protect]
        if not victims:
            return None
        return max(victims, key=lambda r: (r.arrival, r.req_id))

    def release(self, req: Request) -> None:
        """Return a running request's blocks and slot (finish or preempt)."""
        self.alloc.free(req.req_id)
        del self.running[req.req_id]
        self.free_slots.append(req.slot)

    def _preempt(self, req: Request) -> None:
        self.release(req)
        req.preempt()
        self.waiting.appendleft(req)
        self.num_preemptions += 1

    # ------------------------------------------------------------------- plan
    def schedule(self) -> StepPlan:
        """Admit, budget prefill chunks, and preempt until the plan fits."""
        self._admit()
        while True:
            plan = StepPlan()
            budget = self.token_budget
            for req in self.running.values():
                if req.state is RequestState.DECODING:
                    plan.decode.append(req)
            for req in self.running.values():
                if req.state is RequestState.PREFILLING and budget > 0:
                    n = min(req.prefill_remaining, budget)
                    if n > 0:
                        plan.prefill.append((req, n))
                        budget -= n
            if self._blocks_needed(plan) <= self.alloc.num_free:
                return plan
            oldest = min(self.running.values(),
                         key=lambda r: (r.arrival, r.req_id))
            victim = self._pick_victim(protect=oldest)
            if victim is None:
                raise OutOfBlocksError(
                    "a single request exceeds the KV pool; cannot preempt "
                    "further")
            self._preempt(victim)
