"""Serving scheduler: admission, chunked-prefill budgeting, preemption.

The mechanism half of the serving control plane — the *policy* half lives in
``repro.serving.policy``: admission order and preemption-victim choice are
injected strategy objects, never hardcoded branches here (the same split the
operator registry gives kernels: this module is the resolver-user, not the
decider).

Per engine step the scheduler:

  1. **Compacts slots**: a long-lived request sitting on a high slot is
     remapped down into a freed lower slot, so the engine's power-of-two
     active-slot bucket can shrink back after a burst drains.
  2. **Admits** waiting requests in the admission policy's order while a
     batch slot is free and the allocator can hold the whole prompt
     (prefix-cached blocks are adopted at admission and don't count against
     free space).  Head-of-line semantics are per policy: if the policy's
     top pick does not fit, admission stops — no queue-jumping past it.
  3. **Budgets tokens**: every DECODING request always gets its decode
     lane — plus, under speculative decoding, one lane per drafted token
     (``StepPlan.spec``), charged against the per-step token budget ahead
     of prefill; PREFILLING requests share what remains of the budget
     (``token_budget``, vLLM's ``max_num_batched_tokens`` analogue) so long
     prompts are chunked across steps instead of stalling the decode batch.
  4. **Preempts under block pressure**: if the step's block demand (new
     decode/draft blocks + prefill-chunk blocks + copy-on-write copies)
     exceeds the pool, speculative drafts are shed first (losing a step's
     speedup beats recomputing a victim's KV); then the preemption policy's
     top-ranked victim is evicted — its blocks are released and it
     re-queues for recompute-style resume (see ``repro.serving.request``).
     The policy's least-preemptable request is never evicted, so one
     request always makes progress.

The scheduler owns the request queues and the slot free-list; it never
touches device state.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core.paged_kv import BlockAllocator, OutOfBlocksError
from repro.serving import policy as policy_lib
from repro.serving.request import Request, RequestState


@dataclass
class StepPlan:
    """What the engine should run this step.

    ``spec`` maps a DECODING request's id to its drafted tokens for this
    step (speculative decoding): that request's lane count is ``1 +
    len(spec[req_id])`` instead of 1, and the extra lanes were budgeted by
    the scheduler (block demand AND token budget) like prefill chunks.
    """

    decode: List[Request] = field(default_factory=list)
    prefill: List[Tuple[Request, int]] = field(default_factory=list)  # (req, n)
    spec: Dict[int, "np.ndarray"] = field(default_factory=dict)

    def decode_tokens(self, req: Request) -> int:
        """Lane count of one decode request: 1 + its drafted tokens."""
        return 1 + len(self.spec.get(req.req_id, ()))

    @property
    def num_tokens(self) -> int:
        return (sum(self.decode_tokens(r) for r in self.decode)
                + sum(n for _, n in self.prefill))


class Scheduler:
    def __init__(self, alloc: BlockAllocator, *, max_batch: int,
                 token_budget: int,
                 admission: Optional[policy_lib.AdmissionPolicy] = None,
                 preemption: Optional[policy_lib.PreemptionPolicy] = None):
        self.alloc = alloc
        self.max_batch = max_batch
        self.token_budget = max(1, token_budget)
        self.admission = admission or policy_lib.resolve(policy_lib.ADMISSION)
        self.preemption = (preemption
                           or policy_lib.resolve(policy_lib.PREEMPTION))
        self.waiting: Deque[Request] = deque()
        self.running: Dict[int, Request] = {}
        self.free_slots: List[int] = list(range(max_batch - 1, -1, -1))
        self.num_preemptions = 0
        self.num_slot_compactions = 0
        self.num_spec_sheds = 0      # draft sets dropped under block pressure

    # ------------------------------------------------------------------ queue
    def submit(self, req: Request) -> None:
        assert req.state is RequestState.WAITING, req.state
        self.waiting.append(req)

    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    # ------------------------------------------------------------------ slots
    def _compact_slots(self) -> None:
        """Remap running requests into freed lower slots (highest first).

        Slot ids only live in the host-built per-step arrays, so moving a
        request between steps is free — and ``max(slot) + 1`` is what the
        engine buckets to a power of two, so shrinking it shrinks the
        compiled program the next step runs.
        """
        if not self.free_slots:
            return
        # Sort even with nothing running: release() appends in finish order,
        # and admission pops from the end — unsorted, a fresh wave after a
        # drained burst would land on high slots and re-inflate the bucket.
        self.free_slots.sort(reverse=True)          # lowest slot at pop() end
        for req in sorted(self.running.values(),
                          key=lambda r: r.slot, reverse=True):
            low = self.free_slots[-1]
            if low >= req.slot:
                break                               # nobody below can improve
            self.free_slots[-1] = req.slot          # swap: give back the high
            req.slot = low
            self.free_slots.sort(reverse=True)
            self.num_slot_compactions += 1

    # -------------------------------------------------------------- admission
    def _admit(self) -> None:
        now = time.time()
        while self.waiting and self.free_slots:
            req = self.admission.select(self.waiting, now)
            # resume prompt includes generated tokens (recompute preemption)
            active = req.resume_tokens()
            bs = self.alloc.block_size
            cached = self.alloc.peek_prefix(active)
            total_blocks = max(1, -(-len(active) // bs))
            fresh = max(total_blocks - cached // bs, 0) + 1  # +1 decode slack
            if self.alloc.num_free < fresh:
                # Livelock breaker: the whole pool is free and still too
                # small — this request (e.g. one whose resume prompt grew
                # past the pool after preemption) will NEVER be admittable,
                # and as the policy's head-of-line it would starve everyone
                # behind it. Fail loudly instead of spinning.
                if (not self.running
                        and self.alloc.num_free == self.alloc.num_blocks):
                    raise OutOfBlocksError(
                        f"request {req.req_id} needs {fresh} blocks but the "
                        f"whole pool is only {self.alloc.num_blocks}")
                break                     # policy head-of-line: no jumping
            self.waiting.remove(req)
            slot = self.free_slots.pop()
            cached = self.alloc.allocate_prefix(req.req_id, active)
            req.begin_prefill(slot, cached, active_prompt=active)
            self.running[req.req_id] = req
            self.admission.on_admit(req, now)

    # -------------------------------------------------------------- capacity
    def _blocks_needed(self, plan: StepPlan) -> int:
        """Exact pool demand of the plan: new blocks + copy-on-write copies.

        A shared physical block written by several plan members costs
        ``min(#writers, refcount - 1)`` copies, not one per writer: each CoW
        drops the refcount, and the last writer at refcount 1 writes in
        place.
        """
        bs = self.alloc.block_size
        need = 0
        cow_writers: Dict[int, int] = {}     # physical block -> plan writers

        def span(req: Request, n: int) -> int:
            """New blocks + CoW writers for ``n`` tokens appended to req."""
            pos = self.alloc.seq_len(req.req_id)
            table = self.alloc.table(req.req_id)
            last_bi = (pos + n - 1) // bs
            fresh = max(last_bi + 1 - len(table), 0)         # new blocks
            for bi in range(pos // bs, min(last_bi, len(table) - 1) + 1):
                if self.alloc.ref_count(table[bi]) > 1:
                    cow_writers[table[bi]] = cow_writers.get(table[bi], 0) + 1
            return fresh

        for req in plan.decode:
            # a speculative decode lane appends 1 + K draft tokens, all of
            # which need reserved (possibly fresh / CoW'd) write slots
            need += span(req, plan.decode_tokens(req))
        for req, n in plan.prefill:
            need += span(req, n)
        for blk, writers in cow_writers.items():
            need += min(writers, self.alloc.ref_count(blk) - 1)
        return need

    def _pick_victim(self, now: float) -> Optional[Request]:
        """The preemption policy's top-ranked victim.

        The bottom of the ranking (least preemptable) is protected: with
        fewer than two running requests there is no victim, which guarantees
        at least one request keeps making progress.
        """
        ranked = self.preemption.rank(list(self.running.values()),
                                      self.alloc, now)
        if len(ranked) < 2:
            return None
        return ranked[0]

    def release(self, req: Request) -> None:
        """Return a running request's blocks and slot (finish or preempt)."""
        self.alloc.free(req.req_id)
        del self.running[req.req_id]
        self.free_slots.append(req.slot)

    def detach(self, req: Request) -> None:
        """Remove a running request KEEPING its blocks (prefill→decode
        handoff): the slot returns to the free list but the allocator table
        stays live — the caller owns the blocks and must ``alloc.free`` the
        request id once the transfer is done."""
        del self.running[req.req_id]
        self.free_slots.append(req.slot)
        req.slot = -1

    def _preempt(self, req: Request) -> None:
        self.preemption.on_preempt(req, self.alloc)   # table still live here
        self.release(req)
        req.preempt()
        self.waiting.appendleft(req)
        self.num_preemptions += 1

    # ------------------------------------------------------------------- plan
    def schedule(self, spec_drafts: Optional[Dict[int, "np.ndarray"]] = None
                 ) -> StepPlan:
        """Compact, admit, budget prefill chunks, preempt until the plan
        fits.

        ``spec_drafts`` (speculative decoding) maps req_id -> drafted tokens
        for DECODING requests; each draft widens its request's lane count to
        ``1 + K``.  Draft lanes are charged against the step token budget
        ahead of prefill chunks and TRIMMED to it — total lanes stay within
        ``#decode + token_budget``, the same bound the non-spec scheduler
        gives — with half the budget held back for prefill whenever a
        PREFILLING request is waiting on chunks, so speculation can slow
        prefill but never starve it.  Drafts are also charged exact block
        demand like any other appended token; a draft whose request gets
        preempted in the fit loop is simply dropped.
        """
        self._compact_slots()
        self._admit()
        # Same-wave prefix dedup: a mid-prefill request whose next blocks
        # were published since last step (by a same-prompt donor, possibly
        # itself still prefilling — the KV-written watermark is the proof of
        # completeness) fast-forwards over them instead of recomputing.
        for req in self.running.values():
            if req.state is RequestState.PREFILLING:
                adopted = self.alloc.extend_prefix(req.req_id,
                                                   req.active_prompt)
                if adopted:
                    req.prefill_pos += adopted
        spec_drafts = spec_drafts or {}
        while True:
            plan = StepPlan()
            budget = self.token_budget
            prefill_pending = any(r.state is RequestState.PREFILLING
                                  for r in self.running.values())
            spec_budget = budget // 2 if prefill_pending else budget
            for req in self.running.values():
                if req.state is RequestState.DECODING:
                    if len(req.output) >= req.max_new_tokens:
                        # Provisionally complete: the async engine already
                        # committed this request's final token (value still
                        # in flight) — no further lanes; it finishes when
                        # its device future resolves.
                        continue
                    plan.decode.append(req)
                    draft = spec_drafts.get(req.req_id)
                    if draft is not None and spec_budget > 0:
                        take = min(len(draft), spec_budget)
                        if take > 0:
                            plan.spec[req.req_id] = draft[:take]
                            spec_budget -= take
            # speculative lanes consume token budget before prefill chunks
            budget = max(budget - sum(len(d) for d in plan.spec.values()), 0)
            for req in self.running.values():
                if req.state is RequestState.PREFILLING and budget > 0:
                    n = min(req.prefill_remaining, budget)
                    if n > 0:
                        plan.prefill.append((req, n))
                        budget -= n
            if self._blocks_needed(plan) <= self.alloc.num_free:
                return plan
            if plan.spec:
                # Shed optional work first: dropping drafts costs one step's
                # speedup; preempting a request throws away computed KV.
                spec_drafts = {}
                self.num_spec_sheds += 1
                continue
            victim = self._pick_victim(now=time.time())
            if victim is None:
                raise OutOfBlocksError(
                    "a single request exceeds the KV pool; cannot preempt "
                    "further")
            self._preempt(victim)
