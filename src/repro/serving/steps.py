"""Serving step builders: prefill and decode, fully sharded (GSPMD).

Decode uses the sequence-sharded contiguous cache (flash-decoding via GSPMD:
the softmax reductions over the model-sharded seq dim lower to tiny (B,H)
all-reduces). The paged shard_map path (the paper's technique) lives in
``repro.serving.engine`` and ``core.attention_api.paged_attention_sharded``.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import dispatch


def make_prefill_step(model, *, backend: Optional[str] = None):
    """(params, batch) -> last-position logits (B, V).

    Full-sequence forward; only the final position is unembedded so prefill
    never materializes (B, S, V) logits (a 637 GB tensor for 32k×152k).
    ``backend`` scopes any registry-dispatched ops resolved during the
    trace. NOTE: today the dense GSPMD forward/decode paths are pure jnp
    (no registry ops), so this is forward-compatibility plumbing — the
    paged engine path is the one that dispatches through the registry.
    """
    def step(params, batch):
        with dispatch.force_backend(backend):
            logits, _ = model.forward(params, batch["tokens"],
                                      batch.get("extra_embeds"),
                                      last_only=True)
        return logits[:, 0]
    return step


def make_serve_step(model, *, greedy: bool = True,
                    backend: Optional[str] = None):
    """(params, cache, tokens) -> (next_tokens, cache). One decode step."""
    def step(params, cache, tokens):
        with dispatch.force_backend(backend):
            logits, cache = model.decode_step(params, cache, tokens)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt, cache
    return step


def jit_prefill_step(model, mesh, rules, params_shape, batch_shape):
    step = make_prefill_step(model)
    p_spec = rules.params_tree(params_shape)
    b_spec = jax.tree.map(lambda s: rules.batch_spec(s.shape), batch_shape)
    named = partial(jax.tree.map, lambda sp: NamedSharding(mesh, sp))
    return jax.jit(step, in_shardings=(named(p_spec), named(b_spec)))


def jit_serve_step(model, mesh, rules, params_shape, cache_shape,
                   tokens_shape, donate: bool = True):
    step = make_serve_step(model)
    p_spec = rules.params_tree(params_shape)
    c_spec = rules.cache_tree(cache_shape)
    t_spec = rules.batch_spec(tokens_shape.shape)
    named = partial(jax.tree.map, lambda sp: NamedSharding(mesh, sp),)
    in_sh = (named(p_spec), named(c_spec), NamedSharding(mesh, t_spec))
    out_sh = (NamedSharding(mesh, t_spec), named(c_spec))
    return jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                   donate_argnums=(1,) if donate else ())


def abstract_cache(model, batch: int, max_seq: int) -> Any:
    return jax.eval_shape(lambda: model.init_decode_cache(batch, max_seq))
