"""Continuous-batching serving engine over the paged KV cache.

The runtime realization of the paper's §4.2 vLLM case study:
  * requests arrive with a prompt; the scheduler admits them when the
    BlockAllocator has room (paged, on-demand — no pre-allocation);
  * every engine step runs ONE fused decode for all active requests through
    ``decode_step_paged`` with the flat **BlockList** — the paper's
    optimization, end-to-end;
  * slot-stable batching: the decode program is compiled ONCE for
    (max_batch, max_total_blocks); requests map onto fixed slots, inactive
    slots carry zero-length sequences (dropped by the segment ops) — no
    retrace, no recompile, exactly vLLM's persistent-batch trick;
  * prefill is a single teacher-forced forward whose per-layer K/V are
    scattered into the request's pool blocks in bulk (block-aligned pad);
  * finished requests free their blocks immediately (dynamic reuse);
  * TTFT / TPOT per request (paper Fig 17e metrics).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, ServeConfig
from repro.core.paged_kv import (
    BlockAllocator, gather_prefill_into_pool, make_pool)


@dataclass
class Request:
    req_id: int
    prompt: np.ndarray                  # (prompt_len,) int32
    max_new_tokens: int
    arrival: float = field(default_factory=time.time)
    first_token_at: Optional[float] = None
    done_at: Optional[float] = None
    output: List[int] = field(default_factory=list)
    slot: int = -1

    @property
    def ttft(self) -> Optional[float]:
        return (self.first_token_at - self.arrival
                if self.first_token_at else None)

    @property
    def tpot(self) -> Optional[float]:
        if self.done_at is None or self.first_token_at is None:
            return None
        n = max(len(self.output) - 1, 1)
        return (self.done_at - self.first_token_at) / n


class ServingEngine:
    def __init__(self, model, params, cfg: ModelConfig, serve: ServeConfig,
                 *, num_blocks: Optional[int] = None, eos_id: int = -1):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.serve = serve
        self.eos_id = eos_id
        bs = serve.kv_block_size
        nb = num_blocks or serve.max_blocks or serve.max_batch * 64
        a = cfg.attention
        self.alloc = BlockAllocator(num_blocks=nb, block_size=bs)
        pk, pv = make_pool(cfg.num_layers, nb, bs, a.num_kv_heads, a.head_dim,
                           jnp.dtype(cfg.dtype))
        self.pools = {"k": pk, "v": pv}
        self.waiting: List[Request] = []
        self.active: Dict[int, Request] = {}
        self.finished: List[Request] = []
        self.B = serve.max_batch
        self.max_total = nb
        self._free_slots = list(range(self.B - 1, -1, -1))
        self._decode = jax.jit(model.decode_step_paged)
        self._prefill_fwd = jax.jit(
            lambda p, t: model.forward(p, t, return_kv=True, last_only=True))

    # ------------------------------------------------------------- lifecycle
    def submit(self, req: Request) -> None:
        self.waiting.append(req)

    def _try_admit(self) -> None:
        admitted = []
        for req in self.waiting:
            need = -(-len(req.prompt) // self.alloc.block_size) + 1
            if not self._free_slots or self.alloc.num_free < need:
                break  # FCFS
            req.slot = self._free_slots.pop()
            self.alloc.allocate(req.req_id, len(req.prompt))
            self._bulk_prefill(req)
            self.active[req.req_id] = req
            admitted.append(req)
        for req in admitted:
            self.waiting.remove(req)

    def _bulk_prefill(self, req: Request) -> None:
        """One forward pass; scatter per-layer K/V into the pool blocks."""
        bs = self.alloc.block_size
        P = len(req.prompt)
        S_pad = -(-P // bs) * bs
        toks = np.zeros((1, S_pad), np.int32)
        toks[0, :P] = req.prompt
        logits, _, kvs = self._prefill_fwd(self.params, jnp.asarray(toks))
        # NOTE: last_only logits are at padded pos -1; recompute next token
        # from position P-1 via the decode path would cost a step — instead
        # prefill uses exact-length last position: take logits of pos P-1
        # by re-running unembed is avoided: we pad on the RIGHT, so use the
        # stacked kvs (valid for :P) and compute the first token by a decode
        # step over the cached prompt (standard chunked-prefill handoff).
        k_seq, v_seq = kvs                      # (L, 1, S_pad, KV, HD)
        table = np.asarray(self.alloc.table(req.req_id), np.int32)[None]
        pk, pv = self.pools["k"], self.pools["v"]
        scatter = jax.vmap(
            lambda pool_l, seq_l: gather_prefill_into_pool(
                pool_l, seq_l, jnp.asarray(table), S_pad, bs))
        self.pools = {"k": scatter(pk, k_seq), "v": scatter(pv, v_seq)}
        # overwrite allocator length to the true prompt length
        self.alloc._lens[req.req_id] = P
        # first output token via one decode step on this request alone
        nxt = self._single_decode(req, int(req.prompt[-1]))
        req.first_token_at = time.time()
        req.output.append(nxt)

    def _single_decode(self, req: Request, token: int) -> int:
        """Used only at the prefill→decode handoff (batch of 1 slot)."""
        # rewind length by one so the last prompt token is 're-decoded'
        self.alloc._lens[req.req_id] -= 1
        lists, tokens = self._build_lists({req.req_id: req}, {req.req_id: token})
        logits, self.pools = self._decode(self.params, self.pools, lists,
                                          tokens)
        self.alloc.commit_token(req.req_id)
        return int(jnp.argmax(logits[req.slot]))

    def _build_lists(self, reqs: Dict[int, Request],
                     tokens_by_rid: Dict[int, int]):
        B = self.B
        slots = np.full((B, 2), [self.alloc.num_blocks, 0], np.int32)
        seq_lens = np.zeros((B,), np.int32)
        tokens = np.zeros((B,), np.int32)
        bl = np.zeros((self.max_total,), np.int32)
        br = np.full((self.max_total,), B, np.int32)
        bp = np.zeros((self.max_total,), np.int32)
        cursor = 0
        for rid, req in sorted(reqs.items()):
            blk, off = self.alloc.reserve_slot(rid)
            slots[req.slot] = (blk, off)
            seq_lens[req.slot] = self.alloc.seq_len(rid)
            tokens[req.slot] = tokens_by_rid[rid]
            table = self.alloc.table(rid)
            n = len(table)
            bl[cursor:cursor + n] = table
            br[cursor:cursor + n] = req.slot
            bp[cursor:cursor + n] = np.arange(n)
            cursor += n
        lists = {
            "block_list": jnp.asarray(bl), "block_req": jnp.asarray(br),
            "block_pos": jnp.asarray(bp), "seq_lens": jnp.asarray(seq_lens),
            "slots": jnp.asarray(slots),
        }
        return lists, jnp.asarray(tokens)

    # ------------------------------------------------------------- main loop
    def step(self) -> int:
        """One engine iteration: admit + fused batched decode."""
        self._try_admit()
        if not self.active:
            return 0
        toks = {rid: r.output[-1] for rid, r in self.active.items()}
        lists, tokens = self._build_lists(self.active, toks)
        logits, self.pools = self._decode(self.params, self.pools, lists,
                                          tokens)
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        now = time.time()
        stepped = len(self.active)
        for rid in list(self.active):
            req = self.active[rid]
            self.alloc.commit_token(rid)
            tok = int(nxt[req.slot])
            req.output.append(tok)
            if (len(req.output) >= req.max_new_tokens or tok == self.eos_id):
                req.done_at = now
                self.alloc.free(rid)
                self._free_slots.append(req.slot)
                del self.active[rid]
                self.finished.append(req)
        return stepped

    def run_until_done(self, max_steps: int = 100_000) -> None:
        for _ in range(max_steps):
            if not self.waiting and not self.active:
                return
            self.step()
        raise RuntimeError("serving did not converge")

    # --------------------------------------------------------------- metrics
    def metrics(self) -> Dict[str, float]:
        ttfts = [r.ttft for r in self.finished if r.ttft is not None]
        tpots = [r.tpot for r in self.finished if r.tpot is not None]
        toks = sum(len(r.output) for r in self.finished)
        return {
            "finished": len(self.finished),
            "output_tokens": toks,
            "mean_ttft_s": float(np.mean(ttfts)) if ttfts else 0.0,
            "mean_tpot_s": float(np.mean(tpots)) if tpots else 0.0,
            "blocks_free": self.alloc.num_free,
        }
