"""Scheduler-driven serving engine over the paged KV cache.

The runtime realization of the paper's §4.2 vLLM case study, split into the
three layers of a production serving stack:

  * ``repro.serving.request``   — per-request state machine (WAITING ->
    PREFILLING -> DECODING -> PREEMPTED -> FINISHED) + sampling params;
  * ``repro.serving.scheduler`` — admission (prefix-cache aware), chunked-
    prefill token budgeting, preemption under block pressure — with the
    actual decisions (admission order, victim choice, cached-block eviction)
    delegated to registered strategies from ``repro.serving.policy``;
  * this module                 — the jit'd step driver: it renders each
    :class:`StepPlan` into ONE fused device program
    (``model.decode_tokens_paged`` + batched per-request sampling).

Step anatomy (the paper's BlockList optimization, end-to-end):

  * every step runs a single fused program over flat token lanes: one lane
    per decoding request plus up to ``token_budget`` prompt-chunk lanes from
    prefilling requests — chunked prefill never stalls the decode batch and
    there is no separate prefill program;
  * lane counts are bucketed to powers of two, so the engine compiles
    O(log max_tokens) programs total (slot-stable shapes everywhere else:
    block lists are padded to the pool size, sampling inputs to max_batch);
  * prompt prefixes shared across requests reuse pool blocks via the
    allocator's prefix cache (refcounted, copy-on-write on append) — a
    shared-prefix workload allocates strictly fewer blocks than independent
    prompts and skips recomputing the shared KV;
  * under block pressure the scheduler preempts the policy-ranked victim
    (recompute-style: its blocks are freed, generation state survives);
  * finished requests free their blocks immediately; hashed blocks are
    parked cached-free for future prefix hits, evicted by the registered
    eviction policy when the pool runs dry;
  * TTFT / TPOT percentiles, throughput, preemption and prefix-hit counters
    via ``repro.serving.metrics`` (paper Fig 17e metrics).
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, ServeConfig
from repro.core import dispatch
from repro.core.paged_kv import (
    BlockAllocator, copy_pool_blocks, make_pool)
from repro.serving import policy as policy_lib
from repro.serving import sampling as sampling_lib
from repro.serving.metrics import EngineMetrics
from repro.serving.request import Request, RequestState, SamplingParams
from repro.serving.scheduler import Scheduler, StepPlan

__all__ = ["Request", "RequestState", "SamplingParams", "ServingEngine"]


def _bucket(n: int, lo: int = 8) -> int:
    """Round lane count up to a power of two (bounded jit-cache growth)."""
    b = lo
    while b < n:
        b *= 2
    return b


class ServingEngine:
    def __init__(self, model, params, cfg: ModelConfig, serve: ServeConfig,
                 *, num_blocks: Optional[int] = None, eos_id: int = -1,
                 token_budget: Optional[int] = None, seed: int = 0,
                 admission=None, preemption=None, eviction=None):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.serve = serve
        self.eos_id = eos_id
        bs = serve.kv_block_size
        nb = num_blocks or serve.max_blocks or serve.max_batch * 64
        a = cfg.attention
        # Resolve the serving-policy triple ONCE through the policy registry
        # (explicit ctor args > force_policies scope > ServeConfig > default)
        # and pin it for the run — like the attention backend below, metrics
        # are attributable to exactly one admission/preemption/eviction
        # combination.
        adm, pre, evi = policy_lib.resolve_triple(
            admission=admission, preemption=preemption, eviction=eviction,
            config=serve)
        self.policies = {axis: p.name for axis, p in
                         ((policy_lib.ADMISSION, adm),
                          (policy_lib.PREEMPTION, pre),
                          (policy_lib.EVICTION, evi))}
        self._policy_objs = (adm, pre, evi)
        self.alloc = BlockAllocator(num_blocks=nb, block_size=bs,
                                    eviction_policy=evi)
        pk, pv = make_pool(cfg.num_layers, nb, bs, a.num_kv_heads, a.head_dim,
                           jnp.dtype(cfg.dtype))
        self.pools = {"k": pk, "v": pv}
        self.B = serve.max_batch
        self.max_total = nb
        self.scheduler = Scheduler(
            self.alloc, max_batch=self.B,
            token_budget=token_budget or serve.prefill_chunk,
            admission=adm, preemption=pre)
        self._free_slots = self.scheduler.free_slots    # shared list object
        self.finished: List[Request] = []
        # Resolve the hot-path attention backend ONCE through the unified
        # registry (ServeConfig.backend is the config-precedence level; env /
        # force_backend scopes still win, explicit args would win over both).
        # The resolved name is pinned for every step so perf numbers are
        # attributable to one implementation, and exposed via metrics().
        self.attn_backend = dispatch.resolve(
            "paged_attention_chunked", config=serve.backend).backend
        self._metrics = EngineMetrics(backend=self.attn_backend)
        self._key = jax.random.PRNGKey(seed)
        self._step_count = 0
        attn_backend = self.attn_backend

        def fused(params, pools, lists, tokens, key, temps, top_ks, top_ps):
            logits, pools = model.decode_tokens_paged(
                params, pools, lists, tokens, attn_backend=attn_backend)
            nxt = sampling_lib.sample_batched(key, logits, temps, top_ks,
                                              top_ps)
            return nxt, pools

        self._step_fn = jax.jit(fused)

    # -------------------------------------------------------------- lifecycle
    def submit(self, req: Request) -> None:
        if len(req.prompt) == 0:
            raise ValueError(f"request {req.req_id}: empty prompt")
        # KV is written for the prompt and all generated tokens except the
        # last (sampling it finishes the request before its KV lands); the
        # scheduler additionally wants one slack block at admission.
        bs = self.alloc.block_size
        positions = len(req.prompt) + max(req.max_new_tokens - 1, 0)
        worst = max(-(-positions // bs), -(-len(req.prompt) // bs) + 1)
        if worst > self.alloc.num_blocks:
            raise ValueError(
                f"request {req.req_id} can never fit: needs up to {worst} "
                f"blocks, pool has {self.alloc.num_blocks}")
        self.scheduler.submit(req)

    @property
    def waiting(self) -> List[Request]:
        return list(self.scheduler.waiting)

    @property
    def active(self) -> Dict[int, Request]:
        return self.scheduler.running

    # ------------------------------------------------------------- step build
    def _render(self, plan: StepPlan):
        """Render a StepPlan into the fused program's input arrays."""
        alloc, B = self.alloc, self.B
        T = _bucket(plan.num_tokens)
        # Slot-keyed arrays (sampling knobs, kv lens, logit lanes) are sized
        # to a power-of-two bucket of the ACTIVE slots, not max_batch — the
        # same bucketing as token lanes, so a lightly loaded engine samples
        # over 8 lanes instead of max_batch. Slots are allocated low-first,
        # so max(slot)+1 tracks the live batch closely.
        reqs = list(plan.decode) + [req for req, _ in plan.prefill]
        Bs = min(_bucket(1 + max(req.slot for req in reqs)), B)
        tokens = np.zeros((T,), np.int32)
        token_req = np.full((T,), Bs, np.int32)         # Bs == padding lane
        token_pos = np.zeros((T,), np.int32)
        slots = np.full((T, 2), (self.max_total, 0), np.int32)  # dropped write
        last_lane = np.zeros((Bs,), np.int32)
        kv_lens = np.zeros((Bs,), np.int32)
        temps = np.zeros((Bs,), np.float32)
        top_ks = np.zeros((Bs,), np.int32)
        top_ps = np.ones((Bs,), np.float32)
        lane = 0
        committed: List[tuple] = []                     # (req, n_tokens)
        for req in plan.decode:
            rid = req.req_id
            pos = alloc.seq_len(rid)
            s = alloc.reserve_tokens(rid, 1)
            tokens[lane] = req.output[-1]
            token_req[lane] = req.slot
            token_pos[lane] = pos
            slots[lane] = s[0]
            last_lane[req.slot] = lane
            kv_lens[req.slot] = pos + 1
            lane += 1
            committed.append((req, 1))
        for req, n in plan.prefill:
            rid = req.req_id
            pos0 = alloc.seq_len(rid)
            ss = alloc.reserve_tokens(rid, n)
            chunk = req.active_prompt[pos0:pos0 + n]
            tokens[lane:lane + n] = chunk
            token_req[lane:lane + n] = req.slot
            token_pos[lane:lane + n] = pos0 + np.arange(n)
            slots[lane:lane + n] = ss
            last_lane[req.slot] = lane + n - 1
            kv_lens[req.slot] = pos0 + n
            lane += n
            committed.append((req, n))
        for req, _ in committed:
            temps[req.slot] = req.sampling.temperature
            top_ks[req.slot] = req.sampling.top_k
            top_ps[req.slot] = req.sampling.top_p
        # Block lists AFTER reservations (tables may have grown / CoW'd).
        # A prefix-shared block is effectual for EVERY holder, so the entry
        # count can exceed the pool size — bucket the capacity like T.
        tables = {req.req_id: alloc.table(req.req_id) for req, _ in committed}
        needed = sum(len(t) for t in tables.values())
        cap = (self.max_total if needed <= self.max_total
               else _bucket(needed, lo=self.max_total))
        bl = np.zeros((cap,), np.int32)
        br = np.full((cap,), Bs, np.int32)
        bp = np.zeros((cap,), np.int32)
        cursor = 0
        for req, _ in committed:
            table = tables[req.req_id]
            n = len(table)
            bl[cursor:cursor + n] = table
            br[cursor:cursor + n] = req.slot
            bp[cursor:cursor + n] = np.arange(n)
            cursor += n
        lists = {
            "block_list": jnp.asarray(bl), "block_req": jnp.asarray(br),
            "block_pos": jnp.asarray(bp), "kv_lens": jnp.asarray(kv_lens),
            "token_req": jnp.asarray(token_req),
            "token_pos": jnp.asarray(token_pos),
            "slots": jnp.asarray(slots),
            "last_lane": jnp.asarray(last_lane),
        }
        sample_args = (jnp.asarray(temps), jnp.asarray(top_ks),
                       jnp.asarray(top_ps))
        return lists, jnp.asarray(tokens), sample_args, committed

    # -------------------------------------------------------------- main loop
    def step(self) -> int:
        """One engine iteration: schedule + ONE fused chunked-prefill/decode
        program + host-side lifecycle updates. Returns #tokens processed."""
        plan = self.scheduler.schedule()
        if plan.num_tokens == 0:
            return 0
        lists, tokens, sample_args, committed = self._render(plan)
        # apply copy-on-write block copies before the step touches the pool
        copies = self.alloc.drain_copies()
        if copies:
            srcs = jnp.asarray([s for s, _ in copies], jnp.int32)
            dsts = jnp.asarray([d for _, d in copies], jnp.int32)
            self.pools = {k: copy_pool_blocks(p, srcs, dsts)
                          for k, p in self.pools.items()}
        self._step_count += 1
        key = jax.random.fold_in(self._key, self._step_count)
        nxt, self.pools = self._step_fn(self.params, self.pools, lists,
                                        tokens, key, *sample_args)
        nxt = np.asarray(nxt)
        now = time.time()
        for req, n in committed:
            self.alloc.commit_tokens(req.req_id, n)
        for req, n in committed:
            if req.state is RequestState.DECODING:
                self._append_token(req, int(nxt[req.slot]), now)
            else:                                       # prefill chunk
                start = req.prefill_pos
                req.prefill_pos += n
                self.alloc.register_prefix(req.req_id, req.active_prompt,
                                           req.prefill_pos, start=start)
                if req.prefill_remaining == 0:
                    req.to_state(RequestState.DECODING)
                    if req.first_token_at is None:
                        req.first_token_at = now
                    self._append_token(req, int(nxt[req.slot]), now)
        return plan.num_tokens

    def _append_token(self, req: Request, tok: int, now: float) -> None:
        req.output.append(tok)
        if len(req.output) >= req.max_new_tokens or tok == self.eos_id:
            self._finish(req, now)

    def _finish(self, req: Request, now: float) -> None:
        self.scheduler.release(req)
        req.finish(now)
        self.finished.append(req)
        self._metrics.record_finished(
            ttft=req.ttft, tpot=req.tpot, num_output_tokens=len(req.output),
            arrival=req.arrival, done_at=now)

    def run_until_done(self, max_steps: int = 100_000) -> None:
        for _ in range(max_steps):
            if not self.scheduler.has_work():
                return
            self.step()
        raise RuntimeError("serving did not converge")

    # --------------------------------------------------------------- metrics
    def metrics(self) -> Dict[str, float]:
        m = self._metrics.summary()
        hits, misses = self.alloc.prefix_hits, self.alloc.prefix_misses
        m.update({
            "blocks_free": self.alloc.num_free,
            "preemptions": self.scheduler.num_preemptions,
            "slot_compactions": self.scheduler.num_slot_compactions,
            "prefix_hits": hits,
            "prefix_misses": misses,
            "prefix_hit_rate": hits / (hits + misses) if hits + misses else 0.0,
            "cow_copies": self.alloc.cow_copies,
        })
        # The resolved policy triple the run executed with, plus each
        # policy's own counters (admitted / victims / evictions / ...) keyed
        # "<axis>.<counter>" — rows from a --policy sweep are attributable to
        # one admission/preemption/eviction combination.
        for axis, name in self.policies.items():
            m[f"{axis}_policy"] = name
        m["policy_counters"] = {
            f"{p.axis}.{k}": v
            for p in self._policy_objs for k, v in sorted(p.counters.items())}
        return m
