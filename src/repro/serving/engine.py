"""Scheduler-driven serving engine over the paged KV cache.

The runtime realization of the paper's §4.2 vLLM case study, split into the
three layers of a production serving stack:

  * ``repro.serving.request``   — per-request state machine (WAITING ->
    PREFILLING -> DECODING -> PREEMPTED -> FINISHED) + sampling params;
  * ``repro.serving.scheduler`` — admission (prefix-cache aware), chunked-
    prefill token budgeting, preemption under block pressure — with the
    actual decisions (admission order, victim choice, cached-block eviction)
    delegated to registered strategies from ``repro.serving.policy``;
  * this module                 — the jit'd step driver: it renders each
    :class:`StepPlan` into ONE fused device program
    (``model.decode_tokens_paged`` + batched per-request sampling).

Step anatomy (the paper's BlockList optimization, end-to-end):

  * every step runs a single fused program over flat token lanes: one lane
    per decoding request plus up to ``token_budget`` prompt-chunk lanes from
    prefilling requests — chunked prefill never stalls the decode batch and
    there is no separate prefill program;
  * lane counts are bucketed to powers of two, so the engine compiles
    O(log max_tokens) programs total (slot-stable shapes everywhere else:
    block lists are padded to the pool size, sampling inputs to max_batch);
  * prompt prefixes shared across requests reuse pool blocks via the
    allocator's prefix cache (refcounted, copy-on-write on append) — a
    shared-prefix workload allocates strictly fewer blocks than independent
    prompts and skips recomputing the shared KV;
  * under block pressure the scheduler preempts the policy-ranked victim
    (recompute-style: its blocks are freed, generation state survives);
  * finished requests free their blocks immediately; hashed blocks are
    parked cached-free for future prefix hits, evicted by the registered
    eviction policy when the pool runs dry;
  * full blocks produced during DECODE are hash-registered too (not just
    prompt prefill), so preemption-resume recompute and repeated
    prompt+generation prefixes hit the cache;
  * with a registered speculative proposer (``repro.serving.spec``), each
    decoding request's step carries its last token plus K drafted tokens
    through the SAME fused program — the chunked attention grid already
    handles multi-token queries — followed by a batched rejection-accept
    (``verify_batched``) that emits the longest accepted prefix + one
    corrected/bonus token and rewinds speculatively reserved KV blocks;
  * TTFT / TPOT percentiles, throughput, preemption / prefix-hit /
    speculation counters and per-step-phase timing buckets via
    ``repro.serving.metrics`` (paper Fig 17e metrics);
  * with a ``mesh`` (built via ``repro.launch.mesh``), the SAME engine runs
    mesh-native: params are TP-sharded by ``distributed.sharding``'s rules,
    the KV pool is sequence-sharded on its block dimension, each layer's
    append + attention runs under shard_map with per-shard local BlockLists
    and a log-sum-exp combine (``paged_attention_chunked_sharded``, pinned
    through the registry as the ``sharded`` backend), and greedy output
    streams stay bit-identical to the single-device engine — the scheduler
    and StepPlan are device-count-agnostic (docs/sharded_serving.md).
"""
from __future__ import annotations

import contextlib
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import sanitize as sanitize_lib
from repro.config import ModelConfig, ServeConfig
from repro.core import dispatch
from repro.core.paged_kv import (
    BlockAllocator, HostPool, copy_pool_blocks, make_fused_pool)
from repro.perf import autotune as autotune_lib
from repro.serving import policy as policy_lib
from repro.serving import sampling as sampling_lib
from repro.serving import spec as spec_lib
from repro.serving import request as request_lib
from repro.serving.metrics import EngineMetrics
from repro.serving.request import Request, RequestState, SamplingParams
from repro.serving.scheduler import Scheduler, StepPlan

__all__ = ["Request", "RequestState", "SamplingParams", "ServingEngine"]


_bucket = request_lib.bucket_pow2      # lane/slot counts -> power-of-two


class _PendingStep:
    """One dispatched-but-unresolved fused step (docs/async_engine.md).

    Built by ``ServingEngine._build``: the plan was rendered, every lane's
    KV slots were reserved AND provisionally committed, each decode-ish
    action appended a placeholder output token, and the fused program was
    dispatched — ``nxt_dev`` is its device-side future.  ``_resolve`` later
    blocks on the future and reconciles: placeholders become real tokens,
    EOS / max_new_tokens finishes fire, and finishes cancel the request's
    in-flight action in the NEXT pending step (if one was already built
    against the provisional state).
    """

    __slots__ = ("actions", "slots", "chain", "nxt_dev", "cancelled",
                 "phases", "num_tokens", "t_dispatch")

    def __init__(self, *, actions, slots, chain, nxt_dev, phases,
                 num_tokens, t_dispatch):
        # actions: (kind, req, n, pos0, out_idx) — kind "decode"/"prefill";
        # out_idx indexes the placeholder in req.output (None: chunk-only
        # prefill, nothing to resolve).  slots: req_id -> slot snapshot at
        # build time (slot compaction may move requests before resolve).
        self.actions = actions
        self.slots = slots
        self.chain = chain
        self.nxt_dev = nxt_dev
        self.cancelled: set = set()
        self.phases = phases
        self.num_tokens = num_tokens
        self.t_dispatch = t_dispatch

    def cancel(self, req) -> None:
        """A resolve finished ``req`` while its next step is in flight:
        drop the in-flight action (allocator state is already freed) and
        pop the provisional placeholder so the output stream ends at the
        real final token."""
        rid = req.req_id
        for kind, r, _n, _pos0, out_idx in self.actions:
            if r.req_id == rid:
                self.cancelled.add(rid)
                if out_idx is not None:
                    assert out_idx == len(req.output) - 1, (rid, out_idx)
                    req.output.pop()
                return


class ServingEngine:
    def __init__(self, model, params, cfg: ModelConfig, serve: ServeConfig,
                 *, num_blocks: Optional[int] = None, eos_id: int = -1,
                 token_budget: Optional[int] = None, seed: int = 0,
                 admission=None, preemption=None, eviction=None,
                 proposer=None, mesh=None, role: str = "full"):
        self.model = model
        self.cfg = cfg
        self.serve = serve
        self.eos_id = eos_id
        # Disaggregated serving (docs/disaggregated.md): a "prefill"-role
        # engine runs prompt prefill only — a request whose last chunk
        # commits is PARKED on ``self.prefilled`` (state stays PREFILLING,
        # blocks stay live) instead of transitioning to DECODING, for the
        # frontend to hand off to a decode-role engine via take_prefilled().
        if role not in ("full", "prefill"):
            raise ValueError(f"unknown engine role {role!r}")
        self.role = role
        self.prefill_only = role == "prefill"
        self.prefilled: List[Request] = []
        # Mesh-native serving: a jax Mesh (repro.launch.mesh) turns every
        # step into the sharded fused program — params TP-sharded via the
        # repo-wide ShardingRules, KV pool sequence-sharded over the model
        # axis, per-layer attention combined across it.  ``None`` falls
        # back to ``ServeConfig.devices`` (the config-level knob; a count
        # the host can't supply raises in make_serving_mesh rather than
        # silently serving single-device), else the single-device engine,
        # byte-for-byte the old behaviour; the scheduler below never sees
        # the difference.
        if mesh is None and serve.devices > 1:
            from repro.launch.mesh import make_serving_mesh
            mesh = make_serving_mesh(model=serve.devices)
        self.mesh = mesh
        self.mesh_axis = serve.parallel.model_axis
        S = int(mesh.shape[self.mesh_axis]) if mesh is not None else 1
        self.shards = S
        bs = serve.kv_block_size
        nb = num_blocks or serve.max_blocks or serve.max_batch * 64
        nb = -(-nb // S) * S            # pool splits into equal shard slices
        a = cfg.attention
        # Resolve the serving-policy triple ONCE through the policy registry
        # (explicit ctor args > force_policies scope > ServeConfig > default)
        # and pin it for the run — like the attention backend below, metrics
        # are attributable to exactly one admission/preemption/eviction
        # combination.
        adm, pre, evi = policy_lib.resolve_triple(
            admission=admission, preemption=preemption, eviction=eviction,
            config=serve)
        self.policies = {axis: p.name for axis, p in
                         ((policy_lib.ADMISSION, adm),
                          (policy_lib.PREEMPTION, pre),
                          (policy_lib.EVICTION, evi))}
        self._policy_objs = (adm, pre, evi)
        self.alloc = BlockAllocator(num_blocks=nb, block_size=bs,
                                    num_shards=S, eviction_policy=evi)
        # Host-memory KV tier (docs/disaggregated.md): evicted cached-free
        # blocks demote into a host LRU (policy-gated) instead of dropping
        # their content; prefix hits promote them back.  The device↔host
        # copies run in sync_pools()' ordered tier drain.
        self.host_pool: Optional[HostPool] = None
        if serve.host_blocks > 0:
            if S > 1:
                raise ValueError(
                    "host KV tier requires an unsharded pool (the demote/"
                    "promote block copies assume single-device block slices)")
            self.host_pool = HostPool(serve.host_blocks)
            self.alloc.host_pool = self.host_pool
        # ONE fused head-interleaved buffer ([K0, V0, K1, V1, ...] on the
        # head axis): the allocator, CoW drain, tier demote/promote and the
        # disagg handoff each move a single pool; the chunked path reads it
        # through split views (repro.core.paged_kv.fused_kv_views).
        self.pools = {"kv": make_fused_pool(
            cfg.num_layers, nb, bs, a.num_kv_heads, a.head_dim,
            jnp.dtype(cfg.dtype))}
        if mesh is not None:
            from jax.sharding import NamedSharding
            from jax.sharding import PartitionSpec as P

            from repro.distributed.sharding import ShardingRules
            rules = ShardingRules(mesh, head_dim=a.head_dim)
            params = jax.device_put(params,
                                    rules.named(rules.params_tree(params)))
            pool_sh = NamedSharding(mesh, P(None, self.mesh_axis))
            self.pools = {k: jax.device_put(v, pool_sh)
                          for k, v in self.pools.items()}
        self.params = params
        self.B = serve.max_batch
        self.max_total = nb
        self.scheduler = Scheduler(
            self.alloc, max_batch=self.B,
            token_budget=token_budget or serve.prefill_chunk,
            admission=adm, preemption=pre)
        self._free_slots = self.scheduler.free_slots    # shared list object
        self.finished: List[Request] = []
        # Resolve the hot-path attention backend ONCE through the unified
        # registry (ServeConfig.backend is the config-precedence level; env /
        # force_backend scopes still win, explicit args would win over both).
        # The resolved name is pinned for every step so perf numbers are
        # attributable to one implementation, and exposed via metrics().
        # A mesh pins the ``sharded`` backend explicitly (strict resolve —
        # the CallSpec carries the mesh as the capability evidence): the
        # per-layer combine is not a preference a config hint can override,
        # it is what makes the sequence-sharded pool computable at all.
        self.attn_impl = str(serve.attn_impl)
        if self.attn_impl not in ("ragged", "chunked"):
            raise ValueError(
                f"attn_impl {serve.attn_impl!r}: expected 'ragged' or "
                "'chunked'")
        fam = ("paged_attention_ragged" if self.attn_impl == "ragged"
               else "paged_attention_chunked")
        if mesh is not None:
            self.attn_backend = dispatch.resolve(
                fam, dispatch.SHARDED,
                spec=dispatch.CallSpec(platform=jax.default_backend(),
                                       kwargs={"mesh": mesh})).backend
        else:
            self.attn_backend = dispatch.resolve(
                fam, config=serve.backend).backend
        self._metrics = EngineMetrics(backend=self.attn_backend)
        self._key = jax.random.PRNGKey(seed)
        self._step_count = 0
        # Async overlapped loop (docs/async_engine.md): with overlap on,
        # step N+1's propose/schedule/render runs on host while step N's
        # fused program is still on device; ``_pending`` holds step N's
        # un-resolved record, ``_chain`` maps req_id -> step-N slot for
        # requests whose last output token is still a device-side future
        # (the fused program substitutes it via ``tok_src``/``nxt_prev``).
        self.overlap = bool(serve.overlap)
        self.prefetch_depth = int(serve.prefetch_depth)
        self.q_chunk = int(serve.q_chunk)
        # Ragged-kernel tunables: explicit config value (> 0) wins; fields
        # left at 0 consult the committed autotune table for this
        # (page_size, head_dim, backend) cell (repro.perf.autotune,
        # BENCH_010.json — counted tuned_resolved / tuned_fallback, the
        # kernel-layer mirror of the `auto` policy triple), falling back to
        # the registry defaults on any miss.
        defaults = dict(dispatch.get_op("paged_attention_ragged").tunables)
        self._tune_counters = {"tuned_resolved": 0, "tuned_fallback": 0}
        explicit = {k: int(getattr(serve, k)) for k in
                    autotune_lib.TUNABLE_KEYS}
        if self.attn_impl == "ragged" and any(
                v == 0 for v in explicit.values()):
            tuned = autotune_lib.resolve_tunables(bs, a.head_dim,
                                                  self.attn_backend)
            if tuned is not None:
                defaults.update(tuned)
                self._tune_counters["tuned_resolved"] = 1
            else:
                self._tune_counters["tuned_fallback"] = 1
        self.attn_tunables = {k: (explicit[k] if explicit[k] > 0
                                  else int(defaults[k]))
                              for k in autotune_lib.TUNABLE_KEYS}
        # Runtime sanitizers (repro.analysis.sanitize): retrace guard on
        # the step dispatch, host-sync guard around the build half, and
        # allocator invariant checks after every commit reconciliation.
        self.sanitize = bool(serve.sanitize)
        self.sanitizer = (sanitize_lib.Sanitizer() if self.sanitize
                          else None)
        self._pending: Optional[_PendingStep] = None
        self._chain: Dict[int, int] = {}
        self._copy_fn = jax.jit(copy_pool_blocks)
        self._dummy_prev = jnp.zeros((1,), jnp.int32)
        # Inside the sharded program the combine is called directly under
        # shard_map (the registry pinned the name above for attribution);
        # the single-device program threads the resolved name through the
        # chunked op family as before.
        attn_backend = None if mesh is not None else self.attn_backend
        mesh_axis = self.mesh_axis if mesh is not None else None
        prefetch_depth = self.prefetch_depth
        q_chunk = self.q_chunk
        attn_impl = self.attn_impl
        attn_tunables = dict(self.attn_tunables)

        def fused(params, pools, lists, tokens, tok_src, nxt_prev, key,
                  temps, top_ks, top_ps):
            # Device-token chaining: lanes with tok_src >= 0 take their
            # input token from the PREVIOUS step's sampled outputs (still
            # device-resident under overlap) instead of the host-rendered
            # placeholder — the decode input never round-trips to host.
            live = jnp.clip(tok_src, 0, nxt_prev.shape[0] - 1)
            tokens = jnp.where(tok_src >= 0, nxt_prev[live], tokens)
            logits, pools = model.decode_tokens_paged(
                params, pools, lists, tokens, attn_backend=attn_backend,
                q_chunk=q_chunk, prefetch_depth=prefetch_depth, mesh=mesh,
                axis=mesh_axis, attn_impl=attn_impl, **attn_tunables)
            nxt = sampling_lib.sample_batched(key, logits, temps, top_ks,
                                              top_ps)
            return nxt, pools

        self._step_fn = jax.jit(fused)

        # Speculative decoding (repro.serving.spec): resolve the proposer
        # like the policy triple — explicit ctor arg > force_proposer scope >
        # ServeConfig.spec > "off" — and pin it for the run. With a proposer
        # the engine runs the spec step: same fused forward (logit rows at
        # every draft lane via ``logit_lanes``) + batched rejection-accept.
        self.proposer = spec_lib.resolve(proposer, config=serve.spec)
        if (self.proposer is not None
                and not getattr(self.proposer, "deterministic", True)):
            # verify_batched's delta-q acceptance rule treats the draft
            # distribution as a point mass — exact ONLY for deterministic
            # proposers.  A stochastic proposer reaching it would silently
            # skew the sampling distribution, so fail at adoption, not at
            # verify (docs/spec_decoding.md, "Be deterministic").
            raise ValueError(
                f"proposer {self.proposer.name!r} declares "
                "deterministic=False: verify_batched's delta-q rejection "
                "rule assumes the draft distribution is a point mass, so a "
                "stochastic proposer would bias the emitted distribution. "
                "Thread its q distribution through verify_batched or use a "
                "deterministic proposer (see docs/spec_decoding.md).")
        self.spec_k = max(1, serve.spec_k) if self.proposer else 0
        self._spec_counters = {"steps": 0, "drafted_steps": 0,
                               "decode_lanes": 0, "proposed_tokens": 0,
                               "accepted_tokens": 0, "emitted_tokens": 0,
                               "rollback_blocks": 0}
        if self.proposer is not None:
            self.proposer.bind(self)

            def fused_spec(params, pools, lists, tokens, key, temps, top_ks,
                           top_ps, drafts, draft_lens):
                logits, pools = model.decode_tokens_paged(
                    params, pools, lists, tokens, attn_backend=attn_backend,
                    q_chunk=q_chunk, prefetch_depth=prefetch_depth,
                    mesh=mesh, axis=mesh_axis, attn_impl=attn_impl,
                    **attn_tunables)
                out, acc = spec_lib.verify_batched(
                    key, logits, drafts, draft_lens, temps, top_ks, top_ps)
                return out, acc, pools

            self._spec_step_fn = jax.jit(fused_spec)

    # -------------------------------------------------------------- lifecycle
    def submit(self, req: Request) -> None:
        if len(req.prompt) == 0:
            raise ValueError(f"request {req.req_id}: empty prompt")
        # KV is written for the prompt and all generated tokens except the
        # last (sampling it finishes the request before its KV lands); the
        # scheduler additionally wants one slack block at admission.
        bs = self.alloc.block_size
        positions = len(req.prompt) + max(req.max_new_tokens - 1, 0)
        worst = max(-(-positions // bs), -(-len(req.prompt) // bs) + 1)
        if worst > self.alloc.num_blocks:
            raise ValueError(
                f"request {req.req_id} can never fit: needs up to {worst} "
                f"blocks, pool has {self.alloc.num_blocks}")
        self.scheduler.submit(req)

    @property
    def waiting(self) -> List[Request]:
        return list(self.scheduler.waiting)

    @property
    def active(self) -> Dict[int, Request]:
        return self.scheduler.running

    # ------------------------------------------------------------- step build
    def _render(self, plan: StepPlan):
        """Render a StepPlan into the fused program's input arrays."""
        alloc, B = self.alloc, self.B
        T = _bucket(plan.num_tokens)
        # Slot-keyed arrays (sampling knobs, kv lens, logit lanes) are sized
        # to a power-of-two bucket of the ACTIVE slots, not max_batch — the
        # same bucketing as token lanes, so a lightly loaded engine samples
        # over 8 lanes instead of max_batch. Slots are allocated low-first,
        # so max(slot)+1 tracks the live batch closely.
        reqs = list(plan.decode) + [req for req, _ in plan.prefill]
        Bs = min(_bucket(1 + max(req.slot for req in reqs)), B)
        # Verify rows only when this step actually carries drafts: a
        # draftless step (proposer came up empty, drafts shed, prefill-only)
        # runs the plain (B, V) program instead of paying R unembed rows.
        spec_step = bool(plan.spec)
        R = self.spec_k + 1 if spec_step else 1         # logit rows per slot
        tokens = np.zeros((T,), np.int32)
        # tok_src[lane] >= 0: the lane's input token is the PREVIOUS step's
        # sampled output at that slot, still in flight on device — the fused
        # program substitutes it (overlap chaining); -1 = host-known token.
        tok_src = np.full((T,), -1, np.int32)
        token_req = np.full((T,), Bs, np.int32)         # Bs == padding lane
        token_pos = np.zeros((T,), np.int32)
        slots = np.full((T, 2), (self.max_total, 0), np.int32)  # dropped write
        last_lane = np.zeros((Bs,), np.int32)
        kv_lens = np.zeros((Bs,), np.int32)
        temps = np.zeros((Bs,), np.float32)
        top_ks = np.zeros((Bs,), np.int32)
        top_ps = np.ones((Bs,), np.float32)
        logit_lanes = np.zeros((Bs, R), np.int32)
        draft_tokens = np.zeros((Bs, max(R - 1, 1)), np.int32)
        draft_lens = np.zeros((Bs,), np.int32)
        lane = 0
        committed: List[tuple] = []             # (req, n_tokens, start_pos)
        for req in plan.decode:
            rid = req.req_id
            pos = alloc.seq_len(rid)
            draft = plan.spec.get(rid)
            n = 1 if draft is None else 1 + len(draft)
            ss = alloc.reserve_tokens(rid, n)
            src = self._chain.get(rid, -1)
            if src >= 0:
                # output[-1] is an unresolved placeholder — chain it from
                # the pending step's device outputs. Drafted steps resolve
                # the pipeline first, so spec lanes never chain.
                assert draft is None, rid
                tok_src[lane] = src
            else:
                tokens[lane] = req.output[-1]
            if n > 1:                           # drafted lanes ride behind
                tokens[lane + 1:lane + n] = draft
                draft_tokens[req.slot, :n - 1] = draft
                draft_lens[req.slot] = n - 1
            token_req[lane:lane + n] = req.slot
            token_pos[lane:lane + n] = pos + np.arange(n)
            slots[lane:lane + n] = ss
            last_lane[req.slot] = lane + n - 1
            # a row per lane; unused rows repeat the last lane (masked by
            # draft_lens in verify_batched)
            logit_lanes[req.slot] = np.minimum(lane + np.arange(R),
                                               lane + n - 1)
            kv_lens[req.slot] = pos + n
            lane += n
            committed.append((req, n, pos))
        for req, n in plan.prefill:
            rid = req.req_id
            pos0 = alloc.seq_len(rid)
            ss = alloc.reserve_tokens(rid, n)
            chunk = req.active_prompt[pos0:pos0 + n]
            tokens[lane:lane + n] = chunk
            token_req[lane:lane + n] = req.slot
            token_pos[lane:lane + n] = pos0 + np.arange(n)
            slots[lane:lane + n] = ss
            last_lane[req.slot] = lane + n - 1
            logit_lanes[req.slot] = lane + n - 1        # only row 0 is read
            kv_lens[req.slot] = pos0 + n
            lane += n
            committed.append((req, n, pos0))
        for req, _, _ in committed:
            temps[req.slot] = req.sampling.temperature
            top_ks[req.slot] = req.sampling.top_k
            top_ps[req.slot] = req.sampling.top_p
        # Block lists AFTER reservations (tables may have grown / CoW'd).
        # A prefix-shared block is effectual for EVERY holder, so the entry
        # count can exceed the pool size — bucket the capacity like T.
        # With a mesh the allocator renders per-shard LOCAL lists instead
        # (same slot keys, same bucketing per shard slice): the fused
        # program shards them over the model axis and every rank attends
        # against exactly the BlockList slice its pool shard serves.
        if self.mesh is not None:
            bl, br, bp = alloc.build_sharded_block_lists(
                [(req.req_id, req.slot) for req, _, _ in committed],
                pad_req=Bs)
        else:
            tables = {req.req_id: alloc.table(req.req_id)
                      for req, _, _ in committed}
            needed = sum(len(t) for t in tables.values())
            cap = (self.max_total if needed <= self.max_total
                   else _bucket(needed, lo=self.max_total))
            bl = np.zeros((cap,), np.int32)
            br = np.full((cap,), Bs, np.int32)
            bp = np.zeros((cap,), np.int32)
            cursor = 0
            for req, _, _ in committed:
                table = tables[req.req_id]
                n = len(table)
                bl[cursor:cursor + n] = table
                br[cursor:cursor + n] = req.slot
                bp[cursor:cursor + n] = np.arange(n)
                cursor += n
        # Ragged metadata: each committed entry is one contiguous lane run
        # (decode entries first, then prefill chunks — exactly the order the
        # lanes were rendered above), so the prefix sums + slot map describe
        # the same (token_req, token_pos, kv_lens) lanes the chunked path
        # reads directly.  Bs-bucketed like every slot-keyed array, so the
        # ragged program compiles per (T, Bs) bucket — no extra retraces.
        q_lens = np.zeros((Bs,), np.int64)
        kv_l = np.zeros((Bs,), np.int64)
        seq_slot = np.full((Bs,), Bs, np.int32)         # Bs == dropped slot
        for j, (req, n, pos0) in enumerate(committed):
            seq_slot[j] = req.slot
            q_lens[j] = n
            kv_l[j] = pos0 + n
        cu_q = np.zeros((Bs + 1,), np.int32)
        cu_kv = np.zeros((Bs + 1,), np.int32)
        cu_q[1:] = np.cumsum(q_lens)
        cu_kv[1:] = np.cumsum(kv_l)
        lists = {
            "block_list": jnp.asarray(bl), "block_req": jnp.asarray(br),
            "block_pos": jnp.asarray(bp), "kv_lens": jnp.asarray(kv_lens),
            "token_req": jnp.asarray(token_req),
            "token_pos": jnp.asarray(token_pos),
            "cu_q_lens": jnp.asarray(cu_q),
            "cu_kv_lens": jnp.asarray(cu_kv),
            "seq_slot": jnp.asarray(seq_slot),
            "slots": jnp.asarray(slots),
            "last_lane": jnp.asarray(last_lane),
        }
        if spec_step:
            lists["logit_lanes"] = jnp.asarray(logit_lanes)
        sample_args = (jnp.asarray(temps), jnp.asarray(top_ks),
                       jnp.asarray(top_ps))
        spec_args = ((jnp.asarray(draft_tokens), jnp.asarray(draft_lens))
                     if spec_step else None)
        return (lists, jnp.asarray(tokens), jnp.asarray(tok_src),
                sample_args, spec_args, committed)

    # -------------------------------------------------------------- main loop
    def _propose(self) -> Dict[int, np.ndarray]:
        """Ask the proposer for drafts for every DECODING request.

        Runs BEFORE scheduling so the scheduler can budget the extra lanes
        (blocks and tokens); a request preempted in the fit loop simply
        drops its draft.  The draft length is clamped so the step can never
        emit past ``max_new_tokens`` — the worst-case block bound checked at
        submit() is unchanged by speculation.  All requests go through ONE
        ``propose_batch`` call so proposers with a device-side rollout
        (draft-model) amortize it across the batch instead of running
        per-request host loops.
        """
        pend = [(req, min(self.spec_k,
                          req.max_new_tokens - len(req.output) - 1))
                for req in self.scheduler.running.values()
                if req.state is RequestState.DECODING
                and len(req.output) < req.max_new_tokens]
        if not pend:
            return {}
        raw = self.proposer.propose_batch(pend)
        drafts: Dict[int, np.ndarray] = {}
        for req, _ in pend:
            d = raw.get(req.req_id)
            d = (np.zeros((0,), np.int32) if d is None
                 else np.asarray(d, np.int32))
            self.proposer.on_propose(req, len(d))
            if len(d):
                drafts[req.req_id] = d
        return drafts

    def step(self) -> int:
        """One engine iteration: [propose] + schedule + ONE fused
        chunked-prefill/decode[/verify] program + host-side lifecycle
        updates. Returns #tokens processed.

        With ``ServeConfig.overlap`` the build half (propose / schedule /
        render / dispatch) runs against the PREVIOUS step's provisional
        state while that step is still executing on device; its resolve
        (commit reconciliation) happens after this step has been dispatched.
        Overlap off dispatches and resolves in the same call — identical
        behaviour to the serial loop. Greedy output streams are
        bit-identical either way (docs/async_engine.md).
        """
        t0 = time.perf_counter()
        if self.proposer is not None and self._pending is not None:
            # Proposers read the tail of req.output; under overlap its last
            # entry may still be an unresolved placeholder, which would
            # silently starve draft matching. Resolve first — drafted steps
            # are synchronization barriers anyway, so a proposer-active
            # engine sees exactly the serial engine's state at propose time.
            pend, self._pending = self._pending, None
            self._resolve(pend, None)
            if not self.scheduler.has_work():
                return 0        # the resolve finished the last requests —
                                # this iteration was a drain, not an idle tick
        drafts = self._propose() if self.proposer is not None else {}
        t1 = time.perf_counter()
        plan = self.scheduler.schedule(spec_drafts=drafts)
        if plan.num_tokens == 0:
            if self._pending is not None:      # drain the in-flight step
                pend, self._pending = self._pending, None
                pend.phases["propose"] += t1 - t0
                self._resolve(pend, None)
                return 0
            # Idle iteration: nothing scheduled, nothing in flight — record
            # the wall time instead of letting it vanish from phase_s.
            self._metrics.record_step(
                num_tokens=0, emitted_tokens=0, idle=True,
                phases={"propose": t1 - t0,
                        "idle": time.perf_counter() - t1})
            return 0
        if plan.spec:
            # Drafted steps are synchronization barriers: accepted drafts
            # commit KV at positions later lanes depend on and rejection
            # rolls reserved blocks back — never left in flight. Resolve
            # the pipeline first so every token the verify compares against
            # is concrete, then drop plan entries for requests that
            # finished at that resolve.
            if self._pending is not None:
                pend, self._pending = self._pending, None
                self._resolve(pend, None)
                self._filter_finished(plan)
                if plan.num_tokens == 0:
                    return 0
            return self._step_sync(plan, t0, t1)
        pend_new = self._build(plan, t0, t1)
        prev, self._pending = self._pending, None
        if prev is not None:
            self._resolve(prev, pend_new)
        if self.overlap:
            self._pending = pend_new
        else:
            self._resolve(pend_new, None)
        return plan.num_tokens

    # ------------------------------------------------------------- sanitizers
    def _sanitize_scope(self, scope: str):
        """Host-sync guard for the build half (no-op unless sanitizing)."""
        if self.sanitizer is None:
            return contextlib.nullcontext()
        return self.sanitizer.no_host_sync(scope)

    def _expect_cached(self, tag: str, *trees):
        """Retrace guard around one jit dispatch (no-op unless sanitizing)."""
        if self.sanitizer is None:
            return contextlib.nullcontext()
        return self.sanitizer.expect_cached(
            sanitize_lib.jit_signature(tag, *trees))

    def _check_allocator(self) -> None:
        if self.sanitizer is not None:
            self.sanitizer.check_allocator(self.alloc)

    # ---------------------------------------------------- overlapped pipeline
    def _drain_cow(self) -> None:
        """Apply pending copy-on-write block copies to the device pools.

        Copy counts are bucketed to powers of two with out-of-bounds padding
        (src = dst = pool size — the clipped gather reads a throwaway block,
        the ``mode="drop"`` scatter discards it), so a varying number of CoW
        copies per step reuses O(log pool) compiled programs instead of
        retracing ``copy_pool_blocks`` on every new count.
        """
        copies = self.alloc.drain_copies()
        if not copies:
            return
        n = _bucket(len(copies), lo=8)
        srcs = np.full((n,), self.max_total, np.int32)
        dsts = np.full((n,), self.max_total, np.int32)
        srcs[:len(copies)] = [s for s, _ in copies]
        dsts[:len(copies)] = [d for _, d in copies]
        srcs, dsts = jnp.asarray(srcs), jnp.asarray(dsts)
        # one executable per pow2 bucket: a second compile for a seen bucket
        # size would be exactly the per-call retrace class this drain's
        # bucketing exists to prevent
        with self._expect_cached("cow", n):
            self.pools = {k: self._copy_fn(p, srcs, dsts)
                          for k, p in self.pools.items()}

    def _drain_tier(self) -> None:
        """Apply queued host-tier traffic to the device pools, IN ORDER.

        A demote reads its block's per-channel pool slices (ONE fused kv
        slice) to host BEFORE any same-step reuse overwrites them (the slice
        is a data dependency on the in-flight program, so in-flight writes
        land first and the read content is the committed content); a promote
        scatters a previously saved host copy into its fresh block.  Runs
        before the CoW drain: CoW destinations are fresh pops that may be
        demoted blocks being reused.
        """
        channels = sorted(self.pools)
        ops = self.alloc.drain_tier_ops()
        for kind, entry, blk in ops:
            if kind == "demote":
                # documented host roundtrip: a demotion IS a device->host
                # copy — declared to the host-sync guard by reason
                entry.data = tuple(
                    sanitize_lib.host_read(self.pools[c][:, blk],
                                           reason="tier-drain")
                    for c in channels)
            else:
                assert entry.data is not None, "promote before demote copy"
                for c, val in zip(channels, entry.data):
                    self.pools[c] = self.pools[c].at[:, blk].set(
                        jnp.asarray(val, self.pools[c].dtype))

    def sync_pools(self) -> None:
        """Flush allocator-queued device-pool traffic (tier ops, then CoW).

        Public because the disaggregation frontend must flush the decode
        pool before writing handed-off KV into freshly reserved slots —
        a stale CoW whole-block copy or tier op applied later would clobber
        or misread them.
        """
        self._drain_tier()
        self._drain_cow()

    def _build(self, plan: StepPlan, t0: float, t1: float) -> "_PendingStep":
        """Render + dispatch a draftless plan and commit it provisionally.

        Every lane's KV slots are reserved AND committed here (one token per
        decode lane, the whole chunk per prefill lane) so the next schedule
        sees post-step sequence lengths; each decode-ish action appends a
        placeholder output token (the sampled value is still a device
        future) recorded in ``_chain`` for device-token chaining.  All
        host bookkeeping whose content is already known happens now —
        prefill chunk accounting, prompt prefix registration, the
        PREFILLING -> DECODING transition; everything value-dependent
        (EOS, TTFT stamps, generated-block hashing) waits for ``_resolve``.
        """
        # The build half must never block on the in-flight device step: a
        # device->host read here (outside the tier-drain allowlist) would
        # serialize the overlap the async loop exists for.  The retrace
        # guard scopes only the fused dispatch — eager housekeeping
        # (fold_in, render uploads) compiles once harmlessly.
        with self._sanitize_scope("overlap-build"):
            lists, tokens, tok_src, sample_args, spec_args, committed = (
                self._render(plan))
            assert spec_args is None, "drafted plans go through _step_sync"
            self.sync_pools()
            self._step_count += 1
            key = jax.random.fold_in(self._key, self._step_count)
            nxt_prev = (self._pending.nxt_dev if self._pending is not None
                        else self._dummy_prev)
            t2 = time.perf_counter()
            with self._expect_cached("step", lists, tokens, tok_src,
                                     nxt_prev, sample_args):
                nxt_dev, self.pools = self._step_fn(
                    self.params, self.pools, lists, tokens, tok_src,
                    nxt_prev, key, *sample_args)
        actions = []
        chain: Dict[int, int] = {}
        for req, n, pos0 in committed:
            rid = req.req_id
            self.alloc.commit_tokens(rid, n)
            if req.state is RequestState.DECODING:
                req.output.append(0)            # placeholder: value in flight
                chain[rid] = req.slot
                actions.append(("decode", req, n, pos0, len(req.output) - 1))
            else:                               # prefill chunk
                start = req.prefill_pos
                req.prefill_pos += n
                self.alloc.register_prefix(rid, req.active_prompt,
                                           req.prefill_pos, start=start)
                out_idx = None
                if req.prefill_remaining == 0:  # final chunk samples a token
                    if self.prefill_only:
                        # prefill role: park for handoff — no transition, no
                        # sampled token; the decode engine recomputes the
                        # final position's logits at admission (the same
                        # last-token rule the prefix cache already applies)
                        self.prefilled.append(req)
                    else:
                        req.to_state(RequestState.DECODING)
                        req.output.append(0)
                        chain[rid] = req.slot
                        out_idx = len(req.output) - 1
                actions.append(("prefill", req, n, pos0, out_idx))
        self._chain = chain
        if self.proposer is not None:
            self._spec_counters["steps"] += 1
        return _PendingStep(
            actions=actions,
            slots={req.req_id: req.slot for req, _, _ in committed},
            chain=chain, nxt_dev=nxt_dev,
            phases={"propose": t1 - t0,
                    "schedule_render": t2 - t1},
            num_tokens=plan.num_tokens, t_dispatch=t2)

    def _resolve(self, pend: "_PendingStep",
                 next_pending: Optional["_PendingStep"]) -> None:
        """Block on a pending step's device future and reconcile.

        Placeholders become real tokens, EOS / max-token finishes fire
        (cancelling the request's in-flight action in ``next_pending`` —
        the allocator's free is the reconciliation point), preempted-
        mid-flight requests keep their resolved token for recompute-resume,
        and the step's metrics are recorded with the device phase spanning
        dispatch -> future resolved.
        """
        nxt = np.asarray(pend.nxt_dev)          # blocks until step N is done
        t_done = time.perf_counter()
        if self._chain is pend.chain:           # overlap off: nothing newer
            self._chain = {}
        now = time.time()
        emitted = 0
        for kind, req, n, pos0, out_idx in pend.actions:
            rid = req.req_id
            if rid in pend.cancelled or out_idx is None:
                continue        # finished at an earlier resolve / chunk-only
            tok = int(nxt[pend.slots[rid]])
            req.output[out_idx] = tok
            emitted += 1
            preempted = req.state is RequestState.PREEMPTED
            if kind == "decode" and not preempted:
                self._register_generated(req, pos0, new_len=pos0 + n)
            if kind == "prefill" and req.first_token_at is None:
                req.first_token_at = now
            # out_idx + 1 = this request's output length through THIS action
            # (req.output may already hold the NEXT step's placeholder).
            if out_idx + 1 >= req.max_new_tokens or tok == self.eos_id:
                self._finish(req, now, next_pending=next_pending)
        self._metrics.record_step(
            num_tokens=pend.num_tokens, emitted_tokens=emitted,
            phases={**pend.phases, "device": t_done - pend.t_dispatch,
                    "commit": time.perf_counter() - t_done})
        # Post-reconciliation is the quiescent point: provisional commits,
        # finishes and preemption frees have all landed in the allocator.
        self._check_allocator()

    def _filter_finished(self, plan: StepPlan) -> None:
        """Drop plan entries whose request finished while the plan was being
        scheduled against provisional state (resolve ran after schedule)."""
        plan.decode = [r for r in plan.decode
                       if r.state is RequestState.DECODING]
        live = {r.req_id for r in plan.decode}
        plan.spec = {rid: d for rid, d in plan.spec.items() if rid in live}
        plan.prefill = [(r, n) for r, n in plan.prefill
                        if r.state is RequestState.PREFILLING]

    # ------------------------------------------------------ synchronous step
    def _step_sync(self, plan: StepPlan, t0: float, t1: float) -> int:
        """The drafted (speculative) step, fully synchronous."""
        lists, tokens, tok_src, sample_args, spec_args, committed = (
            self._render(plan))
        assert spec_args is not None
        del tok_src                 # pipeline resolved: every token concrete
        self.sync_pools()
        self._step_count += 1
        key = jax.random.fold_in(self._key, self._step_count)
        t2 = time.perf_counter()
        with self._expect_cached("spec", lists, tokens, sample_args,
                                 spec_args):
            out, acc, self.pools = self._spec_step_fn(
                self.params, self.pools, lists, tokens, key, *sample_args,
                *spec_args)
        out, acc = np.asarray(out), np.asarray(acc)
        nxt = out[:, 0]
        t3 = time.perf_counter()
        now = time.time()
        emitted = 0
        for req, n, _ in committed:
            if req.state is RequestState.DECODING:
                # speculative lane: commit the accepted prefix, roll back
                # the rejected tail's reserved blocks (rewind semantics)
                a = min(int(acc[req.slot]), n - 1)
                self.alloc.commit_tokens(req.req_id, 1 + a)
                if a < n - 1:
                    table_before = len(self.alloc.table(req.req_id))
                    self.alloc.truncate(req.req_id,
                                        self.alloc.seq_len(req.req_id))
                    self._spec_counters["rollback_blocks"] += (
                        table_before - len(self.alloc.table(req.req_id)))
            else:
                self.alloc.commit_tokens(req.req_id, n)
        for req, n, pos0 in committed:
            if req.state is RequestState.DECODING:
                a = min(int(acc[req.slot]), n - 1)
                row = out[req.slot]
                self._register_generated(req, pos0, accepted=row[:a])
                appended = 0
                for j in range(a + 1):
                    self._append_token(req, int(row[j]), now)
                    appended += 1
                    if req.state is RequestState.FINISHED:
                        break               # EOS inside the accepted run
                emitted += appended
                if n > 1:
                    # count only DRAFTED lanes, and only tokens that
                    # actually reached the output stream (an EOS mid-
                    # prefix drops the tokens behind it) — an undrafted
                    # lane riding a spec step is a plain decode
                    self._spec_counters["decode_lanes"] += 1
                    self._spec_counters["accepted_tokens"] += min(
                        a, appended)
                    self._spec_counters["emitted_tokens"] += appended
            else:                                       # prefill chunk
                start = req.prefill_pos
                req.prefill_pos += n
                self.alloc.register_prefix(req.req_id, req.active_prompt,
                                           req.prefill_pos, start=start)
                if req.prefill_remaining == 0:
                    if self.prefill_only:
                        self.prefilled.append(req)
                        continue
                    req.to_state(RequestState.DECODING)
                    if req.first_token_at is None:
                        req.first_token_at = now
                    self._append_token(req, int(nxt[req.slot]), now)
                    emitted += 1
        self._spec_counters["steps"] += 1
        self._spec_counters["drafted_steps"] += 1
        self._spec_counters["proposed_tokens"] += sum(
            len(d) for d in plan.spec.values())
        t4 = time.perf_counter()
        self._metrics.record_step(
            num_tokens=plan.num_tokens, emitted_tokens=emitted,
            phases={"propose": t1 - t0, "schedule_render": t2 - t1,
                    "device": t3 - t2, "commit": t4 - t3})
        self._check_allocator()
        return plan.num_tokens

    def _register_generated(self, req: Request, pos0: int,
                            accepted: Optional[np.ndarray] = None,
                            new_len: Optional[int] = None) -> None:
        """Hash-register full KV blocks produced during decode.

        Prompt prefill publishes block hashes as chunks commit; this is the
        decode-side analogue (ROADMAP: generated-token prefix caching): any
        block FILLED by this step's committed tokens becomes prefix-cache
        content, so preemption-resume recompute and repeated
        prompt+generation prefixes get cache hits.  ``accepted`` carries
        this step's committed-but-not-yet-appended draft tokens (spec path).
        ``new_len`` is the post-step sequence length; the overlapped resolve
        passes it explicitly because by resolve time the allocator may
        already hold the NEXT step's provisional commits.
        """
        if new_len is None:
            new_len = self.alloc.seq_len(req.req_id)
        bs = self.alloc.block_size
        if pos0 // bs == new_len // bs:         # no block filled this step
            return
        seq = req.resume_tokens()
        if accepted is not None and len(accepted):
            seq = np.concatenate([seq, np.asarray(accepted, np.int32)])
        self.alloc.register_prefix(req.req_id, seq, new_len, start=pos0)

    def _append_token(self, req: Request, tok: int, now: float) -> None:
        req.output.append(tok)
        if len(req.output) >= req.max_new_tokens or tok == self.eos_id:
            self._finish(req, now)

    def _finish(self, req: Request, now: float,
                next_pending: Optional["_PendingStep"] = None) -> None:
        if req.state is RequestState.PREEMPTED:
            # Finished at resolve AFTER being preempted mid-flight: blocks
            # are already freed; pull it out of the recompute queue.
            try:
                self.scheduler.waiting.remove(req)
            except ValueError:
                pass
        else:
            self.scheduler.release(req)
        req.finish(now)
        self.finished.append(req)
        self._metrics.record_finished(
            ttft=req.ttft, tpot=req.tpot, num_output_tokens=len(req.output),
            arrival=req.arrival, done_at=now)
        if next_pending is not None:
            next_pending.cancel(req)
        self._chain.pop(req.req_id, None)

    @property
    def busy(self) -> bool:
        """Work queued, running, or still in flight in the pipeline."""
        return self.scheduler.has_work() or self._pending is not None

    def take_prefilled(self) -> List[Request]:
        """Prefill role: pop requests whose prompt KV is fully committed.

        Each is detached from the scheduler (slot returned, blocks KEPT and
        still owned by its req_id) — the caller performs the handoff and must
        ``alloc.free(req_id)`` afterwards to release the prefill-side copy
        (its published blocks then park cached-free, keeping the prefill
        prefix cache warm for repeat prompts)."""
        out, self.prefilled = self.prefilled, []
        for req in out:
            self.scheduler.detach(req)
        return out

    def run_until_done(self, max_steps: int = 100_000) -> None:
        for _ in range(max_steps):
            if not self.busy:
                return
            self.step()
        raise RuntimeError("serving did not converge")

    # --------------------------------------------------------------- metrics
    def metrics(self) -> Dict[str, float]:
        m = self._metrics.summary()
        hits, misses = self.alloc.prefix_hits, self.alloc.prefix_misses
        # Mesh attribution: the shape the fused program ran on (axis name ->
        # size; None for the single-device engine) and the device count, so
        # a --devices sweep row is attributable to one mesh like rows are to
        # one backend/policy/proposer.
        mesh_shape = (dict(self.mesh.shape) if self.mesh is not None
                      else None)
        m.update({
            "mesh_shape": mesh_shape,
            "devices": (int(np.prod(list(mesh_shape.values())))
                        if mesh_shape else 1),
            # Pipeline attribution (like backend/mesh_shape): whether the
            # overlapped loop ran and the kernel's KV-page DMA ring depth.
            "overlap": self.overlap,
            "prefetch_depth": self.prefetch_depth,
            "q_chunk": self.q_chunk,
            # Ragged-kernel attribution: which attention family the fused
            # step dispatched and the resolved tunables (explicit config,
            # autotune-table hit, or registry defaults — the
            # tuned_resolved/tuned_fallback counters below say which).
            "attn_impl": self.attn_impl,
            **self.attn_tunables,
            "blocks_free": self.alloc.num_free,
            "preemptions": self.scheduler.num_preemptions,
            "slot_compactions": self.scheduler.num_slot_compactions,
            "prefix_hits": hits,
            "prefix_misses": misses,
            "prefix_hit_rate": hits / (hits + misses) if hits + misses else 0.0,
            "cow_copies": self.alloc.cow_copies,
        })
        # Speculative-decoding attribution: the resolved proposer plus the
        # acceptance evidence (rate, mean accepted length, rollbacks, shed
        # draft sets) — a --spec sweep row is attributable to one proposer.
        c = self._spec_counters
        m["spec"] = {
            "proposer": self.proposer.name if self.proposer else spec_lib.OFF,
            "k": self.spec_k,
            "acceptance_rate": (c["accepted_tokens"] / c["proposed_tokens"]
                                if c["proposed_tokens"] else 0.0),
            "mean_accepted_len": (c["accepted_tokens"] / c["drafted_steps"]
                                  if c["drafted_steps"] else 0.0),
            # output tokens emitted per DRAFTED (request, step) decode lane:
            # > 1 iff accepted drafts actually land (batch-size free;
            # undrafted lanes — whole draftless steps run the plain (B, V)
            # program — don't count)
            "tokens_per_decode_lane": (c["emitted_tokens"] / c["decode_lanes"]
                                       if c["decode_lanes"] else 0.0),
            "spec_sheds": self.scheduler.num_spec_sheds,
            **c,
        }
        if self.proposer is not None:
            m["spec"].update({f"proposer.{k}": v for k, v in
                              sorted(self.proposer.counters.items())})
        # The resolved policy triple the run executed with, plus each
        # policy's own counters (admitted / victims / evictions / ...) keyed
        # "<axis>.<counter>" — rows from a --policy sweep are attributable to
        # one admission/preemption/eviction combination.
        for axis, name in self.policies.items():
            m[f"{axis}_policy"] = name
        m["policy_counters"] = {
            f"{p.axis}.{k}": v
            for p in self._policy_objs for k, v in sorted(p.counters.items())}
        # Engine role (disaggregated serving) + host-tier attribution: pool
        # sizes per tier and the demote/promote/hit/drop traffic, with the
        # counters ALSO flattened next to the policy counters so benchmark
        # rows carry them the same way (docs/disaggregated.md).
        m["role"] = self.role
        hp = self.host_pool
        tier_counters = (dict(hp.counters) if hp is not None else
                         {"demotes": 0, "promotes": 0, "hits": 0, "drops": 0})
        m["tier"] = {
            "hbm_blocks": self.alloc.num_blocks,
            "host_blocks": hp.capacity if hp is not None else 0,
            "host_blocks_used": len(hp) if hp is not None else 0,
            **tier_counters,
        }
        m["policy_counters"].update(
            {f"tier.{k}": v for k, v in sorted(tier_counters.items())})
        m["policy_counters"].update(
            {f"tune.{k}": v for k, v in sorted(self._tune_counters.items())})
        # Sanitizer attribution (docs/static_analysis.md): whether the run
        # was guarded plus the guard counters, ALSO flattened next to the
        # policy counters so benchmark rows carry them the same way.  A
        # clean sanitized run shows retraces == transfer_guard_trips == 0
        # with invariant_checks > 0.
        san = (self.sanitizer.counters() if self.sanitizer is not None else
               {"retraces": 0, "transfer_guard_trips": 0,
                "invariant_checks": 0, "allowed_host_syncs": 0,
                "compiles": 0})
        m["sanitize"] = {"enabled": self.sanitize, **san}
        m["policy_counters"].update(
            {f"sanitize.{k}": v for k, v in sorted(san.items())})
        return m
