"""Batched verify + distribution-preserving rejection-accept.

One fused target forward scores every drafted token: the engine lays a
request's lanes out as ``[last_committed_token, d_1, ..., d_k]``, the
chunked paged-attention op family handles the multi-token causal query
(exactly the machinery chunked prefill already uses), and
``decode_tokens_paged`` returns a logit row per lane.  Row ``j`` is the
target distribution for the token at position ``ctx + j + 1`` — i.e. the
distribution draft ``d_{j+1}`` claims to be sampled from, and row ``k`` is
the bonus distribution after a fully-accepted draft.

Acceptance rule (deterministic proposers ⇒ delta draft distribution q):

  * stochastic lane (``temperature > 0``): accept ``d_j`` with probability
    ``p_j(d_j)`` (= ``min(1, p/q)`` for q a point mass); on the first
    rejection emit a sample from the residual ``p_j`` with ``d_j`` zeroed
    and renormalized (= ``normalize(max(p - q, 0))``).  The emitted token is
    then distributed EXACTLY as ``p_j`` — speculation changes throughput,
    not the sampling distribution (tested by the hypothesis property test).
  * greedy lane (``temperature <= 0``): accept iff ``d_j == argmax`` of the
    raw row logits — and on rejection emit that argmax — so greedy output
    streams are bit-identical to the non-speculative engine.
  * after ``a`` accepted drafts the step emits ``a + 1`` tokens: the
    accepted prefix plus one corrected/bonus token.  ``a == 0`` degrades to
    exactly one ordinary decode token; speculation can never be slower in
    tokens-per-step.

``p_j`` is the temperature/top-k/top-p filtered distribution from
``repro.serving.sampling.filter_logits`` — the SAME filter the plain engine
samples through, so spec and non-spec lanes agree on the target.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.serving.sampling import filter_logits

__all__ = ["verify_batched"]


def verify_batched(key, logits, drafts, draft_lens, temperatures, top_ks,
                   top_ps):
    """Score K drafts per slot and keep the longest accepted prefix.

    logits      (B, R, V)  one row per lane; row 0 follows the last
                           committed token, rows 1..R-1 follow the drafts
    drafts      (B, R-1)   proposed tokens (garbage past ``draft_lens``)
    draft_lens  (B,)       valid drafts per slot (0 ⇒ plain decode lane)
    temperatures/top_ks/top_ps   per-slot sampling knobs as in
                           :func:`repro.serving.sampling.sample_batched`

    Returns ``(out_tokens (B, R) int32, accept_len (B,) int32)``: slot ``b``
    emits ``out_tokens[b, :accept_len[b] + 1]`` — ``accept_len`` accepted
    drafts then the corrected/bonus token.  Rows past that are unspecified.
    All knobs are traced values; one compiled program serves every batch
    mix, like the plain sampling path.
    """
    B, R, V = logits.shape
    keys = jax.random.split(key, B)

    def one(k, rows, draft, d, temp, kk, pp):
        greedy = temp <= 0.0
        row_keys = jax.random.split(k, 2 * R).reshape(R, 2, 2)
        lg32 = rows.astype(jnp.float32)                     # (R, V)
        arg = jnp.argmax(lg32, axis=-1).astype(jnp.int32)   # (R,)
        filt = jax.vmap(lambda r: filter_logits(r, temp, kk, pp))(rows)
        probs = jax.nn.softmax(filt, axis=-1)               # (R, V)

        # acceptance per draft row j (draft j is judged by row j's logits)
        j_idx = jnp.arange(R - 1)
        p_draft = jnp.take_along_axis(probs[:-1], draft[:, None],
                                      axis=-1)[:, 0]        # (R-1,)
        u = jax.vmap(jax.random.uniform)(row_keys[:-1, 0])  # (R-1,)
        acc = jnp.where(greedy, draft == arg[:-1], u < p_draft)
        acc = acc & (j_idx < d)
        a = jnp.sum(jnp.cumprod(acc.astype(jnp.int32)))     # accept prefix

        # per-row fallback tokens: the residual sample (reject at row j) and
        # the ordinary sample (bonus after a full accept).
        resid = jnp.where(
            jnp.arange(V)[None, :] == jnp.pad(draft, (0, 1))[:, None],
            -jnp.inf, filt)
        t_rej = jax.vmap(jax.random.categorical)(row_keys[:, 1], resid)
        t_samp = jax.vmap(jax.random.categorical)(row_keys[:, 1], filt)
        t_rej = jnp.where(greedy, arg, t_rej).astype(jnp.int32)
        t_samp = jnp.where(greedy, arg, t_samp).astype(jnp.int32)

        # out[j < a] = draft[j]; out[a] = residual if a rejected a draft,
        # ordinary sample if every valid draft was accepted (a == d).
        rows_idx = jnp.arange(R)
        tail = jnp.where(a < d, t_rej, t_samp)              # (R,)
        out = jnp.where(rows_idx < a, jnp.pad(draft, (0, 1)), tail)
        return out.astype(jnp.int32), a.astype(jnp.int32)

    return jax.vmap(one)(keys, logits, drafts, draft_lens, temperatures,
                         top_ks, top_ps)
