"""Speculative-decoding proposers: the draft side of propose/verify.

A :class:`Proposer` guesses the next ``k`` tokens of a DECODING request from
host-visible evidence (the request's own prompt + generated tokens, or a
small draft model).  The serving engine then scores all guesses in ONE fused
forward through the chunked paged-attention op family and keeps the longest
accepted prefix (``repro.serving.spec.verify``) — guesses only ever change
*speed*, never *tokens*.

Proposers are registered strategies behind a string key, mirroring
``repro.serving.policy`` (one axis instead of three):

Resolution precedence (highest wins)
------------------------------------
1. explicit argument (a name or a :class:`Proposer` *instance*) at the call
   site — strict: an unknown name raises :class:`UnknownProposerError`;
2. ``with force_proposer("ngram"):`` scope (how ``benchmarks/run.py --spec``
   sweeps proposers);
3. a config hint (``ServeConfig.spec``, fed by ``repro.launch.serve
   --spec``);
4. the default ``"off"``.

``"off"`` is the reserved no-speculation name: it resolves to ``None`` and
the engine runs its plain one-token-per-step path.  Every other name must be
registered.  Proposers are instantiated per resolve and carry per-run
``counters`` (proposals / proposed_tokens / empty), flattened into
``metrics()["spec"]`` by the engine; resolutions are appended to the active
:func:`record_resolutions` scope so benchmark rows can attribute numbers to
the proposer that actually ran.

Deterministic proposers only: ``propose`` must be a pure function of request
state (no RNG), which is what makes the delta-distribution acceptance rule
in ``repro.serving.spec.verify`` exact and greedy runs bit-reproducible.
"""
from __future__ import annotations

import contextlib
import threading
from typing import (Callable, Dict, Iterator, List, Optional, Tuple, Type,
                    Union)

import numpy as np

from repro.serving.request import Request

__all__ = [
    "OFF", "DEFAULT", "UnknownProposerError", "Proposer", "register",
    "names", "get", "resolve", "force_proposer", "forced_proposer",
    "record_resolutions",
]

OFF = "off"                      # reserved: no speculation (resolves to None)
DEFAULT = OFF

_AUTO_NAMES = (None, "", "default")
# Accepted spellings normalized before lookup ("--spec draft" just works).
ALIASES = {"draft": "draft-model"}


class UnknownProposerError(ValueError):
    """A requested proposer name is not registered (and is not ``"off"``)."""


class Proposer:
    """Base class: a registry name + per-run counters.

    Subclasses implement :meth:`propose` (and optionally the batched
    :meth:`propose_batch`); :meth:`bind` runs once when the engine adopts
    the proposer (build a draft model, size windows, ...).

    ``deterministic`` is a capability declaration, not a hint: the delta-q
    acceptance rule in ``repro.serving.spec.verify`` treats the draft
    distribution as a point mass, which is exact ONLY when ``propose`` is a
    pure function of request state.  A proposer that samples its drafts
    must set ``deterministic = False`` — the engine then refuses to adopt
    it with a clear error instead of silently biasing the emitted
    distribution (docs/spec_decoding.md).
    """

    name: str = ""               # set by @register
    deterministic: bool = True   # propose() is a pure function of req state

    def __init__(self) -> None:
        self.counters: Dict[str, int] = {}

    def count(self, key: str, n: int = 1) -> None:
        self.counters[key] = self.counters.get(key, 0) + n

    # -- engine hooks --------------------------------------------------------
    def bind(self, engine) -> None:
        """Called once by the adopting engine (duck-typed; optional)."""

    def propose(self, req: Request, k: int) -> np.ndarray:
        """Up to ``k`` draft tokens continuing ``req``'s sequence.

        Must be deterministic in ``req``'s state.  Return shape ``(d,)``
        int32 with ``0 <= d <= k``; an empty array means "no guess" and the
        request decodes normally this step.
        """
        raise NotImplementedError

    def propose_batch(self, reqs: List[Tuple[Request, int]]
                      ) -> Dict[int, np.ndarray]:
        """Drafts for a whole step's DECODING requests in one call.

        ``reqs`` is ``[(request, k), ...]`` (``k <= 0`` ⇒ no budget: return
        empty).  The engine always proposes through this entry point so a
        proposer with device-side work (the draft-model rollout) can batch
        it across requests; the default just loops :meth:`propose`, which
        is exactly right for host-side proposers like ``ngram``.  Must be
        equivalent to the per-request form: ``out[req.req_id] ==
        propose(req, k)`` for every pair.
        """
        return {req.req_id: (self.propose(req, k) if k > 0
                             else np.zeros((0,), np.int32))
                for req, k in reqs}

    # -- bookkeeping the engine drives --------------------------------------
    def on_propose(self, req: Request, drafted: int) -> None:
        self.count("proposals")
        if drafted:
            self.count("proposed_tokens", drafted)
        else:
            self.count("empty")


# ---------------------------------------------------------------------------
# Registry (mirrors repro.serving.policy: register + resolve, scoped
# override, resolution log; thread-local so scopes can't leak across tests).
# ---------------------------------------------------------------------------
_REGISTRY: Dict[str, Type[Proposer]] = {}
_STATE = threading.local()


def register(name: str) -> Callable[[Type[Proposer]], Type[Proposer]]:
    """Class decorator: register a proposer class under ``name``."""
    if name in (OFF,) + _AUTO_NAMES:
        raise ValueError(f"proposer name {name!r} is reserved")

    def deco(cls: Type[Proposer]) -> Type[Proposer]:
        if not issubclass(cls, Proposer):
            raise TypeError(f"{cls.__name__} must subclass Proposer")
        if name in _REGISTRY:
            raise ValueError(f"proposer {name!r} registered twice")
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def names(include_off: bool = True) -> List[str]:
    """Registered proposer names (sorted), with ``"off"`` leading."""
    rest = sorted(_REGISTRY)
    return ([OFF] + rest) if include_off else rest


def get(name: str) -> Type[Proposer]:
    try:
        return _REGISTRY[ALIASES.get(name, name)]
    except KeyError:
        raise UnknownProposerError(
            f"unknown proposer {name!r}; registered: {names()}") from None


def _validate(name: str) -> None:
    if name != OFF:
        get(name)


# -- scoped override + resolution log ---------------------------------------
def _scope_stack() -> List[str]:
    if not hasattr(_STATE, "forced"):
        _STATE.forced = []
    return _STATE.forced


def _log_stack() -> List[List[str]]:
    if not hasattr(_STATE, "logs"):
        _STATE.logs = []
    return _STATE.logs


@contextlib.contextmanager
def force_proposer(name: Optional[str]) -> Iterator[None]:
    """Scoped proposer preference (``None`` leaves resolution untouched).

    Names are validated on entry — a sweep over a typo'd proposer fails
    before any engine is built, not mid-benchmark.  ``"off"`` is a valid
    forced value: it pins speculation OFF even over a config hint.  Aliases
    are normalized here, so :func:`forced_proposer` always reports the
    canonical name.
    """
    if name not in _AUTO_NAMES:
        _validate(name)
        name = ALIASES.get(name, name)
    stack = _scope_stack()
    stack.append(name)
    try:
        yield
    finally:
        stack.pop()


def forced_proposer() -> Optional[str]:
    """The innermost ``force_proposer`` preference, if any."""
    for name in reversed(_scope_stack()):
        if name not in _AUTO_NAMES:
            return name
    return None


@contextlib.contextmanager
def record_resolutions() -> Iterator[List[str]]:
    """Collect proposer names resolved inside the scope (``"off"`` included)."""
    log: List[str] = []
    _log_stack().append(log)
    try:
        yield log
    finally:
        stack = _log_stack()
        for i in range(len(stack) - 1, -1, -1):   # remove by identity
            if stack[i] is log:
                del stack[i]
                break


def _note(name: str) -> None:
    for log in _log_stack():
        log.append(name)


# -- resolver ----------------------------------------------------------------
def resolve(explicit: Union[None, str, Proposer] = None, *,
            config: Optional[str] = None) -> Optional[Proposer]:
    """Resolve to a fresh :class:`Proposer` instance, or ``None`` for off.

    ``explicit`` may be a registered name, ``"off"``, or an already-built
    proposer instance (injected by tests); instances pass through unchanged
    but are still logged under their registered name.
    """
    if isinstance(explicit, Proposer):
        _note(explicit.name or explicit.__class__.__name__)
        return explicit
    for level in (explicit,                       # 1. explicit — strict
                  forced_proposer(),              # 2. scope
                  config,                         # 3. config hint — strict
                  DEFAULT):                       # 4. default: off
        if level in _AUTO_NAMES:
            continue
        if level == OFF:
            _note(OFF)
            return None
        cls = get(level)
        _note(cls.name)                  # canonical name, aliases normalized
        return cls()
    _note(OFF)
    return None
