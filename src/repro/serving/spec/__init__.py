"""Speculative decoding: draft-propose / batch-verify / rejection-accept.

``repro.serving.spec`` is the engine's multi-token-per-step subsystem:

  * :mod:`~repro.serving.spec.proposer` — the :class:`Proposer` protocol and
    its string-keyed registry (mirroring ``repro.serving.policy``);
  * :mod:`~repro.serving.spec.ngram` / :mod:`~repro.serving.spec.draft_model`
    — the shipped proposers (``ngram`` prompt/generated-token lookup,
    ``draft-model`` shallow-sibling rollout);
  * :mod:`~repro.serving.spec.verify` — the fused batched verify +
    distribution-preserving rejection-accept rule.

See docs/spec_decoding.md for the dataflow and how to add a proposer.
"""
from repro.serving.spec.proposer import (        # noqa: F401
    ALIASES, DEFAULT, OFF, Proposer, UnknownProposerError, force_proposer,
    forced_proposer, get, names, record_resolutions, register, resolve)
from repro.serving.spec import ngram, draft_model  # noqa: F401  (register)
from repro.serving.spec.ngram import NgramProposer          # noqa: F401
from repro.serving.spec.draft_model import DraftModelProposer  # noqa: F401
from repro.serving.spec.verify import verify_batched        # noqa: F401

__all__ = [
    "ALIASES", "DEFAULT", "OFF", "Proposer", "UnknownProposerError",
    "force_proposer",
    "forced_proposer", "get", "names", "record_resolutions", "register",
    "resolve", "NgramProposer", "DraftModelProposer", "verify_batched",
]
