"""Prompt/generated-token lookup proposer (a.k.a. prompt-lookup decoding).

The cheapest useful draft model is the request's own history: if the last
``n`` tokens of the sequence occurred earlier (in the prompt OR in already-
generated output — greedy decodes of small models loop constantly, and
structured prompts repeat suffixes), the tokens that followed that earlier
occurrence are a strong guess for what comes next.  Zero FLOPs, pure host
numpy, and exact determinism.

Matching is longest-n-gram-first (``max_n`` down to ``min_n``) and prefers
the MOST RECENT earlier occurrence — recent repetition (a generation loop)
beats a stale prompt echo.  The proposal is the ``k`` tokens following the
match; a match flush against the sequence end proposes however many tokens
remain (possibly fewer than ``k``).
"""
from __future__ import annotations

import numpy as np

from repro.serving.request import Request
from repro.serving.spec.proposer import Proposer, register


@register("ngram")
class NgramProposer(Proposer):
    """Suffix n-gram lookup over ``prompt + output``."""

    def __init__(self, max_n: int = 3, min_n: int = 1) -> None:
        super().__init__()
        assert 1 <= min_n <= max_n, (min_n, max_n)
        self.max_n = max_n
        self.min_n = min_n

    def propose(self, req: Request, k: int) -> np.ndarray:
        ctx = req.resume_tokens()               # prompt + generated, int32
        L = len(ctx)
        if k <= 0 or L < self.min_n + 1:
            return np.zeros((0,), np.int32)
        for n in range(min(self.max_n, L - 1), self.min_n - 1, -1):
            suffix = ctx[L - n:]
            # candidate start positions of earlier occurrences, newest first
            windows = np.lib.stride_tricks.sliding_window_view(ctx[:-1], n)
            hits = np.nonzero((windows == suffix).all(axis=1))[0]
            for start in hits[::-1]:
                follow = ctx[start + n:start + n + k]
                if len(follow):
                    return np.asarray(follow, np.int32)
        return np.zeros((0,), np.int32)
