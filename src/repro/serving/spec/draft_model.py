"""Draft-model proposer: a small TransformerLM guesses, the big model checks.

Classic two-model speculative decoding.  The draft shares the target's
tokenizer/vocab (it is built from the SAME ``ModelConfig`` with fewer
layers, so its embedding table speaks the same token ids) and rolls out
``k`` greedy tokens host-side; the serving engine then verifies all of them
in one fused target forward.  The draft is deliberately greedy/deterministic
— the delta-distribution acceptance rule in ``repro.serving.spec.verify``
needs no draft probabilities and greedy serving stays bit-reproducible.

Cost model: the engine proposes through :meth:`propose_batch`, which rolls
out ALL requests' drafts together — ``k_max`` forwards of a (B, L) batch per
step instead of ``sum_i k_i`` single-sequence forwards (the PR 4 follow-up in
ROADMAP).  The draft model is ``depth_frac`` as deep as the target and reads
a clipped context window of ``window`` tokens; batch and length are padded
right to power-of-two buckets so the jit cache holds O(log B * log window)
programs (right-padding is sound because causal attention never lets
position ``i`` see ``j > i``, and rows are independent — the batched rollout
proposes exactly what the per-request form would).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.serving.request import Request, bucket_pow2
from repro.serving.spec.proposer import Proposer, register


@register("draft-model")
class DraftModelProposer(Proposer):
    """Greedy k-token rollout of a shallow sibling of the target model.

    ``model``/``params`` may be injected (tests, a properly-trained draft);
    otherwise :meth:`bind` derives a ``max(1, L * depth_frac)``-layer copy of
    the engine's ModelConfig and random-initializes it.  A random draft is a
    *bad* guesser — that is fine: bad guesses cost acceptance rate, never
    correctness.
    """

    def __init__(self, model=None, params=None, *, depth_frac: float = 0.5,
                 window: int = 64, seed: int = 17) -> None:
        super().__init__()
        self.model = model
        self.params = params
        self.depth_frac = depth_frac
        self.window = window
        self.seed = seed
        self._fn = None
        self._batch_fn = None

    def bind(self, engine) -> None:
        if self.model is None:
            import jax
            from repro.models.transformer import TransformerLM
            cfg = engine.cfg
            draft_cfg = dataclasses.replace(
                cfg, num_layers=max(1, int(cfg.num_layers * self.depth_frac)))
            self.model = TransformerLM(draft_cfg, remat=False)
            self.params = self.model.init(jax.random.PRNGKey(self.seed))
        self._build_fn()

    def _build_fn(self) -> None:
        import jax
        import jax.numpy as jnp

        def greedy_next(params, toks, idx):
            # toks (1, Lb) right-padded; idx the last real position (traced,
            # so one compile serves every context length in the bucket).
            logits, _ = self.model.forward(params, toks)
            return jnp.argmax(logits[0, idx], axis=-1).astype(jnp.int32)

        def greedy_next_batch(params, toks, idxs):
            # toks (Bb, Lb) right-padded; idxs (Bb,) each row's last real
            # position — ONE forward advances every request's rollout.
            logits, _ = self.model.forward(params, toks)
            rows = logits[jnp.arange(toks.shape[0]), idxs]      # (Bb, V)
            return jnp.argmax(rows, axis=-1).astype(jnp.int32)

        self._fn = jax.jit(greedy_next)
        self._batch_fn = jax.jit(greedy_next_batch)

    def propose(self, req: Request, k: int) -> np.ndarray:
        if k <= 0:
            return np.zeros((0,), np.int32)
        if self._fn is None:            # never bound: nothing to guess with
            return np.zeros((0,), np.int32)
        import jax.numpy as jnp
        ctx = req.resume_tokens()[-self.window:]
        L = len(ctx)
        Lb = bucket_pow2(L + k, lo=16)
        buf = np.zeros((1, Lb), np.int32)
        buf[0, :L] = ctx
        out = np.zeros((k,), np.int32)
        for j in range(k):
            tok = int(self._fn(self.params, jnp.asarray(buf),
                               jnp.int32(L - 1 + j)))
            out[j] = tok
            buf[0, L + j] = tok
        self.count("draft_forwards", k)
        return out

    def propose_batch(self, reqs: List[Tuple[Request, int]]
                      ) -> Dict[int, np.ndarray]:
        """All requests' rollouts in ``k_max`` BATCHED forwards.

        Rows are causally independent, so round ``j`` of the (Bb, Lb)
        forward computes every request's next greedy token at once; a row
        whose ``k`` budget is exhausted just stops consuming its lane.
        Proposes exactly what per-request :meth:`propose` would.
        """
        import jax.numpy as jnp
        out = {req.req_id: np.zeros((0,), np.int32) for req, _ in reqs}
        live = [(req, k) for req, k in reqs if k > 0]
        if not live or self._batch_fn is None:
            return out                  # no budget, or never bound
        ctxs = [req.resume_tokens()[-self.window:] for req, _ in live]
        lens = np.asarray([len(c) for c in ctxs], np.int32)
        kmax = max(k for _, k in live)
        Bb = bucket_pow2(len(live), lo=1)
        Lb = bucket_pow2(int(lens.max()) + kmax, lo=16)
        buf = np.zeros((Bb, Lb), np.int32)
        for i, c in enumerate(ctxs):
            buf[i, :len(c)] = c
        idxs = np.zeros((Bb,), np.int32)
        idxs[:len(live)] = lens - 1
        drafts = np.zeros((len(live), kmax), np.int32)
        for j in range(kmax):
            toks = np.asarray(self._batch_fn(self.params, jnp.asarray(buf),
                                             jnp.asarray(idxs + j)))
            for i, (_, k) in enumerate(live):
                if j < k:
                    drafts[i, j] = toks[i]
                    buf[i, lens[i] + j] = toks[i]
        self.count("draft_forwards", kmax)
        self.count("batched_rollouts")
        for i, (req, k) in enumerate(live):
            out[req.req_id] = drafts[i, :k]
        return out
