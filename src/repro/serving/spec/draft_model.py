"""Draft-model proposer: a small TransformerLM guesses, the big model checks.

Classic two-model speculative decoding.  The draft shares the target's
tokenizer/vocab (it is built from the SAME ``ModelConfig`` with fewer
layers, so its embedding table speaks the same token ids) and rolls out
``k`` greedy tokens host-side; the serving engine then verifies all of them
in one fused target forward.  The draft is deliberately greedy/deterministic
— the delta-distribution acceptance rule in ``repro.serving.spec.verify``
needs no draft probabilities and greedy serving stays bit-reproducible.

Cost model: the draft runs ``k`` single-sequence forwards per proposal on a
model ``depth_frac`` as deep as the target, over a clipped context window of
``window`` tokens (padded right to a power-of-two bucket so the jit cache
holds O(log window) programs, not one per context length — right-padding is
sound because causal attention never lets position ``i`` see ``j > i``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.serving.request import Request, bucket_pow2
from repro.serving.spec.proposer import Proposer, register


@register("draft-model")
class DraftModelProposer(Proposer):
    """Greedy k-token rollout of a shallow sibling of the target model.

    ``model``/``params`` may be injected (tests, a properly-trained draft);
    otherwise :meth:`bind` derives a ``max(1, L * depth_frac)``-layer copy of
    the engine's ModelConfig and random-initializes it.  A random draft is a
    *bad* guesser — that is fine: bad guesses cost acceptance rate, never
    correctness.
    """

    def __init__(self, model=None, params=None, *, depth_frac: float = 0.5,
                 window: int = 64, seed: int = 17) -> None:
        super().__init__()
        self.model = model
        self.params = params
        self.depth_frac = depth_frac
        self.window = window
        self.seed = seed
        self._fn = None

    def bind(self, engine) -> None:
        if self.model is None:
            import jax
            from repro.models.transformer import TransformerLM
            cfg = engine.cfg
            draft_cfg = dataclasses.replace(
                cfg, num_layers=max(1, int(cfg.num_layers * self.depth_frac)))
            self.model = TransformerLM(draft_cfg, remat=False)
            self.params = self.model.init(jax.random.PRNGKey(self.seed))
        self._build_fn()

    def _build_fn(self) -> None:
        import jax
        import jax.numpy as jnp

        def greedy_next(params, toks, idx):
            # toks (1, Lb) right-padded; idx the last real position (traced,
            # so one compile serves every context length in the bucket).
            logits, _ = self.model.forward(params, toks)
            return jnp.argmax(logits[0, idx], axis=-1).astype(jnp.int32)

        self._fn = jax.jit(greedy_next)

    def propose(self, req: Request, k: int) -> np.ndarray:
        if k <= 0:
            return np.zeros((0,), np.int32)
        if self._fn is None:            # never bound: nothing to guess with
            return np.zeros((0,), np.int32)
        import jax.numpy as jnp
        ctx = req.resume_tokens()[-self.window:]
        L = len(ctx)
        Lb = bucket_pow2(L + k, lo=16)
        buf = np.zeros((1, Lb), np.int32)
        buf[0, :L] = ctx
        out = np.zeros((k,), np.int32)
        for j in range(k):
            tok = int(self._fn(self.params, jnp.asarray(buf),
                               jnp.int32(L - 1 + j)))
            out[j] = tok
            buf[0, L + j] = tok
        self.count("draft_forwards", k)
        return out
