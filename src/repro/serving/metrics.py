"""Serving SLO metrics: streaming percentile tracker for TTFT/TPOT
(paper Fig 17e's axes) without storing every sample."""
from __future__ import annotations

import bisect
from dataclasses import dataclass, field
from typing import Dict, List


@dataclass
class LatencyTracker:
    """Exact percentiles via sorted insertion (fine for ≤1e6 samples)."""

    samples: List[float] = field(default_factory=list)

    def record(self, v: float) -> None:
        bisect.insort(self.samples, v)

    def percentile(self, p: float) -> float:
        if not self.samples:
            return 0.0
        i = min(int(p / 100.0 * len(self.samples)), len(self.samples) - 1)
        return self.samples[i]

    @property
    def mean(self) -> float:
        return sum(self.samples) / len(self.samples) if self.samples else 0.0

    def summary(self) -> Dict[str, float]:
        return {"mean": self.mean, "p50": self.percentile(50),
                "p90": self.percentile(90), "p99": self.percentile(99),
                "n": float(len(self.samples))}
