"""Serving SLO metrics: streaming percentile tracker for TTFT/TPOT
(paper Fig 17e's axes) without storing every sample, plus the engine-level
aggregate (:class:`EngineMetrics`) covering the scheduler-driven lifecycle:
latency percentiles, throughput, preemption and prefix-cache counters,
tokens-per-step, and per-step-phase wall-time buckets (propose / schedule /
device / commit) so speculative-decoding overhead is visible without a
profiler."""
from __future__ import annotations

import bisect
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence


def percentile(samples: Sequence[float], p: float) -> float:
    """Nearest-rank percentile: smallest sample with rank >= ceil(pn).

    The ONE percentile definition in the repo — :class:`LatencyTracker` and
    the trace replayer's SLO scoring (:mod:`repro.perf.replay`) both call it,
    so a p99 here and a p99 in a replay row mean the same statistic.  Accepts
    any sequence (sorted or not); empty returns 0.0.
    """
    if not samples:
        return 0.0
    ordered = sorted(samples)
    n = len(ordered)
    i = max(-(-int(p * n) // 100) - 1, 0)         # ceil(p/100 * n) - 1
    return ordered[min(i, n - 1)]


@dataclass
class LatencyTracker:
    """Exact percentiles via sorted insertion (fine for ≤1e6 samples)."""

    samples: List[float] = field(default_factory=list)

    def record(self, v: float) -> None:
        bisect.insort(self.samples, v)

    def percentile(self, p: float) -> float:
        return percentile(self.samples, p)

    @property
    def mean(self) -> float:
        return sum(self.samples) / len(self.samples) if self.samples else 0.0

    def summary(self) -> Dict[str, float]:
        return {"mean": self.mean, "p50": self.percentile(50),
                "p90": self.percentile(90), "p99": self.percentile(99),
                "n": float(len(self.samples))}


@dataclass
class EngineMetrics:
    """Rollup for one serving-engine run.

    The engine records each finished request here; ``summary`` flattens to
    the dict exposed by ``ServingEngine.metrics()``. Wall-clock spans from
    the first recorded request's arrival to the last finish, so tokens/sec
    reflects the whole run, not just decode steps.
    """

    ttft: LatencyTracker = field(default_factory=LatencyTracker)
    tpot: LatencyTracker = field(default_factory=LatencyTracker)
    finished: int = 0
    output_tokens: int = 0
    first_arrival: Optional[float] = None
    last_done: Optional[float] = None
    # Registry-resolved attention backend the run executed with (see
    # repro.core.dispatch) — perf numbers are attributable to ONE impl.
    backend: str = ""
    # Per-step accounting: lane tokens processed vs output tokens emitted
    # (speculative decoding makes these diverge — emitted/steps > 1 is the
    # multi-token-per-step win), plus wall-time per step phase.
    steps: int = 0
    step_tokens: int = 0
    emitted_tokens: int = 0
    # Iterations where nothing was scheduled and nothing was in flight —
    # their wall time lands in phase_s["idle"] instead of vanishing, but
    # they don't count as steps (tokens-per-step keeps its meaning).
    num_idle_steps: int = 0
    phase_s: Dict[str, float] = field(default_factory=dict)

    def record_step(self, *, num_tokens: int, emitted_tokens: int,
                    phases: Dict[str, float], idle: bool = False) -> None:
        """One engine step: lane count, emitted output tokens, phase walls."""
        if idle:
            self.num_idle_steps += 1
        else:
            self.steps += 1
            self.step_tokens += num_tokens
            self.emitted_tokens += emitted_tokens
        for k, v in phases.items():
            self.phase_s[k] = self.phase_s.get(k, 0.0) + v

    def record_finished(self, *, ttft: Optional[float],
                        tpot: Optional[float], num_output_tokens: int,
                        arrival: float, done_at: float) -> None:
        if ttft is not None:
            self.ttft.record(ttft)
        if tpot is not None:
            self.tpot.record(tpot)
        self.finished += 1
        self.output_tokens += num_output_tokens
        self.first_arrival = (arrival if self.first_arrival is None
                              else min(self.first_arrival, arrival))
        self.last_done = (done_at if self.last_done is None
                          else max(self.last_done, done_at))

    @property
    def elapsed_s(self) -> float:
        if self.first_arrival is None or self.last_done is None:
            return 0.0
        return max(self.last_done - self.first_arrival, 0.0)

    def summary(self) -> Dict[str, object]:
        dt = self.elapsed_s
        return {
            "backend": self.backend,
            "finished": self.finished,
            "output_tokens": self.output_tokens,
            "mean_ttft_s": self.ttft.mean,
            "p50_ttft_s": self.ttft.percentile(50),
            "p90_ttft_s": self.ttft.percentile(90),
            "p99_ttft_s": self.ttft.percentile(99),
            "mean_tpot_s": self.tpot.mean,
            "p50_tpot_s": self.tpot.percentile(50),
            "p90_tpot_s": self.tpot.percentile(90),
            "p99_tpot_s": self.tpot.percentile(99),
            "throughput_tok_s": self.output_tokens / dt if dt > 0 else 0.0,
            "steps": self.steps,
            "num_idle_steps": self.num_idle_steps,
            "tokens_per_step": (self.emitted_tokens / self.steps
                                if self.steps else 0.0),
            "lane_tokens_per_step": (self.step_tokens / self.steps
                                     if self.steps else 0.0),
            "phase_s": dict(self.phase_s),
        }
