"""Token sampling: greedy / temperature / top-k / top-p (jit-safe).

:func:`sample` takes scalar (compile-time) knobs — the single-policy path.
:func:`sample_batched` takes PER-REQUEST knobs as arrays, so one compiled
program serves a batch mixing greedy and stochastic requests (the serving
engine's pluggable-sampling path): lanes with ``temperature <= 0`` reduce to
argmax; ``top_k <= 0`` / ``top_p >= 1`` disable the respective filters.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy(logits):
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def sample(key, logits, *, temperature: float = 1.0, top_k: int = 0,
           top_p: float = 1.0):
    """logits (B, V) -> tokens (B,). temperature 0 ⇒ greedy."""
    if temperature <= 0.0:
        return greedy(logits)
    logits = logits.astype(jnp.float32) / temperature
    if top_k > 0:
        kth = jax.lax.top_k(logits, top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    if top_p < 1.0:
        sorted_logits = jnp.sort(logits, axis=-1)[..., ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # smallest set with cumulative prob >= top_p
        cutoff_idx = jnp.sum(cum < top_p, axis=-1, keepdims=True)
        cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx, axis=-1)
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


def filter_logits(lg, temp, kk, pp):
    """One lane's temperature/top-k/top-p filtered f32 logits (V,).

    The masking half of :func:`sample_batched`, shared with the speculative
    verify step (``repro.serving.spec.verify``) so both paths agree on the
    exact target distribution.  ``temp <= 0`` lanes are handled by the
    CALLER (they reduce to argmax over the raw logits).
    """
    V = lg.shape[-1]
    lg32 = lg.astype(jnp.float32)
    scaled = lg32 / jnp.maximum(temp, 1e-6)
    sorted_desc = jnp.sort(scaled)[::-1]
    # top-k: keep logits >= the kth largest (kk <= 0 disables)
    kth = jnp.where(kk > 0,
                    sorted_desc[jnp.clip(kk, 1, V) - 1], -jnp.inf)
    masked = jnp.where(scaled < kth, -jnp.inf, scaled)
    # top-p AFTER top-k (same order as :func:`sample`): smallest prefix
    # of the surviving probs with mass >= pp
    sorted_m = jnp.sort(masked)[::-1]
    probs = jax.nn.softmax(sorted_m)
    cum = jnp.cumsum(probs)
    cutoff_idx = jnp.sum(cum < pp)
    cutoff = sorted_m[jnp.clip(cutoff_idx, 0, V - 1)]
    return jnp.where(masked < cutoff, -jnp.inf, masked)


def sample_batched(key, logits, temperatures, top_ks, top_ps):
    """Per-request sampling under ONE jit: logits (B, V) -> tokens (B,).

    temperatures / top_ps are float (B,), top_ks int (B,). All knobs are
    traced values (not static), so heterogeneous batches share a compiled
    program — no retrace when the request mix changes.
    """
    B, V = logits.shape
    keys = jax.random.split(key, B)

    def one(k, lg, temp, kk, pp):
        masked = filter_logits(lg, temp, kk, pp)
        tok = jax.random.categorical(k, masked)
        return jnp.where(temp <= 0.0, jnp.argmax(lg.astype(jnp.float32)),
                         tok).astype(jnp.int32)

    return jax.vmap(one)(keys, logits, temperatures, top_ks, top_ps)
