"""Disaggregated prefill/decode serving (docs/disaggregated.md).

A *prefill role* :class:`~repro.serving.engine.ServingEngine` fills KV
blocks, a *decode role* engine consumes them; :class:`DisaggEngine` is the
role-aware frontend that routes requests WAITING -> PREFILLING (prefill
engine) -> handoff -> DECODING (decode engine), with block transfer
expressed through the allocator's public reserve/commit API.
"""
from repro.serving.disagg.frontend import (DisaggEngine, copy_block_tokens,
                                           parse_roles)

__all__ = ["DisaggEngine", "copy_block_tokens", "parse_roles"]
