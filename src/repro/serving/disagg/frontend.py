"""Role-aware frontend for disaggregated prefill/decode serving.

Production engines split the compute-bound prefill phase from the
bandwidth/latency-bound decode phase (the paper's §4.2 asymmetry): a burst
of long prompts then saturates the *prefill* engine while the *decode*
engine keeps emitting tokens at its own cadence instead of carrying prompt
chunks inside every fused step.  :class:`DisaggEngine` wires two ordinary
:class:`~repro.serving.engine.ServingEngine` instances into that shape:

  * the **prefill role** engine (``role="prefill"``) admits and chunk-
    prefills prompts exactly like the monolithic engine, but PARKS a
    request whose last chunk commits instead of decoding it;
  * the frontend pops parked requests (``take_prefilled``), performs the
    **handoff**, and submits a decode-side clone to the **decode role**
    engine, which runs the unmodified full engine (speculation, overlap,
    policies, host tier all apply).

Handoff contract (public allocator API only):

  1. the prefill side guarantees every committed token is KV-written
    (``BlockAllocator.transferable`` — the per-block watermark is the
    proof);
  2. the frontend stages the prompt's FULL blocks into the decode pool
    under a reserved negative request id: ``allocate_prefix`` adopts
    whatever the decode cache already holds (HBM hits and host-tier
    promotions both count), ``reserve_tokens``/``commit_tokens`` transfer
    the rest (:func:`copy_block_tokens` moves the raw KV, routed through
    host so cross-device role placement works), ``register_prefix``
    publishes the hashes;
  3. the prefill side frees its copy — the blocks park cached-free, so the
    prefill engine's prefix cache stays warm for repeated prompts;
  4. the decode-side clone is submitted as a fresh WAITING request: normal
    admission adopts every staged block and recomputes only the sub-block
    tail + final logits — exactly the prefix-cache last-token rule — so
    greedy streams are bit-identical to the monolithic engine.  The
    staging id is released only once the clone leaves WAITING, so staged
    blocks cannot be evicted while the clone queues.

Prompts shorter than one KV block carry no transferable KV and route
straight to the decode engine.

Determinism: the frontend loop is strictly serial (one prefill step, the
handoffs it unlocked, then up to ``decode_steps_per_step`` decode steps), so
runs are reproducible — and because greedy token values depend only on KV
*content*, never on step interleaving, outputs are bit-identical to the
monolithic engine for any interleave ratio.  Overlap (``ServeConfig.overlap``)
still hides device time inside each engine's own pipeline; the
``decode_steps_per_step`` knob is what decouples decode cadence from prefill
program latency (the TPOT protection measured by ``benchmarks/disagg.py``).
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import sanitize as sanitize_lib
from repro.config import ModelConfig, ServeConfig
from repro.serving.engine import Request, ServingEngine
from repro.serving.metrics import LatencyTracker
from repro.serving.request import RequestState

__all__ = ["DisaggEngine", "copy_block_tokens"]


def parse_roles(roles: str) -> Tuple[str, ...]:
    """Validate a ``ServeConfig.roles`` string -> role tuple ("" = mono)."""
    if not roles:
        return ()
    parts = tuple(p.strip() for p in roles.replace("+", ",").split(",") if p.strip())
    if parts in (("split",), ("prefill", "decode"), ("decode", "prefill")):
        return ("prefill", "decode")
    raise ValueError(
        f"unsupported roles spec {roles!r}; use 'prefill,decode' (or 'split')")


def copy_block_tokens(dst_pools, src_pools, src_slots: np.ndarray,
                      dst_slots: np.ndarray):
    """Copy per-token KV entries between two layer-stacked pools.

    ``src_slots`` / ``dst_slots`` are (n, 2) ``[block, offset]`` arrays (the
    shape ``reserve_tokens`` returns).  The gather round-trips through host
    (``np.asarray`` forces the source device copy) so the two pools may live
    on different devices; the in-flight-program data dependency on the
    source pool guarantees the content read is the committed content.
    Returns the updated ``dst_pools`` dict.
    """
    sb, so = np.asarray(src_slots[:, 0]), np.asarray(src_slots[:, 1])
    db, do = jnp.asarray(dst_slots[:, 0]), jnp.asarray(dst_slots[:, 1])
    out = dict(dst_pools)
    for c in dst_pools:          # ONE fused kv channel with the fused pool
        # documented host roundtrip — declared to the host-sync sanitizer
        vals = sanitize_lib.host_read(src_pools[c][:, sb, so],
                                      reason="disagg-handoff")  # (L, n, ...)
        out[c] = dst_pools[c].at[:, db, do].set(
            jnp.asarray(vals, dst_pools[c].dtype))
    return out


class DisaggEngine:
    """Two-role disaggregated serving frontend (see module docstring).

    Mirrors the monolithic :class:`ServingEngine` surface the launcher and
    benchmarks use: ``submit`` / ``step`` / ``run_until_done`` /
    ``finished`` / ``metrics``.
    """

    def __init__(self, model, params, cfg: ModelConfig, serve: ServeConfig,
                 *, num_blocks: Optional[int] = None,
                 prefill_blocks: Optional[int] = None,
                 decode_blocks: Optional[int] = None,
                 eos_id: int = -1, token_budget: Optional[int] = None,
                 seed: int = 0, devices: Optional[Sequence] = None,
                 decode_steps_per_step: int = 4):
        if parse_roles(serve.roles or "prefill,decode") != ("prefill",
                                                           "decode"):
            raise ValueError(f"unsupported roles {serve.roles!r}")
        if serve.devices > 1:
            raise ValueError(
                "disaggregated roles run one engine per role; pass per-role "
                "devices via the `devices` pair, not ServeConfig.devices")
        if devices is not None and len(devices) != 2:
            raise ValueError("devices must be a (prefill, decode) pair")
        self._devices = tuple(devices) if devices is not None else (None,
                                                                    None)
        # The prefill role never decodes: speculation is decode-only work,
        # so it is forced off there; everything else (chunk budget, overlap,
        # policies, host tier) applies to both roles.
        pre_serve = dataclasses.replace(serve, roles="", spec="off")
        dec_serve = dataclasses.replace(serve, roles="")

        def build(role: str, sv: ServeConfig, nb: Optional[int], dev):
            ctx = (jax.default_device(dev) if dev is not None
                   else contextlib.nullcontext())
            with ctx:
                p = (jax.device_put(params, dev) if dev is not None
                     else params)
                return ServingEngine(model, p, cfg, sv, num_blocks=nb,
                                     eos_id=eos_id, token_budget=token_budget,
                                     seed=seed, role=role)

        self.pre = build("prefill", pre_serve,
                         prefill_blocks or num_blocks, self._devices[0])
        self.dec = build("full", dec_serve,
                         decode_blocks or num_blocks, self._devices[1])
        self.block_size = serve.kv_block_size
        self.eos_id = eos_id
        self.decode_steps_per_step = max(1, decode_steps_per_step)
        self.finished: List[Request] = self.dec.finished   # shared list
        self.handoff = LatencyTracker()                    # seconds parked
        self.num_handoffs = 0
        self.num_direct = 0        # sub-block prompts routed straight to dec
        self._pending_handoffs: Deque[Tuple[Request, float]] = deque()
        self._originals: Dict[int, Request] = {}
        self._dreqs: Dict[int, Request] = {}
        self._staged: Dict[int, int] = {}                  # rid -> staging id

    # -------------------------------------------------------------- lifecycle
    @staticmethod
    def _clone(req: Request, max_new: int) -> Request:
        """A fresh WAITING copy for one role (identity + policy fields)."""
        return Request(req_id=req.req_id, prompt=req.prompt,
                       max_new_tokens=max_new, sampling=req.sampling,
                       arrival=req.arrival, priority=req.priority,
                       deadline=req.deadline)

    def submit(self, req: Request) -> None:
        if req.req_id < 0:
            raise ValueError(
                f"request {req.req_id}: negative ids are reserved for "
                "handoff staging")
        if req.req_id in self._originals:
            raise ValueError(f"request {req.req_id}: duplicate id")
        full = len(req.prompt) // self.block_size
        if full > 0 and full + 2 > self.dec.alloc.num_blocks:
            raise ValueError(
                f"request {req.req_id}: handoff stages {full} full blocks "
                f"and admission needs 2 more, decode pool has only "
                f"{self.dec.alloc.num_blocks}")
        self._originals[req.req_id] = req
        if full == 0:
            # No transferable KV in a sub-block prompt: the prefill leg
            # would be pure overhead — decode engine prefills it itself.
            dreq = self._clone(req, req.max_new_tokens)
            self._dreqs[req.req_id] = dreq
            self.dec.submit(dreq)
            self.num_direct += 1
            return
        self.pre.submit(self._clone(req, 1))     # max_new sizes decode slack

    def step(self) -> int:
        """One frontend iteration: prefill step -> unlocked handoffs ->
        up to ``decode_steps_per_step`` decode steps.  Returns lane tokens
        processed across both engines."""
        n = 0
        if self.pre.busy:
            n += self.pre.step()
            t = time.perf_counter()
            for req in self.pre.take_prefilled():
                self._pending_handoffs.append((req, t))
        self._try_handoffs()
        for _ in range(self.decode_steps_per_step):
            if not self.dec.busy:
                break
            n += self.dec.step()
            self._release_staged()
            self._try_handoffs()
        return n

    @property
    def busy(self) -> bool:
        return (self.pre.busy or self.dec.busy
                or bool(self._pending_handoffs))

    def run_until_done(self, max_steps: int = 100_000) -> None:
        for _ in range(max_steps):
            if not self.busy:
                return
            self.step()
        raise RuntimeError("disaggregated serving did not converge")

    # ---------------------------------------------------------------- handoff
    def _try_handoffs(self) -> None:
        """Move parked prefills into the decode pool, FIFO, while it fits.

        Worst-case pops of one staging import: every full block fresh plus
        one copy-on-write for an already-fully-cached tail — back-pressure
        keeps the request parked (prefill-side blocks intact) until the
        decode pool can absorb it, so a decode-side burst can never strand
        KV mid-transfer.
        """
        while self._pending_handoffs:
            req, t0 = self._pending_handoffs[0]
            full = len(req.prompt) // self.block_size
            if self.dec.alloc.num_free < full + 1:
                break
            self._pending_handoffs.popleft()
            self._handoff(req)
            self.handoff.record(time.perf_counter() - t0)
            self.num_handoffs += 1

    def _handoff(self, preq: Request) -> None:
        rid = preq.req_id
        prompt = np.asarray(preq.prompt, np.int32)
        bs = self.block_size
        n_import = (len(prompt) // bs) * bs
        pre_alloc, dec_alloc = self.pre.alloc, self.dec.alloc
        assert pre_alloc.seq_len(rid) >= n_import, (
            rid, pre_alloc.seq_len(rid), n_import)
        assert pre_alloc.transferable(rid), (
            f"request {rid}: parked blocks not fully KV-written")
        pre_table = pre_alloc.table(rid)
        hand = -rid - 1                         # staging id (disjoint space)
        cached = dec_alloc.allocate_prefix(hand, prompt)
        if cached < n_import:
            dst = dec_alloc.reserve_tokens(hand, n_import - cached)
            src = np.array([(pre_table[p // bs], p % bs)
                            for p in range(cached, n_import)], np.int32)
            # flush staged CoW/tier traffic first: a whole-block copy or
            # promote applied after our slot writes would clobber them
            self.dec.sync_pools()
            self.dec.pools = copy_block_tokens(self.dec.pools, self.pre.pools,
                                               src, dst)
            dec_alloc.commit_tokens(hand, n_import - cached)
        dec_alloc.register_prefix(hand, prompt, n_import, start=0)
        pre_alloc.free(rid)         # prefill copy parks cached-free (warm)
        dreq = self._clone(self._originals[rid],
                           self._originals[rid].max_new_tokens)
        self._dreqs[rid] = dreq
        self._staged[rid] = hand
        self.dec.submit(dreq)

    def _release_staged(self) -> None:
        """Drop staging holds whose decode clone has been admitted.

        Admission adopted the staged blocks (refcount bump), so releasing
        the staging id cannot drop content a queued clone still needs — the
        hold exists exactly to pin blocks while the clone is WAITING.
        """
        for rid in [r for r, d in self._dreqs.items()
                    if r in self._staged
                    and d.state is not RequestState.WAITING]:
            self.dec.alloc.free(self._staged.pop(rid))

    # ---------------------------------------------------------------- metrics
    def metrics(self) -> Dict[str, object]:
        """Decode-engine metrics (arrival-to-done spans cover the whole
        pipeline since clones keep the original arrival), plus per-role and
        handoff attribution."""
        m = dict(self.dec.metrics())
        pre_m = self.pre.metrics()
        role_keys = ("steps", "num_idle_steps", "lane_tokens_per_step",
                     "output_tokens", "finished", "preemptions",
                     "prefix_hits", "prefix_misses", "backend", "overlap",
                     "phase_s", "tier")
        m["roles"] = {
            "prefill": {**{k: pre_m[k] for k in role_keys},
                        "prefills_completed": self.num_handoffs},
            "decode": {**{k: m[k] for k in role_keys},
                       "direct_submits": self.num_direct},
        }
        m["handoffs"] = self.num_handoffs
        m["handoff_ms"] = {k: (v * 1e3 if k != "n" else v)
                           for k, v in self.handoff.summary().items()}
        # flatten prefill-side tier counters beside the decode ones
        m["policy_counters"] = dict(m["policy_counters"])
        m["policy_counters"].update(
            {f"tier.prefill.{k}": v
             for k, v in sorted(pre_m["tier"].items())
             if k in ("demotes", "promotes", "hits", "drops")})
        m["role"] = "prefill,decode"
        return m
