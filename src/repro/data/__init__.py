from repro.data.pipeline import DataPipeline, SyntheticLMDataset, SyntheticRecSysDataset  # noqa: F401
