"""Deterministic sharded data pipeline with background prefetch.

Design mirrors a production input pipeline:
  * a Dataset yields numpy batches deterministically from (seed, step) —
    restart-safe: resuming at step k reproduces the same stream with no
    state file (the checkpoint only needs the step counter);
  * per-host sharding: host i of n reads only its slice of the global batch
    (``host_slice``), matching multi-host jax.Array construction;
  * a bounded background prefetch thread hides host-side batch synthesis
    (stand-in for tokenization / embedding-id generation I/O).
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


class SyntheticLMDataset:
    """Deterministic token batches: batch (B, S) int32 + loss mask."""

    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 seed: int = 0):
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.seed = seed

    def batch_at(self, step: int, host: int = 0, num_hosts: int = 1
                 ) -> Dict[str, np.ndarray]:
        b = self.global_batch // num_hosts
        rng = np.random.Generator(np.random.Philox(
            key=self.seed, counter=[step, host, 0, 0]))
        tokens = rng.integers(0, self.vocab_size, (b, self.seq_len),
                              dtype=np.int32)
        return {"tokens": tokens}


class SyntheticRecSysDataset:
    """Deterministic DLRM batches (dense features + per-table bag indices)."""

    def __init__(self, cfg, global_batch: int, seed: int = 0):
        self.cfg = cfg
        self.global_batch = global_batch
        self.seed = seed

    def batch_at(self, step: int, host: int = 0, num_hosts: int = 1
                 ) -> Dict[str, np.ndarray]:
        c = self.cfg
        b = self.global_batch // num_hosts
        rng = np.random.Generator(np.random.Philox(
            key=self.seed, counter=[step, host, 0, 0]))
        return {
            "dense": rng.standard_normal((b, c.dense_features),
                                         dtype=np.float32),
            "indices": rng.integers(
                0, c.num_embeddings,
                (b, c.num_tables, c.gathers_per_table), dtype=np.int32),
            "label": rng.integers(0, 2, (b,), dtype=np.int32),
        }


class DataPipeline:
    """Bounded background prefetcher over a deterministic dataset."""

    def __init__(self, dataset, start_step: int = 0, prefetch: int = 2,
                 host: int = 0, num_hosts: int = 1):
        self.dataset = dataset
        self.host = host
        self.num_hosts = num_hosts
        self._step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.dataset.batch_at(step, self.host, self.num_hosts)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        step, batch = self._q.get()
        return step, batch

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
