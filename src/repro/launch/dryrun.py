import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_DRYRUN_XLA_FLAGS") or
                           "--xla_force_host_platform_device_count=512")
# NOTE: the two lines above MUST run before any other import (jax locks the
# device count at first init). REPRO_DRYRUN_XLA_FLAGS exists so tests can run
# the same machinery with 8 host devices.

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes, print memory/cost analysis, and emit the roofline
records consumed by EXPERIMENTS.md.

Usage:
    python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k
    python -m repro.launch.dryrun --all                 # single-pod 16x16
    python -m repro.launch.dryrun --all --multi-pod     # 2x16x16
    python -m repro.launch.dryrun --all --probes        # + depth probes

Roofline trip-count correction: XLA's cost_analysis counts a scan body ONCE,
so for scan-over-layers programs we also compile UNROLLED depth-1 and depth-2
probes (same width/mesh/batch) and extrapolate:
    flops_total = flops(d2) + (depth_units - 2) * (flops(d2) - flops(d1))
Collective bytes are parsed from the full program's HLO (trip-count scaled).
"""
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402

from repro.config import SHAPES, ModelConfig, get_config  # noqa: E402
from repro.configs import ASSIGNED_LM_ARCHS  # noqa: E402
from repro.distributed.sharding import ShardingRules  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.api import build_model, input_specs  # noqa: E402
from repro.optim import adamw, cosine_warmup  # noqa: E402
from repro.roofline.analysis import HW, RooflineReport, xla_costs  # noqa: E402
from repro.roofline.model_flops import model_flops  # noqa: E402
from repro.serving.steps import (  # noqa: E402
    abstract_cache, jit_prefill_step, jit_serve_step)
from repro.training.train_step import (  # noqa: E402
    abstract_state, jit_train_step)

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

SKIP = {
    # long_500k needs sub-quadratic attention (DESIGN.md §7)
    ("qwen3-moe-235b-a22b", "long_500k"): "full attention O(S^2) — skipped",
    ("granite-moe-1b-a400m", "long_500k"): "full attention O(S^2) — skipped",
    ("qwen2-1.5b", "long_500k"): "full attention O(S^2) — skipped",
    ("qwen3-32b", "long_500k"): "full attention O(S^2) — skipped",
    ("internlm2-20b", "long_500k"): "full attention O(S^2) — skipped",
    ("smollm-360m", "long_500k"): "full attention O(S^2) — skipped",
    ("internvl2-26b", "long_500k"): "full attention O(S^2) — skipped",
    ("whisper-tiny", "long_500k"): "full attention O(S^2) — skipped",
}


def _lower_compile(cfg: ModelConfig, cell, mesh, *, scan_layers=True,
                   remat=None, q_chunk=512):
    """Build + lower + compile one cell's step. Returns compiled exe."""
    from repro.distributed.act_sharding import activation_sharding
    from repro.launch.mesh import data_axes

    if remat is None:
        # §Perf C1: 'dots' saves matmul outputs (−17 % recompute FLOPs,
        # measured) for dense archs; MoE keeps full remat — saving the
        # (G,E,C,F) expert activations would cost ~24 GB/device at 235B.
        remat = "full" if cfg.moe is not None else "dots"
    attn = getattr(cfg, "attention", None)
    rules = ShardingRules(mesh, head_dim=attn.head_dim if attn else None)
    import numpy as _np
    kw = {}
    if cfg.moe is not None:
        kw["shard_moe"] = True
        # §Perf B1: per-data-shard grouped MoE dispatch (shard-local gathers)
        kw["moe_groups"] = int(_np.prod(
            [mesh.shape[a] for a in data_axes(mesh)]))
    if not scan_layers:
        # cost probe: unroll the attention q-chunk scan too, so HLO FLOPs
        # count every chunk (XLA cost analysis visits scan bodies once)
        kw["unroll_attn"] = True
    model = build_model(cfg, scan_layers=scan_layers, remat=remat,
                        q_chunk=q_chunk, **kw)
    specs = input_specs(cfg, cell)
    with activation_sharding(data_axes(mesh)):
        if cell.kind == "train":
            opt = adamw()
            state = abstract_state(model, opt)
            step = jit_train_step(model, opt, cosine_warmup(3e-4, 100, 1000),
                                  mesh, rules, state, specs)
            with mesh:
                return step.lower(state, specs).compile()
        if cell.kind == "prefill":
            params = model.init_abstract()
            step = jit_prefill_step(model, mesh, rules, params, specs)
            with mesh:
                return step.lower(params, specs).compile()
        # decode — §Perf iteration A3: donate the cache so the per-layer
        # update is in-place (no full-cache copy per step)
        params = model.init_abstract()
        cache = abstract_cache(model, cell.global_batch, cell.seq_len)
        step = jit_serve_step(model, mesh, rules, params, cache,
                              specs["tokens"], donate=True)
        with mesh:
            return step.lower(params, cache, specs["tokens"]).compile()


def run_cell(arch: str, shape: str, *, multi_pod: bool, probes: bool,
             verbose: bool = True) -> dict:
    cfg = get_config(arch)
    cell = SHAPES[shape]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
           "status": "ok", "ts": time.time()}
    if (arch, shape) in SKIP:
        rec["status"] = "skipped"
        rec["reason"] = SKIP[(arch, shape)]
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.time()
    compiled = _lower_compile(cfg, cell, mesh)
    rec["compile_s"] = time.time() - t0
    costs = xla_costs(compiled)
    rec["full"] = costs
    if verbose:
        print(f"--- {arch} × {shape} × {mesh_name} "
              f"(compile {rec['compile_s']:.1f}s)")
        print(compiled.memory_analysis())
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, list) else ca
        print({k: ca[k] for k in ("flops", "bytes accessed") if k in ca})
        print("collectives:", costs["collectives"])

    # cost_analysis is PER-DEVICE (verified in tests); globalize by chips.
    flops, byts = costs["flops"] * chips, costs["bytes"] * chips
    if probes:
        try:
            c1 = _lower_compile(cfg.with_depth(1), cell, mesh,
                                scan_layers=False)
            c2 = _lower_compile(cfg.with_depth(2), cell, mesh,
                                scan_layers=False)
            x1, x2 = xla_costs(c1), xla_costs(c2)
            units = cfg.depth_units
            flops = (x2["flops"] + (units - 2)
                     * (x2["flops"] - x1["flops"])) * chips
            byts = (x2["bytes"] + (units - 2)
                    * (x2["bytes"] - x1["bytes"])) * chips
            rec["probe_d1"] = {"flops": x1["flops"], "bytes": x1["bytes"]}
            rec["probe_d2"] = {"flops": x2["flops"], "bytes": x2["bytes"]}
        except Exception as e:  # probes are best-effort
            rec["probe_error"] = f"{type(e).__name__}: {e}"

    report = RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=flops, hlo_bytes=byts,
        # parser returns per-device bytes; globalize like flops/bytes
        collective_bytes=costs["collectives"].get("total", 0.0) * chips,
        collectives=costs["collectives"],
        model_flops=model_flops(cfg, cell),
        memory_per_device=costs.get("peak_memory", 0.0), hw=HW())
    rec["roofline"] = report.to_dict()
    if verbose:
        r = report
        print(f"roofline: compute {r.t_compute*1e3:.3f} ms | memory "
              f"{r.t_memory*1e3:.3f} ms | collective {r.t_collective*1e3:.3f}"
              f" ms | bottleneck={r.bottleneck} | useful={r.useful_flops_ratio:.2f}"
              f" | MFU={r.mfu:.3f}")
    return rec


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None, choices=list(SHAPES))
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--all", action="store_true")
    p.add_argument("--probes", action="store_true")
    p.add_argument("--out", default=str(OUT_DIR))
    args = p.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    cells = []
    if args.all:
        for arch in ASSIGNED_LM_ARCHS:
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    failures = 0
    for arch, shape in cells:
        mesh_name = "2x16x16" if args.multi_pod else "16x16"
        out = out_dir / f"{arch}__{shape}__{mesh_name}.json"
        try:
            rec = run_cell(arch, shape, multi_pod=args.multi_pod,
                           probes=args.probes)
        except Exception as e:
            traceback.print_exc()
            rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                   "status": "error", "error": f"{type(e).__name__}: {e}"}
            failures += 1
        out.write_text(json.dumps(rec, indent=2, default=float))
    print(f"\n{len(cells) - failures}/{len(cells)} cells OK")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
