"""Training launcher: ``python -m repro.launch.train --arch smollm-360m
--steps 50 --batch 8 --seq 256`` — runs a real training loop on the local
devices (CPU smoke scale or a real TPU slice; the same code path the
multi-pod dry-run lowers at 16×16/2×16×16)."""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.config import get_config
from repro.data.pipeline import DataPipeline, SyntheticLMDataset
from repro.distributed.sharding import ShardingRules
from repro.launch.mesh import make_smoke_mesh
from repro.models.api import build_model
from repro.optim import adamw, cosine_warmup
from repro.training.train_step import init_state, jit_train_step
from repro.training.trainer import Trainer


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--steps", type=int, default=50)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=256)
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--reduced", action="store_true",
                   help="use the smoke-scale config (CPU-friendly)")
    p.add_argument("--ckpt-dir", default="")
    p.add_argument("--resume", action="store_true")
    p.add_argument("--dtype", default="float32")
    args = p.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(dtype=args.dtype)
    else:
        cfg = dataclasses.replace(cfg, dtype=args.dtype)
    model = build_model(cfg)
    mesh = make_smoke_mesh()
    attn = getattr(cfg, "attention", None)
    rules = ShardingRules(mesh, head_dim=attn.head_dim if attn else None)
    opt = adamw()
    lr_fn = cosine_warmup(args.lr, max(args.steps // 10, 1), args.steps)

    state = init_state(model, jax.random.PRNGKey(0), opt)
    batch_shape = {"tokens": jax.ShapeDtypeStruct((args.batch, args.seq),
                                                  jnp.int32)}
    step = jit_train_step(model, opt, lr_fn, mesh, rules,
                          jax.eval_shape(lambda: state), batch_shape)

    ds = SyntheticLMDataset(cfg.vocab_size, args.seq, args.batch)
    pipe = DataPipeline(ds)
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    trainer = Trainer(step_fn=lambda s, b: step(s, b), state=state,
                      pipeline=pipe, ckpt=ckpt,
                      metrics_hook=lambda i, r: print(
                          f"step {i:5d}  loss {r['loss']:.4f}  "
                          f"{r['dt']*1e3:.0f} ms"))
    if args.resume:
        start = trainer.maybe_restore()
        print(f"resumed from step {start}")
    t0 = time.time()
    with mesh:
        summary = trainer.run(args.steps)
    pipe.close()
    print(f"done in {time.time()-t0:.1f}s: {summary}")


if __name__ == "__main__":
    main()
