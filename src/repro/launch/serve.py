"""Serving launcher: continuous-batching engine with the paged BlockList
PagedAttention (the paper's technique) — ``python -m repro.launch.serve
--arch smollm-360m --requests 8 --reduced``.

``--trace path.json`` replays a recorded/synthetic trace (repro.perf) in
deterministic virtual time instead of the synthetic workload and reports the
SLO scorecard; ``--policy auto`` resolves the whole policy triple from the
committed perf table for the trace's scenario (docs/perf_gate.md)."""
from __future__ import annotations

import argparse
import contextlib
import time

import jax
import numpy as np

from repro.config import ServeConfig, get_config
from repro.models.api import build_model
from repro.serving.engine import Request, ServingEngine


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--requests", type=int, default=8)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--max-new", type=int, default=16)
    p.add_argument("--block-size", type=int, default=16)
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--backend", default="auto",
                   help="operator-backend preference for the paged-attention "
                        "hot path (auto | ref | xla | pallas | "
                        "pallas_interpret); resolved through "
                        "repro.core.dispatch and reported in metrics")
    from repro.serving import policy as policy_lib
    for axis in policy_lib.AXES:
        p.add_argument(f"--{axis}", default=policy_lib.DEFAULTS[axis],
                       choices=policy_lib.names(axis),
                       help=f"serving {axis} policy (repro.serving.policy); "
                            "resolved through the policy registry and "
                            "reported in metrics")
    p.add_argument("--policy", default="",
                   help="convenience triple: one name for all three axes "
                        "(e.g. 'auto') or 'admission/preemption/eviction'; "
                        "overrides the per-axis flags")
    p.add_argument("--trace", default="",
                   help="path to a repro.perf.trace JSON to replay in "
                        "deterministic virtual time instead of the synthetic "
                        "workload (docs/perf_gate.md)")
    p.add_argument("--slo-ttft", type=float, default=1.0,
                   help="p99 TTFT target in virtual seconds for --trace "
                        "scoring")
    p.add_argument("--slo-tpot", type=float, default=0.3,
                   help="p99 TPOT target in virtual seconds for --trace "
                        "scoring")
    from repro.serving import spec as spec_lib
    p.add_argument("--spec", default=spec_lib.OFF,
                   choices=spec_lib.names() + sorted(spec_lib.ALIASES),
                   help="speculative-decoding proposer (repro.serving.spec); "
                        "'off' decodes one token per request per step")
    p.add_argument("--spec-k", type=int, default=4,
                   help="max draft tokens proposed+verified per request "
                        "per step")
    p.add_argument("--devices", type=int, default=0,
                   help="model-axis device count of the serving mesh "
                        "(docs/sharded_serving.md); 0/1 = single-device "
                        "engine, > 1 builds a mesh via repro.launch.mesh "
                        "and runs the sharded fused step (greedy streams "
                        "stay bit-identical)")
    p.add_argument("--overlap", default="off", choices=("on", "off"),
                   help="async overlapped engine loop "
                        "(docs/async_engine.md): step N+1's host work runs "
                        "while step N is on device; greedy streams stay "
                        "bit-identical")
    p.add_argument("--prefetch-depth", type=int, default=0,
                   help="KV-page DMA ring depth for the Pallas chunked "
                        "kernel (0/1 = BlockSpec pipeline, >= 2 = "
                        "multi-buffered manual DMA; ignored by jnp backends)")
    p.add_argument("--q-chunk", type=int, default=16,
                   help="query-tile rows of the chunked paged-attention "
                        "kernel grid (the op family's q_chunk tunable; "
                        "ignored by jnp backends)")
    p.add_argument("--attn-impl", default="ragged",
                   choices=("ragged", "chunked"),
                   help="attention op family for the fused step "
                        "(docs/ragged_kernel.md): 'ragged' = ONE launch for "
                        "prefill + decode over the fused KV pool, 'chunked' "
                        "= the token-lane path on split views; greedy "
                        "streams are bit-identical")
    p.add_argument("--num-queries-per-block", type=int, default=0,
                   help="ragged-kernel query-tile rows (0 = consult the "
                        "committed autotune table BENCH_010.json, falling "
                        "back to the registry default)")
    p.add_argument("--num-kv-pages-per-block", type=int, default=0,
                   help="fused KV pages per ragged grid step — the "
                        "double-buffered DMA ring holds 2x this many pages "
                        "in VMEM (0 = autotune table, then registry default)")
    p.add_argument("--vmem-limit-bytes", type=int, default=0,
                   help="VMEM cap for the ragged kernel's fused-page ring; "
                        "clamps the page group and is forwarded to the "
                        "Mosaic compiler (0 = autotune table / uncapped)")
    p.add_argument("--sanitize", default="off", choices=("on", "off"),
                   help="runtime sanitizers (docs/static_analysis.md): "
                        "retrace guard, host-sync guard around the overlap "
                        "build half, allocator invariant checks after every "
                        "step; counters land in metrics as sanitize.*")
    p.add_argument("--roles", default="",
                   help="'' = monolithic engine; 'prefill,decode' (or "
                        "'split') = disaggregated two-role serving "
                        "(docs/disaggregated.md): prompts prefill on one "
                        "engine, KV blocks hand off through the allocator, "
                        "decode runs on the other; greedy streams stay "
                        "bit-identical")
    p.add_argument("--host-blocks", type=int, default=0,
                   help="host-memory KV tier capacity in blocks (0 = "
                        "HBM-only): evicted cached-free blocks demote to a "
                        "host LRU and promote back on prefix hit — pair "
                        "with --eviction tiered (docs/disaggregated.md)")
    args = p.parse_args()
    if args.policy:
        parts = args.policy.split("/")
        if len(parts) == 1:
            parts = parts * 3
        if len(parts) != 3:
            p.error("--policy takes one name or admission/preemption/eviction")
        args.admission, args.preemption, args.eviction = parts

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(dtype="float32")
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    serve = ServeConfig(model=args.arch, kv_block_size=args.block_size,
                        max_batch=args.requests, backend=args.backend,
                        admission=args.admission, preemption=args.preemption,
                        eviction=args.eviction, spec=args.spec,
                        spec_k=args.spec_k, devices=args.devices,
                        overlap=args.overlap == "on",
                        prefetch_depth=args.prefetch_depth,
                        q_chunk=args.q_chunk,
                        attn_impl=args.attn_impl,
                        num_queries_per_block=args.num_queries_per_block,
                        num_kv_pages_per_block=args.num_kv_pages_per_block,
                        vmem_limit_bytes=args.vmem_limit_bytes,
                        sanitize=args.sanitize == "on",
                        roles=args.roles, host_blocks=args.host_blocks,
                        trace=args.trace)
    trace = None
    ctx = contextlib.nullcontext()
    if serve.trace:
        from repro.perf.table import perf_context
        from repro.perf.trace import LengthModel, Trace
        trace = Trace.load(serve.trace)
        # Full-fit pool for the demo CLI; the benchmark scenarios starve the
        # pool deliberately, the launcher shouldn't.
        total_blocks = sum(
            -(-(len(r.prompt) + r.max_new_tokens) // args.block_size) + 1
            for r in trace.requests)
        # The replay context keys the `auto` triple's perf-table lookup and
        # feeds predicted-length's cost model; engines resolve policies at
        # construction, so it must wrap the ctor.
        ctx = perf_context(scenario=trace.scenario,
                           length_model=LengthModel.fit(trace))
    else:
        total_blocks = args.requests * (
            -(-(args.prompt_len + args.max_new) // args.block_size) + 1)
    # ServeConfig.devices > 1 makes the engine build the serving mesh itself
    # (repro.launch.mesh.make_serving_mesh) and run the sharded fused step.
    # ServeConfig.roles builds the disaggregated two-role frontend instead:
    # prefill and decode engines each get the full pool (equal HBM per
    # role), pinned to separate devices when the host has two or more.
    with ctx:
        if serve.roles:
            from repro.serving.disagg import DisaggEngine
            devs = jax.devices()
            pair = (devs[0], devs[1]) if len(devs) >= 2 else None
            engine = DisaggEngine(model, params, cfg, serve,
                                  num_blocks=total_blocks, devices=pair)
        else:
            engine = ServingEngine(model, params, cfg, serve,
                                   num_blocks=total_blocks)

    t0 = time.time()
    if trace is not None:
        from repro.perf import replay as replay_lib
        result = replay_lib.replay(engine, trace)
        report = replay_lib.score(result, replay_lib.Slo(
            ttft_s=args.slo_ttft, tpot_s=args.slo_tpot))
    else:
        rng = np.random.default_rng(0)
        for i in range(args.requests):
            engine.submit(Request(
                req_id=i,
                prompt=rng.integers(0, cfg.vocab_size, (args.prompt_len,),
                                    dtype=np.int32),
                max_new_tokens=args.max_new))
        engine.run_until_done()
    dt = time.time() - t0
    m = engine.metrics()
    if trace is not None:
        c = result.counters()
        print(f"replayed trace {trace.name} [{trace.scenario}] "
              f"{len(trace.requests)} requests in {result.steps} virtual "
              f"steps ({c['idle_ff']} idle fast-forwards)")
        print(f"virtual TTFT p50 {report.p50_ttft_s:.2f} / p99 "
              f"{report.p99_ttft_s:.2f} s  TPOT p50 {report.p50_tpot_s:.3f} "
              f"/ p99 {report.p99_tpot_s:.3f} s  attainment "
              f"ttft={report.attainment_ttft:.0%} "
              f"tpot={report.attainment_tpot:.0%}  "
              f"SLO {'MET' if report.ok else 'MISSED'} "
              f"(targets {args.slo_ttft}s / {args.slo_tpot}s)")
    print(f"served {m['finished']} requests, {m['output_tokens']} tokens "
          f"in {dt:.2f}s ({m['output_tokens']/dt:.1f} tok/s) "
          f"[backend={m['backend']} devices={m['devices']} "
          f"mesh={m['mesh_shape']} overlap={m['overlap']} "
          f"prefetch_depth={m['prefetch_depth']} q_chunk={m['q_chunk']}]")
    print(f"attn {m['attn_impl']}  "
          f"num_queries_per_block={m['num_queries_per_block']}  "
          f"num_kv_pages_per_block={m['num_kv_pages_per_block']}  "
          f"vmem_limit_bytes={m['vmem_limit_bytes']}")
    print(f"TTFT p50 {m['p50_ttft_s']*1e3:.1f} / p99 {m['p99_ttft_s']*1e3:.1f} ms  "
          f"TPOT p50 {m['p50_tpot_s']*1e3:.1f} / p99 {m['p99_tpot_s']*1e3:.1f} ms")
    print(f"preemptions {m['preemptions']}  "
          f"prefix hit rate {m['prefix_hit_rate']:.2f}  "
          f"cow copies {m['cow_copies']}")
    print(f"policies {m['admission_policy']}/{m['preemption_policy']}/"
          f"{m['eviction_policy']}  counters {m['policy_counters']}")
    t = m["tier"]
    print(f"role {m['role']}  tier hbm={t['hbm_blocks']} "
          f"host={t['host_blocks']} (used {t['host_blocks_used']})  "
          f"demotes {t['demotes']}  promotes {t['promotes']}  "
          f"hits {t['hits']}  drops {t['drops']}")
    if serve.roles:
        h = m["handoff_ms"]
        print(f"handoffs {m['handoffs']}  latency p50 {h['p50']:.2f} / "
              f"p99 {h['p99']:.2f} ms  prefill steps "
              f"{m['roles']['prefill']['steps']}  decode steps "
              f"{m['roles']['decode']['steps']}")
    sz = m["sanitize"]
    if sz["enabled"]:
        print(f"sanitize on  retraces {sz['retraces']}  "
              f"host-sync trips {sz['transfer_guard_trips']}  "
              f"invariant checks {sz['invariant_checks']}  "
              f"allowed host syncs {sz['allowed_host_syncs']}")
    s = m["spec"]
    print(f"spec {s['proposer']} k={s['k']}  "
          f"accept_rate {s['acceptance_rate']:.2f}  "
          f"mean_accepted {s['mean_accepted_len']:.2f}  "
          f"tokens/step {m['tokens_per_step']:.2f}")


if __name__ == "__main__":
    main()
