"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — required because the dry-run
sets ``xla_force_host_platform_device_count`` before first jax init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single pod (256 chips) or 2×16×16 (512 chips, 2 pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over whatever devices exist (tests on 1 CPU device)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, n // data)
    return jax.make_mesh((data, model), ("data", "model"))


def data_axes(mesh) -> tuple:
    """Batch-sharding axes for a mesh (includes 'pod' when present)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)
