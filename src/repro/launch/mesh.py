"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — required because the dry-run
sets ``xla_force_host_platform_device_count`` before first jax init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single pod (256 chips) or 2×16×16 (512 chips, 2 pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over whatever devices exist (tests on 1 CPU device)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, n // data)
    return jax.make_mesh((data, model), ("data", "model"))


def make_serving_mesh(model: int = 0):
    """(1, model) mesh for the sharded serving engine.

    ``model`` is the model-axis device count (``ServeConfig.devices``);
    0 means "all local devices".  An explicit count the host cannot supply
    raises — silently serving on fewer devices than requested would make
    every ``devices=``-attributed number a lie.  The engine TP-shards
    params over ``model`` and sequence-shards the KV pool's block dimension
    over it — the data axis exists (size 1) so ``ShardingRules`` sees its
    usual axis names (docs/sharded_serving.md).
    """
    n = len(jax.devices())
    if model > n:
        raise ValueError(
            f"make_serving_mesh: {model} model-axis devices requested but "
            f"only {n} local device(s) exist (set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={model} on CPU hosts)")
    model = n if model <= 0 else model
    return jax.make_mesh((1, model), ("data", "model"))


def data_axes(mesh) -> tuple:
    """Batch-sharding axes for a mesh (includes 'pod' when present)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)
