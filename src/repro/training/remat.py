"""Activation-checkpoint (remat) policies.

§Perf iteration C1: ``full`` remat recomputes the whole layer in backward
(≈2× forward memory traffic); ``dots`` saves matmul outputs and recomputes
only cheap elementwise ops — the standard MaxText-style trade of HBM
capacity for bandwidth. ``none`` disables checkpointing (smoke tests).
"""
from __future__ import annotations

from typing import Callable, Union

import jax

Mode = Union[bool, str]


def resolve(mode: Mode) -> str:
    if mode is True:
        return "full"
    if mode is False:
        return "none"
    assert mode in ("full", "dots", "none"), mode
    return mode


def wrap(fn: Callable, mode: Mode) -> Callable:
    mode = resolve(mode)
    if mode == "none":
        return fn
    if mode == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)
