"""pjit train step: loss → grads → AdamW update, fully sharded (GSPMD).

One step function serves every architecture; sharding comes from
``ShardingRules`` (FSDP over data, TP/EP over model, DP over pod).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.optim.optimizer import Optimizer, apply_updates


class TrainState(NamedTuple):
    params: Any
    opt: Any
    step: jnp.ndarray


def init_state(model, key, optimizer: Optimizer) -> TrainState:
    params = model.init(key)
    return TrainState(params=params, opt=optimizer.init(params),
                      step=jnp.zeros((), jnp.int32))


def abstract_state(model, optimizer: Optimizer) -> TrainState:
    """eval_shape'd TrainState — dry-run input without allocation."""
    return jax.eval_shape(
        lambda: init_state(model, jax.random.PRNGKey(0), optimizer))


def make_train_step(model, optimizer: Optimizer,
                    lr_fn: Callable[[jnp.ndarray], jnp.ndarray]):
    """Returns step(state, batch) -> (state, metrics)."""

    def step(state: TrainState, batch) -> tuple:
        loss, grads = jax.value_and_grad(model.loss)(state.params, batch)
        lr = lr_fn(state.step)
        updates, opt_state, gnorm = optimizer.update(
            grads, state.opt, state.params, lr)
        params = apply_updates(state.params, updates)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return TrainState(params, opt_state, state.step + 1), metrics

    return step


def state_specs(rules, state_shape: TrainState):
    """PartitionSpec TrainState matching an abstract state."""
    from jax.sharding import PartitionSpec as P
    p_spec = rules.params_tree(state_shape.params)
    opt_spec = _opt_specs(rules, state_shape)
    return TrainState(params=p_spec, opt=opt_spec, step=P())


def _opt_specs(rules, state_shape: TrainState):
    """Moments share the param spec; step counters replicate."""
    from jax.sharding import PartitionSpec as P
    p_spec = rules.params_tree(state_shape.params)
    opt = state_shape.opt
    # NamedTuple (AdamWState / SGDState): first field is step
    fields = opt._fields
    new = {}
    for f in fields:
        v = getattr(opt, f)
        if f == "step":
            new[f] = P()
        else:
            new[f] = p_spec
    return type(opt)(**new)


def jit_train_step(model, optimizer, lr_fn, mesh, rules, state_shape,
                   batch_shape, donate: bool = True):
    """Fully-specified pjit train step ready to lower/compile."""
    from jax.sharding import NamedSharding

    step = make_train_step(model, optimizer, lr_fn)
    s_spec = state_specs(rules, state_shape)
    b_spec = jax.tree.map(lambda s: rules.batch_spec(s.shape), batch_shape)
    named = partial(jax.tree.map, lambda sp: NamedSharding(mesh, sp))
    in_sh = (named(s_spec), named(b_spec))
    out_sh = (named(s_spec), None)
    return jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                   donate_argnums=(0,) if donate else ())
