"""Sharding-friendly losses.

``softmax_cross_entropy`` is vocab-parallel safe (Megatron-style): the
normalizer is a reduction over the (model-sharded) vocab dim and the target
logit is an iota-select-reduce — XLA fuses both into local loops + tiny
(B,S) all-reduces. The naive ``log_softmax`` + ``take_along_axis`` form
all-gathers the full (B,S,V) logits (~100 GB at 4k×152k — measured).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def softmax_cross_entropy(logits, targets):
    """logits (..., V) any dtype; targets (...) int32 -> nll (...) f32."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    V = logits.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    tgt = jnp.sum(jnp.where(iota == targets[..., None], logits, 0.0), axis=-1)
    return lse - tgt


def next_token_loss(logits, tokens, loss_mask=None):
    """Next-token CE over (B, S, V) logits vs (B, S) tokens."""
    nll = softmax_cross_entropy(logits[:, :-1], tokens[:, 1:])
    if loss_mask is not None:
        m = loss_mask[:, 1:].astype(jnp.float32)
        return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)
    return jnp.mean(nll)
