"""Production trainer loop: data pipeline + pjit step + async checkpoints +
straggler watchdog + elastic restart hooks."""
from __future__ import annotations

import time
from typing import Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.distributed.elastic import StragglerWatchdog
from repro.training.train_step import TrainState


class Trainer:
    def __init__(self, *, step_fn: Callable, state: TrainState, pipeline,
                 ckpt: Optional[CheckpointManager] = None,
                 checkpoint_every: int = 200,
                 log_every: int = 10,
                 watchdog: Optional[StragglerWatchdog] = None,
                 metrics_hook: Optional[Callable[[int, Dict], None]] = None):
        self.step_fn = step_fn
        self.state = state
        self.pipeline = pipeline
        self.ckpt = ckpt
        self.checkpoint_every = checkpoint_every
        self.log_every = log_every
        self.watchdog = watchdog or StragglerWatchdog()
        self.metrics_hook = metrics_hook
        self.history: list = []

    def maybe_restore(self) -> int:
        """Resume from the latest checkpoint if one exists."""
        if self.ckpt is None:
            return 0
        latest = self.ckpt.latest_step()
        if latest is None:
            return 0
        def placer(x, like):
            sharding = getattr(like, "sharding", None)
            return (jax.device_put(x, sharding) if sharding is not None
                    else jax.device_put(x))
        self.state = self.ckpt.restore(latest, self.state, placer=placer)
        return latest

    def run(self, num_steps: int) -> Dict[str, float]:
        last_loss = float("nan")
        for _ in range(num_steps):
            step_idx, batch = next(self.pipeline)
            t0 = time.time()
            self.state, metrics = self.step_fn(self.state, batch)
            loss = float(metrics["loss"])          # sync point
            dt = time.time() - t0
            slow = self.watchdog.record(step_idx, dt)
            rec = {"step": step_idx, "loss": loss, "dt": dt, "slow": slow,
                   "grad_norm": float(metrics["grad_norm"])}
            self.history.append(rec)
            last_loss = loss
            if self.metrics_hook and step_idx % self.log_every == 0:
                self.metrics_hook(step_idx, rec)
            if (self.ckpt is not None and self.checkpoint_every
                    and (step_idx + 1) % self.checkpoint_every == 0):
                self.ckpt.save(step_idx + 1, self.state)
        if self.ckpt is not None:
            self.ckpt.wait()
        losses = [h["loss"] for h in self.history]
        return {
            "final_loss": last_loss,
            "min_loss": min(losses) if losses else float("nan"),
            "mean_dt": float(np.mean([h["dt"] for h in self.history])),
            "straggler_steps": len(self.watchdog.slow_steps),
        }
