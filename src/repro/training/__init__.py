from repro.training.train_step import TrainState, make_train_step  # noqa: F401
