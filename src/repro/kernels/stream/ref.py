"""Oracles for the STREAM microbenchmarks (paper Alg 1)."""


def add_ref(a, b):
    return a + b


def scale_ref(a, scalar):
    return scalar * a


def triad_ref(a, b, scalar):
    return scalar * a + b
