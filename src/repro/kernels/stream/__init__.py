from repro.kernels.stream.ops import stream_add, stream_scale, stream_triad  # noqa: F401
