"""Public jit'd wrappers for the STREAM kernels (1D API, auto 2D tiling)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.stream.kernel import (
    LANES, add_pallas, scale_pallas, triad_pallas)
from repro.kernels.stream.ref import add_ref, scale_ref, triad_ref


def _to2d(x):
    n = x.shape[0]
    assert n % LANES == 0, n
    return x.reshape(n // LANES, LANES)


@partial(jax.jit, static_argnames=("block_rows", "backend"))
def stream_add(a, b, block_rows: int = 256, backend: str = "auto"):
    if backend == "ref":
        return add_ref(a, b)
    interpret = jax.default_backend() != "tpu" or backend == "interpret"
    return add_pallas(_to2d(a), _to2d(b), block_rows=block_rows,
                      interpret=interpret).reshape(a.shape)


@partial(jax.jit, static_argnames=("block_rows", "backend"))
def stream_scale(a, scalar, block_rows: int = 256, backend: str = "auto"):
    if backend == "ref":
        return scale_ref(a, scalar)
    interpret = jax.default_backend() != "tpu" or backend == "interpret"
    return scale_pallas(_to2d(a), scalar, block_rows=block_rows,
                        interpret=interpret).reshape(a.shape)


@partial(jax.jit, static_argnames=("block_rows", "backend"))
def stream_triad(a, b, scalar, block_rows: int = 256, backend: str = "auto"):
    if backend == "ref":
        return triad_ref(a, b, scalar)
    interpret = jax.default_backend() != "tpu" or backend == "interpret"
    return triad_pallas(_to2d(a), _to2d(b), scalar, block_rows=block_rows,
                        interpret=interpret).reshape(a.shape)
