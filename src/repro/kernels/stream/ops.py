"""STREAM ops (1D API, auto 2D tiling) through the unified registry.

Registers ``stream_add`` / ``stream_scale`` / ``stream_triad`` implementations
with :mod:`repro.core.dispatch`; the shared resolver owns backend selection.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import dispatch
from repro.kernels.stream.kernel import (
    LANES, add_pallas, scale_pallas, triad_pallas)
from repro.kernels.stream.ref import add_ref, scale_ref, triad_ref


def _to2d(x):
    n = x.shape[0]
    assert n % LANES == 0, n
    return x.reshape(n // LANES, LANES)


def _tileable(spec: dispatch.CallSpec) -> bool:
    """Pallas tiling needs a 1D array of whole 128-lane rows."""
    if not spec.args:
        return True
    a = spec.args[0]
    return a.ndim == 1 and a.shape[0] % LANES == 0


def _pallas_supported(spec: dispatch.CallSpec) -> bool:
    return dispatch.on_tpu(spec) and _tileable(spec)


def _example_add():
    a = jnp.arange(2 * LANES, dtype=jnp.float32)
    b = jnp.ones((2 * LANES,), jnp.float32)
    return (a, b), {"block_rows": 1}


def _example_scale():
    a = jnp.arange(2 * LANES, dtype=jnp.float32)
    return (a, 3.0), {"block_rows": 1}


def _example_triad():
    a = jnp.arange(2 * LANES, dtype=jnp.float32)
    b = jnp.ones((2 * LANES,), jnp.float32)
    return (a, b, 3.0), {"block_rows": 1}


_ADD = dispatch.op("stream_add", example=_example_add,
                   doc="STREAM ADD: a + b over 1D arrays")
_SCALE = dispatch.op("stream_scale", example=_example_scale,
                     doc="STREAM SCALE: s * a over 1D arrays")
_TRIAD = dispatch.op("stream_triad", example=_example_triad,
                     doc="STREAM TRIAD: s * a + b over 1D arrays")


@_ADD.register("ref")
@partial(jax.jit, static_argnames=("block_rows",))
def _add_ref(a, b, block_rows: int = 256):
    del block_rows
    return add_ref(a, b)


@_ADD.register("pallas", supports=_pallas_supported)
@partial(jax.jit, static_argnames=("block_rows",))
def _add_pallas(a, b, block_rows: int = 256):
    return add_pallas(_to2d(a), _to2d(b), block_rows=block_rows,
                      interpret=False).reshape(a.shape)


@_ADD.register("pallas_interpret", supports=_tileable)
@partial(jax.jit, static_argnames=("block_rows",))
def _add_interpret(a, b, block_rows: int = 256):
    return add_pallas(_to2d(a), _to2d(b), block_rows=block_rows,
                      interpret=True).reshape(a.shape)


@_SCALE.register("ref")
@partial(jax.jit, static_argnames=("block_rows",))
def _scale_ref(a, scalar, block_rows: int = 256):
    del block_rows
    return scale_ref(a, scalar)


@_SCALE.register("pallas", supports=_pallas_supported)
@partial(jax.jit, static_argnames=("block_rows",))
def _scale_pallas(a, scalar, block_rows: int = 256):
    return scale_pallas(_to2d(a), scalar, block_rows=block_rows,
                        interpret=False).reshape(a.shape)


@_SCALE.register("pallas_interpret", supports=_tileable)
@partial(jax.jit, static_argnames=("block_rows",))
def _scale_interpret(a, scalar, block_rows: int = 256):
    return scale_pallas(_to2d(a), scalar, block_rows=block_rows,
                        interpret=True).reshape(a.shape)


@_TRIAD.register("ref")
@partial(jax.jit, static_argnames=("block_rows",))
def _triad_ref(a, b, scalar, block_rows: int = 256):
    del block_rows
    return triad_ref(a, b, scalar)


@_TRIAD.register("pallas", supports=_pallas_supported)
@partial(jax.jit, static_argnames=("block_rows",))
def _triad_pallas(a, b, scalar, block_rows: int = 256):
    return triad_pallas(_to2d(a), _to2d(b), scalar, block_rows=block_rows,
                        interpret=False).reshape(a.shape)


@_TRIAD.register("pallas_interpret", supports=_tileable)
@partial(jax.jit, static_argnames=("block_rows",))
def _triad_interpret(a, b, scalar, block_rows: int = 256):
    return triad_pallas(_to2d(a), _to2d(b), scalar, block_rows=block_rows,
                        interpret=True).reshape(a.shape)


def stream_add(a, b, block_rows: int = 256, backend=None):
    return _ADD(a, b, block_rows=block_rows, backend=backend)


def stream_scale(a, scalar, block_rows: int = 256, backend=None):
    return _SCALE(a, scalar, block_rows=block_rows, backend=backend)


def stream_triad(a, b, scalar, block_rows: int = 256, backend=None):
    return _TRIAD(a, b, scalar, block_rows=block_rows, backend=backend)
