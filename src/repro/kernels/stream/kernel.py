"""STREAM kernels (paper Alg 1: ADD / SCALE / TRIAD) as Pallas pipelines.

The paper sweeps Gaudi data-access granularity (256 B cliff) and unroll
factor; the TPU analogue is the BlockSpec tile shape: ``block_rows`` rows of
128 lanes per grid step. The benchmark harness sweeps block_rows to expose
the HBM→VMEM pipeline-efficiency curve (the TPU's "access granularity" —
small tiles under-utilize the DMA engine exactly like sub-256 B accesses on
Gaudi; the pipelined grid is the analogue of loop unrolling).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat

LANES = 128


def _add_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] + b_ref[...]


def _scale_kernel(s_ref, a_ref, o_ref):
    o_ref[...] = s_ref[0] * a_ref[...]


def _triad_kernel(s_ref, a_ref, b_ref, o_ref):
    o_ref[...] = s_ref[0] * a_ref[...] + b_ref[...]


def _call(kernel, args, rows, block_rows, dtype, n_scalar=0,
          interpret=True):
    grid = (rows // block_rows,)
    spec = pl.BlockSpec((block_rows, LANES), lambda i, *_: (i, 0))
    n_in = len(args) - n_scalar
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=n_scalar,
        grid=grid,
        in_specs=[spec] * n_in,
        out_specs=spec,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((rows, LANES), dtype),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(*args)


def add_pallas(a, b, *, block_rows: int = 256, interpret: bool = True):
    """a, b (rows, 128)."""
    return _call(_add_kernel, (a, b), a.shape[0], block_rows, a.dtype,
                 interpret=interpret)


def scale_pallas(a, scalar, *, block_rows: int = 256, interpret: bool = True):
    s = jnp.asarray([scalar], a.dtype)
    return _call(_scale_kernel, (s, a), a.shape[0], block_rows, a.dtype,
                 n_scalar=1, interpret=interpret)


def triad_pallas(a, b, scalar, *, block_rows: int = 256,
                 interpret: bool = True):
    s = jnp.asarray([scalar], a.dtype)
    return _call(_triad_kernel, (s, a, b), a.shape[0], block_rows, a.dtype,
                 n_scalar=1, interpret=interpret)
