"""Pallas TPU kernels for the paper's compute hot-spots.

Each kernel subpackage has:
  kernel.py — ``pl.pallas_call`` + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — registers ref / pallas / pallas_interpret implementations with
              the unified operator-backend registry (``repro.core.dispatch``)
              and exposes thin public wrappers; NO per-file dispatch
  ref.py    — pure-jnp oracle used by the allclose test sweeps

Kernels are validated with ``interpret=True`` on CPU; on TPU the same code
compiles via Mosaic. The jnp reference path (not interpret mode) is what the
dry-run lowers, so cost analysis reflects XLA's view of the same math.
Backend selection (explicit arg > scope > env > config > capability-ranked
auto) is documented in docs/backends.md.
"""
