"""Pallas TPU kernels for the paper's compute hot-spots.

Each kernel subpackage has:
  kernel.py — ``pl.pallas_call`` + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper (shape plumbing, dispatch, interpret flag)
  ref.py    — pure-jnp oracle used by the allclose test sweeps

Kernels are validated with ``interpret=True`` on CPU; on TPU the same code
compiles via Mosaic. The jnp reference path (not interpret mode) is what the
dry-run lowers, so cost analysis reflects XLA's view of the same math.
"""
