"""Version compatibility for jax APIs the kernels/serving stack touches.

* jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams`` (and
  back, depending on release line); resolve whichever this install provides
  once so every kernel call site stays version-agnostic.
* ``shard_map`` graduated from ``jax.experimental.shard_map`` to the top
  level around 0.5; the sharded serving path imports it from here.
"""
from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")

try:                                    # jax >= 0.5 exposes it at top level
    from jax import shard_map
except ImportError:                     # pragma: no cover - version compat
    from jax.experimental.shard_map import shard_map  # noqa: F401
