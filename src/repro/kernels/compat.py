"""Version compatibility for jax APIs the kernels/serving stack touches.

* jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams`` (and
  back, depending on release line); resolve whichever this install provides
  once so every kernel call site stays version-agnostic.
* ``shard_map`` graduated from ``jax.experimental.shard_map`` to the top
  level around 0.5; the sharded serving path imports it from here.
"""
import dataclasses
import inspect

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")


def _compiler_param_names():
    try:
        return {f.name for f in dataclasses.fields(CompilerParams)}
    except TypeError:                   # pragma: no cover - version compat
        return set(inspect.signature(CompilerParams).parameters)


_PARAM_NAMES = _compiler_param_names()


def compiler_params(**kwargs):
    """``CompilerParams`` filtered to the fields this jax version accepts.

    Newer knobs (``vmem_limit_bytes``) silently drop on older releases —
    they are performance hints, never semantics — and ``None`` values are
    treated as "unset" so callers can thread optional tunables straight
    through.
    """
    return CompilerParams(**{k: v for k, v in kwargs.items()
                             if k in _PARAM_NAMES and v is not None})

try:                                    # jax >= 0.5 exposes it at top level
    from jax import shard_map
except ImportError:                     # pragma: no cover - version compat
    from jax.experimental.shard_map import shard_map  # noqa: F401
