"""Version compatibility for Pallas TPU APIs.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams`` (and back,
depending on release line); resolve whichever this install provides once so
every kernel call site stays version-agnostic.
"""
from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")
