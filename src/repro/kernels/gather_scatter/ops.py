"""Random vector gather/scatter through the unified registry.

Registers ``vector_gather`` / ``vector_scatter`` implementations with
:mod:`repro.core.dispatch`; the shared resolver owns backend selection.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import dispatch
from repro.kernels.gather_scatter.kernel import gather_pallas, scatter_pallas
from repro.kernels.gather_scatter.ref import gather_ref, scatter_ref


def _example_gather():
    tbl = jax.random.normal(jax.random.PRNGKey(0), (32, 128), jnp.float32)
    idx = jax.random.randint(jax.random.PRNGKey(1), (8,), 0, 32)
    return (tbl, idx), {}


def _example_scatter():
    tbl = jax.random.normal(jax.random.PRNGKey(0), (32, 128), jnp.float32)
    # unique indices: scatter order must not matter for the parity check
    idx = jnp.asarray([3, 17, 0, 9, 21, 30, 5, 11], jnp.int32)
    src = jax.random.normal(jax.random.PRNGKey(2), (8, 128), jnp.float32)
    return (tbl, idx, src), {}


_GATHER = dispatch.op("vector_gather", example=_example_gather,
                      doc="GUPS-style random row gather: table[idx]")
_SCATTER = dispatch.op("vector_scatter", example=_example_scatter,
                       doc="GUPS-style random row scatter: table.at[idx].set")


@_GATHER.register("ref")
@jax.jit
def _gather_ref(table, idx):
    return gather_ref(table, idx)


@_GATHER.register("pallas")
@jax.jit
def _gather_pallas(table, idx):
    return gather_pallas(table, idx, interpret=False)


@_GATHER.register("pallas_interpret")
@jax.jit
def _gather_interpret(table, idx):
    return gather_pallas(table, idx, interpret=True)


@_SCATTER.register("ref")
@jax.jit
def _scatter_ref(table, idx, src):
    return scatter_ref(table, idx, src)


@_SCATTER.register("pallas")
@jax.jit
def _scatter_pallas(table, idx, src):
    return scatter_pallas(table, idx, src, interpret=False)


@_SCATTER.register("pallas_interpret")
@jax.jit
def _scatter_interpret(table, idx, src):
    return scatter_pallas(table, idx, src, interpret=True)


def vector_gather(table, idx, backend=None):
    return _GATHER(table, idx, backend=backend)


def vector_scatter(table, idx, src, backend=None):
    return _SCATTER(table, idx, src, backend=backend)
