"""Public jit'd wrappers for random vector gather/scatter."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.gather_scatter.kernel import gather_pallas, scatter_pallas
from repro.kernels.gather_scatter.ref import gather_ref, scatter_ref


@partial(jax.jit, static_argnames=("backend",))
def vector_gather(table, idx, backend: str = "auto"):
    if backend == "ref":
        return gather_ref(table, idx)
    interpret = jax.default_backend() != "tpu" or backend == "interpret"
    return gather_pallas(table, idx, interpret=interpret)


@partial(jax.jit, static_argnames=("backend",))
def vector_scatter(table, idx, src, backend: str = "auto"):
    if backend == "ref":
        return scatter_ref(table, idx, src)
    interpret = jax.default_backend() != "tpu" or backend == "interpret"
    return scatter_pallas(table, idx, src, interpret=interpret)
