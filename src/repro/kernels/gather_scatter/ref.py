"""Oracles for GUPS-style random vector gather/scatter (paper Fig 9)."""
import jax.numpy as jnp


def gather_ref(table, idx):
    return jnp.take(table, idx, axis=0)


def scatter_ref(table, idx, src):
    # duplicate indices: last write wins (matches sequential kernel order)
    return table.at[idx].set(src, mode="drop")
