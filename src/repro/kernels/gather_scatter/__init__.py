from repro.kernels.gather_scatter.ops import vector_gather, vector_scatter  # noqa: F401
