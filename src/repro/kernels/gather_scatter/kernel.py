"""GUPS-style random vector gather / scatter Pallas kernels (paper Fig 9).

Vector width D is the swept parameter: on Gaudi the cliff is at 256 B
(minimum access granularity); on TPU the analogous cliff is the (8, 128)
tile — a D < 128·dtype row still moves a full lane tile HBM→VMEM, wasting
bandwidth in exactly the way the paper measures for sub-256 B vectors.
Scalar-prefetched indices drive the BlockSpec index_map (the gather/scatter
never touches rows it doesn't need).
"""
from __future__ import annotations

import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat


def _gather_kernel(idx, row_ref, o_ref):
    o_ref[...] = row_ref[...]


def _scatter_kernel(idx, src_ref, tbl_ref, o_ref):
    del tbl_ref  # present only as the aliased output buffer
    o_ref[...] = src_ref[...]


def gather_pallas(table, idx, *, interpret: bool = True):
    """table (R, D); idx (N,) -> (N, D)."""
    R, D = table.shape
    N = idx.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(N,),
        in_specs=[pl.BlockSpec((1, D), lambda i, ids: (ids[i], 0))],
        out_specs=pl.BlockSpec((1, D), lambda i, ids: (i, 0)),
    )
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((N, D), table.dtype),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(idx, table)


def scatter_pallas(table, idx, src, *, interpret: bool = True):
    """Write src (N, D) rows into table (R, D) at idx (N,). Last write wins."""
    R, D = table.shape
    N = idx.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(N,),
        in_specs=[pl.BlockSpec((1, D), lambda i, ids: (i, 0)),
                  pl.BlockSpec((1, D), lambda i, ids: (ids[i], 0))],
        out_specs=pl.BlockSpec((1, D), lambda i, ids: (ids[i], 0)),
    )
    return pl.pallas_call(
        _scatter_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((R, D), table.dtype),
        input_output_aliases={2: 0},     # table buffer updated in place
        compiler_params=compat.CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(idx, src, table)
