"""BatchedTable fused embedding-bag Pallas kernel (paper §4.1, Fig 14b).

ONE kernel launch serves every (table, bag) pair: the concatenated table
lives in HBM; scalar-prefetched *global* row ids (local index + tableOffset,
computed on the host exactly like FBGEMM's BatchedTable) drive the BlockSpec
index_map, so each grid step DMAs one (1, D) embedding row into VMEM and
accumulates it into the bag's VMEM scratch. This is the TPU analogue of the
paper's TPC-C kernel: the per-table launch overhead of SingleTable is gone
and row fetches from *different tables* overlap in the same HBM→VMEM
pipeline (the paper's "chip-wide memory-level parallelism").

Grid (num_bags, L): L (pooling factor) is innermost/sequential so the bag
accumulator persists; bags are parallel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat


def _embed_kernel(global_ids, row_ref, o_ref, acc_ref, *, pool_l: int):
    l = pl.program_id(1)

    @pl.when(l == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += row_ref[...].astype(jnp.float32)

    @pl.when(l == pool_l - 1)
    def _finalize():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def batched_embedding_pallas(big_table, global_ids, pool_l: int, *,
                             interpret: bool = True):
    """big_table (R, D); global_ids (num_bags * pool_l,) -> (num_bags, D)."""
    R, D = big_table.shape
    num_bags = global_ids.shape[0] // pool_l

    def row_map(b, l, ids):
        return (ids[b * pool_l + l], 0)

    def out_map(b, l, ids):
        return (b, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(num_bags, pool_l),
        in_specs=[pl.BlockSpec((1, D), row_map)],
        out_specs=pl.BlockSpec((1, D), out_map),
        scratch_shapes=[pltpu.VMEM((1, D), jnp.float32)],
    )
    kernel = functools.partial(_embed_kernel, pool_l=pool_l)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_bags, D), big_table.dtype),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(global_ids, big_table)
