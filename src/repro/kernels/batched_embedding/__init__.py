from repro.kernels.batched_embedding.ops import batched_embedding_op  # noqa: F401
