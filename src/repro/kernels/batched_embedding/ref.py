"""Oracle: the jnp BatchedTable embedding bag."""
from repro.core.embedding_api import batched_table_lookup as batched_embedding_ref  # noqa: F401
