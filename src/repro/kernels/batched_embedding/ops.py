"""BatchedTable embedding bag through the unified registry.

This is the single registration site for the ``embedding_bag`` op family:
``ref`` is the fused jnp BatchedTable lookup (the paper's FBGEMM-style
technique at the XLA level) and ``pallas``/``pallas_interpret`` the Pallas
kernel over the same math.  The public wrapper in
``repro.core.embedding_api`` routes through :mod:`repro.core.dispatch`.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import dispatch
from repro.kernels.batched_embedding.kernel import batched_embedding_pallas
from repro.kernels.batched_embedding.ref import batched_embedding_ref


def _example():
    R, D, B, T, L = 16, 128, 2, 3, 4
    tbl = jax.random.normal(jax.random.PRNGKey(0), (R * T, D), jnp.float32)
    offs = jnp.arange(T, dtype=jnp.int32) * R
    idx = jax.random.randint(jax.random.PRNGKey(1), (B, T, L), 0, R)
    return (tbl, offs, idx), {}


_OP = dispatch.op(
    "embedding_bag", example=_example,
    doc="Fused BatchedTable embedding bag: (B,T,L) local ids -> (B,T,D)")


@_OP.register("ref")
@jax.jit
def _embed_ref(big_table, table_offsets, indices):
    return batched_embedding_ref(big_table, table_offsets, indices)


def _pallas(big_table, table_offsets, indices, *, interpret: bool):
    B, T, L = indices.shape
    global_ids = (indices + table_offsets[None, :, None]).reshape(-1)
    out = batched_embedding_pallas(big_table, global_ids, L,
                                   interpret=interpret)
    return out.reshape(B, T, big_table.shape[1])


@_OP.register("pallas")
@jax.jit
def _embed_pallas(big_table, table_offsets, indices):
    return _pallas(big_table, table_offsets, indices, interpret=False)


@_OP.register("pallas_interpret")
@jax.jit
def _embed_interpret(big_table, table_offsets, indices):
    return _pallas(big_table, table_offsets, indices, interpret=True)


def batched_embedding_op(big_table, table_offsets, indices, backend=None):
    """indices (B, T, L) local ids -> pooled (B, T, D)."""
    return _OP(big_table, table_offsets, indices, backend=backend)
