"""Public jit'd wrapper for the BatchedTable embedding kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.batched_embedding.kernel import batched_embedding_pallas
from repro.kernels.batched_embedding.ref import batched_embedding_ref


@partial(jax.jit, static_argnames=("backend",))
def batched_embedding_op(big_table, table_offsets, indices,
                         backend: str = "auto"):
    """indices (B, T, L) local ids -> pooled (B, T, D)."""
    if backend == "ref":
        return batched_embedding_ref(big_table, table_offsets, indices)
    B, T, L = indices.shape
    global_ids = (indices + table_offsets[None, :, None]).reshape(-1)
    interpret = jax.default_backend() != "tpu" or backend == "interpret"
    out = batched_embedding_pallas(big_table, global_ids, L,
                                   interpret=interpret)
    return out.reshape(B, T, big_table.shape[1])
