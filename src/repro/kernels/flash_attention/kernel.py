"""Flash attention Pallas TPU kernel (prefill/train path).

Grid (B, H, nq, nk); nk is the innermost (sequential) dimension so the VMEM
scratch accumulators (acc, m, l) persist across KV blocks of one (b, h, iq)
tile — the canonical TPU online-softmax pipeline. Block shapes are explicit
BlockSpecs: q/o tiles (1, bq, 1, hd), k/v tiles (1, bk, 1, hd); with
bq=bk=512, hd=128 the working set is ≈ 0.8 MB << 16 MB VMEM, leaving room
for double buffering of the HBM→VMEM stream.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  sm_scale: float, causal: bool, bq: int, bk: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, :, 0, :]
    k = k_ref[0, :, 0, :]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * sm_scale
    if causal:
        rows = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = rows >= cols
        s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[:, 0]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    if causal:
        p = jnp.where(mask, p, 0.0)
    l_new = l_ref[:, 0] * corr + p.sum(axis=-1)
    pv = jax.lax.dot_general(p.astype(v_ref.dtype), v_ref[0, :, 0, :],
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr[:, None] + pv
    m_ref[:, 0] = m_new
    l_ref[:, 0] = l_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True, sm_scale=None,
                           bq: int = 512, bk: int = 512,
                           interpret: bool = True):
    """q (B,S,H,hd); k,v (B,S,KV,hd) -> (B,S,H,hd)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = float(sm_scale if sm_scale is not None else hd ** -0.5)
    bq = min(bq, S)
    bk = min(bk, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    grid = (B, H, S // bq, S // bk)

    kernel = functools.partial(_flash_kernel, sm_scale=scale, causal=causal,
                               bq=bq, bk=bk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, hd), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda b, h, i, j: (b, j, h // G, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda b, h, i, j: (b, j, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, hd), lambda b, h, i, j: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
