"""Flash attention through the unified operator-backend registry.

This module registers every implementation of the ``flash_attention`` op
family with :mod:`repro.core.dispatch` — there is no ad-hoc string dispatch
here; backend selection (explicit arg / scope / env / config / auto) happens
in the one shared resolver.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import dispatch
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref


def _example():
    """Small parity-suite inputs (see tests/test_backend_parity.py)."""
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 64, 4, 16), jnp.float32)
    k = jax.random.normal(ks[1], (1, 64, 2, 16), jnp.float32)
    v = jax.random.normal(ks[2], (1, 64, 2, 16), jnp.float32)
    return (q, k, v), {"causal": True, "bq": 32, "bk": 32}


_OP = dispatch.op(
    "flash_attention", example=_example,
    doc="GQA flash attention: q (B,S,H,hd), k/v (B,S,KV,hd) -> (B,S,H,hd)")


def _tiles_divide(spec: dispatch.CallSpec) -> bool:
    """The Pallas grid needs S divisible by the (clamped) q/k tiles."""
    if not spec.args:
        return True
    S = spec.args[0].shape[1]
    bq = spec.kwargs.get("bq", 512)
    bk = spec.kwargs.get("bk", 512)
    return S % min(bq, S) == 0 and S % min(bk, S) == 0


def _pallas_supported(spec: dispatch.CallSpec) -> bool:
    return dispatch.on_tpu(spec) and _tiles_divide(spec)


@_OP.register("ref")
@partial(jax.jit, static_argnames=("causal", "bq", "bk"))
def _flash_ref(q, k, v, *, causal: bool = True, bq: int = 512, bk: int = 512):
    del bq, bk                       # tiling is a kernel-backend concern
    return flash_attention_ref(q, k, v, causal=causal)


@_OP.register("pallas", supports=_pallas_supported)
@partial(jax.jit, static_argnames=("causal", "bq", "bk"))
def _flash_pallas(q, k, v, *, causal: bool = True, bq: int = 512,
                  bk: int = 512):
    return flash_attention_pallas(q, k, v, causal=causal, bq=bq, bk=bk,
                                  interpret=False)


@_OP.register("pallas_interpret", supports=_tiles_divide)
@partial(jax.jit, static_argnames=("causal", "bq", "bk"))
def _flash_pallas_interpret(q, k, v, *, causal: bool = True, bq: int = 512,
                            bk: int = 512):
    return flash_attention_pallas(q, k, v, causal=causal, bq=bq, bk=bk,
                                  interpret=True)


def flash_attention(q, k, v, *, causal: bool = True, backend=None,
                    bq: int = 512, bk: int = 512):
    """Public entry point: one registry resolution, then the chosen impl."""
    return _OP(q, k, v, causal=causal, bq=bq, bk=bk, backend=backend)
