"""Public jit'd wrapper for the flash-attention kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref


@partial(jax.jit, static_argnames=("causal", "backend", "bq", "bk"))
def flash_attention(q, k, v, *, causal: bool = True, backend: str = "auto",
                    bq: int = 512, bk: int = 512):
    """Dispatch: pallas on TPU, pallas-interpret for validation, jnp ref else."""
    if backend == "ref":
        return flash_attention_ref(q, k, v, causal=causal)
    interpret = jax.default_backend() != "tpu"
    if backend == "interpret":
        interpret = True
    return flash_attention_pallas(q, k, v, causal=causal, bq=bq, bk=bk,
                                  interpret=interpret)
