"""Pure-jnp oracle for flash attention (GQA, causal optional)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True, sm_scale=None):
    """q (B,S,H,hd); k,v (B,S,KV,hd) -> (B,S,H,hd). f32 softmax."""
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    scale = sm_scale if sm_scale is not None else hd ** -0.5
    qg = q.reshape(B, Sq, KV, H // KV, hd)
    s = jnp.einsum("bikgd,bjkd->bkgij", qg, k).astype(jnp.float32) * scale
    if causal:
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    o = jnp.einsum("bkgij,bjkd->bikgd", w, v)
    return o.reshape(B, Sq, H, hd)
