"""PagedAttention op families through the unified registry.

Single registration site for three op families:

* ``paged_attention`` — decode shape: one query token per request,
  q (B, H, hd) against the flat BlockList.
* ``paged_attention_chunked`` — the serving engine's fused chunked-prefill +
  decode shape: q (T, H, hd) flat token lanes, each attending causally to its
  request's pool blocks.
* ``paged_attention_ragged`` — the same mixed lanes described by
  cu_q_lens/cu_kv_lens prefix sums over a FUSED head-interleaved KV pool
  (``[K0, V0, K1, V1, ...]`` on the head axis — one buffer, one DMA ring),
  with ``num_queries_per_block`` / ``num_kv_pages_per_block`` /
  ``vmem_limit_bytes`` as measured tunables (see docs/ragged_kernel.md).

The jnp BlockList form (``repro.core.attention_api``) is registered as both
``ref`` (it is the oracle) and ``xla`` (it is also the tuned XLA production
path — segment-softmax, only effectual blocks gathered), so auto resolution
on CPU picks it while perf attribution still distinguishes the two roles.
The Pallas kernels register as ``pallas`` (TPU) and ``pallas_interpret``.
The chunked family additionally registers ``sharded``: the shard_map
log-sum-exp combine (``paged_attention_chunked_sharded``), capability-gated
on mesh presence (``dispatch.mesh_present``) — the standalone form splits
the flat BlockList across a 1-D mesh over every local device, which is both
the parity harness for the collective math and the single-resolver home of
the sharded serving engine's per-layer attention (the engine runs the same
kernel under its own mesh with a sequence-sharded pool; see
docs/sharded_serving.md).
"""
from __future__ import annotations

from functools import lru_cache, partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import dispatch, paged_kv
from repro.core.attention_api import (
    paged_attention_chunked as _chunked_jnp,
    paged_attention_chunked_sharded, paged_attention_opt,
    paged_attention_ragged as _ragged_jnp, paged_attention_ragged_sharded)
from repro.kernels.compat import shard_map as _shard_map
from repro.kernels.paged_attention.kernel import (
    paged_attention_chunked_pallas, paged_attention_pallas,
    paged_attention_ragged_pallas)


def _pools(key, NB=8, BS=4, KV=2, hd=16):
    ks = jax.random.split(key, 2)
    pk = jax.random.normal(ks[0], (NB, BS, KV, hd), jnp.float32)
    pv = jax.random.normal(ks[1], (NB, BS, KV, hd), jnp.float32)
    return pk, pv


def _example_decode():
    """2 requests (lens 6 and 3), blocks 0,1 / 2, one pad entry."""
    key = jax.random.PRNGKey(0)
    pk, pv = _pools(key)
    B, H, hd = 2, 4, 16
    q = jax.random.normal(jax.random.fold_in(key, 1), (B, H, hd), jnp.float32)
    bl = jnp.asarray([0, 1, 2, 0], jnp.int32)
    br = jnp.asarray([0, 0, 1, B], jnp.int32)
    bp = jnp.asarray([0, 1, 0, 0], jnp.int32)
    lens = jnp.asarray([6, 3], jnp.int32)
    return (q, pk, pv, bl, br, bp, lens), {}


def _example_chunked():
    """Mixed lanes: req 0 decode token (pos 5) + req 1 prefill chunk (pos
    1..2) + one padding lane, over the same pool as the decode example."""
    key = jax.random.PRNGKey(0)
    pk, pv = _pools(key)
    B, H, hd = 2, 4, 16
    q = jax.random.normal(jax.random.fold_in(key, 2), (4, H, hd), jnp.float32)
    bl = jnp.asarray([0, 1, 2, 0], jnp.int32)
    br = jnp.asarray([0, 0, 1, B], jnp.int32)
    bp = jnp.asarray([0, 1, 0, 0], jnp.int32)
    kv_lens = jnp.asarray([6, 3], jnp.int32)
    token_req = jnp.asarray([0, 1, 1, B], jnp.int32)
    token_pos = jnp.asarray([5, 1, 2, 0], jnp.int32)
    return (q, pk, pv, bl, br, bp, kv_lens, token_req, token_pos), \
        {"q_chunk": 2, "prefetch_depth": 2}


def _example_ragged():
    """The chunked example re-expressed as ragged metadata over the FUSED
    pool: cu_q_lens/cu_kv_lens/seq_slot derive the exact token_req [0,1,1,B]
    / token_pos [5,1,2,0] / kv_lens [6,3] lanes of ``_example_chunked``, so
    cross-family parity asserts are bitwise, not approximate."""
    key = jax.random.PRNGKey(0)
    pk, pv = _pools(key)
    kv_pool = paged_kv.fuse_kv_heads(pk, pv)
    H, hd = 4, 16
    q = jax.random.normal(jax.random.fold_in(key, 2), (4, H, hd), jnp.float32)
    bl = jnp.asarray([0, 1, 2, 0], jnp.int32)
    br = jnp.asarray([0, 0, 1, 2], jnp.int32)
    bp = jnp.asarray([0, 1, 0, 0], jnp.int32)
    cu_q = jnp.asarray([0, 1, 3], jnp.int32)
    cu_kv = jnp.asarray([0, 6, 9], jnp.int32)
    seq_slot = jnp.asarray([0, 1], jnp.int32)
    return (q, kv_pool, bl, br, bp, cu_q, cu_kv, seq_slot), \
        {"num_queries_per_block": 2, "num_kv_pages_per_block": 2,
         "vmem_limit_bytes": 0}


_DECODE = dispatch.op(
    "paged_attention", example=_example_decode,
    doc="BlockList PagedAttention, decode shape: one query token per request")
_CHUNKED = dispatch.op(
    "paged_attention_chunked", example=_example_chunked,
    doc="Fused chunked-prefill + decode PagedAttention over flat token lanes",
    # Cross-backend knobs: query-chunk grid tile and the KV-page DMA ring
    # depth (0/1 = BlockSpec pipeline, >=2 = multi-buffered manual DMA in the
    # Pallas kernel; jnp backends ignore it). Swept by benchmarks/saturation.
    tunables={"q_chunk": 16, "prefetch_depth": 0})
_RAGGED = dispatch.op(
    "paged_attention_ragged", example=_example_ragged,
    doc="Ragged prefill+decode PagedAttention over the fused KV pool",
    # Measured by the autotune sweep in benchmarks/paged_attention_bench.py;
    # the committed best-per-(page_size, head_dim, backend) table
    # (BENCH_010.json via repro.perf.autotune) overrides these defaults at
    # engine resolve time.
    tunables={"num_queries_per_block": 16, "num_kv_pages_per_block": 1,
              "vmem_limit_bytes": 0})


@jax.jit
def _decode_jnp(q, pool_k, pool_v, block_list, block_req, block_pos,
                seq_lens):
    return paged_attention_opt(q, pool_k, pool_v, block_list, block_req,
                               block_pos, seq_lens)


# ONE jitted function under both names (shared compile cache); the ranks
# keep the oracle/production roles distinguishable in attribution.
_DECODE.register("ref")(_decode_jnp)
_DECODE.register("xla")(_decode_jnp)


@_DECODE.register("pallas")
@jax.jit
def _decode_pallas(q, pool_k, pool_v, block_list, block_req, block_pos,
                   seq_lens):
    return paged_attention_pallas(q, pool_k, pool_v, block_list, block_req,
                                  block_pos, seq_lens, interpret=False)


@_DECODE.register("pallas_interpret")
@jax.jit
def _decode_interpret(q, pool_k, pool_v, block_list, block_req, block_pos,
                      seq_lens):
    return paged_attention_pallas(q, pool_k, pool_v, block_list, block_req,
                                  block_pos, seq_lens, interpret=True)


@partial(jax.jit, static_argnames=("q_chunk", "prefetch_depth"))
def _chunked_ref(q, pool_k, pool_v, block_list, block_req, block_pos,
                 kv_lens, token_req, token_pos, *, q_chunk: int = 16,
                 prefetch_depth: int = 0):
    del q_chunk, prefetch_depth      # DMA strategy is a kernel-backend concern
    return _chunked_jnp(q, pool_k, pool_v, block_list, block_req, block_pos,
                        kv_lens, token_req, token_pos)


_CHUNKED.register("ref")(_chunked_ref)
_CHUNKED.register("xla")(_chunked_ref)


@_CHUNKED.register("pallas")
@partial(jax.jit, static_argnames=("q_chunk", "prefetch_depth"))
def _chunked_pallas(q, pool_k, pool_v, block_list, block_req, block_pos,
                    kv_lens, token_req, token_pos, *, q_chunk: int = 16,
                    prefetch_depth: int = 0):
    return paged_attention_chunked_pallas(
        q, pool_k, pool_v, block_list, block_req, block_pos, kv_lens,
        token_req, token_pos, q_chunk=q_chunk,
        prefetch_depth=prefetch_depth, interpret=False)


@_CHUNKED.register("pallas_interpret")
@partial(jax.jit, static_argnames=("q_chunk", "prefetch_depth"))
def _chunked_interpret(q, pool_k, pool_v, block_list, block_req, block_pos,
                       kv_lens, token_req, token_pos, *, q_chunk: int = 16,
                       prefetch_depth: int = 0):
    return paged_attention_chunked_pallas(
        q, pool_k, pool_v, block_list, block_req, block_pos, kv_lens,
        token_req, token_pos, q_chunk=q_chunk,
        prefetch_depth=prefetch_depth, interpret=True)


@lru_cache(maxsize=None)
def _sharded_chunked_fn(ndev: int):
    """Jitted shard_map combine over a 1-D mesh of ``ndev`` local devices.

    Cached per device count so repeated calls hit ONE jit cache entry (the
    registry rule: impls are registered pre-jitted; a fresh closure per
    call would retrace every time).
    """
    mesh = jax.make_mesh((ndev,), ("seq",))
    fn = _shard_map(
        partial(paged_attention_chunked_sharded, axis="seq"),
        mesh=mesh,
        in_specs=(P(), P(), P(), P("seq"), P("seq"), P("seq"), P(), P(),
                  P()),
        out_specs=P(), check_rep=False)
    return jax.jit(fn)


@_CHUNKED.register("sharded")
def _chunked_sharded(q, pool_k, pool_v, block_list, block_req, block_pos,
                     kv_lens, token_req, token_pos, *, q_chunk: int = 16,
                     prefetch_depth: int = 0):
    """Family-signature wrapper around the shard_map chunked combine.

    Splits the flat BlockList contiguously across a 1-D mesh over every
    local device (the pool stays replicated — a global BlockList has global
    pool indices) and runs ``paged_attention_chunked_sharded`` per rank.
    The serving engine goes further (sequence-sharded pool + local index
    translation) but reduces to the same per-rank kernel; this form is what
    the registry-enumerated parity suite and standalone callers exercise.
    """
    del q_chunk, prefetch_depth      # DMA strategy is a kernel-backend concern
    ndev = len(jax.devices())
    B = kv_lens.shape[0]
    Tb = block_list.shape[0]
    pad = -Tb % ndev
    if pad:
        block_list = jnp.pad(block_list, (0, pad))
        block_req = jnp.pad(block_req, (0, pad), constant_values=B)
        block_pos = jnp.pad(block_pos, (0, pad))
    return _sharded_chunked_fn(ndev)(q, pool_k, pool_v, block_list,
                                     block_req, block_pos, kv_lens,
                                     token_req, token_pos)


_RAGGED_TUNABLES = ("num_queries_per_block", "num_kv_pages_per_block",
                    "vmem_limit_bytes")


@partial(jax.jit, static_argnames=_RAGGED_TUNABLES)
def _ragged_ref(q, kv_pool, block_list, block_req, block_pos, cu_q_lens,
                cu_kv_lens, seq_slot, *, num_queries_per_block: int = 16,
                num_kv_pages_per_block: int = 1, vmem_limit_bytes: int = 0):
    del num_queries_per_block, num_kv_pages_per_block, vmem_limit_bytes
    return _ragged_jnp(q, kv_pool, block_list, block_req, block_pos,
                       cu_q_lens, cu_kv_lens, seq_slot)


_RAGGED.register("ref")(_ragged_ref)
_RAGGED.register("xla")(_ragged_ref)


@_RAGGED.register("pallas")
@partial(jax.jit, static_argnames=_RAGGED_TUNABLES)
def _ragged_pallas(q, kv_pool, block_list, block_req, block_pos, cu_q_lens,
                   cu_kv_lens, seq_slot, *, num_queries_per_block: int = 16,
                   num_kv_pages_per_block: int = 1, vmem_limit_bytes: int = 0):
    return paged_attention_ragged_pallas(
        q, kv_pool, block_list, block_req, block_pos, cu_q_lens, cu_kv_lens,
        seq_slot, num_queries_per_block=num_queries_per_block,
        num_kv_pages_per_block=num_kv_pages_per_block,
        vmem_limit_bytes=vmem_limit_bytes, interpret=False)


@_RAGGED.register("pallas_interpret")
@partial(jax.jit, static_argnames=_RAGGED_TUNABLES)
def _ragged_interpret(q, kv_pool, block_list, block_req, block_pos,
                      cu_q_lens, cu_kv_lens, seq_slot, *,
                      num_queries_per_block: int = 16,
                      num_kv_pages_per_block: int = 1,
                      vmem_limit_bytes: int = 0):
    return paged_attention_ragged_pallas(
        q, kv_pool, block_list, block_req, block_pos, cu_q_lens, cu_kv_lens,
        seq_slot, num_queries_per_block=num_queries_per_block,
        num_kv_pages_per_block=num_kv_pages_per_block,
        vmem_limit_bytes=vmem_limit_bytes, interpret=True)


@lru_cache(maxsize=None)
def _sharded_ragged_fn(ndev: int):
    """Jitted shard_map ragged combine — the chunked combine's twin over the
    fused pool, with the cu prefix sums replicated (every rank derives the
    same lane metadata; only the BlockList splits)."""
    mesh = jax.make_mesh((ndev,), ("seq",))
    fn = _shard_map(
        partial(paged_attention_ragged_sharded, axis="seq"),
        mesh=mesh,
        in_specs=(P(), P(), P("seq"), P("seq"), P("seq"), P(), P(), P()),
        out_specs=P(), check_rep=False)
    return jax.jit(fn)


@_RAGGED.register("sharded")
def _ragged_sharded(q, kv_pool, block_list, block_req, block_pos, cu_q_lens,
                    cu_kv_lens, seq_slot, *, num_queries_per_block: int = 16,
                    num_kv_pages_per_block: int = 1,
                    vmem_limit_bytes: int = 0):
    del num_queries_per_block, num_kv_pages_per_block, vmem_limit_bytes
    ndev = len(jax.devices())
    B = seq_slot.shape[0]
    Tb = block_list.shape[0]
    pad = -Tb % ndev
    if pad:
        block_list = jnp.pad(block_list, (0, pad))
        block_req = jnp.pad(block_req, (0, pad), constant_values=B)
        block_pos = jnp.pad(block_pos, (0, pad))
    return _sharded_ragged_fn(ndev)(q, kv_pool, block_list, block_req,
                                    block_pos, cu_q_lens, cu_kv_lens,
                                    seq_slot)
