"""Public jit'd wrapper for the BlockList PagedAttention kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.paged_attention.kernel import paged_attention_pallas
from repro.kernels.paged_attention.ref import paged_attention_ref


@partial(jax.jit, static_argnames=("backend",))
def paged_attention_kernel_op(q, pool_k, pool_v, block_list, block_req,
                              block_pos, seq_lens, backend: str = "auto"):
    if backend == "ref":
        return paged_attention_ref(q, pool_k, pool_v, block_list, block_req,
                                   block_pos, seq_lens)
    interpret = jax.default_backend() != "tpu" or backend == "interpret"
    return paged_attention_pallas(q, pool_k, pool_v, block_list, block_req,
                                  block_pos, seq_lens, interpret=interpret)
