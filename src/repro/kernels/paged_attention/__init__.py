from repro.kernels.paged_attention.ops import paged_attention_kernel_op  # noqa: F401
