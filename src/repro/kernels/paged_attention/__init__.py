from repro.kernels.paged_attention.kernel import (  # noqa: F401
    paged_attention_chunked_pallas, paged_attention_pallas)
import repro.kernels.paged_attention.ops  # noqa: F401  (registers backends)
