"""PagedAttention decode kernel — the paper's BlockList technique, TPU-native.

The flat BlockList of *effectual* KV-block indices IS the Pallas grid: scalar
prefetch (``pltpu.PrefetchScalarGridSpec``) feeds the block ids to the
BlockSpec ``index_map``, so each grid step DMAs exactly one useful
(block_size, KV, hd) tile from the HBM pool into VMEM. Zero-pad blocks never
leave HBM — this is the TPU realization of vLLM_opt's "gather only effectual
blocks" (paper Fig 16b), with the online-softmax accumulation replacing the
separate Softmax launch.

The BlockList is sorted by request (the allocator guarantees it), so per-
request accumulators live in VMEM scratch across the blocks of one request;
output rows are rewritten as the running normalized value and the final
grid step for a request leaves the correct result.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(
    # scalar-prefetched
    block_list, block_req, block_pos, seq_lens,
    # blocked inputs
    q_ref, k_ref, v_ref,
    # output
    o_ref,
    # scratch
    acc_ref, m_ref, l_ref,
    *, bs: int, num_kv: int, num_reqs: int, sm_scale: float,
):
    t = pl.program_id(0)
    req = block_req[t]
    is_pad = req >= num_reqs
    prev_req = block_req[jnp.maximum(t - 1, 0)]
    first = jnp.logical_or(t == 0, req != prev_req)

    @pl.when(jnp.logical_and(first, jnp.logical_not(is_pad)))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(jnp.logical_not(is_pad))
    def _step():
        H, hd = q_ref.shape[1], q_ref.shape[2]
        G = H // num_kv
        pos = block_pos[t] * bs + jax.lax.broadcasted_iota(
            jnp.int32, (1, bs), 1)[0]
        valid = pos < seq_lens[jnp.minimum(req, num_reqs - 1)]

        for kv in range(num_kv):                       # static small loop
            q = q_ref[0, kv * G:(kv + 1) * G, :]       # (G, hd)
            k = k_ref[0, :, kv, :]                     # (bs, hd)
            v = v_ref[0, :, kv, :]
            s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32)
            s = s * sm_scale                           # (G, bs)
            s = jnp.where(valid[None, :], s, NEG_INF)
            m_prev = m_ref[kv, :G]
            m_new = jnp.maximum(m_prev, s.max(axis=-1))
            corr = jnp.exp(m_prev - m_new)
            p = jnp.exp(s - m_new[:, None])
            p = jnp.where(valid[None, :], p, 0.0)
            l_new = l_ref[kv, :G] * corr + p.sum(axis=-1)
            pv = jax.lax.dot_general(p.astype(v.dtype), v,
                                     (((1,), (0,)), ((), ())),
                                     preferred_element_type=jnp.float32)
            acc_ref[kv * G:(kv + 1) * G, :] = (
                acc_ref[kv * G:(kv + 1) * G, :] * corr[:, None] + pv)
            m_ref[kv, :G] = m_new
            l_ref[kv, :G] = l_new

        # Rewrite the running normalized output; the last block of this
        # request leaves the final value.
        l = jnp.maximum(l_ref[:, :G].reshape(H, 1), 1e-30)
        o_ref[0, :, :] = (acc_ref[...] / l).astype(o_ref.dtype)


def paged_attention_pallas(q, pool_k, pool_v, block_list, block_req,
                           block_pos, seq_lens, *, sm_scale=None,
                           interpret: bool = True):
    """q (B,H,hd); pools (NB,BS,KV,hd); flat BlockList arrays (T,)."""
    B, H, hd = q.shape
    NB, BS, KV, _ = pool_k.shape
    T = block_list.shape[0]
    scale = float(sm_scale if sm_scale is not None else hd ** -0.5)

    kernel = functools.partial(_paged_kernel, bs=BS, num_kv=KV, num_reqs=B,
                               sm_scale=scale)

    # index maps take (grid ids, *prefetched scalars)
    def q_map(t, bl, br, bp, sl):
        return (jnp.minimum(br[t], B - 1), 0, 0)

    def kv_map(t, bl, br, bp, sl):
        return (bl[t], 0, 0, 0)

    def o_map(t, bl, br, bp, sl):
        return (jnp.minimum(br[t], B - 1), 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(T,),
        in_specs=[
            pl.BlockSpec((1, H, hd), q_map),
            pl.BlockSpec((1, BS, KV, hd), kv_map),
            pl.BlockSpec((1, BS, KV, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, H, hd), o_map),
        scratch_shapes=[
            pltpu.VMEM((H, hd), jnp.float32),
            pltpu.VMEM((KV, max(8, H // KV)), jnp.float32),
            pltpu.VMEM((KV, max(8, H // KV)), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(block_list, block_req, block_pos, seq_lens, q, pool_k, pool_v)
