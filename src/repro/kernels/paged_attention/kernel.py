"""PagedAttention decode kernel — the paper's BlockList technique, TPU-native.

The flat BlockList of *effectual* KV-block indices IS the Pallas grid: scalar
prefetch (``pltpu.PrefetchScalarGridSpec``) feeds the block ids to the
BlockSpec ``index_map``, so each grid step DMAs exactly one useful
(block_size, KV, hd) tile from the HBM pool into VMEM. Zero-pad blocks never
leave HBM — this is the TPU realization of vLLM_opt's "gather only effectual
blocks" (paper Fig 16b), with the online-softmax accumulation replacing the
separate Softmax launch.

The BlockList is sorted by request (the allocator guarantees it), so per-
request accumulators live in VMEM scratch across the blocks of one request;
output rows are rewritten as the running normalized value and the final
grid step for a request leaves the correct result.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat

NEG_INF = -1e30


def _paged_kernel(
    # scalar-prefetched
    block_list, block_req, block_pos, seq_lens,
    # blocked inputs
    q_ref, k_ref, v_ref,
    # output
    o_ref,
    # scratch
    acc_ref, m_ref, l_ref,
    *, bs: int, num_kv: int, num_reqs: int, sm_scale: float,
):
    t = pl.program_id(0)
    req = block_req[t]
    is_pad = req >= num_reqs
    prev_req = block_req[jnp.maximum(t - 1, 0)]
    first = jnp.logical_or(t == 0, req != prev_req)

    @pl.when(jnp.logical_and(first, jnp.logical_not(is_pad)))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(jnp.logical_not(is_pad))
    def _step():
        H, hd = q_ref.shape[1], q_ref.shape[2]
        G = H // num_kv
        pos = block_pos[t] * bs + jax.lax.broadcasted_iota(
            jnp.int32, (1, bs), 1)[0]
        valid = pos < seq_lens[jnp.minimum(req, num_reqs - 1)]

        for kv in range(num_kv):                       # static small loop
            q = q_ref[0, kv * G:(kv + 1) * G, :]       # (G, hd)
            k = k_ref[0, :, kv, :]                     # (bs, hd)
            v = v_ref[0, :, kv, :]
            s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32)
            s = s * sm_scale                           # (G, bs)
            s = jnp.where(valid[None, :], s, NEG_INF)
            m_prev = m_ref[kv, :G]
            m_new = jnp.maximum(m_prev, s.max(axis=-1))
            corr = jnp.exp(m_prev - m_new)
            p = jnp.exp(s - m_new[:, None])
            p = jnp.where(valid[None, :], p, 0.0)
            l_new = l_ref[kv, :G] * corr + p.sum(axis=-1)
            pv = jax.lax.dot_general(p.astype(v.dtype), v,
                                     (((1,), (0,)), ((), ())),
                                     preferred_element_type=jnp.float32)
            acc_ref[kv * G:(kv + 1) * G, :] = (
                acc_ref[kv * G:(kv + 1) * G, :] * corr[:, None] + pv)
            m_ref[kv, :G] = m_new
            l_ref[kv, :G] = l_new

        # Rewrite the running normalized output; the last block of this
        # request leaves the final value.
        l = jnp.maximum(l_ref[:, :G].reshape(H, 1), 1e-30)
        o_ref[0, :, :] = (acc_ref[...] / l).astype(o_ref.dtype)


def paged_attention_pallas(q, pool_k, pool_v, block_list, block_req,
                           block_pos, seq_lens, *, sm_scale=None,
                           interpret: bool = True):
    """q (B,H,hd); pools (NB,BS,KV,hd); flat BlockList arrays (T,)."""
    B, H, hd = q.shape
    NB, BS, KV, _ = pool_k.shape
    T = block_list.shape[0]
    scale = float(sm_scale if sm_scale is not None else hd ** -0.5)

    kernel = functools.partial(_paged_kernel, bs=BS, num_kv=KV, num_reqs=B,
                               sm_scale=scale)

    # index maps take (grid ids, *prefetched scalars)
    def q_map(t, bl, br, bp, sl):
        return (jnp.minimum(br[t], B - 1), 0, 0)

    def kv_map(t, bl, br, bp, sl):
        return (bl[t], 0, 0, 0)

    def o_map(t, bl, br, bp, sl):
        return (jnp.minimum(br[t], B - 1), 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(T,),
        in_specs=[
            pl.BlockSpec((1, H, hd), q_map),
            pl.BlockSpec((1, BS, KV, hd), kv_map),
            pl.BlockSpec((1, BS, KV, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, H, hd), o_map),
        scratch_shapes=[
            pltpu.VMEM((H, hd), jnp.float32),
            pltpu.VMEM((KV, max(8, H // KV)), jnp.float32),
            pltpu.VMEM((KV, max(8, H // KV)), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(block_list, block_req, block_pos, seq_lens, q, pool_k, pool_v)


def _chunked_flash_update(q_ref, k_blk, v_blk, o_ref, acc_ref, m_ref, l_ref,
                          valid, *, num_kv: int, sm_scale: float):
    """One online-softmax update of a query chunk against one KV block tile.

    ``k_blk``/``v_blk`` are the (bs, KV, hd) tile VALUES for this BlockList
    entry — loaded either by the BlockSpec pipeline (``_chunked_kernel``) or
    from the manual multi-buffered DMA ring (``_chunked_kernel_prefetch``).
    Shared so the two DMA strategies cannot drift numerically.
    """
    TQ, H, hd = q_ref.shape
    G = H // num_kv
    for kv in range(num_kv):                       # static small loop
        q = q_ref[:, kv * G:(kv + 1) * G, :]       # (TQ, G, hd)
        k = k_blk[:, kv, :]                        # (bs, hd)
        v = v_blk[:, kv, :]
        s = jax.lax.dot_general(q, k, (((2,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * sm_scale                           # (TQ, G, bs)
        s = jnp.where(valid[:, None, :], s, NEG_INF)
        m_prev = m_ref[:, kv * G:(kv + 1) * G]     # (TQ, G)
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, :, None])
        p = jnp.where(valid[:, None, :], p, 0.0)
        l_new = l_ref[:, kv * G:(kv + 1) * G] * corr + p.sum(axis=-1)
        pv = jax.lax.dot_general(p.astype(v.dtype), v,
                                 (((2,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[:, kv * G:(kv + 1) * G, :] = (
            acc_ref[:, kv * G:(kv + 1) * G, :] * corr[:, :, None] + pv)
        m_ref[:, kv * G:(kv + 1) * G] = m_new
        l_ref[:, kv * G:(kv + 1) * G] = l_new

    # Rewrite the running normalized output; the last BlockList entry
    # leaves the final value for this query chunk.
    l = jnp.maximum(l_ref[...], 1e-30)             # (TQ, H)
    o_ref[...] = (acc_ref[...] / l[:, :, None]).astype(o_ref.dtype)


def _chunked_valid_mask(block_req, block_pos, kv_lens, treq_ref, tpos_ref,
                        t, *, bs: int, num_reqs: int):
    """(TQ, bs) ownership+causality+length mask for BlockList entry ``t``."""
    req = block_req[t]
    treq = treq_ref[:, 0]                          # (TQ,)
    tpos = tpos_ref[:, 0]
    key_pos = block_pos[t] * bs + jax.lax.broadcasted_iota(
        jnp.int32, (1, bs), 1)[0]                  # (bs,)
    kvl = kv_lens[jnp.minimum(req, num_reqs - 1)]
    lane_ok = (treq == req) & (treq < num_reqs)    # (TQ,)
    return (lane_ok[:, None]
            & (key_pos[None, :] <= tpos[:, None])   # causal
            & (key_pos[None, :] < kvl))             # (TQ, bs)


def _chunked_kernel(
    # scalar-prefetched
    block_list, block_req, block_pos, kv_lens,
    # blocked inputs
    q_ref, k_ref, v_ref, treq_ref, tpos_ref,
    # output
    o_ref,
    # scratch
    acc_ref, m_ref, l_ref,
    *, bs: int, num_kv: int, num_reqs: int, sm_scale: float,
):
    """Chunked-prefill grid step: one (query-chunk, BlockList entry) pair.

    Grid is (num_q_chunks, T_blocks) with the block dimension innermost, so
    the per-chunk online-softmax accumulators persist in VMEM scratch across
    every BlockList entry of one query chunk.  Lanes of a chunk may belong to
    different requests — ownership, causality and KV length are all enforced
    by the mask, exactly as in ``paged_attention_chunked`` (the jnp ref).
    """
    t = pl.program_id(1)
    is_pad = block_req[t] >= num_reqs

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        # Lanes with no valid keys (padding, empty requests) must read 0.
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(jnp.logical_not(is_pad))
    def _step():
        valid = _chunked_valid_mask(block_req, block_pos, kv_lens, treq_ref,
                                    tpos_ref, t, bs=bs, num_reqs=num_reqs)
        _chunked_flash_update(q_ref, k_ref[0], v_ref[0], o_ref, acc_ref,
                              m_ref, l_ref, valid, num_kv=num_kv,
                              sm_scale=sm_scale)


def _chunked_kernel_prefetch(
    # scalar-prefetched
    block_list, block_req, block_pos, kv_lens,
    # blocked inputs (pools stay in HBM/ANY — DMA'd manually below)
    q_ref, k_hbm, v_hbm, treq_ref, tpos_ref,
    # output
    o_ref,
    # scratch
    acc_ref, m_ref, l_ref, k_buf, v_buf, k_sem, v_sem,
    *, bs: int, num_kv: int, num_reqs: int, sm_scale: float, depth: int,
    num_blocks: int,
):
    """Multi-buffered variant: the KV-page HBM→VMEM DMA runs ``depth`` deep.

    Instead of letting the BlockSpec pipeline fetch one (bs, KV, hd) page
    per grid step, the pools stay in HBM (``memory_space=ANY``) and the
    kernel drives its own DMA ring: VMEM scratch holds ``depth`` page slots
    per pool, and at BlockList entry ``t`` the page for entry
    ``t + depth - 1`` is *started* before the page for ``t`` is *waited* —
    so up to ``depth - 1`` page fetches are in flight behind the flash
    inner loop.  Entry 0 of every query chunk warm-starts the first
    ``depth - 1`` pages.  Every started copy is waited exactly once
    (pad entries included — they fetch a real page and skip only the
    compute), keeping the per-slot DMA semaphores balanced across the grid.
    """
    t = pl.program_id(1)
    Tb = pl.num_programs(1)
    is_pad = block_req[t] >= num_reqs

    def start(e):
        slot = jax.lax.rem(e, depth)
        blk = jnp.minimum(block_list[e], num_blocks - 1)
        pltpu.make_async_copy(k_hbm.at[blk], k_buf.at[slot],
                              k_sem.at[slot]).start()
        pltpu.make_async_copy(v_hbm.at[blk], v_buf.at[slot],
                              v_sem.at[slot]).start()

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        o_ref[...] = jnp.zeros_like(o_ref)
        for d in range(min(depth - 1, Tb)):       # warm-up: fill the ring
            start(jnp.int32(d))

    @pl.when(t + depth - 1 < Tb)                  # steady state: run ahead
    def _ahead():
        start(t + depth - 1)

    slot = jax.lax.rem(t, depth)
    blk = jnp.minimum(block_list[t], num_blocks - 1)
    pltpu.make_async_copy(k_hbm.at[blk], k_buf.at[slot], k_sem.at[slot]).wait()
    pltpu.make_async_copy(v_hbm.at[blk], v_buf.at[slot], v_sem.at[slot]).wait()

    @pl.when(jnp.logical_not(is_pad))
    def _step():
        valid = _chunked_valid_mask(block_req, block_pos, kv_lens, treq_ref,
                                    tpos_ref, t, bs=bs, num_reqs=num_reqs)
        _chunked_flash_update(q_ref, k_buf[slot], v_buf[slot], o_ref, acc_ref,
                              m_ref, l_ref, valid, num_kv=num_kv,
                              sm_scale=sm_scale)


def paged_attention_chunked_pallas(q, pool_k, pool_v, block_list, block_req,
                                   block_pos, kv_lens, token_req, token_pos,
                                   *, sm_scale=None, q_chunk: int = 16,
                                   prefetch_depth: int = 0,
                                   interpret: bool = True):
    """Chunked-prefill PagedAttention with a query-chunk grid dimension.

    Same contract as ``repro.core.attention_api.paged_attention_chunked``:
    q (T, H, hd) flat token lanes (decode tokens and prompt-chunk tokens
    mixed), flat BlockList arrays (Tb,), kv_lens (B,), token_req/token_pos
    (T,).  The decode kernel above is the one-lane-per-request special case;
    here the grid grows a leading query-chunk dimension and the scalar-
    prefetched BlockList still drives exact-tile DMA — zero-pad pool blocks
    never leave HBM.

    ``prefetch_depth`` selects the KV-page DMA strategy.  0 (and 1) keep the
    BlockSpec pipeline: Pallas fetches one page per grid step, overlapping at
    most one fetch with compute.  depth >= 2 switches to the manual
    multi-buffered ring in ``_chunked_kernel_prefetch``: the pools stay in
    HBM and up to ``depth - 1`` page DMAs run ahead of the flash loop, at the
    cost of ``2 * depth`` (bs, KV, hd) page slots of VMEM scratch.  Both
    strategies share the flash update, so results are identical.
    """
    T, H, hd = q.shape
    NB, BS, KV, _ = pool_k.shape
    B = kv_lens.shape[0]
    Tb = block_list.shape[0]
    scale = float(sm_scale if sm_scale is not None else hd ** -0.5)
    depth = int(prefetch_depth)
    if depth < 0:
        raise ValueError(f"prefetch_depth must be >= 0, got {depth}")

    tq = max(min(q_chunk, T), 1)
    pad = (-T) % tq
    if pad:
        q = jnp.pad(q, ((0, pad), (0, 0), (0, 0)))
        # Padding lanes get an out-of-range owner so every key is masked.
        token_req = jnp.pad(token_req, (0, pad), constant_values=B)
        token_pos = jnp.pad(token_pos, (0, pad))
    Tp = T + pad
    treq = token_req.reshape(Tp, 1).astype(jnp.int32)
    tpos = token_pos.reshape(Tp, 1).astype(jnp.int32)

    # index maps take (grid ids, *prefetched scalars)
    def q_map(i, t, bl, br, bp, kvl):
        return (i, 0, 0)

    def kv_map(i, t, bl, br, bp, kvl):
        return (bl[t], 0, 0, 0)

    def lane_map(i, t, bl, br, bp, kvl):
        return (i, 0)

    if depth >= 2:
        kernel = functools.partial(
            _chunked_kernel_prefetch, bs=BS, num_kv=KV, num_reqs=B,
            sm_scale=scale, depth=depth, num_blocks=NB)
        # Pools stay in HBM; the kernel rings its own page DMAs.
        kv_spec = pl.BlockSpec(memory_space=pltpu.ANY)
        scratch = [
            pltpu.VMEM((tq, H, hd), jnp.float32),
            pltpu.VMEM((tq, H), jnp.float32),
            pltpu.VMEM((tq, H), jnp.float32),
            pltpu.VMEM((depth, BS, KV, hd), pool_k.dtype),
            pltpu.VMEM((depth, BS, KV, hd), pool_v.dtype),
            pltpu.SemaphoreType.DMA((depth,)),
            pltpu.SemaphoreType.DMA((depth,)),
        ]
        # The DMA ring state spans grid steps of the q-chunk dim too (warm-up
        # reruns per chunk), so neither dimension may be parallelized.
        semantics = ("arbitrary", "arbitrary")
    else:
        kernel = functools.partial(_chunked_kernel, bs=BS, num_kv=KV,
                                   num_reqs=B, sm_scale=scale)
        kv_spec = pl.BlockSpec((1, BS, KV, hd), kv_map)
        scratch = [
            pltpu.VMEM((tq, H, hd), jnp.float32),
            pltpu.VMEM((tq, H), jnp.float32),
            pltpu.VMEM((tq, H), jnp.float32),
        ]
        semantics = ("parallel", "arbitrary")

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(Tp // tq, Tb),
        in_specs=[
            pl.BlockSpec((tq, H, hd), q_map),
            kv_spec,
            kv_spec,
            pl.BlockSpec((tq, 1), lane_map),
            pl.BlockSpec((tq, 1), lane_map),
        ],
        out_specs=pl.BlockSpec((tq, H, hd), q_map),
        scratch_shapes=scratch,
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Tp, H, hd), q.dtype),
        compiler_params=compat.CompilerParams(
            dimension_semantics=semantics),
        interpret=interpret,
    )(block_list, block_req, block_pos, kv_lens, q, pool_k, pool_v,
      treq, tpos)
    return out[:T]


def _ragged_kernel(
    # scalar-prefetched
    block_list, block_req, block_pos, kv_lens,
    # blocked inputs (the fused pool stays in HBM/ANY — DMA'd manually)
    q_ref, kv_hbm, treq_ref, tpos_ref,
    # output
    o_ref,
    # scratch
    acc_ref, m_ref, l_ref, kv_buf, kv_sem,
    *, bs: int, num_kv: int, num_reqs: int, sm_scale: float, pages: int,
    num_blocks: int,
):
    """Ragged grid step over the FUSED head-interleaved pool.

    Grid is (num_q_tiles, num_page_groups): one step consumes ``pages``
    BlockList entries against one ``num_queries_per_block``-row query tile.
    The fused pool means ONE ``(bs, 2*KV, hd)`` page per DMA instead of a
    (k, v) pair — the ring holds half as many transfers in flight for the
    same bytes.  The ring is double-buffered over page GROUPS: group ``t+1``
    starts before group ``t`` is waited, so a whole group's pages stream
    behind the flash inner loop.  Pad entries fetch a real page and skip
    only the compute, keeping every started copy waited exactly once.

    The per-page math is byte-for-byte ``_chunked_flash_update`` +
    ``_chunked_valid_mask`` on split VIEWS of the fused tile — the ragged
    and chunked paths cannot drift.
    """
    t = pl.program_id(1)
    Tg = pl.num_programs(1)

    def start_group(g):
        slot = jax.lax.rem(g, 2)
        for j in range(pages):
            blk = jnp.minimum(block_list[g * pages + j], num_blocks - 1)
            pltpu.make_async_copy(kv_hbm.at[blk], kv_buf.at[slot, j],
                                  kv_sem.at[slot, j]).start()

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        # Lanes with no valid keys (padding, empty requests) must read 0.
        o_ref[...] = jnp.zeros_like(o_ref)
        start_group(jnp.int32(0))                 # warm-up: fill slot 0

    @pl.when(t + 1 < Tg)                          # steady state: run ahead
    def _ahead():
        start_group(t + 1)

    slot = jax.lax.rem(t, 2)
    for j in range(pages):                        # static small loop
        g = t
        blk = jnp.minimum(block_list[g * pages + j], num_blocks - 1)
        pltpu.make_async_copy(kv_hbm.at[blk], kv_buf.at[slot, j],
                              kv_sem.at[slot, j]).wait()
        e = t * pages + j
        is_pad = block_req[e] >= num_reqs

        @pl.when(jnp.logical_not(is_pad))
        def _step(e=e, j=j):
            valid = _chunked_valid_mask(block_req, block_pos, kv_lens,
                                        treq_ref, tpos_ref, e, bs=bs,
                                        num_reqs=num_reqs)
            tile = kv_buf[slot, j]                # (bs, 2*KV, hd) fused page
            split = tile.reshape(bs, num_kv, 2, tile.shape[-1])
            _chunked_flash_update(q_ref, split[:, :, 0, :], split[:, :, 1, :],
                                  o_ref, acc_ref, m_ref, l_ref, valid,
                                  num_kv=num_kv, sm_scale=sm_scale)


def paged_attention_ragged_pallas(q, kv_pool, block_list, block_req,
                                  block_pos, cu_q_lens, cu_kv_lens, seq_slot,
                                  *, sm_scale=None,
                                  num_queries_per_block: int = 16,
                                  num_kv_pages_per_block: int = 1,
                                  vmem_limit_bytes: int = 0,
                                  interpret: bool = True):
    """Ragged fused-pool PagedAttention: one launch for prefill + decode.

    Same contract as ``repro.core.attention_api.paged_attention_ragged``:
    q (T, H, hd) flat token lanes with sequences contiguous in lane order,
    kv_pool (NB, BS, 2*KV, hd) fused head-interleaved layer, flat BlockList
    arrays (Tb,), and cu_q_lens/cu_kv_lens/seq_slot ragged metadata.  The
    lane arrays the grid masks against are DERIVED from the prefix sums at
    the XLA level (``ragged_lane_metadata`` — the same integer math as the
    jnp ref), then scalar-prefetched exactly like the chunked kernel.

    Tunables (registered on the ``paged_attention_ragged`` family, measured
    by the autotune sweep in ``benchmarks/paged_attention_bench.py``):

    * ``num_queries_per_block`` — query-tile rows per grid step (the ragged
      analogue of ``q_chunk``).
    * ``num_kv_pages_per_block`` — fused KV pages one grid step consumes;
      the double-buffered DMA ring holds ``2 *`` this many pages in VMEM.
    * ``vmem_limit_bytes`` — cap on the ring's VMEM footprint: the page
      group is clamped so the ring fits, and the limit is forwarded to the
      Mosaic compiler when this jax version accepts it.
    """
    from repro.core.attention_api import ragged_lane_metadata

    T, H, hd = q.shape
    NB, BS, KV2, _ = kv_pool.shape
    num_kv = KV2 // 2
    B = seq_slot.shape[0]
    Tb = block_list.shape[0]
    scale = float(sm_scale if sm_scale is not None else hd ** -0.5)

    token_req, token_pos, kv_lens = ragged_lane_metadata(
        cu_q_lens, cu_kv_lens, seq_slot, T, B)

    pages = max(int(num_kv_pages_per_block), 1)
    if vmem_limit_bytes:
        page_bytes = BS * KV2 * hd * jnp.dtype(kv_pool.dtype).itemsize
        pages = max(min(pages, int(vmem_limit_bytes) // (2 * page_bytes)), 1)
    tq = max(min(int(num_queries_per_block), T), 1)

    pad = (-T) % tq
    if pad:
        q = jnp.pad(q, ((0, pad), (0, 0), (0, 0)))
        # Padding lanes get an out-of-range owner so every key is masked.
        token_req = jnp.pad(token_req, (0, pad), constant_values=B)
        token_pos = jnp.pad(token_pos, (0, pad))
    Tp = T + pad
    treq = token_req.reshape(Tp, 1).astype(jnp.int32)
    tpos = token_pos.reshape(Tp, 1).astype(jnp.int32)

    bpad = (-Tb) % pages
    if bpad:
        # Pad entries still fetch a (clamped) real page — only compute skips.
        block_list = jnp.pad(block_list, (0, bpad))
        block_req = jnp.pad(block_req, (0, bpad), constant_values=B)
        block_pos = jnp.pad(block_pos, (0, bpad))
    Tg = (Tb + bpad) // pages

    kernel = functools.partial(
        _ragged_kernel, bs=BS, num_kv=num_kv, num_reqs=B, sm_scale=scale,
        pages=pages, num_blocks=NB)

    # index maps take (grid ids, *prefetched scalars)
    def q_map(i, t, bl, br, bp, kvl):
        return (i, 0, 0)

    def lane_map(i, t, bl, br, bp, kvl):
        return (i, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(Tp // tq, Tg),
        in_specs=[
            pl.BlockSpec((tq, H, hd), q_map),
            # ONE buffer in HBM; the kernel rings its own fused-page DMAs.
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec((tq, 1), lane_map),
            pl.BlockSpec((tq, 1), lane_map),
        ],
        out_specs=pl.BlockSpec((tq, H, hd), q_map),
        scratch_shapes=[
            pltpu.VMEM((tq, H, hd), jnp.float32),
            pltpu.VMEM((tq, H), jnp.float32),
            pltpu.VMEM((tq, H), jnp.float32),
            pltpu.VMEM((2, pages, BS, KV2, hd), kv_pool.dtype),
            pltpu.SemaphoreType.DMA((2, pages)),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Tp, H, hd), q.dtype),
        # The ring state spans grid steps of the q-tile dim too (warm-up
        # reruns per tile), so neither dimension may be parallelized.
        compiler_params=compat.compiler_params(
            dimension_semantics=("arbitrary", "arbitrary"),
            vmem_limit_bytes=int(vmem_limit_bytes) or None),
        interpret=interpret,
    )(block_list, block_req, block_pos, kv_lens, q, kv_pool, treq, tpos)
    return out[:T]
