"""Oracle: the jnp BlockList paged attention (same math as the kernel)."""
from repro.core.attention_api import paged_attention_opt as paged_attention_ref  # noqa: F401
