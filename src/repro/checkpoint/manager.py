"""Fault-tolerant checkpointing: async sharded npz + manifest, atomic
rename, keep-K retention, restore-with-remesh.

Layout:
    <dir>/step_000123/
        manifest.json      — step, tree structure, shapes/dtypes, mesh info
        shard_h<host>.npz  — flattened leaves (this host's addressable data)
    <dir>/LATEST           — atomic pointer (text: step number)

Async: ``save`` snapshots device arrays to host (blocking only for the
device→host copy), then writes in a background thread — training continues
during serialization (standard async-checkpoint pattern). ``wait`` joins.
Elastic restore: leaves are loaded and re-placed onto the CURRENT mesh's
shardings, so a run checkpointed on one topology restarts on another
(pod loss ⇒ 16×16 restart from a 2×16×16 checkpoint just works).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Callable, List, Optional

import jax
import numpy as np


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, host: int = 0):
        self.dir = Path(directory)
        self.keep = keep
        self.host = host
        self.dir.mkdir(parents=True, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, *, blocking: bool = False) -> None:
        """Snapshot to host, then serialize in the background."""
        self.wait()  # one in-flight save at a time
        leaves, treedef = jax.tree.flatten(tree)
        host_leaves = [np.asarray(x) for x in leaves]  # device→host now
        meta = {
            "step": step,
            "treedef": str(treedef),
            "leaves": [{"shape": list(x.shape), "dtype": str(x.dtype)}
                       for x in host_leaves],
            "time": time.time(),
        }

        def write():
            try:
                tmp = self.dir / f".tmp_step_{step:09d}_h{self.host}"
                final = self.dir / f"step_{step:09d}"
                tmp.mkdir(parents=True, exist_ok=True)
                np.savez(tmp / f"shard_h{self.host}.npz",
                         **{f"leaf_{i}": x for i, x in enumerate(host_leaves)})
                (tmp / "manifest.json").write_text(json.dumps(meta))
                if final.exists():
                    shutil.rmtree(final)
                os.replace(tmp, final)                 # atomic publish
                latest_tmp = self.dir / ".LATEST.tmp"
                latest_tmp.write_text(str(step))
                os.replace(latest_tmp, self.dir / "LATEST")
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        if blocking:
            write()
            self._raise_if_failed()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def _raise_if_failed(self):
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError(f"async checkpoint failed: {err!r}") from err

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:09d}", ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> List[int]:
        out = []
        for p in self.dir.glob("step_*"):
            try:
                out.append(int(p.name.split("_")[1]))
            except (IndexError, ValueError):
                continue
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        f = self.dir / "LATEST"
        if f.exists():
            try:
                s = int(f.read_text().strip())
                if (self.dir / f"step_{s:09d}").exists():
                    return s
            except ValueError:
                pass
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any,
                placer: Optional[Callable[[np.ndarray, Any], Any]] = None
                ) -> Any:
        """Rebuild ``like``-structured tree from disk.

        ``placer(host_array, like_leaf)`` re-places data (e.g.
        ``jax.device_put(x, like_leaf.sharding)``) — the elastic-remesh hook.
        """
        d = self.dir / f"step_{step:09d}"
        data = np.load(d / f"shard_h{self.host}.npz")
        leaves_like, treedef = jax.tree.flatten(like)
        restored = []
        for i, leaf in enumerate(leaves_like):
            x = data[f"leaf_{i}"]
            assert tuple(x.shape) == tuple(leaf.shape), (x.shape, leaf.shape)
            if placer is not None:
                restored.append(placer(x, leaf))
            else:
                restored.append(x)
        return jax.tree.unflatten(treedef, restored)
