"""LR schedules (pure functions of step, jit-safe)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_warmup(base_lr: float, warmup_steps: int, total_steps: int,
                  min_ratio: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = base_lr * step / max(1, warmup_steps)
        progress = jnp.clip((step - warmup_steps) /
                            max(1, total_steps - warmup_steps), 0.0, 1.0)
        cos = base_lr * (min_ratio + (1 - min_ratio) * 0.5 *
                         (1 + jnp.cos(jnp.pi * progress)))
        return jnp.where(step < warmup_steps, warm, cos)
    return lr


def constant(base_lr: float):
    def lr(step):
        return jnp.full((), base_lr, jnp.float32)
    return lr
