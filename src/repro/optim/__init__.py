from repro.optim.adafactor import adafactor  # noqa: F401
from repro.optim.optimizer import adamw, sgd_momentum  # noqa: F401
from repro.optim.schedules import cosine_warmup  # noqa: F401
