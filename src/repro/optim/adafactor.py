"""Adafactor-lite: factored second moments for matrix params.

At 235B params, Adam's f32 (m, v) costs 8 bytes/param (≈1.9 TB). Adafactor
stores row/col second-moment factors for ≥2-D params — O(n+m) instead of
O(n·m) — cutting optimizer HBM ≈2× (momentum-free variant). Standard
Shazeer & Stern (2018) update with RMS-scaled steps and update clipping.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.optim.optimizer import Optimizer, clip_by_global_norm, global_norm


class AdafactorState(NamedTuple):
    step: jnp.ndarray
    vr: Any          # row factors (or full v for 1-D params)
    vc: Any          # col factors (None marker: zeros(0,) for 1-D)


def _factored(p) -> bool:
    return p.ndim >= 2


def adafactor(eps: float = 1e-30, clip_threshold: float = 1.0,
              decay_pow: float = 0.8, weight_decay: float = 0.0,
              grad_clip: Optional[float] = 1.0) -> Optimizer:
    def init(params):
        def vr_init(p):
            if _factored(p):
                return jnp.zeros(p.shape[:-1], jnp.float32)   # reduce last
            return jnp.zeros(p.shape, jnp.float32)

        def vc_init(p):
            if _factored(p):
                return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            return jnp.zeros((0,), jnp.float32)

        return AdafactorState(step=jnp.zeros((), jnp.int32),
                              vr=jax.tree.map(vr_init, params),
                              vc=jax.tree.map(vc_init, params))

    def update(grads, state: AdafactorState, params, lr):
        if grad_clip is not None:
            grads, gnorm = clip_by_global_norm(grads, grad_clip)
        else:
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
            gnorm = global_norm(grads)
        step = state.step + 1
        beta2 = 1.0 - step.astype(jnp.float32) ** -decay_pow

        def upd(g, vr, vc, p):
            g2 = g * g + eps
            if _factored(p):
                vr_n = beta2 * vr + (1 - beta2) * g2.mean(axis=-1)
                vc_n = beta2 * vc + (1 - beta2) * g2.mean(axis=-2)
                denom = jnp.sqrt(
                    vr_n[..., None] * vc_n[..., None, :]
                    / jnp.maximum(vr_n.mean(-1, keepdims=True)[..., None],
                                  eps))
            else:
                vr_n = beta2 * vr + (1 - beta2) * g2
                vc_n = vc
                denom = jnp.sqrt(vr_n)
            u = g / jnp.maximum(denom, eps)
            # update clipping: RMS(u) <= clip_threshold
            rms = jnp.sqrt(jnp.mean(u * u) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (-lr * u).astype(p.dtype), vr_n, vc_n

        out = jax.tree.map(upd, grads, state.vr, state.vc, params)
        updates = jax.tree.map(lambda o: o[0], out,
                               is_leaf=lambda x: isinstance(x, tuple))
        vr = jax.tree.map(lambda o: o[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
        vc = jax.tree.map(lambda o: o[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
        return updates, AdafactorState(step, vr, vc), gnorm

    return Optimizer(init=init, update=update)
