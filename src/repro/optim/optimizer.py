"""Optimizers (native, no optax dependency): AdamW + SGD-momentum.

Functional API mirroring optax: ``opt.init(params) -> state``,
``opt.update(grads, state, params, lr) -> (updates, state)``. Moments are
f32 regardless of param dtype (bf16 training with f32 optimizer state).
Global-norm clipping is built in.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), tree), norm


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[..., Any]


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, grad_clip: Optional[float] = 1.0
          ) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          m=jax.tree.map(zeros, params),
                          v=jax.tree.map(zeros, params))

    def update(grads, state: AdamWState, params, lr):
        if grad_clip is not None:
            grads, gnorm = clip_by_global_norm(grads, grad_clip)
        else:
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
            gnorm = global_norm(grads)
        step = state.step + 1
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g, state.m, grads)
        v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2) * g * g,
                         state.v, grads)

        def upd(mm, vv, p):
            mhat = mm / bc1
            vhat = vv / bc2
            u = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
            return (-lr * u).astype(p.dtype)

        updates = jax.tree.map(upd, m, v, params)
        return updates, AdamWState(step, m, v), gnorm

    return Optimizer(init=init, update=update)


class SGDState(NamedTuple):
    step: jnp.ndarray
    mom: Any


def sgd_momentum(momentum: float = 0.9, grad_clip: Optional[float] = None
                 ) -> Optimizer:
    def init(params):
        return SGDState(step=jnp.zeros((), jnp.int32),
                        mom=jax.tree.map(
                            lambda p: jnp.zeros(p.shape, jnp.float32), params))

    def update(grads, state: SGDState, params, lr):
        if grad_clip is not None:
            grads, gnorm = clip_by_global_norm(grads, grad_clip)
        else:
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
            gnorm = global_norm(grads)
        mom = jax.tree.map(lambda b, g: momentum * b + g, state.mom, grads)
        updates = jax.tree.map(lambda b, p: (-lr * b).astype(p.dtype), mom,
                               params)
        return updates, SGDState(state.step + 1, mom), gnorm

    return Optimizer(init=init, update=update)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)
