"""Sharding rules: param/batch/cache PartitionSpecs for every model family.

Strategy (DESIGN.md §4):
  * TP over ``model``: column weights (in→hidden) shard the output dim;
    row weights (hidden→out) shard the input dim; vocab shards over model.
  * FSDP over ``data``: the *other* matmul dim.
  * MoE EP: expert dim over ``model``; expert matrices additionally FSDP on
    d_model.
  * DP over ``pod`` (+ optionally FSDP over ('data','pod') — hillclimb knob).
  * Every rule degrades gracefully: a dim that doesn't divide the axis size
    is left unsharded (e.g. granite's vocab 49155 on 16-way model).

Rules are name+shape driven so one walker serves all seven model families.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# weight-name classification
_COL = {"wq", "wk", "wv", "w_gate", "w_up", "w_in", "in_proj", "wr", "w1",
        "u", "router"}          # shard OUTPUT (last) dim over model
_ROW = {"wo", "w_down", "w_out", "out_proj", "wv_cm", "w2", "v"}
# rwkv channel-mix wv is hidden->d (row); plain dict key is "wv" inside "cm".


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def _fits(dim: int, mesh: Mesh, axis) -> bool:
    s = _axis_size(mesh, axis)
    return s > 1 and dim % s == 0


class ShardingRules:
    def __init__(self, mesh: Mesh, *, fsdp_axis="data", model_axis="model",
                 fsdp_over_pod: bool = False,
                 head_dim: Optional[int] = None):
        """``head_dim``: attention head width, when the caller knows it.

        With it set, attention projections (wq/wk/wv output, wo input) are
        TP-sharded only on whole-head boundaries — the standard Megatron
        constraint. Sub-head TP shards are never useful (RoPE and softmax
        need the full head together, so XLA reshards before attention
        anyway) and sharding a fraction of a head across ``model`` inside a
        scan-over-layers body miscompiles under jax 0.4.37's GSPMD
        partitioner: the sharded forward silently diverges from the
        single-device result by ~0.6% (bisected in test_distributed —
        identical math unrolled or applied outside lax.scan is exact).
        ``None`` preserves the raw divisibility rule for callers that don't
        know the attention geometry.
        """
        self.mesh = mesh
        names = mesh.axis_names
        self.model = model_axis if model_axis in names else None
        fsdp = fsdp_axis if fsdp_axis in names else None
        if fsdp_over_pod and "pod" in names and fsdp is not None:
            fsdp = ("pod", fsdp)
        self.fsdp = fsdp
        self.dp = tuple(a for a in ("pod", "data") if a in names) or None
        self.head_dim = head_dim

    def _head_granular(self, d: int) -> bool:
        """Would sharding ``d`` over ``model`` keep whole heads per shard?"""
        if self.head_dim is None or self.head_dim <= 0:
            return True
        if d % self.head_dim != 0:
            return False
        return (d // self.head_dim) % _axis_size(self.mesh, self.model) == 0

    # ----------------------------------------------------------------- params
    def param_spec(self, path: Tuple[str, ...], shape) -> P:
        """Spec for one parameter given its tree path and shape."""
        name = path[-1]
        parent = path[-2] if len(path) > 1 else ""
        nd = len(shape)
        none = (None,) * nd

        def spec(*entries):
            # pad leading dims (layer stacking) with None
            return P(*(none[:nd - len(entries)] + tuple(entries)))

        def m_if(d, heads=False):
            if not (self.model and _fits(d, self.mesh, self.model)):
                return None
            if heads and not self._head_granular(d):
                return None
            return self.model

        def f_if(d):
            return self.fsdp if self.fsdp and _fits(d, self.mesh, self.fsdp) else None

        if name == "table":
            # Vocab-parallel embedding/head (Megatron): vocab→model, d
            # REPLICATED. Sharding d over fsdp makes the unembed einsum emit
            # a partial-sum all-reduce of the full (B,S,V) logits (≈200 GB
            # for 4k×152k) — measured in the first dry-run iteration.
            return P(m_if(shape[0]), None)
        # MoE expert tensors: (..., E, D, F) or (..., E, F, D)
        if _is_moe_path(path) and name in ("w_gate", "w_up"):
            E, D, F = shape[-3:]
            return spec(m_if(E), f_if(D), None)
        if _is_moe_path(path) and name == "w_down":
            E, F, D = shape[-3:]
            return spec(m_if(E), None, f_if(D))
        if _is_moe_path(path) and name == "router":
            D, E = shape[-2:]
            return spec(f_if(D), None)
        if nd >= 2 and name in _ROW:
            din, dout = shape[-2:]
            return spec(m_if(din, heads=name == "wo"), f_if(dout))
        if nd >= 2 and name in _COL:
            din, dout = shape[-2:]
            return spec(f_if(din), m_if(dout, heads=name in ("wq", "wk", "wv")))
        if nd >= 2 and name == "conv_w":         # (…, K, conv_dim)
            return spec(None, m_if(shape[-1]))
        if nd >= 2 and name in ("w", ):          # dlrm mlp
            din, dout = shape[-2:]
            return spec(f_if(din), m_if(dout))
        if name == "embedding":                  # dlrm big table: rows→model
            return P(m_if(shape[0]), None)
        return P(*none)                          # norms, biases, scalars

    def params_tree(self, params_shape):
        """PartitionSpec pytree matching a params (shape) pytree."""
        def walk(path, leaf):
            keys = tuple(_key_name(p) for p in path)
            return self.param_spec(keys, leaf.shape)
        return jax.tree_util.tree_map_with_path(walk, params_shape)

    def named(self, spec_tree):
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s), spec_tree,
                            is_leaf=lambda x: isinstance(x, P))

    # ------------------------------------------------------------- batch/cache
    def batch_spec(self, shape) -> P:
        """Batch arrays: dim0 over data axes when divisible."""
        b = shape[0]
        dp = self.dp if self.dp and b % _axis_size(self.mesh, self.dp) == 0 else None
        return P(dp, *(None,) * (len(shape) - 1))

    def batch_tree(self, specs):
        return jax.tree.map(lambda s: self.batch_spec(s.shape), specs)

    def cache_spec(self, path: Tuple[str, ...], shape) -> P:
        """Decode caches. Contiguous KV (L,B,S,KV,HD): B→dp, S→model
        (sequence-sharded flash-decoding). States: B→dp, heads→model."""
        name = path[-1]
        nd = len(shape)

        def m_if(d):
            return self.model if self.model and _fits(d, self.mesh, self.model) else None

        def dp_if(d):
            return self.dp if self.dp and d % _axis_size(self.mesh, self.dp) == 0 else None

        if name in ("k", "v", "xk", "xv") and nd == 5:    # (L,B,S,KV,HD)
            return P(None, dp_if(shape[1]), m_if(shape[2]), None, None)
        if name == "seq_lens":
            return P(dp_if(shape[0]))
        if name == "S" and nd == 5:                        # rwkv (L,B,H,N,N)
            return P(None, dp_if(shape[1]), m_if(shape[2]), None, None)
        if name in ("tm_shift", "cm_shift") and nd == 3:   # (L,B,D)
            return P(None, dp_if(shape[1]), m_if(shape[2]))
        if name == "conv" and nd == 5:                     # (G,PG,B,K,convd)
            return P(None, None, dp_if(shape[2]), None, m_if(shape[4]))
        if name == "h" and nd == 6:                        # (G,PG,B,H,hd,N)
            return P(None, None, dp_if(shape[2]), m_if(shape[3]), None, None)
        return P(*(None,) * nd)

    def cache_tree(self, cache_shape):
        def walk(path, leaf):
            keys = tuple(_key_name(p) for p in path)
            return self.cache_spec(keys, leaf.shape)
        return jax.tree_util.tree_map_with_path(walk, cache_shape)


def _key_name(entry) -> str:
    if hasattr(entry, "key"):
        return str(entry.key)
    if hasattr(entry, "idx"):
        return str(entry.idx)
    return str(entry)


def _is_moe_path(path: Tuple[str, ...]) -> bool:
    # shared-expert weights are plain dense mats, not (E, ., .) stacks
    return "moe" in path and "shared" not in path
