"""GPipe-style pipeline parallelism over shard_map + collective_permute.

For depth-dominated models (qwen3-moe's 94 layers) at >512 chips, PP trades
the per-layer FSDP all-gathers for point-to-point boundary transfers. This
module implements the schedule as a pure function so it composes with the
GSPMD layers INSIDE each stage:

  * stage s owns layers [s·L/S, (s+1)·L/S);
  * the loop runs S + M - 1 ticks (M microbatches); at each tick a stage
    processes one microbatch and `collective_permute`s its boundary
    activation to the next stage — compute and the permute overlap since
    the permute of microbatch m is independent of compute on m+1;
  * bubble fraction = (S-1)/(S+M-1), reported by :func:`bubble_fraction`.

Used by ``examples/pipeline_train.py`` and unit-tested against the
unpipelined reference (identical outputs).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp


def bubble_fraction(num_stages: int, num_microbatches: int) -> float:
    return (num_stages - 1) / (num_stages + num_microbatches - 1)


def pipeline_forward(layer_fn: Callable[[Any, jnp.ndarray], jnp.ndarray],
                     stage_params: Any, x_micro: jnp.ndarray, *,
                     axis: str, num_stages: int) -> jnp.ndarray:
    """Run microbatched pipeline forward inside shard_map.

    layer_fn(stage_params, x) applies THIS stage's layers. x_micro
    (M, mb, ...) microbatches, already sharded so each stage rank holds the
    full microbatch set (stage 0 feeds real data; later stages receive via
    permute). Returns (M, mb, ...) outputs valid on the LAST stage.
    """
    stage = jax.lax.axis_index(axis)
    M = x_micro.shape[0]
    S = num_stages
    perm = [(i, i + 1) for i in range(S - 1)]

    def tick(carry, t):
        buf, outs = carry                    # buf: activation in flight here
        # stage 0 injects microbatch t; other stages use the permuted buffer
        inject = jax.lax.dynamic_index_in_dim(
            x_micro, jnp.clip(t, 0, M - 1), keepdims=False)
        cur = jnp.where(stage == 0, inject, buf)
        y = layer_fn(stage_params, cur)
        # microbatch id at this stage this tick; invalid ids compute garbage
        # that is never stored (warm-up / drain bubbles)
        mid = t - stage
        valid = (mid >= 0) & (mid < M) & (stage == S - 1)
        upd = jax.lax.dynamic_update_index_in_dim(
            outs, y, jnp.clip(mid, 0, M - 1), 0)
        outs = jnp.where(valid, upd, outs)
        nxt = jax.lax.ppermute(y, axis, perm)
        return (nxt, outs), None

    # carries become device-varying through ppermute; mark them as such
    # (pre-0.5 jax has no pvary — everything inside shard_map is varying)
    pvary = getattr(jax.lax, "pvary", lambda x, axes: x)
    buf0 = pvary(jnp.zeros_like(x_micro[0]), (axis,))
    outs0 = pvary(jnp.zeros_like(x_micro), (axis,))
    (_, outs), _ = jax.lax.scan(tick, (buf0, outs0), jnp.arange(S + M - 1))
    # broadcast the last stage's outputs to every rank (replicated result);
    # a production loss would instead consume outs on the last stage only
    return jax.lax.psum(jnp.where(stage == S - 1, outs, 0), axis)
