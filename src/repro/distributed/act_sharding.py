"""Activation sharding constraints at layer boundaries.

GSPMD loses the batch sharding of activations inside remat'd scan bodies
(measured: (B,S,·) tensors with unsharded B all-reduced per layer). The
standard fix (MaxText does the same) is re-anchoring activations with
``with_sharding_constraint`` at every block entry. Models call
:func:`constrain_batch`; the launcher scopes the axes with
:func:`activation_sharding` so model code stays mesh-agnostic.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional, Sequence

import jax
from jax.sharding import PartitionSpec as P

_ACT_AXES: contextvars.ContextVar[Optional[tuple]] = contextvars.ContextVar(
    "repro_act_axes", default=None)


@contextlib.contextmanager
def activation_sharding(dp_axes: Sequence[str]):
    """Scope under which activations are batch-sharded over ``dp_axes``."""
    tok = _ACT_AXES.set(tuple(dp_axes))
    try:
        yield
    finally:
        _ACT_AXES.reset(tok)


def constrain_batch(x):
    """Anchor dim0 of x to the scoped data axes (no-op outside the scope)."""
    axes = _ACT_AXES.get()
    if axes is None:
        return x
    if x.shape[0] % _axes_size(axes) != 0:
        return x
    spec = P(axes, *(None,) * (x.ndim - 1))
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError, TypeError):
        return x


def current_data_axes():
    """The data axes scoped by :func:`activation_sharding` (or None)."""
    return _ACT_AXES.get()


def _axes_size(axes) -> int:
    try:
        mesh = jax.sharding.get_abstract_mesh()
        n = 1
        for a in axes:
            n *= dict(zip(mesh.axis_names, mesh.axis_sizes)).get(a, 1)
        return max(n, 1)
    except Exception:
        return 1
