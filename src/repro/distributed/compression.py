"""Gradient compression: int8-quantized all-reduce with error feedback.

For cross-pod (DCI) gradient reduction the wire bytes dominate; int8
quantization cuts them 4× vs f32 (2× vs bf16) at negligible quality cost
when residuals are fed back (1-bit Adam / PowerSGD lineage).

``compressed_psum`` runs INSIDE shard_map over the reduction axis:
    q, scale = quantize(g + residual);  s = psum(q);  g' = dequant(s)
    residual' = (g + residual) - dequant(q)        (local error feedback)
The GSPMD training path uses XLA's native all-reduce; this module serves the
shard_map pipeline trainer and is unit/property-tested on its own.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum(grad: jnp.ndarray, residual: jnp.ndarray, axis: str
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """int8 all-reduce of one gradient leaf with error feedback.

    Must run inside shard_map with ``axis``. Returns (reduced, new_residual).
    Wire cost: N int8 + 1 f32 scale vs N f32 — 4× compression.
    """
    comp = grad.astype(jnp.float32) + residual
    q, scale = quantize_int8(comp)
    # max-scale so every rank dequantizes against the same grid
    gscale = jax.lax.pmax(scale, axis)
    q = jnp.clip(jnp.round(comp / gscale), -127, 127).astype(jnp.int8)
    summed = jax.lax.psum(q.astype(jnp.int32), axis)  # int32 accumulation
    n = jax.lax.psum(1, axis)
    reduced = summed.astype(jnp.float32) * gscale / n
    new_residual = comp - q.astype(jnp.float32) * gscale
    return reduced.astype(grad.dtype), new_residual


def compressed_psum_tree(grads: Any, residuals: Any, axis: str
                         ) -> Tuple[Any, Any]:
    pairs = jax.tree.map(
        lambda g, r: compressed_psum(g, r, axis), grads, residuals)
    reduced = jax.tree.map(lambda p: p[0], pairs,
                           is_leaf=lambda x: isinstance(x, tuple))
    resid = jax.tree.map(lambda p: p[1], pairs,
                         is_leaf=lambda x: isinstance(x, tuple))
    return reduced, resid


def init_residuals(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
