"""Elastic scaling + fault tolerance + straggler mitigation.

On a real multi-pod deployment the coordinator (jax.distributed) detects
failed hosts; this module implements the *decision layer* that a 1000+ node
run needs, in a backend-independent way so it is fully testable on CPU:

* :class:`HeartbeatMonitor` — per-host heartbeats with timeout → dead set.
* :func:`plan_remesh` — given surviving chips and the parallelism minima,
  choose the largest valid (pod, data, model) mesh ≤ survivors (whole-pod
  granularity for the pod axis, power-of-two shrink for data).
* :class:`ElasticTrainer` hooks (in ``repro.training.trainer``) re-mesh,
  restore from the last checkpoint via ``CheckpointManager.restore`` with a
  device_put placer, and continue — the checkpoint layout is topology-free.
* :class:`StragglerWatchdog` — EWMA step-time tracker; flags steps slower
  than ``threshold×`` the moving median. On TPU pods the mitigation is
  re-sharding around the slow host (swap with a hot spare) — the watchdog
  emits the decision; the swap is a remesh with the spare included.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple


class HeartbeatMonitor:
    def __init__(self, hosts: Sequence[int], timeout_s: float = 60.0):
        self.timeout = timeout_s
        self._last: Dict[int, float] = {h: time.time() for h in hosts}

    def beat(self, host: int, now: Optional[float] = None) -> None:
        self._last[host] = time.time() if now is None else now

    def dead(self, now: Optional[float] = None) -> List[int]:
        now = time.time() if now is None else now
        return sorted(h for h, t in self._last.items()
                      if now - t > self.timeout)

    def alive(self, now: Optional[float] = None) -> List[int]:
        d = set(self.dead(now))
        return sorted(h for h in self._last if h not in d)


def plan_remesh(total_chips: int, chips_per_pod: int, *,
                model_parallel: int, min_data: int = 1
                ) -> Optional[Tuple[int, int, int]]:
    """Largest valid (pods, data, model) mesh from surviving chips.

    Pod axis shrinks in whole pods; within a pod, data shrinks by powers of
    two (keeping global batch divisible). Returns None if nothing fits.
    """
    pods = total_chips // chips_per_pod
    if pods >= 1:
        data = chips_per_pod // model_parallel
        if data >= min_data:
            return (pods, data, model_parallel)
    # sub-pod survivor set: shrink data by powers of two
    data = chips_per_pod // model_parallel
    while data >= max(min_data, 1):
        if data * model_parallel <= total_chips:
            return (1, data, model_parallel)
        data //= 2
    return None


@dataclass
class StragglerWatchdog:
    """EWMA step-time tracker; flags slow steps / slow hosts."""

    threshold: float = 2.0
    alpha: float = 0.1
    _ewma: Optional[float] = None
    slow_steps: List[int] = field(default_factory=list)

    def record(self, step: int, dt: float) -> bool:
        """Returns True if this step is a straggler."""
        if self._ewma is None:
            self._ewma = dt
            return False
        is_slow = dt > self.threshold * self._ewma
        # slow steps don't poison the baseline
        if not is_slow:
            self._ewma = (1 - self.alpha) * self._ewma + self.alpha * dt
        else:
            self.slow_steps.append(step)
        return is_slow

    @property
    def baseline(self) -> Optional[float]:
        return self._ewma
