"""Analytic MODEL_FLOPS per (arch × shape): 6·N·D train / 2·N·D inference
(+ attention/state terms). Used for the useful-compute ratio vs HLO_FLOPs."""
from __future__ import annotations

from repro.config import ModelConfig, ShapeCell


def _attn_flops_per_token(cfg: ModelConfig, ctx: int, *, causal_avg: bool):
    """qk + pv FLOPs for ONE query token against ctx keys (fwd)."""
    if cfg.attention is None:
        return 0.0
    a = cfg.attention
    eff = ctx / 2 if causal_avg else ctx
    per_layer = 4.0 * eff * a.num_heads * a.head_dim  # 2 qk + 2 pv
    n_attn = (cfg.num_layers // cfg.hybrid_attn_every
              if cfg.family == "hybrid" else cfg.num_layers)
    return per_layer * n_attn


def _state_flops_per_token(cfg: ModelConfig):
    """Linear-state update+read FLOPs per token (rwkv6 / mamba2)."""
    if cfg.family == "ssm" and cfg.rwkv is not None:
        N = cfg.rwkv.head_size
        return 4.0 * cfg.d_model * N * cfg.num_layers
    if cfg.family == "hybrid" and cfg.ssm is not None:
        d_inner = cfg.ssm.expand * cfg.d_model
        return 4.0 * d_inner * cfg.ssm.d_state * cfg.num_layers
    return 0.0


def model_flops(cfg: ModelConfig, cell: ShapeCell) -> float:
    n_active = cfg.num_active_params()
    B, S = cell.global_batch, cell.seq_len
    if cell.kind == "train":
        tokens = B * S
        return (6.0 * n_active * tokens
                + 3.0 * tokens * _attn_flops_per_token(cfg, S, causal_avg=True)
                + 3.0 * tokens * _state_flops_per_token(cfg))
    if cell.kind == "prefill":
        tokens = B * S
        return (2.0 * n_active * tokens
                + tokens * _attn_flops_per_token(cfg, S, causal_avg=True)
                + tokens * _state_flops_per_token(cfg))
    # decode: one token per request against a ctx of S
    return (2.0 * n_active * B
            + B * _attn_flops_per_token(cfg, S, causal_avg=False)
            + B * _state_flops_per_token(cfg))
