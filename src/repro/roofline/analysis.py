"""Roofline analysis from compiled XLA artifacts (no hardware required).

Three terms per (arch × shape × mesh), per the assignment:
    compute    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory     = HLO_bytes / (chips × HBM_bw)
    collective = collective_bytes / (chips × link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``. XLA's cost
analysis DOES scale while-loop bodies by known trip counts (verified in
tests/test_roofline.py — a scanned model reports ≈ the unrolled FLOPs), so
scan-over-layers programs are counted correctly.

collective_bytes is parsed from ``compiled.as_text()`` (post-SPMD): every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
instruction's operand bytes are summed (via a name→shape map built from the
instruction definitions). Collectives inside while loops are multiplied by
the loop trip count when it is statically known.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional

# ---------------------------------------------------------------------------
# Hardware constants (assignment): TPU-class chip
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class HW:
    peak_bf16: float = 197e12        # FLOP/s per chip
    hbm_bw: float = 819e9            # B/s per chip
    ici_bw: float = 50e9             # B/s per link
    hbm_bytes: float = 32e9          # capacity (reporting only)


_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+"
                     r"([\w\-]+)\(", re.M)
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def _shape_bytes(type_str: str) -> int:
    """Bytes of an HLO type string (handles tuples)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_CONVERT_RE = re.compile(
    r"=\s*f32\[([\d,]*)\][^=]*?\bconvert\(%?([\w.\-]+)\)")


def bf16_convert_penalty(hlo_text: str) -> float:
    """Spurious traffic from the CPU backend's bf16→f32 float-normalization.

    The CPU PJRT backend cannot compute in bf16, so every bf16 tensor is
    materialized as f32 (convert: read N bf16 + write 2N f32; downstream
    reads then move 2N instead of N). A TPU lowering has none of this. We
    sum 4·N_bf16 per upcast convert — the before/after deltas in §Perf are
    backend-consistent either way; this correction is reported alongside.
    """
    shapes: Dict[str, str] = {}
    for m in re.finditer(
            r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\S[^=]*?)\s+[\w\-]+\(",
            hlo_text, re.M):
        shapes[m.group(1)] = m.group(2)
    penalty = 0.0
    for m in _CONVERT_RE.finditer(hlo_text):
        op = m.group(2)
        src_type = shapes.get(op, "")
        if src_type.strip().startswith("bf16"):
            penalty += 4.0 * _shape_bytes(src_type)
    return penalty


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, float]:
    """Sum operand bytes of every collective op, by op kind.

    Handles while-loops with statically-known trip counts: collective bytes
    inside a loop body computation are scaled by the trip count. (XLA's
    post-optimization HLO annotates ``known_trip_count``.)
    """
    # name -> result type string (definitions)
    shapes: Dict[str, str] = {}
    for m in re.finditer(
            r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\)|[\w\[\],{}\/: ]+?))\s+[\w\-]+\(",
            hlo_text, re.M):
        shapes[m.group(1)] = m.group(2)

    # computation -> trip count multiplier (from while ops calling body=...)
    comp_mult: Dict[str, int] = {}
    for m in re.finditer(
            r"while\([^)]*\).*?body=%?([\w.\-]+).*?$", hlo_text, re.M):
        line = m.group(0)
        t = _TRIP_RE.search(line)
        comp_mult[m.group(1)] = int(t.group(1)) if t else 1

    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    current_comp = None
    current_mult = 1
    for line in hlo_text.splitlines():
        cm = re.match(r"^\s*%?([\w.\-]+)\s+\([^)]*\)\s*->", line)
        if line.startswith("%") or (cm and "{" in line):
            pass
        comp_hdr = re.match(
            r"^(?:ENTRY\s+)?(?:ROOT\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{",
            line.strip())
        if comp_hdr:
            current_comp = comp_hdr.group(1)
            current_mult = comp_mult.get(current_comp, 1)
            continue
        for kind in _COLLECTIVES:
            if re.search(rf"\s{kind}(?:-start|-done)?\(", line):
                if f" {kind}-done(" in line:
                    continue  # counted at -start
                # operand names
                call = re.search(rf"{kind}(?:-start)?\((.*?)\)", line)
                if not call:
                    continue
                operands = re.findall(r"%?([\w.\-]+)", call.group(1))
                b = 0
                for op in operands:
                    if op in shapes:
                        b += _shape_bytes(shapes[op])
                if b == 0:  # fall back to result type on the lhs
                    lhs = line.split("=", 1)
                    if len(lhs) == 2:
                        b = _shape_bytes(lhs[1])
                out[kind] += b * current_mult
                break
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    collectives: Dict[str, float] = field(default_factory=dict)
    model_flops: float = 0.0
    memory_per_device: float = 0.0
    hw: HW = field(default_factory=HW)

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * self.hw.peak_bf16)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self.chips * self.hw.hbm_bw)

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / (self.chips * self.hw.ici_bw)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Roofline step time = max of the three overlappable terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def mfu(self) -> float:
        """Model FLOPs / (chips × peak × roofline step time)."""
        t = self.step_time
        if t <= 0:
            return 0.0
        return self.model_flops / (self.chips * self.hw.peak_bf16 * t)

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips, "hlo_flops": self.hlo_flops,
            "hlo_bytes": self.hlo_bytes,
            "collective_bytes": self.collective_bytes,
            "collectives": self.collectives,
            "model_flops": self.model_flops,
            "memory_per_device": self.memory_per_device,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu": self.mfu,
        }


def xla_costs(compiled) -> dict:
    """flops / bytes / per-kind collective bytes / peak memory of one
    compiled executable. NOTE: XLA cost analysis counts while-loop bodies
    ONCE (no trip-count scaling) — callers doing scan-over-layers must apply
    the depth-probe extrapolation (see launch/dryrun.py). Collective bytes
    ARE trip-count scaled (parsed from HLO with known_trip_count)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    out = {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
    }
    try:
        txt = compiled.as_text()
        out["collectives"] = collective_bytes_from_hlo(txt)
        out["bf16_convert_penalty"] = bf16_convert_penalty(txt)
    except Exception:
        out["collectives"] = {"total": 0.0}
        out["bf16_convert_penalty"] = 0.0
    try:
        ma = compiled.memory_analysis()
        out["peak_memory"] = float(getattr(ma, "peak_memory_in_bytes", 0) or (
            ma.argument_size_in_bytes + ma.output_size_in_bytes
            + ma.temp_size_in_bytes))
        out["argument_bytes"] = float(ma.argument_size_in_bytes)
        out["output_bytes"] = float(ma.output_size_in_bytes)
        out["temp_bytes"] = float(ma.temp_size_in_bytes)
    except Exception:
        out["peak_memory"] = 0.0
    return out


def analyze_compiled(compiled, *, arch: str, shape: str, mesh_name: str,
                     chips: int, model_flops: float,
                     hw: Optional[HW] = None) -> RooflineReport:
    """Build a RooflineReport from a compiled executable."""
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    try:
        hlo = compiled.as_text()
        coll = collective_bytes_from_hlo(hlo)
    except Exception:
        coll = {"total": 0.0}
    mem = 0.0
    try:
        ma = compiled.memory_analysis()
        mem = (getattr(ma, "argument_size_in_bytes", 0)
               + getattr(ma, "output_size_in_bytes", 0)
               + getattr(ma, "temp_size_in_bytes", 0)
               + getattr(ma, "generated_code_size_in_bytes", 0))
    except Exception:
        pass
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=flops, hlo_bytes=byts,
        collective_bytes=coll.get("total", 0.0), collectives=coll,
        model_flops=model_flops, memory_per_device=mem,
        hw=hw or HW())
