"""AST-based architectural linter (``python -m repro.analysis.lint``).

The repo's load-bearing contracts — allocator state is mutated only through
its public API, backend choice flows only through ``core/dispatch.py``,
every op family is parity-enrolled, every registry tunable is reachable from
``ServeConfig`` and the launcher, every started Pallas DMA is waited, device
code reads no wall clock — have each been hand-fixed at least once.  This
module enforces them by machine: rules are registered in a strict named
registry (mirroring the ``repro.core.dispatch`` idiom — decorator
registration, duplicate rejection, strict lookup, enumerable), each rule
walks pre-parsed module ASTs and yields :class:`Finding` records, and the
CLI exits nonzero when any finding survives.

The linter imports only the standard library, so CI can gate on it before
paying for a jax import.  Rules live in :mod:`repro.analysis.rules`; see
docs/static_analysis.md for the catalog and how to add one.
"""
from __future__ import annotations

import argparse
import ast
import dataclasses
import json
import os
import sys
from dataclasses import dataclass
from typing import Callable, Dict, Iterable, List, Optional, Sequence

__all__ = ["Finding", "LintRule", "LintContext", "Module", "rule",
           "get_rule", "list_rules", "run_lint", "main",
           "DuplicateRuleError", "UnknownRuleError"]


@dataclass(frozen=True)
class Finding:
    """One rule violation, anchored to a source location."""

    rule: str
    path: str
    line: int
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


@dataclass(frozen=True)
class Module:
    """One parsed source file handed to every rule."""

    path: str          # as discovered (repo-relative when linting the repo)
    tree: ast.Module
    text: str

    def rel(self, *suffixes: str) -> bool:
        """True iff this module's path ends with any of ``suffixes``
        (path-separator aware, so "core/paged_kv.py" never matches
        "not_core/paged_kv.py")."""
        norm = self.path.replace(os.sep, "/")
        return any(norm == s or norm.endswith("/" + s) for s in suffixes)


class LintContext:
    """Everything a rule may inspect: the linted modules plus the repo
    files cross-file rules consult (the parity suite, by default the
    sibling ``tests/`` directory of the linted root)."""

    def __init__(self, modules: Sequence[Module],
                 tests_dir: Optional[str] = None):
        self.modules = list(modules)
        self.tests_dir = tests_dir

    def module(self, *suffixes: str) -> Optional[Module]:
        for mod in self.modules:
            if mod.rel(*suffixes):
                return mod
        return None

    def read_test(self, name: str) -> Optional[str]:
        """Source text of ``tests_dir/name`` (None when absent)."""
        if self.tests_dir is None:
            return None
        path = os.path.join(self.tests_dir, name)
        try:
            with open(path, encoding="utf-8") as f:
                return f.read()
        except OSError:
            return None


# ---------------------------------------------------------------------------
# Rule registry (the dispatch.py idiom: named, decorator-registered, strict)
# ---------------------------------------------------------------------------
class DuplicateRuleError(ValueError):
    pass


class UnknownRuleError(KeyError):
    pass


@dataclass(frozen=True)
class LintRule:
    name: str
    doc: str
    check: Callable[[LintContext], Iterable[Finding]]

    def __call__(self, ctx: LintContext) -> List[Finding]:
        return list(self.check(ctx) or [])


_RULES: Dict[str, LintRule] = {}


def rule(name: str) -> Callable:
    """Register a lint rule under ``name`` (strict: duplicates raise).

    The decorated callable takes a :class:`LintContext` and yields
    :class:`Finding`s; its first docstring line is the catalog entry."""

    def deco(fn: Callable[[LintContext], Iterable[Finding]]) -> LintRule:
        if name in _RULES:
            raise DuplicateRuleError(f"lint rule {name!r} already registered")
        doc = (fn.__doc__ or "").strip().splitlines()
        r = LintRule(name=name, doc=doc[0] if doc else "", check=fn)
        _RULES[name] = r
        return r

    return deco


def _ensure_registered() -> None:
    """Import every rule-registering module (the dispatch idiom: the
    registry is populated by imports, consumers never hand-maintain it)."""
    from repro.analysis import rules  # noqa: F401  (registers on import)


def get_rule(name: str) -> LintRule:
    _ensure_registered()
    if name not in _RULES:
        raise UnknownRuleError(
            f"unknown lint rule {name!r}; have {sorted(_RULES)}")
    return _RULES[name]


def list_rules() -> List[LintRule]:
    _ensure_registered()
    return [_RULES[k] for k in sorted(_RULES)]


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------
def _collect(paths: Sequence[str]) -> List[Module]:
    files: List[str] = []
    for p in paths:
        if os.path.isdir(p):
            for root, _dirs, names in os.walk(p):
                files.extend(os.path.join(root, n) for n in sorted(names)
                             if n.endswith(".py"))
        elif p.endswith(".py"):
            files.append(p)
    modules = []
    for path in sorted(set(files)):
        with open(path, encoding="utf-8") as f:
            text = f.read()
        try:
            tree = ast.parse(text, filename=path)
        except SyntaxError as e:
            # a file the linter cannot parse is itself a finding — surface
            # it through a synthetic rule name instead of crashing the run
            _SYNTAX_ERRORS.append(Finding(
                rule="syntax", path=path, line=e.lineno or 1,
                message=f"unparseable: {e.msg}"))
            continue
        modules.append(Module(path=path, tree=tree, text=text))
    return modules


_SYNTAX_ERRORS: List[Finding] = []


def run_lint(paths: Sequence[str], tests_dir: Optional[str] = "tests",
             rules: Optional[Sequence[str]] = None) -> List[Finding]:
    """Lint ``paths`` (files or directory roots) with every registered rule
    (or the named subset) and return the findings, stably ordered."""
    _ensure_registered()
    _SYNTAX_ERRORS.clear()
    ctx = LintContext(_collect(paths), tests_dir=tests_dir)
    selected = ([get_rule(n) for n in rules] if rules is not None
                else list_rules())
    findings = list(_SYNTAX_ERRORS)
    for r in selected:
        findings.extend(r(ctx))
    findings.sort(key=lambda f: (f.path, f.line, f.rule, f.message))
    return findings


def main(argv: Optional[Sequence[str]] = None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="Repo-specific architectural linter; exits nonzero "
                    "when any rule finds a violation.")
    p.add_argument("paths", nargs="*", default=None,
                   help="files or directories to lint (default: src/)")
    p.add_argument("--tests-dir", default="tests",
                   help="directory the cross-file rules consult for the "
                        "parity suite (default: tests)")
    p.add_argument("--rules", default=None,
                   help="comma-separated rule subset (default: all)")
    p.add_argument("--json", action="store_true",
                   help="emit findings as a JSON array on stdout")
    p.add_argument("--list", action="store_true",
                   help="print the rule catalog and exit")
    args = p.parse_args(argv)
    if args.list:
        for r in list_rules():
            print(f"{r.name}: {r.doc}")
        return 0
    paths = args.paths or ["src"]
    rules_sel = ([s.strip() for s in args.rules.split(",") if s.strip()]
                 if args.rules else None)
    try:
        findings = run_lint(paths, tests_dir=args.tests_dir, rules=rules_sel)
    except UnknownRuleError as e:
        print(e, file=sys.stderr)
        return 2
    if args.json:
        print(json.dumps([dataclasses.asdict(f) for f in findings],
                         indent=2))
    else:
        for f in findings:
            print(f.format())
    if findings:
        print(f"{len(findings)} finding(s)", file=sys.stderr)
        return 1
    if not args.json:
        print(f"lint OK: {len(list_rules())} rules clean on "
              f"{', '.join(paths)}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    # delegate to the canonical module object: under ``python -m`` this file
    # runs as ``__main__`` with its own registry, while the rules module
    # registers into ``repro.analysis.lint``
    from repro.analysis.lint import main as _main
    raise SystemExit(_main())
