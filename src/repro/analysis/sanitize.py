"""Runtime sanitizers behind the single ``ServeConfig.sanitize`` switch.

Three guards, each targeting a hazard class this repo has hand-fixed once
and the Gaudi literature blames for most perf cliffs:

* **Retrace guard** — a process-wide jax compile-event listener plus
  per-signature bookkeeping.  ``expect_cached(sig)`` scopes a region that
  dispatches a jit'd callable: the *first* compile for a signature is the
  warm-up and is free; any later compile for an already-seen signature is a
  steady-state retrace (PR 5's per-call ``jax.jit`` bug class) and counts —
  or raises under ``strict``.
* **Host-sync guard** — ``no_host_sync(scope)`` wraps the overlap build
  half, where a device→host read serializes the pipeline the async engine
  exists to hide.  jax's native ``transfer_guard`` is layered in on non-CPU
  platforms; on CPU (where numpy reads device buffers through the buffer
  protocol without jax noticing) the guard is engine-cooperative: the
  engine's documented host roundtrips route through :func:`host_read`,
  which books allowlisted reasons (``disagg-handoff``, ``tier-drain``) and
  trips on anything else inside a guarded scope.
* **Allocator invariant checker** — ``check_allocator`` runs
  :meth:`repro.core.paged_kv.BlockAllocator.check_invariants` after commit,
  counting checks and surfacing violations as :class:`SanitizeError`.

Counters surface in ``ServingEngine.metrics()`` flattened beside
``policy_counters`` (``sanitize.retraces`` etc.); ``tools/ci_fast.sh`` runs
a sanitized smoke asserting all-zero.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, Iterator, Optional, Set, Tuple

import jax
import numpy as np

__all__ = ["SanitizeError", "Sanitizer", "jit_signature", "host_read",
           "DEFAULT_HOST_SYNC_ALLOWLIST"]

# Engine host roundtrips that are part of the design, not hazards: the
# disagg prefill->decode KV handoff copies through host memory by contract,
# and the HBM->host tier demotion is a host write by definition.
DEFAULT_HOST_SYNC_ALLOWLIST = frozenset({"disagg-handoff", "tier-drain"})


class SanitizeError(RuntimeError):
    """A sanitizer invariant was violated (strict mode)."""


# ---------------------------------------------------------------------------
# Compile-event plumbing (process-wide, installed once)
# ---------------------------------------------------------------------------
_COMPILE_EVENTS = 0
_LISTENER_INSTALLED = False
_COMPILE_EVENT_NAME = "/jax/compilation_cache/compile_requests_use_cache"


def _on_event(event: str, **kwargs: Any) -> None:
    # fires once per *actual* compilation; cache hits do not emit it
    global _COMPILE_EVENTS
    if event == _COMPILE_EVENT_NAME:
        _COMPILE_EVENTS += 1


def _install_compile_listener() -> None:
    global _LISTENER_INSTALLED
    if not _LISTENER_INSTALLED:
        jax.monitoring.register_event_listener(_on_event)
        _LISTENER_INSTALLED = True


def jit_signature(tag: str, *trees: Any) -> Tuple:
    """Hashable abstract signature of a jit call site: tag + treedefs +
    (shape, dtype) per leaf.  Two calls with equal signatures must hit the
    same executable — a second compile for one is a retrace."""
    sig = [tag]
    for tree in trees:
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        sig.append(str(treedef))
        sig.append(tuple(
            (tuple(getattr(x, "shape", ())), str(getattr(x, "dtype", type(x))))
            for x in leaves))
    return tuple(sig)


# ---------------------------------------------------------------------------
# Host-sync guard plumbing (thread-local so overlap's builder thread and the
# resolver never see each other's scopes)
# ---------------------------------------------------------------------------
_TLS = threading.local()


def _guard_stack():
    stack = getattr(_TLS, "stack", None)
    if stack is None:
        stack = _TLS.stack = []
    return stack


def host_read(x: Any, *, reason: str) -> np.ndarray:
    """Materialize a device value on the host, declaring why.

    This is the engine's single doorway for *intentional* device→host
    roundtrips.  Outside any guarded scope it is just ``np.asarray``.
    Inside :meth:`Sanitizer.no_host_sync`, allowlisted reasons are counted
    (``allowed_host_syncs``) and anything else is a trip."""
    stack = _guard_stack()
    if stack:
        sanitizer, scope = stack[-1]
        sanitizer._on_host_read(reason, scope)
    return np.asarray(x)


class Sanitizer:
    """Per-engine runtime guard bundle (see module docstring).

    ``strict=True`` raises :class:`SanitizeError` at the violation site;
    ``strict=False`` only counts, for benchmarking with attribution."""

    def __init__(self, *, strict: bool = True,
                 host_sync_allowlist: Optional[Set[str]] = None):
        _install_compile_listener()
        self.strict = strict
        self.allowlist = frozenset(
            DEFAULT_HOST_SYNC_ALLOWLIST if host_sync_allowlist is None
            else host_sync_allowlist)
        self._seen: Set[Tuple] = set()
        self._counters: Dict[str, int] = {
            "retraces": 0,
            "transfer_guard_trips": 0,
            "invariant_checks": 0,
            "allowed_host_syncs": 0,
            "compiles": 0,
        }

    # -- retrace guard ------------------------------------------------------
    @contextlib.contextmanager
    def expect_cached(self, sig: Tuple) -> Iterator[None]:
        """Scope one dispatch of a jit'd callable with signature ``sig``.

        A compile inside the scope is free the first time ``sig`` is seen
        (warm-up) and a retrace every later time."""
        before = _COMPILE_EVENTS
        try:
            yield
        finally:
            compiled = _COMPILE_EVENTS - before
            if compiled:
                self._counters["compiles"] += compiled
                if sig in self._seen:
                    self._counters["retraces"] += 1
                    if self.strict:
                        raise SanitizeError(
                            f"retrace: recompiled for already-seen jit "
                            f"signature {sig[0]!r} — a steady-state step "
                            f"must reuse its executable (PR 5 bug class)")
            self._seen.add(sig)

    # -- host-sync guard ----------------------------------------------------
    @contextlib.contextmanager
    def no_host_sync(self, scope: str) -> Iterator[None]:
        """Forbid device→host reads inside the scope except through
        :func:`host_read` with an allowlisted reason."""
        stack = _guard_stack()
        stack.append((self, scope))
        native = (jax.transfer_guard_device_to_host("disallow")
                  if jax.default_backend() != "cpu" else
                  contextlib.nullcontext())
        try:
            with native:
                yield
        finally:
            stack.pop()

    def _on_host_read(self, reason: str, scope: str) -> None:
        if reason in self.allowlist:
            self._counters["allowed_host_syncs"] += 1
            return
        self._counters["transfer_guard_trips"] += 1
        if self.strict:
            raise SanitizeError(
                f"host sync {reason!r} inside no_host_sync scope "
                f"{scope!r}; allowlist={sorted(self.allowlist)}")

    # -- allocator invariants ----------------------------------------------
    def check_allocator(self, alloc: Any, *, drained: bool = False) -> None:
        self._counters["invariant_checks"] += 1
        try:
            alloc.check_invariants(drained=drained)
        except ValueError as e:
            raise SanitizeError(f"allocator invariant violated: {e}") from e

    # -- reporting ----------------------------------------------------------
    def counters(self) -> Dict[str, int]:
        return dict(self._counters)

    @property
    def clean(self) -> bool:
        return (self._counters["retraces"] == 0
                and self._counters["transfer_guard_trips"] == 0)
