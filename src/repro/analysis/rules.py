"""The repo's architectural lint rules (registered on import).

Each rule encodes one contract this codebase has already paid to learn
(docs/static_analysis.md lists the incident behind each).  Rules are pure
AST walks over :class:`~repro.analysis.lint.LintContext` — no imports of
the code under analysis, so a broken tree still lints.
"""
from __future__ import annotations

import ast
import re
from collections import Counter
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.lint import Finding, LintContext, Module, rule

# The allocator/pool owners whose private attributes are API-sealed: an
# expression whose final path component is one of these names (``alloc``,
# ``self.alloc``, ``eng.alloc``, ``pre_alloc``, ``host_pool``...) is treated
# as a BlockAllocator / HostPool handle.
_ALLOC_EXPR = re.compile(r"(?:^|[._])(?:alloc|allocator|host_pool)$")
_ALLOC_OWNER = ("core/paged_kv.py",)

# Host wall-clock / ambient-randomness call prefixes banned in device code
# (jax.random is fine — it is a functional PRNG keyed by traced state).
_WALLCLOCK_PREFIXES = ("time.", "datetime.", "np.random.", "numpy.random.",
                      "random.")


def _unparse(node: ast.AST) -> str:
    try:
        return ast.unparse(node)
    except Exception:           # pragma: no cover - malformed subtree
        return ""


@rule("allocator-privacy")
def check_allocator_privacy(ctx: LintContext) -> Iterable[Finding]:
    """No private ``BlockAllocator``/``HostPool`` attribute access outside
    ``core/paged_kv.py``.

    Sequence state (``_tables``/``_lens``/``_ref``/``_free``/...) is mutated
    only through the public allocate/reserve/commit/truncate/free API — the
    reserve/commit/truncate triple is the speculative-rollback primitive and
    the disagg handoff contract, and both break silently if an engine pokes
    the dicts directly.
    """
    for mod in ctx.modules:
        if mod.rel(*_ALLOC_OWNER):
            continue
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Attribute):
                continue
            attr = node.attr
            if not attr.startswith("_") or attr.startswith("__"):
                continue
            base = _unparse(node.value)
            if base and _ALLOC_EXPR.search(base):
                yield Finding(
                    rule="allocator-privacy", path=mod.path,
                    line=node.lineno,
                    message=f"private allocator state {base}.{attr} accessed "
                            f"outside core/paged_kv.py — use the public "
                            f"allocate/reserve/commit/truncate/free API")


@rule("backend-conditional")
def check_backend_conditional(ctx: LintContext) -> Iterable[Finding]:
    """No ad-hoc ``if backend == "..."`` dispatch outside
    ``core/dispatch.py``.

    Backend choice flows through ONE registry (capability predicates +
    precedence chain); a string comparison against a backend name anywhere
    else reintroduces the double dispatch PR 2 removed.
    """
    for mod in ctx.modules:
        if mod.rel("core/dispatch.py"):
            continue
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Compare):
                continue
            sides = [node.left] + list(node.comparators)
            named = any(
                (isinstance(s, ast.Name)
                 and (s.id == "backend" or s.id.endswith("_backend")))
                or (isinstance(s, ast.Attribute) and s.attr == "backend")
                for s in sides)
            literal = any(isinstance(s, ast.Constant)
                          and isinstance(s.value, str) for s in sides)
            if named and literal:
                yield Finding(
                    rule="backend-conditional", path=mod.path,
                    line=node.lineno,
                    message=f"ad-hoc backend dispatch "
                            f"`{_unparse(node)}` — route the choice through "
                            f"repro.core.dispatch (resolve/force_backend)")


def _op_declarations(mod: Module) -> List[Tuple[str, Optional[str],
                                                ast.Call]]:
    """(family_name, bound_variable, call_node) for every ``dispatch.op``
    declaration in a module (``_FAM = dispatch.op("name", ...)``)."""
    out = []
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Assign):
            continue
        call = node.value
        if not (isinstance(call, ast.Call)
                and isinstance(call.func, ast.Attribute)
                and call.func.attr == "op"
                and _unparse(call.func).endswith("dispatch.op")):
            continue
        if not (call.args and isinstance(call.args[0], ast.Constant)
                and isinstance(call.args[0].value, str)):
            continue
        var = (node.targets[0].id
               if node.targets and isinstance(node.targets[0], ast.Name)
               else None)
        out.append((call.args[0].value, var, call))
    return out


def _registered_backends(mod: Module) -> Dict[str, Set[str]]:
    """variable -> backend names registered on it
    (``@_FAM.register("ref")`` and ``_FAM.register("ref")(fn)`` forms)."""
    regs: Dict[str, Set[str]] = {}
    for node in ast.walk(mod.tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "register"
                and isinstance(node.func.value, ast.Name)):
            continue
        var = node.func.value.id
        if not node.args:
            continue
        arg = node.args[0]
        if isinstance(arg, ast.Constant) and isinstance(arg.value, str):
            regs.setdefault(var, set()).add(arg.value)
        elif isinstance(arg, ast.Attribute):    # dispatch.REF etc.
            regs.setdefault(var, set()).add(arg.attr.lower())
    return regs


@rule("op-ref-parity")
def check_op_ref_parity(ctx: LintContext) -> Iterable[Finding]:
    """Every registered op family ships a ``ref`` impl, an ``example``
    factory, and is enrolled in ``tests/test_backend_parity.py``.

    The parity suite parametrizes FROM the registry, so enrollment means
    either the suite enumerates ``dispatch.list_ops()`` (every family rides
    automatically) or it names the family explicitly.
    """
    parity_text = ctx.read_test("test_backend_parity.py")
    registry_driven = bool(parity_text) and "list_ops" in parity_text
    for mod in ctx.modules:
        regs = _registered_backends(mod)
        for name, var, call in _op_declarations(mod):
            if not any(kw.arg == "example" for kw in call.keywords):
                yield Finding(
                    rule="op-ref-parity", path=mod.path, line=call.lineno,
                    message=f"op family {name!r} declares no example= "
                            f"factory — parity tests cannot auto-enroll it")
            backends = regs.get(var or "", set())
            if "ref" not in backends:
                yield Finding(
                    rule="op-ref-parity", path=mod.path, line=call.lineno,
                    message=f"op family {name!r} registers no 'ref' "
                            f"implementation in its declaring module — "
                            f"parity has no oracle")
            if parity_text is not None and not registry_driven \
                    and f'"{name}"' not in parity_text \
                    and f"'{name}'" not in parity_text:
                yield Finding(
                    rule="op-ref-parity", path=mod.path, line=call.lineno,
                    message=f"op family {name!r} is not enrolled in "
                            f"test_backend_parity.py (the suite neither "
                            f"enumerates dispatch.list_ops() nor names it)")


def _serve_config_fields(ctx: LintContext) -> Optional[Set[str]]:
    cfg = ctx.module("repro/config.py", "config.py")
    if cfg is None:
        return None
    for node in ast.walk(cfg.tree):
        if isinstance(node, ast.ClassDef) and node.name == "ServeConfig":
            return {s.target.id for s in node.body
                    if isinstance(s, ast.AnnAssign)
                    and isinstance(s.target, ast.Name)}
    return None


@rule("tunable-reachability")
def check_tunable_reachability(ctx: LintContext) -> Iterable[Finding]:
    """Every dispatch-registry tunable is a ``ServeConfig`` field and a
    ``launch/serve.py`` argparse flag.

    A tunable only reachable by editing kernel code is dead weight for the
    serving stack: sweeps, CI smokes and operators all configure through
    ServeConfig / the launcher.
    """
    fields = _serve_config_fields(ctx)
    launcher = ctx.module("launch/serve.py")
    launcher_text = launcher.text if launcher is not None else None
    for mod in ctx.modules:
        for name, _var, call in _op_declarations(mod):
            for kw in call.keywords:
                if kw.arg != "tunables" or not isinstance(kw.value, ast.Dict):
                    continue
                keys = [k.value for k in kw.value.keys
                        if isinstance(k, ast.Constant)
                        and isinstance(k.value, str)]
                for key in keys:
                    if fields is not None and key not in fields:
                        yield Finding(
                            rule="tunable-reachability", path=mod.path,
                            line=kw.value.lineno,
                            message=f"tunable {key!r} of op family {name!r} "
                                    f"has no ServeConfig field — it is "
                                    f"unreachable from serving config")
                    flag = "--" + key.replace("_", "-")
                    if launcher_text is not None \
                            and flag not in launcher_text:
                        yield Finding(
                            rule="tunable-reachability", path=mod.path,
                            line=kw.value.lineno,
                            message=f"tunable {key!r} of op family {name!r} "
                                    f"has no {flag} flag in launch/serve.py")


def _dma_copy_call(node: ast.AST) -> Optional[Tuple[str, str, int]]:
    """(kind, normalized_args, line) when ``node`` is
    ``...make_async_copy(ARGS).start()`` / ``.wait()``."""
    if not (isinstance(node, ast.Call)
            and isinstance(node.func, ast.Attribute)
            and node.func.attr in ("start", "wait")):
        return None
    inner = node.func.value
    if not (isinstance(inner, ast.Call)
            and _unparse(inner.func).endswith("make_async_copy")):
        return None
    args = ", ".join(_unparse(a) for a in inner.args)
    return node.func.attr, args, node.lineno


@rule("dma-pairing")
def check_dma_pairing(ctx: LintContext) -> Iterable[Finding]:
    """Every Pallas ``make_async_copy(...).start()`` has a matching
    ``.wait()`` on the same (src, dst, semaphore) triple, and every DMA
    semaphore ring is sized to a VMEM ring's leading dim.

    An unpaired start leaves a DMA in flight past the grid step that issued
    it (semaphore imbalance — the interpret-mode kernels validate semantics,
    so only this rule and real hardware catch it); a semaphore array sized
    differently from its ring buffer aliases slots.
    """
    for mod in ctx.modules:
        starts: Counter = Counter()
        waits: Counter = Counter()
        first_line: Dict[Tuple[str, str], int] = {}
        sem_dims: List[Tuple[str, int]] = []
        vmem_dims: Set[str] = set()
        for node in ast.walk(mod.tree):
            hit = _dma_copy_call(node)
            if hit is not None:
                kind, args, line = hit
                (starts if kind == "start" else waits)[args] += 1
                first_line.setdefault((kind, args), line)
            if isinstance(node, ast.Call):
                fname = _unparse(node.func)
                if fname.endswith("SemaphoreType.DMA") and node.args:
                    shape = node.args[0]
                    if isinstance(shape, ast.Tuple) and shape.elts:
                        sem_dims.append((_unparse(shape.elts[0]),
                                         node.lineno))
                elif fname.endswith("VMEM") and node.args:
                    shape = node.args[0]
                    if isinstance(shape, ast.Tuple) and shape.elts:
                        vmem_dims.add(_unparse(shape.elts[0]))
        for args in sorted(set(starts) | set(waits)):
            ns, nw = starts[args], waits[args]
            if ns != nw:
                kind = "start" if ns > nw else "wait"
                line = first_line.get((kind, args), 1)
                yield Finding(
                    rule="dma-pairing", path=mod.path, line=line,
                    message=f"make_async_copy({args}) has {ns} start(s) "
                            f"but {nw} wait(s) — every started DMA must be "
                            f"waited on the same (src, dst, sem) triple")
        for dim, line in sem_dims:
            if vmem_dims and dim not in vmem_dims:
                yield Finding(
                    rule="dma-pairing", path=mod.path, line=line,
                    message=f"DMA semaphore ring sized ({dim},) matches no "
                            f"VMEM ring buffer leading dim "
                            f"({sorted(vmem_dims)}) — slots would alias")


def _device_functions(mod: Module) -> List[ast.FunctionDef]:
    """Functions compiled for device: jit/pallas_call-decorated, passed to
    ``jax.jit(...)``/``pl.pallas_call(...)`` by name, or ``*_kernel``."""
    jitted_names: Set[str] = set()
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Call):
            fname = _unparse(node.func)
            if fname.endswith(("jax.jit", "pallas_call")) \
                    or fname in ("jit",):
                for arg in node.args:
                    if isinstance(arg, ast.Name):
                        jitted_names.add(arg.id)
    out = []
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.FunctionDef):
            continue
        deco = " ".join(_unparse(d) for d in node.decorator_list)
        if ("jit" in deco or "pallas_call" in deco
                or node.name in jitted_names
                or node.name.endswith("_kernel")
                or "_kernel_" in node.name):
            out.append(node)
    return out


@rule("wallclock-in-device-code")
def check_wallclock(ctx: LintContext) -> Iterable[Finding]:
    """No wall-clock or ambient host randomness inside jit'd or kernel
    bodies.

    ``time.*`` / ``np.random.*`` / ``random.*`` inside a traced function
    burns its value into the compiled program at trace time — steps silently
    stop varying, and a retrace makes them vary again.  ``jax.random`` is
    exempt: it is a functional PRNG keyed by traced state.
    """
    for mod in ctx.modules:
        for fn in _device_functions(mod):
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                fname = _unparse(node.func)
                if fname.startswith(_WALLCLOCK_PREFIXES):
                    yield Finding(
                        rule="wallclock-in-device-code", path=mod.path,
                        line=node.lineno,
                        message=f"{fname}(...) inside device function "
                                f"{fn.name!r} — its value freezes at trace "
                                f"time; hoist it to the host caller")


def _registered_policy_names(mod: Module) -> List[Tuple[str, int]]:
    """(policy_name, lineno) for every ``register(AXIS, "name")`` call —
    decorator or direct — in a policy-registry module."""
    out = []
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        if not _unparse(node.func).endswith("register"):
            continue
        if len(node.args) < 2:
            continue
        axis, name = node.args[0], node.args[1]
        if not isinstance(axis, (ast.Name, ast.Attribute)):
            continue
        if not (isinstance(name, ast.Constant)
                and isinstance(name.value, str)):
            continue
        out.append((name.value, node.lineno))
    return out


@rule("policy-enrollment")
def check_policy_enrollment(ctx: LintContext) -> Iterable[Finding]:
    """Every policy registered in ``serving/policy.py`` is named in
    ``tests/test_policy.py``.

    The policy parity sweep enumerates ``policy.names(axis)`` so new
    policies ride automatically, but its SHIPPED registry-shape check (and
    any policy-specific behaviour test) names policies explicitly — a
    registration that never appears in the suite is a policy nobody asserted
    anything about.  Mirrors op-ref-parity's enrollment check.
    """
    text = ctx.read_test("test_policy.py")
    if text is None:            # no tests dir to check against
        return
    for mod in ctx.modules:
        if not mod.rel("serving/policy.py"):
            continue
        for name, line in _registered_policy_names(mod):
            if f'"{name}"' not in text and f"'{name}'" not in text:
                yield Finding(
                    rule="policy-enrollment", path=mod.path, line=line,
                    message=f"policy {name!r} registered in "
                            f"serving/policy.py but never named in "
                            f"test_policy.py — enroll it in the SHIPPED "
                            f"registry-shape check")
