"""Correctness tooling: static architectural lint + runtime sanitizers.

Two layers (docs/static_analysis.md):

* :mod:`repro.analysis.lint` — an AST-based linter whose rules encode the
  repo's architectural contracts (allocator privacy, single-registry
  dispatch, op/parity enrollment, tunable reachability, Pallas DMA pairing,
  no wall-clock in device code).  ``python -m repro.analysis.lint`` exits
  nonzero with ``file:line`` findings; ``tools/ci_fast.sh`` gates on it.
* :mod:`repro.analysis.sanitize` — runtime sanitizers behind the single
  ``ServeConfig.sanitize`` switch: retrace guard (zero steady-state
  recompiles across the engine step loop), host-sync guard (no device→host
  reads inside the overlap build half outside an explicit allowlist) and
  the allocator invariant checker
  (:meth:`repro.core.paged_kv.BlockAllocator.check_invariants`).

Both are import-light on purpose: the linter imports nothing but the
standard library (CI can run it before the heavyweight test tier), and the
sanitizers import jax only.
"""
